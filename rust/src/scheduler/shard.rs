//! Client-side shard selection: which of N provider endpoints a release
//! goes to.
//!
//! Selection conditions only on client-observable state — the client's own
//! submitted-not-yet-completed count per shard plus statically advertised
//! capacity weights (an operator knows the tier/region/rate-limit of its
//! own endpoints even though per-request behavior stays opaque). It never
//! sees a shard's hidden queue or running count: a full shard still
//! *accepts* the submission and queues it invisibly, so a bad pick costs
//! real latency. That asymmetry is why the policy choice matters.
//!
//! Policies:
//! * [`ShardPolicy::LeastInflight`] — argmin of the client's own in-flight
//!   count; the classic "join the shortest (observable) queue".
//! * [`ShardPolicy::Weighted`] — argmin of `(inflight+1)/weight`; sends
//!   proportionally more to advertised-faster shards, the right call for
//!   heterogeneous fleets.
//! * [`ShardPolicy::HashAffinity`] — deterministic hash of the request id;
//!   stateless and cache/session-friendly, blind to load.
//!
//! All ties break toward the lowest shard index, keeping every run
//! bit-reproducible.

use std::collections::HashMap;

use crate::core::ReqId;

/// Shard-selection policy (client-side).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardPolicy {
    LeastInflight,
    Weighted,
    HashAffinity,
}

impl ShardPolicy {
    pub const ALL: [ShardPolicy; 3] =
        [ShardPolicy::LeastInflight, ShardPolicy::Weighted, ShardPolicy::HashAffinity];

    pub fn name(self) -> &'static str {
        match self {
            ShardPolicy::LeastInflight => "least_inflight",
            ShardPolicy::Weighted => "weighted",
            ShardPolicy::HashAffinity => "hash_affinity",
        }
    }

    pub fn parse(s: &str) -> Option<ShardPolicy> {
        match s {
            "least_inflight" | "lif" => Some(ShardPolicy::LeastInflight),
            "weighted" | "wlif" => Some(ShardPolicy::Weighted),
            "hash_affinity" | "hash" => Some(ShardPolicy::HashAffinity),
            _ => None,
        }
    }
}

/// Client-side view of the endpoint fleet.
#[derive(Debug, Clone)]
pub struct ShardCfg {
    /// Endpoint count. 1 = the classic single-provider setup.
    pub n: usize,
    pub policy: ShardPolicy,
    /// Advertised relative capacity per shard (used by `Weighted`); empty
    /// means uniform. Length must be `n` when non-empty.
    pub weights: Vec<f64>,
}

impl ShardCfg {
    pub fn single() -> ShardCfg {
        ShardCfg { n: 1, policy: ShardPolicy::LeastInflight, weights: Vec::new() }
    }

    pub fn new(n: usize, policy: ShardPolicy, weights: Vec<f64>) -> ShardCfg {
        assert!(n >= 1, "need at least one shard");
        assert!(weights.is_empty() || weights.len() == n, "weights must match shard count");
        ShardCfg { n, policy, weights }
    }
}

impl Default for ShardCfg {
    fn default() -> Self {
        ShardCfg::single()
    }
}

/// SplitMix64 finalizer — the affinity hash. Deterministic, dependency-free,
/// and well-mixed over sequential ids.
#[inline]
fn hash_id(id: ReqId) -> u64 {
    let mut z = (id as u64).wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Stateful selector owned by the scheduler: picks a shard per release and
/// tracks the client's per-shard in-flight counts.
pub struct ShardSelector {
    cfg: ShardCfg,
    inflight: Vec<usize>,
    /// id → shard for in-flight requests (multi-shard only).
    assigned: HashMap<ReqId, u32>,
}

impl ShardSelector {
    pub fn new(cfg: ShardCfg) -> ShardSelector {
        assert!(cfg.n >= 1, "need at least one shard");
        assert!(
            cfg.weights.is_empty() || cfg.weights.len() == cfg.n,
            "weights must match shard count"
        );
        ShardSelector { inflight: vec![0; cfg.n], assigned: HashMap::new(), cfg }
    }

    pub fn n_shards(&self) -> usize {
        self.cfg.n
    }

    pub fn inflight(&self, shard: usize) -> usize {
        self.inflight[shard]
    }

    fn weight(&self, i: usize) -> f64 {
        if self.cfg.weights.is_empty() {
            1.0
        } else {
            self.cfg.weights[i]
        }
    }

    /// Choose the shard for `id`, record the assignment, and bump the
    /// client-side in-flight count. O(n_shards); the 1-shard fast path is
    /// branch-and-return (no map traffic), keeping the classic setup free.
    pub fn pick(&mut self, id: ReqId) -> usize {
        if self.cfg.n == 1 {
            return 0;
        }
        let shard = match self.cfg.policy {
            ShardPolicy::LeastInflight => {
                let mut best = 0usize;
                for (i, &f) in self.inflight.iter().enumerate().skip(1) {
                    if f < self.inflight[best] {
                        best = i;
                    }
                }
                best
            }
            ShardPolicy::Weighted => {
                let mut best = 0usize;
                let mut best_score = (self.inflight[0] as f64 + 1.0) / self.weight(0);
                for i in 1..self.cfg.n {
                    let score = (self.inflight[i] as f64 + 1.0) / self.weight(i);
                    if score < best_score {
                        best = i;
                        best_score = score;
                    }
                }
                best
            }
            ShardPolicy::HashAffinity => (hash_id(id) % self.cfg.n as u64) as usize,
        };
        self.inflight[shard] += 1;
        let prev = self.assigned.insert(id, shard as u32);
        debug_assert!(prev.is_none(), "shard pick for already-assigned {id}");
        shard
    }

    /// The request left the provider (completion or client abandon): free
    /// its shard's client-side slot. Unknown ids are ignored (e.g. a
    /// completion observed after abandon).
    pub fn on_done(&mut self, id: ReqId) {
        if self.cfg.n == 1 {
            return;
        }
        if let Some(s) = self.assigned.remove(&id) {
            self.inflight[s as usize] -= 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn selector(n: usize, policy: ShardPolicy, weights: Vec<f64>) -> ShardSelector {
        ShardSelector::new(ShardCfg::new(n, policy, weights))
    }

    #[test]
    fn least_inflight_round_robins_under_symmetry() {
        let mut s = selector(3, ShardPolicy::LeastInflight, vec![]);
        // Ties break to the lowest index, so fresh picks walk 0,1,2.
        assert_eq!(s.pick(10), 0);
        assert_eq!(s.pick(11), 1);
        assert_eq!(s.pick(12), 2);
        // Completing on shard 1 makes it least-loaded again.
        s.on_done(11);
        assert_eq!(s.pick(13), 1);
        assert_eq!(s.inflight(0), 1);
        assert_eq!(s.inflight(1), 1);
    }

    #[test]
    fn weighted_prefers_advertised_capacity() {
        // Shard 1 advertises 3× capacity: it should absorb ~3 of every 4.
        let mut s = selector(2, ShardPolicy::Weighted, vec![1.0, 3.0]);
        let mut counts = [0usize; 2];
        for id in 0..8 {
            counts[s.pick(id)] += 1;
        }
        assert_eq!(counts, [2, 6], "weighted split at 1:3");
    }

    #[test]
    fn hash_affinity_is_sticky_and_spread() {
        let mut a = selector(4, ShardPolicy::HashAffinity, vec![]);
        let mut b = selector(4, ShardPolicy::HashAffinity, vec![]);
        let mut counts = [0usize; 4];
        for id in 0..64 {
            let sa = a.pick(id);
            assert_eq!(sa, b.pick(id), "same id, same shard, always");
            counts[sa] += 1;
        }
        // The finalizer spreads sequential ids: no shard starves or hogs.
        for (i, c) in counts.iter().enumerate() {
            assert!((4..=28).contains(c), "shard {i} got {c}/64");
        }
    }

    #[test]
    fn single_shard_fast_path_is_free() {
        let mut s = selector(1, ShardPolicy::HashAffinity, vec![]);
        for id in 0..10 {
            assert_eq!(s.pick(id), 0);
        }
        s.on_done(3);
        assert_eq!(s.inflight(0), 0, "1-shard selector tracks nothing");
    }

    #[test]
    fn unknown_done_is_ignored() {
        let mut s = selector(2, ShardPolicy::LeastInflight, vec![]);
        s.pick(1);
        s.on_done(99);
        assert_eq!(s.inflight(0), 1);
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in ShardPolicy::ALL {
            assert_eq!(ShardPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(ShardPolicy::parse("bogus"), None);
    }
}
