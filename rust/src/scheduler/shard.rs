//! Client-side shard selection: which of N provider endpoints a release
//! goes to.
//!
//! Selection conditions only on client-observable state — the client's own
//! submitted-not-yet-completed count per shard plus statically advertised
//! capacity weights (an operator knows the tier/region/rate-limit of its
//! own endpoints even though per-request behavior stays opaque). It never
//! sees a shard's hidden queue or running count: a full shard still
//! *accepts* the submission and queues it invisibly, so a bad pick costs
//! real latency. That asymmetry is why the policy choice matters.
//!
//! Policies:
//! * [`ShardPolicy::LeastInflight`] — argmin of the client's own in-flight
//!   count; the classic "join the shortest (observable) queue".
//! * [`ShardPolicy::Weighted`] — argmin of `(inflight+1)/weight`; sends
//!   proportionally more to advertised-faster shards, the right call for
//!   heterogeneous fleets.
//! * [`ShardPolicy::HashAffinity`] — deterministic hash of the request id;
//!   stateless and cache/session-friendly, blind to load.
//!
//! All ties break toward the lowest shard index, keeping every run
//! bit-reproducible.

use std::collections::HashMap;

use crate::core::ReqId;
use crate::scheduler::state::ABANDON_TAIL_RATIO;
use crate::util::stats::Ewma;

/// EWMA smoothing for the per-shard tail signal — the same constant
/// `ApiState::tail_ratio` uses, so per-shard and global severity read the
/// same kind of quantity at the same timescale.
const TAIL_ALPHA: f64 = 0.15;

/// Tail ratio at or above which a shard reads as *unhealthy* to failover
/// routing. Strictly below [`ABANDON_TAIL_RATIO`] so a single saturating
/// timeout is enough to mark a shard down, and above the overload
/// controller's 1.5 tail cap so ordinary congestion never triggers
/// failover on its own.
pub const FAILOVER_TAIL_THRESHOLD: f64 = 1.8;

/// Per-fleet-completion geometric decay applied to an idle unhealthy
/// shard's tail, pulling it toward [`RECOVERY_DECAY_TARGET`]. From the
/// saturated 2.0 it takes 5 fleet completions to cross back under
/// [`FAILOVER_TAIL_THRESHOLD`] — fail down instantly, recover deliberately.
const RECOVERY_DECAY_FACTOR: f64 = 0.95;

/// Decay target: the "completion exactly at budget" tail ratio, i.e.
/// neutral-but-wary rather than provably calm.
const RECOVERY_DECAY_TARGET: f64 = 1.0;

/// Shard-selection policy (client-side).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardPolicy {
    /// Argmin of the client's own per-shard in-flight count.
    LeastInflight,
    /// Argmin of `(inflight+1)/weight` — capacity-aware least-inflight.
    Weighted,
    /// Deterministic hash of the request id; load-blind but sticky.
    HashAffinity,
}

impl ShardPolicy {
    /// Every policy, in CLI/report order.
    pub const ALL: [ShardPolicy; 3] =
        [ShardPolicy::LeastInflight, ShardPolicy::Weighted, ShardPolicy::HashAffinity];

    /// Stable CLI/CSV name (`--shard-policy <name>`).
    pub fn name(self) -> &'static str {
        match self {
            ShardPolicy::LeastInflight => "least_inflight",
            ShardPolicy::Weighted => "weighted",
            ShardPolicy::HashAffinity => "hash_affinity",
        }
    }

    /// Parse a [`ShardPolicy::name`] (plus short aliases).
    pub fn parse(s: &str) -> Option<ShardPolicy> {
        match s {
            "least_inflight" | "lif" => Some(ShardPolicy::LeastInflight),
            "weighted" | "wlif" => Some(ShardPolicy::Weighted),
            "hash_affinity" | "hash" => Some(ShardPolicy::HashAffinity),
            _ => None,
        }
    }
}

/// Client-side view of the endpoint fleet.
#[derive(Debug, Clone)]
pub struct ShardCfg {
    /// Endpoint count. 1 = the classic single-provider setup.
    pub n: usize,
    /// How releases are routed across the fleet.
    pub policy: ShardPolicy,
    /// Advertised relative capacity per shard (used by `Weighted`); empty
    /// means uniform. Length must be `n` when non-empty.
    pub weights: Vec<f64>,
    /// Route around unhealthy shards (tail ≥ [`FAILOVER_TAIL_THRESHOLD`])
    /// and decay their stale tail evidence so recovered shards regain
    /// traffic. Off by default: legacy routing is bit-identical.
    pub failover: bool,
}

impl ShardCfg {
    /// The classic single-endpoint setup (no routing decision to make).
    pub fn single() -> ShardCfg {
        ShardCfg { n: 1, policy: ShardPolicy::LeastInflight, weights: Vec::new(), failover: false }
    }

    /// A fleet of `n` shards routed by `policy`; `weights` may be empty
    /// (uniform) or one advertised capacity per shard.
    pub fn new(n: usize, policy: ShardPolicy, weights: Vec<f64>) -> ShardCfg {
        assert!(n >= 1, "need at least one shard");
        assert!(weights.is_empty() || weights.len() == n, "weights must match shard count");
        ShardCfg { n, policy, weights, failover: false }
    }

    /// Enable or disable failover routing (consuming builder).
    pub fn with_failover(mut self, failover: bool) -> ShardCfg {
        self.failover = failover;
        self
    }
}

impl Default for ShardCfg {
    fn default() -> Self {
        ShardCfg::single()
    }
}

/// SplitMix64 finalizer — the affinity hash. Deterministic, dependency-free,
/// and well-mixed over sequential ids.
#[inline]
fn hash_id(id: ReqId) -> u64 {
    let mut z = (id as u64).wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Stateful selector owned by the scheduler: picks a shard per release and
/// tracks the client's per-shard in-flight counts plus a per-shard
/// client-measured tail signal (EWMA of latency/deadline-budget among
/// completions routed there). Routing *and* shard-aware overload shedding
/// both condition on this one state — the shard the router would use is the
/// shard whose severity gates the release.
pub struct ShardSelector {
    cfg: ShardCfg,
    inflight: Vec<usize>,
    /// Per-shard EWMA of completion latency / deadline budget — the
    /// per-shard analogue of `ApiState::tail_ratio`.
    tail: Vec<Ewma>,
    /// id → shard for in-flight requests (multi-shard only).
    assigned: HashMap<ReqId, u32>,
}

impl ShardSelector {
    /// A selector for `cfg` with all shards idle and no tail evidence.
    pub fn new(cfg: ShardCfg) -> ShardSelector {
        assert!(cfg.n >= 1, "need at least one shard");
        assert!(
            cfg.weights.is_empty() || cfg.weights.len() == cfg.n,
            "weights must match shard count"
        );
        ShardSelector {
            inflight: vec![0; cfg.n],
            tail: (0..cfg.n).map(|_| Ewma::new(TAIL_ALPHA)).collect(),
            assigned: HashMap::new(),
            cfg,
        }
    }

    /// Number of shards in the fleet.
    pub fn n_shards(&self) -> usize {
        self.cfg.n
    }

    /// Client-side in-flight count currently attributed to `shard`.
    pub fn inflight(&self, shard: usize) -> usize {
        self.inflight[shard]
    }

    /// Per-shard client-measured tail ratio (0 until the shard has a
    /// completion) — the tail input to that shard's severity.
    pub fn tail_ratio(&self, shard: usize) -> f64 {
        self.tail[shard].get_or(0.0)
    }

    fn weight(&self, i: usize) -> f64 {
        if self.cfg.weights.is_empty() {
            1.0
        } else {
            self.cfg.weights[i]
        }
    }

    /// Choose the shard for `id`, record the assignment, and bump the
    /// client-side in-flight count. O(n_shards); the 1-shard fast path is
    /// branch-and-return (no map traffic), keeping the classic setup free.
    pub fn pick(&mut self, id: ReqId) -> usize {
        let shard = self.preview(id);
        self.commit(id, shard);
        shard
    }

    /// Choose the shard `id` *would* be routed to, without committing.
    ///
    /// Shard-aware overload control routes first and gates second: the
    /// scheduler previews the routing decision, evaluates that shard's
    /// severity, and only commits if the release is admitted — a deferred
    /// or rejected candidate never perturbs the in-flight bookkeeping.
    pub fn preview(&self, id: ReqId) -> usize {
        if self.cfg.n == 1 {
            return 0;
        }
        if self.cfg.failover {
            if let Some(shard) = self.preview_filtered(id, true) {
                return shard;
            }
            // Every shard unhealthy: fall through to the unfiltered policy —
            // degraded-everywhere routing beats routing nowhere.
        }
        self.preview_filtered(id, false).expect("unfiltered preview always picks a shard")
    }

    /// Whether `shard` is eligible under the (optional) health filter.
    fn usable(&self, shard: usize, healthy_only: bool) -> bool {
        !healthy_only || self.tail[shard].get_or(0.0) < FAILOVER_TAIL_THRESHOLD
    }

    /// The policy argmin restricted to usable shards. With
    /// `healthy_only = false` this is exactly the legacy policy (same
    /// lowest-index tie-breaks); with `true`, `HashAffinity` probes
    /// `(home + k) % n` for the first healthy shard so pinned sessions
    /// land on the nearest live neighbor deterministically.
    fn preview_filtered(&self, id: ReqId, healthy_only: bool) -> Option<usize> {
        match self.cfg.policy {
            ShardPolicy::LeastInflight => {
                let mut best: Option<usize> = None;
                for i in 0..self.cfg.n {
                    if !self.usable(i, healthy_only) {
                        continue;
                    }
                    if best.map_or(true, |b| self.inflight[i] < self.inflight[b]) {
                        best = Some(i);
                    }
                }
                best
            }
            ShardPolicy::Weighted => {
                let mut best: Option<(usize, f64)> = None;
                for i in 0..self.cfg.n {
                    if !self.usable(i, healthy_only) {
                        continue;
                    }
                    let score = (self.inflight[i] as f64 + 1.0) / self.weight(i);
                    if best.map_or(true, |(_, bs)| score < bs) {
                        best = Some((i, score));
                    }
                }
                best.map(|(i, _)| i)
            }
            ShardPolicy::HashAffinity => {
                let home = (hash_id(id) % self.cfg.n as u64) as usize;
                (0..self.cfg.n)
                    .map(|k| (home + k) % self.cfg.n)
                    .find(|&s| self.usable(s, healthy_only))
            }
        }
    }

    /// Record a routing decision from a prior [`ShardSelector::preview`]:
    /// bump the shard's client-side in-flight count and remember the
    /// assignment so the completion can be routed back.
    pub fn commit(&mut self, id: ReqId, shard: usize) {
        if self.cfg.n == 1 {
            return;
        }
        self.inflight[shard] += 1;
        let prev = self.assigned.insert(id, shard as u32);
        debug_assert!(prev.is_none(), "shard pick for already-assigned {id}");
    }

    /// Completion observed for `id`: update its shard's tail signal with
    /// the client-measured latency/deadline ratio (the same quantity the
    /// global severity tracks) and free the shard's client-side slot.
    pub fn on_completion(&mut self, id: ReqId, latency_ms: f64, deadline_budget_ms: f64) {
        if self.cfg.n == 1 {
            return;
        }
        if let Some(s) = self.assigned.remove(&id) {
            self.inflight[s as usize] -= 1;
            if deadline_budget_ms > 0.0 {
                self.tail[s as usize].push(latency_ms / deadline_budget_ms);
            }
            if self.cfg.failover {
                self.decay_unhealthy_idle(s as usize);
            }
        }
    }

    /// Unlearning path for censored tails (failover mode only): each fleet
    /// completion geometrically decays the tail of every *other* shard that
    /// is idle and unhealthy. A blacked-out shard never completes anything,
    /// so its saturated 2.0 tail would otherwise persist forever past
    /// recovery; decay lets it re-earn traffic after ~5 healthy-shard
    /// completions, and a failed probe re-saturates it instantly.
    fn decay_unhealthy_idle(&mut self, except: usize) {
        for j in 0..self.cfg.n {
            if j != except
                && self.inflight[j] == 0
                && self.tail[j].get_or(0.0) >= FAILOVER_TAIL_THRESHOLD
            {
                self.tail[j].decay_toward(RECOVERY_DECAY_TARGET, RECOVERY_DECAY_FACTOR);
            }
        }
    }

    /// Client abandoned an in-flight request (hard timeout): free its
    /// shard's slot and record a censored pessimistic tail observation
    /// ([`ABANDON_TAIL_RATIO`]). Without this, a shard slow enough to time
    /// requests out would keep an empty tail signal and a perpetually-reset
    /// in-flight count — reading as *calm* to both routing and the
    /// shard-aware cost ladder, the exact blind spot the per-shard signal
    /// exists to close. The *global* `ApiState::tail_ratio` records the
    /// same sample per abandon (PR 5 closed the ROADMAP "censored global
    /// tail" item), so single- and multi-endpoint severity agree on what a
    /// timeout means.
    pub fn on_abandon(&mut self, id: ReqId) {
        if self.cfg.n == 1 {
            return;
        }
        if let Some(s) = self.assigned.remove(&id) {
            self.inflight[s as usize] -= 1;
            if self.cfg.failover {
                // Saturate instead of blending: one timeout marks the shard
                // down (2.0 ≥ FAILOVER_TAIL_THRESHOLD) no matter how calm
                // its smoothed history was. Recovery goes through
                // `decay_unhealthy_idle`, never through averaging.
                self.tail[s as usize].set(ABANDON_TAIL_RATIO);
            } else {
                self.tail[s as usize].push(ABANDON_TAIL_RATIO);
            }
        }
    }
}

/// Carve `n_items` into `n_parts` balanced contiguous half-open ranges
/// (the first `n_items % n_parts` ranges get one extra item).
///
/// This is the deterministic partition map for the partitioned event loop
/// (`sim::partition`), applied at two granularities. Multi-tenant runs
/// carve *tenants*: selector state — in-flight counts, tail EWMAs, hash
/// affinity — is entirely tenant-local (nothing here aggregates across
/// tenants), so each partition carries its tenants' selectors untouched,
/// bit-identical to serial. Single-tenant request-local runs
/// (`SchedulerCfg::request_local`) carve *request ids* with the same map:
/// per-request decisions draw no cross-request state, so contiguous
/// arrival-order ranges split just as cleanly.
pub fn carve(n_items: usize, n_parts: usize) -> Vec<(usize, usize)> {
    assert!(n_parts >= 1, "need at least one part");
    let base = n_items / n_parts;
    let extra = n_items % n_parts;
    let mut out = Vec::with_capacity(n_parts);
    let mut lo = 0usize;
    for i in 0..n_parts {
        let len = base + usize::from(i < extra);
        out.push((lo, lo + len));
        lo += len;
    }
    debug_assert_eq!(lo, n_items);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn selector(n: usize, policy: ShardPolicy, weights: Vec<f64>) -> ShardSelector {
        ShardSelector::new(ShardCfg::new(n, policy, weights))
    }

    #[test]
    fn least_inflight_round_robins_under_symmetry() {
        let mut s = selector(3, ShardPolicy::LeastInflight, vec![]);
        // Ties break to the lowest index, so fresh picks walk 0,1,2.
        assert_eq!(s.pick(10), 0);
        assert_eq!(s.pick(11), 1);
        assert_eq!(s.pick(12), 2);
        // Completing on shard 1 makes it least-loaded again.
        s.on_completion(11, 100.0, 1_000.0);
        assert_eq!(s.pick(13), 1);
        assert_eq!(s.inflight(0), 1);
        assert_eq!(s.inflight(1), 1);
    }

    #[test]
    fn weighted_prefers_advertised_capacity() {
        // Shard 1 advertises 3× capacity: it should absorb ~3 of every 4.
        let mut s = selector(2, ShardPolicy::Weighted, vec![1.0, 3.0]);
        let mut counts = [0usize; 2];
        for id in 0..8 {
            counts[s.pick(id)] += 1;
        }
        assert_eq!(counts, [2, 6], "weighted split at 1:3");
    }

    #[test]
    fn hash_affinity_is_sticky_and_spread() {
        let mut a = selector(4, ShardPolicy::HashAffinity, vec![]);
        let mut b = selector(4, ShardPolicy::HashAffinity, vec![]);
        let mut counts = [0usize; 4];
        for id in 0..64 {
            let sa = a.pick(id);
            assert_eq!(sa, b.pick(id), "same id, same shard, always");
            counts[sa] += 1;
        }
        // The finalizer spreads sequential ids: no shard starves or hogs.
        for (i, c) in counts.iter().enumerate() {
            assert!((4..=28).contains(c), "shard {i} got {c}/64");
        }
    }

    #[test]
    fn single_shard_fast_path_is_free() {
        let mut s = selector(1, ShardPolicy::HashAffinity, vec![]);
        for id in 0..10 {
            assert_eq!(s.pick(id), 0);
        }
        s.on_completion(3, 10.0, 100.0);
        assert_eq!(s.inflight(0), 0, "1-shard selector tracks nothing");
        assert_eq!(s.tail_ratio(0), 0.0);
    }

    #[test]
    fn unknown_completion_is_ignored() {
        let mut s = selector(2, ShardPolicy::LeastInflight, vec![]);
        s.pick(1);
        s.on_completion(99, 10.0, 100.0);
        assert_eq!(s.inflight(0), 1);
        assert_eq!(s.tail_ratio(0), 0.0, "unknown id must not feed any shard's tail");
    }

    #[test]
    fn preview_does_not_commit() {
        let mut s = selector(2, ShardPolicy::LeastInflight, vec![]);
        // Previewing repeatedly is idempotent: no in-flight bookkeeping.
        assert_eq!(s.preview(1), 0);
        assert_eq!(s.preview(2), 0);
        assert_eq!(s.inflight(0), 0);
        // Commit applies it; the next preview sees the new load.
        s.commit(1, 0);
        assert_eq!(s.inflight(0), 1);
        assert_eq!(s.preview(2), 1);
        // pick == preview + commit.
        assert_eq!(s.pick(2), 1);
        assert_eq!(s.inflight(1), 1);
    }

    #[test]
    fn completion_feeds_the_shard_tail_signal() {
        let mut s = selector(2, ShardPolicy::LeastInflight, vec![]);
        assert_eq!(s.tail_ratio(0), 0.0, "no completions yet");
        s.pick(1); // shard 0
        s.pick(2); // shard 1
        // Shard 0 completes 2× over budget; shard 1 well within.
        s.on_completion(1, 5_000.0, 2_500.0);
        s.on_completion(2, 500.0, 2_500.0);
        assert!(s.tail_ratio(0) > s.tail_ratio(1), "hot shard carries the larger tail signal");
        assert!((s.tail_ratio(0) - 2.0).abs() < 1e-9, "first EWMA sample is the ratio itself");
        assert_eq!(s.inflight(0), 0);
        assert_eq!(s.inflight(1), 0);
    }

    #[test]
    fn timeout_abandon_pressures_the_shard_tail() {
        // A shard that times requests out must not read as calm: abandons
        // free the slot AND push a censored pessimistic tail sample.
        let mut s = selector(2, ShardPolicy::LeastInflight, vec![]);
        s.pick(1); // shard 0
        s.pick(2); // shard 1
        s.on_abandon(1);
        assert_eq!(s.inflight(0), 0, "slot freed");
        assert!(s.tail_ratio(0) >= 1.5, "abandon saturates the tail term: {}", s.tail_ratio(0));
        assert_eq!(s.tail_ratio(1), 0.0, "neighbor shard untouched");
        // Unknown/duplicate abandons stay inert.
        s.on_abandon(1);
        assert_eq!(s.inflight(0), 0);
    }

    fn failover_selector(n: usize, policy: ShardPolicy) -> ShardSelector {
        ShardSelector::new(ShardCfg::new(n, policy, vec![]).with_failover(true))
    }

    #[test]
    fn failover_routes_around_a_dead_shard() {
        let mut s = failover_selector(2, ShardPolicy::LeastInflight);
        s.pick(0); // shard 0
        s.on_abandon(0); // timeout: shard 0 saturates to 2.0 and is idle
        assert!(s.tail_ratio(0) >= FAILOVER_TAIL_THRESHOLD);
        // Shard 0 is idle (inflight 0 < shard 1's anything) but unhealthy:
        // every new pick must land on shard 1.
        for id in 1..6 {
            assert_eq!(s.pick(id), 1, "id {id} must avoid the dead shard");
        }
        assert_eq!(s.inflight(0), 0);
        assert_eq!(s.inflight(1), 5);
    }

    #[test]
    fn recovered_shard_regains_traffic() {
        // Regression for the unlearning gap: without decay, the censored
        // 2.0 tail from a blackout persists forever and a *recovered* shard
        // never sees traffic again.
        let mut s = failover_selector(2, ShardPolicy::LeastInflight);
        s.pick(0);
        s.on_abandon(0); // shard 0 marked down
        // Healthy-shard completions decay the stale evidence...
        let mut regained = None;
        for round in 0..20u64 {
            let id = 100 + round as usize;
            assert_eq!(s.pick(id), 1);
            s.on_completion(id, 100.0, 1_000.0);
            if s.tail_ratio(0) < FAILOVER_TAIL_THRESHOLD {
                regained = Some(round);
                break;
            }
        }
        let rounds = regained.expect("decay must eventually clear the censored tail");
        assert!((3..=10).contains(&rounds), "recovered after {rounds} completions");
        // ...and the recovered (idle) shard wins the next pick again.
        assert_eq!(s.pick(999), 0, "recovered shard regains traffic");
    }

    #[test]
    fn hash_affinity_probes_to_the_nearest_live_shard() {
        let mut s = failover_selector(4, ShardPolicy::HashAffinity);
        // Find an id homed on shard 2, then kill shard 2.
        let id = (0..1000).find(|&i| s.preview(i) == 2).unwrap();
        s.commit(id, 2);
        s.on_abandon(id);
        // The pinned id deterministically probes the next shard in ring
        // order instead of resubmitting into the dead one.
        assert_eq!(s.preview(id), 3);
        // Ids homed elsewhere keep their affinity.
        let other = (0..1000).find(|&i| s.preview(i) == 1).unwrap();
        assert_eq!(s.preview(other), 1);
    }

    #[test]
    fn failover_off_keeps_legacy_routing_bit_identical() {
        // Without the flag, an abandoned (idle) shard still wins
        // least-inflight — the pre-failover behavior existing tables bake in.
        let mut s = selector(2, ShardPolicy::LeastInflight, vec![]);
        s.pick(0);
        s.on_abandon(0);
        s.pick(1); // shard 0 idle again → legacy argmin picks it
        assert_eq!(s.inflight(0), 1, "legacy routing ignores the tail");
        // And abandons blend (EWMA push), not saturate: feed a calm history
        // first, then abandon — the blended value stays below saturation.
        let mut calm = selector(2, ShardPolicy::LeastInflight, vec![]);
        for id in 0..20 {
            calm.commit(id, 0);
            calm.on_completion(id, 100.0, 1_000.0);
        }
        calm.commit(99, 0);
        calm.on_abandon(99);
        assert!(calm.tail_ratio(0) < 1.0, "legacy abandon blends: {}", calm.tail_ratio(0));
    }

    #[test]
    fn all_shards_unhealthy_falls_back_to_unfiltered_policy() {
        let mut s = failover_selector(2, ShardPolicy::LeastInflight);
        for id in 0..2 {
            s.pick(id);
            s.on_abandon(id);
        }
        assert!(s.tail_ratio(0) >= FAILOVER_TAIL_THRESHOLD);
        assert!(s.tail_ratio(1) >= FAILOVER_TAIL_THRESHOLD);
        // Nothing healthy: route anyway, lowest-index tie-break.
        assert_eq!(s.pick(50), 0, "degraded-everywhere still routes");
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in ShardPolicy::ALL {
            assert_eq!(ShardPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(ShardPolicy::parse("bogus"), None);
    }

    #[test]
    fn carve_is_balanced_contiguous_and_covering() {
        for n_items in 0..40usize {
            for n_parts in 1..10usize {
                let parts = carve(n_items, n_parts);
                assert_eq!(parts.len(), n_parts, "{n_items}/{n_parts}");
                let mut expect_lo = 0usize;
                let (mut min_len, mut max_len) = (usize::MAX, 0usize);
                for &(lo, hi) in &parts {
                    assert_eq!(lo, expect_lo, "contiguous {n_items}/{n_parts}");
                    assert!(hi >= lo);
                    min_len = min_len.min(hi - lo);
                    max_len = max_len.max(hi - lo);
                    expect_lo = hi;
                }
                assert_eq!(expect_lo, n_items, "covering {n_items}/{n_parts}");
                assert!(max_len - min_len <= 1, "balanced {n_items}/{n_parts}");
            }
        }
    }

    #[test]
    fn carve_gives_every_part_work_when_items_suffice() {
        for &(n_items, n_parts) in &[(8usize, 4usize), (9, 4), (4, 4), (100, 7)] {
            for (lo, hi) in carve(n_items, n_parts) {
                assert!(hi > lo, "{n_items}/{n_parts}: empty part");
            }
        }
    }
}
