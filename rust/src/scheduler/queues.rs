//! Per-class client-side queues holding the scheduler's view of pending
//! requests.

use crate::core::{Class, Priors, ReqId};
use crate::predictor::Route;

/// The scheduler's view of one pending request (no hidden fields).
#[derive(Debug, Clone)]
pub struct SchedRequest {
    pub id: ReqId,
    pub arrival_ms: f64,
    pub deadline_ms: f64,
    pub priors: Priors,
    pub route: Route,
    /// Number of times overload control has deferred this request.
    pub defer_attempts: u32,
}

impl SchedRequest {
    pub fn class(&self) -> Class {
        self.route.class
    }
}

/// Two FIFO-ordered vectors (ordering policies select an index; removal is
/// O(n) with n = queue depth, which stays small — see benches).
pub struct ClassQueues {
    queues: [Vec<SchedRequest>; 2],
    /// Running sum of queued p50 estimates — the queue-pressure signal is
    /// read once per pump iteration, so it is maintained incrementally
    /// instead of rescanned (EXPERIMENTS.md §Perf opt 2).
    queued_tokens: f64,
}

impl ClassQueues {
    pub fn new() -> Self {
        ClassQueues { queues: [Vec::new(), Vec::new()], queued_tokens: 0.0 }
    }

    pub fn push(&mut self, req: SchedRequest) {
        self.queued_tokens += req.priors.p50;
        self.queues[req.class().index()].push(req);
    }

    /// Re-insert a deferred request keeping arrival order (stable position
    /// by arrival time) so deferral does not silently reset its seniority.
    pub fn push_ordered(&mut self, req: SchedRequest) {
        self.queued_tokens += req.priors.p50;
        let q = &mut self.queues[req.class().index()];
        let pos = q.partition_point(|r| r.arrival_ms <= req.arrival_ms);
        q.insert(pos, req);
    }

    pub fn queue(&self, class: Class) -> &[SchedRequest] {
        &self.queues[class.index()]
    }

    pub fn remove_at(&mut self, class: Class, idx: usize) -> SchedRequest {
        let req = self.queues[class.index()].remove(idx);
        self.queued_tokens -= req.priors.p50;
        req
    }

    /// Remove by request id (timeout cancel). Returns the request if found.
    pub fn remove_id(&mut self, id: ReqId) -> Option<SchedRequest> {
        for q in &mut self.queues {
            if let Some(pos) = q.iter().position(|r| r.id == id) {
                let req = q.remove(pos);
                self.queued_tokens -= req.priors.p50;
                return Some(req);
            }
        }
        None
    }

    pub fn len(&self, class: Class) -> usize {
        self.queues[class.index()].len()
    }

    pub fn total_len(&self) -> usize {
        self.queues[0].len() + self.queues[1].len()
    }

    pub fn is_empty(&self) -> bool {
        self.total_len() == 0
    }

    /// Sum of queued p50 token estimates (queue-pressure signal).
    /// O(1): maintained incrementally by push/remove.
    pub fn queued_tokens(&self) -> f64 {
        self.queued_tokens
    }
}

impl Default for ClassQueues {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::TokenBucket;

    fn sreq(id: ReqId, arrival: f64, bucket: TokenBucket, p50: f64) -> SchedRequest {
        SchedRequest {
            id,
            arrival_ms: arrival,
            deadline_ms: arrival + 1000.0,
            priors: Priors::new(p50, p50 * 1.5),
            route: Route::from_bucket(bucket),
            defer_attempts: 0,
        }
    }

    #[test]
    fn routes_to_class_queues() {
        let mut q = ClassQueues::new();
        q.push(sreq(1, 0.0, TokenBucket::Short, 30.0));
        q.push(sreq(2, 1.0, TokenBucket::XLong, 2000.0));
        q.push(sreq(3, 2.0, TokenBucket::Medium, 100.0));
        assert_eq!(q.len(Class::Interactive), 1);
        assert_eq!(q.len(Class::Heavy), 2);
        assert_eq!(q.total_len(), 3);
        assert_eq!(q.queued_tokens(), 2130.0);
    }

    #[test]
    fn remove_by_id() {
        let mut q = ClassQueues::new();
        q.push(sreq(1, 0.0, TokenBucket::Short, 30.0));
        q.push(sreq(2, 1.0, TokenBucket::Long, 500.0));
        assert_eq!(q.remove_id(2).unwrap().id, 2);
        assert_eq!(q.remove_id(2).map(|r| r.id), None);
        assert_eq!(q.total_len(), 1);
    }

    #[test]
    fn push_ordered_preserves_arrival_order() {
        let mut q = ClassQueues::new();
        q.push(sreq(1, 10.0, TokenBucket::Long, 500.0));
        q.push(sreq(2, 30.0, TokenBucket::Long, 500.0));
        // Deferred request that arrived at t=20 goes back between them.
        q.push_ordered(sreq(3, 20.0, TokenBucket::Long, 500.0));
        let ids: Vec<ReqId> = q.queue(Class::Heavy).iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 3, 2]);
    }

    #[test]
    fn remove_at_returns_request() {
        let mut q = ClassQueues::new();
        q.push(sreq(5, 0.0, TokenBucket::XLong, 1500.0));
        let r = q.remove_at(Class::Heavy, 0);
        assert_eq!(r.id, 5);
        assert!(q.is_empty());
    }
}
