//! Per-class client-side queues holding the scheduler's view of pending
//! requests.
//!
//! Storage is a slab: every queued [`SchedRequest`] lives in a stable slot,
//! the two classes are intrusive doubly-linked lists threaded through the
//! slots, and a dense id→slot table makes [`ClassQueues::remove_id`] O(1).
//! The previous representation (two `Vec`s with `Vec::remove`) cost O(n)
//! per removal and an O(n) scan per timeout cancel, which dominated the
//! event loop at large queue depths; the slab makes push/remove O(1) and
//! ordered re-insertion O(min(distance from head, distance from tail)) —
//! the lists stay arrival-sorted, so the boundary is found from both ends.

use crate::core::{Class, Priors, ReqId};
use crate::predictor::Route;
use crate::scheduler::ordering::Ordering;

const NIL: u32 = u32::MAX;

/// The scheduler's view of one pending request (no hidden fields).
#[derive(Debug, Clone)]
pub struct SchedRequest {
    /// Stable request id (dense per run — the request table index).
    pub id: ReqId,
    /// Arrival time (model ms).
    pub arrival_ms: f64,
    /// Absolute deadline (model ms).
    pub deadline_ms: f64,
    /// Policy-facing cost priors (p50/p90 output-token estimates).
    pub priors: Priors,
    /// Predictor route: the class and bucket this request was filed under.
    pub route: Route,
    /// Number of times overload control has deferred this request.
    pub defer_attempts: u32,
}

impl SchedRequest {
    /// The class queue this request is routed to.
    pub fn class(&self) -> Class {
        self.route.class
    }
}

/// One slab slot: the request plus its intrusive list links. Free slots
/// keep their last request value (plain data, no heap) and chain through
/// `next` onto the free list.
struct Slot {
    req: SchedRequest,
    prev: u32,
    next: u32,
    occupied: bool,
}

/// Slab-backed per-class FIFO queues with an id→slot index.
pub struct ClassQueues {
    slots: Vec<Slot>,
    free_head: u32,
    head: [u32; 2],
    tail: [u32; 2],
    len: [usize; 2],
    /// ReqId → slot (NIL when not queued). Ids are dense per run (the
    /// request table index), so a flat table beats hashing on the hot path.
    index: Vec<u32>,
    /// Running sum of queued p50 estimates — the queue-pressure signal is
    /// read once per pump iteration, so it is maintained incrementally
    /// instead of rescanned.
    queued_tokens: f64,
}

impl ClassQueues {
    /// Empty queues with no reserved slots.
    pub fn new() -> Self {
        ClassQueues {
            slots: Vec::new(),
            free_head: NIL,
            head: [NIL, NIL],
            tail: [NIL, NIL],
            len: [0, 0],
            index: Vec::new(),
            queued_tokens: 0.0,
        }
    }

    /// Allocate a slot for `req`, register it in the id index, and account
    /// its tokens. Links are initialized to NIL; the caller wires them.
    fn alloc(&mut self, req: SchedRequest) -> u32 {
        self.queued_tokens += req.priors.p50;
        let id = req.id;
        let slot = match self.free_head {
            NIL => {
                assert!(self.slots.len() < NIL as usize, "queue slot space exhausted");
                self.slots.push(Slot { req, prev: NIL, next: NIL, occupied: true });
                (self.slots.len() - 1) as u32
            }
            s => {
                self.free_head = self.slots[s as usize].next;
                let sl = &mut self.slots[s as usize];
                sl.req = req;
                sl.prev = NIL;
                sl.next = NIL;
                sl.occupied = true;
                s
            }
        };
        if id >= self.index.len() {
            self.index.resize(id + 1, NIL);
        }
        debug_assert_eq!(self.index[id], NIL, "request {id} queued twice");
        self.index[id] = slot;
        slot
    }

    /// Unlink `slot` from class list `c`, retire it, and return the request.
    fn unlink(&mut self, slot: u32, c: usize) -> SchedRequest {
        let (prev, next) = {
            let s = &self.slots[slot as usize];
            debug_assert!(s.occupied, "unlink of free slot");
            (s.prev, s.next)
        };
        if prev == NIL {
            self.head[c] = next;
        } else {
            self.slots[prev as usize].next = next;
        }
        if next == NIL {
            self.tail[c] = prev;
        } else {
            self.slots[next as usize].prev = prev;
        }
        self.len[c] -= 1;
        let s = &mut self.slots[slot as usize];
        s.occupied = false;
        s.next = self.free_head;
        self.free_head = slot;
        let req = s.req.clone();
        self.index[req.id] = NIL;
        self.queued_tokens -= req.priors.p50;
        req
    }

    /// Append to the tail of the request's class queue. O(1).
    pub fn push(&mut self, req: SchedRequest) {
        let c = req.class().index();
        let slot = self.alloc(req);
        let t = self.tail[c];
        self.slots[slot as usize].prev = t;
        if t == NIL {
            self.head[c] = slot;
        } else {
            self.slots[t as usize].next = slot;
        }
        self.tail[c] = slot;
        self.len[c] += 1;
    }

    /// Re-insert a deferred request keeping arrival order (stable position
    /// by arrival time) so deferral does not silently reset its seniority.
    ///
    /// The class lists stay arrival-sorted (plain pushes happen in event
    /// time order; this method preserves the order), so the insertion
    /// boundary — after the last node with `arrival_ms <=` the request's —
    /// is approached from both ends at once: O(min(distance from head,
    /// distance from tail)). Old deferred requests land near the head,
    /// urgency-deferred fresh ones near the tail; both walks are short.
    pub fn push_ordered(&mut self, req: SchedRequest) {
        let c = req.class().index();
        let arrival = req.arrival_ms;
        let mut front = self.head[c];
        let mut back = self.tail[c];
        loop {
            if front == NIL {
                // Empty class list.
                self.push(req);
                return;
            }
            if self.slots[front as usize].req.arrival_ms > arrival {
                // `front` is the first strictly-newer node.
                let slot = self.alloc(req);
                self.link_before(slot, front, c);
                return;
            }
            if self.slots[back as usize].req.arrival_ms <= arrival {
                // `back` is the last not-newer node: insert right after it.
                let next = self.slots[back as usize].next;
                if next == NIL {
                    self.push(req);
                } else {
                    let slot = self.alloc(req);
                    self.link_before(slot, next, c);
                }
                return;
            }
            front = self.slots[front as usize].next;
            back = self.slots[back as usize].prev;
        }
    }

    /// Link freshly allocated `slot` immediately before occupied node `at`.
    fn link_before(&mut self, slot: u32, at: u32, c: usize) {
        let prev = self.slots[at as usize].prev;
        self.slots[slot as usize].prev = prev;
        self.slots[slot as usize].next = at;
        self.slots[at as usize].prev = slot;
        if prev == NIL {
            self.head[c] = slot;
        } else {
            self.slots[prev as usize].next = slot;
        }
        self.len[c] += 1;
    }

    /// Remove the `idx`-th request (FIFO position) of a class. O(idx);
    /// kept for tests and model-checking — the dispatch path removes by id.
    pub fn remove_at(&mut self, class: Class, idx: usize) -> SchedRequest {
        let c = class.index();
        let mut at = self.head[c];
        for _ in 0..idx {
            assert!(at != NIL, "remove_at index {idx} out of bounds");
            at = self.slots[at as usize].next;
        }
        assert!(at != NIL, "remove_at index {idx} out of bounds");
        self.unlink(at, c)
    }

    /// Remove by request id (dispatch + timeout cancel). O(1).
    pub fn remove_id(&mut self, id: ReqId) -> Option<SchedRequest> {
        let slot = *self.index.get(id)?;
        if slot == NIL {
            return None;
        }
        let c = self.slots[slot as usize].req.class().index();
        Some(self.unlink(slot, c))
    }

    /// Queued request by id, if present. O(1).
    pub fn get(&self, id: ReqId) -> Option<&SchedRequest> {
        let slot = *self.index.get(id)?;
        if slot == NIL {
            None
        } else {
            Some(&self.slots[slot as usize].req)
        }
    }

    /// Oldest request of a class (FIFO head). O(1).
    pub fn head(&self, class: Class) -> Option<&SchedRequest> {
        let h = self.head[class.index()];
        if h == NIL {
            None
        } else {
            Some(&self.slots[h as usize].req)
        }
    }

    /// Iterate a class queue in FIFO order.
    pub fn iter(&self, class: Class) -> QueueIter<'_> {
        QueueIter { queues: self, at: self.head[class.index()] }
    }

    /// Borrowed view of one class queue — what ordering policies select
    /// from without materializing a slice.
    pub fn view(&self, class: Class) -> QueueView<'_> {
        QueueView { queues: self, class }
    }

    /// Queued request count of one class. O(1).
    pub fn len(&self, class: Class) -> usize {
        self.len[class.index()]
    }

    /// Queued request count across both classes. O(1).
    pub fn total_len(&self) -> usize {
        self.len[0] + self.len[1]
    }

    /// Whether both class queues are empty.
    pub fn is_empty(&self) -> bool {
        self.total_len() == 0
    }

    /// Sum of queued p50 token estimates (queue-pressure signal).
    /// O(1): maintained incrementally by push/remove.
    pub fn queued_tokens(&self) -> f64 {
        self.queued_tokens
    }

    // ---- hook-driving variants ----
    //
    // Every slab mutation on the scheduler's hot path notifies the mutated
    // class's ordering policy (see [`Ordering::on_push`]/[`on_remove`]), so
    // incremental ordering indexes stay consistent with the queue without
    // the pump re-deriving which class moved. `ordering` is the scheduler's
    // per-class pair `[interactive, heavy]`.

    /// [`ClassQueues::push`] + ordering lifecycle hook. O(1) + hook cost.
    pub fn push_with(
        &mut self,
        req: SchedRequest,
        ordering: &mut [Box<dyn Ordering>; 2],
        now: f64,
    ) {
        ordering[req.class().index()].on_push(&req, now);
        self.push(req);
    }

    /// [`ClassQueues::push_ordered`] + ordering lifecycle hook.
    pub fn push_ordered_with(
        &mut self,
        req: SchedRequest,
        ordering: &mut [Box<dyn Ordering>; 2],
        now: f64,
    ) {
        ordering[req.class().index()].on_push(&req, now);
        self.push_ordered(req);
    }

    /// [`ClassQueues::remove_id`] + ordering lifecycle hook. O(1) + hook.
    pub fn remove_id_with(
        &mut self,
        id: ReqId,
        ordering: &mut [Box<dyn Ordering>; 2],
    ) -> Option<SchedRequest> {
        let req = self.remove_id(id)?;
        ordering[req.class().index()].on_remove(&req);
        Some(req)
    }
}

impl Default for ClassQueues {
    fn default() -> Self {
        Self::new()
    }
}

/// FIFO-order iterator over one class queue.
pub struct QueueIter<'a> {
    queues: &'a ClassQueues,
    at: u32,
}

impl<'a> Iterator for QueueIter<'a> {
    type Item = &'a SchedRequest;

    fn next(&mut self) -> Option<&'a SchedRequest> {
        if self.at == NIL {
            return None;
        }
        let s = &self.queues.slots[self.at as usize];
        self.at = s.next;
        Some(&s.req)
    }
}

/// Borrowed single-class view handed to ordering policies.
#[derive(Clone, Copy)]
pub struct QueueView<'a> {
    queues: &'a ClassQueues,
    class: Class,
}

impl<'a> QueueView<'a> {
    /// Iterate the viewed class in FIFO (arrival) order.
    pub fn iter(&self) -> QueueIter<'a> {
        self.queues.iter(self.class)
    }

    /// Oldest request of the viewed class.
    pub fn head(&self) -> Option<&'a SchedRequest> {
        self.queues.head(self.class)
    }

    /// Queued request count of the viewed class. O(1).
    pub fn len(&self) -> usize {
        self.queues.len(self.class)
    }

    /// Whether the viewed class queue is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::TokenBucket;

    fn sreq(id: ReqId, arrival: f64, bucket: TokenBucket, p50: f64) -> SchedRequest {
        SchedRequest {
            id,
            arrival_ms: arrival,
            deadline_ms: arrival + 1000.0,
            priors: Priors::new(p50, p50 * 1.5),
            route: Route::from_bucket(bucket),
            defer_attempts: 0,
        }
    }

    #[test]
    fn routes_to_class_queues() {
        let mut q = ClassQueues::new();
        q.push(sreq(1, 0.0, TokenBucket::Short, 30.0));
        q.push(sreq(2, 1.0, TokenBucket::XLong, 2000.0));
        q.push(sreq(3, 2.0, TokenBucket::Medium, 100.0));
        assert_eq!(q.len(Class::Interactive), 1);
        assert_eq!(q.len(Class::Heavy), 2);
        assert_eq!(q.total_len(), 3);
        assert_eq!(q.queued_tokens(), 2130.0);
    }

    #[test]
    fn remove_by_id() {
        let mut q = ClassQueues::new();
        q.push(sreq(1, 0.0, TokenBucket::Short, 30.0));
        q.push(sreq(2, 1.0, TokenBucket::Long, 500.0));
        assert_eq!(q.remove_id(2).unwrap().id, 2);
        assert_eq!(q.remove_id(2).map(|r| r.id), None);
        assert_eq!(q.remove_id(999).map(|r| r.id), None, "unknown id");
        assert_eq!(q.total_len(), 1);
    }

    #[test]
    fn push_ordered_preserves_arrival_order() {
        let mut q = ClassQueues::new();
        q.push(sreq(1, 10.0, TokenBucket::Long, 500.0));
        q.push(sreq(2, 30.0, TokenBucket::Long, 500.0));
        // Deferred request that arrived at t=20 goes back between them.
        q.push_ordered(sreq(3, 20.0, TokenBucket::Long, 500.0));
        let ids: Vec<ReqId> = q.iter(Class::Heavy).map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 3, 2]);
    }

    #[test]
    fn push_ordered_ties_keep_earlier_first() {
        let mut q = ClassQueues::new();
        q.push(sreq(1, 10.0, TokenBucket::Long, 500.0));
        // Same arrival: the re-inserted request goes after the incumbent
        // (partition on `<=`, matching the old Vec implementation).
        q.push_ordered(sreq(2, 10.0, TokenBucket::Long, 500.0));
        let ids: Vec<ReqId> = q.iter(Class::Heavy).map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2]);
    }

    #[test]
    fn push_ordered_near_both_ends_and_middle() {
        let mut q = ClassQueues::new();
        for id in 0..8 {
            q.push(sreq(id, (id * 10) as f64, TokenBucket::Long, 100.0));
        }
        q.push_ordered(sreq(100, 5.0, TokenBucket::Long, 100.0)); // near head
        q.push_ordered(sreq(101, 75.0, TokenBucket::Long, 100.0)); // near tail
        q.push_ordered(sreq(102, 35.0, TokenBucket::Long, 100.0)); // middle
        q.push_ordered(sreq(103, 999.0, TokenBucket::Long, 100.0)); // append
        let ids: Vec<ReqId> = q.iter(Class::Heavy).map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 100, 1, 2, 3, 102, 4, 5, 6, 7, 101, 103]);
    }

    #[test]
    fn remove_at_returns_request() {
        let mut q = ClassQueues::new();
        q.push(sreq(5, 0.0, TokenBucket::XLong, 1500.0));
        let r = q.remove_at(Class::Heavy, 0);
        assert_eq!(r.id, 5);
        assert!(q.is_empty());
    }

    #[test]
    fn head_get_and_view() {
        let mut q = ClassQueues::new();
        assert!(q.head(Class::Heavy).is_none());
        q.push(sreq(7, 0.0, TokenBucket::Long, 400.0));
        q.push(sreq(8, 1.0, TokenBucket::Long, 900.0));
        assert_eq!(q.head(Class::Heavy).unwrap().id, 7);
        assert_eq!(q.get(8).unwrap().priors.p50, 900.0);
        assert!(q.get(9).is_none());
        let v = q.view(Class::Heavy);
        assert_eq!(v.len(), 2);
        assert_eq!(v.head().unwrap().id, 7);
        assert_eq!(v.iter().count(), 2);
        assert!(q.view(Class::Interactive).is_empty());
    }

    #[test]
    fn slots_are_reused_after_removal() {
        let mut q = ClassQueues::new();
        for id in 0..64 {
            q.push(sreq(id, id as f64, TokenBucket::Long, 100.0));
        }
        for id in 0..64 {
            assert_eq!(q.remove_id(id).unwrap().id, id);
        }
        // Refill: the slab must not grow beyond its high-water mark.
        for id in 64..128 {
            q.push(sreq(id, id as f64, TokenBucket::Long, 100.0));
        }
        assert_eq!(q.slots.len(), 64);
        assert_eq!(q.total_len(), 64);
        let ids: Vec<ReqId> = q.iter(Class::Heavy).map(|r| r.id).collect();
        assert_eq!(ids, (64..128).collect::<Vec<_>>());
    }

    /// Model-checks the slab against the original two-Vec implementation:
    /// production-shaped push/push_ordered/remove_at/remove_id sequences
    /// (plain pushes in nondecreasing event time, ordered re-inserts with
    /// past arrivals — the DES contract) must keep per-class order
    /// identical and `queued_tokens` equal to the true sum (the incremental
    /// counter's invariant).
    #[test]
    fn prop_matches_vec_model_and_queued_tokens_never_drifts() {
        use crate::testing::prop;

        prop::forall(120, |g| {
            let mut q = ClassQueues::new();
            let mut model: [Vec<SchedRequest>; 2] = [Vec::new(), Vec::new()];
            let mut next_id = 0usize;
            let mut clock = 0.0_f64;
            let n_ops = g.usize_in(1, 100);
            for _ in 0..n_ops {
                match g.usize_in(0, 5) {
                    0 | 1 => {
                        // New arrival: event time only moves forward.
                        clock += g.f64_in(0.0, 50.0);
                        let r = sreq(
                            next_id,
                            clock,
                            *g.choice(&TokenBucket::ALL),
                            g.f64_in(10.0, 3000.0),
                        );
                        next_id += 1;
                        model[r.class().index()].push(r.clone());
                        q.push(r);
                    }
                    2 => {
                        // Deferred re-insert: the request arrived in the
                        // past (never ahead of the event clock — the DES
                        // contract that keeps the class lists sorted).
                        let r = sreq(
                            next_id,
                            g.f64_in(0.0, clock),
                            *g.choice(&TokenBucket::ALL),
                            g.f64_in(10.0, 3000.0),
                        );
                        next_id += 1;
                        let m = &mut model[r.class().index()];
                        // After every element with arrival <= (the old
                        // partition_point semantics on a sorted queue).
                        let pos = m
                            .iter()
                            .position(|x| x.arrival_ms > r.arrival_ms)
                            .unwrap_or(m.len());
                        m.insert(pos, r.clone());
                        q.push_ordered(r);
                    }
                    3 => {
                        let (ci, class) = *g.choice(&[
                            (0usize, Class::Interactive),
                            (1usize, Class::Heavy),
                        ]);
                        if !model[ci].is_empty() {
                            let idx = g.usize_in(0, model[ci].len());
                            let got = q.remove_at(class, idx);
                            let want = model[ci].remove(idx);
                            assert_eq!(got.id, want.id);
                        }
                    }
                    _ => {
                        let id = g.usize_in(0, next_id.max(1));
                        let got = q.remove_id(id);
                        let found = model.iter().enumerate().find_map(|(ci, v)| {
                            v.iter().position(|x| x.id == id).map(|p| (ci, p))
                        });
                        match found {
                            Some((ci, p)) => {
                                let want = model[ci].remove(p);
                                assert_eq!(got.map(|r| r.id), Some(want.id));
                            }
                            None => assert!(got.is_none()),
                        }
                    }
                }
                // Invariants after every operation.
                let true_sum: f64 =
                    model.iter().flat_map(|v| v.iter()).map(|r| r.priors.p50).sum();
                let qt = q.queued_tokens();
                assert!(
                    (qt - true_sum).abs() <= 1e-6 * true_sum.max(1.0),
                    "queued_tokens drift: counter {qt} vs true sum {true_sum}"
                );
                for (ci, class) in [(0usize, Class::Interactive), (1usize, Class::Heavy)] {
                    assert_eq!(q.len(class), model[ci].len());
                    let got: Vec<ReqId> = q.iter(class).map(|r| r.id).collect();
                    let want: Vec<ReqId> = model[ci].iter().map(|r| r.id).collect();
                    assert_eq!(got, want, "class {ci} order diverged from model");
                }
                assert_eq!(q.total_len(), model[0].len() + model[1].len());
            }
        });
    }
}
