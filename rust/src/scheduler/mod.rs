//! The client-side layered scheduler — the paper's system contribution.
//!
//! Composition (paper §3.1): the **allocation** layer selects a class; the
//! **ordering** layer names a concrete request in that class; the
//! **overload** layer may block (defer) or shed (reject) that release.
//! Everything here conditions only on client-observable state
//! (`state::ApiState`) and policy-facing priors — the black-box constraint.
//!
//! Hot-path contract: every entry point *appends* its actions to a
//! caller-owned buffer instead of returning a fresh `Vec` — the driver
//! reuses one buffer for the whole run, so steady-state dispatch performs
//! no per-event allocations (queues are slab-backed, ordering selection is
//! a single pass, and removal is O(1) by id).

#![warn(missing_docs)]

pub mod allocation;
pub mod ordering;
pub mod overload;
pub mod queues;
pub mod shard;
pub mod state;

pub use ordering::OrderingCfg;
pub use shard::{ShardCfg, ShardPolicy};

use crate::core::{Class, Priors, ReqId, Request};
use crate::predictor::{Recalibrator, Route};
use allocation::{
    AdaptiveDrr, AllocCtx, Allocator, DrrCfg, FairQueuing, PacedFifo, QuotaTiered, ShortPriority,
};
use ordering::{Edf, FeasibleSet, Fifo, Ordering, RobustSjf, Sjf};
use overload::{OverloadCfg, OverloadController, OverloadDecision, SeveritySignals};
use queues::{ClassQueues, SchedRequest};
use shard::ShardSelector;
use state::ApiState;
use std::collections::HashMap;

/// Named strategies evaluated in the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StrategyKind {
    /// Uncontrolled dispatch: send on arrival, no cap, no layers.
    DirectNaive,
    /// Fixed per-class in-flight quotas, FIFO in class, no overload.
    QuotaTiered,
    /// Adaptive DRR + feasible-set ordering, no overload control.
    AdaptiveDrr,
    /// The full three-layer stack ("Final (OLC)").
    FinalAdrrOlc,
    /// Round-robin allocation (§4.6), FIFO in class.
    FairQueuing,
    /// Strict interactive priority (§4.6), FIFO in class.
    ShortPriority,
    /// Ablation: DRR without congestion adaptation, no overload.
    PlainDrr,
    /// Paced class-blind FIFO — Table 4's "Direct (FIFO)" baseline.
    PacedFifo,
}

impl StrategyKind {
    /// Every strategy, in the paper's presentation order (baselines first).
    pub const ALL: [StrategyKind; 8] = [
        StrategyKind::DirectNaive,
        StrategyKind::PacedFifo,
        StrategyKind::QuotaTiered,
        StrategyKind::AdaptiveDrr,
        StrategyKind::FinalAdrrOlc,
        StrategyKind::FairQueuing,
        StrategyKind::ShortPriority,
        StrategyKind::PlainDrr,
    ];

    /// Stable CLI/CSV name (`bbsched run --strategy <name>`).
    pub fn name(self) -> &'static str {
        match self {
            StrategyKind::DirectNaive => "direct_naive",
            StrategyKind::QuotaTiered => "quota_tiered",
            StrategyKind::AdaptiveDrr => "adaptive_drr",
            StrategyKind::FinalAdrrOlc => "final_adrr_olc",
            StrategyKind::FairQueuing => "fair_queuing",
            StrategyKind::ShortPriority => "short_priority",
            StrategyKind::PlainDrr => "plain_drr",
            StrategyKind::PacedFifo => "paced_fifo",
        }
    }

    /// Parse a CLI name (long form or shorthand) back into a strategy.
    pub fn parse(s: &str) -> Option<StrategyKind> {
        match s {
            "direct_naive" | "naive" => Some(StrategyKind::DirectNaive),
            "quota_tiered" | "quota" => Some(StrategyKind::QuotaTiered),
            "adaptive_drr" | "adrr" => Some(StrategyKind::AdaptiveDrr),
            "final_adrr_olc" | "final" => Some(StrategyKind::FinalAdrrOlc),
            "fair_queuing" | "fq" => Some(StrategyKind::FairQueuing),
            "short_priority" | "sp" => Some(StrategyKind::ShortPriority),
            "plain_drr" => Some(StrategyKind::PlainDrr),
            "paced_fifo" | "fifo" => Some(StrategyKind::PacedFifo),
            _ => None,
        }
    }
}

/// Intra-class ordering choice (the paper's design + ablations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OrderingKind {
    /// The paper's design: release the candidate whose admission leaves the
    /// feasible set (requests that can still meet their deadlines) largest.
    FeasibleSet,
    /// Arrival order — the ablation baseline.
    Fifo,
    /// Shortest job first by prior p50 cost.
    Sjf,
    /// Earliest deadline first.
    Edf,
    /// Uncertainty-aware SJF: orders by `p50 + θ·width`, demoting requests
    /// whose priors carry wide prediction intervals. Identical to `Sjf`
    /// when every prior is a point estimate (width 0).
    RobustSjf,
}

impl OrderingKind {
    /// Every ordering, paper design first (the `scale` experiment and the
    /// bench `--depth` leg sweep these). `RobustSjf` is deliberately NOT
    /// listed: it only differs from `Sjf` under interval priors, and adding
    /// it here would grow the `scale` grid and the bench depth tables —
    /// the `uncertainty` experiment sweeps it explicitly instead.
    pub const ALL: [OrderingKind; 4] =
        [OrderingKind::FeasibleSet, OrderingKind::Sjf, OrderingKind::Edf, OrderingKind::Fifo];

    fn build(self, cfg: &OrderingCfg) -> Box<dyn Ordering> {
        match self {
            OrderingKind::FeasibleSet => Box::new(FeasibleSet::new(cfg.clone())),
            OrderingKind::Fifo => Box::new(Fifo),
            OrderingKind::Sjf => Box::new(Sjf::new()),
            OrderingKind::Edf => Box::new(Edf::new()),
            OrderingKind::RobustSjf => Box::new(RobustSjf::new()),
        }
    }

    /// Stable CLI/CSV name.
    pub fn name(self) -> &'static str {
        match self {
            OrderingKind::FeasibleSet => "feasible_set",
            OrderingKind::Fifo => "fifo",
            OrderingKind::Sjf => "sjf",
            OrderingKind::Edf => "edf",
            OrderingKind::RobustSjf => "robust_sjf",
        }
    }

    /// Parse a CLI name back into an ordering.
    pub fn parse(s: &str) -> Option<OrderingKind> {
        match s {
            "feasible_set" => Some(OrderingKind::FeasibleSet),
            "fifo" => Some(OrderingKind::Fifo),
            "sjf" => Some(OrderingKind::Sjf),
            "edf" => Some(OrderingKind::Edf),
            "robust_sjf" => Some(OrderingKind::RobustSjf),
            _ => None,
        }
    }
}

/// Client retry-amplification policy: a timed-out or rejected request
/// re-enters the client as a fresh arrival after exponential backoff,
/// up to a per-request attempt budget. This is the storm generator —
/// under faults or overload, retries multiply offered load exactly when
/// capacity is scarcest — and the disabled default is a guaranteed
/// no-op (the sim driver consults it only on terminal outcomes).
#[derive(Debug, Clone, PartialEq)]
pub struct RetryCfg {
    /// Re-entries allowed per request after its first attempt; 0 disables
    /// retries entirely. Budget exhaustion is terminal (the request stays
    /// timed-out/rejected), so every retry storm terminates.
    pub max_attempts: u32,
    /// Backoff before retry `k` (0-based) is `base_ms · 2^k`, capped below.
    pub base_ms: f64,
    /// Backoff ceiling, ms.
    pub cap_ms: f64,
}

impl RetryCfg {
    /// No client retries (the default everywhere).
    pub fn disabled() -> Self {
        RetryCfg { max_attempts: 0, base_ms: 250.0, cap_ms: 4_000.0 }
    }

    /// Retry up to `max_attempts` times with `base_ms·2^k` backoff capped
    /// at `cap_ms`.
    pub fn new(max_attempts: u32, base_ms: f64, cap_ms: f64) -> Self {
        assert!(base_ms > 0.0 && cap_ms >= base_ms);
        RetryCfg { max_attempts, base_ms, cap_ms }
    }

    /// Whether any retry can ever fire.
    pub fn enabled(&self) -> bool {
        self.max_attempts > 0
    }

    /// Backoff delay before 0-based retry `attempt`.
    pub fn backoff_ms(&self, attempt: u32) -> f64 {
        (self.base_ms * f64::powi(2.0, attempt.min(30) as i32)).min(self.cap_ms)
    }
}

impl Default for RetryCfg {
    fn default() -> Self {
        RetryCfg::disabled()
    }
}

/// Full scheduler configuration.
#[derive(Debug, Clone)]
pub struct SchedulerCfg {
    /// Which named strategy this configuration composes.
    pub strategy: StrategyKind,
    /// Client's global in-flight budget (its own pacing target; the
    /// provider's true concurrency is unknown to it).
    pub max_inflight: usize,
    /// Extra in-flight headroom reserved for the interactive class: shorts
    /// are cheap, so the client may exceed its pacing budget by this much
    /// for them rather than park them behind heavy work ("protected
    /// share"). Quota-tiered ignores this (strict isolation).
    pub interactive_bypass: usize,
    /// Deficit round-robin allocation parameters.
    pub drr: DrrCfg,
    /// Feasible-set ordering parameters (score weights).
    pub ordering: OrderingCfg,
    /// Overload-control parameters (cost ladder thresholds, defer backoff).
    pub overload: OverloadCfg,
    /// `QuotaTiered` in-flight quota for the interactive class.
    pub quota_interactive: usize,
    /// `QuotaTiered` in-flight quota for the heavy class.
    pub quota_heavy: usize,
    /// Heavy-class ordering (interactive is always FIFO, matching §3.1:
    /// the feasible-set rule is specified "for the heavy class").
    pub heavy_ordering: OrderingKind,
    /// Endpoint fleet view: shard count, selection policy, advertised
    /// weights. Defaults to the classic single-provider setup; the sim
    /// driver reconciles `n`/weights with the actual `PoolCfg` it runs.
    pub shards: ShardCfg,
    /// Online interval recalibration: when `true`, the scheduler rescales
    /// each arriving prior's width by a per-route multiplier learned from
    /// observed completions (see `predictor::recal`). Off by default —
    /// disabled recalibration is a guaranteed bit-exact no-op.
    pub recalibrate: bool,
    /// Client retry amplification on terminal timeouts/rejects (the sim
    /// driver enforces it). Disabled by default — bit-exact no-op.
    pub retry: RetryCfg,
}

impl SchedulerCfg {
    /// The paper's default configuration for `strategy`: overload control
    /// enabled only for the full stack, everything else at §4 defaults.
    pub fn for_strategy(strategy: StrategyKind) -> Self {
        let overload = match strategy {
            StrategyKind::FinalAdrrOlc => OverloadCfg::default(),
            _ => OverloadCfg::disabled(),
        };
        SchedulerCfg {
            // The client paces around the provider's soft-capacity knee
            // (slowdown_ref ≈ 8): beyond it, everyone's generation slows —
            // which is how naive dispatch loses its short tail.
            strategy,
            max_inflight: 8,
            interactive_bypass: 4,
            drr: DrrCfg::default(),
            ordering: OrderingCfg::default(),
            overload,
            quota_interactive: 4,
            quota_heavy: 4,
            heavy_ordering: OrderingKind::FeasibleSet,
            shards: ShardCfg::single(),
            recalibrate: false,
            retry: RetryCfg::disabled(),
        }
    }

    /// Whether a scheduler built from this config makes every per-request
    /// decision independently of every other request — i.e. two fresh
    /// clones fed disjoint request subsets decide bit-identically to one
    /// instance fed the union.
    ///
    /// This is what lets the partitioned event loop carve a single-tenant
    /// run into contiguous request-id ranges (`sim/partition.rs`): each
    /// worker drives its own clone. It holds only for `DirectNaive`
    /// (dispatch immediately, no queues, no pacing budget consulted, no
    /// ordering or overload state) on a single-shard fleet (the one
    /// selector that draws no state) with recalibration off (the
    /// recalibrator learns cross-request multipliers). Client retries stay
    /// request-local either way: backoff is a deterministic per-attempt
    /// function and attempt counts live per request in the driver.
    pub fn request_local(&self) -> bool {
        matches!(self.strategy, StrategyKind::DirectNaive)
            && !self.recalibrate
            && self.shards.n == 1
    }
}

/// Scheduler output the driver must act on.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Action {
    /// Submit to provider endpoint `shard` now (0 for single-provider).
    Send { id: ReqId, shard: usize },
    /// Re-offer to the scheduler at `at_ms` (deferred).
    Retry { id: ReqId, at_ms: f64 },
    /// Shed explicitly.
    Reject { id: ReqId },
}

/// Aggregate policy-side statistics for a run.
#[derive(Debug, Clone, Default)]
pub struct SchedStats {
    /// Completed sends observed by the scheduler (the driver counts raw
    /// sends separately).
    pub sends: u64,
    /// Total defer decisions issued by the overload controller.
    pub defers: u64,
    /// Total reject decisions issued by the overload controller.
    pub rejects: u64,
    /// Releases where the chosen candidate shrank the feasible set.
    pub feasibility_violations: u64,
}

/// The composed client scheduler.
pub struct ClientScheduler {
    cfg: SchedulerCfg,
    allocator: Option<Box<dyn Allocator>>, // None for DirectNaive
    ordering: [Box<dyn Ordering>; 2],
    controller: OverloadController,
    queues: ClassQueues,
    deferred: HashMap<ReqId, SchedRequest>,
    state: ApiState,
    selector: ShardSelector,
    feasibility_violations_base: u64,
    recal: Recalibrator,
}

impl ClientScheduler {
    /// Compose the layers named by `cfg.strategy`.
    pub fn new(cfg: SchedulerCfg) -> Self {
        let allocator: Option<Box<dyn Allocator>> = match cfg.strategy {
            StrategyKind::DirectNaive => None,
            StrategyKind::QuotaTiered => {
                Some(Box::new(QuotaTiered::new(cfg.quota_interactive, cfg.quota_heavy)))
            }
            StrategyKind::AdaptiveDrr | StrategyKind::FinalAdrrOlc => {
                Some(Box::new(AdaptiveDrr::new(cfg.drr.clone())))
            }
            StrategyKind::PlainDrr => Some(Box::new(AdaptiveDrr::non_adaptive(cfg.drr.clone()))),
            StrategyKind::FairQueuing => Some(Box::new(FairQueuing::new())),
            StrategyKind::ShortPriority => Some(Box::new(ShortPriority::new())),
            StrategyKind::PacedFifo => Some(Box::new(PacedFifo::new())),
        };
        let heavy_ordering = match cfg.strategy {
            // Pure allocation-layer comparisons keep FIFO inside classes.
            StrategyKind::QuotaTiered
            | StrategyKind::FairQueuing
            | StrategyKind::ShortPriority
            | StrategyKind::PacedFifo
            | StrategyKind::DirectNaive => OrderingKind::Fifo,
            _ => cfg.heavy_ordering,
        };
        ClientScheduler {
            ordering: [Box::new(Fifo), heavy_ordering.build(&cfg.ordering)],
            allocator,
            controller: OverloadController::new(cfg.overload.clone()),
            queues: ClassQueues::new(),
            deferred: HashMap::new(),
            state: ApiState::new(),
            selector: ShardSelector::new(cfg.shards.clone()),
            feasibility_violations_base: 0,
            recal: if cfg.recalibrate {
                Recalibrator::enabled()
            } else {
                Recalibrator::disabled()
            },
            cfg,
        }
    }

    /// The configuration this scheduler was built from.
    pub fn cfg(&self) -> &SchedulerCfg {
        &self.cfg
    }

    /// Client-observable API state (in-flight set, latency signals).
    pub fn state(&self) -> &ApiState {
        &self.state
    }

    /// The overload controller (severity and defer/reject counters).
    pub fn controller(&self) -> &OverloadController {
        &self.controller
    }

    /// Requests currently queued client-side (both classes).
    pub fn queued(&self) -> usize {
        self.queues.total_len()
    }

    /// Requests parked in deferral backoff awaiting their retry event.
    pub fn deferred_count(&self) -> usize {
        self.deferred.len()
    }

    /// Feasibility violations recorded by the heavy ordering layer.
    pub fn feasibility_violations(&self) -> u64 {
        self.ordering_violations() + self.feasibility_violations_base
    }

    /// Cumulative ordering-index work done by releases (entries examined +
    /// migrations processed across both classes) — the deterministic
    /// per-release cost signal the bench `--depth` leg gates.
    pub fn ordering_work(&self) -> u64 {
        self.ordering[0].select_work() + self.ordering[1].select_work()
    }

    /// Peak distinct ordering index groups held across both classes —
    /// under quantized grouping this is the number of occupied prior bins,
    /// the quantity that bounds per-release scan cost.
    pub fn ordering_group_count(&self) -> u64 {
        self.ordering[0].group_count() + self.ordering[1].group_count()
    }

    /// Releases where an ordering index degenerated to examining every
    /// live entry on the selected side (full-scan fallback).
    pub fn ordering_scan_fallbacks(&self) -> u64 {
        self.ordering[0].scan_fallbacks() + self.ordering[1].scan_fallbacks()
    }

    /// The online interval recalibrator (per-route width multipliers).
    pub fn recalibrator(&self) -> &Recalibrator {
        &self.recal
    }

    /// Feed the recalibrator one *observed* completion: the source-claimed
    /// priors (pre-recalibration), the route, and the realized output
    /// length. The driver calls this only for real completions — abandoned
    /// and timed-out requests are censored and must never reach here.
    pub fn observe_completion(&mut self, claimed: Priors, route: &Route, observed_tokens: f64) {
        self.recal.observe(claimed, route, observed_tokens);
    }

    fn ordering_violations(&self) -> u64 {
        // Only FeasibleSet tracks violations; the trait default is 0.
        self.ordering[1].feasibility_violations()
    }

    // ---- event entry points ----
    //
    // All of them append the actions the driver must take to `out`; the
    // caller owns (and typically reuses) the buffer and clears it between
    // events.

    /// New request arrives with its policy-facing priors + route. When
    /// recalibration is on, the source-claimed interval width is rescaled
    /// by the route lane's learned multiplier before any layer sees it.
    pub fn on_arrival(
        &mut self,
        req: &Request,
        priors: Priors,
        route: Route,
        now: f64,
        out: &mut Vec<Action>,
    ) {
        let priors = self.recal.apply(priors, &route);
        let sreq = SchedRequest {
            id: req.id,
            arrival_ms: req.arrival_ms,
            deadline_ms: req.deadline_ms,
            priors,
            route,
            defer_attempts: 0,
        };
        if self.cfg.strategy == StrategyKind::DirectNaive {
            // Uncontrolled: straight to the provider, unbounded in-flight.
            self.state.on_send(sreq.id, route.class, priors.p50, now);
            let shard = self.selector.pick(sreq.id);
            out.push(Action::Send { id: sreq.id, shard });
            return;
        }
        self.queues.push_with(sreq, &mut self.ordering, now);
        self.pump(now, out);
    }

    /// A deferral backoff expired: the request re-enters its queue.
    pub fn on_retry_due(&mut self, id: ReqId, now: f64, out: &mut Vec<Action>) {
        if let Some(sreq) = self.deferred.remove(&id) {
            self.queues.push_ordered_with(sreq, &mut self.ordering, now);
        }
        self.pump(now, out);
    }

    /// Completion observed (client-measured latency).
    pub fn on_completion(
        &mut self,
        id: ReqId,
        latency_ms: f64,
        deadline_budget_ms: f64,
        now: f64,
        out: &mut Vec<Action>,
    ) {
        self.state.on_completion(id, latency_ms, deadline_budget_ms);
        self.selector.on_completion(id, latency_ms, deadline_budget_ms);
        if self.cfg.strategy == StrategyKind::DirectNaive {
            return;
        }
        self.pump(now, out);
    }

    /// Client gives up on a request (hard timeout). Removes it from any
    /// client-side holding area; frees the slot if it was in flight (and
    /// records the censored tail evidence against its shard).
    pub fn cancel(&mut self, id: ReqId, now: f64, out: &mut Vec<Action>) {
        let was_inflight = self.state.on_abandon(id).is_some();
        if was_inflight {
            self.selector.on_abandon(id);
        }
        let _ = self.queues.remove_id_with(id, &mut self.ordering);
        let _ = self.deferred.remove(&id);
        if was_inflight && self.cfg.strategy != StrategyKind::DirectNaive {
            self.pump(now, out);
        }
    }

    /// Core release loop: allocation → ordering → overload, repeated while
    /// slots and eligible work remain. Appends actions to `out`.
    pub fn pump(&mut self, now: f64, out: &mut Vec<Action>) {
        debug_assert!(self.cfg.strategy != StrategyKind::DirectNaive);
        // Quota-tiered is strict isolation: no interactive bypass.
        let bypass = if self.cfg.strategy == StrategyKind::QuotaTiered {
            0
        } else {
            self.cfg.interactive_bypass
        };
        loop {
            if self.queues.is_empty() {
                break;
            }
            let inflight = self.state.inflight();
            // Per-class release eligibility: heavy respects the pacing
            // budget; interactive may additionally use the bypass headroom.
            let can_send = [
                inflight < self.cfg.max_inflight + bypass, // interactive
                inflight < self.cfg.max_inflight,          // heavy
            ];
            if !can_send[0] && !can_send[1] {
                break;
            }
            // Severity drives both DRR adaptation and overload decisions.
            let signals = SeveritySignals::gather(&self.state, &self.queues, self.cfg.max_inflight);
            let severity = self.controller.severity(&signals);

            // Ordered head per class (classes at their cap are masked out).
            // Selection names the winner by id; the slab resolves it O(1).
            // Score-based orderings answer from incremental indexes kept
            // consistent by the lifecycle hooks every queue mutation below
            // drives — per-release cost is O(log depth + touched), not
            // O(live depth), so deep steady-state queues (rate scaling)
            // no longer make releases linear. See ordering/mod.rs.
            let mut head_id: [Option<ReqId>; 2] = [None, None];
            let mut head_cost = [None, None];
            let mut head_arrival = [None, None];
            for class in Class::ALL {
                let ci = class.index();
                if !can_send[ci] {
                    continue;
                }
                if let Some(id) = self.ordering[ci].select(self.queues.view(class), now) {
                    let r = self.queues.get(id).expect("ordering selected a queued id");
                    head_id[ci] = Some(id);
                    head_cost[ci] = Some(r.priors.p50);
                    head_arrival[ci] = Some(r.arrival_ms);
                }
            }
            let ctx = AllocCtx {
                congestion: severity,
                inflight_by_class: [
                    self.state.inflight_class(Class::Interactive),
                    self.state.inflight_class(Class::Heavy),
                ],
                head_cost,
                head_arrival,
            };
            let allocator = self.allocator.as_mut().expect("non-naive has allocator");
            let Some(class) = allocator.next_class(&ctx) else {
                break;
            };
            let id = head_id[class.index()].expect("allocator picked a backlogged class");
            // Route first, then gate: the shard the selector would use is
            // the shard whose severity the cost ladder evaluates, so
            // routing and shedding condition on the same per-shard state.
            // The 1-shard path keeps the global signal bit-for-bit (the
            // degenerate selector tracks nothing, and per-shard severity
            // would be the same quantity anyway).
            let shard = self.selector.preview(id);
            let decision = {
                let candidate = self.queues.get(id).expect("candidate still queued");
                let gate_severity = if self.selector.n_shards() == 1 {
                    severity
                } else {
                    let sh = SeveritySignals::gather_shard(
                        &self.selector,
                        &self.queues,
                        self.cfg.max_inflight,
                        shard,
                    );
                    self.controller.severity_value(&sh)
                };
                self.controller.decide(candidate, gate_severity)
            };
            let removed = self.queues.remove_id_with(id, &mut self.ordering);
            let mut sreq = removed.expect("candidate still queued");
            match decision {
                OverloadDecision::Admit => {
                    self.allocator.as_mut().unwrap().on_send(class, sreq.priors.p50);
                    self.state.on_send(sreq.id, class, sreq.priors.p50, now);
                    self.selector.commit(sreq.id, shard);
                    out.push(Action::Send { id: sreq.id, shard });
                }
                OverloadDecision::Defer { delay_ms } => {
                    sreq.defer_attempts += 1;
                    let at = now + delay_ms;
                    self.deferred.insert(id, sreq);
                    out.push(Action::Retry { id, at_ms: at });
                }
                OverloadDecision::Reject => {
                    out.push(Action::Reject { id: sreq.id });
                }
            }
        }
    }

    /// Run-level stats snapshot.
    pub fn stats(&self) -> SchedStats {
        SchedStats {
            sends: self.state.completions(), // completed sends; driver counts raw sends
            defers: self.controller.total_defers(),
            rejects: self.controller.total_rejects(),
            feasibility_violations: self.feasibility_violations(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{SloPolicy, TokenBucket};
    use crate::predictor::{InfoLevel, LadderSource, PriorSource};
    use crate::util::rng::Rng;
    use crate::workload::{Mix, SynthGen};

    fn requests(n: usize, mix: Mix) -> Vec<Request> {
        let mut g = SynthGen::new(mix, Rng::new(5));
        let slo = SloPolicy::default();
        (0..n).map(|i| g.sample(i, i as f64 * 10.0, &slo)).collect()
    }

    fn arrive_all(
        sched: &mut ClientScheduler,
        reqs: &[Request],
        src: &mut dyn PriorSource,
    ) -> Vec<Action> {
        let mut actions = Vec::new();
        for r in reqs {
            let (p, route) = src.priors(r);
            sched.on_arrival(r, p, route, r.arrival_ms, &mut actions);
        }
        actions
    }

    #[test]
    fn naive_sends_everything_immediately() {
        let mut sched = ClientScheduler::new(SchedulerCfg::for_strategy(StrategyKind::DirectNaive));
        let reqs = requests(30, Mix::Heavy);
        let mut src = LadderSource::new(InfoLevel::Coarse, Rng::new(1));
        let actions = arrive_all(&mut sched, &reqs, &mut src);
        assert_eq!(actions.len(), 30);
        assert!(actions.iter().all(|a| matches!(a, Action::Send { .. })));
        assert_eq!(sched.state().inflight(), 30, "no cap for naive");
    }

    #[test]
    fn sends_spread_across_shards_with_least_inflight() {
        let mut cfg = SchedulerCfg::for_strategy(StrategyKind::DirectNaive);
        cfg.shards = ShardCfg::new(3, ShardPolicy::LeastInflight, Vec::new());
        let mut sched = ClientScheduler::new(cfg);
        let reqs = requests(9, Mix::Balanced);
        let mut src = LadderSource::new(InfoLevel::Coarse, Rng::new(1));
        let actions = arrive_all(&mut sched, &reqs, &mut src);
        let mut counts = [0usize; 3];
        for a in &actions {
            if let Action::Send { shard, .. } = a {
                counts[*shard] += 1;
            }
        }
        assert_eq!(counts, [3, 3, 3], "no completions → least-inflight round-robins the fleet");
    }

    #[test]
    fn budget_caps_sends_and_queues_the_rest() {
        let mut cfg = SchedulerCfg::for_strategy(StrategyKind::AdaptiveDrr);
        cfg.max_inflight = 4;
        cfg.interactive_bypass = 0;
        let mut sched = ClientScheduler::new(cfg);
        let reqs = requests(20, Mix::Heavy);
        let mut src = LadderSource::new(InfoLevel::Coarse, Rng::new(1));
        let actions = arrive_all(&mut sched, &reqs, &mut src);
        let sends = actions.iter().filter(|a| matches!(a, Action::Send { .. })).count();
        assert_eq!(sends, 4);
        assert_eq!(sched.state().inflight(), 4);
        assert_eq!(sched.queued(), 16);
    }

    #[test]
    fn completion_releases_the_next_queued_request() {
        let mut cfg = SchedulerCfg::for_strategy(StrategyKind::AdaptiveDrr);
        cfg.max_inflight = 2;
        cfg.interactive_bypass = 0;
        let mut sched = ClientScheduler::new(cfg);
        let reqs = requests(5, Mix::Balanced);
        let mut src = LadderSource::new(InfoLevel::Oracle, Rng::new(1));
        let actions = arrive_all(&mut sched, &reqs, &mut src);
        let first: Vec<ReqId> = actions
            .iter()
            .filter_map(|a| if let Action::Send { id, .. } = a { Some(*id) } else { None })
            .collect();
        assert_eq!(first.len(), 2);
        let mut next = Vec::new();
        sched.on_completion(first[0], 300.0, 2500.0, 1_000.0, &mut next);
        assert_eq!(
            next.iter().filter(|a| matches!(a, Action::Send { .. })).count(),
            1,
            "slot handoff"
        );
    }

    #[test]
    fn interactive_bypass_admits_shorts_over_budget() {
        let mut cfg = SchedulerCfg::for_strategy(StrategyKind::AdaptiveDrr);
        cfg.max_inflight = 2;
        cfg.interactive_bypass = 3;
        let mut sched = ClientScheduler::new(cfg);
        // Fill the budget with heavy-class work only…
        let heavy: Vec<Request> = requests(60, Mix::Heavy)
            .into_iter()
            .filter(|r| r.true_bucket != TokenBucket::Short)
            .collect();
        let mut src = LadderSource::new(InfoLevel::Oracle, Rng::new(1));
        let _ = arrive_all(&mut sched, &heavy, &mut src);
        assert_eq!(sched.state().inflight(), 2);
        // …then a short must still go out through the bypass headroom.
        let mut g = SynthGen::new(Mix::Balanced, Rng::new(9));
        let slo = SloPolicy::default();
        let short = (0..200)
            .map(|i| g.sample(1000 + i, 500.0, &slo))
            .find(|r| r.true_bucket == TokenBucket::Short)
            .expect("a short sample");
        let (p, route) = src.priors(&short);
        let mut actions = Vec::new();
        sched.on_arrival(&short, p, route, 500.0, &mut actions);
        assert!(
            actions.iter().any(|a| matches!(a, Action::Send { id, .. } if *id == short.id)),
            "short must bypass the saturated budget: {actions:?}"
        );
    }

    #[test]
    fn cancel_removes_from_queue_and_frees_slots() {
        let mut cfg = SchedulerCfg::for_strategy(StrategyKind::AdaptiveDrr);
        cfg.max_inflight = 1;
        cfg.interactive_bypass = 0;
        let mut sched = ClientScheduler::new(cfg);
        let reqs = requests(3, Mix::Heavy);
        let mut src = LadderSource::new(InfoLevel::Oracle, Rng::new(1));
        let actions = arrive_all(&mut sched, &reqs, &mut src);
        let sent: ReqId = actions
            .iter()
            .find_map(|a| if let Action::Send { id, .. } = a { Some(*id) } else { None })
            .unwrap();
        assert_eq!(sched.queued(), 2);
        // Cancel a queued request: queue shrinks, no new send (slot busy).
        let queued_id = reqs.iter().map(|r| r.id).find(|id| *id != sent).unwrap();
        let mut actions = Vec::new();
        sched.cancel(queued_id, 100.0, &mut actions);
        assert!(actions.is_empty());
        assert_eq!(sched.queued(), 1);
        // Cancel the in-flight request: the slot frees and the pump releases
        // the remaining queued one.
        actions.clear();
        sched.cancel(sent, 200.0, &mut actions);
        assert_eq!(actions.iter().filter(|a| matches!(a, Action::Send { .. })).count(), 1);
    }

    #[test]
    fn recalibrator_learns_only_from_observed_completions() {
        let mut cfg = SchedulerCfg::for_strategy(StrategyKind::AdaptiveDrr);
        cfg.recalibrate = true;
        let mut sched = ClientScheduler::new(cfg);
        let route = Route::from_bucket(TokenBucket::Long);
        assert!(sched.recalibrator().is_enabled());
        // Arrivals alone — and any censored endings (timeouts, sheds,
        // cancels), which the driver never routes to observe_completion —
        // leave the lane untouched.
        let reqs = requests(10, Mix::Heavy);
        let claimed = Priors::with_width(800.0, 2000.0, 400.0);
        let mut actions = Vec::new();
        for r in &reqs {
            sched.on_arrival(r, claimed, route, r.arrival_ms, &mut actions);
        }
        assert_eq!(sched.recalibrator().observations(&route), 0);
        assert_eq!(sched.recalibrator().multiplier(&route), 1.0);
        // One observed completion well inside the claimed interval shrinks
        // the lane's multiplier; the width the next arrival sees follows.
        sched.observe_completion(claimed, &route, 820.0);
        assert_eq!(sched.recalibrator().observations(&route), 1);
        assert!(sched.recalibrator().multiplier(&route) < 1.0);
    }

    #[test]
    fn recalibrate_off_is_the_default_and_disabled() {
        let sched = ClientScheduler::new(SchedulerCfg::for_strategy(StrategyKind::AdaptiveDrr));
        assert!(!sched.recalibrator().is_enabled());
    }

    #[test]
    fn timeout_abandons_escalate_global_severity() {
        // Regression for the ROADMAP "censored global tail" item: a dead
        // provider that never completes anything used to keep the global
        // tail signal at 0 — severity read calm while every in-flight
        // request timed out. Each in-flight abandon now records the same
        // censored pessimistic sample the per-shard signal gets, so global
        // severity escalates even with zero completions.
        let mut cfg = SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc);
        cfg.max_inflight = 2;
        cfg.interactive_bypass = 0;
        let mut sched = ClientScheduler::new(cfg);
        let reqs: Vec<Request> = requests(8, Mix::Heavy);
        let mut src = LadderSource::new(InfoLevel::Oracle, Rng::new(1));
        let _ = arrive_all(&mut sched, &reqs, &mut src);
        assert!(sched.state().inflight() > 0, "some requests must have been released");
        // The dead-provider pattern: client timeouts fire for everything,
        // completions never arrive. Cancels of queued requests record no
        // sample (nothing was observed); in-flight abandons record 2.0.
        let mut actions = Vec::new();
        for r in &reqs {
            sched.cancel(r.id, 10_000.0, &mut actions);
        }
        assert_eq!(sched.state().inflight(), 0);
        assert!(
            sched.state().tail_ratio.get_or(0.0) >= 1.5,
            "abandons must saturate the global tail signal: {}",
            sched.state().tail_ratio.get_or(0.0)
        );
        let signals = SeveritySignals::gather(&sched.state, &sched.queues, sched.cfg.max_inflight);
        let sev = sched.controller.severity_value(&signals);
        assert!(sev > 0.25, "dead endpoint must escalate severity, got {sev}");
    }

    #[test]
    fn deferred_requests_return_via_retry() {
        let mut cfg = SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc);
        // Force high severity: tiny budget so load signal saturates.
        cfg.max_inflight = 1;
        cfg.interactive_bypass = 0;
        cfg.overload.queue_budget_tokens = 100.0;
        let mut sched = ClientScheduler::new(cfg);
        // Long/xlong only: mediums carry ladder weight 0 and are always
        // admitted, which is itself part of the design under test.
        let reqs: Vec<Request> = requests(80, Mix::Heavy)
            .into_iter()
            .filter(|r| {
                matches!(r.true_bucket, TokenBucket::Long | TokenBucket::XLong)
            })
            .collect();
        let mut src = LadderSource::new(InfoLevel::Oracle, Rng::new(1));
        let actions = arrive_all(&mut sched, &reqs, &mut src);
        let sent: ReqId = actions
            .iter()
            .find_map(|a| if let Action::Send { id, .. } = a { Some(*id) } else { None })
            .expect("first request sends");
        // Releases are evaluated when a slot frees: completing the in-flight
        // request while queue pressure is saturated must defer/reject the
        // next heavy candidates instead of admitting them.
        let mut actions = Vec::new();
        sched.on_completion(sent, 5_000.0, 2_500.0, 6_000.0, &mut actions);
        let deferred: Vec<(ReqId, f64)> = actions
            .iter()
            .filter_map(|a| if let Action::Retry { id, at_ms } = a { Some((*id, *at_ms)) } else { None })
            .collect();
        assert!(!deferred.is_empty(), "severity must trigger defers: {actions:?}");
        assert_eq!(sched.deferred_count(), deferred.len());
        // Retry re-enters the queue (or sheds again) — never lost.
        let before = sched.deferred_count();
        let mut retry_actions = Vec::new();
        sched.on_retry_due(deferred[0].0, deferred[0].1, &mut retry_actions);
        assert!(sched.deferred_count() <= before);
    }

    #[test]
    fn strategy_parse_roundtrip() {
        for s in StrategyKind::ALL {
            assert_eq!(StrategyKind::parse(s.name()), Some(s));
        }
        assert_eq!(StrategyKind::parse("bogus"), None);
    }
}
