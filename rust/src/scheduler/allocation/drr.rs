//! Deficit Round Robin with congestion-adaptive weights and work-conserving
//! borrowing — the paper's allocation design (§3.1 layer 1).
//!
//! Each class keeps a deficit counter in estimated-token units. When
//! visited, a backlogged class earns `quantum × effective_weight`; it may
//! send when its deficit covers the head's estimated cost. An idle class's
//! deficit resets (classic DRR), so its unused share is consumed by the
//! backlogged peer — work conservation. Congestion feedback scales the
//! interactive class's effective weight up under stress, biasing send
//! opportunities toward latency-sensitive work exactly when it matters.

use super::{AllocCtx, Allocator};
use crate::core::Class;

/// DRR parameters (quantum, class weights, congestion gain).
#[derive(Debug, Clone)]
pub struct DrrCfg {
    /// Tokens granted per visit (before weighting).
    pub quantum_tokens: f64,
    /// Base weight of the interactive class.
    pub w_interactive: f64,
    /// Base weight of the heavy class.
    pub w_heavy: f64,
    /// Interactive weight multiplier grows to (1 + gain) at severity 1.
    pub adaptive_gain: f64,
}

impl Default for DrrCfg {
    fn default() -> Self {
        DrrCfg { quantum_tokens: 400.0, w_interactive: 2.0, w_heavy: 1.0, adaptive_gain: 1.5 }
    }
}

/// Deficit round-robin allocator, optionally congestion-adaptive.
pub struct AdaptiveDrr {
    cfg: DrrCfg,
    deficit: [f64; 2],
    /// Round-robin pointer: which class is visited next.
    ptr: usize,
    /// Whether the class under the pointer has already received its quantum
    /// for the current visit (classic DRR grants once per visit).
    granted_this_visit: bool,
    /// Rotations bound per decision (cost/quantum can need several grants).
    max_rotations: usize,
    /// Whether weights react to congestion (false = plain DRR ablation).
    adaptive: bool,
}

impl AdaptiveDrr {
    /// Congestion-adaptive DRR (the paper's design).
    pub fn new(cfg: DrrCfg) -> Self {
        AdaptiveDrr {
            cfg,
            deficit: [0.0, 0.0],
            ptr: 0,
            granted_this_visit: false,
            max_rotations: 64,
            adaptive: true,
        }
    }

    /// Plain DRR without congestion adaptation (ablation).
    pub fn non_adaptive(cfg: DrrCfg) -> Self {
        AdaptiveDrr { adaptive: false, ..Self::new(cfg) }
    }

    fn eff_weight(&self, class: Class, congestion: f64) -> f64 {
        match class {
            Class::Interactive => {
                let boost = if self.adaptive { 1.0 + self.cfg.adaptive_gain * congestion } else { 1.0 };
                self.cfg.w_interactive * boost
            }
            Class::Heavy => self.cfg.w_heavy,
        }
    }

    /// Current deficit counter of `class`, in estimated-token units.
    pub fn deficit(&self, class: Class) -> f64 {
        self.deficit[class.index()]
    }

    fn advance(&mut self) {
        self.ptr = 1 - self.ptr;
        self.granted_this_visit = false;
    }
}

impl Allocator for AdaptiveDrr {
    fn next_class(&mut self, ctx: &AllocCtx) -> Option<Class> {
        if !ctx.any_backlog() {
            return None;
        }
        // Visit classes round-robin; a backlogged class earns one quantum
        // per *visit* (not per call — on_send keeps the pointer in place so
        // a class serves its whole deficit burst before rotating, classic
        // DRR). Bounded: with at least one backlogged class, each full
        // rotation strictly increases that class's deficit, so eligibility
        // is reached in ≤ cost/quantum rotations (capped by max_rotations
        // for safety — hitting the cap grants the most-starved backlogged
        // class anyway to preserve work conservation).
        for _ in 0..self.max_rotations * 2 {
            let class = Class::ALL[self.ptr];
            match ctx.head(class) {
                None => {
                    // Idle class: reset deficit (classic DRR), pass the
                    // opportunity to the peer — borrowing.
                    self.deficit[class.index()] = 0.0;
                    self.advance();
                }
                Some(cost) => {
                    if self.deficit[class.index()] >= cost {
                        return Some(class);
                    }
                    if !self.granted_this_visit {
                        self.granted_this_visit = true;
                        self.deficit[class.index()] +=
                            self.cfg.quantum_tokens * self.eff_weight(class, ctx.congestion);
                        if self.deficit[class.index()] >= cost {
                            return Some(class);
                        }
                    }
                    self.advance();
                }
            }
        }
        // Safety valve: pick the backlogged class with the largest
        // deficit/cost ratio so the scheduler never stalls with free slots.
        Class::ALL
            .iter()
            .copied()
            .filter(|c| ctx.head(*c).is_some())
            .max_by(|a, b| {
                let ra = self.deficit[a.index()] / ctx.head(*a).unwrap().max(1.0);
                let rb = self.deficit[b.index()] / ctx.head(*b).unwrap().max(1.0);
                ra.partial_cmp(&rb).unwrap()
            })
    }

    fn on_send(&mut self, class: Class, cost: f64) {
        let d = &mut self.deficit[class.index()];
        *d = (*d - cost).max(-cost); // deficit may dip; clamp runaway
    }

    fn name(&self) -> &'static str {
        if self.adaptive {
            "adaptive_drr"
        } else {
            "drr"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::ctx;
    use super::*;

    fn drr() -> AdaptiveDrr {
        AdaptiveDrr::new(DrrCfg::default())
    }

    #[test]
    fn empty_queues_yield_none() {
        let mut d = drr();
        assert_eq!(d.next_class(&ctx(None, None)), None);
    }

    #[test]
    fn single_backlog_borrows_everything() {
        let mut d = drr();
        // Only heavy backlogged: must always be served (work conservation),
        // even with a huge head cost.
        for _ in 0..10 {
            let c = d.next_class(&ctx(None, Some(3000.0))).unwrap();
            assert_eq!(c, Class::Heavy);
            d.on_send(Class::Heavy, 3000.0);
        }
    }

    #[test]
    fn share_follows_weights() {
        let mut d = drr();
        let mut sends = [0u32; 2];
        // Equal head costs; interactive weight 2 vs heavy 1 → ≈2:1 token share.
        for _ in 0..3000 {
            let c = d.next_class(&ctx(Some(100.0), Some(100.0))).unwrap();
            sends[c.index()] += 1;
            d.on_send(c, 100.0);
        }
        let ratio = sends[0] as f64 / sends[1] as f64;
        assert!((ratio - 2.0).abs() < 0.2, "ratio={ratio} sends={sends:?}");
    }

    #[test]
    fn token_share_balances_unequal_costs() {
        // DRR equalizes *token* share, not send counts: with heavy heads 10×
        // the cost, heavy should get ~10× fewer sends at equal weights.
        let mut d = AdaptiveDrr::new(DrrCfg {
            w_interactive: 1.0,
            w_heavy: 1.0,
            ..DrrCfg::default()
        });
        let mut tokens = [0f64; 2];
        for _ in 0..5000 {
            let c = d.next_class(&ctx(Some(50.0), Some(500.0))).unwrap();
            let cost = if c == Class::Interactive { 50.0 } else { 500.0 };
            tokens[c.index()] += cost;
            d.on_send(c, cost);
        }
        let ratio = tokens[0] / tokens[1];
        assert!((ratio - 1.0).abs() < 0.25, "token ratio={ratio}");
    }

    #[test]
    fn congestion_boosts_interactive() {
        let share = |congestion: f64| {
            let mut d = drr();
            let mut sends = [0u32; 2];
            for _ in 0..2000 {
                let mut c = ctx(Some(100.0), Some(100.0));
                c.congestion = congestion;
                let cls = d.next_class(&c).unwrap();
                sends[cls.index()] += 1;
                d.on_send(cls, 100.0);
            }
            sends[0] as f64 / (sends[0] + sends[1]) as f64
        };
        let calm = share(0.0);
        let stressed = share(1.0);
        assert!(stressed > calm + 0.1, "calm={calm} stressed={stressed}");
    }

    #[test]
    fn non_adaptive_ignores_congestion() {
        let mut d = AdaptiveDrr::non_adaptive(DrrCfg::default());
        let mut sends = [0u32; 2];
        for _ in 0..2000 {
            let mut c = ctx(Some(100.0), Some(100.0));
            c.congestion = 1.0;
            let cls = d.next_class(&c).unwrap();
            sends[cls.index()] += 1;
            d.on_send(cls, 100.0);
        }
        let ratio = sends[0] as f64 / sends[1] as f64;
        assert!((ratio - 2.0).abs() < 0.2, "ratio={ratio}");
    }

    #[test]
    fn idle_class_deficit_resets() {
        let mut d = drr();
        // Build interactive deficit…
        let _ = d.next_class(&ctx(Some(10_000.0), None));
        assert!(d.deficit(Class::Interactive) > 0.0);
        // …then interactive goes idle: a decision with it empty resets it.
        let _ = d.next_class(&ctx(None, Some(100.0)));
        assert_eq!(d.deficit(Class::Interactive), 0.0);
    }
}
