//! Paced global FIFO — the "Direct (FIFO)" baseline of Table 4 (§4.6).
//!
//! Unlike `DirectNaive` (which floods the provider), paced FIFO respects the
//! client's in-flight budget but ignores classes entirely: the next send
//! opportunity always goes to the oldest queued request, whichever class it
//! sits in. Size-blind and class-blind — the pre-semi-clairvoyant default.

use super::{AllocCtx, Allocator};
use crate::core::Class;

/// Chooses the class whose head arrived first. Requires `head_arrival` to
/// be populated in the context (the scheduler fills it for all allocators).
pub struct PacedFifo;

impl PacedFifo {
    /// Construct the (stateless) policy.
    pub fn new() -> Self {
        PacedFifo
    }
}

impl Default for PacedFifo {
    fn default() -> Self {
        Self::new()
    }
}

impl Allocator for PacedFifo {
    fn next_class(&mut self, ctx: &AllocCtx) -> Option<Class> {
        match (ctx.head_arrival[0], ctx.head_arrival[1]) {
            (Some(a), Some(b)) => {
                if a <= b {
                    Some(Class::Interactive)
                } else {
                    Some(Class::Heavy)
                }
            }
            (Some(_), None) => Some(Class::Interactive),
            (None, Some(_)) => Some(Class::Heavy),
            (None, None) => None,
        }
    }

    fn on_send(&mut self, _class: Class, _cost: f64) {}

    fn name(&self) -> &'static str {
        "paced_fifo"
    }
}

#[cfg(test)]
mod tests {
    use super::super::ctx;
    use super::*;

    #[test]
    fn picks_older_head_across_classes() {
        let mut pf = PacedFifo::new();
        let mut c = ctx(Some(10.0), Some(1000.0));
        c.head_arrival = [Some(50.0), Some(20.0)];
        assert_eq!(pf.next_class(&c), Some(Class::Heavy));
        c.head_arrival = [Some(5.0), Some(20.0)];
        assert_eq!(pf.next_class(&c), Some(Class::Interactive));
    }

    #[test]
    fn single_class_served() {
        let mut pf = PacedFifo::new();
        let mut c = ctx(None, Some(1000.0));
        c.head_arrival = [None, Some(20.0)];
        assert_eq!(pf.next_class(&c), Some(Class::Heavy));
        let mut c = ctx(None, None);
        c.head_arrival = [None, None];
        assert_eq!(pf.next_class(&c), None);
    }
}
