//! Allocation layer (paper §3.1 layer 1): inter-class share of send
//! opportunities. Implementations: adaptive DRR (the paper's design),
//! Fair Queuing (round-robin, §4.6), Short-Priority (strict priority,
//! §4.6), and quota-tiered isolation (baseline in §4.5).

pub mod drr;
pub mod fair_queuing;
pub mod paced_fifo;
pub mod quota;
pub mod short_priority;

pub use drr::{AdaptiveDrr, DrrCfg};
pub use fair_queuing::FairQueuing;
pub use paced_fifo::PacedFifo;
pub use quota::QuotaTiered;
pub use short_priority::ShortPriority;

use crate::core::Class;

/// Context for one allocation decision — only client-observable signals.
#[derive(Debug, Clone, Copy)]
pub struct AllocCtx {
    /// Congestion signal in [0, 1] (overload severity; 0 when unknown).
    pub congestion: f64,
    /// Client in-flight counts per class.
    pub inflight_by_class: [usize; 2],
    /// Estimated cost (p50 tokens) of each class's *ordered* head, None if
    /// the class queue is empty.
    pub head_cost: [Option<f64>; 2],
    /// Arrival time of each class's ordered head (for class-blind FIFO).
    pub head_arrival: [Option<f64>; 2],
}

impl AllocCtx {
    /// Estimated cost of `class`'s ordered head (`None` = empty queue).
    pub fn head(&self, class: Class) -> Option<f64> {
        self.head_cost[class.index()]
    }

    /// Whether any class has queued work.
    pub fn any_backlog(&self) -> bool {
        self.head_cost.iter().any(Option::is_some)
    }
}

/// Inter-class share policy.
///
/// `Send` is a supertrait for the same reason as [`Ordering`]'s: tenant
/// schedulers cross into partition worker threads
/// (`sim::partition`), boxed allocator included.
///
/// [`Ordering`]: crate::scheduler::ordering::Ordering
pub trait Allocator: Send {
    /// Which class gets the next send opportunity? `None` = no eligible
    /// class (all queues empty, or quota exhausted for backlogged classes).
    fn next_class(&mut self, ctx: &AllocCtx) -> Option<Class>;

    /// Account a completed send of `cost` estimated tokens.
    fn on_send(&mut self, class: Class, cost: f64);

    /// Stable policy name (CSV/report label).
    fn name(&self) -> &'static str;

    /// Quota-style allocators constrain per-class concurrency; DRR-style
    /// ones rely on the global in-flight cap only.
    fn class_quota(&self, _class: Class) -> Option<usize> {
        None
    }
}

#[cfg(test)]
pub(crate) fn ctx(head_int: Option<f64>, head_heavy: Option<f64>) -> AllocCtx {
    AllocCtx {
        congestion: 0.0,
        inflight_by_class: [0, 0],
        head_cost: [head_int, head_heavy],
        head_arrival: [head_int.map(|_| 0.0), head_heavy.map(|_| 0.0)],
    }
}
