//! Quota-tiered isolation (paper §4.5 baseline): fixed per-class in-flight
//! slot quotas, *not* work-conserving — an idle class's slots stay idle.
//! Protects short tails unconditionally but strands heavy work under
//! heavy-dominated mixes (the completion collapse in Table 2).

use super::{AllocCtx, Allocator};
use crate::core::Class;

/// Fixed per-class in-flight quota allocator (no borrowing).
pub struct QuotaTiered {
    quota: [usize; 2],
}

impl QuotaTiered {
    /// `quota_interactive` + `quota_heavy` should equal the client's global
    /// in-flight budget (the scheduler also enforces the global cap).
    pub fn new(quota_interactive: usize, quota_heavy: usize) -> Self {
        assert!(quota_interactive > 0 && quota_heavy > 0);
        QuotaTiered { quota: [quota_interactive, quota_heavy] }
    }
}

impl Allocator for QuotaTiered {
    fn next_class(&mut self, ctx: &AllocCtx) -> Option<Class> {
        // Serve interactive first within its quota, then heavy within its
        // own; never borrow.
        for class in Class::ALL {
            if ctx.head(class).is_some() && ctx.inflight_by_class[class.index()] < self.quota[class.index()]
            {
                return Some(class);
            }
        }
        None
    }

    fn on_send(&mut self, _class: Class, _cost: f64) {}

    fn name(&self) -> &'static str {
        "quota_tiered"
    }

    fn class_quota(&self, class: Class) -> Option<usize> {
        Some(self.quota[class.index()])
    }
}

#[cfg(test)]
mod tests {
    use super::super::ctx;
    use super::*;

    #[test]
    fn respects_quota() {
        let mut q = QuotaTiered::new(2, 1);
        let mut c = ctx(Some(10.0), Some(100.0));
        c.inflight_by_class = [2, 0]; // interactive full
        assert_eq!(q.next_class(&c), Some(Class::Heavy));
        c.inflight_by_class = [2, 1]; // both full
        assert_eq!(q.next_class(&c), None, "no borrowing even with backlog");
    }

    #[test]
    fn not_work_conserving() {
        let mut q = QuotaTiered::new(2, 1);
        let mut c = ctx(None, Some(100.0));
        c.inflight_by_class = [0, 1];
        // Interactive slots free but its queue empty; heavy at quota: stall.
        assert_eq!(q.next_class(&c), None);
    }

    #[test]
    fn interactive_preferred() {
        let mut q = QuotaTiered::new(2, 2);
        let c = ctx(Some(10.0), Some(10.0));
        assert_eq!(q.next_class(&c), Some(Class::Interactive));
    }

    #[test]
    fn exposes_quota() {
        let q = QuotaTiered::new(3, 1);
        assert_eq!(q.class_quota(Class::Interactive), Some(3));
        assert_eq!(q.class_quota(Class::Heavy), Some(1));
    }
}
