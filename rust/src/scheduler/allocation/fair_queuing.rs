//! Fair Queuing allocation (paper §4.6): strict round-robin alternation of
//! send opportunities between classes, regardless of request size — the
//! "equal service opportunities" objective. Work-conserving: an empty
//! class's turn passes to the backlogged peer.

use super::{AllocCtx, Allocator};
use crate::core::Class;

/// Round-robin class alternation, size-blind and work-conserving.
pub struct FairQueuing {
    /// Class that gets the next opportunity.
    ptr: usize,
}

impl FairQueuing {
    /// Start with the interactive class holding the first turn.
    pub fn new() -> Self {
        FairQueuing { ptr: 0 }
    }
}

impl Default for FairQueuing {
    fn default() -> Self {
        Self::new()
    }
}

impl Allocator for FairQueuing {
    fn next_class(&mut self, ctx: &AllocCtx) -> Option<Class> {
        let first = Class::ALL[self.ptr];
        let second = Class::ALL[1 - self.ptr];
        if ctx.head(first).is_some() {
            Some(first)
        } else if ctx.head(second).is_some() {
            Some(second)
        } else {
            None
        }
    }

    fn on_send(&mut self, class: Class, _cost: f64) {
        // Alternate after every send the served class actually took; if the
        // other class was empty the pointer still flips, which is fine — its
        // next turn comes right back.
        self.ptr = 1 - class.index();
    }

    fn name(&self) -> &'static str {
        "fair_queuing"
    }
}

#[cfg(test)]
mod tests {
    use super::super::ctx;
    use super::*;

    #[test]
    fn alternates_between_backlogged_classes() {
        let mut fq = FairQueuing::new();
        let mut order = Vec::new();
        for _ in 0..6 {
            let c = fq.next_class(&ctx(Some(10.0), Some(1000.0))).unwrap();
            order.push(c);
            fq.on_send(c, 1.0);
        }
        assert_eq!(
            order,
            vec![
                Class::Interactive,
                Class::Heavy,
                Class::Interactive,
                Class::Heavy,
                Class::Interactive,
                Class::Heavy
            ]
        );
    }

    #[test]
    fn size_blind() {
        // Costs do not affect the alternation (unlike DRR).
        let mut fq = FairQueuing::new();
        let mut sends = [0u32; 2];
        for _ in 0..1000 {
            let c = fq.next_class(&ctx(Some(10.0), Some(4000.0))).unwrap();
            sends[c.index()] += 1;
            fq.on_send(c, if c == Class::Interactive { 10.0 } else { 4000.0 });
        }
        assert_eq!(sends[0], sends[1]);
    }

    #[test]
    fn work_conserving_on_empty_peer() {
        let mut fq = FairQueuing::new();
        for _ in 0..5 {
            let c = fq.next_class(&ctx(None, Some(100.0))).unwrap();
            assert_eq!(c, Class::Heavy);
            fq.on_send(c, 100.0);
        }
        assert_eq!(fq.next_class(&ctx(None, None)), None);
    }
}
