//! Short-Priority allocation (paper §4.6): strict priority for the
//! interactive class — heavy work is served only when no interactive
//! request is pending. Optimizes interactive tails at the cost of heavy
//! starvation (the +116% long-P90 "fairness tax" of Table 4).

use super::{AllocCtx, Allocator};
use crate::core::Class;

/// Strict interactive-first allocator (stateless).
pub struct ShortPriority;

impl ShortPriority {
    /// Construct the (stateless) policy.
    pub fn new() -> Self {
        ShortPriority
    }
}

impl Default for ShortPriority {
    fn default() -> Self {
        Self::new()
    }
}

impl Allocator for ShortPriority {
    fn next_class(&mut self, ctx: &AllocCtx) -> Option<Class> {
        if ctx.head(Class::Interactive).is_some() {
            Some(Class::Interactive)
        } else if ctx.head(Class::Heavy).is_some() {
            Some(Class::Heavy)
        } else {
            None
        }
    }

    fn on_send(&mut self, _class: Class, _cost: f64) {}

    fn name(&self) -> &'static str {
        "short_priority"
    }
}

#[cfg(test)]
mod tests {
    use super::super::ctx;
    use super::*;

    #[test]
    fn interactive_always_wins() {
        let mut sp = ShortPriority::new();
        assert_eq!(sp.next_class(&ctx(Some(1e6), Some(1.0))), Some(Class::Interactive));
    }

    #[test]
    fn heavy_only_when_interactive_empty() {
        let mut sp = ShortPriority::new();
        assert_eq!(sp.next_class(&ctx(None, Some(1.0))), Some(Class::Heavy));
        assert_eq!(sp.next_class(&ctx(None, None)), None);
    }
}
