//! Severity inputs — the three observable signals (paper §3.1 layer 3):
//! provider load (in-flight vs the client's budget), queue pressure
//! (estimated queued tokens), and tail behavior (latency/deadline ratio of
//! recent completions).
//!
//! Two gathering modes share the same signal shape:
//! * [`SeveritySignals::gather`] — the classic global view (one provider,
//!   or the fleet as a whole);
//! * [`SeveritySignals::gather_shard`] — one endpoint's view on a
//!   multi-shard fleet: the client's own in-flight on that shard against
//!   its 1/N share of the pacing budget, and that shard's client-measured
//!   tail ratio. Queue pressure stays fleet-wide (the backlog is one queue
//!   regardless of where releases are routed).

use crate::scheduler::queues::ClassQueues;
use crate::scheduler::shard::ShardSelector;
use crate::scheduler::state::ApiState;

/// Raw (pre-normalization) severity inputs.
#[derive(Debug, Clone, Copy)]
pub struct SeveritySignals {
    /// In-flight / client budget, already in [0, 1].
    pub provider_load: f64,
    /// Sum of queued p50 token estimates.
    pub queued_tokens: f64,
    /// EWMA of completion latency / deadline budget (≈1 = at deadline).
    pub tail_latency_ratio: f64,
}

impl SeveritySignals {
    /// Gather signals from the client-observable state.
    pub fn gather(state: &ApiState, queues: &ClassQueues, max_inflight: usize) -> SeveritySignals {
        SeveritySignals {
            provider_load: state.inflight() as f64 / max_inflight.max(1) as f64,
            queued_tokens: queues.queued_tokens(),
            tail_latency_ratio: state.tail_ratio.get_or(0.0),
        }
    }

    /// Gather one shard's severity inputs on a multi-shard fleet: the
    /// client's own in-flight on `shard` against its 1/N share of the
    /// pacing budget, the fleet-wide queue pressure, and the shard's own
    /// client-measured tail ratio. Only meaningful for `n_shards > 1` —
    /// the 1-shard selector tracks nothing, and the scheduler keeps the
    /// global [`SeveritySignals::gather`] path there bit-for-bit.
    pub fn gather_shard(
        selector: &ShardSelector,
        queues: &ClassQueues,
        max_inflight: usize,
        shard: usize,
    ) -> SeveritySignals {
        let budget_share = max_inflight.max(1) as f64 / selector.n_shards() as f64;
        SeveritySignals {
            provider_load: selector.inflight(shard) as f64 / budget_share,
            queued_tokens: queues.queued_tokens(),
            tail_latency_ratio: selector.tail_ratio(shard),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Class, Priors, TokenBucket};
    use crate::predictor::Route;
    use crate::scheduler::queues::SchedRequest;

    #[test]
    fn gather_reads_state() {
        let mut state = ApiState::new();
        let mut queues = ClassQueues::new();
        state.on_send(1, Class::Interactive, 100.0, 0.0);
        state.on_send(2, Class::Heavy, 900.0, 0.0);
        queues.push(SchedRequest {
            id: 3,
            arrival_ms: 0.0,
            deadline_ms: 100.0,
            priors: Priors::new(700.0, 1400.0),
            route: Route::from_bucket(TokenBucket::Long),
            defer_attempts: 0,
        });
        let s = SeveritySignals::gather(&state, &queues, 8);
        assert_eq!(s.provider_load, 2.0 / 8.0);
        assert_eq!(s.queued_tokens, 700.0);
        assert_eq!(s.tail_latency_ratio, 0.0);

        state.on_completion(1, 2500.0, 2500.0);
        let s = SeveritySignals::gather(&state, &queues, 8);
        assert!((s.tail_latency_ratio - 1.0).abs() < 1e-9);
    }

    #[test]
    fn gather_shard_reads_per_shard_state() {
        use crate::scheduler::shard::{ShardCfg, ShardPolicy};
        let mut sel = ShardSelector::new(ShardCfg::new(2, ShardPolicy::LeastInflight, Vec::new()));
        let queues = ClassQueues::new();
        // Load shard 0 with two releases, shard 1 with one.
        sel.commit(1, 0);
        sel.commit(2, 0);
        sel.commit(3, 1);
        // Budget 8 across 2 shards → per-shard share 4.
        let s0 = SeveritySignals::gather_shard(&sel, &queues, 8, 0);
        let s1 = SeveritySignals::gather_shard(&sel, &queues, 8, 1);
        assert_eq!(s0.provider_load, 2.0 / 4.0);
        assert_eq!(s1.provider_load, 1.0 / 4.0);
        assert_eq!(s0.queued_tokens, 0.0);
        assert_eq!(s0.tail_latency_ratio, 0.0, "no completions yet");
        // A slow completion on shard 0 raises only shard 0's tail input.
        sel.on_completion(1, 5_000.0, 2_500.0);
        let s0 = SeveritySignals::gather_shard(&sel, &queues, 8, 0);
        let s1 = SeveritySignals::gather_shard(&sel, &queues, 8, 1);
        assert!((s0.tail_latency_ratio - 2.0).abs() < 1e-9);
        assert_eq!(s1.tail_latency_ratio, 0.0);
    }
}
