//! Severity inputs — the three observable signals (paper §3.1 layer 3):
//! provider load (in-flight vs the client's budget), queue pressure
//! (estimated queued tokens), and tail behavior (latency/deadline ratio of
//! recent completions).

use crate::scheduler::queues::ClassQueues;
use crate::scheduler::state::ApiState;

/// Raw (pre-normalization) severity inputs.
#[derive(Debug, Clone, Copy)]
pub struct SeveritySignals {
    /// In-flight / client budget, already in [0, 1].
    pub provider_load: f64,
    /// Sum of queued p50 token estimates.
    pub queued_tokens: f64,
    /// EWMA of completion latency / deadline budget (≈1 = at deadline).
    pub tail_latency_ratio: f64,
}

impl SeveritySignals {
    /// Gather signals from the client-observable state.
    pub fn gather(state: &ApiState, queues: &ClassQueues, max_inflight: usize) -> SeveritySignals {
        SeveritySignals {
            provider_load: state.inflight() as f64 / max_inflight.max(1) as f64,
            queued_tokens: queues.queued_tokens(),
            tail_latency_ratio: state.tail_ratio.get_or(0.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Class, Priors, TokenBucket};
    use crate::predictor::Route;
    use crate::scheduler::queues::SchedRequest;

    #[test]
    fn gather_reads_state() {
        let mut state = ApiState::new();
        let mut queues = ClassQueues::new();
        state.on_send(1, Class::Interactive, 100.0, 0.0);
        state.on_send(2, Class::Heavy, 900.0, 0.0);
        queues.push(SchedRequest {
            id: 3,
            arrival_ms: 0.0,
            deadline_ms: 100.0,
            priors: Priors::new(700.0, 1400.0),
            route: Route::from_bucket(TokenBucket::Long),
            defer_attempts: 0,
        });
        let s = SeveritySignals::gather(&state, &queues, 8);
        assert_eq!(s.provider_load, 2.0 / 8.0);
        assert_eq!(s.queued_tokens, 700.0);
        assert_eq!(s.tail_latency_ratio, 0.0);

        state.on_completion(1, 2500.0, 2500.0);
        let s = SeveritySignals::gather(&state, &queues, 8);
        assert!((s.tail_latency_ratio - 1.0).abs() < 1e-9);
    }
}
