//! Cost-ladder bucket policies (paper §3.1 layer 3 and §4.7).
//!
//! A bucket weight ∈ {0, 1, 2} gates which actions a request is exposed to:
//! weight 0 = always admitted; weight ≥ 1 = deferrable at t_defer and
//! rejectable at t_reject_long; weight ≥ 2 = rejectable already at
//! t_reject_xlong. Short requests are weight 0 under every *labeled*
//! policy — "short requests are never rejected". A request with no bucket
//! belief (no-information blind) carries weight 1: uniform admission
//! severity that cannot protect shorts it cannot identify.

use crate::core::TokenBucket;

/// Admission decision for one candidate release.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OverloadDecision {
    /// Release now.
    Admit,
    /// Hold the candidate and retry after `delay_ms` (exponential backoff).
    Defer {
        /// How long to hold before the next admission attempt.
        delay_ms: f64,
    },
    /// Shed the request outright (counts against goodput, not timeouts).
    Reject,
}

/// The shedding shape (§4.7 comparison).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BucketPolicy {
    /// Default: medium=0, long=1, xlong=2 — sacrifice concentrates on the
    /// most expensive work.
    CostLadder,
    /// One shared mid-tier severity for all non-short work (class-agnostic,
    /// defer-heavy, rarely rejects).
    UniformMild,
    /// Harshest non-short tier applied uniformly (rejects early across all
    /// non-short classes).
    UniformHarsh,
    /// Inverted long/xlong ordering — stress contrast only.
    Reverse,
}

impl BucketPolicy {
    /// Shedding weight ∈ {0, 1, 2} for a bucket belief (`None` = neutral
    /// lane, weight 1).
    pub fn weight(self, bucket: Option<TokenBucket>) -> u8 {
        let Some(bucket) = bucket else {
            return 1; // neutral lane: uniform admission severity
        };
        match self {
            BucketPolicy::CostLadder => match bucket {
                TokenBucket::Short | TokenBucket::Medium => 0,
                TokenBucket::Long => 1,
                TokenBucket::XLong => 2,
            },
            BucketPolicy::UniformMild => match bucket {
                TokenBucket::Short => 0,
                _ => 1,
            },
            BucketPolicy::UniformHarsh => match bucket {
                TokenBucket::Short => 0,
                _ => 2,
            },
            BucketPolicy::Reverse => match bucket {
                TokenBucket::Short | TokenBucket::Medium => 0,
                TokenBucket::Long => 2,
                TokenBucket::XLong => 1,
            },
        }
    }

    /// Stable CLI/CSV name.
    pub fn name(self) -> &'static str {
        match self {
            BucketPolicy::CostLadder => "cost_ladder",
            BucketPolicy::UniformMild => "uniform_mild",
            BucketPolicy::UniformHarsh => "uniform_harsh",
            BucketPolicy::Reverse => "reverse",
        }
    }

    /// Parse a [`BucketPolicy::name`] (plus short aliases).
    pub fn parse(s: &str) -> Option<BucketPolicy> {
        match s {
            "cost_ladder" | "ladder" => Some(BucketPolicy::CostLadder),
            "uniform_mild" => Some(BucketPolicy::UniformMild),
            "uniform_harsh" => Some(BucketPolicy::UniformHarsh),
            "reverse" => Some(BucketPolicy::Reverse),
            _ => None,
        }
    }

    /// Every policy, in report order.
    pub const ALL: [BucketPolicy; 4] = [
        BucketPolicy::CostLadder,
        BucketPolicy::UniformMild,
        BucketPolicy::UniformHarsh,
        BucketPolicy::Reverse,
    ];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_weights() {
        let p = BucketPolicy::CostLadder;
        assert_eq!(p.weight(Some(TokenBucket::Short)), 0);
        assert_eq!(p.weight(Some(TokenBucket::Medium)), 0);
        assert_eq!(p.weight(Some(TokenBucket::Long)), 1);
        assert_eq!(p.weight(Some(TokenBucket::XLong)), 2);
    }

    #[test]
    fn uniform_variants() {
        assert_eq!(BucketPolicy::UniformMild.weight(Some(TokenBucket::XLong)), 1);
        assert_eq!(BucketPolicy::UniformMild.weight(Some(TokenBucket::Medium)), 1);
        assert_eq!(BucketPolicy::UniformHarsh.weight(Some(TokenBucket::Medium)), 2);
        assert_eq!(BucketPolicy::UniformHarsh.weight(Some(TokenBucket::Short)), 0);
    }

    #[test]
    fn reverse_inverts() {
        assert_eq!(BucketPolicy::Reverse.weight(Some(TokenBucket::Long)), 2);
        assert_eq!(BucketPolicy::Reverse.weight(Some(TokenBucket::XLong)), 1);
    }

    #[test]
    fn neutral_lane_weight_one() {
        for p in BucketPolicy::ALL {
            assert_eq!(p.weight(None), 1, "{p:?}");
        }
    }

    #[test]
    fn parse_roundtrip() {
        for p in BucketPolicy::ALL {
            assert_eq!(BucketPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(BucketPolicy::parse("ladder"), Some(BucketPolicy::CostLadder));
        assert_eq!(BucketPolicy::parse("nope"), None);
    }
}
