//! Overload control layer (paper §3.1 layer 3): explicit admit/defer/reject
//! at the client admission boundary, replacing implicit timeout failures
//! with objective-aligned shedding.

pub mod ladder;
pub mod severity;

pub use ladder::{BucketPolicy, OverloadDecision};
pub use severity::SeveritySignals;

use crate::core::TokenBucket;
use crate::scheduler::queues::SchedRequest;

/// Overload controller configuration. Threshold defaults are the paper's:
/// defer at 0.45, reject-xlong at 0.65, reject-long at 0.80; cost-ladder
/// bucket weights medium=0, long=1, xlong=2; shorts never rejected.
#[derive(Debug, Clone)]
pub struct OverloadCfg {
    /// Master switch; disabled = admit everything (timeout-only baseline).
    pub enabled: bool,
    /// Severity weight on provider load (observable in-flight fraction).
    pub w_load: f64,
    /// Severity weight on queue pressure (queued estimated tokens).
    pub w_queue: f64,
    /// Severity weight on the tail latency/deadline ratio.
    pub w_tail: f64,
    /// Severity at which deferrable buckets start deferring.
    pub t_defer: f64,
    /// Severity at which weight-2 (xlong) buckets are rejected.
    pub t_reject_xlong: f64,
    /// Severity at which weight-1 (long) buckets are rejected.
    pub t_reject_long: f64,
    /// How bucket beliefs map to shedding weights.
    pub bucket_policy: BucketPolicy,
    /// Base deferral backoff; doubles per attempt up to `defer_cap_ms`.
    pub defer_base_ms: f64,
    /// Upper bound on the exponential deferral backoff.
    pub defer_cap_ms: f64,
    /// Queue-pressure normalization (estimated queued tokens at pressure 1).
    pub queue_budget_tokens: f64,
    /// tail_latency_ratio ≥ this counts as full tail pressure.
    pub tail_ratio_cap: f64,
}

impl Default for OverloadCfg {
    fn default() -> Self {
        OverloadCfg {
            enabled: true,
            w_load: 0.4,
            w_queue: 0.3,
            w_tail: 0.3,
            t_defer: 0.45,
            t_reject_xlong: 0.65,
            t_reject_long: 0.80,
            bucket_policy: BucketPolicy::CostLadder,
            defer_base_ms: 400.0,
            defer_cap_ms: 6_400.0,
            queue_budget_tokens: 6_000.0,
            tail_ratio_cap: 1.5,
        }
    }
}

impl OverloadCfg {
    /// Controller off: every candidate is admitted (ablation baseline).
    pub fn disabled() -> Self {
        OverloadCfg { enabled: false, ..Default::default() }
    }

    /// Scale the three thresholds and backoff (sensitivity sweep §4.9).
    pub fn perturbed(&self, factor: f64) -> Self {
        OverloadCfg {
            t_defer: self.t_defer * factor,
            t_reject_xlong: self.t_reject_xlong * factor,
            t_reject_long: self.t_reject_long * factor,
            defer_base_ms: self.defer_base_ms * factor,
            ..self.clone()
        }
    }
}

/// Stateful controller: computes severity from observable signals and maps
/// (severity, bucket belief) through the bucket policy.
pub struct OverloadController {
    cfg: OverloadCfg,
    /// Defer counters by *belief-at-decision* bucket index (4 = no belief /
    /// neutral lane).
    pub defers_by_bucket: [u64; 5],
    /// Reject counters, same indexing as `defers_by_bucket`.
    pub rejects_by_bucket: [u64; 5],
    last_severity: f64,
}

impl OverloadController {
    /// A controller for `cfg` with zeroed action counters.
    pub fn new(cfg: OverloadCfg) -> Self {
        OverloadController {
            cfg,
            defers_by_bucket: [0; 5],
            rejects_by_bucket: [0; 5],
            last_severity: 0.0,
        }
    }

    /// The active configuration.
    pub fn cfg(&self) -> &OverloadCfg {
        &self.cfg
    }

    /// Severity in [0, 1]: w_load·provider_load + w_queue·queue_pressure +
    /// w_tail·tail_latency_ratio (each input normalized to [0, 1]).
    pub fn severity(&mut self, s: &SeveritySignals) -> f64 {
        let sev = self.severity_value(s);
        self.last_severity = sev;
        sev
    }

    /// The same severity computation without updating `last_severity` —
    /// shard-aware overload control evaluates one severity per endpoint
    /// from per-shard signals while the global value (used for DRR
    /// congestion adaptation and diagnostics) stays the recorded one.
    pub fn severity_value(&self, s: &SeveritySignals) -> f64 {
        let c = &self.cfg;
        let load = s.provider_load.clamp(0.0, 1.0);
        let queue = (s.queued_tokens / c.queue_budget_tokens).clamp(0.0, 1.0);
        let tail = (s.tail_latency_ratio / c.tail_ratio_cap).clamp(0.0, 1.0);
        (c.w_load * load + c.w_queue * queue + c.w_tail * tail)
            / (c.w_load + c.w_queue + c.w_tail)
    }

    /// The most recent severity recorded via [`OverloadController::severity`].
    pub fn last_severity(&self) -> f64 {
        self.last_severity
    }

    /// Decide for a candidate at the given severity.
    pub fn decide(&mut self, req: &SchedRequest, severity: f64) -> OverloadDecision {
        if !self.cfg.enabled {
            return OverloadDecision::Admit;
        }
        let weight = self.cfg.bucket_policy.weight(req.route.bucket_belief);
        let decision = if weight >= 2 && severity >= self.cfg.t_reject_xlong {
            OverloadDecision::Reject
        } else if weight >= 1 && severity >= self.cfg.t_reject_long {
            OverloadDecision::Reject
        } else if weight >= 1 && severity >= self.cfg.t_defer {
            let backoff = (self.cfg.defer_base_ms * 2f64.powi(req.defer_attempts as i32))
                .min(self.cfg.defer_cap_ms);
            OverloadDecision::Defer { delay_ms: backoff }
        } else {
            OverloadDecision::Admit
        };
        let bidx = req.route.bucket_belief.map(TokenBucket::index).unwrap_or(4);
        match decision {
            OverloadDecision::Defer { .. } => self.defers_by_bucket[bidx] += 1,
            OverloadDecision::Reject => self.rejects_by_bucket[bidx] += 1,
            OverloadDecision::Admit => {}
        }
        decision
    }

    /// Deferrals issued so far, summed over buckets.
    pub fn total_defers(&self) -> u64 {
        self.defers_by_bucket.iter().sum()
    }

    /// Rejections issued so far, summed over buckets.
    pub fn total_rejects(&self) -> u64 {
        self.rejects_by_bucket.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Priors;
    use crate::predictor::Route;

    fn sreq(bucket: Option<TokenBucket>, attempts: u32) -> SchedRequest {
        SchedRequest {
            id: 0,
            arrival_ms: 0.0,
            deadline_ms: 1e6,
            priors: Priors::new(100.0, 200.0),
            route: match bucket {
                Some(b) => Route::from_bucket(b),
                None => Route::neutral(),
            },
            defer_attempts: attempts,
        }
    }

    fn signals(load: f64, queued: f64, tail: f64) -> SeveritySignals {
        SeveritySignals { provider_load: load, queued_tokens: queued, tail_latency_ratio: tail }
    }

    #[test]
    fn severity_normalized() {
        let mut c = OverloadController::new(OverloadCfg::default());
        assert_eq!(c.severity(&signals(0.0, 0.0, 0.0)), 0.0);
        let max = c.severity(&signals(1.0, 1e9, 1e9));
        assert!((max - 1.0).abs() < 1e-9);
        let mid = c.severity(&signals(0.5, 3_000.0, 0.75));
        assert!((mid - 0.5).abs() < 1e-9);
    }

    #[test]
    fn calm_admits_everything() {
        let mut c = OverloadController::new(OverloadCfg::default());
        for b in TokenBucket::ALL {
            assert_eq!(c.decide(&sreq(Some(b), 0), 0.2), OverloadDecision::Admit);
        }
        assert_eq!(c.total_defers() + c.total_rejects(), 0);
    }

    #[test]
    fn ladder_thresholds() {
        let mut c = OverloadController::new(OverloadCfg::default());
        // severity 0.5: long/xlong deferred, short/medium admitted.
        assert_eq!(c.decide(&sreq(Some(TokenBucket::Short), 0), 0.5), OverloadDecision::Admit);
        assert_eq!(c.decide(&sreq(Some(TokenBucket::Medium), 0), 0.5), OverloadDecision::Admit);
        assert!(matches!(
            c.decide(&sreq(Some(TokenBucket::Long), 0), 0.5),
            OverloadDecision::Defer { .. }
        ));
        // severity 0.7: xlong rejected, long still deferred.
        assert_eq!(c.decide(&sreq(Some(TokenBucket::XLong), 0), 0.7), OverloadDecision::Reject);
        assert!(matches!(
            c.decide(&sreq(Some(TokenBucket::Long), 0), 0.7),
            OverloadDecision::Defer { .. }
        ));
        // severity 0.85: long rejected too; short/medium never.
        assert_eq!(c.decide(&sreq(Some(TokenBucket::Long), 0), 0.85), OverloadDecision::Reject);
        assert_eq!(c.decide(&sreq(Some(TokenBucket::Short), 0), 0.85), OverloadDecision::Admit);
        assert_eq!(c.decide(&sreq(Some(TokenBucket::Medium), 0), 0.85), OverloadDecision::Admit);
    }

    #[test]
    fn shorts_never_rejected_under_any_labeled_policy() {
        for policy in [
            BucketPolicy::CostLadder,
            BucketPolicy::UniformMild,
            BucketPolicy::UniformHarsh,
            BucketPolicy::Reverse,
        ] {
            let mut c =
                OverloadController::new(OverloadCfg { bucket_policy: policy, ..Default::default() });
            for sev in [0.5, 0.7, 0.9, 1.0] {
                assert_eq!(
                    c.decide(&sreq(Some(TokenBucket::Short), 0), sev),
                    OverloadDecision::Admit,
                    "{policy:?} sev={sev}"
                );
            }
        }
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let mut c = OverloadController::new(OverloadCfg::default());
        let d0 = c.decide(&sreq(Some(TokenBucket::Long), 0), 0.5);
        let d3 = c.decide(&sreq(Some(TokenBucket::Long), 3), 0.5);
        let d9 = c.decide(&sreq(Some(TokenBucket::Long), 9), 0.5);
        match (d0, d3, d9) {
            (
                OverloadDecision::Defer { delay_ms: a },
                OverloadDecision::Defer { delay_ms: b },
                OverloadDecision::Defer { delay_ms: z },
            ) => {
                assert_eq!(a, 400.0);
                assert_eq!(b, 3200.0);
                assert_eq!(z, 6400.0, "capped");
            }
            other => panic!("expected defers, got {other:?}"),
        }
    }

    #[test]
    fn disabled_admits_always() {
        let mut c = OverloadController::new(OverloadCfg::disabled());
        assert_eq!(c.decide(&sreq(Some(TokenBucket::XLong), 0), 1.0), OverloadDecision::Admit);
    }

    #[test]
    fn neutral_lane_uniform_admission() {
        // No bucket belief (no-info blind): weight 1 for everything — even
        // (unknowably) short requests get deferred under stress.
        let mut c = OverloadController::new(OverloadCfg::default());
        assert!(matches!(c.decide(&sreq(None, 0), 0.5), OverloadDecision::Defer { .. }));
        assert_eq!(c.decide(&sreq(None, 0), 0.85), OverloadDecision::Reject);
        assert_eq!(c.defers_by_bucket[4], 1);
        assert_eq!(c.rejects_by_bucket[4], 1);
    }

    #[test]
    fn action_counters_track_buckets() {
        let mut c = OverloadController::new(OverloadCfg::default());
        c.decide(&sreq(Some(TokenBucket::XLong), 0), 0.7);
        c.decide(&sreq(Some(TokenBucket::Long), 0), 0.5);
        c.decide(&sreq(Some(TokenBucket::Long), 0), 0.5);
        assert_eq!(c.rejects_by_bucket[TokenBucket::XLong.index()], 1);
        assert_eq!(c.defers_by_bucket[TokenBucket::Long.index()], 2);
        assert_eq!(c.total_rejects(), 1);
        assert_eq!(c.total_defers(), 2);
    }

    #[test]
    fn perturbed_scales_thresholds() {
        let base = OverloadCfg::default();
        let hi = base.perturbed(1.2);
        assert!((hi.t_defer - 0.54).abs() < 1e-9);
        assert!((hi.defer_base_ms - 480.0).abs() < 1e-9);
        assert_eq!(hi.w_load, base.w_load);
    }
}
