//! Ordering layer (paper §3.1 layer 2): intra-class sequencing. The paper's
//! design is the slowdown-aware feasible-set rule for the heavy class;
//! FIFO/SJF/EDF are baselines and ablations.

pub mod feasible_set;

pub use feasible_set::{FeasibleSet, OrderingCfg};

use crate::scheduler::queues::SchedRequest;

/// Intra-class sequencing policy: pick the index of the next request to
/// release from `queue` (None iff empty).
pub trait Ordering {
    fn select(&mut self, queue: &[SchedRequest], now: f64) -> Option<usize>;
    fn name(&self) -> &'static str;

    /// Feasibility violations recorded so far (only `FeasibleSet` tracks
    /// these; everything else reports 0).
    fn feasibility_violations(&self) -> u64 {
        0
    }
}

/// First-in-first-out (queues are arrival-ordered, so index 0).
pub struct Fifo;

impl Ordering for Fifo {
    fn select(&mut self, queue: &[SchedRequest], _now: f64) -> Option<usize> {
        if queue.is_empty() {
            None
        } else {
            Some(0)
        }
    }

    fn name(&self) -> &'static str {
        "fifo"
    }
}

/// Shortest job first by p50 prior (ties → older first).
pub struct Sjf;

impl Ordering for Sjf {
    fn select(&mut self, queue: &[SchedRequest], _now: f64) -> Option<usize> {
        queue
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                a.priors
                    .p50
                    .partial_cmp(&b.priors.p50)
                    .unwrap()
                    .then(a.arrival_ms.partial_cmp(&b.arrival_ms).unwrap())
            })
            .map(|(i, _)| i)
    }

    fn name(&self) -> &'static str {
        "sjf"
    }
}

/// Earliest deadline first.
pub struct Edf;

impl Ordering for Edf {
    fn select(&mut self, queue: &[SchedRequest], _now: f64) -> Option<usize> {
        queue
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| a.deadline_ms.partial_cmp(&b.deadline_ms).unwrap())
            .map(|(i, _)| i)
    }

    fn name(&self) -> &'static str {
        "edf"
    }
}

#[cfg(test)]
pub(crate) mod test_util {
    use crate::core::{Priors, TokenBucket};
    use crate::predictor::Route;
    use crate::scheduler::queues::SchedRequest;

    pub fn sreq(id: usize, arrival: f64, p50: f64, deadline: f64) -> SchedRequest {
        SchedRequest {
            id,
            arrival_ms: arrival,
            deadline_ms: deadline,
            priors: Priors::new(p50, p50 * 1.5),
            route: Route::from_bucket(TokenBucket::from_tokens(p50)),
            defer_attempts: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::test_util::sreq;
    use super::*;

    #[test]
    fn fifo_picks_head() {
        let q = vec![sreq(1, 0.0, 500.0, 1e5), sreq(2, 1.0, 10.0, 1e5)];
        assert_eq!(Fifo.select(&q, 10.0), Some(0));
        assert_eq!(Fifo.select(&[], 10.0), None);
    }

    #[test]
    fn sjf_picks_smallest() {
        let q = vec![sreq(1, 0.0, 500.0, 1e5), sreq(2, 1.0, 10.0, 1e5), sreq(3, 2.0, 100.0, 1e5)];
        assert_eq!(Sjf.select(&q, 10.0), Some(1));
    }

    #[test]
    fn sjf_ties_break_by_age() {
        let q = vec![sreq(1, 5.0, 100.0, 1e5), sreq(2, 1.0, 100.0, 1e5)];
        assert_eq!(Sjf.select(&q, 10.0), Some(1));
    }

    #[test]
    fn edf_picks_earliest_deadline() {
        let q = vec![sreq(1, 0.0, 10.0, 9000.0), sreq(2, 1.0, 10.0, 4000.0)];
        assert_eq!(Edf.select(&q, 10.0), Some(1));
    }
}
