//! Ordering layer (paper §3.1 layer 2): intra-class sequencing. The paper's
//! design is the slowdown-aware feasible-set rule for the heavy class;
//! FIFO/SJF/EDF are baselines and ablations.
//!
//! Policies select over a borrowed [`QueueView`] and name the winner by
//! request id — a single pass with no intermediate allocations, and the
//! scheduler removes the winner in O(1) through the slab's id index.

pub mod feasible_set;

pub use feasible_set::{FeasibleSet, OrderingCfg};

use crate::core::ReqId;
use crate::scheduler::queues::QueueView;

/// Intra-class sequencing policy: pick the id of the next request to
/// release from `queue` (None iff empty).
pub trait Ordering {
    fn select(&mut self, queue: QueueView<'_>, now: f64) -> Option<ReqId>;
    fn name(&self) -> &'static str;

    /// Feasibility violations recorded so far (only `FeasibleSet` tracks
    /// these; everything else reports 0).
    fn feasibility_violations(&self) -> u64 {
        0
    }
}

/// First-in-first-out (queues are arrival-ordered, so the head). O(1).
pub struct Fifo;

impl Ordering for Fifo {
    fn select(&mut self, queue: QueueView<'_>, _now: f64) -> Option<ReqId> {
        queue.head().map(|r| r.id)
    }

    fn name(&self) -> &'static str {
        "fifo"
    }
}

/// Shortest job first by p50 prior (ties → older first).
pub struct Sjf;

impl Ordering for Sjf {
    fn select(&mut self, queue: QueueView<'_>, _now: f64) -> Option<ReqId> {
        let mut best: Option<&crate::scheduler::queues::SchedRequest> = None;
        for r in queue.iter() {
            let better = match best {
                None => true,
                Some(b) => {
                    r.priors.p50 < b.priors.p50
                        || (r.priors.p50 == b.priors.p50 && r.arrival_ms < b.arrival_ms)
                }
            };
            if better {
                best = Some(r);
            }
        }
        best.map(|r| r.id)
    }

    fn name(&self) -> &'static str {
        "sjf"
    }
}

/// Earliest deadline first (ties → FIFO position, i.e. first seen).
pub struct Edf;

impl Ordering for Edf {
    fn select(&mut self, queue: QueueView<'_>, _now: f64) -> Option<ReqId> {
        let mut best: Option<&crate::scheduler::queues::SchedRequest> = None;
        for r in queue.iter() {
            if best.map_or(true, |b| r.deadline_ms < b.deadline_ms) {
                best = Some(r);
            }
        }
        best.map(|r| r.id)
    }

    fn name(&self) -> &'static str {
        "edf"
    }
}

#[cfg(test)]
pub(crate) mod test_util {
    use crate::core::{Class, Priors, TokenBucket};
    use crate::predictor::Route;
    use crate::scheduler::queues::{ClassQueues, SchedRequest};

    /// Test request. Routed to the heavy class regardless of p50 so that
    /// ordering tests exercise one queue in push order.
    pub fn sreq(id: usize, arrival: f64, p50: f64, deadline: f64) -> SchedRequest {
        SchedRequest {
            id,
            arrival_ms: arrival,
            deadline_ms: deadline,
            priors: Priors::new(p50, p50 * 1.5),
            route: Route::from_bucket(TokenBucket::Long),
            defer_attempts: 0,
        }
    }

    /// Build slab queues holding `reqs` in order (all heavy-class).
    pub fn queues_of(reqs: Vec<SchedRequest>) -> ClassQueues {
        let mut q = ClassQueues::new();
        for r in reqs {
            q.push(r);
        }
        q
    }

    pub const HEAVY: Class = Class::Heavy;
}

#[cfg(test)]
mod tests {
    use super::test_util::{queues_of, sreq, HEAVY};
    use super::*;

    #[test]
    fn fifo_picks_head() {
        let q = queues_of(vec![sreq(1, 0.0, 500.0, 1e5), sreq(2, 1.0, 10.0, 1e5)]);
        assert_eq!(Fifo.select(q.view(HEAVY), 10.0), Some(1));
        let empty = queues_of(vec![]);
        assert_eq!(Fifo.select(empty.view(HEAVY), 10.0), None);
    }

    #[test]
    fn sjf_picks_smallest() {
        let q = queues_of(vec![
            sreq(1, 0.0, 500.0, 1e5),
            sreq(2, 1.0, 10.0, 1e5),
            sreq(3, 2.0, 100.0, 1e5),
        ]);
        assert_eq!(Sjf.select(q.view(HEAVY), 10.0), Some(2));
    }

    #[test]
    fn sjf_ties_break_by_age() {
        let q = queues_of(vec![sreq(1, 5.0, 100.0, 1e5), sreq(2, 1.0, 100.0, 1e5)]);
        assert_eq!(Sjf.select(q.view(HEAVY), 10.0), Some(2));
    }

    #[test]
    fn edf_picks_earliest_deadline() {
        let q = queues_of(vec![sreq(1, 0.0, 10.0, 9000.0), sreq(2, 1.0, 10.0, 4000.0)]);
        assert_eq!(Edf.select(q.view(HEAVY), 10.0), Some(2));
    }
}
