//! Ordering layer (paper §3.1 layer 2): intra-class sequencing. The paper's
//! design is the slowdown-aware feasible-set rule for the heavy class;
//! FIFO/SJF/EDF are baselines and ablations.
//!
//! Policies select over a borrowed [`QueueView`] and name the winner by
//! request id — the scheduler removes the winner in O(1) through the slab's
//! id index.
//!
//! ## Incremental indexes (lifecycle hooks)
//!
//! Scored orderings used to rescan the whole class queue on every release —
//! O(live depth), which grows linearly with offered *rate* (steady-state
//! depth ≈ rate × SLO-timeout window). The trait now carries lifecycle
//! hooks, [`Ordering::on_push`] / [`Ordering::on_remove`], that the slab
//! ([`ClassQueues`](crate::scheduler::queues::ClassQueues) `*_with`
//! variants) and the pump drive on every queue mutation, so each policy can
//! maintain a keyed index and answer `select` sublinearly:
//!
//! * [`Sjf`] keeps a predicted-tokens-keyed index (the magnitude bucket is
//!   the float's exponent field — the leading bits of the sort key), so
//!   selection is the first entry of a BTree: O(log depth).
//! * [`Edf`] does the same keyed by deadline.
//! * [`FeasibleSet`] keeps a group/phase index (its score is time-varying,
//!   but statically ordered within a prior group per urgency phase) with
//!   lazily-fired once-per-entry migrations; see `feasible_set.rs`.
//!
//! **Bit-compat contract:** every index must reproduce the retained O(n)
//! reference scan ([`Ordering::reference_select`]) *exactly*, including the
//! documented tie rules, so no experiment table moves. Debug builds assert
//! the equivalence on every call; `tests/ordering_index.rs` property-tests
//! it on production-shaped op sequences.
//!
//! Hook contract (the DES invariants the indexes lean on): plain pushes
//! arrive in nondecreasing event time, re-pushes go through `push_ordered`
//! (which keeps the class lists arrival-sorted), `now` never decreases
//! across calls, and every queue mutation fires exactly one hook.

pub mod feasible_set;

pub use feasible_set::{FeasibleSet, OrderingCfg, QUANT_BITS_DEFAULT};

use crate::core::ReqId;
use crate::scheduler::queues::{QueueView, SchedRequest};
use std::collections::BTreeSet;

/// Sentinel for "not indexed" in the dense id→seq tables.
const NO_SEQ: u64 = u64::MAX;

/// Intra-class sequencing policy: pick the id of the next request to
/// release from `queue` (None iff empty).
///
/// `Send` is a supertrait: the partitioned event loop (`sim::partition`)
/// hands each tenant's scheduler — boxed policies included — to its
/// partition's worker thread. Every policy is plain owned data, so the
/// bound costs implementors nothing.
pub trait Ordering: Send {
    /// Pick the next release from `queue` at event time `now`, answering
    /// from the policy's incremental index (`None` iff the queue is empty).
    fn select(&mut self, queue: QueueView<'_>, now: f64) -> Option<ReqId>;

    /// Stable policy name (CSV/report label).
    fn name(&self) -> &'static str;

    /// The retained O(depth) reference scan — the semantic spec that
    /// `select` must reproduce bit-for-bit (same winner, same tie rules).
    /// Pure; used by debug assertions and the index-vs-reference property
    /// tests.
    fn reference_select(&self, queue: QueueView<'_>, now: f64) -> Option<ReqId>;

    /// Lifecycle hook: `req` entered the class queue (plain push or ordered
    /// re-push) at event time `now`. Default no-op — FIFO needs no index.
    fn on_push(&mut self, _req: &SchedRequest, _now: f64) {}

    /// Lifecycle hook: `req` left the class queue (dispatch, timeout
    /// cancel, or deferral). Default no-op.
    fn on_remove(&mut self, _req: &SchedRequest) {}

    /// Feasibility violations recorded so far (only `FeasibleSet` tracks
    /// these; everything else reports 0).
    fn feasibility_violations(&self) -> u64 {
        0
    }

    /// Cumulative index work done by `select` calls: entries examined plus
    /// migrations processed. Deterministic (no wall clock), so the bench
    /// `--depth` leg can gate per-release scaling on it exactly. The FIFO
    /// default reports 0 — its selection reads one pointer.
    fn select_work(&self) -> u64 {
        0
    }

    /// Peak number of distinct prior groups the index has held (only
    /// `FeasibleSet` groups; everything else reports 0). Under quantized
    /// grouping this stays far below the entry count even for continuous
    /// priors — the observable form of the grouping win.
    fn group_count(&self) -> u64 {
        0
    }

    /// Number of `select` calls that degenerated to examining at least as
    /// many entries as were live (a per-entry scan — the regime quantized
    /// grouping exists to prevent). 0 for O(log) indexes.
    fn scan_fallbacks(&self) -> u64 {
        0
    }
}

/// Dense id → insertion-sequence table shared by the keyed indexes. The
/// sequence number is the entry's queue-position tie-breaker: the class
/// lists stay arrival-sorted, so queue iteration order is exactly
/// `(arrival_ms, seq)` and every index can reproduce position-based tie
/// rules without walking the list.
#[derive(Default)]
struct SeqTable {
    next: u64,
    of: Vec<u64>,
}

impl SeqTable {
    fn assign(&mut self, id: ReqId) -> u64 {
        let s = self.next;
        self.next += 1;
        if id >= self.of.len() {
            self.of.resize(id + 1, NO_SEQ);
        }
        debug_assert_eq!(self.of[id], NO_SEQ, "request {id} indexed twice (double on_push?)");
        self.of[id] = s;
        s
    }

    /// Retire and return the id's sequence number. Panics on an id that was
    /// never pushed — a missed lifecycle hook, which must be loud.
    fn take(&mut self, id: ReqId) -> u64 {
        let s = self.of[id];
        assert_ne!(s, NO_SEQ, "on_remove for unindexed request {id} (missed on_push?)");
        self.of[id] = NO_SEQ;
        s
    }
}

/// Sortable bit pattern of a non-negative f64 (IEEE order == numeric order
/// for non-negative values; all event times, priors, and deadlines are
/// non-negative by construction).
#[inline]
fn key_bits(v: f64) -> u64 {
    debug_assert!(v >= 0.0, "sort key {v} must be non-negative");
    v.to_bits()
}

/// First-in-first-out (queues are arrival-ordered, so the head). O(1).
pub struct Fifo;

impl Ordering for Fifo {
    fn select(&mut self, queue: QueueView<'_>, now: f64) -> Option<ReqId> {
        self.reference_select(queue, now)
    }

    fn reference_select(&self, queue: QueueView<'_>, _now: f64) -> Option<ReqId> {
        queue.head().map(|r| r.id)
    }

    fn name(&self) -> &'static str {
        "fifo"
    }
}

/// Shortest job first by p50 prior (ties → older first).
///
/// Incremental: a BTree keyed `(p50, arrival, seq)` — the leading bits of
/// the p50 key are its magnitude bucket, so the structure is the
/// "predicted-tokens buckets" index with exact within-bucket order fused
/// into one comparison. Selection is `first()`: O(log depth).
#[derive(Default)]
pub struct Sjf {
    index: BTreeSet<(u64, u64, u64, ReqId)>,
    seqs: SeqTable,
    work: u64,
}

impl Sjf {
    /// An empty SJF index.
    pub fn new() -> Sjf {
        Sjf::default()
    }

    fn key(req: &SchedRequest, seq: u64) -> (u64, u64, u64, ReqId) {
        (key_bits(req.priors.p50), key_bits(req.arrival_ms), seq, req.id)
    }
}

impl Ordering for Sjf {
    fn select(&mut self, queue: QueueView<'_>, now: f64) -> Option<ReqId> {
        debug_assert_eq!(self.index.len(), queue.len(), "sjf index out of sync (missed hook?)");
        let winner = self.index.first().map(|&(_, _, _, id)| id);
        self.work += u64::from(winner.is_some());
        debug_assert_eq!(winner, self.reference_select(queue, now), "sjf index vs reference");
        winner
    }

    fn select_work(&self) -> u64 {
        self.work
    }

    fn reference_select(&self, queue: QueueView<'_>, _now: f64) -> Option<ReqId> {
        let mut best: Option<&SchedRequest> = None;
        for r in queue.iter() {
            let better = match best {
                None => true,
                Some(b) => {
                    r.priors.p50 < b.priors.p50
                        || (r.priors.p50 == b.priors.p50 && r.arrival_ms < b.arrival_ms)
                }
            };
            if better {
                best = Some(r);
            }
        }
        best.map(|r| r.id)
    }

    fn on_push(&mut self, req: &SchedRequest, _now: f64) {
        let seq = self.seqs.assign(req.id);
        self.index.insert(Self::key(req, seq));
    }

    fn on_remove(&mut self, req: &SchedRequest) {
        let seq = self.seqs.take(req.id);
        let removed = self.index.remove(&Self::key(req, seq));
        debug_assert!(removed, "sjf index missing request {}", req.id);
    }

    fn name(&self) -> &'static str {
        "sjf"
    }
}

/// Width-demotion factor for [`RobustSjf`]: effective cost is
/// `p50 + ROBUST_THETA · width`. At θ=1 a request whose interval is as wide
/// as its estimate sorts like a job twice its size — uncertain work yields
/// to confidently-small work, bounding the damage a wrong small prediction
/// can do (the "Adaptively Robust LLM Inference Optimization" hedge).
pub const ROBUST_THETA: f64 = 1.0;

/// Robust shortest-job-first: SJF on the width-demoted cost
/// `p50 + θ·width` (ties → older first). For point priors (`width == 0`)
/// this is numerically identical to [`Sjf`].
///
/// Incremental: the same BTree machinery as [`Sjf`], keyed
/// `(robust_cost, arrival, seq)`; selection is `first()`: O(log depth).
#[derive(Default)]
pub struct RobustSjf {
    index: BTreeSet<(u64, u64, u64, ReqId)>,
    seqs: SeqTable,
    work: u64,
}

impl RobustSjf {
    /// An empty robust-SJF index.
    pub fn new() -> RobustSjf {
        RobustSjf::default()
    }

    fn key(req: &SchedRequest, seq: u64) -> (u64, u64, u64, ReqId) {
        (key_bits(req.priors.robust_cost(ROBUST_THETA)), key_bits(req.arrival_ms), seq, req.id)
    }
}

impl Ordering for RobustSjf {
    fn select(&mut self, queue: QueueView<'_>, now: f64) -> Option<ReqId> {
        debug_assert_eq!(
            self.index.len(),
            queue.len(),
            "robust_sjf index out of sync (missed hook?)"
        );
        let winner = self.index.first().map(|&(_, _, _, id)| id);
        self.work += u64::from(winner.is_some());
        debug_assert_eq!(winner, self.reference_select(queue, now), "robust_sjf vs reference");
        winner
    }

    fn select_work(&self) -> u64 {
        self.work
    }

    fn reference_select(&self, queue: QueueView<'_>, _now: f64) -> Option<ReqId> {
        let mut best: Option<(&SchedRequest, f64)> = None;
        for r in queue.iter() {
            let cost = r.priors.robust_cost(ROBUST_THETA);
            let better = match best {
                None => true,
                Some((b, bc)) => cost < bc || (cost == bc && r.arrival_ms < b.arrival_ms),
            };
            if better {
                best = Some((r, cost));
            }
        }
        best.map(|(r, _)| r.id)
    }

    fn on_push(&mut self, req: &SchedRequest, _now: f64) {
        let seq = self.seqs.assign(req.id);
        self.index.insert(Self::key(req, seq));
    }

    fn on_remove(&mut self, req: &SchedRequest) {
        let seq = self.seqs.take(req.id);
        let removed = self.index.remove(&Self::key(req, seq));
        debug_assert!(removed, "robust_sjf index missing request {}", req.id);
    }

    fn name(&self) -> &'static str {
        "robust_sjf"
    }
}

/// Earliest deadline first (ties → FIFO position, i.e. first seen).
///
/// Incremental: a BTree keyed `(deadline, arrival, seq)` — deadline buckets
/// with exact within-bucket queue order, selection O(log depth). The
/// `(arrival, seq)` suffix *is* queue position (lists stay arrival-sorted),
/// so the first entry reproduces the scan's first-seen tie rule.
#[derive(Default)]
pub struct Edf {
    index: BTreeSet<(u64, u64, u64, ReqId)>,
    seqs: SeqTable,
    work: u64,
}

impl Edf {
    /// An empty EDF index.
    pub fn new() -> Edf {
        Edf::default()
    }

    fn key(req: &SchedRequest, seq: u64) -> (u64, u64, u64, ReqId) {
        (key_bits(req.deadline_ms), key_bits(req.arrival_ms), seq, req.id)
    }
}

impl Ordering for Edf {
    fn select(&mut self, queue: QueueView<'_>, now: f64) -> Option<ReqId> {
        debug_assert_eq!(self.index.len(), queue.len(), "edf index out of sync (missed hook?)");
        let winner = self.index.first().map(|&(_, _, _, id)| id);
        self.work += u64::from(winner.is_some());
        debug_assert_eq!(winner, self.reference_select(queue, now), "edf index vs reference");
        winner
    }

    fn select_work(&self) -> u64 {
        self.work
    }

    fn reference_select(&self, queue: QueueView<'_>, _now: f64) -> Option<ReqId> {
        let mut best: Option<&SchedRequest> = None;
        for r in queue.iter() {
            if best.map_or(true, |b| r.deadline_ms < b.deadline_ms) {
                best = Some(r);
            }
        }
        best.map(|r| r.id)
    }

    fn on_push(&mut self, req: &SchedRequest, _now: f64) {
        let seq = self.seqs.assign(req.id);
        self.index.insert(Self::key(req, seq));
    }

    fn on_remove(&mut self, req: &SchedRequest) {
        let seq = self.seqs.take(req.id);
        let removed = self.index.remove(&Self::key(req, seq));
        debug_assert!(removed, "edf index missing request {}", req.id);
    }

    fn name(&self) -> &'static str {
        "edf"
    }
}

#[cfg(test)]
pub(crate) mod test_util {
    use super::Ordering;
    use crate::core::{Class, Priors, TokenBucket};
    use crate::predictor::Route;
    use crate::scheduler::queues::{ClassQueues, SchedRequest};

    /// Test request. Routed to the heavy class regardless of p50 so that
    /// ordering tests exercise one queue in push order.
    pub fn sreq(id: usize, arrival: f64, p50: f64, deadline: f64) -> SchedRequest {
        SchedRequest {
            id,
            arrival_ms: arrival,
            deadline_ms: deadline,
            priors: Priors::new(p50, p50 * 1.5),
            route: Route::from_bucket(TokenBucket::Long),
            defer_attempts: 0,
        }
    }

    /// Like [`sreq`] but with an interval width on the prior.
    pub fn sreq_w(id: usize, arrival: f64, p50: f64, width: f64, deadline: f64) -> SchedRequest {
        let mut r = sreq(id, arrival, p50, deadline);
        r.priors = Priors::with_width(p50, p50 * 1.5, width);
        r
    }

    /// Build slab queues holding `reqs` in order (all heavy-class),
    /// driving the ordering's lifecycle hooks at push time `now = 0` (so
    /// any later select time is valid under the monotone-now contract).
    pub fn queues_into(reqs: Vec<SchedRequest>, ord: &mut dyn Ordering) -> ClassQueues {
        let mut q = ClassQueues::new();
        for r in reqs {
            ord.on_push(&r, 0.0);
            q.push(r);
        }
        q
    }

    pub const HEAVY: Class = Class::Heavy;
}

#[cfg(test)]
mod tests {
    use super::test_util::{queues_into, sreq, sreq_w, HEAVY};
    use super::*;

    #[test]
    fn fifo_picks_head() {
        let mut f = Fifo;
        let q = queues_into(vec![sreq(1, 0.0, 500.0, 1e5), sreq(2, 1.0, 10.0, 1e5)], &mut f);
        assert_eq!(f.select(q.view(HEAVY), 10.0), Some(1));
        let empty = queues_into(vec![], &mut f);
        assert_eq!(f.select(empty.view(HEAVY), 10.0), None);
    }

    #[test]
    fn sjf_picks_smallest() {
        let mut s = Sjf::new();
        let q = queues_into(
            vec![sreq(1, 0.0, 500.0, 1e5), sreq(2, 1.0, 10.0, 1e5), sreq(3, 2.0, 100.0, 1e5)],
            &mut s,
        );
        assert_eq!(s.select(q.view(HEAVY), 10.0), Some(2));
    }

    #[test]
    fn sjf_ties_break_by_age() {
        let mut s = Sjf::new();
        let q = queues_into(vec![sreq(1, 5.0, 100.0, 1e5), sreq(2, 1.0, 100.0, 1e5)], &mut s);
        assert_eq!(s.select(q.view(HEAVY), 10.0), Some(2));
    }

    #[test]
    fn sjf_index_tracks_removals() {
        let mut s = Sjf::new();
        let mut q = queues_into(
            vec![sreq(1, 0.0, 50.0, 1e5), sreq(2, 1.0, 20.0, 1e5), sreq(3, 2.0, 90.0, 1e5)],
            &mut s,
        );
        assert_eq!(s.select(q.view(HEAVY), 5.0), Some(2));
        let r = q.remove_id(2).unwrap();
        s.on_remove(&r);
        assert_eq!(s.select(q.view(HEAVY), 6.0), Some(1));
        let r = q.remove_id(1).unwrap();
        s.on_remove(&r);
        assert_eq!(s.select(q.view(HEAVY), 7.0), Some(3));
        let r = q.remove_id(3).unwrap();
        s.on_remove(&r);
        assert_eq!(s.select(q.view(HEAVY), 8.0), None);
    }

    #[test]
    fn robust_sjf_demotes_wide_intervals() {
        let mut s = RobustSjf::new();
        // id 1: small point estimate but huge uncertainty (robust cost 100
        // + 400 = 500); id 2: larger but confident (robust cost 300).
        let q = queues_into(
            vec![sreq_w(1, 0.0, 100.0, 400.0, 1e5), sreq_w(2, 1.0, 300.0, 0.0, 1e5)],
            &mut s,
        );
        assert_eq!(s.select(q.view(HEAVY), 10.0), Some(2));
        // Plain SJF would have picked the small-but-uncertain one.
        let mut plain = Sjf::new();
        let q2 = queues_into(
            vec![sreq_w(1, 0.0, 100.0, 400.0, 1e5), sreq_w(2, 1.0, 300.0, 0.0, 1e5)],
            &mut plain,
        );
        assert_eq!(plain.select(q2.view(HEAVY), 10.0), Some(1));
    }

    #[test]
    fn robust_sjf_equals_sjf_on_point_priors() {
        let reqs =
            vec![sreq(1, 0.0, 500.0, 1e5), sreq(2, 1.0, 10.0, 1e5), sreq(3, 2.0, 10.0, 1e5)];
        let mut robust = RobustSjf::new();
        let qa = queues_into(reqs.clone(), &mut robust);
        let mut plain = Sjf::new();
        let qb = queues_into(reqs, &mut plain);
        assert_eq!(robust.select(qa.view(HEAVY), 5.0), plain.select(qb.view(HEAVY), 5.0));
    }

    #[test]
    fn robust_sjf_ties_break_by_age() {
        let mut s = RobustSjf::new();
        // Equal robust costs (100+50 == 140+10), older wins.
        let q = queues_into(
            vec![sreq_w(1, 5.0, 100.0, 50.0, 1e5), sreq_w(2, 1.0, 140.0, 10.0, 1e5)],
            &mut s,
        );
        assert_eq!(s.select(q.view(HEAVY), 10.0), Some(2));
    }

    #[test]
    fn robust_sjf_index_tracks_removals() {
        let mut s = RobustSjf::new();
        let mut q = queues_into(
            vec![
                sreq_w(1, 0.0, 50.0, 100.0, 1e5),
                sreq_w(2, 1.0, 120.0, 0.0, 1e5),
                sreq_w(3, 2.0, 90.0, 200.0, 1e5),
            ],
            &mut s,
        );
        assert_eq!(s.select(q.view(HEAVY), 5.0), Some(2));
        let r = q.remove_id(2).unwrap();
        s.on_remove(&r);
        assert_eq!(s.select(q.view(HEAVY), 6.0), Some(1));
        let r = q.remove_id(1).unwrap();
        s.on_remove(&r);
        assert_eq!(s.select(q.view(HEAVY), 7.0), Some(3));
    }

    #[test]
    fn edf_picks_earliest_deadline() {
        let mut e = Edf::new();
        let q = queues_into(vec![sreq(1, 0.0, 10.0, 9000.0), sreq(2, 1.0, 10.0, 4000.0)], &mut e);
        assert_eq!(e.select(q.view(HEAVY), 10.0), Some(2));
    }

    #[test]
    fn edf_deadline_ties_keep_queue_order() {
        let mut e = Edf::new();
        let q = queues_into(vec![sreq(1, 0.0, 10.0, 4000.0), sreq(2, 1.0, 10.0, 4000.0)], &mut e);
        // Equal deadlines: the reference scan keeps the first-seen (queue
        // head); the index must agree.
        assert_eq!(e.select(q.view(HEAVY), 10.0), Some(1));
    }
}
