//! Slowdown-aware feasible-set ordering (paper §3.1 layer 2).
//!
//! Among requests eligible under fairness constraints, score candidates:
//!
//!   score = w_wait · (wait / cost) − w_size · (size / ref) + w_urg · urgency
//!
//! favoring older and smaller jobs while respecting deadline urgency. The
//! *feasible set* restricts candidates to those whose estimated completion
//! (client-side service estimate on the p90 prior) still meets the deadline;
//! if no candidate is feasible the rule falls back to the full set and
//! counts a feasibility violation (the paper reports zero across all runs —
//! our integration tests assert the counter stays 0 in the main benchmark).
//!
//! Selection is one pass over the queue view with no intermediate index
//! vectors: the best feasible and best overall candidates are tracked
//! simultaneously (the previous implementation allocated two `Vec<usize>`
//! per pump iteration, which dominated allocator traffic at scale).

use super::Ordering;
use crate::core::ReqId;
use crate::scheduler::queues::{QueueView, SchedRequest};

#[derive(Debug, Clone)]
pub struct OrderingCfg {
    pub w_wait: f64,
    pub w_size: f64,
    pub w_urgency: f64,
    /// Normalizing token reference for the size term.
    pub ref_tokens: f64,
    /// Client-side belief of the provider's linear service model (for the
    /// feasibility estimate; learned constants would also work — kept
    /// explicit so the feasibility rule is auditable).
    pub est_base_ms: f64,
    pub est_per_token_ms: f64,
    /// Safety multiplier on the estimate (provider congestion headroom).
    pub est_slack_factor: f64,
}

impl Default for OrderingCfg {
    fn default() -> Self {
        OrderingCfg {
            w_wait: 1.0,
            w_size: 0.6,
            w_urgency: 0.8,
            ref_tokens: 512.0,
            est_base_ms: 150.0,
            est_per_token_ms: 0.9,
            est_slack_factor: 1.5,
        }
    }
}

pub struct FeasibleSet {
    cfg: OrderingCfg,
    violations: u64,
}

impl FeasibleSet {
    pub fn new(cfg: OrderingCfg) -> Self {
        FeasibleSet { cfg, violations: 0 }
    }

    /// Times the full set had no feasible candidate (fallback taken).
    pub fn violations(&self) -> u64 {
        self.violations
    }

    /// Estimated service time for a prior (p90, conservative).
    fn est_service_ms(&self, p90_tokens: f64) -> f64 {
        (self.cfg.est_base_ms + self.cfg.est_per_token_ms * p90_tokens) * self.cfg.est_slack_factor
    }

    fn feasible(&self, r: &SchedRequest, now: f64) -> bool {
        now + self.est_service_ms(r.priors.p90) <= r.deadline_ms
    }

    /// The paper's score; higher = release sooner.
    pub fn score(&self, r: &SchedRequest, now: f64) -> f64 {
        let c = &self.cfg;
        let wait_s = r.wait_ms(now) / 1000.0;
        let cost = r.priors.p50.max(1.0);
        // wait/cost in seconds-per-kilotoken so magnitudes are O(1).
        let wait_term = wait_s / (cost / 1000.0);
        let size_term = r.priors.p50 / c.ref_tokens;
        // Urgency ramps 0→1 as slack shrinks below the urgency window
        // (one estimated service time).
        let window = self.est_service_ms(r.priors.p90).max(1.0);
        let slack = r.deadline_ms - now;
        let urgency = (1.0 - slack / (2.0 * window)).clamp(0.0, 1.0);
        c.w_wait * wait_term - c.w_size * size_term + c.w_urgency * urgency
    }
}

trait WaitExt {
    fn wait_ms(&self, now: f64) -> f64;
}

impl WaitExt for SchedRequest {
    fn wait_ms(&self, now: f64) -> f64 {
        (now - self.arrival_ms).max(0.0)
    }
}

impl Ordering for FeasibleSet {
    fn select(&mut self, queue: QueueView<'_>, now: f64) -> Option<ReqId> {
        // `>=` keeps the later candidate on score ties, matching the
        // previous max_by-based selection (max_by returns the last maximum)
        // so this refactor changes no run output.
        let mut best_feasible: Option<(ReqId, f64)> = None;
        let mut best_any: Option<(ReqId, f64)> = None;
        for r in queue.iter() {
            let s = self.score(r, now);
            if best_any.map_or(true, |(_, b)| s >= b) {
                best_any = Some((r.id, s));
            }
            if self.feasible(r, now) && best_feasible.map_or(true, |(_, b)| s >= b) {
                best_feasible = Some((r.id, s));
            }
        }
        match (best_feasible, best_any) {
            (Some((id, _)), _) => Some(id),
            (None, Some((id, _))) => {
                self.violations += 1;
                Some(id)
            }
            (None, None) => None,
        }
    }

    fn name(&self) -> &'static str {
        "feasible_set"
    }

    fn feasibility_violations(&self) -> u64 {
        self.violations
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_util::{queues_of, sreq, HEAVY};
    use super::*;

    fn fs() -> FeasibleSet {
        FeasibleSet::new(OrderingCfg::default())
    }

    #[test]
    fn favors_older_jobs() {
        let mut f = fs();
        // Same size/deadline-slack; the older one (id 2) wins.
        let q = queues_of(vec![sreq(1, 1000.0, 500.0, 1e6), sreq(2, 0.0, 500.0, 1e6)]);
        assert_eq!(f.select(q.view(HEAVY), 2000.0), Some(2));
    }

    #[test]
    fn favors_smaller_jobs() {
        let mut f = fs();
        let q = queues_of(vec![sreq(1, 0.0, 3000.0, 1e6), sreq(2, 0.0, 300.0, 1e6)]);
        assert_eq!(f.select(q.view(HEAVY), 100.0), Some(2));
    }

    #[test]
    fn urgency_overrides_size() {
        let f = fs();
        // Large job right at its deadline window vs small job with huge slack.
        let big_deadline = 100.0 + (170.0 + 0.9 * 3000.0 * 1.5) * 1.4; // inside 2×window
        let big = sreq(1, 0.0, 2000.0, big_deadline);
        let small = sreq(2, 0.0, 400.0, 1e7);
        let s_big = f.score(&big, 100.0);
        let s_small = f.score(&small, 100.0);
        assert!(s_big > s_small - 2.0, "urgency should lift the big job: {s_big} vs {s_small}");
    }

    #[test]
    fn infeasible_candidates_excluded() {
        let mut f = fs();
        // Request 1's deadline already passed; request 2 comfortably feasible.
        let q = queues_of(vec![sreq(1, 0.0, 100.0, 50.0), sreq(2, 0.0, 4000.0, 1e7)]);
        assert_eq!(
            f.select(q.view(HEAVY), 100.0),
            Some(2),
            "feasible big beats infeasible small"
        );
        assert_eq!(f.violations(), 0);
    }

    #[test]
    fn all_infeasible_falls_back_and_counts() {
        let mut f = fs();
        let q = queues_of(vec![sreq(1, 0.0, 100.0, 10.0), sreq(2, 0.0, 200.0, 20.0)]);
        let sel = f.select(q.view(HEAVY), 100.0);
        assert!(sel.is_some());
        assert_eq!(f.violations(), 1);
    }

    #[test]
    fn empty_queue() {
        let mut f = fs();
        let q = queues_of(vec![]);
        assert_eq!(f.select(q.view(HEAVY), 0.0), None);
        assert_eq!(f.violations(), 0);
    }

    #[test]
    fn score_monotone_in_wait() {
        let f = fs();
        let r = sreq(1, 0.0, 500.0, 1e6);
        assert!(f.score(&r, 5000.0) > f.score(&r, 1000.0));
    }

    #[test]
    fn prop_select_returns_a_queued_id() {
        use crate::testing::prop;
        prop::forall(100, |g| {
            let mut f = fs();
            let n = g.usize_in(1, 30);
            let reqs: Vec<_> = (0..n)
                .map(|i| {
                    sreq(
                        i,
                        g.f64_in(0.0, 1000.0),
                        g.f64_in(10.0, 4000.0),
                        g.f64_in(0.0, 200_000.0),
                    )
                })
                .collect();
            let q = queues_of(reqs);
            let now = g.f64_in(0.0, 5000.0);
            let sel = f.select(q.view(HEAVY), now).unwrap();
            assert!(sel < n, "selected id {sel} not in 0..{n}");
            assert!(q.get(sel).is_some(), "selected id must still be queued");
        });
    }

    #[test]
    fn single_pass_matches_two_phase_reference() {
        use crate::testing::prop;
        // The fused selection must agree with the spec's two-phase rule:
        // argmax score over the feasible set, else argmax over everything.
        prop::forall(100, |g| {
            let mut f = fs();
            let n = g.usize_in(1, 25);
            let reqs: Vec<_> = (0..n)
                .map(|i| {
                    sreq(
                        i,
                        g.f64_in(0.0, 2000.0),
                        g.f64_in(10.0, 4000.0),
                        g.f64_in(0.0, 60_000.0),
                    )
                })
                .collect();
            let now = g.f64_in(0.0, 10_000.0);
            let reference = {
                let r = fs();
                let feasible: Vec<&SchedRequest> = reqs
                    .iter()
                    .filter(|x| now + r.est_service_ms(x.priors.p90) <= x.deadline_ms)
                    .collect();
                let pool: Vec<&SchedRequest> =
                    if feasible.is_empty() { reqs.iter().collect() } else { feasible };
                pool.into_iter()
                    .map(|x| (x.id, r.score(x, now)))
                    .max_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
                    .map(|(id, _)| id)
            };
            let q = queues_of(reqs);
            assert_eq!(f.select(q.view(HEAVY), now), reference);
        });
    }
}
