//! Slowdown-aware feasible-set ordering (paper §3.1 layer 2).
//!
//! Among requests eligible under fairness constraints, score candidates:
//!
//!   score = w_wait · (wait / cost) − w_size · (size / ref) + w_urg · urgency
//!
//! favoring older and smaller jobs while respecting deadline urgency. The
//! *feasible set* restricts candidates to those whose estimated completion
//! (client-side service estimate on the p90 prior) still meets the deadline;
//! if no candidate is feasible the rule falls back to the full set and
//! counts a feasibility violation (the paper reports zero across all runs —
//! our integration tests assert the counter stays 0 in the main benchmark).
//!
//! ## Incremental candidate index
//!
//! The score is time-varying, so no single static key orders candidates.
//! But its structure is narrow:
//!
//! * Entries sharing the *same prior bits* `(p50, p90)` share the same
//!   cost, size term, feasibility window, and urgency window — and ladder
//!   priors are discrete, so live entries collapse into a **handful of
//!   groups**. (Continuous priors degrade gracefully: one group per entry
//!   makes selection a scan again, never worse than the reference.)
//! * Within a group, an entry passes through three **urgency phases**:
//!   pre-urgent (clamped to 0), the ramp, and saturated (clamped to 1).
//!   In the clamped phases the score differs across the group only through
//!   the wait term, which is weakly decreasing in arrival for every `now` —
//!   so the group order is *static* (by arrival) and the exact maximum is a
//!   tie-prefix walk from the front. In the ramp phase the *real* score is
//!   `Φ_group(now) − κ` for the static per-entry key
//!   `κ = w_wait·arrival/cost + w_urg·deadline/(2·window)`, so the order is
//!   static up to f64 rounding wobble — the walk takes every entry whose κ
//!   is within a conservative ε of the minimum (ε is many orders above the
//!   rounding bound and many below real κ gaps) and scores those exactly.
//! * Phase boundaries and the feasible→infeasible flip happen **once per
//!   entry**, at instants found by binary search over the f64 bit space of
//!   the *actual* predicates (the same arithmetic `select` evaluates), so
//!   migrations are bit-exact and cost O(1) amortized per entry lifetime —
//!   not per bucket crossing, not per release.
//!
//! Selection therefore reads O(groups · (log + prefix)) entries plus the
//! due migrations, instead of rescanning O(live depth); `select_work()`
//! counts every entry examined so the bench `--depth` leg can gate the
//! scaling deterministically.
//!
//! The retained reference scan ([`FeasibleSet::reference_select`]) is the
//! spec; debug builds assert index == reference on every call and
//! `tests/ordering_index.rs` property-tests the equivalence in release.

use super::Ordering;
use crate::core::ReqId;
use crate::scheduler::queues::{QueueView, SchedRequest};
use std::collections::{BTreeMap, BTreeSet, HashMap};

/// Default mantissa bits kept by quantized grouping (`OrderingCfg::
/// quant_bits`): bins are ~0.8% wide in relative terms — coarse enough
/// that continuous noisy priors collapse into a bounded bin population,
/// fine enough that the per-group affine score bounds stay tight and the
/// bound-pruned walk touches only a short κ-prefix per group.
pub const QUANT_BITS_DEFAULT: u32 = 7;

/// Feasible-set score weights and the client-side service-time belief.
#[derive(Debug, Clone)]
pub struct OrderingCfg {
    /// Weight of the normalized-wait term (favors older requests).
    pub w_wait: f64,
    /// Weight of the size penalty (favors smaller jobs).
    pub w_size: f64,
    /// Weight of the deadline-urgency term.
    pub w_urgency: f64,
    /// Normalizing token reference for the size term.
    pub ref_tokens: f64,
    /// Client-side belief of the provider's linear service model (for the
    /// feasibility estimate; learned constants would also work — kept
    /// explicit so the feasibility rule is auditable).
    pub est_base_ms: f64,
    /// Per-token slope of the same service-time belief.
    pub est_per_token_ms: f64,
    /// Safety multiplier on the estimate (provider congestion headroom).
    pub est_slack_factor: f64,
    /// Quantized index grouping: `Some(m)` groups entries by the top `m`
    /// mantissa bits of `(p50, p90)` instead of their exact bit patterns,
    /// so *continuous* priors (noisy sources) collapse into a bounded set
    /// of bins instead of one group per entry. Scoring stays exact on the
    /// raw floats — only the grouping key coarsens, and within-bin
    /// selection walks a κ-ordered list under a per-bin affine score bound,
    /// so winners (and the keep-later tie rule) are bit-identical to the
    /// reference scan. `None` (default) keeps exact-bit grouping and the
    /// original selection path, work counts included.
    pub quant_bits: Option<u32>,
}

impl Default for OrderingCfg {
    fn default() -> Self {
        OrderingCfg {
            w_wait: 1.0,
            w_size: 0.6,
            w_urgency: 0.8,
            ref_tokens: 512.0,
            est_base_ms: 150.0,
            est_per_token_ms: 0.9,
            est_slack_factor: 1.5,
            quant_bits: None,
        }
    }
}

impl OrderingCfg {
    /// The default config with quantized grouping at [`QUANT_BITS_DEFAULT`].
    pub fn quantized() -> Self {
        OrderingCfg { quant_bits: Some(QUANT_BITS_DEFAULT), ..OrderingCfg::default() }
    }
}

/// Index sides: feasible entries first, the fallback pool second.
const FEASIBLE: usize = 0;
const INFEASIBLE: usize = 1;

/// One list entry: `(primary key bits, arrival bits, seq, id)`. The primary
/// key is the arrival again for the clamped phases (static order by age)
/// and κ for the ramp phase; `(arrival, seq)` is exact queue position (the
/// class lists stay arrival-sorted), which the keep-later tie rule needs.
type ListKey = (u64, u64, u64, ReqId);

/// Per-entry index metadata (a copy of the score inputs — hooks see the
/// request only at push/remove, but scoring needs them at arbitrary times).
struct Entry {
    seq: u64,
    arrival_ms: f64,
    deadline_ms: f64,
    p50: f64,
    p90: f64,
    /// Static ramp-phase order key (see module docs).
    kappa: f64,
    /// Quantized-mode clamped-phase key: `w_wait·arr/bin_lo(p50) +
    /// w_size·p50/ref`. Built against the entry's *bin* bounds so the
    /// per-group bound `slope_ub·now − κ + shared` dominates the true
    /// score pointwise (see `select_side_quant`). 0.0 in exact mode.
    kq_clamped: f64,
    /// Quantized-mode ramp key: `kq_clamped + w_urg·deadline/(2·win_hi)`
    /// with `win_hi` from the p90 bin's upper bound. 0.0 in exact mode.
    kq_ramp: f64,
    /// 0 = pre-urgent, 1 = ramp, 2 = saturated.
    phase: usize,
    feasible: bool,
    /// First instant the urgency term computes > 0 (f64 bits).
    t_ramp_bits: u64,
    /// First instant the urgency term computes == 1 (f64 bits).
    t_sat_bits: u64,
    /// First instant the feasibility predicate computes false (f64 bits).
    expire_bits: u64,
}

/// Entries sharing one `(p50 bits, p90 bits)` prior: per side, per phase,
/// a statically-ordered list.
#[derive(Default)]
struct Group {
    lists: [[BTreeSet<ListKey>; 3]; 2],
    len: [usize; 2],
}

/// The slowdown-aware feasible-set ordering with its incremental
/// group/phase candidate index (see the module docs).
pub struct FeasibleSet {
    cfg: OrderingCfg,
    violations: u64,
    /// Prior-keyed groups. A BTreeMap (not a HashMap) so iteration order —
    /// which the quantized walk's global-best pruning makes observable
    /// through `select_work` — is a pure function of the keys.
    groups: BTreeMap<(u64, u64), Group>,
    entries: HashMap<ReqId, Entry>,
    /// (t_ramp bits, id) for phase-0 entries.
    ramp_due: BTreeSet<(u64, ReqId)>,
    /// (t_sat bits, id) for phase-0/1 entries.
    sat_due: BTreeSet<(u64, ReqId)>,
    /// (first-infeasible bits, id) for feasible entries.
    expiries: BTreeSet<(u64, ReqId)>,
    /// Live entry counts per side.
    live: [usize; 2],
    next_seq: u64,
    /// Largest arrival ever pushed. The ramp κ order encodes the score only
    /// where the wait term is unclamped (`now ≥ arrival`); the production
    /// scheduler always pushes at `now == arrival`, but the hook API does
    /// not forbid future arrivals, so κ-pruning stays off until `now` has
    /// passed every pushed arrival.
    max_arrival: f64,
    /// Cumulative entries examined + migrations processed by `select` —
    /// the deterministic per-release cost the bench `--depth` leg gates.
    work: u64,
    /// Peak number of distinct prior groups held (diagnostics).
    peak_groups: u64,
    /// Selects that examined at least as many entries as were live on the
    /// scanned side — the per-entry-scan regime (diagnostics).
    scan_fallbacks: u64,
}

impl FeasibleSet {
    /// An empty index with the given weights.
    pub fn new(cfg: OrderingCfg) -> Self {
        // The index leans on score monotonicity in `now`; negative wait or
        // urgency weights would break it (and were never meaningful).
        assert!(
            cfg.w_wait >= 0.0 && cfg.w_urgency >= 0.0,
            "feasible-set wait/urgency weights must be non-negative"
        );
        if let Some(m) = cfg.quant_bits {
            // The quantized keys additionally require a non-negative size
            // weight (κ must be bit-orderable) and a monotone service
            // estimate (the per-bin window bounds lean on it).
            assert!((1..=52).contains(&m), "quant_bits {m} outside 1..=52");
            assert!(
                cfg.w_size >= 0.0 && cfg.est_per_token_ms >= 0.0 && cfg.est_slack_factor >= 0.0,
                "quantized grouping requires non-negative size weight and service slopes"
            );
        }
        FeasibleSet {
            cfg,
            violations: 0,
            groups: BTreeMap::new(),
            entries: HashMap::new(),
            ramp_due: BTreeSet::new(),
            sat_due: BTreeSet::new(),
            expiries: BTreeSet::new(),
            live: [0, 0],
            next_seq: 0,
            max_arrival: f64::NEG_INFINITY,
            work: 0,
            peak_groups: 0,
            scan_fallbacks: 0,
        }
    }

    /// Times the full set had no feasible candidate (fallback taken).
    pub fn violations(&self) -> u64 {
        self.violations
    }

    /// Estimated service time for a prior (p90, conservative).
    fn est_service_ms(&self, p90_tokens: f64) -> f64 {
        (self.cfg.est_base_ms + self.cfg.est_per_token_ms * p90_tokens) * self.cfg.est_slack_factor
    }

    fn feasible_at(&self, deadline_ms: f64, p90: f64, now: f64) -> bool {
        now + self.est_service_ms(p90) <= deadline_ms
    }

    fn feasible(&self, r: &SchedRequest, now: f64) -> bool {
        self.feasible_at(r.deadline_ms, r.priors.p90, now)
    }

    /// The urgency term exactly as the score computes it.
    fn urgency_at(&self, p90: f64, deadline_ms: f64, now: f64) -> f64 {
        let window = self.est_service_ms(p90).max(1.0);
        let slack = deadline_ms - now;
        (1.0 - slack / (2.0 * window)).clamp(0.0, 1.0)
    }

    /// The paper's score; higher = release sooner.
    pub fn score(&self, r: &SchedRequest, now: f64) -> f64 {
        self.score_parts(r.arrival_ms, r.priors.p50, r.priors.p90, r.deadline_ms, now)
    }

    /// Score from cached inputs — bit-identical arithmetic to [`Self::score`].
    fn score_parts(&self, arrival_ms: f64, p50: f64, p90: f64, deadline_ms: f64, now: f64) -> f64 {
        let c = &self.cfg;
        let wait_s = (now - arrival_ms).max(0.0) / 1000.0;
        let cost = p50.max(1.0);
        // wait/cost in seconds-per-kilotoken so magnitudes are O(1).
        let wait_term = wait_s / (cost / 1000.0);
        let size_term = p50 / c.ref_tokens;
        // Urgency ramps 0→1 as slack shrinks below the urgency window
        // (one estimated service time).
        let urgency = self.urgency_at(p90, deadline_ms, now);
        c.w_wait * wait_term - c.w_size * size_term + c.w_urgency * urgency
    }

    /// Upper bound on d(score)/d(now) — used only to scale the ramp ε.
    fn max_rate(&self, p50: f64, p90: f64) -> f64 {
        let cost = p50.max(1.0);
        let window = self.est_service_ms(p90).max(1.0);
        self.cfg.w_wait / cost + self.cfg.w_urgency / (2.0 * window)
    }

    /// Smallest f64 instant at which an upward-closed predicate over `now`
    /// becomes true, by binary search over the bit space of non-negative
    /// f64s (bit order == numeric order there). Every phase/feasibility
    /// predicate is monotone in `now` (f64 arithmetic is weakly monotone),
    /// so the flip point this finds is *exactly* where the scan's own
    /// arithmetic flips — no `deadline − est` style rounding drift.
    fn first_instant(pred: impl Fn(f64) -> bool) -> f64 {
        if pred(0.0) {
            return 0.0;
        }
        if !pred(f64::INFINITY) {
            return f64::INFINITY;
        }
        let mut lo = 0f64.to_bits(); // pred false
        let mut hi = f64::INFINITY.to_bits(); // pred true
        while hi - lo > 1 {
            let mid = lo + (hi - lo) / 2;
            if pred(f64::from_bits(mid)) {
                hi = mid;
            } else {
                lo = mid;
            }
        }
        f64::from_bits(hi)
    }

    /// Smallest instant at which the entry's feasibility predicate is false.
    fn first_infeasible_ms(&self, deadline_ms: f64, p90: f64) -> f64 {
        Self::first_instant(|t| !self.feasible_at(deadline_ms, p90, t))
    }

    /// Width (in raw u64 bit-space) of one quantization bin: everything
    /// below the top `m` mantissa bits is masked off, so bin boundaries are
    /// exact powers-of-two steps in the float's bit pattern.
    fn bin_step(m: u32) -> u64 {
        1u64 << (52 - m)
    }

    /// `[lo, hi)` bin bounds of a non-negative float under `m`-bit
    /// quantization. `hi` is the next bin's first representable value.
    fn bin_bounds(v: f64, m: u32) -> (f64, f64) {
        let step = Self::bin_step(m);
        let lo = v.to_bits() & !(step - 1);
        (f64::from_bits(lo), f64::from_bits(lo + step))
    }

    /// Group key for an entry's priors: exact `(p50, p90)` bits, or their
    /// bin floors under quantized grouping.
    fn group_key(&self, p50: f64, p90: f64) -> (u64, u64) {
        match self.cfg.quant_bits {
            None => (p50.to_bits(), p90.to_bits()),
            Some(m) => {
                let step = Self::bin_step(m);
                (p50.to_bits() & !(step - 1), p90.to_bits() & !(step - 1))
            }
        }
    }

    /// Per-group affine score-bound slopes under quantized grouping, from
    /// the group key's bin bounds alone: `(clamped, ramp)` upper bounds on
    /// d(bound)/d(now). The clamped slope `w_wait / bin_lo(p50)` pairs with
    /// `kq_clamped`; the ramp slope adds `w_urg / (2·win_hi)` and pairs
    /// with `kq_ramp` (see `select_side_quant` for the dominance argument).
    fn group_slopes(&self, gk: (u64, u64), m: u32) -> (f64, f64) {
        let p50_lo = f64::from_bits(gk.0).max(1.0);
        let p90_hi = f64::from_bits(gk.1 + Self::bin_step(m));
        let win_hi = self.est_service_ms(p90_hi).max(1.0);
        let clamped = self.cfg.w_wait / p50_lo;
        (clamped, clamped + self.cfg.w_urgency / (2.0 * win_hi))
    }

    fn list_key(&self, e: &Entry, id: ReqId) -> ListKey {
        let primary = if self.cfg.quant_bits.is_some() {
            if e.phase == 1 {
                e.kq_ramp.to_bits()
            } else {
                e.kq_clamped.to_bits()
            }
        } else if e.phase == 1 {
            e.kappa.to_bits()
        } else {
            e.arrival_ms.to_bits()
        };
        (primary, e.arrival_ms.to_bits(), e.seq, id)
    }

    fn side_of(e: &Entry) -> usize {
        if e.feasible {
            FEASIBLE
        } else {
            INFEASIBLE
        }
    }

    /// Insert `id` into its group list per its current (side, phase).
    fn list_insert(&mut self, id: ReqId) {
        let e = &self.entries[&id];
        let gk = self.group_key(e.p50, e.p90);
        let (sd, ph) = (Self::side_of(e), e.phase);
        let key = self.list_key(e, id);
        let g = self.groups.entry(gk).or_default();
        let inserted = g.lists[sd][ph].insert(key);
        debug_assert!(inserted, "duplicate index entry for {id}");
        g.len[sd] += 1;
        self.live[sd] += 1;
        self.peak_groups = self.peak_groups.max(self.groups.len() as u64);
    }

    /// Remove `id` from its group list (entry metadata stays).
    fn list_remove(&mut self, id: ReqId) {
        let e = &self.entries[&id];
        let gk = self.group_key(e.p50, e.p90);
        let (sd, ph) = (Self::side_of(e), e.phase);
        let key = self.list_key(e, id);
        let empty = {
            let g = self.groups.get_mut(&gk).expect("entry group present");
            let removed = g.lists[sd][ph].remove(&key);
            debug_assert!(removed, "index entry missing for {id}");
            g.len[sd] -= 1;
            g.len[0] == 0 && g.len[1] == 0
        };
        self.live[sd] -= 1;
        if empty {
            self.groups.remove(&gk);
        }
    }

    /// Bring the index current at `now`: each migration fires once per
    /// entry lifetime (phase boundaries and the feasibility flip), so the
    /// amortized cost per release is O(1) per touched entry.
    fn refresh(&mut self, now: f64) {
        // Pre-urgent → ramp. t_ramp ≤ t_sat always, so running this loop
        // first means the saturation loop only ever sees phase-1 entries.
        while let Some(&(bits, id)) = self.ramp_due.first() {
            if f64::from_bits(bits) > now {
                break;
            }
            self.ramp_due.pop_first();
            self.work += 1;
            self.list_remove(id);
            self.entries.get_mut(&id).expect("ramp entry known").phase = 1;
            self.list_insert(id);
        }
        // Ramp → saturated.
        while let Some(&(bits, id)) = self.sat_due.first() {
            if f64::from_bits(bits) > now {
                break;
            }
            self.sat_due.pop_first();
            self.work += 1;
            self.list_remove(id);
            {
                let e = self.entries.get_mut(&id).expect("sat entry known");
                debug_assert_eq!(e.phase, 1, "saturation fires after the ramp transition");
                e.phase = 2;
            }
            self.list_insert(id);
        }
        // Feasible → infeasible (same phase, sibling side).
        while let Some(&(bits, id)) = self.expiries.first() {
            if f64::from_bits(bits) > now {
                break;
            }
            self.expiries.pop_first();
            self.work += 1;
            self.list_remove(id);
            self.entries.get_mut(&id).expect("expiring entry known").feasible = false;
            self.list_insert(id);
        }
    }

    fn consider(best: &mut Option<(f64, (u64, u64), ReqId)>, s: f64, q: (u64, u64), id: ReqId) {
        // Exact reference semantics: max score, ties keep the later queue
        // position (the scan's `>=` update in queue order).
        let better = match best {
            None => true,
            Some((bs, bq, _)) => s > *bs || (s == *bs && q > *bq),
        };
        if better {
            *best = Some((s, q, id));
        }
    }

    /// Exact argmax over one side. Clamped phases: the group order is
    /// static by arrival, so the maximum lives in the exact-score tie
    /// prefix. Ramp phase: the order is static by κ up to rounding wobble,
    /// so every entry within ε of the minimum κ is scored exactly (ε sits
    /// ~9 decimal orders above the f64 error bound of the score evaluation
    /// and far below real κ gaps, so nothing outside the prefix can win).
    fn select_side(&self, sd: usize, now: f64) -> (Option<ReqId>, u64) {
        let mut best: Option<(f64, (u64, u64), ReqId)> = None;
        let mut examined = 0u64;
        for g in self.groups.values() {
            if g.len[sd] == 0 {
                continue;
            }
            for phase in [0usize, 2] {
                let mut first_score: Option<f64> = None;
                for &(_, arr_bits, seq, id) in &g.lists[sd][phase] {
                    let e = &self.entries[&id];
                    let s = self.score_parts(e.arrival_ms, e.p50, e.p90, e.deadline_ms, now);
                    examined += 1;
                    match first_score {
                        None => first_score = Some(s),
                        // Scores are weakly decreasing along the list, so
                        // the first drop ends the tie prefix.
                        Some(f0) => {
                            if s != f0 {
                                break;
                            }
                        }
                    }
                    Self::consider(&mut best, s, (arr_bits, seq), id);
                }
            }
            // κ encodes the ramp score only where the wait term is
            // unclamped: with any live entry possibly arriving after `now`
            // (test harnesses; never the DES scheduler, which pushes at
            // `now == arrival`), prune nothing and score the whole list.
            let prune = now >= self.max_arrival;
            let mut kmin: Option<(f64, f64)> = None;
            for &(kbits, arr_bits, seq, id) in &g.lists[sd][1] {
                let kappa = f64::from_bits(kbits);
                let e = &self.entries[&id];
                match kmin {
                    None => {
                        let size = self.cfg.w_size * (e.p50 / self.cfg.ref_tokens).abs();
                        let drift = now * self.max_rate(e.p50, e.p90);
                        let eps = 1e-7 * (1.0 + kappa.abs()) + 1e-10 * (1.0 + drift + size);
                        kmin = Some((kappa, eps));
                    }
                    Some((k0, eps)) => {
                        if prune && kappa > k0 + eps {
                            break;
                        }
                    }
                }
                let s = self.score_parts(e.arrival_ms, e.p50, e.p90, e.deadline_ms, now);
                examined += 1;
                Self::consider(&mut best, s, (arr_bits, seq), id);
            }
        }
        (best.map(|(_, _, id)| id), examined)
    }

    /// Exact argmax over one side under *quantized* grouping. Entries in a
    /// bin no longer share score inputs, so the clamped tie-prefix trick is
    /// unavailable; instead every phase list is κ-ordered against keys built
    /// from the bin bounds, and the walk stops once the per-bin affine
    /// bound falls below the best exact score found so far.
    ///
    /// Dominance (real arithmetic, for `now ≥` every live arrival):
    /// * clamped phases: `w_wait·(now−arr)/cost ≤ w_wait·(now−arr)/bin_lo`
    ///   since `cost ≥ bin_lo(p50)`, so
    ///   `score ≤ (w_wait/bin_lo)·now − kq_clamped + shared`
    ///   with `kq_clamped = w_wait·arr/bin_lo + w_size·p50/ref` and
    ///   `shared ∈ {0, w_urg}`.
    /// * ramp phase: additionally `w_urg·(now−dl)/(2·win) ≤
    ///   w_urg·(now−dl)/(2·win_hi)` because `now < dl` for every live
    ///   ramp entry (saturation migrates at `t_sat ≤ dl`) and
    ///   `win ≤ win_hi = win(bin_hi(p90))`, so
    ///   `score ≤ slope_ramp·now − kq_ramp + w_urg`.
    ///
    /// The bound is decreasing in κ along each list, so once it drops an
    /// ε-margin below the best score no later entry can win *or tie* — the
    /// margin (~1e-9 relative) sits many orders above the f64 evaluation
    /// error of either side and guards the keep-later tie rule. Every
    /// walked entry is scored by the exact `score_parts` arithmetic, so
    /// winners match the reference scan bit-for-bit.
    fn select_side_quant(&self, sd: usize, now: f64, m: u32) -> (Option<ReqId>, u64) {
        // As in the exact ramp walk: the bounds only dominate where the
        // wait term is unclamped, so pruning stays off until `now` has
        // passed every pushed arrival (always true in the DES scheduler,
        // which pushes at `now == arrival`).
        let prune = now >= self.max_arrival;
        let mut best: Option<(f64, (u64, u64), ReqId)> = None;
        let mut examined = 0u64;
        for (gk, g) in &self.groups {
            if g.len[sd] == 0 {
                continue;
            }
            let (slope_clamped, slope_ramp) = self.group_slopes(*gk, m);
            for (phase, shared, slope_ub) in [
                (0usize, 0.0, slope_clamped),
                (2, self.cfg.w_urgency, slope_clamped),
                (1, self.cfg.w_urgency, slope_ramp),
            ] {
                let drift = slope_ub * now;
                for &(kbits, arr_bits, seq, id) in &g.lists[sd][phase] {
                    if prune {
                        if let Some((bs, _, _)) = best {
                            let kappa = f64::from_bits(kbits);
                            let bound = drift - kappa + shared;
                            let margin = 1e-9 * (1.0 + kappa + drift + shared);
                            if bs > bound + margin {
                                break;
                            }
                        }
                    }
                    let e = &self.entries[&id];
                    let s = self.score_parts(e.arrival_ms, e.p50, e.p90, e.deadline_ms, now);
                    examined += 1;
                    Self::consider(&mut best, s, (arr_bits, seq), id);
                }
            }
        }
        (best.map(|(_, _, id)| id), examined)
    }

    /// Dispatch to the grouping mode's side walk.
    fn side_select(&self, sd: usize, now: f64) -> (Option<ReqId>, u64) {
        match self.cfg.quant_bits {
            None => self.select_side(sd, now),
            Some(m) => self.select_side_quant(sd, now, m),
        }
    }
}

impl Ordering for FeasibleSet {
    fn select(&mut self, queue: QueueView<'_>, now: f64) -> Option<ReqId> {
        debug_assert_eq!(
            self.live[0] + self.live[1],
            queue.len(),
            "feasible-set index out of sync with the queue (missed lifecycle hook?)"
        );
        self.refresh(now);
        let (winner, examined, side_live) = if self.live[FEASIBLE] > 0 {
            let (w, examined) = self.side_select(FEASIBLE, now);
            (w, examined, self.live[FEASIBLE])
        } else if self.live[INFEASIBLE] > 0 {
            self.violations += 1;
            let (w, examined) = self.side_select(INFEASIBLE, now);
            (w, examined, self.live[INFEASIBLE])
        } else {
            (None, 0, 0)
        };
        self.work += examined;
        if side_live > 1 && examined >= side_live as u64 {
            self.scan_fallbacks += 1;
        }
        debug_assert_eq!(
            winner,
            self.reference_select(queue, now),
            "feasible-set index winner diverged from the reference scan at now={now}"
        );
        winner
    }

    fn reference_select(&self, queue: QueueView<'_>, now: f64) -> Option<ReqId> {
        // `>=` keeps the later candidate on score ties, matching the
        // historical max_by-based selection (max_by returns the last
        // maximum) — the tie rule the index must reproduce.
        let mut best_feasible: Option<(ReqId, f64)> = None;
        let mut best_any: Option<(ReqId, f64)> = None;
        for r in queue.iter() {
            let s = self.score(r, now);
            if best_any.map_or(true, |(_, b)| s >= b) {
                best_any = Some((r.id, s));
            }
            if self.feasible(r, now) && best_feasible.map_or(true, |(_, b)| s >= b) {
                best_feasible = Some((r.id, s));
            }
        }
        best_feasible.or(best_any).map(|(id, _)| id)
    }

    fn on_push(&mut self, req: &SchedRequest, now: f64) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.max_arrival = self.max_arrival.max(req.arrival_ms);
        let (arrival_ms, deadline_ms) = (req.arrival_ms, req.deadline_ms);
        let (p50, p90) = (req.priors.p50, req.priors.p90);
        let cost = p50.max(1.0);
        let window = self.est_service_ms(p90).max(1.0);
        let wait_key = self.cfg.w_wait * (arrival_ms / cost);
        let urgency_key = self.cfg.w_urgency * (deadline_ms / (2.0 * window));
        let kappa = wait_key + urgency_key;
        // Quantized-mode keys, built against the *bin* bounds so the
        // per-group affine bound dominates the true score pointwise (see
        // `select_side_quant`). Non-negative by the `new()` asserts, so
        // plain IEEE bit order sorts them.
        let (kq_clamped, kq_ramp) = match self.cfg.quant_bits {
            None => (0.0, 0.0),
            Some(m) => {
                let (p50_lo, _) = Self::bin_bounds(p50, m);
                let (_, p90_hi) = Self::bin_bounds(p90, m);
                let win_hi = self.est_service_ms(p90_hi).max(1.0);
                let kc = self.cfg.w_wait * (arrival_ms / p50_lo.max(1.0))
                    + self.cfg.w_size * (p50 / self.cfg.ref_tokens);
                let kr = kc + self.cfg.w_urgency * (deadline_ms / (2.0 * win_hi));
                debug_assert!(kc >= 0.0 && kr >= 0.0, "quant keys must be bit-orderable");
                (kc, kr)
            }
        };
        let t_ramp = Self::first_instant(|t| self.urgency_at(p90, deadline_ms, t) > 0.0);
        let t_sat = Self::first_instant(|t| self.urgency_at(p90, deadline_ms, t) >= 1.0);
        let t_star = self.first_infeasible_ms(deadline_ms, p90);
        let phase = if now < t_ramp {
            0
        } else if now < t_sat {
            1
        } else {
            2
        };
        let feasible = now < t_star;
        let entry = Entry {
            seq,
            arrival_ms,
            deadline_ms,
            p50,
            p90,
            kappa,
            kq_clamped,
            kq_ramp,
            phase,
            feasible,
            t_ramp_bits: t_ramp.to_bits(),
            t_sat_bits: t_sat.to_bits(),
            expire_bits: t_star.to_bits(),
        };
        if phase == 0 {
            self.ramp_due.insert((entry.t_ramp_bits, req.id));
        }
        if phase <= 1 {
            self.sat_due.insert((entry.t_sat_bits, req.id));
        }
        if feasible {
            self.expiries.insert((entry.expire_bits, req.id));
        }
        let prev = self.entries.insert(req.id, entry);
        debug_assert!(prev.is_none(), "request {} indexed twice (double on_push?)", req.id);
        self.list_insert(req.id);
    }

    fn on_remove(&mut self, req: &SchedRequest) {
        self.list_remove(req.id);
        let e = self
            .entries
            .remove(&req.id)
            .unwrap_or_else(|| panic!("on_remove for unindexed request {}", req.id));
        if e.phase == 0 {
            self.ramp_due.remove(&(e.t_ramp_bits, req.id));
        }
        if e.phase <= 1 {
            self.sat_due.remove(&(e.t_sat_bits, req.id));
        }
        if e.feasible {
            self.expiries.remove(&(e.expire_bits, req.id));
        }
    }

    fn name(&self) -> &'static str {
        "feasible_set"
    }

    fn feasibility_violations(&self) -> u64 {
        self.violations
    }

    fn select_work(&self) -> u64 {
        self.work
    }

    fn group_count(&self) -> u64 {
        self.peak_groups
    }

    fn scan_fallbacks(&self) -> u64 {
        self.scan_fallbacks
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_util::{queues_into, sreq, HEAVY};
    use super::*;

    fn fs() -> FeasibleSet {
        FeasibleSet::new(OrderingCfg::default())
    }

    fn fsq() -> FeasibleSet {
        FeasibleSet::new(OrderingCfg::quantized())
    }

    /// 400 entries with *continuous* priors (every p50 distinct): the
    /// regime where exact-bit grouping degenerates to one group per entry.
    fn continuous_reqs() -> Vec<SchedRequest> {
        (0..400).map(|i| sreq(i, i as f64, 700.0 + (i as f64) * 0.01, 1e9)).collect()
    }

    #[test]
    fn favors_older_jobs() {
        let mut f = fs();
        // Same size/deadline-slack; the older one (id 2) wins.
        let q = queues_into(vec![sreq(1, 1000.0, 500.0, 1e6), sreq(2, 0.0, 500.0, 1e6)], &mut f);
        assert_eq!(f.select(q.view(HEAVY), 2000.0), Some(2));
    }

    #[test]
    fn favors_smaller_jobs() {
        let mut f = fs();
        let q = queues_into(vec![sreq(1, 0.0, 3000.0, 1e6), sreq(2, 0.0, 300.0, 1e6)], &mut f);
        assert_eq!(f.select(q.view(HEAVY), 100.0), Some(2));
    }

    #[test]
    fn urgency_overrides_size() {
        let f = fs();
        // Large job right at its deadline window vs small job with huge slack.
        let big_deadline = 100.0 + (170.0 + 0.9 * 3000.0 * 1.5) * 1.4; // inside 2×window
        let big = sreq(1, 0.0, 2000.0, big_deadline);
        let small = sreq(2, 0.0, 400.0, 1e7);
        let s_big = f.score(&big, 100.0);
        let s_small = f.score(&small, 100.0);
        assert!(s_big > s_small - 2.0, "urgency should lift the big job: {s_big} vs {s_small}");
    }

    #[test]
    fn infeasible_candidates_excluded() {
        let mut f = fs();
        // Request 1's deadline already passed; request 2 comfortably feasible.
        let q = queues_into(vec![sreq(1, 0.0, 100.0, 50.0), sreq(2, 0.0, 4000.0, 1e7)], &mut f);
        assert_eq!(
            f.select(q.view(HEAVY), 100.0),
            Some(2),
            "feasible big beats infeasible small"
        );
        assert_eq!(f.violations(), 0);
    }

    #[test]
    fn all_infeasible_falls_back_and_counts() {
        let mut f = fs();
        let q = queues_into(vec![sreq(1, 0.0, 100.0, 10.0), sreq(2, 0.0, 200.0, 20.0)], &mut f);
        let sel = f.select(q.view(HEAVY), 100.0);
        assert!(sel.is_some());
        assert_eq!(f.violations(), 1);
    }

    #[test]
    fn feasibility_expiry_migrates_entries() {
        let mut f = fs();
        // Feasible at push (deadline far beyond the service estimate), but
        // the window closes long before the second select.
        let q = queues_into(vec![sreq(1, 0.0, 100.0, 2_000.0), sreq(2, 0.0, 100.0, 1e7)], &mut f);
        assert!(f.select(q.view(HEAVY), 0.0).is_some());
        assert_eq!(f.violations(), 0);
        // At now = 1e6 request 1 is far past its deadline: only request 2
        // remains feasible and must win regardless of score details.
        assert_eq!(f.select(q.view(HEAVY), 1e6), Some(2));
        assert_eq!(f.violations(), 0);
    }

    #[test]
    fn empty_queue() {
        let mut f = fs();
        let q = queues_into(vec![], &mut f);
        assert_eq!(f.select(q.view(HEAVY), 0.0), None);
        assert_eq!(f.violations(), 0);
    }

    #[test]
    fn score_monotone_in_wait() {
        let f = fs();
        let r = sreq(1, 0.0, 500.0, 1e6);
        assert!(f.score(&r, 5000.0) > f.score(&r, 1000.0));
    }

    #[test]
    fn first_infeasible_is_the_exact_predicate_boundary() {
        let f = fs();
        for (deadline, p90) in [(2_000.0, 150.0), (50.0, 150.0), (1e6, 4000.0), (427.5, 150.0)] {
            let t = f.first_infeasible_ms(deadline, p90);
            assert!(!f.feasible_at(deadline, p90, t), "t* itself must be infeasible");
            if t > 0.0 {
                let below = f64::from_bits(t.to_bits() - 1);
                assert!(f.feasible_at(deadline, p90, below), "one ulp below t* is feasible");
            }
        }
    }

    #[test]
    fn phase_boundaries_bracket_the_urgency_ramp() {
        let f = fs();
        let (deadline, p90) = (20_000.0, 1_000.0);
        let t_ramp = FeasibleSet::first_instant(|t| f.urgency_at(p90, deadline, t) > 0.0);
        let t_sat = FeasibleSet::first_instant(|t| f.urgency_at(p90, deadline, t) >= 1.0);
        assert!(t_ramp < t_sat, "ramp opens before it saturates");
        assert_eq!(f.urgency_at(p90, deadline, f64::from_bits(t_ramp.to_bits() - 1)), 0.0);
        assert!(f.urgency_at(p90, deadline, t_ramp) > 0.0);
        assert!(f.urgency_at(p90, deadline, f64::from_bits(t_sat.to_bits() - 1)) < 1.0);
        assert_eq!(f.urgency_at(p90, deadline, t_sat), 1.0);
    }

    #[test]
    fn future_arrival_ramp_entries_disable_kappa_pruning() {
        // The hook API allows pushing entries whose arrival lies after the
        // current `now` (test harnesses do; the DES scheduler never does).
        // A clamped-wait entry's score is not `Φ − κ`, so κ-pruning must
        // stay off until `now` passes every pushed arrival: here both
        // entries share one (p50, p90) group and sit in the urgency ramp,
        // and the future-arrival entry 2 (κ larger by ≫ ε) is the true
        // winner on urgency alone.
        let mut f = fs();
        let q = queues_into(
            vec![sreq(1, 0.0, 1000.0, 4000.0), sreq(2, 1000.0, 1000.0, 2400.0)],
            &mut f,
        );
        assert_eq!(f.select(q.view(HEAVY), 100.0), Some(2), "clamped-wait urgent entry wins");
    }

    #[test]
    fn select_work_stays_sublinear_on_shared_priors() {
        // 400 entries with identical priors and distinct arrivals collapse
        // into one group ordered statically by age: a release must examine
        // a handful of entries, not the whole queue.
        let mut f = fs();
        let reqs: Vec<_> = (0..400).map(|i| sreq(i, i as f64, 700.0, 1e9)).collect();
        let q = queues_into(reqs, &mut f);
        let before = f.select_work();
        assert_eq!(f.select(q.view(HEAVY), 500.0), Some(0), "oldest wins pre-urgency");
        let examined = f.select_work() - before;
        assert!(examined <= 10, "deep shared-prior queue examined {examined} entries");
    }

    #[test]
    fn exact_grouping_scans_continuous_priors_and_counts_it() {
        let mut f = fs();
        let q = queues_into(continuous_reqs(), &mut f);
        let before = f.select_work();
        assert_eq!(f.select(q.view(HEAVY), 500.0), Some(0), "oldest wins pre-urgency");
        let examined = f.select_work() - before;
        assert!(examined >= 400, "exact-bit groups must degenerate to a scan: {examined}");
        assert_eq!(f.scan_fallbacks(), 1, "the scan regime must be observable");
        assert_eq!(f.group_count(), 400, "one group per distinct prior");
    }

    #[test]
    fn quantized_grouping_restores_sublinear_selection() {
        let mut f = fsq();
        let q = queues_into(continuous_reqs(), &mut f);
        let before = f.select_work();
        assert_eq!(f.select(q.view(HEAVY), 500.0), Some(0), "same winner as the exact scan");
        let examined = f.select_work() - before;
        assert!(examined <= 40, "quantized bins examined {examined} of 400 entries");
        assert_eq!(f.scan_fallbacks(), 0);
        assert!(f.group_count() <= 4, "continuous priors collapse into bins: {}", f.group_count());
    }

    #[test]
    fn quantized_matches_reference_on_random_continuous_cases() {
        use crate::testing::prop;
        prop::forall(300, |g| {
            let mut f = fsq();
            let n = g.usize_in(1, 30);
            let reqs: Vec<_> = (0..n)
                .map(|i| {
                    sreq(
                        i,
                        g.f64_in(0.0, 2000.0),
                        g.f64_in(10.0, 4000.0),
                        g.f64_in(0.0, 60_000.0),
                    )
                })
                .collect();
            let q = queues_into(reqs, &mut f);
            // Spans both sides of max_arrival, so the pruned and unpruned
            // walks are both exercised (select's debug_assert compares
            // against the reference on every call).
            let now = g.f64_in(0.0, 10_000.0);
            let sel = f.select(q.view(HEAVY), now);
            assert_eq!(sel, f.reference_select(q.view(HEAVY), now));
        });
    }

    #[test]
    fn quantized_expiry_and_phase_migrations_keep_equivalence() {
        // Same shape as feasibility_expiry_migrates_entries, quantized:
        // migrations re-key entries into κ lists and must stay exact.
        let mut f = fsq();
        let q = queues_into(vec![sreq(1, 0.0, 100.0, 2_000.0), sreq(2, 0.0, 100.0, 1e7)], &mut f);
        assert!(f.select(q.view(HEAVY), 0.0).is_some());
        assert_eq!(f.select(q.view(HEAVY), 1e6), Some(2));
        assert_eq!(f.violations(), 0);
    }

    #[test]
    fn bin_bounds_bracket_the_value() {
        for m in [1u32, 7, 12, 52] {
            for v in [1.0f64, 1.5, 180.0, 700.37, 4096.0, 6553.6] {
                let (lo, hi) = FeasibleSet::bin_bounds(v, m);
                assert!(lo <= v && v < hi, "m={m} v={v} lo={lo} hi={hi}");
                // At 52 kept bits the bin is a single ulp: lo == v.
                if m == 52 {
                    assert_eq!(lo, v);
                }
            }
        }
    }

    #[test]
    fn prop_select_returns_a_queued_id() {
        use crate::testing::prop;
        prop::forall(100, |g| {
            let mut f = fs();
            let n = g.usize_in(1, 30);
            let reqs: Vec<_> = (0..n)
                .map(|i| {
                    sreq(
                        i,
                        g.f64_in(0.0, 1000.0),
                        g.f64_in(10.0, 4000.0),
                        g.f64_in(0.0, 200_000.0),
                    )
                })
                .collect();
            let q = queues_into(reqs, &mut f);
            let now = g.f64_in(0.0, 5000.0);
            let sel = f.select(q.view(HEAVY), now).unwrap();
            assert!(sel < n, "selected id {sel} not in 0..{n}");
            assert!(q.get(sel).is_some(), "selected id must still be queued");
        });
    }

    #[test]
    fn single_pass_matches_two_phase_reference() {
        use crate::testing::prop;
        // The indexed selection must agree with the spec's two-phase rule:
        // argmax score over the feasible set, else argmax over everything.
        prop::forall(100, |g| {
            let mut f = fs();
            let n = g.usize_in(1, 25);
            let reqs: Vec<_> = (0..n)
                .map(|i| {
                    sreq(
                        i,
                        g.f64_in(0.0, 2000.0),
                        g.f64_in(10.0, 4000.0),
                        g.f64_in(0.0, 60_000.0),
                    )
                })
                .collect();
            let now = g.f64_in(0.0, 10_000.0);
            let reference = {
                let r = fs();
                let feasible: Vec<&SchedRequest> = reqs
                    .iter()
                    .filter(|x| now + r.est_service_ms(x.priors.p90) <= x.deadline_ms)
                    .collect();
                let pool: Vec<&SchedRequest> =
                    if feasible.is_empty() { reqs.iter().collect() } else { feasible };
                pool.into_iter()
                    .map(|x| (x.id, r.score(x, now)))
                    .max_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
                    .map(|(id, _)| id)
            };
            let q = queues_into(reqs, &mut f);
            assert_eq!(f.select(q.view(HEAVY), now), reference);
        });
    }
}
