//! Slowdown-aware feasible-set ordering (paper §3.1 layer 2).
//!
//! Among requests eligible under fairness constraints, score candidates:
//!
//!   score = w_wait · (wait / cost) − w_size · (size / ref) + w_urg · urgency
//!
//! favoring older and smaller jobs while respecting deadline urgency. The
//! *feasible set* restricts candidates to those whose estimated completion
//! (client-side service estimate on the p90 prior) still meets the deadline;
//! if no candidate is feasible the rule falls back to the full set and
//! counts a feasibility violation (the paper reports zero across all runs —
//! our integration tests assert the counter stays 0 in the main benchmark).

use super::Ordering;
use crate::scheduler::queues::SchedRequest;

#[derive(Debug, Clone)]
pub struct OrderingCfg {
    pub w_wait: f64,
    pub w_size: f64,
    pub w_urgency: f64,
    /// Normalizing token reference for the size term.
    pub ref_tokens: f64,
    /// Client-side belief of the provider's linear service model (for the
    /// feasibility estimate; learned constants would also work — kept
    /// explicit so the feasibility rule is auditable).
    pub est_base_ms: f64,
    pub est_per_token_ms: f64,
    /// Safety multiplier on the estimate (provider congestion headroom).
    pub est_slack_factor: f64,
}

impl Default for OrderingCfg {
    fn default() -> Self {
        OrderingCfg {
            w_wait: 1.0,
            w_size: 0.6,
            w_urgency: 0.8,
            ref_tokens: 512.0,
            est_base_ms: 150.0,
            est_per_token_ms: 0.9,
            est_slack_factor: 1.5,
        }
    }
}

pub struct FeasibleSet {
    cfg: OrderingCfg,
    violations: u64,
}

impl FeasibleSet {
    pub fn new(cfg: OrderingCfg) -> Self {
        FeasibleSet { cfg, violations: 0 }
    }

    /// Times the full set had no feasible candidate (fallback taken).
    pub fn violations(&self) -> u64 {
        self.violations
    }

    /// Estimated service time for a prior (p90, conservative).
    fn est_service_ms(&self, p90_tokens: f64) -> f64 {
        (self.cfg.est_base_ms + self.cfg.est_per_token_ms * p90_tokens) * self.cfg.est_slack_factor
    }

    fn feasible(&self, r: &SchedRequest, now: f64) -> bool {
        now + self.est_service_ms(r.priors.p90) <= r.deadline_ms
    }

    /// The paper's score; higher = release sooner.
    pub fn score(&self, r: &SchedRequest, now: f64) -> f64 {
        let c = &self.cfg;
        let wait_s = r.wait_ms(now) / 1000.0;
        let cost = r.priors.p50.max(1.0);
        // wait/cost in seconds-per-kilotoken so magnitudes are O(1).
        let wait_term = wait_s / (cost / 1000.0);
        let size_term = r.priors.p50 / c.ref_tokens;
        // Urgency ramps 0→1 as slack shrinks below the urgency window
        // (one estimated service time).
        let window = self.est_service_ms(r.priors.p90).max(1.0);
        let slack = r.deadline_ms - now;
        let urgency = (1.0 - slack / (2.0 * window)).clamp(0.0, 1.0);
        c.w_wait * wait_term - c.w_size * size_term + c.w_urgency * urgency
    }
}

trait WaitExt {
    fn wait_ms(&self, now: f64) -> f64;
}

impl WaitExt for SchedRequest {
    fn wait_ms(&self, now: f64) -> f64 {
        (now - self.arrival_ms).max(0.0)
    }
}

impl Ordering for FeasibleSet {
    fn select(&mut self, queue: &[SchedRequest], now: f64) -> Option<usize> {
        if queue.is_empty() {
            return None;
        }
        let feasible: Vec<usize> =
            (0..queue.len()).filter(|i| self.feasible(&queue[*i], now)).collect();
        let candidates: Vec<usize> = if feasible.is_empty() {
            self.violations += 1;
            (0..queue.len()).collect()
        } else {
            feasible
        };
        candidates
            .into_iter()
            .map(|i| (i, self.score(&queue[i], now)))
            .max_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
            .map(|(i, _)| i)
    }

    fn name(&self) -> &'static str {
        "feasible_set"
    }

    fn feasibility_violations(&self) -> u64 {
        self.violations
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_util::sreq;
    use super::*;

    fn fs() -> FeasibleSet {
        FeasibleSet::new(OrderingCfg::default())
    }

    #[test]
    fn favors_older_jobs() {
        let mut f = fs();
        // Same size/deadline-slack; the older one wins.
        let q = vec![sreq(1, 1000.0, 500.0, 1e6), sreq(2, 0.0, 500.0, 1e6)];
        assert_eq!(f.select(&q, 2000.0), Some(1));
    }

    #[test]
    fn favors_smaller_jobs() {
        let mut f = fs();
        let q = vec![sreq(1, 0.0, 3000.0, 1e6), sreq(2, 0.0, 300.0, 1e6)];
        assert_eq!(f.select(&q, 100.0), Some(1));
    }

    #[test]
    fn urgency_overrides_size() {
        let mut f = fs();
        // Large job right at its deadline window vs small job with huge slack.
        let big_deadline = 100.0 + (170.0 + 0.9 * 3000.0 * 1.5) * 1.4; // inside 2×window
        let q = vec![sreq(1, 0.0, 2000.0, big_deadline), sreq(2, 0.0, 400.0, 1e7)];
        let s_big = f.score(&q[0], 100.0);
        let s_small = f.score(&q[1], 100.0);
        assert!(s_big > s_small - 2.0, "urgency should lift the big job: {s_big} vs {s_small}");
    }

    #[test]
    fn infeasible_candidates_excluded() {
        let mut f = fs();
        // Request 1's deadline already passed; request 2 comfortably feasible.
        let q = vec![sreq(1, 0.0, 100.0, 50.0), sreq(2, 0.0, 4000.0, 1e7)];
        assert_eq!(f.select(&q, 100.0), Some(1), "feasible big beats infeasible small");
        assert_eq!(f.violations(), 0);
    }

    #[test]
    fn all_infeasible_falls_back_and_counts() {
        let mut f = fs();
        let q = vec![sreq(1, 0.0, 100.0, 10.0), sreq(2, 0.0, 200.0, 20.0)];
        let sel = f.select(&q, 100.0);
        assert!(sel.is_some());
        assert_eq!(f.violations(), 1);
    }

    #[test]
    fn empty_queue() {
        let mut f = fs();
        assert_eq!(f.select(&[], 0.0), None);
        assert_eq!(f.violations(), 0);
    }

    #[test]
    fn score_monotone_in_wait() {
        let f = fs();
        let r = sreq(1, 0.0, 500.0, 1e6);
        assert!(f.score(&r, 5000.0) > f.score(&r, 1000.0));
    }

    #[test]
    fn prop_select_in_bounds() {
        use crate::testing::prop;
        prop::forall(100, |g| {
            let mut f = fs();
            let n = g.usize_in(1, 30);
            let q: Vec<_> = (0..n)
                .map(|i| {
                    sreq(
                        i,
                        g.f64_in(0.0, 1000.0),
                        g.f64_in(10.0, 4000.0),
                        g.f64_in(0.0, 200_000.0),
                    )
                })
                .collect();
            let now = g.f64_in(0.0, 5000.0);
            let sel = f.select(&q, now).unwrap();
            assert!(sel < q.len());
        });
    }
}
