//! API-visible state: everything the client can legitimately observe at the
//! black-box boundary (paper §2: "feedback is delayed and aggregate").
//!
//! The scheduler never sees provider internals — only its own submissions,
//! completions with client-measured latency, and quantities derived from
//! them. This module is that boundary, as a type.

use crate::core::{Class, ReqId};
use crate::util::stats::{Ewma, RecentWindow};
use std::collections::HashMap;

/// Censored tail sample recorded when the client abandons an in-flight
/// request (hard timeout): the request consumed its entire timeout window,
/// well past its deadline, so the true latency/deadline ratio is > 1 but
/// unobserved. 2.0 sits above the overload controller's default
/// `tail_ratio_cap` (1.5), so a timeout saturates the tail term — an
/// endpoint must not look *calmer* because it times requests out instead of
/// completing them. Shared by the global signal here and the per-shard
/// signal in [`crate::scheduler::shard::ShardSelector`].
pub const ABANDON_TAIL_RATIO: f64 = 2.0;

/// Observable client-side state.
pub struct ApiState {
    /// Requests submitted and not yet completed/abandoned.
    inflight: HashMap<ReqId, InflightEntry>,
    inflight_by_class: [usize; 2],
    /// In-flight estimated token work (p50 sums), for load signals.
    inflight_tokens: f64,
    /// Recent completion latencies (ms), windowed.
    pub recent_latency: RecentWindow,
    /// EWMA of latency / deadline-budget ratio among completions — the
    /// tail_latency_ratio input to overload severity.
    pub tail_ratio: Ewma,
    completions: u64,
}

#[derive(Debug, Clone, Copy)]
struct InflightEntry {
    class: Class,
    est_tokens: f64,
    sent_ms: f64,
}

impl ApiState {
    /// Fresh state: nothing in flight, no latency evidence yet.
    pub fn new() -> Self {
        ApiState {
            inflight: HashMap::new(),
            inflight_by_class: [0, 0],
            inflight_tokens: 0.0,
            recent_latency: RecentWindow::new(64),
            tail_ratio: Ewma::new(0.15),
            completions: 0,
        }
    }

    /// Record a submission: `id` enters the in-flight set with its class
    /// and estimated token cost.
    pub fn on_send(&mut self, id: ReqId, class: Class, est_tokens: f64, now: f64) {
        let prev = self
            .inflight
            .insert(id, InflightEntry { class, est_tokens, sent_ms: now });
        debug_assert!(prev.is_none(), "double send for {id}");
        self.inflight_by_class[class.index()] += 1;
        self.inflight_tokens += est_tokens;
    }

    /// Completion observed; returns the class it freed (None if unknown —
    /// e.g. completion after abandon).
    pub fn on_completion(&mut self, id: ReqId, latency_ms: f64, deadline_budget_ms: f64) -> Option<Class> {
        let entry = self.inflight.remove(&id)?;
        self.inflight_by_class[entry.class.index()] -= 1;
        self.inflight_tokens -= entry.est_tokens;
        self.recent_latency.push(latency_ms);
        if deadline_budget_ms > 0.0 {
            self.tail_ratio.push(latency_ms / deadline_budget_ms);
        }
        self.completions += 1;
        Some(entry.class)
    }

    /// Client gave up on an in-flight request (timeout): frees the client's
    /// slot. No latency sample exists (the completion was never observed),
    /// but the abandonment itself is tail *evidence* — the censored
    /// pessimistic sample [`ABANDON_TAIL_RATIO`] feeds the global tail
    /// EWMA, exactly as [`crate::scheduler::shard::ShardSelector::on_abandon`]
    /// feeds the per-shard one. Without it a dead endpoint kept global
    /// severity calm while timing everything out (ROADMAP "censored global
    /// tail" item; regenerates every table with in-flight timeouts).
    pub fn on_abandon(&mut self, id: ReqId) -> Option<Class> {
        let entry = self.inflight.remove(&id)?;
        self.inflight_by_class[entry.class.index()] -= 1;
        self.inflight_tokens -= entry.est_tokens;
        self.tail_ratio.push(ABANDON_TAIL_RATIO);
        Some(entry.class)
    }

    /// Requests currently in flight.
    pub fn inflight(&self) -> usize {
        self.inflight.len()
    }

    /// Requests of `class` currently in flight.
    pub fn inflight_class(&self, class: Class) -> usize {
        self.inflight_by_class[class.index()]
    }

    /// Sum of p50 token estimates currently in flight (load signal).
    pub fn inflight_tokens(&self) -> f64 {
        self.inflight_tokens
    }

    /// Whether `id` is currently in flight.
    pub fn is_inflight(&self, id: ReqId) -> bool {
        self.inflight.contains_key(&id)
    }

    /// Submission time of an in-flight request.
    pub fn sent_ms(&self, id: ReqId) -> Option<f64> {
        self.inflight.get(&id).map(|e| e.sent_ms)
    }

    /// Completions observed so far.
    pub fn completions(&self) -> u64 {
        self.completions
    }
}

impl Default for ApiState {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn send_complete_cycle() {
        let mut s = ApiState::new();
        s.on_send(1, Class::Interactive, 50.0, 10.0);
        s.on_send(2, Class::Heavy, 800.0, 11.0);
        assert_eq!(s.inflight(), 2);
        assert_eq!(s.inflight_class(Class::Heavy), 1);
        assert_eq!(s.inflight_tokens(), 850.0);
        assert_eq!(s.sent_ms(1), Some(10.0));

        let freed = s.on_completion(1, 300.0, 2500.0);
        assert_eq!(freed, Some(Class::Interactive));
        assert_eq!(s.inflight(), 1);
        assert_eq!(s.inflight_tokens(), 800.0);
        assert_eq!(s.completions(), 1);
        assert_eq!(s.recent_latency.len(), 1);
        assert!((s.tail_ratio.get().unwrap() - 0.12).abs() < 1e-9);
    }

    #[test]
    fn abandon_frees_without_latency_sample() {
        let mut s = ApiState::new();
        s.on_send(1, Class::Heavy, 2000.0, 0.0);
        assert_eq!(s.on_abandon(1), Some(Class::Heavy));
        assert_eq!(s.inflight(), 0);
        assert_eq!(s.recent_latency.len(), 0);
        assert_eq!(s.on_abandon(1), None, "idempotent");
        assert_eq!(s.on_completion(1, 10.0, 10.0), None, "late completion ignored");
    }

    #[test]
    fn abandon_records_censored_tail_evidence() {
        // A dead provider (no completions, all timeouts) must escalate the
        // global tail signal instead of reading calm.
        let mut s = ApiState::new();
        s.on_send(1, Class::Heavy, 2000.0, 0.0);
        assert_eq!(s.tail_ratio.get(), None, "no evidence before the abandon");
        s.on_abandon(1);
        assert_eq!(s.tail_ratio.get(), Some(ABANDON_TAIL_RATIO), "first sample is the ratio");
        // Unknown ids stay inert — only real in-flight abandons are evidence.
        s.on_abandon(42);
        assert_eq!(s.tail_ratio.get(), Some(ABANDON_TAIL_RATIO));
    }

    #[test]
    fn tail_ratio_tracks_pressure() {
        let mut s = ApiState::new();
        for i in 0..20 {
            s.on_send(i, Class::Interactive, 10.0, 0.0);
            s.on_completion(i, 5000.0, 2500.0); // 2× over budget
        }
        assert!(s.tail_ratio.get().unwrap() > 1.5);
    }
}
