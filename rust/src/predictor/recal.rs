//! Online interval recalibration from observed completions — the feedback
//! loop "Queueing, Predictions, and LLMs" poses as open.
//!
//! Prior *sources* are pure functions of the request (the driver samples
//! them once, in arrival order, before the event loop starts), so the
//! feedback loop cannot live inside the source chain. Instead each client
//! scheduler owns a [`Recalibrator`]: at arrival it rescales the source's
//! claimed interval width by a per-route multiplier; at every *real*
//! completion it updates that multiplier from the realized error. Abandoned
//! and timed-out requests never reach the update path — their realized
//! length is censored (the client never saw the response), and learning
//! from them would bias the intervals toward whatever the overload policy
//! happened to shed.
//!
//! The update is an EWMA of the normalized error `|observed − p50| / width`
//! per route lane. A source whose claimed widths consistently overcover
//! (ratio < 1) sees its multiplier decay toward the observed ratio —
//! intervals shrink monotonically; a source that undercovers is widened the
//! same way. Multipliers start at exactly `1.0` and widths scale by
//! multiplication, so a recalibrator that never observes anything — or one
//! that is disabled — is bit-for-bit equivalent to the static source.

use crate::core::Priors;
use crate::predictor::Route;

/// EWMA step per observation. Small enough that one outlier cannot whip
/// the interval, large enough to converge within a few hundred completions.
pub const RECAL_ALPHA: f64 = 0.05;

/// Multiplier clamp: intervals never shrink below ×0.25 or grow past ×4 of
/// the source's claim — the source stays the anchor, recalibration trims.
pub const RECAL_MIN_MULT: f64 = 0.25;
/// See [`RECAL_MIN_MULT`].
pub const RECAL_MAX_MULT: f64 = 4.0;

/// Number of route lanes tracked (no-belief + four buckets); see
/// [`Route::lane`].
const LANES: usize = 5;

/// Per-route online interval recalibrator (one per client scheduler).
#[derive(Debug, Clone)]
pub struct Recalibrator {
    enabled: bool,
    /// Per-lane width multiplier, applied at arrival.
    mult: [f64; LANES],
    /// Per-lane completion observations consumed.
    observed: [u64; LANES],
}

impl Recalibrator {
    /// A recalibrator that applies and learns; multipliers start at 1.0.
    pub fn enabled() -> Recalibrator {
        Recalibrator { enabled: true, mult: [1.0; LANES], observed: [0; LANES] }
    }

    /// A recalibrator that is a guaranteed bit-exact no-op.
    pub fn disabled() -> Recalibrator {
        Recalibrator { enabled: false, mult: [1.0; LANES], observed: [0; LANES] }
    }

    /// Whether this instance learns and applies.
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Current width multiplier for `route`'s lane.
    pub fn multiplier(&self, route: &Route) -> f64 {
        self.mult[route.lane()]
    }

    /// Completions consumed for `route`'s lane.
    pub fn observations(&self, route: &Route) -> u64 {
        self.observed[route.lane()]
    }

    /// Rescale a source-claimed interval by the lane's learned multiplier.
    /// Point priors (`width == 0`) and disabled recalibrators pass through
    /// untouched; an enabled-but-unobserved lane multiplies by exactly
    /// `1.0`, which is bit-identity for finite widths.
    pub fn apply(&self, priors: Priors, route: &Route) -> Priors {
        if !self.enabled || priors.width == 0.0 {
            return priors;
        }
        Priors::with_width(priors.p50, priors.p90, priors.width * self.mult[route.lane()])
    }

    /// Consume one *observed* completion: the request's policy-facing prior
    /// (as claimed by the source, pre-recalibration), its route, and the
    /// realized output length. Callers must NOT invoke this for abandoned,
    /// shed, or timed-out requests — those lengths are censored.
    pub fn observe(&mut self, claimed: Priors, route: &Route, observed_tokens: f64) {
        if !self.enabled || claimed.width <= 0.0 {
            // Point priors carry no interval to recalibrate.
            return;
        }
        let lane = route.lane();
        // Normalized error: how many claimed half-widths the truth landed
        // from the point estimate. Calibrated ⇒ ~1 on average.
        let ratio = (observed_tokens - claimed.p50).abs() / claimed.width;
        let target = ratio.clamp(RECAL_MIN_MULT, RECAL_MAX_MULT);
        let m = self.mult[lane] * (1.0 - RECAL_ALPHA) + target * RECAL_ALPHA;
        self.mult[lane] = m.clamp(RECAL_MIN_MULT, RECAL_MAX_MULT);
        self.observed[lane] += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::TokenBucket;

    fn route() -> Route {
        Route::from_bucket(TokenBucket::Long)
    }

    #[test]
    fn disabled_is_bit_exact_identity() {
        let mut r = Recalibrator::disabled();
        let p = Priors::with_width(100.0, 200.0, 40.0);
        // Even after (ignored) observations, apply is untouched.
        for _ in 0..100 {
            r.observe(p, &route(), 500.0);
        }
        let out = r.apply(p, &route());
        assert_eq!(out.width.to_bits(), p.width.to_bits());
        assert_eq!(r.observations(&route()), 0);
    }

    #[test]
    fn unobserved_enabled_lane_is_identity() {
        let r = Recalibrator::enabled();
        let p = Priors::with_width(123.456, 789.1, 55.5);
        let out = r.apply(p, &route());
        assert_eq!(out.width.to_bits(), p.width.to_bits());
        assert_eq!(out.p50.to_bits(), p.p50.to_bits());
    }

    #[test]
    fn consistent_overcoverage_shrinks_monotonically() {
        let mut r = Recalibrator::enabled();
        // Claimed half-width 100, realized error always 30 ⇒ ratio 0.3.
        let p = Priors::with_width(200.0, 400.0, 100.0);
        let mut last = r.multiplier(&route());
        for _ in 0..500 {
            r.observe(p, &route(), 230.0);
            let m = r.multiplier(&route());
            assert!(m <= last, "multiplier must shrink monotonically: {m} > {last}");
            last = m;
        }
        assert!((last - 0.3).abs() < 0.01, "converges to the observed ratio, got {last}");
        let out = r.apply(p, &route());
        assert!(out.width < p.width * 0.35);
    }

    #[test]
    fn consistent_undercoverage_widens() {
        let mut r = Recalibrator::enabled();
        // Claimed half-width 50, realized error 150 ⇒ ratio 3.
        let p = Priors::with_width(200.0, 400.0, 50.0);
        for _ in 0..500 {
            r.observe(p, &route(), 350.0);
        }
        let m = r.multiplier(&route());
        assert!((m - 3.0).abs() < 0.05, "got {m}");
    }

    #[test]
    fn multiplier_clamped() {
        let mut r = Recalibrator::enabled();
        let p = Priors::with_width(200.0, 400.0, 1.0);
        for _ in 0..2_000 {
            r.observe(p, &route(), 4_000.0); // ratio 3800 — absurd outlier
        }
        assert_eq!(r.multiplier(&route()), RECAL_MAX_MULT);
    }

    #[test]
    fn lanes_are_independent() {
        let mut r = Recalibrator::enabled();
        let p = Priors::with_width(200.0, 400.0, 100.0);
        for _ in 0..50 {
            r.observe(p, &Route::from_bucket(TokenBucket::Short), 210.0);
        }
        assert!(r.multiplier(&Route::from_bucket(TokenBucket::Short)) < 1.0);
        assert_eq!(r.multiplier(&Route::from_bucket(TokenBucket::XLong)), 1.0);
        assert_eq!(r.multiplier(&Route::neutral()), 1.0);
    }

    #[test]
    fn point_priors_never_update() {
        let mut r = Recalibrator::enabled();
        let p = Priors::new(200.0, 400.0); // width 0
        r.observe(p, &route(), 1_000.0);
        assert_eq!(r.observations(&route()), 0);
        assert_eq!(r.multiplier(&route()), 1.0);
    }
}
