//! Feature extraction for the neural predictor — the exact Rust twin of
//! `python/compile/datagen.py::features_from_raw` (layout asserted against
//! `predictor_meta.json` by `runtime::meta`).

use crate::core::Request;

/// Feature vector width (must equal the model's D_IN).
pub const D_IN: usize = 32;

/// Compute the client-observable feature vector for a request.
///
/// Layout (lanes 8.. are zero padding):
///   0: prompt_tokens / 2048
///   1: log1p(prompt_tokens) / 8
///   2–5: one-hot task type (chat, summarize, code, extract)
///   6: temperature
///   7: max_tokens / 4096
pub fn features(req: &Request) -> [f32; D_IN] {
    let mut f = [0.0f32; D_IN];
    let pt = req.prompt_tokens as f64;
    f[0] = (pt / 2048.0) as f32;
    f[1] = (pt.ln_1p() / 8.0) as f32;
    f[2 + req.task.index()] = 1.0;
    f[6] = req.temperature as f32;
    f[7] = (req.max_tokens as f64 / 4096.0) as f32;
    f
}

/// Flatten a batch of requests into a row-major feature matrix, zero-padded
/// to `batch` rows (the AOT artifacts have static batch shapes).
pub fn batch_features(reqs: &[&Request], batch: usize) -> Vec<f32> {
    assert!(reqs.len() <= batch, "batch overflow: {} > {batch}", reqs.len());
    let mut out = vec![0.0f32; batch * D_IN];
    for (i, r) in reqs.iter().enumerate() {
        out[i * D_IN..(i + 1) * D_IN].copy_from_slice(&features(r));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Task, TokenBucket};

    fn req(prompt: u32, task: Task, temp: f64, max_tok: u32) -> Request {
        Request {
            id: 0,
            arrival_ms: 0.0,
            prompt_tokens: prompt,
            task,
            temperature: temp,
            max_tokens: max_tok,
            deadline_ms: 1000.0,
            timeout_ms: 2000.0,
            true_output_tokens: 100,
            true_bucket: TokenBucket::Medium,
        }
    }

    #[test]
    fn layout_matches_python() {
        let r = req(100, Task::Code, 0.5, 1024);
        let f = features(&r);
        assert!((f[0] - 100.0 / 2048.0).abs() < 1e-7);
        assert!((f[1] - (101.0f64.ln() / 8.0) as f32).abs() < 1e-6);
        assert_eq!(f[2], 0.0); // chat
        assert_eq!(f[4], 1.0); // code
        assert_eq!(f[6], 0.5);
        assert!((f[7] - 0.25).abs() < 1e-7);
        assert!(f[8..].iter().all(|x| *x == 0.0));
    }

    #[test]
    fn batch_pads_with_zeros() {
        let r1 = req(10, Task::Chat, 0.0, 256);
        let r2 = req(20, Task::Extract, 1.0, 512);
        let m = batch_features(&[&r1, &r2], 4);
        assert_eq!(m.len(), 4 * D_IN);
        assert_ne!(m[0], 0.0);
        assert_eq!(m[2 * D_IN..], vec![0.0; 2 * D_IN][..]);
    }

    #[test]
    #[should_panic(expected = "batch overflow")]
    fn batch_overflow_panics() {
        let r = req(10, Task::Chat, 0.0, 256);
        batch_features(&[&r, &r], 1);
    }
}
