//! The four-level information ladder (paper §4.4): what the client may know
//! about each request, with the Final (OLC) stack held fixed.

use crate::core::{Priors, Request};
use crate::predictor::{PriorSource, Route};
use crate::util::rng::Rng;

/// Neutral p50/p90 used when per-request magnitude is unavailable —
/// "fixed neutral p50/p90 for budgeting and scoring" (§4.4). Chosen as the
/// balanced-mix geometric scale; the point is that it is *constant*, so
/// allocation/ordering/budgets cannot distinguish cheap from expensive work.
pub const NEUTRAL_P50: f64 = 180.0;
pub const NEUTRAL_P90: f64 = 900.0;

/// Ladder condition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InfoLevel {
    /// No per-request estimates and no size-derived routing: one neutral
    /// lane, neutral priors, uniform (cost-blind) admission severity.
    NoInfo,
    /// The generator's class label drives routing + tiered overload, but
    /// priors stay neutral: "which lane, not how large within the lane."
    ClassOnly,
    /// Default semi-clairvoyant setting: coarse per-request p50/p90,
    /// multiplicatively noisy around truth.
    Coarse,
    /// Exact output-token count before dispatch — information frontier,
    /// not a deployable predictor.
    Oracle,
}

impl InfoLevel {
    pub fn name(self) -> &'static str {
        match self {
            InfoLevel::NoInfo => "no_info",
            InfoLevel::ClassOnly => "class_only",
            InfoLevel::Coarse => "coarse",
            InfoLevel::Oracle => "oracle",
        }
    }

    pub fn parse(s: &str) -> Option<InfoLevel> {
        match s {
            "no_info" => Some(InfoLevel::NoInfo),
            "class_only" => Some(InfoLevel::ClassOnly),
            "coarse" => Some(InfoLevel::Coarse),
            "oracle" => Some(InfoLevel::Oracle),
            _ => None,
        }
    }

    pub const ALL: [InfoLevel; 4] =
        [InfoLevel::NoInfo, InfoLevel::ClassOnly, InfoLevel::Coarse, InfoLevel::Oracle];
}

/// Coarse-prior shape: log-normal multiplicative error on the true count
/// plus a fixed p90/p50 spread. σ=0.25 ≈ ±28% one-sigma relative error —
/// "coarse but correlated with actual cost" (§3.3).
pub const COARSE_SIGMA: f64 = 0.25;
pub const COARSE_SPREAD: f64 = 1.8;

/// Ladder-conditioned prior source.
pub struct LadderSource {
    level: InfoLevel,
    rng: Rng,
}

impl LadderSource {
    pub fn new(level: InfoLevel, rng: Rng) -> Self {
        LadderSource { level, rng }
    }

    pub fn level(&self) -> InfoLevel {
        self.level
    }
}

impl PriorSource for LadderSource {
    fn priors(&mut self, req: &Request) -> (Priors, Route) {
        match self.level {
            InfoLevel::NoInfo => {
                (Priors::new(NEUTRAL_P50, NEUTRAL_P90), Route::neutral())
            }
            InfoLevel::ClassOnly => (
                Priors::new(NEUTRAL_P50, NEUTRAL_P90),
                Route::from_bucket(req.true_bucket),
            ),
            InfoLevel::Coarse => {
                let factor = self.rng.lognormal(0.0, COARSE_SIGMA);
                let p50 = (req.true_output_tokens as f64 * factor).max(1.0);
                let priors = Priors::new(p50, p50 * COARSE_SPREAD);
                // Routing follows the *predicted* bucket — the client has no
                // generator label under semi-clairvoyance.
                (priors, Route::from_bucket(priors.bucket()))
            }
            InfoLevel::Oracle => {
                let t = req.true_output_tokens as f64;
                (Priors::new(t, t), Route::from_bucket(req.true_bucket))
            }
        }
    }

    fn name(&self) -> String {
        self.level.name().to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Class, SloPolicy, TokenBucket};
    use crate::workload::{Mix, SynthGen};

    fn requests(n: usize) -> Vec<Request> {
        let mut g = SynthGen::new(Mix::Balanced, Rng::new(3));
        let slo = SloPolicy::default();
        (0..n).map(|i| g.sample(i, 0.0, &slo)).collect()
    }

    #[test]
    fn no_info_is_constant_and_neutral() {
        let mut src = LadderSource::new(InfoLevel::NoInfo, Rng::new(1));
        for r in requests(50) {
            let (p, route) = src.priors(&r);
            assert_eq!(p.p50, NEUTRAL_P50);
            assert_eq!(p.p90, NEUTRAL_P90);
            assert_eq!(route, Route::neutral());
        }
    }

    #[test]
    fn class_only_routes_but_neutral_magnitude() {
        let mut src = LadderSource::new(InfoLevel::ClassOnly, Rng::new(1));
        for r in requests(50) {
            let (p, route) = src.priors(&r);
            assert_eq!(p.p50, NEUTRAL_P50, "magnitude must stay neutral");
            assert_eq!(route.bucket_belief, Some(r.true_bucket));
            assert_eq!(route.class, r.true_bucket.class());
        }
    }

    #[test]
    fn coarse_correlates_with_truth() {
        let mut src = LadderSource::new(InfoLevel::Coarse, Rng::new(7));
        let reqs = requests(500);
        let mut ratios = Vec::new();
        for r in &reqs {
            let (p, _) = src.priors(r);
            ratios.push(p.p50 / r.true_output_tokens as f64);
            assert!(p.p90 >= p.p50);
        }
        let (mean, std) = crate::util::stats::mean_std(&ratios);
        // log-normal(0, 0.25): mean ≈ e^{σ²/2} ≈ 1.032, sd ≈ 0.26.
        assert!((mean - 1.03).abs() < 0.08, "mean ratio {mean}");
        assert!(std > 0.1 && std < 0.5, "std {std}");
    }

    #[test]
    fn coarse_routing_can_mislabel() {
        // With noisy magnitude, bucket beliefs near boundaries can differ
        // from truth — that's the semi-clairvoyant realism.
        let mut src = LadderSource::new(InfoLevel::Coarse, Rng::new(11));
        let reqs = requests(2000);
        let mislabeled = reqs
            .iter()
            .filter(|r| {
                let (_, route) = src.priors(r);
                route.bucket_belief != Some(r.true_bucket)
            })
            .count();
        assert!(mislabeled > 0, "expected some routing mislabels");
        assert!((mislabeled as f64) < 0.5 * reqs.len() as f64, "but mostly right");
    }

    #[test]
    fn oracle_is_exact() {
        let mut src = LadderSource::new(InfoLevel::Oracle, Rng::new(1));
        for r in requests(50) {
            let (p, route) = src.priors(&r);
            assert_eq!(p.p50, r.true_output_tokens as f64);
            assert_eq!(p.p90, p.p50);
            assert_eq!(route.bucket_belief, Some(r.true_bucket));
        }
    }

    #[test]
    fn short_class_routing() {
        let mut src = LadderSource::new(InfoLevel::Oracle, Rng::new(1));
        for r in requests(200) {
            let (_, route) = src.priors(&r);
            match r.true_bucket {
                TokenBucket::Short => assert_eq!(route.class, Class::Interactive),
                _ => assert_eq!(route.class, Class::Heavy),
            }
        }
    }
}
