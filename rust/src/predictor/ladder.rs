//! The four-level information ladder (paper §4.4): what the client may know
//! about each request, with the Final (OLC) stack held fixed.

use crate::core::{Priors, Request};
use crate::predictor::{PriorSource, Route};
use crate::util::rng::Rng;

/// Neutral p50/p90 used when per-request magnitude is unavailable —
/// "fixed neutral p50/p90 for budgeting and scoring" (§4.4). Chosen as the
/// balanced-mix geometric scale; the point is that it is *constant*, so
/// allocation/ordering/budgets cannot distinguish cheap from expensive work.
pub const NEUTRAL_P50: f64 = 180.0;
/// The p90 companion to [`NEUTRAL_P50`] (same rationale; the 5× spread
/// mirrors the balanced mix's tail ratio).
pub const NEUTRAL_P90: f64 = 900.0;

/// Ladder condition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InfoLevel {
    /// No per-request estimates and no size-derived routing: one neutral
    /// lane, neutral priors, uniform (cost-blind) admission severity.
    NoInfo,
    /// The generator's class label drives routing + tiered overload, but
    /// priors stay neutral: "which lane, not how large within the lane."
    ClassOnly,
    /// Default semi-clairvoyant setting: coarse per-request p50/p90,
    /// multiplicatively noisy around truth.
    Coarse,
    /// Exact output-token count before dispatch — information frontier,
    /// not a deployable predictor.
    Oracle,
}

impl InfoLevel {
    /// CLI / CSV name.
    pub fn name(self) -> &'static str {
        match self {
            InfoLevel::NoInfo => "no_info",
            InfoLevel::ClassOnly => "class_only",
            InfoLevel::Coarse => "coarse",
            InfoLevel::Oracle => "oracle",
        }
    }

    /// Inverse of [`InfoLevel::name`].
    pub fn parse(s: &str) -> Option<InfoLevel> {
        match s {
            "no_info" => Some(InfoLevel::NoInfo),
            "class_only" => Some(InfoLevel::ClassOnly),
            "coarse" => Some(InfoLevel::Coarse),
            "oracle" => Some(InfoLevel::Oracle),
            _ => None,
        }
    }

    /// All four rungs, bottom to top.
    pub const ALL: [InfoLevel; 4] =
        [InfoLevel::NoInfo, InfoLevel::ClassOnly, InfoLevel::Coarse, InfoLevel::Oracle];
}

/// One-sigma interval half-width (tokens) when the client has *no* usable
/// label: half the full output-token span, `(4096 − 8) / 2`. The widest
/// calibrated interval the ladder can honestly claim.
pub const NO_INFO_WIDTH: f64 = 2_044.0;

/// Coarse-prior shape: log-normal multiplicative error on the true count
/// plus a fixed p90/p50 spread. σ=0.25 ≈ ±28% one-sigma relative error —
/// "coarse but correlated with actual cost" (§3.3).
pub const COARSE_SIGMA: f64 = 0.25;
/// Fixed p90/p50 spread the coarse rung claims (see [`COARSE_SIGMA`]).
pub const COARSE_SPREAD: f64 = 1.8;

/// Ladder-conditioned prior source. Every rung emits a *calibrated*
/// interval width alongside its point quantiles — derived from the rung's
/// known error model, never from extra RNG draws, so the numeric p50/p90
/// streams are bit-identical to the pre-interval ladder:
///
/// - `no_info`: [`NO_INFO_WIDTH`] (half the full token span — the source
///   knows nothing).
/// - `class_only`: half the believed bucket's token range (the label is
///   exact; magnitude within the bucket is not).
/// - `coarse`: `p50 · sinh(σ)` — the one-sigma half-width of the
///   log-normal multiplicative error, in tokens around the estimate.
/// - `oracle`: `0.0` (exact by construction).
pub struct LadderSource {
    level: InfoLevel,
    rng: Rng,
}

impl LadderSource {
    /// Build a source at `level`; `rng` must be the derived `"priors"`
    /// stream so draws are independent of every other stream.
    pub fn new(level: InfoLevel, rng: Rng) -> Self {
        LadderSource { level, rng }
    }

    /// The ladder rung this source was built at.
    pub fn level(&self) -> InfoLevel {
        self.level
    }
}

impl PriorSource for LadderSource {
    fn priors(&mut self, req: &Request) -> (Priors, Route) {
        match self.level {
            InfoLevel::NoInfo => (
                Priors::with_width(NEUTRAL_P50, NEUTRAL_P90, NO_INFO_WIDTH),
                Route::neutral(),
            ),
            InfoLevel::ClassOnly => {
                let (lo, hi) = req.true_bucket.bounds();
                let width = (hi - lo) as f64 * 0.5;
                (
                    Priors::with_width(NEUTRAL_P50, NEUTRAL_P90, width),
                    Route::from_bucket(req.true_bucket),
                )
            }
            InfoLevel::Coarse => {
                let factor = self.rng.lognormal(0.0, COARSE_SIGMA);
                let p50 = (req.true_output_tokens as f64 * factor).max(1.0);
                let priors = Priors::with_width(p50, p50 * COARSE_SPREAD, p50 * COARSE_SIGMA.sinh());
                // Routing follows the *predicted* bucket — the client has no
                // generator label under semi-clairvoyance.
                (priors, Route::from_bucket(priors.bucket()))
            }
            InfoLevel::Oracle => {
                let t = req.true_output_tokens as f64;
                (Priors::new(t, t), Route::from_bucket(req.true_bucket))
            }
        }
    }

    fn name(&self) -> String {
        self.level.name().to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Class, SloPolicy, TokenBucket};
    use crate::workload::{Mix, SynthGen};

    fn requests(n: usize) -> Vec<Request> {
        let mut g = SynthGen::new(Mix::Balanced, Rng::new(3));
        let slo = SloPolicy::default();
        (0..n).map(|i| g.sample(i, 0.0, &slo)).collect()
    }

    #[test]
    fn no_info_is_constant_and_neutral() {
        let mut src = LadderSource::new(InfoLevel::NoInfo, Rng::new(1));
        for r in requests(50) {
            let (p, route) = src.priors(&r);
            assert_eq!(p.p50, NEUTRAL_P50);
            assert_eq!(p.p90, NEUTRAL_P90);
            assert_eq!(route, Route::neutral());
        }
    }

    #[test]
    fn class_only_routes_but_neutral_magnitude() {
        let mut src = LadderSource::new(InfoLevel::ClassOnly, Rng::new(1));
        for r in requests(50) {
            let (p, route) = src.priors(&r);
            assert_eq!(p.p50, NEUTRAL_P50, "magnitude must stay neutral");
            assert_eq!(route.bucket_belief, Some(r.true_bucket));
            assert_eq!(route.class, r.true_bucket.class());
        }
    }

    #[test]
    fn coarse_correlates_with_truth() {
        let mut src = LadderSource::new(InfoLevel::Coarse, Rng::new(7));
        let reqs = requests(500);
        let mut ratios = Vec::new();
        for r in &reqs {
            let (p, _) = src.priors(r);
            ratios.push(p.p50 / r.true_output_tokens as f64);
            assert!(p.p90 >= p.p50);
        }
        let (mean, std) = crate::util::stats::mean_std(&ratios);
        // log-normal(0, 0.25): mean ≈ e^{σ²/2} ≈ 1.032, sd ≈ 0.26.
        assert!((mean - 1.03).abs() < 0.08, "mean ratio {mean}");
        assert!(std > 0.1 && std < 0.5, "std {std}");
    }

    #[test]
    fn coarse_routing_can_mislabel() {
        // With noisy magnitude, bucket beliefs near boundaries can differ
        // from truth — that's the semi-clairvoyant realism.
        let mut src = LadderSource::new(InfoLevel::Coarse, Rng::new(11));
        let reqs = requests(2000);
        let mislabeled = reqs
            .iter()
            .filter(|r| {
                let (_, route) = src.priors(r);
                route.bucket_belief != Some(r.true_bucket)
            })
            .count();
        assert!(mislabeled > 0, "expected some routing mislabels");
        assert!((mislabeled as f64) < 0.5 * reqs.len() as f64, "but mostly right");
    }

    #[test]
    fn widths_are_calibrated_per_rung() {
        let reqs = requests(100);
        let mut no_info = LadderSource::new(InfoLevel::NoInfo, Rng::new(1));
        let mut class_only = LadderSource::new(InfoLevel::ClassOnly, Rng::new(1));
        let mut coarse = LadderSource::new(InfoLevel::Coarse, Rng::new(1));
        let mut oracle = LadderSource::new(InfoLevel::Oracle, Rng::new(1));
        for r in &reqs {
            assert_eq!(no_info.priors(r).0.width, NO_INFO_WIDTH);
            let (lo, hi) = r.true_bucket.bounds();
            assert_eq!(class_only.priors(r).0.width, (hi - lo) as f64 * 0.5);
            let (p, _) = coarse.priors(r);
            assert_eq!(p.width, p.p50 * COARSE_SIGMA.sinh());
            assert_eq!(oracle.priors(r).0.width, 0.0);
        }
        // Widths narrow as information improves (for any concrete request).
        let r = &reqs[0];
        let w_no = LadderSource::new(InfoLevel::NoInfo, Rng::new(2)).priors(r).0.width;
        let w_cls = LadderSource::new(InfoLevel::ClassOnly, Rng::new(2)).priors(r).0.width;
        assert!(w_no > w_cls && w_cls > 0.0);
    }

    #[test]
    fn width_does_not_disturb_point_stream() {
        // The interval extension must not change the numeric p50/p90
        // sequence: same seed, draw-for-draw identical quantiles.
        let reqs = requests(200);
        let mut src = LadderSource::new(InfoLevel::Coarse, Rng::new(7));
        let mut rng = Rng::new(7);
        for r in &reqs {
            let (p, _) = src.priors(r);
            let factor = rng.lognormal(0.0, COARSE_SIGMA);
            let expect = (r.true_output_tokens as f64 * factor).max(1.0);
            assert_eq!(p.p50.to_bits(), expect.to_bits());
            assert_eq!(p.p90.to_bits(), (expect * COARSE_SPREAD).to_bits());
        }
    }

    #[test]
    fn oracle_is_exact() {
        let mut src = LadderSource::new(InfoLevel::Oracle, Rng::new(1));
        for r in requests(50) {
            let (p, route) = src.priors(&r);
            assert_eq!(p.p50, r.true_output_tokens as f64);
            assert_eq!(p.p90, p.p50);
            assert_eq!(route.bucket_belief, Some(r.true_bucket));
        }
    }

    #[test]
    fn short_class_routing() {
        let mut src = LadderSource::new(InfoLevel::Oracle, Rng::new(1));
        for r in requests(200) {
            let (_, route) = src.priors(&r);
            match r.true_bucket {
                TokenBucket::Short => assert_eq!(route.class, Class::Interactive),
                _ => assert_eq!(route.class, Class::Heavy),
            }
        }
    }
}
