//! Predictor-quality sweep support (paper §4.10): deterministic per-request
//! multiplicative error injected into the *policy-facing* p50/p90 after the
//! usual coarse prior is formed. Routing buckets and mock physics stay
//! unchanged — the sweep isolates what the client believes about length.

use crate::core::{Priors, Request};
use crate::predictor::{PriorSource, Route};
use crate::util::rng::Rng;

/// Wraps an inner source and multiplies its priors by U[1−L, 1+L].
///
/// The injected error also *widens* the interval: the wrapper knows its own
/// noise level, so the calibrated one-sigma half-width grows by `L·p50`
/// (the uniform perturbation's scale in tokens) before the multiplicative
/// factor is applied. At `L = 0` the wrapper is a bit-exact identity —
/// priors, widths, and the RNG stream all pass through untouched.
pub struct NoisySource<S: PriorSource> {
    inner: S,
    level: f64,
    rng: Rng,
}

impl<S: PriorSource> NoisySource<S> {
    /// `level` = L ∈ [0, 1): up to ±100·L % relative error at the endpoints.
    pub fn new(inner: S, level: f64, rng: Rng) -> Self {
        assert!((0.0..1.0).contains(&level), "noise level {level} out of range");
        NoisySource { inner, level, rng }
    }
}

impl<S: PriorSource> PriorSource for NoisySource<S> {
    fn priors(&mut self, req: &Request) -> (Priors, Route) {
        let (p, route) = self.inner.priors(req);
        if self.level == 0.0 {
            return (p, route);
        }
        let factor = self.rng.range(1.0 - self.level, 1.0 + self.level);
        // Routing is NOT recomputed from the noisy value: §4.10 holds
        // routing buckets fixed and perturbs only the numeric priors.
        // Widen first (the wrapper's own error budget, in inner-token
        // units), then scale — `scaled` keeps width in the same units as
        // the quantiles it rides with.
        let widened = Priors::with_width(p.p50, p.p90, p.width + self.level * p.p50);
        (widened.scaled(factor), route)
    }

    fn name(&self) -> String {
        format!("{}+noise{:.1}", self.inner.name(), self.level)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::SloPolicy;
    use crate::predictor::ladder::{InfoLevel, LadderSource};
    use crate::workload::{Mix, SynthGen};

    fn requests(n: usize) -> Vec<Request> {
        let mut g = SynthGen::new(Mix::Balanced, Rng::new(3));
        let slo = SloPolicy::default();
        (0..n).map(|i| g.sample(i, 0.0, &slo)).collect()
    }

    #[test]
    fn zero_noise_is_identity() {
        let reqs = requests(20);
        let mut a = LadderSource::new(InfoLevel::Oracle, Rng::new(1));
        let mut b =
            NoisySource::new(LadderSource::new(InfoLevel::Oracle, Rng::new(1)), 0.0, Rng::new(2));
        for r in &reqs {
            assert_eq!(a.priors(r).0, b.priors(r).0);
        }
    }

    #[test]
    fn noise_bounded_by_level() {
        let reqs = requests(500);
        for level in [0.1, 0.2, 0.4, 0.6] {
            let mut src = NoisySource::new(
                LadderSource::new(InfoLevel::Oracle, Rng::new(5)),
                level,
                Rng::new(9),
            );
            for r in &reqs {
                let (p, _) = src.priors(r);
                let ratio = p.p50 / r.true_output_tokens as f64;
                assert!(
                    ratio >= 1.0 - level - 1e-9 && ratio <= 1.0 + level + 1e-9,
                    "level={level} ratio={ratio}"
                );
            }
        }
    }

    #[test]
    fn route_unchanged_by_noise() {
        let reqs = requests(200);
        let mut base = LadderSource::new(InfoLevel::ClassOnly, Rng::new(5));
        let mut noisy = NoisySource::new(
            LadderSource::new(InfoLevel::ClassOnly, Rng::new(5)),
            0.6,
            Rng::new(11),
        );
        for r in &reqs {
            assert_eq!(base.priors(r).1, noisy.priors(r).1);
        }
    }

    #[test]
    fn monotone_quantiles_preserved() {
        let reqs = requests(300);
        let mut src = NoisySource::new(
            LadderSource::new(InfoLevel::Coarse, Rng::new(5)),
            0.6,
            Rng::new(13),
        );
        for r in &reqs {
            let (p, _) = src.priors(r);
            assert!(p.p90 >= p.p50 && p.p50 > 0.0);
        }
    }

    #[test]
    fn noise_widens_intervals() {
        let reqs = requests(200);
        let level = 0.4;
        let mut base = LadderSource::new(InfoLevel::Coarse, Rng::new(5));
        let mut noisy = NoisySource::new(
            LadderSource::new(InfoLevel::Coarse, Rng::new(5)),
            level,
            Rng::new(13),
        );
        let mut noise_rng = Rng::new(13);
        for r in &reqs {
            let (p0, _) = base.priors(r);
            let (p, _) = noisy.priors(r);
            let factor = noise_rng.range(1.0 - level, 1.0 + level);
            assert_eq!(p.width.to_bits(), ((p0.width + level * p0.p50) * factor).to_bits());
            assert!(p.width > p0.width * (1.0 - level) - 1e-12);
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_invalid_level() {
        let _ = NoisySource::new(LadderSource::new(InfoLevel::Oracle, Rng::new(1)), 1.0, Rng::new(2));
    }
}
