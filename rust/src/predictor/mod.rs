//! Output-length priors: the semi-clairvoyant signal (paper §3.3, §4.4,
//! §4.10), extended to *interval* priors — every source emits a calibrated
//! prediction width alongside its point quantiles.
//!
//! A `PriorSource` maps a request to the *policy-facing* `(Priors, Route)`
//! pair — what the scheduler is allowed to know. The four information-ladder
//! conditions (§4.4) plus the multiplicative-noise wrapper (§4.10) and the
//! PJRT-served neural predictor (runtime::nn) all implement it. The
//! [`recal`] module closes the loop: an online recalibrator that shrinks or
//! widens per-route intervals from observed completions.

#![warn(missing_docs)]

pub mod features;
pub mod ladder;
pub mod noise;
pub mod recal;

pub use ladder::{InfoLevel, LadderSource, NEUTRAL_P50, NEUTRAL_P90, NO_INFO_WIDTH};
pub use noise::NoisySource;
pub use recal::Recalibrator;

use crate::core::{Class, Priors, Request, TokenBucket};

/// What the scheduler believes about a request's routing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Route {
    /// Allocation-layer lane.
    pub class: Class,
    /// Bucket belief for tiered overload; `None` = no usable label
    /// (no-information blind: a single neutral lane, uniform admission).
    pub bucket_belief: Option<TokenBucket>,
}

impl Route {
    /// The blind route: interactive lane, no bucket belief.
    pub fn neutral() -> Route {
        Route { class: Class::Interactive, bucket_belief: None }
    }

    /// Route derived from a (believed) token bucket.
    pub fn from_bucket(b: TokenBucket) -> Route {
        Route { class: b.class(), bucket_belief: Some(b) }
    }

    /// Dense lane index for per-route state tables: 0 = no belief,
    /// 1–4 = the believed bucket. Stable across runs.
    pub fn lane(&self) -> usize {
        match self.bucket_belief {
            None => 0,
            Some(b) => 1 + b.index(),
        }
    }
}

/// Source of policy-facing priors. `&mut` because stochastic sources carry
/// RNG state (deterministic per seed).
pub trait PriorSource {
    /// The `(Priors, Route)` pair the scheduler may see for `req`.
    fn priors(&mut self, req: &Request) -> (Priors, Route);
    /// Human/CSV-facing condition name (e.g. `coarse+noise0.4`).
    fn name(&self) -> String;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn neutral_route_has_no_belief() {
        let r = Route::neutral();
        assert_eq!(r.bucket_belief, None);
        assert_eq!(r.class, Class::Interactive);
    }

    #[test]
    fn route_from_bucket_maps_class() {
        assert_eq!(Route::from_bucket(TokenBucket::Short).class, Class::Interactive);
        assert_eq!(Route::from_bucket(TokenBucket::XLong).class, Class::Heavy);
        assert_eq!(
            Route::from_bucket(TokenBucket::Long).bucket_belief,
            Some(TokenBucket::Long)
        );
    }
}
