//! Output-length priors: the semi-clairvoyant signal (paper §3.3, §4.4,
//! §4.10).
//!
//! A `PriorSource` maps a request to the *policy-facing* `(Priors, Route)`
//! pair — what the scheduler is allowed to know. The four information-ladder
//! conditions (§4.4) plus the multiplicative-noise wrapper (§4.10) and the
//! PJRT-served neural predictor (runtime::nn) all implement it.

pub mod features;
pub mod ladder;
pub mod noise;

pub use ladder::{InfoLevel, LadderSource, NEUTRAL_P50, NEUTRAL_P90};
pub use noise::NoisySource;

use crate::core::{Class, Priors, Request, TokenBucket};

/// What the scheduler believes about a request's routing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Route {
    /// Allocation-layer lane.
    pub class: Class,
    /// Bucket belief for tiered overload; `None` = no usable label
    /// (no-information blind: a single neutral lane, uniform admission).
    pub bucket_belief: Option<TokenBucket>,
}

impl Route {
    pub fn neutral() -> Route {
        Route { class: Class::Interactive, bucket_belief: None }
    }

    pub fn from_bucket(b: TokenBucket) -> Route {
        Route { class: b.class(), bucket_belief: Some(b) }
    }
}

/// Source of policy-facing priors. `&mut` because stochastic sources carry
/// RNG state (deterministic per seed).
pub trait PriorSource {
    fn priors(&mut self, req: &Request) -> (Priors, Route);
    fn name(&self) -> String;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn neutral_route_has_no_belief() {
        let r = Route::neutral();
        assert_eq!(r.bucket_belief, None);
        assert_eq!(r.class, Class::Interactive);
    }

    #[test]
    fn route_from_bucket_maps_class() {
        assert_eq!(Route::from_bucket(TokenBucket::Short).class, Class::Interactive);
        assert_eq!(Route::from_bucket(TokenBucket::XLong).class, Class::Heavy);
        assert_eq!(
            Route::from_bucket(TokenBucket::Long).bucket_belief,
            Some(TokenBucket::Long)
        );
    }
}
