//! `predictor_meta.json` parsing + constants-drift guard.
//!
//! The artifact metadata carries the generative-model constants the
//! predictor was trained under; `check_constants` asserts they match the
//! constants compiled into this binary (`workload::synth::GEN_CONSTANTS`),
//! so a stale artifact cannot silently serve an out-of-distribution model.

use anyhow::{bail, Context, Result};

use crate::core::TokenBucket;
use crate::util::jsonio::Json;
use crate::workload::synth::GEN_CONSTANTS;

/// Golden input/output vectors for the runtime numerics test.
#[derive(Debug, Clone)]
pub struct Golden {
    pub features: Vec<Vec<f32>>,
    pub expected_p50: Vec<f64>,
    pub expected_p90: Vec<f64>,
    pub true_tokens: Vec<f64>,
}

/// Parsed predictor metadata.
#[derive(Debug, Clone)]
pub struct PredictorMeta {
    pub d_in: usize,
    pub token_scale: f64,
    pub batch_sizes: Vec<usize>,
    pub artifacts: Vec<String>,
    pub golden: Golden,
    pub training_coverage_p90: f64,
    raw: Json,
}

impl PredictorMeta {
    pub fn load(path: &str) -> Result<PredictorMeta> {
        let j = Json::read_file(path).with_context(|| format!("reading {path}"))?;
        let model = j.req("model")?;
        let d_in = model.req("d_in")?.as_usize().context("model.d_in")?;
        let token_scale = model.req("token_scale")?.as_f64().context("model.token_scale")?;
        let batch_sizes: Vec<usize> = model
            .req("batch_sizes")?
            .as_arr()
            .context("model.batch_sizes")?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let artifacts: Vec<String> = j
            .req("artifacts")?
            .as_arr()
            .context("artifacts")?
            .iter()
            .filter_map(|a| a.as_str().map(str::to_string))
            .collect();
        if artifacts.len() != batch_sizes.len() {
            bail!("artifacts/batch_sizes length mismatch");
        }
        let g = j.req("golden")?;
        let features = g
            .req("features")?
            .as_arr()
            .context("golden.features")?
            .iter()
            .map(|row| {
                row.f64_array().map(|v| v.into_iter().map(|x| x as f32).collect::<Vec<f32>>())
            })
            .collect::<std::result::Result<Vec<_>, _>>()?;
        let golden = Golden {
            features,
            expected_p50: g.req("expected_p50")?.f64_array()?,
            expected_p90: g.req("expected_p90")?.f64_array()?,
            true_tokens: g.req("true_tokens")?.f64_array()?,
        };
        let training_coverage_p90 =
            j.get("training").map(|t| t.f64_or("coverage_p90", f64::NAN)).unwrap_or(f64::NAN);
        Ok(PredictorMeta { d_in, token_scale, batch_sizes, artifacts, golden, training_coverage_p90, raw: j })
    }

    /// Assert the artifact's generative-model constants match this binary's.
    pub fn check_constants(&self) -> Result<()> {
        let dg = self.raw.req("datagen")?;
        // Bucket bounds.
        let buckets = dg.req("buckets")?;
        for b in TokenBucket::ALL {
            let bounds = buckets.req(b.name())?.f64_array()?;
            let (lo, hi) = b.bounds();
            if bounds != vec![lo as f64, hi as f64] {
                bail!("bucket {} bounds drift: artifact {:?} vs binary {:?}", b.name(), bounds, (lo, hi));
            }
        }
        // Prompt model.
        let close = |a: &[f64], b: &[f64]| {
            a.len() == b.len() && a.iter().zip(b).all(|(x, y)| (x - y).abs() < 1e-9)
        };
        let alpha = dg.req("prompt_alpha")?.f64_array()?;
        let beta = dg.req("prompt_beta")?.f64_array()?;
        if !close(&alpha, &GEN_CONSTANTS.prompt_alpha) || !close(&beta, &GEN_CONSTANTS.prompt_beta) {
            bail!("prompt alpha/beta drift");
        }
        let sigma = dg.req("prompt_sigma")?.as_f64().context("prompt_sigma")?;
        if (sigma - GEN_CONSTANTS.prompt_sigma).abs() > 1e-9 {
            bail!("prompt_sigma drift: {sigma}");
        }
        // Task-given-bucket matrix.
        let tgb = dg.req("task_given_bucket")?;
        for (bi, b) in TokenBucket::ALL.iter().enumerate() {
            let row = tgb.req(b.name())?.f64_array()?;
            if !close(&row, &GEN_CONSTANTS.task_given_bucket[bi]) {
                bail!("task_given_bucket[{}] drift", b.name());
            }
        }
        // Max-tokens grid.
        let grid = dg.req("max_tokens_grid")?.f64_array()?;
        let want: Vec<f64> = GEN_CONSTANTS.max_tokens_grid.iter().map(|x| *x as f64).collect();
        if grid != want {
            bail!("max_tokens_grid drift");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, default_artifacts_dir};

    #[test]
    fn parses_and_checks_real_artifacts_when_present() {
        let dir = default_artifacts_dir();
        if !artifacts_available(&dir) {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let meta = PredictorMeta::load(&format!("{dir}/predictor_meta.json")).unwrap();
        assert_eq!(meta.d_in, 32);
        assert_eq!(meta.batch_sizes, vec![128, 512]);
        assert_eq!(meta.golden.features.len(), meta.golden.expected_p50.len());
        meta.check_constants().expect("constants must match");
        for (p50, p90) in meta.golden.expected_p50.iter().zip(&meta.golden.expected_p90) {
            assert!(p90 >= p50, "monotone golden quantiles");
        }
    }

    #[test]
    fn detects_bucket_drift() {
        let text = r#"{
          "model": {"d_in": 32, "token_scale": 256, "batch_sizes": [128]},
          "artifacts": ["a.hlo.txt"],
          "golden": {"features": [[0.0]], "expected_p50": [1], "expected_p90": [2], "true_tokens": [1]},
          "datagen": {"buckets": {"short": [8, 63], "medium": [65, 256], "long": [257, 1024], "xlong": [1025, 4096]},
                      "prompt_alpha": [2.2, 4.1, 1.8, 3.5], "prompt_beta": [0.55, 0.35, 0.7, 0.3],
                      "prompt_sigma": 0.45,
                      "task_given_bucket": {"short": [0.45, 0.05, 0.1, 0.4], "medium": [0.4, 0.2, 0.25, 0.15],
                                             "long": [0.25, 0.35, 0.3, 0.1], "xlong": [0.1, 0.4, 0.45, 0.05]},
                      "max_tokens_grid": [256, 512, 1024, 2048, 4096]}
        }"#;
        let path = std::env::temp_dir().join("bbsched_meta_drift.json");
        std::fs::write(&path, text).unwrap();
        let meta = PredictorMeta::load(path.to_str().unwrap()).unwrap();
        let err = meta.check_constants().unwrap_err();
        assert!(format!("{err:#}").contains("bounds drift"), "{err:#}");
        std::fs::remove_file(path).ok();
    }
}
