//! Neural prior source: the trained quantile MLP served through PJRT as a
//! `PriorSource` — the deployable analogue of the paper's SageSched
//! predictor premise. Used by the end-to-end example and the `*_nn`
//! strategy variants; table experiments default to the analytic ladder
//! (matching the paper's controlled setup).

use crate::core::{Priors, Request};
use crate::predictor::features::{batch_features, features, D_IN};
use crate::predictor::{PriorSource, Route};
use crate::runtime::Predictor;

/// Per-request prior source backed by the PJRT predictor.
///
/// Each `priors()` call executes one (padded) kernel batch; for bulk
/// workloads prefer [`NnPriorSource::predict_all`] which packs requests into
/// the largest compiled batch.
pub struct NnPriorSource {
    predictor: Predictor,
    calls: u64,
}

impl NnPriorSource {
    pub fn new(predictor: Predictor) -> Self {
        NnPriorSource { predictor, calls: 0 }
    }

    pub fn predictor(&self) -> &Predictor {
        &self.predictor
    }

    pub fn calls(&self) -> u64 {
        self.calls
    }

    /// Batched bulk prediction: one PJRT execution per `max_batch` rows.
    pub fn predict_all(&mut self, requests: &[&Request]) -> anyhow::Result<Vec<(Priors, Route)>> {
        let mut out = Vec::with_capacity(requests.len());
        let bmax = self.predictor.max_batch();
        for chunk in requests.chunks(bmax.max(1)) {
            let feats = batch_features(chunk, chunk.len());
            let priors = self.predictor.predict(&feats, chunk.len())?;
            self.calls += 1;
            for p in priors {
                out.push((p, Route::from_bucket(p.bucket())));
            }
        }
        Ok(out)
    }
}

impl PriorSource for NnPriorSource {
    fn priors(&mut self, req: &Request) -> (Priors, Route) {
        let f: [f32; D_IN] = features(req);
        self.calls += 1;
        let p = self
            .predictor
            .predict(&f, 1)
            .expect("PJRT predictor execution failed")
            .pop()
            .expect("one row in, one prior out");
        // Semi-clairvoyant routing: the class lane follows the *predicted*
        // bucket — the client has no generator label.
        (p, Route::from_bucket(p.bucket()))
    }

    fn name(&self) -> String {
        "nn_pjrt".to_string()
    }
}
