//! PJRT runtime: load the AOT-compiled predictor (HLO text emitted by
//! `python/compile/aot.py`) and execute it from the Rust hot path.
//!
//! Python never runs here — the artifacts are self-contained HLO with the
//! trained weights baked in as constants. Interchange is HLO *text* (the
//! image's xla_extension 0.5.1 rejects jax ≥ 0.5's 64-bit-id serialized
//! protos; the text parser reassigns ids).
//!
//! The execution path needs the `xla` PJRT bindings, which not every build
//! environment vendors, so it is gated behind the `pjrt` cargo feature.
//! Without the feature a stub [`Predictor`] with the identical signature is
//! compiled instead: `load` fails with an actionable message and every
//! caller (serve demo, `bbsched predict`, benches) degrades to the analytic
//! ladder sources, keeping the default build dependency-free.

pub mod meta;
pub mod nn;

pub use meta::PredictorMeta;
pub use nn::NnPriorSource;

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use anyhow::{bail, Context, Result};

    use super::meta::PredictorMeta;
    use crate::core::Priors;
    use crate::predictor::features::D_IN;

    /// A compiled predictor executable at one static batch size.
    struct BatchExe {
        batch: usize,
        exe: xla::PjRtLoadedExecutable,
    }

    /// The AOT predictor served through PJRT.
    pub struct Predictor {
        _client: xla::PjRtClient,
        exes: Vec<BatchExe>,
        pub meta: PredictorMeta,
    }

    impl Predictor {
        /// Load every artifact listed in `predictor_meta.json` and compile
        /// it on the PJRT CPU client.
        pub fn load(artifacts_dir: &str) -> Result<Predictor> {
            let meta = PredictorMeta::load(&format!("{artifacts_dir}/predictor_meta.json"))
                .context("loading predictor_meta.json (run `make artifacts`)")?;
            meta.check_constants().context("artifact/binary constants drift")?;
            if meta.d_in != D_IN {
                bail!("artifact d_in {} != binary D_IN {}", meta.d_in, D_IN);
            }
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            let mut exes = Vec::new();
            for (batch, name) in meta.batch_sizes.iter().zip(meta.artifacts.iter()) {
                let path = format!("{artifacts_dir}/{name}");
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .with_context(|| format!("parsing HLO text {path}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client.compile(&comp).with_context(|| format!("compiling {name}"))?;
                exes.push(BatchExe { batch: *batch, exe });
            }
            exes.sort_by_key(|e| e.batch);
            Ok(Predictor { _client: client, exes, meta })
        }

        /// Largest compiled batch size.
        pub fn max_batch(&self) -> usize {
            self.exes.last().map(|e| e.batch).unwrap_or(0)
        }

        /// Run the predictor on `n` feature rows (row-major `n × D_IN`).
        /// Rows beyond the chosen executable's batch are processed in
        /// chunks. Returns one `Priors` per input row.
        pub fn predict(&self, features: &[f32], n: usize) -> Result<Vec<Priors>> {
            assert_eq!(features.len(), n * D_IN, "feature matrix shape");
            let mut out = Vec::with_capacity(n);
            let mut row = 0;
            while row < n {
                let remaining = n - row;
                // Smallest executable that covers the remainder, else the largest.
                let exe = self
                    .exes
                    .iter()
                    .find(|e| e.batch >= remaining)
                    .or_else(|| self.exes.last())
                    .context("no compiled executables")?;
                let take = remaining.min(exe.batch);
                let mut padded = vec![0.0f32; exe.batch * D_IN];
                padded[..take * D_IN]
                    .copy_from_slice(&features[row * D_IN..(row + take) * D_IN]);
                let quantiles = self.execute_one(exe, &padded)?;
                for i in 0..take {
                    out.push(Priors::new(quantiles[2 * i] as f64, quantiles[2 * i + 1] as f64));
                }
                row += take;
            }
            Ok(out)
        }

        /// Execute one padded batch; returns the raw (batch × 2) quantile rows.
        fn execute_one(&self, exe: &BatchExe, padded: &[f32]) -> Result<Vec<f32>> {
            let x = xla::Literal::vec1(padded).reshape(&[exe.batch as i64, D_IN as i64])?;
            let result = exe.exe.execute::<xla::Literal>(&[x])?[0][0].to_literal_sync()?;
            // aot.py lowers with return_tuple=True → 1-tuple.
            let out = result.to_tuple1()?;
            let v = out.to_vec::<f32>()?;
            if v.len() != exe.batch * 2 {
                bail!("unexpected output size {} (want {})", v.len(), exe.batch * 2);
            }
            Ok(v)
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::Predictor;

#[cfg(not(feature = "pjrt"))]
mod pjrt_stub {
    use anyhow::{bail, Result};

    use super::meta::PredictorMeta;
    use crate::core::Priors;

    /// Stub predictor compiled when the `pjrt` feature is disabled (the
    /// default in environments without the vendored `xla` bindings). The
    /// public surface matches the real runtime so every caller compiles
    /// unchanged; loading fails with an actionable message and the callers
    /// fall back to the analytic ladder sources.
    pub struct Predictor {
        /// Parsed artifact metadata (never populated by the stub; the field
        /// exists so metadata consumers compile against both builds).
        pub meta: PredictorMeta,
    }

    impl Predictor {
        /// Always fails: the execution path needs the `pjrt` feature.
        pub fn load(artifacts_dir: &str) -> Result<Predictor> {
            bail!(
                "PJRT runtime disabled: this binary was built without the `pjrt` \
                 cargo feature, so artifacts in {artifacts_dir:?} cannot be served; \
                 rebuild with `--features pjrt` (requires the xla bindings) or use \
                 the analytic prior sources"
            )
        }

        /// Largest compiled batch size (0: nothing is ever compiled).
        pub fn max_batch(&self) -> usize {
            0
        }

        /// Always fails: no executables exist without the `pjrt` feature.
        pub fn predict(&self, _features: &[f32], _n: usize) -> Result<Vec<Priors>> {
            bail!("PJRT runtime disabled: built without the `pjrt` feature")
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use pjrt_stub::Predictor;

/// Artifacts directory default, overridable via BBSCHED_ARTIFACTS.
pub fn default_artifacts_dir() -> String {
    std::env::var("BBSCHED_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}

/// True if artifacts exist (integration tests skip gracefully otherwise).
pub fn artifacts_available(dir: &str) -> bool {
    std::path::Path::new(&format!("{dir}/predictor_meta.json")).exists()
}
