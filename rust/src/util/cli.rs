//! Declarative CLI argument parser (the image vendors no clap).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, typed
//! accessors with defaults, required-argument errors, and auto-generated
//! `--help` text. Used by `rust/src/main.rs` and every example binary.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug)]
pub enum CliError {
    UnknownFlag(String),
    MissingValue(String),
    MissingRequired(String),
    Invalid { flag: String, value: String, expected: &'static str },
    UnexpectedPositional(String),
}

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CliError::UnknownFlag(flag) => write!(f, "unknown flag: {flag} (try --help)"),
            CliError::MissingValue(flag) => write!(f, "flag {flag} expects a value"),
            CliError::MissingRequired(name) => write!(f, "missing required argument: --{name}"),
            CliError::Invalid { flag, value, expected } => {
                write!(f, "invalid value for --{flag}: {value:?} ({expected})")
            }
            CliError::UnexpectedPositional(arg) => {
                write!(f, "unexpected positional argument: {arg}")
            }
        }
    }
}

impl std::error::Error for CliError {}

/// One declared option.
#[derive(Debug, Clone)]
struct OptSpec {
    name: String,
    help: String,
    takes_value: bool,
    default: Option<String>,
    required: bool,
}

/// Declarative command spec: `Cmd::new("run").opt(...).flag(...)`.
#[derive(Debug, Clone)]
pub struct Cmd {
    name: String,
    about: String,
    opts: Vec<OptSpec>,
    allow_positionals: bool,
}

impl Cmd {
    pub fn new(name: &str, about: &str) -> Self {
        Cmd { name: name.to_string(), about: about.to_string(), opts: Vec::new(), allow_positionals: false }
    }

    /// `--name <value>` with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            takes_value: true,
            default: Some(default.to_string()),
            required: false,
        });
        self
    }

    /// `--name <value>`, required.
    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            takes_value: true,
            default: None,
            required: true,
        });
        self
    }

    /// Boolean `--name` flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            takes_value: false,
            default: None,
            required: false,
        });
        self
    }

    pub fn positionals(mut self) -> Self {
        self.allow_positionals = true;
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let head = if o.takes_value {
                format!("  --{} <v>", o.name)
            } else {
                format!("  --{}", o.name)
            };
            let def = match (&o.default, o.required) {
                (Some(d), _) => format!(" [default: {d}]"),
                (None, true) => " [required]".to_string(),
                _ => String::new(),
            };
            s.push_str(&format!("{head:<26}{}{def}\n", o.help));
        }
        s
    }

    /// Parse a raw arg list (without the binary/subcommand names).
    pub fn parse(&self, args: &[String]) -> Result<Args, CliError> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut positionals: Vec<String> = Vec::new();
        for o in &self.opts {
            if let Some(d) = &o.default {
                values.insert(o.name.clone(), d.clone());
            }
        }
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Ok(Args { help: true, ..Args::new(values, flags, positionals) });
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| CliError::UnknownFlag(a.clone()))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i).cloned().ok_or_else(|| CliError::MissingValue(a.clone()))?
                        }
                    };
                    values.insert(name, v);
                } else {
                    flags.push(name);
                }
            } else if self.allow_positionals {
                positionals.push(a.clone());
            } else {
                return Err(CliError::UnexpectedPositional(a.clone()));
            }
            i += 1;
        }
        for o in &self.opts {
            if o.required && !values.contains_key(&o.name) {
                return Err(CliError::MissingRequired(o.name.clone()));
            }
        }
        Ok(Args::new(values, flags, positionals))
    }
}

/// Parsed arguments with typed accessors.
#[derive(Debug, Clone)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positionals: Vec<String>,
    pub help: bool,
}

impl Args {
    fn new(values: BTreeMap<String, String>, flags: Vec<String>, positionals: Vec<String>) -> Self {
        Args { values, flags, positionals, help: false }
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str) -> &str {
        self.get(name).unwrap_or_else(|| panic!("undeclared option {name}"))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn f64(&self, name: &str) -> Result<f64, CliError> {
        let v = self.str(name);
        v.parse().map_err(|_| CliError::Invalid {
            flag: name.to_string(),
            value: v.to_string(),
            expected: "number",
        })
    }

    pub fn u64(&self, name: &str) -> Result<u64, CliError> {
        let v = self.str(name);
        v.parse().map_err(|_| CliError::Invalid {
            flag: name.to_string(),
            value: v.to_string(),
            expected: "integer",
        })
    }

    pub fn usize(&self, name: &str) -> Result<usize, CliError> {
        Ok(self.u64(name)? as usize)
    }

    /// Comma-separated list.
    pub fn list(&self, name: &str) -> Vec<String> {
        let v = self.str(name);
        if v.is_empty() {
            Vec::new()
        } else {
            v.split(',').map(|s| s.trim().to_string()).collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn cmd() -> Cmd {
        Cmd::new("run", "run an experiment")
            .opt("seeds", "5", "number of seeds")
            .opt("regime", "balanced_high", "regime name")
            .req("out", "output path")
            .flag("verbose", "chatty")
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cmd().parse(&argv(&["--out", "/tmp/x", "--seeds=7"])).unwrap();
        assert_eq!(a.usize("seeds").unwrap(), 7);
        assert_eq!(a.str("regime"), "balanced_high");
        assert_eq!(a.str("out"), "/tmp/x");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn flags() {
        let a = cmd().parse(&argv(&["--out", "x", "--verbose"])).unwrap();
        assert!(a.flag("verbose"));
    }

    #[test]
    fn missing_required() {
        assert!(matches!(cmd().parse(&argv(&[])), Err(CliError::MissingRequired(_))));
    }

    #[test]
    fn unknown_flag() {
        assert!(matches!(
            cmd().parse(&argv(&["--out", "x", "--nope"])),
            Err(CliError::UnknownFlag(_))
        ));
    }

    #[test]
    fn bad_number() {
        let a = cmd().parse(&argv(&["--out", "x", "--seeds", "abc"])).unwrap();
        assert!(matches!(a.usize("seeds"), Err(CliError::Invalid { .. })));
    }

    #[test]
    fn help_flag() {
        let a = cmd().parse(&argv(&["--help"])).unwrap();
        assert!(a.help);
        assert!(cmd().help_text().contains("--seeds"));
    }

    #[test]
    fn list_parsing() {
        let a = cmd()
            .opt("ls", "a,b", "list")
            .parse(&argv(&["--out", "x", "--ls", "p, q ,r"]))
            .unwrap();
        assert_eq!(a.list("ls"), vec!["p", "q", "r"]);
    }

    #[test]
    fn positionals_rejected_unless_allowed() {
        assert!(cmd().parse(&argv(&["--out", "x", "stray"])).is_err());
        let a = cmd().positionals().parse(&argv(&["--out", "x", "stray"])).unwrap();
        assert_eq!(a.positionals, vec!["stray"]);
    }
}
