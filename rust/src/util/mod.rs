//! Dependency-free substrates: deterministic RNG, JSON/CSV I/O, CLI
//! parsing, statistics, and the scoped worker pool (the offline image
//! vendors only the `xla` closure, so these replace
//! rand/serde/clap/rayon/criterion-adjacent helpers).

pub mod cli;
pub mod csvio;
pub mod jsonio;
pub mod pool;
pub mod rng;
pub mod stats;
