//! Dependency-free substrates: deterministic RNG, JSON/CSV I/O, CLI
//! parsing, and statistics (the offline image vendors only the `xla`
//! closure, so these replace rand/serde/clap/criterion-adjacent helpers).

pub mod cli;
pub mod csvio;
pub mod jsonio;
pub mod rng;
pub mod stats;
