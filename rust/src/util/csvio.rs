//! CSV writer for `paper_results/tables/*.csv` — mirrors the CSV artifacts
//! the paper cites (`prior_ablation_summary.csv`, etc.).

use std::io::Write;

/// Minimal CSV table builder with RFC-4180 quoting.
#[derive(Debug, Clone)]
pub struct CsvTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvTable {
    pub fn new<S: Into<String>>(columns: impl IntoIterator<Item = S>) -> Self {
        CsvTable { header: columns.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn columns(&self) -> &[String] {
        &self.header
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Push a row; panics if the width mismatches the header (programmer error).
    pub fn row<S: Into<String>>(&mut self, cells: impl IntoIterator<Item = S>) {
        let row: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            row.len(),
            self.header.len(),
            "csv row width {} != header width {}",
            row.len(),
            self.header.len()
        );
        self.rows.push(row);
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        write_record(&mut out, &self.header);
        for row in &self.rows {
            write_record(&mut out, row);
        }
        out
    }

    pub fn write_file(&self, path: &str) -> std::io::Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_string().as_bytes())
    }
}

fn write_record(out: &mut String, cells: &[String]) {
    for (i, cell) in cells.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
            out.push('"');
            out.push_str(&cell.replace('"', "\"\""));
            out.push('"');
        } else {
            out.push_str(cell);
        }
    }
    out.push('\n');
}

/// Format `mean ± std` the way the paper's tables print it.
pub fn pm(mean: f64, std: f64) -> String {
    if mean.abs() >= 100.0 {
        format!("{mean:.0}±{std:.0}")
    } else if mean.abs() >= 10.0 {
        format!("{mean:.1}±{std:.1}")
    } else {
        format!("{mean:.2}±{std:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_table() {
        let mut t = CsvTable::new(["a", "b"]);
        t.row(["1", "2"]);
        t.row(["x,y", "q\"z"]);
        assert_eq!(t.to_string(), "a,b\n1,2\n\"x,y\",\"q\"\"z\"\n");
        assert_eq!(t.n_rows(), 2);
    }

    #[test]
    #[should_panic(expected = "csv row width")]
    fn width_mismatch_panics() {
        let mut t = CsvTable::new(["a", "b"]);
        t.row(["only-one"]);
    }

    #[test]
    fn pm_formats() {
        assert_eq!(pm(347.4, 27.5), "347±28");
        assert_eq!(pm(4.2, 1.6), "4.20±1.60");
        assert_eq!(pm(17.4, 1.3), "17.4±1.3");
    }
}
