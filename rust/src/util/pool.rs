//! Scoped worker pool for deterministic fan-out (std::thread only — the
//! offline image vendors no rayon).
//!
//! [`scoped_map`] runs a function over a work list on up to `jobs` threads
//! and returns the results **in input order**, so a parallel experiment
//! sweep is byte-identical to a serial one. Workers claim the next
//! unclaimed index from a shared atomic counter (dynamic load balancing —
//! experiment cells have very uneven costs), and every item is executed
//! exactly once: the counter hands each index to exactly one worker, and
//! the per-slot `Option` take asserts single ownership.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Worker count meaning "all available cores".
pub const ALL_CORES: usize = 0;

/// Number of worker threads used when `jobs == 0` (all available cores).
pub fn default_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Map `f` over `items` with up to `jobs` workers, preserving input order.
///
/// `jobs == 0` means [`default_jobs`]; the effective worker count is also
/// capped by the item count. With one worker the items run serially on the
/// calling thread — the same code path a `--jobs 1` sweep takes. A panic in
/// `f` propagates to the caller after the scope joins its workers.
pub fn scoped_map<T, R, F>(items: Vec<T>, jobs: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    let requested = if jobs == ALL_CORES { default_jobs() } else { jobs };
    let jobs = requested.min(n.max(1));
    if jobs <= 1 {
        return items.into_iter().map(f).collect();
    }
    // Per-slot locks (not one big queue lock): claims are index-based via
    // the atomic counter, so workers never contend on the same slot.
    let work: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = work[i].lock().unwrap().take().expect("index claimed exactly once");
                let r = f(item);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });
    results
        .into_iter()
        .map(|slot| slot.into_inner().unwrap().expect("worker filled every slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn preserves_input_order() {
        let items: Vec<usize> = (0..257).collect();
        let out = scoped_map(items, 8, |x| x * 3);
        assert_eq!(out, (0..257).map(|x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn executes_every_job_exactly_once_under_contention() {
        // Many more jobs than cores, with uneven per-item work so workers
        // race on the claim counter: every per-item counter must end at 1.
        let n = 500;
        let counts: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        let out = scoped_map((0..n).collect::<Vec<usize>>(), 16, |i| {
            counts[i].fetch_add(1, Ordering::SeqCst);
            // Uneven spin: early items are much more expensive.
            let mut acc = 0u64;
            for k in 0..((n - i) as u64 * 50) {
                acc = acc.wrapping_add(std::hint::black_box(k));
            }
            (i, acc)
        });
        assert_eq!(out.len(), n);
        for (idx, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::SeqCst), 1, "item {idx} run count");
        }
        for (idx, (i, _)) in out.iter().enumerate() {
            assert_eq!(*i, idx, "result slot matches input slot");
        }
    }

    #[test]
    fn matches_serial_for_any_job_count() {
        let serial = scoped_map((0..40).collect::<Vec<i64>>(), 1, |x| x * x - 7);
        for jobs in [0, 2, 3, 8, 64] {
            let par = scoped_map((0..40).collect::<Vec<i64>>(), jobs, |x| x * x - 7);
            assert_eq!(par, serial, "jobs={jobs}");
        }
    }

    #[test]
    fn more_workers_than_items() {
        let out = scoped_map(vec![10, 20], 32, |x| x + 1);
        assert_eq!(out, vec![11, 21]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<u32> = scoped_map(Vec::<u32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn default_jobs_is_positive() {
        assert!(default_jobs() >= 1);
    }

    #[test]
    fn moves_non_copy_items() {
        let items: Vec<String> = (0..20).map(|i| format!("req-{i}")).collect();
        let out = scoped_map(items, 4, |s| s.len());
        assert_eq!(out[0], 5);
        assert_eq!(out[19], 6);
    }
}
