//! Scoped worker pool for deterministic fan-out (std::thread only — the
//! offline image vendors no rayon).
//!
//! Two layers share one spawn/join primitive, [`scoped_workers`]:
//!
//! * [`scoped_map`] runs a function over a work list on up to `jobs` threads
//!   and returns the results **in input order**, so a parallel experiment
//!   sweep is byte-identical to a serial one. Workers claim the next
//!   unclaimed index from a shared atomic counter (dynamic load balancing —
//!   experiment cells have very uneven costs), and every item is executed
//!   exactly once: the counter hands each index to exactly one worker, and
//!   the per-slot `Option` take asserts single ownership.
//! * The partitioned event loop (`sim::partition`) spawns one long-lived
//!   worker per partition plus a coordinator on the calling thread,
//!   synchronized by a [`SpinBarrier`] at lookahead-window boundaries.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Worker count meaning "all available cores".
pub const ALL_CORES: usize = 0;

/// Number of worker threads used when `jobs == 0` (all available cores).
pub fn default_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Spawn `n` scoped worker threads running `worker(i)` while `coordinator`
/// runs on the calling thread; join everything and return the workers'
/// results **in index order** alongside the coordinator's result.
///
/// This is the one spawn/claim/join site shared by [`scoped_map`] (whose
/// coordinator is a no-op — the calling thread just waits) and the
/// partition executor (whose coordinator drives the window protocol). A
/// worker panic propagates to the caller after the scope joins the rest;
/// callers whose workers block on shared synchronization (barriers) must
/// arrange their own abort signalling so sibling workers still exit.
pub fn scoped_workers<R, W, C, K>(n: usize, worker: W, coordinator: K) -> (Vec<R>, C)
where
    R: Send,
    W: Fn(usize) -> R + Sync,
    K: FnOnce() -> C,
{
    std::thread::scope(|scope| {
        let worker = &worker;
        let handles: Vec<_> = (0..n).map(|i| scope.spawn(move || worker(i))).collect();
        let coord = coordinator();
        let results: Vec<R> = handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|p| std::panic::resume_unwind(p)))
            .collect();
        (results, coord)
    })
}

/// Map `f` over `items` with up to `jobs` workers, preserving input order.
///
/// `jobs == 0` means [`default_jobs`]; the effective worker count is also
/// capped by the item count. With one worker the items run serially on the
/// calling thread — the same code path a `--jobs 1` sweep takes. A panic in
/// `f` propagates to the caller after the scope joins its workers.
pub fn scoped_map<T, R, F>(items: Vec<T>, jobs: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    let requested = if jobs == ALL_CORES { default_jobs() } else { jobs };
    let jobs = requested.min(n.max(1));
    if jobs <= 1 {
        return items.into_iter().map(f).collect();
    }
    // Per-slot locks (not one big queue lock): claims are index-based via
    // the atomic counter, so workers never contend on the same slot.
    let work: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    scoped_workers(
        jobs,
        |_| loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            let item = work[i].lock().unwrap().take().expect("index claimed exactly once");
            let r = f(item);
            *results[i].lock().unwrap() = Some(r);
        },
        || (),
    );
    results
        .into_iter()
        .map(|slot| slot.into_inner().unwrap().expect("worker filled every slot"))
        .collect()
}

/// A reusable generation-counted barrier for `n` participants.
///
/// Unlike `std::sync::Barrier`, waiters spin briefly before falling back to
/// `yield_now` — the partition executor crosses a barrier every lookahead
/// window (sub-millisecond cadence), where parking/unparking OS primitives
/// dominate the window's useful work, but pure spinning starves oversubscribed
/// runners (P workers + 1 coordinator on P cores is the common CI shape).
///
/// The barrier is reusable: the last arriver resets the arrival count
/// *before* bumping the generation, and no thread can re-enter `wait` until
/// the generation it observed has been bumped, so arrivals for round k+1
/// never race the reset for round k.
pub struct SpinBarrier {
    n: usize,
    arrived: AtomicUsize,
    generation: AtomicUsize,
}

impl SpinBarrier {
    /// A barrier releasing when `n` participants have called [`wait`](Self::wait).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "barrier needs at least one participant");
        SpinBarrier { n, arrived: AtomicUsize::new(0), generation: AtomicUsize::new(0) }
    }

    /// Block (spin, then yield) until all `n` participants have arrived.
    pub fn wait(&self) {
        let generation = self.generation.load(Ordering::Acquire);
        if self.arrived.fetch_add(1, Ordering::AcqRel) + 1 == self.n {
            self.arrived.store(0, Ordering::Release);
            self.generation.store(generation.wrapping_add(1), Ordering::Release);
            return;
        }
        let mut spins = 0u32;
        while self.generation.load(Ordering::Acquire) == generation {
            spins = spins.saturating_add(1);
            if spins < 64 {
                std::hint::spin_loop();
            } else {
                std::thread::yield_now();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn preserves_input_order() {
        let items: Vec<usize> = (0..257).collect();
        let out = scoped_map(items, 8, |x| x * 3);
        assert_eq!(out, (0..257).map(|x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn executes_every_job_exactly_once_under_contention() {
        // Many more jobs than cores, with uneven per-item work so workers
        // race on the claim counter: every per-item counter must end at 1.
        let n = 500;
        let counts: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        let out = scoped_map((0..n).collect::<Vec<usize>>(), 16, |i| {
            counts[i].fetch_add(1, Ordering::SeqCst);
            // Uneven spin: early items are much more expensive.
            let mut acc = 0u64;
            for k in 0..((n - i) as u64 * 50) {
                acc = acc.wrapping_add(std::hint::black_box(k));
            }
            (i, acc)
        });
        assert_eq!(out.len(), n);
        for (idx, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::SeqCst), 1, "item {idx} run count");
        }
        for (idx, (i, _)) in out.iter().enumerate() {
            assert_eq!(*i, idx, "result slot matches input slot");
        }
    }

    #[test]
    fn matches_serial_for_any_job_count() {
        let serial = scoped_map((0..40).collect::<Vec<i64>>(), 1, |x| x * x - 7);
        for jobs in [0, 2, 3, 8, 64] {
            let par = scoped_map((0..40).collect::<Vec<i64>>(), jobs, |x| x * x - 7);
            assert_eq!(par, serial, "jobs={jobs}");
        }
    }

    #[test]
    fn more_workers_than_items() {
        let out = scoped_map(vec![10, 20], 32, |x| x + 1);
        assert_eq!(out, vec![11, 21]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<u32> = scoped_map(Vec::<u32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn default_jobs_is_positive() {
        assert!(default_jobs() >= 1);
    }

    #[test]
    fn moves_non_copy_items() {
        let items: Vec<String> = (0..20).map(|i| format!("req-{i}")).collect();
        let out = scoped_map(items, 4, |s| s.len());
        assert_eq!(out[0], 5);
        assert_eq!(out[19], 6);
    }

    #[test]
    fn scoped_workers_returns_results_in_index_order() {
        let (results, coord) = scoped_workers(
            8,
            |i| {
                // Uneven spin so completion order scrambles.
                let mut acc = 0u64;
                for k in 0..((8 - i) as u64 * 5_000) {
                    acc = acc.wrapping_add(std::hint::black_box(k));
                }
                std::hint::black_box(acc);
                i * 10
            },
            || "done",
        );
        assert_eq!(results, (0..8).map(|i| i * 10).collect::<Vec<_>>());
        assert_eq!(coord, "done");
    }

    #[test]
    fn scoped_workers_coordinator_runs_concurrently() {
        // The coordinator and workers must overlap: workers block on a
        // barrier only the coordinator's participation can release.
        let barrier = SpinBarrier::new(5);
        let (results, _) = scoped_workers(
            4,
            |i| {
                barrier.wait();
                i
            },
            || barrier.wait(),
        );
        assert_eq!(results, vec![0, 1, 2, 3]);
    }

    #[test]
    fn spin_barrier_is_reusable_across_rounds() {
        // 4 workers + coordinator cross the same barrier 100 times; a
        // shared counter bumped strictly between crossings must show every
        // participant saw every round.
        const ROUNDS: usize = 100;
        const WORKERS: usize = 4;
        let barrier = SpinBarrier::new(WORKERS + 1);
        let round = AtomicUsize::new(0);
        let (counts, _) = scoped_workers(
            WORKERS,
            |_| {
                let mut seen = 0usize;
                for r in 0..ROUNDS {
                    barrier.wait();
                    // Between the two barriers the coordinator has set
                    // `round` to r and nobody may advance past it.
                    assert_eq!(round.load(Ordering::SeqCst), r);
                    seen += 1;
                    barrier.wait();
                }
                seen
            },
            || {
                for r in 0..ROUNDS {
                    round.store(r, Ordering::SeqCst);
                    barrier.wait();
                    barrier.wait();
                }
            },
        );
        assert_eq!(counts, vec![ROUNDS; WORKERS]);
    }

    #[test]
    #[should_panic(expected = "worker 2 exploded")]
    fn scoped_workers_propagates_worker_panics() {
        let _ = scoped_workers(
            4,
            |i| {
                if i == 2 {
                    panic!("worker 2 exploded");
                }
                i
            },
            || (),
        );
    }
}
