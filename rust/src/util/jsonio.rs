//! Minimal JSON substrate (the offline image vendors no serde facade).
//!
//! Covers everything the system needs: parsing `artifacts/predictor_meta.json`,
//! reading/writing config files, and emitting experiment results + traces.
//! Full RFC 8259 value model; parser accepts the standard grammar (no
//! extensions); writer emits compact or pretty output with stable key order
//! (insertion order preserved — results files diff cleanly).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object with insertion-ordered keys (Vec of pairs keeps output stable).
    Obj(Vec<(String, Json)>),
}

#[derive(Debug)]
pub enum JsonError {
    Parse { pos: usize, msg: String },
    Type { expected: &'static str, path: String },
    Missing(String),
    Io(std::io::Error),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Parse { pos, msg } => write!(f, "json parse error at byte {pos}: {msg}"),
            JsonError::Type { expected, path } => {
                write!(f, "json type error: expected {expected} at {path}")
            }
            JsonError::Missing(key) => write!(f, "json missing key: {key}"),
            JsonError::Io(err) => write!(f, "io: {err}"),
        }
    }
}

impl std::error::Error for JsonError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            JsonError::Io(err) => Some(err),
            _ => None,
        }
    }
}

impl From<std::io::Error> for JsonError {
    fn from(err: std::io::Error) -> JsonError {
        JsonError::Io(err)
    }
}

impl Json {
    // ---- constructors ----
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut pairs) = self {
            if let Some(slot) = pairs.iter_mut().find(|(k, _)| k == key) {
                slot.1 = value.into();
            } else {
                pairs.push((key.to_string(), value.into()));
            }
        }
        self
    }

    // ---- accessors ----
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required config fields.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError::Missing(key.to_string()))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }

    /// Array of f64s (type-checked).
    pub fn f64_array(&self) -> Result<Vec<f64>, JsonError> {
        match self {
            Json::Arr(v) => v
                .iter()
                .map(|x| {
                    x.as_f64().ok_or(JsonError::Type { expected: "number", path: String::new() })
                })
                .collect(),
            _ => Err(JsonError::Type { expected: "array", path: String::new() }),
        }
    }

    // ---- serialization ----
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !pairs.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    // ---- parsing ----
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn read_file(path: &str) -> Result<Json, JsonError> {
        let text = std::fs::read_to_string(path)?;
        Json::parse(&text)
    }

    pub fn write_file(&self, path: &str) -> Result<(), JsonError> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_string_pretty())?;
        Ok(())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_nan() || x.is_infinite() {
        // JSON has no NaN/Inf; emit null (numpy's json does the same via allow_nan=False fallback).
        out.push_str("null");
    } else if x == x.trunc() && x.abs() < 1e15 {
        fmt::Write::write_fmt(out, format_args!("{}", x as i64)).unwrap();
    } else {
        fmt::Write::write_fmt(out, format_args!("{x}")).unwrap();
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32)).unwrap()
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError::Parse { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Surrogate pairs.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let combined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("bad unicode escape"))?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Advance by full UTF-8 char.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("short unicode escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}
impl From<&[f64]> for Json {
    fn from(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
    }
}
impl From<BTreeMap<String, f64>> for Json {
    fn from(m: BTreeMap<String, f64>) -> Json {
        Json::Obj(m.into_iter().map(|(k, v)| (k, Json::Num(v))).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "42", "-3.5", "1e3", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            let back = Json::parse(&v.to_string_compact()).unwrap();
            assert_eq!(v, back);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let text = r#"{"a":[1,2,{"b":null,"c":[true,false]}],"d":"x\ny","e":-0.25}"#;
        let v = Json::parse(text).unwrap();
        let back = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, back);
        let pretty = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, pretty);
    }

    #[test]
    fn object_accessors() {
        let v = Json::parse(r#"{"x": 3, "s": "str", "b": true, "arr": [1.5, 2.5]}"#).unwrap();
        assert_eq!(v.get("x").unwrap().as_f64(), Some(3.0));
        assert_eq!(v.str_or("s", "?"), "str");
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("arr").unwrap().f64_array().unwrap(), vec![1.5, 2.5]);
        assert_eq!(v.f64_or("missing", 9.0), 9.0);
        assert!(v.req("nope").is_err());
    }

    #[test]
    fn builder_pattern() {
        let v = Json::obj().set("a", 1.0).set("b", "two").set("a", 3.0);
        assert_eq!(v.get("a").unwrap().as_f64(), Some(3.0));
        assert_eq!(v.to_string_compact(), r#"{"a":3,"b":"two"}"#);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "tru", "1.2.3", "\"unterminated", "[] []"] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn integers_emit_without_decimal() {
        assert_eq!(Json::Num(5.0).to_string_compact(), "5");
        assert_eq!(Json::Num(5.5).to_string_compact(), "5.5");
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
    }

    #[test]
    fn parses_real_meta_shape() {
        let text = r#"{"model":{"d_in":32,"batch_sizes":[128,512]},"golden":{"features":[[0.1,0.2]]}}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("model").unwrap().get("d_in").unwrap().as_usize(), Some(32));
        let feats = v.get("golden").unwrap().get("features").unwrap().as_arr().unwrap();
        assert_eq!(feats[0].f64_array().unwrap(), vec![0.1, 0.2]);
    }
}
