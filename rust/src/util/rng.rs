//! Deterministic, dependency-free PRNG: SplitMix64 seeding + Xoshiro256++.
//!
//! Every stochastic component in the simulator draws from an explicitly
//! seeded stream, and streams are derived by hashing a seed *path*
//! (`derive`), so adding a new consumer never perturbs existing streams —
//! the property that makes the paper's five-seed tables reproducible
//! bit-for-bit.

/// SplitMix64 step — used for seeding and stream derivation.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Xoshiro256++ PRNG (Blackman & Vigna). Not cryptographic; fast and
/// high-quality for simulation.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed from a single u64 via SplitMix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for a named consumer.
    ///
    /// `Rng::new(seed).derive("arrivals")` and `.derive("provider")` are
    /// statistically independent and stable across code changes.
    pub fn derive(&self, label: &str) -> Rng {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325; // FNV-1a offset basis
        for b in label.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        // Mix the label hash with this stream's full state.
        let mut sm = h ^ self.s[0] ^ self.s[1].rotate_left(17) ^ self.s[2].rotate_left(31)
            ^ self.s[3].rotate_left(47);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → uniform double in [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Exponential with the given rate (mean = 1/rate).
    #[inline]
    pub fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        // 1 - f64() ∈ (0, 1] avoids ln(0).
        -(1.0 - self.f64()).ln() / rate
    }

    /// Standard normal via Box–Muller (polar form avoided for determinism
    /// of draw counts: always consumes exactly two uniforms).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal: exp(mu + sigma * N(0,1)).
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Log-uniform over [lo, hi] (both > 0).
    #[inline]
    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        debug_assert!(lo > 0.0 && hi >= lo);
        (self.range(lo.ln(), hi.ln())).exp()
    }

    /// Categorical draw over unnormalized weights; returns an index.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        debug_assert!(!weights.is_empty());
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if u < *w {
                return i;
            }
            u -= w;
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn derive_is_stable_and_independent() {
        let root = Rng::new(7);
        let mut a1 = root.derive("arrivals");
        let mut a2 = root.derive("arrivals");
        let mut b = root.derive("provider");
        assert_eq!(a1.next_u64(), a2.next_u64());
        assert_ne!(a1.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn index_unbiased_smoke() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.index(5)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(17);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn log_uniform_bounds() {
        let mut r = Rng::new(19);
        for _ in 0..10_000 {
            let x = r.log_uniform(65.0, 256.0);
            assert!((65.0..=256.0).contains(&x));
        }
    }

    #[test]
    fn categorical_proportions() {
        let mut r = Rng::new(23);
        let w = [0.5, 0.25, 0.15, 0.10];
        let mut counts = [0usize; 4];
        for _ in 0..100_000 {
            counts[r.categorical(&w)] += 1;
        }
        for (c, wi) in counts.iter().zip(w.iter()) {
            let frac = *c as f64 / 100_000.0;
            assert!((frac - wi).abs() < 0.01, "counts={counts:?}");
        }
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(29);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}
