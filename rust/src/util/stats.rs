//! Statistics substrate: exact percentiles, Welford accumulators,
//! mean ± std aggregation across seeds, and least-squares linear fit
//! (used by the latency-calibration experiment to report R²).

/// Exact percentile over a sample (linear interpolation, like
/// `numpy.percentile(..., method="linear")`). Returns `None` on empty input.
///
/// Implemented with `select_nth_unstable` (expected O(n)) rather than a
/// full sort — the overload controller's tail signal and the metrics pass
/// both sit on this (346 µs → ~20 µs on 10k samples vs the old full
/// sort; tracked by `cargo bench --bench hot_paths`).
pub fn percentile(xs: &[f64], p: f64) -> Option<f64> {
    if xs.is_empty() {
        return None;
    }
    debug_assert!((0.0..=100.0).contains(&p));
    let n = xs.len();
    if n == 1 {
        return Some(xs[0]);
    }
    let rank = p / 100.0 * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let frac = rank - lo as f64;
    let mut v: Vec<f64> = xs.to_vec();
    let cmp = |a: &f64, b: &f64| a.partial_cmp(b).unwrap();
    let (_, lo_val, right) = v.select_nth_unstable_by(lo, cmp);
    let lo_val = *lo_val;
    if frac == 0.0 || right.is_empty() {
        return Some(lo_val);
    }
    // The (lo+1)-th order statistic is the minimum of the right partition.
    let hi_val = right.iter().copied().fold(f64::INFINITY, f64::min);
    Some(lo_val * (1.0 - frac) + hi_val * frac)
}

/// Percentile over an already-sorted slice. Empty input returns NaN —
/// all-rejected runs legitimately produce empty latency vectors, and an
/// unguarded `(n - 1)` here underflowed in release builds before indexing.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Population variance (n denominator); matches numpy's default ddof=0
    /// which the paper's mean±std tables use.
    pub fn var(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// mean ± std of a slice (population std, ddof=0).
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    let mut w = Welford::new();
    for x in xs {
        w.push(*x);
    }
    (w.mean(), w.std())
}

/// Simple mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        f64::NAN
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Ordinary least squares `y = a + b x`; returns (a, b, r2).
pub fn linear_fit(x: &[f64], y: &[f64]) -> (f64, f64, f64) {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2, "need at least two points");
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let sxy: f64 = x.iter().zip(y).map(|(xi, yi)| (xi - mx) * (yi - my)).sum();
    let sxx: f64 = x.iter().map(|xi| (xi - mx) * (xi - mx)).sum();
    let b = sxy / sxx;
    let a = my - b * mx;
    let ss_tot: f64 = y.iter().map(|yi| (yi - my) * (yi - my)).sum();
    let ss_res: f64 = x
        .iter()
        .zip(y)
        .map(|(xi, yi)| {
            let e = yi - (a + b * xi);
            e * e
        })
        .sum();
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    (a, b, r2)
}

/// Exponentially weighted moving average with configurable smoothing.
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ewma { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) {
        self.value = Some(match self.value {
            None => x,
            Some(v) => self.alpha * x + (1.0 - self.alpha) * v,
        });
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }

    pub fn get_or(&self, default: f64) -> f64 {
        self.value.unwrap_or(default)
    }

    /// Overwrite the smoothed value outright (seeding if unseeded).
    /// For saturating censored evidence where averaging would understate —
    /// a timeout says the signal is *at least* this bad, not that it should
    /// be blended toward it.
    pub fn set(&mut self, x: f64) {
        self.value = Some(x);
    }

    /// Geometric decay toward `target`: `v ← target + (v − target)·factor`.
    /// No-op while unseeded. This is the *unlearning* path for censored
    /// signals — a shard that stopped completing (blackout) keeps its
    /// penalty samples forever under `push` alone, so recovery code decays
    /// the stale evidence instead of waiting for samples that never come.
    pub fn decay_toward(&mut self, target: f64, factor: f64) {
        debug_assert!((0.0..=1.0).contains(&factor));
        if let Some(v) = self.value {
            self.value = Some(target + (v - target) * factor);
        }
    }
}

/// Fixed-capacity ring buffer of recent samples; O(1) push, percentile on
/// demand. The overload controller's tail-latency signal uses this (a real
/// client would similarly window its recent completions).
#[derive(Debug, Clone)]
pub struct RecentWindow {
    cap: usize,
    buf: Vec<f64>,
    next: usize,
}

impl RecentWindow {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        RecentWindow { cap, buf: Vec::with_capacity(cap), next: 0 }
    }

    pub fn push(&mut self, x: f64) {
        if self.buf.len() < self.cap {
            self.buf.push(x);
        } else {
            self.buf[self.next] = x;
            self.next = (self.next + 1) % self.cap;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn percentile(&self, p: f64) -> Option<f64> {
        percentile(&self.buf, p)
    }

    pub fn mean(&self) -> Option<f64> {
        if self.buf.is_empty() {
            None
        } else {
            Some(self.buf.iter().sum::<f64>() / self.buf.len() as f64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), Some(1.0));
        assert_eq!(percentile(&xs, 100.0), Some(5.0));
        assert_eq!(percentile(&xs, 50.0), Some(3.0));
        assert_eq!(percentile(&xs, 25.0), Some(2.0));
        assert_eq!(percentile(&[], 50.0), None);
        assert_eq!(percentile(&[7.0], 95.0), Some(7.0));
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 95.0), Some(9.5));
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 50.0), Some(3.0));
    }

    #[test]
    fn percentile_sorted_empty_input_is_nan_not_ub() {
        // All-rejected runs produce empty latency vectors; the guard must
        // hold in release builds too (the old debug_assert! did not).
        for p in [0.0, 50.0, 95.0, 100.0] {
            assert!(percentile_sorted(&[], p).is_nan(), "p={p}");
        }
        assert_eq!(percentile_sorted(&[7.0], 95.0), 7.0);
    }

    #[test]
    fn percentile_sorted_matches_percentile() {
        let mut xs = vec![5.0, 1.0, 3.0, 2.0, 4.0, 9.5, 0.25];
        let unsorted = xs.clone();
        xs.sort_unstable_by(f64::total_cmp);
        for p in [0.0, 10.0, 37.5, 50.0, 90.0, 95.0, 100.0] {
            assert_eq!(Some(percentile_sorted(&xs, p)), percentile(&unsorted, p), "p={p}");
        }
    }

    #[test]
    fn welford_matches_direct() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for x in xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        assert!((w.std() - 2.0).abs() < 1e-12);
        assert_eq!(w.count(), 8);
    }

    #[test]
    fn mean_std_empty_is_nan() {
        let (m, s) = mean_std(&[]);
        assert!(m.is_nan() && s.is_nan());
    }

    #[test]
    fn linear_fit_exact_line() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [5.0, 7.0, 9.0, 11.0];
        let (a, b, r2) = linear_fit(&x, &y);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn linear_fit_noisy_r2_below_one() {
        let x: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|xi| 2.0 * xi + ((xi * 7.7).sin()) * 5.0).collect();
        let (_, b, r2) = linear_fit(&x, &y);
        assert!(b > 1.5 && b < 2.5);
        assert!(r2 > 0.9 && r2 < 1.0);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        assert_eq!(e.get(), None);
        e.push(10.0);
        assert_eq!(e.get(), Some(10.0));
        for _ in 0..64 {
            e.push(2.0);
        }
        assert!((e.get().unwrap() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn ewma_decay_toward_unlearns() {
        let mut e = Ewma::new(0.15);
        e.decay_toward(1.0, 0.9); // unseeded: no-op
        assert_eq!(e.get(), None);
        e.push(2.0);
        for _ in 0..10 {
            e.decay_toward(1.0, 0.9);
        }
        let v = e.get().unwrap();
        assert!(v < 1.4 && v > 1.0, "v={v}");
    }

    #[test]
    fn recent_window_wraps() {
        let mut w = RecentWindow::new(3);
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            w.push(x);
        }
        assert_eq!(w.len(), 3);
        // window now holds {3,4,5}
        assert_eq!(w.percentile(0.0), Some(3.0));
        assert_eq!(w.percentile(100.0), Some(5.0));
        assert_eq!(w.mean(), Some(4.0));
    }
}
