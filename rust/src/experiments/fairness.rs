//! T4 — Fair Queuing vs Short-Priority (paper §4.6, Table 4): allocation-
//! layer alternatives on a heavy-dominated workload (70% long/xlong),
//! reporting short/long P90 with % deltas vs FIFO and the global latency
//! standard deviation (the "uniform treatment" signal).

use anyhow::Result;

use crate::core::SloPolicy;
use crate::experiments::runner::{CellSpec, Congestion, Regime};
use crate::experiments::ExpOpts;
use crate::metrics::report::TextTable;
use crate::metrics::Aggregate;
use crate::scheduler::{SchedulerCfg, StrategyKind};
use crate::util::csvio::CsvTable;
use crate::workload::Mix;

pub const STRATEGIES: [StrategyKind; 3] =
    [StrategyKind::PacedFifo, StrategyKind::ShortPriority, StrategyKind::FairQueuing];

fn pct_delta(base: f64, x: f64) -> f64 {
    // Positive = improvement (lower latency), matching the paper's signs.
    (base - x) / base * 100.0
}

pub fn run(opts: &ExpOpts) -> Result<()> {
    let regime = Regime { mix: Mix::FairnessHeavy, congestion: Congestion::High };
    let specs: Vec<CellSpec> = STRATEGIES
        .iter()
        .map(|strategy| {
            // Pure allocation-layer comparison: no interactive bypass — every
            // class competes for the same paced send opportunities, so the
            // *allocator* is the only difference (the paper's Table 4 setting).
            let mut sched = SchedulerCfg::for_strategy(*strategy);
            sched.interactive_bypass = 0;
            // A tight client budget makes send opportunities the scarce
            // resource the allocators are fighting over (the paper's fairness
            // numbers imply near-serial service: long P90s of ~50–105 s).
            sched.max_inflight = 2;
            sched.quota_interactive = 1;
            sched.quota_heavy = 1;
            let mut spec = CellSpec::new(regime, sched, opts.n_requests);
            // Deep saturation, near-disabled give-ups: the starvation tax needs
            // room to accumulate rather than being censored by client timeouts
            // (Table 4 reports latency only). A higher per-request base cost
            // makes interactive work a non-trivial capacity share, as under the
            // paper's production-scale physics (base ≈ 3.3 s).
            spec.rate_rps = 0.75;
            spec.provider.base_ms = 2000.0;
            spec.slo = SloPolicy { timeout_factor: 20.0, ..SloPolicy::default() };
            spec
        })
        .collect();
    let all_runs = opts.sweep().run_cells(&specs, opts.seeds);
    let rows: Vec<_> = STRATEGIES
        .iter()
        .zip(&all_runs)
        .map(|(strategy, runs)| {
            let agg = Aggregate::new(runs);
            (
                *strategy,
                agg.mean_std(|m| m.short_p90_ms).0,
                agg.mean_std(|m| m.heavy_p90_ms).0,
                agg.mean_std(|m| m.global_std_ms).0,
            )
        })
        .collect();
    let (base_short, base_long) = (rows[0].1, rows[0].2);

    let mut table =
        TextTable::new(["Policy", "Short P90 (ms)", "Long P90 (ms)", "Global Stdev"]);
    let mut csv = CsvTable::new([
        "policy", "short_p90_ms", "short_delta_pct", "long_p90_ms", "long_delta_pct",
        "global_std_ms",
    ]);
    for (strategy, short, long, std) in &rows {
        let label = match strategy {
            StrategyKind::PacedFifo => "Direct (FIFO)".to_string(),
            StrategyKind::ShortPriority => "Short-Priority".to_string(),
            StrategyKind::FairQueuing => "Fair Queuing".to_string(),
            other => other.name().to_string(),
        };
        let (sd, ld) = (pct_delta(base_short, *short), pct_delta(base_long, *long));
        let fmt_with_delta = |x: f64, d: f64, base: bool| {
            if base {
                format!("{x:.0}")
            } else {
                format!("{x:.0} ({:+.0}%)", d)
            }
        };
        let is_base = *strategy == StrategyKind::PacedFifo;
        table.row([
            label.clone(),
            fmt_with_delta(*short, sd, is_base),
            fmt_with_delta(*long, ld, is_base),
            format!("{std:.0}"),
        ]);
        csv.row([
            label,
            format!("{short:.1}"),
            format!("{sd:.1}"),
            format!("{long:.1}"),
            format!("{ld:.1}"),
            format!("{std:.1}"),
        ]);
    }
    println!("\nTable 4 — Fair Queuing vs Short-Priority (heavy-dominated, 70% long/xlong)");
    println!("(positive % = improvement over FIFO; negative = overhead)");
    println!("{}", table.render());
    let path = format!("{}/fair_queuing_comparison.csv", opts.out_dir);
    csv.write_file(&path)?;
    println!("wrote {path}");
    Ok(())
}
