//! Design-choice ablations (beyond the paper's tables — see
//! `docs/EXPERIMENTS.md`): what each knob of the full stack buys.
//!
//! 1. Heavy-lane ordering: feasible-set vs FIFO vs SJF vs EDF.
//! 2. DRR congestion adaptation: adaptive vs plain weights.
//! 3. Interactive bypass headroom: 0 vs default.

use anyhow::Result;

use crate::experiments::runner::{CellSpec, Congestion, Regime};
use crate::experiments::ExpOpts;
use crate::metrics::report::{fmt_pm, fmt_rate, TextTable};
use crate::metrics::Aggregate;
use crate::scheduler::{OrderingKind, SchedulerCfg, StrategyKind};
use crate::util::csvio::CsvTable;
use crate::workload::Mix;

pub fn run(opts: &ExpOpts) -> Result<()> {
    let hh = Regime { mix: Mix::Heavy, congestion: Congestion::High };
    let bh = Regime { mix: Mix::Balanced, congestion: Congestion::High };

    let mut table = TextTable::new([
        "Ablation", "Variant", "Short P95", "Global P95", "CR", "Satisf.", "Goodput",
    ]);
    let mut csv = CsvTable::new([
        "ablation", "variant", "short_p95_mean", "global_p95_mean", "cr_mean",
        "satisfaction_mean", "goodput_mean",
    ]);
    // Build the whole variant list first so one sweep covers all three
    // ablations; row order matches the previous serial emission.
    let mut labels: Vec<(&str, &str)> = Vec::new();
    let mut specs: Vec<CellSpec> = Vec::new();

    // 1. Heavy-lane ordering under heavy/high.
    for (name, kind) in [
        ("feasible_set", OrderingKind::FeasibleSet),
        ("fifo", OrderingKind::Fifo),
        ("sjf", OrderingKind::Sjf),
        ("edf", OrderingKind::Edf),
    ] {
        let mut sched = SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc);
        sched.heavy_ordering = kind;
        labels.push(("heavy ordering", name));
        specs.push(CellSpec::new(hh, sched, opts.n_requests));
    }

    // 2. DRR adaptation under balanced/high. Measured with the bypass off:
    //    the interactive lane must win its share through *allocation*, which
    //    is exactly where congestion-scaled weights act.
    for (name, strategy) in
        [("adaptive", StrategyKind::AdaptiveDrr), ("plain", StrategyKind::PlainDrr)]
    {
        let mut sched = SchedulerCfg::for_strategy(strategy);
        sched.interactive_bypass = 0;
        labels.push(("drr weights", name));
        specs.push(CellSpec::new(bh, sched, opts.n_requests));
    }

    // 3. Interactive bypass headroom under heavy/high.
    for bypass in [0usize, 4] {
        let mut sched = SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc);
        sched.interactive_bypass = bypass;
        labels.push(("interactive bypass", if bypass == 0 { "off" } else { "+4 slots" }));
        specs.push(CellSpec::new(hh, sched, opts.n_requests));
    }

    let all_runs = opts.sweep().run_cells(&specs, opts.seeds);
    for ((ablation, variant), runs) in labels.iter().zip(all_runs) {
        let agg = Aggregate::new(&runs);
        let short = agg.mean_std(|m| m.short_p95_ms);
        let global = agg.mean_std(|m| m.global_p95_ms);
        let cr = agg.mean_std(|m| m.completion_rate);
        let sat = agg.mean_std(|m| m.satisfaction);
        let good = agg.mean_std(|m| m.goodput_rps);
        table.row([
            ablation.to_string(),
            variant.to_string(),
            fmt_pm(short),
            fmt_pm(global),
            fmt_rate(cr),
            fmt_rate(sat),
            format!("{:.1}±{:.1}", good.0, good.1),
        ]);
        csv.row([
            ablation.to_string(),
            variant.to_string(),
            format!("{:.1}", short.0),
            format!("{:.1}", global.0),
            format!("{:.4}", cr.0),
            format!("{:.4}", sat.0),
            format!("{:.3}", good.0),
        ]);
    }

    println!("\nAblations — what each design choice buys (extension beyond the paper)");
    println!("{}", table.render());
    println!("notes: adaptive vs plain DRR weights are indistinguishable at this");
    println!("quantum/cost ratio (one 400-token grant always covers an interactive");
    println!("head of ~30 tokens, so the boost never changes a decision) — the");
    println!("bypass headroom is the operative short-tail protection in this mock;");
    println!("feasible-set ordering buys its margin on the *global* tail.");
    let path = format!("{}/ablation_summary.csv", opts.out_dir);
    csv.write_file(&path)?;
    println!("wrote {path}");
    Ok(())
}
