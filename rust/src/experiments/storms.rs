//! Storms experiment: adversarial arrival processes × provider faults ×
//! the client-side resilience stack (failover routing + retry backoff).
//!
//! Every other table assumes a well-behaved Poisson front door and a fleet
//! that never falters. This grid turns both knobs at once and asks what the
//! full stack buys when traffic and providers misbehave together:
//!
//! * **Scenario** — `flash_crowd` (8× spikes on a Poisson base),
//!   `diurnal` (sinusoidal load with 80% swing), `session`
//!   (session-affinity streams pinned by `hash_affinity`), `retry_storm`
//!   (a mid-run half-speed brownout on shard 0 with client retries armed),
//!   and `blackout` (shard 0 dark from t=0 for longer than any timeout
//!   budget — the censored-tail failover's live-fire test).
//! * **Condition** — `full` (tail-based failover on, retries with
//!   exponential backoff and a budget of 4) vs `ablation` (failover off,
//!   retries disabled: the pre-storms scheduler).
//! * **Congestion** — the paper's medium and high bands.
//!
//! Cells run two tenants on a four-shard fleet through [`driver::
//! run_tenants`], so the whole grid rides both CI determinism diffs:
//! byte-identical across `--jobs` (the sweep fan-out) *and* across
//! `--partitions` (fault plans here are extension-only, so the partitioned
//! loop's lookahead floor stays valid and the parallel path really runs).
//!
//! The CSV adds the two storm diagnostics to the usual quality columns:
//! `retries_scheduled` (client re-entries, zero whenever retries are off)
//! and `faulted_shard_ms` (service-time extension injected by the fault
//! plan, zero for fault-free scenarios).

use anyhow::Result;

use crate::experiments::runner::{Congestion, Regime};
use crate::experiments::ExpOpts;
use crate::metrics::report::{fmt_rate, TextTable};
use crate::metrics::{Aggregate, RunMetrics};
use crate::predictor::InfoLevel;
use crate::provider::fault::FaultPlan;
use crate::provider::pool::PoolCfg;
use crate::provider::ProviderCfg;
use crate::scheduler::{RetryCfg, SchedulerCfg, ShardPolicy, StrategyKind};
use crate::sim::driver::{self, TenantSpec};
use crate::util::csvio::CsvTable;
use crate::util::stats::mean;
use crate::workload::{ArrivalSpec, Mix, WorkloadSpec};

/// Tenants sharing the fleet in every cell (the smallest shape that makes
/// the grid a real multi-tenant partitioned run).
const TENANTS: usize = 2;

/// Shards in the fleet. Faulted scenarios darken shard 0 and leave three
/// survivors, so the surviving capacity still covers the offered load.
const SHARDS: usize = 4;

/// Retry budget for the `full` condition: enough attempts to outlive a
/// brownout window, few enough that storms terminate fast.
const RETRY_BUDGET: u32 = 4;

/// Storm scenario: which arrival process drives the front door and which
/// fault plan (if any) hits the fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Scenario {
    /// Poisson base with 8× arrival-rate spikes.
    FlashCrowd,
    /// Sinusoidal mean rate, 80% swing around the base.
    Diurnal,
    /// Session streams (4 turns, 800 ms think time) pinned to shards by
    /// `hash_affinity` — the cache-locality routing regime.
    Session,
    /// Half-speed brownout on shard 0 over a mid-run window; client
    /// retries (when armed) re-enter through the backoff ladder.
    RetryStorm,
    /// Shard 0 dark from t=0, longer than every timeout budget: stranded
    /// in-flight work can only be rescued by failover + retry.
    Blackout,
}

impl Scenario {
    const ALL: [Scenario; 5] = [
        Scenario::FlashCrowd,
        Scenario::Diurnal,
        Scenario::Session,
        Scenario::RetryStorm,
        Scenario::Blackout,
    ];

    fn name(self) -> &'static str {
        match self {
            Scenario::FlashCrowd => "flash_crowd",
            Scenario::Diurnal => "diurnal",
            Scenario::Session => "session",
            Scenario::RetryStorm => "retry_storm",
            Scenario::Blackout => "blackout",
        }
    }

    fn arrivals(self) -> ArrivalSpec {
        match self {
            Scenario::FlashCrowd => {
                ArrivalSpec::FlashCrowd { spike_factor: 8.0, every_ms: 30_000.0, spike_ms: 2_000.0 }
            }
            Scenario::Diurnal => ArrivalSpec::Diurnal { period_ms: 60_000.0, depth: 0.8 },
            Scenario::Session => ArrivalSpec::Session { turns: 4, think_ms: 800.0 },
            Scenario::RetryStorm | Scenario::Blackout => ArrivalSpec::Poisson,
        }
    }

    /// Deterministic fault schedule. Both plans are extension-only
    /// (speeds ≤ 1), so the partitioned loop's lookahead floor holds and
    /// these cells exercise the parallel path, not the serial fallback.
    fn faults(self) -> FaultPlan {
        match self {
            Scenario::RetryStorm => FaultPlan::default()
                .brownout(0, 2_000.0, 30_000.0, 0.35)
                .expect("static plan is valid"),
            Scenario::Blackout => FaultPlan::default()
                .blackout(0, 0.0, 600_000.0)
                .expect("static plan is valid"),
            _ => FaultPlan::default(),
        }
    }

    /// Session streams exercise affinity pinning; everything else routes
    /// by instantaneous load.
    fn policy(self) -> ShardPolicy {
        match self {
            Scenario::Session => ShardPolicy::HashAffinity,
            _ => ShardPolicy::LeastInflight,
        }
    }
}

/// Resilience condition: the full stack vs the pre-storms scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Condition {
    /// Tail-based failover routing + client retries with backoff.
    Full,
    /// Failover off, retries disabled — routing trusts every shard
    /// forever and a timed-out request is simply lost.
    Ablation,
}

impl Condition {
    const ALL: [Condition; 2] = [Condition::Full, Condition::Ablation];

    fn name(self) -> &'static str {
        match self {
            Condition::Full => "full",
            Condition::Ablation => "ablation",
        }
    }
}

/// One grid cell.
#[derive(Debug, Clone)]
struct StormCell {
    scenario: Scenario,
    condition: Condition,
    congestion: Congestion,
}

impl StormCell {
    fn rate_rps(&self) -> f64 {
        Regime { mix: Mix::Balanced, congestion: self.congestion }.rate_rps()
    }

    fn sched(&self) -> SchedulerCfg {
        let mut sched = SchedulerCfg::for_strategy(StrategyKind::AdaptiveDrr);
        sched.shards.policy = self.scenario.policy();
        sched.shards.failover = self.condition == Condition::Full;
        sched.retry = match self.condition {
            Condition::Full => RetryCfg::new(RETRY_BUDGET, 250.0, 2_000.0),
            Condition::Ablation => RetryCfg::disabled(),
        };
        sched
    }

    fn specs(&self, n_requests: usize) -> Vec<TenantSpec> {
        let per_rate = self.rate_rps() / TENANTS as f64;
        driver::split_requests(n_requests, TENANTS)
            .into_iter()
            .map(|per_n| TenantSpec {
                workload: WorkloadSpec::new(Mix::Balanced, per_n, per_rate)
                    .with_arrivals(self.scenario.arrivals()),
                sched: self.sched(),
                info: InfoLevel::Coarse,
                noise: 0.0,
            })
            .collect()
    }
}

/// Per-seed result: per-tenant quality metrics plus the fleet-wide storm
/// diagnostics.
struct SeedOut {
    tenants: Vec<RunMetrics>,
    retries_scheduled: u64,
    faulted_shard_ms: f64,
}

fn run_cell_seed(cell: &StormCell, n_requests: usize, seed: u64) -> SeedOut {
    let pool = PoolCfg::split(ProviderCfg::default(), SHARDS)
        .with_faults(cell.scenario.faults());
    let out = driver::run_tenants(&cell.specs(n_requests), &pool, seed);
    SeedOut {
        tenants: out.tenants.into_iter().map(|t| t.metrics).collect(),
        retries_scheduled: out.diagnostics.retries_scheduled,
        faulted_shard_ms: out.diagnostics.faulted_shard_ms,
    }
}

/// The grid: scenario × condition × congestion.
fn grid() -> Vec<StormCell> {
    let mut cells = Vec::new();
    for scenario in Scenario::ALL {
        for condition in Condition::ALL {
            for congestion in [Congestion::Medium, Congestion::High] {
                cells.push(StormCell { scenario, condition, congestion });
            }
        }
    }
    cells
}

pub fn run(opts: &ExpOpts) -> Result<()> {
    let cells = grid();
    let all: Vec<Vec<SeedOut>> = opts
        .sweep()
        .map_cells(cells.len(), opts.seeds, |c, s| run_cell_seed(&cells[c], opts.n_requests, s));

    let mut table = TextTable::new([
        "Scenario",
        "Condition",
        "Congestion",
        "CR",
        "Worst P95",
        "Timeouts",
        "Retries",
        "Faulted (s)",
    ]);
    let mut csv = CsvTable::new([
        "scenario",
        "condition",
        "congestion",
        "arrivals",
        "rate_rps",
        "requests",
        "cr_mean",
        "cr_std",
        "worst_p95_mean",
        "goodput_mean",
        "goodput_std",
        "timeouts_mean",
        "rejects_mean",
        "retries_scheduled_mean",
        "faulted_shard_ms_mean",
    ]);
    for (cell, runs) in cells.iter().zip(&all) {
        // Fleet-level completion: sum over tenants, mean±std over seeds.
        let fleet: Vec<RunMetrics> = runs
            .iter()
            .map(|r| {
                let mut acc = r.tenants[0].clone();
                for t in &r.tenants[1..] {
                    acc.n_offered += t.n_offered;
                    acc.n_completed += t.n_completed;
                    acc.n_rejected += t.n_rejected;
                    acc.n_timed_out += t.n_timed_out;
                    acc.goodput_rps += t.goodput_rps;
                }
                acc.completion_rate = if acc.n_offered > 0 {
                    acc.n_completed as f64 / acc.n_offered as f64
                } else {
                    0.0
                };
                acc
            })
            .collect();
        let agg = Aggregate::new(&fleet);
        let cr = agg.mean_std(|m| m.completion_rate);
        let good = agg.mean_std(|m| m.goodput_rps);
        let timeouts = agg.mean_std(|m| m.n_timed_out as f64);
        let rejects = agg.mean_std(|m| m.n_rejected as f64);
        // Worst-tenant tail per seed (NaN when no tenant observed one),
        // then the per-seed mean — the isolation-under-storm readout.
        let worst_p95 = mean(
            &runs
                .iter()
                .map(|r| {
                    r.tenants
                        .iter()
                        .map(|t| t.global_p95_ms)
                        .filter(|p| p.is_finite())
                        .fold(f64::NAN, f64::max)
                })
                .collect::<Vec<f64>>(),
        );
        let retries = mean(&runs.iter().map(|r| r.retries_scheduled as f64).collect::<Vec<f64>>());
        let faulted = mean(&runs.iter().map(|r| r.faulted_shard_ms).collect::<Vec<f64>>());
        table.row([
            cell.scenario.name().to_string(),
            cell.condition.name().to_string(),
            cell.congestion.name().to_string(),
            fmt_rate(cr),
            format!("{worst_p95:.1}"),
            format!("{:.1}", timeouts.0),
            format!("{retries:.1}"),
            format!("{:.1}", faulted / 1e3),
        ]);
        csv.row([
            cell.scenario.name().to_string(),
            cell.condition.name().to_string(),
            cell.congestion.name().to_string(),
            cell.scenario.arrivals().name().to_string(),
            format!("{:.1}", cell.rate_rps()),
            opts.n_requests.to_string(),
            format!("{:.4}", cr.0),
            format!("{:.4}", cr.1),
            format!("{worst_p95:.1}"),
            format!("{:.3}", good.0),
            format!("{:.3}", good.1),
            format!("{:.1}", timeouts.0),
            format!("{:.1}", rejects.0),
            format!("{retries:.1}"),
            format!("{faulted:.1}"),
        ]);
    }
    println!("\nStorms — arrival storms × provider faults × resilience stack (mean over seeds)");
    println!("{}", table.render());
    let path = format!("{}/storms.csv", opts.out_dir);
    csv.write_file(&path)?;
    println!("wrote {path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_shape_is_stable() {
        let cells = grid();
        // 5 scenarios × 2 conditions × 2 congestion bands.
        assert_eq!(cells.len(), 20);
        assert!(cells
            .iter()
            .filter(|c| c.condition == Condition::Ablation)
            .all(|c| !c.sched().retry.enabled() && !c.sched().shards.failover));
    }

    #[test]
    fn cell_runner_is_deterministic() {
        let cell = StormCell {
            scenario: Scenario::RetryStorm,
            condition: Condition::Full,
            congestion: Congestion::High,
        };
        let a = run_cell_seed(&cell, 40, 1);
        let b = run_cell_seed(&cell, 40, 1);
        assert_eq!(a.retries_scheduled, b.retries_scheduled);
        assert_eq!(a.faulted_shard_ms.to_bits(), b.faulted_shard_ms.to_bits());
        for (x, y) in a.tenants.iter().zip(&b.tenants) {
            assert_eq!(x.n_completed, y.n_completed);
            assert_eq!(x.global_p95_ms.to_bits(), y.global_p95_ms.to_bits());
        }
    }

    #[test]
    fn clean_scenarios_report_zero_storm_diagnostics() {
        // Fault-free scenario + ablation condition = exactly the pre-storms
        // scheduler: both storm counters must sit at hard zero.
        let cell = StormCell {
            scenario: Scenario::FlashCrowd,
            condition: Condition::Ablation,
            congestion: Congestion::Medium,
        };
        let out = run_cell_seed(&cell, 40, 2);
        assert_eq!(out.retries_scheduled, 0);
        assert_eq!(out.faulted_shard_ms, 0.0);
    }

    #[test]
    fn blackout_full_stack_beats_the_ablation() {
        // The acceptance contrast at the experiment level: with shard 0
        // dark past every timeout budget, the full stack re-routes and
        // retries its casualties while the ablation keeps losing work to
        // the dead shard.
        let mk = |condition| StormCell {
            scenario: Scenario::Blackout,
            condition,
            congestion: Congestion::Medium,
        };
        let full = run_cell_seed(&mk(Condition::Full), 40, 3);
        let ablated = run_cell_seed(&mk(Condition::Ablation), 40, 3);
        let done = |r: &SeedOut| r.tenants.iter().map(|t| t.n_completed).sum::<usize>();
        assert!(full.retries_scheduled > 0, "blackout casualties must re-enter via retry");
        assert!(full.faulted_shard_ms > 0.0, "the blackout must actually bite");
        assert!(
            done(&full) > done(&ablated),
            "full stack {} must complete more than the ablation {}",
            done(&full),
            done(&ablated)
        );
    }
}
