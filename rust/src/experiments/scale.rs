//! Rate-scaling experiment: offered-rate multiplier × heavy-class ordering
//! × congestion regime, with steady-state queue depth as a first-class
//! column.
//!
//! The classic tables hold the arrival rate in the paper's bands, where
//! live queue depth stays modest and a per-release O(depth) scan is cheap.
//! This grid asks the *rate*-scaling question instead: multiply the offered
//! rate (and the request count, so the model-time horizon is constant) by
//! {1×, 4×, 16×} and watch what deep steady-state queues do to each
//! ordering policy. The strategy is `AdaptiveDrr` (full allocation +
//! ordering stack, no overload shedding), so queues are free to deepen with
//! rate — the regime PR 5's incremental ordering indexes exist for; the
//! per-release *cost* side of the story is gated by `bbsched bench --depth`.
//!
//! Congestion axes: `balanced/high` (the paper's high band) and
//! `heavy/high` (heavy-dominated traffic, the class whose ordering is
//! scored).
//!
//! Fanned out on [`ParallelSweep`], so `scale.csv` is byte-identical for
//! any `--jobs` value (the CI determinism gate covers it via `exp all`).

use anyhow::Result;

use crate::experiments::runner::{Congestion, Regime};
use crate::experiments::ExpOpts;
use crate::metrics::report::{fmt_pm, fmt_rate, TextTable};
use crate::metrics::{Aggregate, RunMetrics};
use crate::predictor::{InfoLevel, LadderSource};
use crate::provider::ProviderCfg;
use crate::scheduler::{OrderingKind, SchedulerCfg, StrategyKind};
use crate::sim::driver;
use crate::util::csvio::CsvTable;
use crate::util::rng::Rng;
use crate::util::stats::mean;
use crate::workload::{Mix, WorkloadSpec};

/// Offered-rate multipliers on the regime's base rate.
const MULTS: [f64; 3] = [1.0, 4.0, 16.0];

/// One grid cell.
#[derive(Debug, Clone)]
struct ScaleCell {
    regime: Regime,
    mult: f64,
    ordering: OrderingKind,
}

/// Per-seed result: run metrics + (mean, peak) scheduler queue depth.
fn run_cell_seed(cell: &ScaleCell, n_base: usize, seed: u64) -> (RunMetrics, f64, usize) {
    // Requests scale with the rate so every cell covers the same
    // model-time horizon — depth differences are rate effects, not
    // run-length effects.
    let n = (n_base as f64 * cell.mult) as usize;
    let rate = cell.regime.rate_rps() * cell.mult;
    let requests = WorkloadSpec::new(cell.regime.mix, n, rate).generate(seed);
    let root = Rng::new(seed ^ 0x5EED_50_u64);
    let mut src = LadderSource::new(InfoLevel::Coarse, root.derive("priors"));
    let mut sched = SchedulerCfg::for_strategy(StrategyKind::AdaptiveDrr);
    sched.heavy_ordering = cell.ordering;
    let out = driver::run(&requests, &mut src, sched, ProviderCfg::default(), seed);
    (out.metrics, out.diagnostics.mean_queue_depth, out.diagnostics.peak_queue_depth)
}

/// The grid: regime × rate multiplier × heavy-class ordering.
fn grid() -> Vec<ScaleCell> {
    let mut cells = Vec::new();
    for regime in [
        Regime { mix: Mix::Balanced, congestion: Congestion::High },
        Regime { mix: Mix::Heavy, congestion: Congestion::High },
    ] {
        for mult in MULTS {
            for ordering in OrderingKind::ALL {
                cells.push(ScaleCell { regime, mult, ordering });
            }
        }
    }
    cells
}

pub fn run(opts: &ExpOpts) -> Result<()> {
    let cells = grid();
    let all: Vec<Vec<(RunMetrics, f64, usize)>> = opts
        .sweep()
        .map_cells(cells.len(), opts.seeds, |c, s| run_cell_seed(&cells[c], opts.n_requests, s));

    let mut table = TextTable::new([
        "Regime",
        "Rate",
        "Ordering",
        "Depth (mean)",
        "Depth (peak)",
        "CR",
        "Short P95",
        "Global P95",
        "Goodput",
    ]);
    let mut csv = CsvTable::new([
        "regime",
        "rate_mult",
        "ordering",
        "rate_rps",
        "requests",
        "depth_mean",
        "depth_peak_mean",
        "cr_mean",
        "cr_std",
        "short_p95_mean",
        "short_p95_std",
        "global_p95_mean",
        "global_p95_std",
        "goodput_mean",
        "goodput_std",
        "timeouts_mean",
    ]);
    for (cell, runs) in cells.iter().zip(&all) {
        let metrics: Vec<RunMetrics> = runs.iter().map(|(m, _, _)| m.clone()).collect();
        let depths: Vec<f64> = runs.iter().map(|(_, d, _)| *d).collect();
        let peaks: Vec<f64> = runs.iter().map(|(_, _, p)| *p as f64).collect();
        let agg = Aggregate::new(&metrics);
        let cr = agg.mean_std(|m| m.completion_rate);
        let short = agg.mean_std(|m| m.short_p95_ms);
        let global = agg.mean_std(|m| m.global_p95_ms);
        let good = agg.mean_std(|m| m.goodput_rps);
        let timeouts = agg.mean_std(|m| m.n_timed_out as f64);
        let depth = mean(&depths);
        let peak = mean(&peaks);
        let rate = cell.regime.rate_rps() * cell.mult;
        let n = (opts.n_requests as f64 * cell.mult) as usize;
        table.row([
            cell.regime.name(),
            format!("{:.0}x", cell.mult),
            cell.ordering.name().to_string(),
            format!("{depth:.1}"),
            format!("{peak:.0}"),
            fmt_rate(cr),
            fmt_pm(short),
            fmt_pm(global),
            format!("{:.1}±{:.1}", good.0, good.1),
        ]);
        csv.row([
            cell.regime.name(),
            format!("{:.0}", cell.mult),
            cell.ordering.name().to_string(),
            format!("{rate:.1}"),
            n.to_string(),
            format!("{depth:.2}"),
            format!("{peak:.1}"),
            format!("{:.4}", cr.0),
            format!("{:.4}", cr.1),
            format!("{:.1}", short.0),
            format!("{:.1}", short.1),
            format!("{:.1}", global.0),
            format!("{:.1}", global.1),
            format!("{:.3}", good.0),
            format!("{:.3}", good.1),
            format!("{:.1}", timeouts.0),
        ]);
    }
    println!("\nRate scaling — offered-rate multiplier × heavy ordering (mean±std over seeds)");
    println!("{}", table.render());
    let path = format!("{}/scale.csv", opts.out_dir);
    csv.write_file(&path)?;
    println!("wrote {path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_shape_is_stable() {
        let cells = grid();
        // 2 regimes × 3 multipliers × 4 orderings.
        assert_eq!(cells.len(), 24);
        assert!(cells.iter().all(|c| MULTS.contains(&c.mult)));
    }

    #[test]
    fn cell_runner_is_deterministic_and_depth_scales_with_rate() {
        let cell = |mult: f64| ScaleCell {
            regime: Regime { mix: Mix::Heavy, congestion: Congestion::High },
            mult,
            ordering: OrderingKind::FeasibleSet,
        };
        let (a, depth_a, peak_a) = run_cell_seed(&cell(4.0), 30, 1);
        let (b, depth_b, peak_b) = run_cell_seed(&cell(4.0), 30, 1);
        assert_eq!(a.n_completed, b.n_completed);
        assert_eq!(depth_a.to_bits(), depth_b.to_bits());
        assert_eq!(peak_a, peak_b);
        // Higher offered rate builds deeper steady-state queues.
        let (_, depth_lo, _) = run_cell_seed(&cell(1.0), 30, 1);
        assert!(
            depth_a > depth_lo,
            "4x rate must deepen the queue: {depth_a:.2} vs {depth_lo:.2}"
        );
    }
}
