//! §4.9 — overload-threshold sensitivity: perturb defer/reject cutoffs and
//! backoff by ±20% from baseline (Final OLC, coarse priors fixed) and check
//! joint-metric stability.

use anyhow::Result;

use crate::experiments::runner::{CellSpec, Regime};
use crate::experiments::ExpOpts;
use crate::metrics::report::{fmt_pm, fmt_rate, TextTable};
use crate::metrics::Aggregate;
use crate::scheduler::{SchedulerCfg, StrategyKind};
use crate::util::csvio::CsvTable;

pub const FACTORS: [f64; 3] = [0.8, 1.0, 1.2];

pub fn run(opts: &ExpOpts) -> Result<()> {
    let mut table = TextTable::new([
        "Regime", "Thresholds", "Short P95", "CR", "Satisf.", "Goodput", "Rejects", "Defers",
    ]);
    let mut csv = CsvTable::new([
        "regime", "factor", "short_p95_mean", "cr_mean", "satisfaction_mean", "goodput_mean",
        "rejects_mean", "defers_mean",
    ]);
    // Track max relative drift vs baseline for the summary line.
    let mut max_sat_drift: f64 = 0.0;
    let mut max_short_drift: f64 = 0.0;
    let mut min_cr: f64 = 1.0;
    let mut cells = Vec::new();
    for regime in Regime::GRID {
        for factor in FACTORS {
            cells.push((regime, factor));
        }
    }
    let specs: Vec<CellSpec> = cells
        .iter()
        .map(|(regime, factor)| {
            let mut sched = SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc);
            sched.overload = sched.overload.perturbed(*factor);
            CellSpec::new(*regime, sched, opts.n_requests)
        })
        .collect();
    let all_runs = opts.sweep().run_cells(&specs, opts.seeds);
    let mut results = cells.into_iter().zip(all_runs);
    for regime in Regime::GRID {
        let mut baseline: Option<(f64, f64)> = None; // (short, sat)
        for factor in FACTORS {
            let ((cell_regime, cell_factor), runs) = results.next().expect("one result per cell");
            debug_assert!(cell_regime == regime && cell_factor == factor);
            let agg = Aggregate::new(&runs);
            let short = agg.mean_std(|m| m.short_p95_ms);
            let cr = agg.mean_std(|m| m.completion_rate);
            let sat = agg.mean_std(|m| m.satisfaction);
            let good = agg.mean_std(|m| m.goodput_rps);
            let rej = agg.mean_std(|m| m.rejects_total as f64);
            let def = agg.mean_std(|m| m.defers_total as f64);
            if factor == 1.0 {
                baseline = Some((short.0, sat.0));
            }
            if let Some((bs, bsat)) = baseline {
                if factor != 1.0 {
                    max_short_drift = max_short_drift.max(((short.0 - bs) / bs).abs());
                    max_sat_drift = max_sat_drift.max(((sat.0 - bsat) / bsat.max(1e-9)).abs());
                }
            }
            min_cr = min_cr.min(cr.0);
            let label = if factor == 1.0 { "baseline".to_string() } else { format!("{:+.0}%", (factor - 1.0) * 100.0) };
            table.row([
                regime.name(),
                label.clone(),
                fmt_pm(short),
                fmt_rate(cr),
                fmt_rate(sat),
                format!("{:.1}±{:.1}", good.0, good.1),
                format!("{:.1}", rej.0),
                format!("{:.1}", def.0),
            ]);
            csv.row([
                regime.name(),
                format!("{factor:.1}"),
                format!("{:.1}", short.0),
                format!("{:.4}", cr.0),
                format!("{:.4}", sat.0),
                format!("{:.3}", good.0),
                format!("{:.1}", rej.0),
                format!("{:.1}", def.0),
            ]);
        }
    }
    println!("\n§4.9 — threshold sensitivity (±20% on cutoffs + backoff)");
    println!("{}", table.render());
    println!(
        "max drift vs baseline: satisfaction {:.1}%, short P95 {:.1}%; min CR {:.2} \
         (paper: ≤4.2%, ≤5.9%, CR ≥0.99)",
        max_sat_drift * 100.0,
        max_short_drift * 100.0,
        min_cr
    );
    let path = format!("{}/threshold_sensitivity.csv", opts.out_dir);
    csv.write_file(&path)?;
    println!("wrote {path}");
    Ok(())
}
