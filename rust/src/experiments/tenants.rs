//! Multi-tenant fleet-sharing experiment: tenant count × tenant mix ×
//! shard count × congestion, on the full scheduler stack.
//!
//! Every other table runs ONE client scheduler against the fleet; this grid
//! asks the fleet-sharing question instead: with the same total offered
//! load split across M independent client schedulers — each seeing only its
//! own slice of the black box — how well does per-tenant SLO isolation
//! hold, and what does a single heavy tenant cost its neighbors? The
//! 1-tenant cells are the control group: they run the exact single-client
//! physics every other table uses (and are byte-identical to `run_pool`
//! by the driver's bit-compat contract).
//!
//! Tenant mixes:
//! * `symmetric` — M identical tenants (balanced mix, rate/M each);
//! * `one_heavy` — tenant 0 switches to the heavy mix at the same rate
//!   share: the noisy-neighbor regime.
//!
//! The CSV reports one row per (cell, tenant) with per-tenant P95,
//! deadline satisfaction, and goodput columns — the isolation metrics.
//! Fanned out on [`ParallelSweep`], so the CSV is byte-identical for any
//! `--jobs` value (the CI determinism gate covers it).

use anyhow::Result;

use crate::experiments::runner::{Congestion, Regime};
use crate::experiments::ExpOpts;
use crate::metrics::report::{fmt_rate, TextTable};
use crate::metrics::{Aggregate, RunMetrics};
use crate::predictor::InfoLevel;
use crate::provider::pool::PoolCfg;
use crate::provider::ProviderCfg;
use crate::scheduler::{SchedulerCfg, StrategyKind};
use crate::sim::driver::{self, TenantSpec};
use crate::util::csvio::CsvTable;
use crate::workload::{Mix, WorkloadSpec};

/// One grid cell.
#[derive(Debug, Clone)]
struct TenantCell {
    congestion: Congestion,
    rate_rps: f64,
    shards: usize,
    tenants: usize,
    /// `one_heavy` mix when true (tenant 0 runs the heavy mix).
    one_heavy: bool,
}

impl TenantCell {
    fn mix_name(&self) -> &'static str {
        if self.one_heavy {
            "one_heavy"
        } else {
            "symmetric"
        }
    }

    /// Per-tenant specs: total offered load split across tenants with the
    /// fleet-wide total conserved (`driver::split_requests`).
    fn specs(&self, n_requests: usize) -> Vec<TenantSpec> {
        let per_rate = self.rate_rps / self.tenants as f64;
        driver::split_requests(n_requests, self.tenants)
            .into_iter()
            .enumerate()
            .map(|(t, per_n)| {
                let mix = if self.one_heavy && t == 0 { Mix::Heavy } else { Mix::Balanced };
                TenantSpec {
                    workload: WorkloadSpec::new(mix, per_n, per_rate),
                    sched: SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc),
                    info: InfoLevel::Coarse,
                    noise: 0.0,
                }
            })
            .collect()
    }
}

/// Per-seed result: one `RunMetrics` per tenant.
fn run_cell_seed(cell: &TenantCell, n_requests: usize, seed: u64) -> Vec<RunMetrics> {
    let pool = PoolCfg::split(ProviderCfg::default(), cell.shards);
    let out = driver::run_tenants(&cell.specs(n_requests), &pool, seed);
    out.tenants.into_iter().map(|t| t.metrics).collect()
}

/// The grid: per (congestion, shard count), a 1-tenant control cell plus
/// tenant counts {2, 8} × mixes {symmetric, one_heavy}.
fn grid() -> Vec<TenantCell> {
    let mut cells = Vec::new();
    for congestion in [Congestion::Medium, Congestion::High] {
        let rate_rps = Regime { mix: Mix::Balanced, congestion }.rate_rps();
        for shards in [1usize, 4] {
            cells.push(TenantCell { congestion, rate_rps, shards, tenants: 1, one_heavy: false });
            for tenants in [2usize, 8] {
                for one_heavy in [false, true] {
                    cells.push(TenantCell { congestion, rate_rps, shards, tenants, one_heavy });
                }
            }
        }
    }
    cells
}

pub fn run(opts: &ExpOpts) -> Result<()> {
    let cells = grid();
    // all[cell][seed] = one RunMetrics per tenant.
    let all: Vec<Vec<Vec<RunMetrics>>> = opts
        .sweep()
        .map_cells(cells.len(), opts.seeds, |c, s| run_cell_seed(&cells[c], opts.n_requests, s));

    let mut table = TextTable::new([
        "Congestion",
        "Shards",
        "Tenants",
        "Mix",
        "Worst short P95",
        "Worst satisfaction",
        "Fleet goodput",
        "T0 goodput",
    ]);
    let mut csv = CsvTable::new([
        "congestion",
        "shards",
        "tenants",
        "mix",
        "tenant",
        "role",
        "short_p95_mean",
        "short_p95_std",
        "global_p95_mean",
        "global_p95_std",
        "cr_mean",
        "satisfaction_mean",
        "satisfaction_std",
        "goodput_mean",
        "goodput_std",
        "rejects_mean",
        "defers_mean",
    ]);
    for (cell, runs) in cells.iter().zip(&all) {
        // Regroup seed-major → tenant-major: per_tenant[t][seed].
        let per_tenant: Vec<Vec<RunMetrics>> = (0..cell.tenants)
            .map(|t| runs.iter().map(|seed_run| seed_run[t].clone()).collect())
            .collect();
        // NaN until some tenant has a finite short tail (a tenant that
        // completes no shorts yields NaN percentiles): a cell where every
        // tenant's short tail is unobserved must print NaN, not a
        // best-possible-looking 0.0.
        let mut worst_short: f64 = f64::NAN;
        let mut worst_sat: f64 = f64::INFINITY;
        let mut fleet_goodput: f64 = 0.0;
        let mut t0_goodput: f64 = 0.0;
        for (t, tenant_runs) in per_tenant.iter().enumerate() {
            let agg = Aggregate::new(tenant_runs);
            let short = agg.mean_std(|m| m.short_p95_ms);
            let global = agg.mean_std(|m| m.global_p95_ms);
            let cr = agg.mean_std(|m| m.completion_rate);
            let sat = agg.mean_std(|m| m.satisfaction);
            let good = agg.mean_std(|m| m.goodput_rps);
            let rejects = agg.mean_std(|m| m.rejects_total as f64);
            let defers = agg.mean_std(|m| m.defers_total as f64);
            if short.0.is_finite() {
                // f64::max ignores a NaN accumulator, so the first finite
                // sample replaces the NaN sentinel.
                worst_short = worst_short.max(short.0);
            }
            worst_sat = worst_sat.min(sat.0);
            fleet_goodput += good.0;
            if t == 0 {
                t0_goodput = good.0;
            }
            let role = if cell.one_heavy && t == 0 { "heavy" } else { "standard" };
            csv.row([
                cell.congestion.name().to_string(),
                cell.shards.to_string(),
                cell.tenants.to_string(),
                cell.mix_name().to_string(),
                t.to_string(),
                role.to_string(),
                format!("{:.1}", short.0),
                format!("{:.1}", short.1),
                format!("{:.1}", global.0),
                format!("{:.1}", global.1),
                format!("{:.4}", cr.0),
                format!("{:.4}", sat.0),
                format!("{:.4}", sat.1),
                format!("{:.3}", good.0),
                format!("{:.3}", good.1),
                format!("{:.1}", rejects.0),
                format!("{:.1}", defers.0),
            ]);
        }
        // Worst-tenant summary line: the isolation story at a glance.
        table.row([
            cell.congestion.name().to_string(),
            cell.shards.to_string(),
            cell.tenants.to_string(),
            cell.mix_name().to_string(),
            format!("{worst_short:.1}"),
            fmt_rate((worst_sat, 0.0)),
            format!("{fleet_goodput:.2}"),
            format!("{t0_goodput:.2}"),
        ]);
    }
    println!("\nMulti-tenant fleet sharing — tenants × mix × shards (mean over seeds)");
    println!("{}", table.render());
    let path = format!("{}/tenants_summary.csv", opts.out_dir);
    csv.write_file(&path)?;
    println!("wrote {path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_shape_is_stable() {
        let cells = grid();
        // Per (congestion, shards): 1 control + 2 tenant counts × 2 mixes
        // = 5; two congestion levels × two shard counts.
        assert_eq!(cells.len(), 20);
        assert!(cells.iter().all(|c| c.tenants == 1 || c.tenants == 2 || c.tenants == 8));
        assert!(cells.iter().filter(|c| c.tenants == 1).all(|c| !c.one_heavy));
    }

    #[test]
    fn cell_runner_is_deterministic_per_tenant() {
        let cell = TenantCell {
            congestion: Congestion::Medium,
            rate_rps: 12.0,
            shards: 4,
            tenants: 2,
            one_heavy: true,
        };
        let a = run_cell_seed(&cell, 40, 1);
        let b = run_cell_seed(&cell, 40, 1);
        assert_eq!(a.len(), 2, "one metrics per tenant");
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.n_completed, y.n_completed);
            assert_eq!(x.global_p95_ms.to_bits(), y.global_p95_ms.to_bits());
        }
        // Both tenants offered their split share.
        assert!(a.iter().all(|m| m.n_offered == 20));
    }
}
