//! T3 — latency calibration (paper §4.1, Table 3,
//! `latency_calibration.csv`): 18 low-load single requests across three
//! buckets against the paper-scale mock; linear fit + R².
//!
//! Deliberately not on the parallel sweep engine: the harness is one
//! provider probed strictly sequentially (concurrency would add the
//! slowdown term the measurement must exclude), and the whole experiment
//! is 18 simulated requests — there is no grid to fan out.

use anyhow::Result;

use crate::experiments::ExpOpts;
use crate::metrics::report::TextTable;
use crate::provider::calibration::run_calibration;
use crate::provider::ProviderCfg;
use crate::util::csvio::CsvTable;

pub fn run(opts: &ExpOpts) -> Result<()> {
    let res = run_calibration(ProviderCfg::paper_scale(), 42);

    let mut table = TextTable::new([
        "Bucket", "Count", "Mean tokens", "Std tokens", "Mean latency (ms)", "Std latency (ms)",
    ]);
    let mut csv = CsvTable::new([
        "bucket", "count", "mean_tokens", "std_tokens", "mean_latency_ms", "std_latency_ms",
    ]);
    for row in &res.rows {
        table.row([
            row.bucket.name().to_string(),
            row.count.to_string(),
            format!("{:.0}", row.mean_tokens),
            format!("{:.0}", row.std_tokens),
            format!("{:.0}", row.mean_latency_ms),
            format!("{:.0}", row.std_latency_ms),
        ]);
        csv.row([
            row.bucket.name().to_string(),
            row.count.to_string(),
            format!("{:.2}", row.mean_tokens),
            format!("{:.2}", row.std_tokens),
            format!("{:.2}", row.mean_latency_ms),
            format!("{:.2}", row.std_latency_ms),
        ]);
    }
    println!("\nTable 3 — latency calibration by bucket (mock, paper-scale physics)");
    println!("{}", table.render());
    println!(
        "linear fit: latency_ms = {:.0} + {:.1} × output_tokens   (R² = {:.3})",
        res.intercept, res.slope, res.r2
    );
    println!("paper:      latency_ms = 3294 + 18.7 × output_tokens (R² = 0.97)");

    let path = format!("{}/latency_calibration.csv", opts.out_dir);
    csv.write_file(&path)?;

    // Raw samples too (the paper's CSV is per-request).
    let mut raw = CsvTable::new(["bucket", "output_tokens", "latency_ms"]);
    for s in &res.samples {
        raw.row([
            s.bucket.name().to_string(),
            format!("{:.0}", s.output_tokens),
            format!("{:.1}", s.latency_ms),
        ]);
    }
    raw.write_file(&format!("{}/latency_calibration_raw.csv", opts.out_dir))?;
    println!("wrote {path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produces_csvs() {
        let dir = std::env::temp_dir().join("bbsched_calib_test");
        let opts =
            ExpOpts { out_dir: dir.to_str().unwrap().to_string(), ..ExpOpts::default() };
        run(&opts).unwrap();
        assert!(dir.join("latency_calibration.csv").exists());
        let text = std::fs::read_to_string(dir.join("latency_calibration.csv")).unwrap();
        assert_eq!(text.lines().count(), 4, "header + 3 buckets");
        std::fs::remove_dir_all(dir).ok();
    }
}
