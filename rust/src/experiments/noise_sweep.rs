//! F8 — predictor-quality sweep (paper §4.10,
//! `predictor_noise_summary.csv`): multiplicative noise U[1−L, 1+L] on the
//! policy-facing p50/p90 priors, L ∈ {0, 0.1, 0.2, 0.4, 0.6}; Final (OLC)
//! fixed; mock physics and routing buckets unchanged.

use anyhow::Result;

use crate::experiments::runner::{CellSpec, Regime};
use crate::experiments::ExpOpts;
use crate::metrics::report::{fmt_pm, fmt_rate, TextTable};
use crate::metrics::Aggregate;
use crate::scheduler::{SchedulerCfg, StrategyKind};
use crate::util::csvio::CsvTable;

pub const LEVELS: [f64; 5] = [0.0, 0.1, 0.2, 0.4, 0.6];

pub fn run(opts: &ExpOpts) -> Result<()> {
    let mut table =
        TextTable::new(["Regime", "L", "Short P95", "CR", "Satisfaction", "Goodput"]);
    let mut csv = CsvTable::new([
        "regime", "noise_l", "short_p95_mean", "short_p95_std", "cr_mean", "cr_std",
        "satisfaction_mean", "satisfaction_std", "goodput_mean", "goodput_std",
    ]);
    let mut collapse_check: Vec<(String, f64, f64)> = Vec::new();
    let mut cells = Vec::new();
    for regime in Regime::GRID {
        for l in LEVELS {
            cells.push((regime, l));
        }
    }
    let specs: Vec<CellSpec> = cells
        .iter()
        .map(|(regime, l)| {
            CellSpec::new(
                *regime,
                SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc),
                opts.n_requests,
            )
            .with_noise(*l)
        })
        .collect();
    let all_runs = opts.sweep().run_cells(&specs, opts.seeds);
    for ((regime, l), runs) in cells.into_iter().zip(all_runs) {
        let agg = Aggregate::new(&runs);
        let short = agg.mean_std(|m| m.short_p95_ms);
        let cr = agg.mean_std(|m| m.completion_rate);
        let sat = agg.mean_std(|m| m.satisfaction);
        let good = agg.mean_std(|m| m.goodput_rps);
        collapse_check.push((regime.name(), l, cr.0));
        table.row([
            regime.name(),
            format!("{l:.1}"),
            fmt_pm(short),
            fmt_rate(cr),
            fmt_rate(sat),
            format!("{:.1}±{:.1}", good.0, good.1),
        ]);
        csv.row([
            regime.name(),
            format!("{l:.1}"),
            format!("{:.1}", short.0),
            format!("{:.1}", short.1),
            format!("{:.4}", cr.0),
            format!("{:.4}", cr.1),
            format!("{:.4}", sat.0),
            format!("{:.4}", sat.1),
            format!("{:.3}", good.0),
            format!("{:.3}", good.1),
        ]);
    }
    println!("\nFigure 8 — predictor-noise sweep (Final OLC fixed)");
    println!("{}", table.render());

    // Graceful-degradation check: CR at L=0.6 within 0.1 of CR at L=0.
    for regime in Regime::GRID {
        let cr0 = collapse_check
            .iter()
            .find(|(n, l, _)| *n == regime.name() && *l == 0.0)
            .map(|(_, _, c)| *c)
            .unwrap_or(f64::NAN);
        let cr6 = collapse_check
            .iter()
            .find(|(n, l, _)| *n == regime.name() && *l == 0.6)
            .map(|(_, _, c)| *c)
            .unwrap_or(f64::NAN);
        println!("  {}: CR drift L=0→0.6: {:.3} → {:.3}", regime.name(), cr0, cr6);
    }
    let path = format!("{}/predictor_noise_summary.csv", opts.out_dir);
    csv.write_file(&path)?;
    println!("wrote {path}");
    Ok(())
}
