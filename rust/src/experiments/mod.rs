//! Experiment harness: one module per paper table/figure (catalogued in
//! `docs/EXPERIMENTS.md`), a shared multi-seed cell runner, and a registry
//! dispatched by `bbsched exp <name>` and the `benches/` targets.

pub mod ablation;
pub mod burst;
pub mod calibration;
pub mod fairness;
pub mod info_ladder;
pub mod layerwise;
pub mod main_benchmark;
pub mod noise_sweep;
pub mod overload_policy;
pub mod runner;
pub mod scale;
pub mod sensitivity;
pub mod sharded;
pub mod sharegpt;
pub mod storms;
pub mod tenants;
pub mod uncertainty;

pub use runner::{run_cell, run_seed, CellSpec, Congestion, ParallelSweep, Regime};

use anyhow::{bail, Result};

/// Common experiment options (CLI-settable).
#[derive(Debug, Clone)]
pub struct ExpOpts {
    /// Seeds per cell (paper: 5).
    pub seeds: u64,
    /// Offered requests per run.
    pub n_requests: usize,
    /// Output directory for the paper-parity CSVs.
    pub out_dir: String,
    /// Sweep worker threads (0 = all cores). Results are byte-identical
    /// for every value — see [`ParallelSweep`].
    pub jobs: usize,
    /// Print per-seed detail.
    pub verbose: bool,
}

impl Default for ExpOpts {
    fn default() -> Self {
        ExpOpts {
            seeds: 5,
            n_requests: 200,
            out_dir: "paper_results/tables".to_string(),
            jobs: 0,
            verbose: false,
        }
    }
}

impl ExpOpts {
    /// The sweep engine every grid experiment fans out on.
    pub fn sweep(&self) -> ParallelSweep {
        ParallelSweep::new(self.jobs)
    }
}

/// All experiment names, in paper order (repo extensions at the end).
pub const ALL_EXPERIMENTS: [&str; 16] = [
    "calibration",
    "ladder",
    "main",
    "sharegpt",
    "fairness",
    "overload",
    "layerwise",
    "sensitivity",
    "noise",
    "ablation",
    "burst",
    "sharded",
    "tenants",
    "scale",
    "uncertainty",
    "storms",
];

/// Dispatch one experiment by name ("all" runs the full battery).
pub fn run_experiment(name: &str, opts: &ExpOpts) -> Result<()> {
    match name {
        "calibration" => calibration::run(opts),
        "ladder" => info_ladder::run(opts),
        "main" => main_benchmark::run(opts),
        "sharegpt" => sharegpt::run(opts),
        "fairness" => fairness::run(opts),
        "overload" => overload_policy::run(opts),
        "layerwise" => layerwise::run(opts),
        "sensitivity" => sensitivity::run(opts),
        "noise" => noise_sweep::run(opts),
        "ablation" => ablation::run(opts),
        "burst" => burst::run(opts),
        "sharded" => sharded::run(opts),
        "tenants" => tenants::run(opts),
        "scale" => scale::run(opts),
        "uncertainty" => uncertainty::run(opts),
        "storms" => storms::run(opts),
        "all" => {
            for n in ALL_EXPERIMENTS {
                println!("\n########## experiment: {n} ##########");
                run_experiment(n, opts)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment {other:?}; have {ALL_EXPERIMENTS:?} or 'all'"),
    }
}
