//! T1 — information ladder (paper §4.4, Table 1 + Figure 2,
//! `prior_ablation_summary.csv`): hold the Final (OLC) stack fixed and vary
//! only what the client may know — no-info, class-only, coarse, oracle.

use anyhow::Result;

use crate::experiments::runner::{CellSpec, Regime};
use crate::experiments::ExpOpts;
use crate::metrics::report::{fmt_pm, fmt_rate, TextTable};
use crate::metrics::{Aggregate, RunMetrics};
use crate::predictor::InfoLevel;
use crate::scheduler::{SchedulerCfg, StrategyKind};
use crate::util::csvio::CsvTable;

pub struct LadderCell {
    pub regime: Regime,
    pub info: InfoLevel,
    pub runs: Vec<RunMetrics>,
}

pub fn run_grid(opts: &ExpOpts) -> Vec<LadderCell> {
    let mut cells = Vec::new();
    for regime in Regime::GRID {
        for info in InfoLevel::ALL {
            cells.push((regime, info));
        }
    }
    let specs: Vec<CellSpec> = cells
        .iter()
        .map(|(regime, info)| {
            CellSpec::new(
                *regime,
                SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc),
                opts.n_requests,
            )
            .with_info(*info)
        })
        .collect();
    let all_runs = opts.sweep().run_cells(&specs, opts.seeds);
    cells
        .into_iter()
        .zip(all_runs)
        .map(|((regime, info), runs)| LadderCell { regime, info, runs })
        .collect()
}

pub fn render(cells: &[LadderCell], opts: &ExpOpts) -> Result<()> {
    let mut table = TextTable::new([
        "Regime", "Information", "Short P95", "Global P95", "CR", "Satisfaction", "Goodput",
    ]);
    let mut csv = CsvTable::new([
        "regime", "information", "short_p95_mean", "short_p95_std", "global_p95_mean",
        "global_p95_std", "cr_mean", "cr_std", "satisfaction_mean", "satisfaction_std",
        "goodput_mean", "goodput_std",
    ]);
    for c in cells {
        let agg = Aggregate::new(&c.runs);
        let short = agg.mean_std(|m| m.short_p95_ms);
        let global = agg.mean_std(|m| m.global_p95_ms);
        let cr = agg.mean_std(|m| m.completion_rate);
        let sat = agg.mean_std(|m| m.satisfaction);
        let good = agg.mean_std(|m| m.goodput_rps);
        table.row([
            c.regime.name(),
            c.info.name().to_string(),
            fmt_pm(short),
            fmt_pm(global),
            fmt_rate(cr),
            fmt_rate(sat),
            format!("{:.1}±{:.1}", good.0, good.1),
        ]);
        csv.row([
            c.regime.name(),
            c.info.name().to_string(),
            format!("{:.1}", short.0),
            format!("{:.1}", short.1),
            format!("{:.1}", global.0),
            format!("{:.1}", global.1),
            format!("{:.4}", cr.0),
            format!("{:.4}", cr.1),
            format!("{:.4}", sat.0),
            format!("{:.4}", sat.1),
            format!("{:.3}", good.0),
            format!("{:.3}", good.1),
        ]);
    }
    println!("\nTable 1 — information ladder (Final OLC fixed; mean±std over seeds)");
    println!("{}", table.render());

    // Headline check the paper calls out: removing magnitude inflates short
    // P95 by a large multiplicative factor in stressed cells.
    let cell = |regime: Regime, info: InfoLevel| {
        cells
            .iter()
            .find(|c| c.regime == regime, )
            .map(|_| ())
            .and_then(|_| {
                cells
                    .iter()
                    .find(|c| c.regime == regime && c.info == info)
                    .map(|c| Aggregate::new(&c.runs).mean_std(|m| m.short_p95_ms).0)
            })
    };
    let bh = Regime::GRID[1];
    if let (Some(blind), Some(coarse)) = (cell(bh, InfoLevel::NoInfo), cell(bh, InfoLevel::Coarse)) {
        println!(
            "balanced/high short-P95 inflation without magnitude: {:.1}× (paper: ~5.8×)",
            blind / coarse
        );
    }

    let path = format!("{}/prior_ablation_summary.csv", opts.out_dir);
    csv.write_file(&path)?;
    println!("wrote {path}");
    Ok(())
}

pub fn run(opts: &ExpOpts) -> Result<()> {
    let cells = run_grid(opts);
    render(&cells, opts)
}
