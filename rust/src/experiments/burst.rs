//! Burst-robustness extension (beyond the paper): the paper's arrival
//! process is Poisson; production traffic bursts. This experiment replays
//! the main strategies under Markov-modulated bursty arrivals (calm rate =
//! the regime rate, bursts at 4×, ~2 s phases) and checks that the
//! layered stack's advantages survive non-memoryless load — the natural
//! "shadow deployment" question §7 leaves open.

use anyhow::Result;

use crate::experiments::runner::{CellSpec, Congestion, Regime};
use crate::experiments::ExpOpts;
use crate::metrics::report::{fmt_pm, fmt_rate, TextTable};
use crate::metrics::{Aggregate, RunMetrics};
use crate::predictor::LadderSource;
use crate::scheduler::{SchedulerCfg, StrategyKind};
use crate::sim::driver;
use crate::util::csvio::CsvTable;
use crate::util::rng::Rng;
use crate::workload::{Mix, WorkloadSpec};

pub const BURST_FACTOR: f64 = 4.0;
pub const MEAN_PHASE_MS: f64 = 2_000.0;

/// One seed of a bursty-arrival cell; pure in (spec, seed), so the sweep
/// engine can fan seeds out in any worker order.
fn run_bursty_seed(spec: &CellSpec, seed: u64) -> RunMetrics {
    let workload = WorkloadSpec::new(spec.mix, spec.n_requests, spec.rate_rps)
        .bursty(BURST_FACTOR, MEAN_PHASE_MS);
    let requests = workload.generate(seed);
    let mut src = LadderSource::new(spec.info, Rng::new(seed ^ 0x5EED_50_u64).derive("priors"));
    driver::run(&requests, &mut src, spec.sched.clone(), spec.provider.clone(), seed).metrics
}

pub fn run(opts: &ExpOpts) -> Result<()> {
    let regimes = [
        Regime { mix: Mix::Balanced, congestion: Congestion::High },
        Regime { mix: Mix::Heavy, congestion: Congestion::High },
    ];
    let strategies =
        [StrategyKind::DirectNaive, StrategyKind::QuotaTiered, StrategyKind::FinalAdrrOlc];
    let mut table = TextTable::new([
        "Regime", "Strategy", "Short P95", "Global P95", "CR", "Satisf.", "Goodput",
    ]);
    let mut csv = CsvTable::new([
        "regime", "strategy", "short_p95_mean", "short_p95_std", "global_p95_mean", "cr_mean",
        "satisfaction_mean", "goodput_mean",
    ]);
    let mut cells = Vec::new();
    for regime in regimes {
        for strategy in strategies {
            cells.push((regime, strategy));
        }
    }
    let specs: Vec<CellSpec> = cells
        .iter()
        .map(|(regime, strategy)| {
            CellSpec::new(*regime, SchedulerCfg::for_strategy(*strategy), opts.n_requests)
        })
        .collect();
    let all_runs = opts
        .sweep()
        .map_cells(specs.len(), opts.seeds, |cell, seed| run_bursty_seed(&specs[cell], seed));
    for ((regime, strategy), runs) in cells.into_iter().zip(all_runs) {
        let agg = Aggregate::new(&runs);
        let short = agg.mean_std(|m| m.short_p95_ms);
        let global = agg.mean_std(|m| m.global_p95_ms);
        let cr = agg.mean_std(|m| m.completion_rate);
        let sat = agg.mean_std(|m| m.satisfaction);
        let good = agg.mean_std(|m| m.goodput_rps);
        table.row([
            format!("{} (bursty)", regime.name()),
            strategy.name().to_string(),
            fmt_pm(short),
            fmt_pm(global),
            fmt_rate(cr),
            fmt_rate(sat),
            format!("{:.1}±{:.1}", good.0, good.1),
        ]);
        csv.row([
            regime.name(),
            strategy.name().to_string(),
            format!("{:.1}", short.0),
            format!("{:.1}", short.1),
            format!("{:.1}", global.0),
            format!("{:.4}", cr.0),
            format!("{:.4}", sat.0),
            format!("{:.3}", good.0),
        ]);
    }
    println!("\nBurst robustness (extension): 4× bursts, ~2 s phases, calm = regime rate");
    println!("{}", table.render());
    let path = format!("{}/burst_robustness.csv", opts.out_dir);
    csv.write_file(&path)?;
    println!("wrote {path}");
    Ok(())
}
