//! T2 — main policy comparison (paper §4.5, Table 2 + Figures 3 & 4):
//! quota-tiered vs adaptive DRR vs Final (OLC) across the four-regime grid,
//! with direct-naive included for the scatter plots.

use anyhow::Result;

use crate::experiments::runner::{CellSpec, Regime};
use crate::experiments::ExpOpts;
use crate::metrics::report::{fmt_pm, fmt_rate, TextTable};
use crate::metrics::{Aggregate, RunMetrics};
use crate::scheduler::{SchedulerCfg, StrategyKind};
use crate::util::csvio::CsvTable;

/// Strategies in the table (naive is scatter-only, appended to the CSV).
pub const TABLE_STRATEGIES: [StrategyKind; 3] =
    [StrategyKind::QuotaTiered, StrategyKind::AdaptiveDrr, StrategyKind::FinalAdrrOlc];

pub struct CellResult {
    pub regime: Regime,
    pub strategy: StrategyKind,
    pub runs: Vec<RunMetrics>,
}

/// Run the full grid (all four regimes × strategies × seeds), fanned out
/// across the parallel sweep engine; cell order matches the serial loop.
pub fn run_grid(opts: &ExpOpts, include_naive: bool) -> Vec<CellResult> {
    let mut strategies: Vec<StrategyKind> = TABLE_STRATEGIES.to_vec();
    if include_naive {
        strategies.insert(0, StrategyKind::DirectNaive);
    }
    let mut cells = Vec::new();
    for regime in Regime::GRID {
        for strategy in &strategies {
            cells.push((regime, *strategy));
        }
    }
    let specs: Vec<CellSpec> = cells
        .iter()
        .map(|(regime, strategy)| {
            CellSpec::new(*regime, SchedulerCfg::for_strategy(*strategy), opts.n_requests)
        })
        .collect();
    let all_runs = opts.sweep().run_cells(&specs, opts.seeds);
    cells
        .into_iter()
        .zip(all_runs)
        .map(|((regime, strategy), runs)| CellResult { regime, strategy, runs })
        .collect()
}

pub fn render(results: &[CellResult], opts: &ExpOpts) -> Result<()> {
    let mut table = TextTable::new([
        "Regime", "Strategy", "Short P95", "Global P95", "Makespan", "CR", "Satisf.", "Goodput",
    ]);
    let mut csv = CsvTable::new([
        "regime",
        "strategy",
        "short_p95_mean",
        "short_p95_std",
        "global_p95_mean",
        "global_p95_std",
        "makespan_mean",
        "makespan_std",
        "cr_mean",
        "cr_std",
        "satisfaction_mean",
        "satisfaction_std",
        "goodput_mean",
        "goodput_std",
        "rejects_mean",
        "defers_mean",
    ]);
    for cell in results {
        let agg = Aggregate::new(&cell.runs);
        let short = agg.mean_std(|m| m.short_p95_ms);
        let global = agg.mean_std(|m| m.global_p95_ms);
        let makespan = agg.mean_std(|m| m.makespan_ms);
        let cr = agg.mean_std(|m| m.completion_rate);
        let sat = agg.mean_std(|m| m.satisfaction);
        let good = agg.mean_std(|m| m.goodput_rps);
        let rejects = agg.mean_std(|m| m.rejects_total as f64);
        let defers = agg.mean_std(|m| m.defers_total as f64);
        if cell.strategy != StrategyKind::DirectNaive {
            table.row([
                cell.regime.name(),
                cell.strategy.name().to_string(),
                fmt_pm(short),
                fmt_pm(global),
                fmt_pm(makespan),
                fmt_rate(cr),
                fmt_rate(sat),
                format!("{:.1}±{:.1}", good.0, good.1),
            ]);
        }
        csv.row([
            cell.regime.name(),
            cell.strategy.name().to_string(),
            format!("{:.1}", short.0),
            format!("{:.1}", short.1),
            format!("{:.1}", global.0),
            format!("{:.1}", global.1),
            format!("{:.1}", makespan.0),
            format!("{:.1}", makespan.1),
            format!("{:.4}", cr.0),
            format!("{:.4}", cr.1),
            format!("{:.4}", sat.0),
            format!("{:.4}", sat.1),
            format!("{:.3}", good.0),
            format!("{:.3}", good.1),
            format!("{:.1}", rejects.0),
            format!("{:.1}", defers.0),
        ]);
    }
    println!("\nTable 2 — main policy comparison (mean±std over seeds)");
    println!("{}", table.render());
    let path = format!("{}/main_benchmark_summary.csv", opts.out_dir);
    csv.write_file(&path)?;
    println!("wrote {path}");

    // Figures 3 & 4 scatter data: per-seed points (short P95 vs CR;
    // goodput vs global P95), naive included.
    let mut fig = CsvTable::new([
        "regime", "strategy", "seed", "short_p95_ms", "completion_rate", "goodput_rps",
        "global_p95_ms",
    ]);
    for cell in results {
        for (seed, m) in cell.runs.iter().enumerate() {
            fig.row([
                cell.regime.name(),
                cell.strategy.name().to_string(),
                seed.to_string(),
                format!("{:.1}", m.short_p95_ms),
                format!("{:.4}", m.completion_rate),
                format!("{:.3}", m.goodput_rps),
                format!("{:.1}", m.global_p95_ms),
            ]);
        }
    }
    let fig_path = format!("{}/fig3_fig4_scatter.csv", opts.out_dir);
    fig.write_file(&fig_path)?;
    println!("wrote {fig_path}");
    Ok(())
}

pub fn run(opts: &ExpOpts) -> Result<()> {
    let results = run_grid(opts, true);
    render(&results, opts)
}
