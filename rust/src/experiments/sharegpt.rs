//! T6 — ShareGPT real-trace validation (paper §4.1): replay the
//! ShareGPT-derived output-token distribution (12/42/46/<1 bucket split)
//! under high congestion; direct_naive vs quota_tiered vs final_adrr_olc.

use anyhow::Result;

use crate::experiments::runner::{CellSpec, Congestion, Regime};
use crate::experiments::ExpOpts;
use crate::metrics::report::{fmt_pm, fmt_rate, TextTable};
use crate::metrics::Aggregate;
use crate::scheduler::{SchedulerCfg, StrategyKind};
use crate::util::csvio::CsvTable;
use crate::workload::Mix;

pub const STRATEGIES: [StrategyKind; 3] =
    [StrategyKind::DirectNaive, StrategyKind::QuotaTiered, StrategyKind::FinalAdrrOlc];

pub fn run(opts: &ExpOpts) -> Result<()> {
    let regime = Regime { mix: Mix::ShareGpt, congestion: Congestion::High };
    let mut table =
        TextTable::new(["Strategy", "Short P95 (ms)", "Global P95 (ms)", "Makespan (ms)", "Satisfaction"]);
    let mut csv = CsvTable::new([
        "strategy", "short_p95_mean", "short_p95_std", "global_p95_mean", "global_p95_std",
        "makespan_mean", "makespan_std", "satisfaction_mean", "satisfaction_std", "cr_mean",
        "goodput_mean",
    ]);
    let specs: Vec<CellSpec> = STRATEGIES
        .iter()
        .map(|strategy| {
            CellSpec::new(regime, SchedulerCfg::for_strategy(*strategy), opts.n_requests)
        })
        .collect();
    let all_runs = opts.sweep().run_cells(&specs, opts.seeds);
    for (strategy, runs) in STRATEGIES.iter().zip(all_runs) {
        let agg = Aggregate::new(&runs);
        let short = agg.mean_std(|m| m.short_p95_ms);
        let global = agg.mean_std(|m| m.global_p95_ms);
        let makespan = agg.mean_std(|m| m.makespan_ms);
        let sat = agg.mean_std(|m| m.satisfaction);
        let cr = agg.mean_std(|m| m.completion_rate);
        let good = agg.mean_std(|m| m.goodput_rps);
        table.row([
            strategy.name().to_string(),
            fmt_pm(short),
            fmt_pm(global),
            fmt_pm(makespan),
            fmt_rate(sat),
        ]);
        csv.row([
            strategy.name().to_string(),
            format!("{:.1}", short.0),
            format!("{:.1}", short.1),
            format!("{:.1}", global.0),
            format!("{:.1}", global.1),
            format!("{:.1}", makespan.0),
            format!("{:.1}", makespan.1),
            format!("{:.4}", sat.0),
            format!("{:.4}", sat.1),
            format!("{:.4}", cr.0),
            format!("{:.3}", good.0),
        ]);
    }
    println!("\nTable 6 — ShareGPT real-trace validation (high congestion)");
    println!("{}", table.render());
    let path = format!("{}/sharegpt_validation.csv", opts.out_dir);
    csv.write_file(&path)?;
    println!("wrote {path}");
    Ok(())
}
