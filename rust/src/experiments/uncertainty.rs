//! Uncertainty experiment: interval-prior conditions × uncertainty-aware
//! ordering × offered rate.
//!
//! The paper's noise sweep (§4.10) scales a point estimate and asks how
//! fast scheduling value decays; this grid gives the scheduler the *width*
//! of its own uncertainty and asks what it buys back. Axes:
//!
//! * **Condition** — `oracle` (exact, width 0), `coarse` (the ladder's
//!   calibrated per-rung widths), `coarse+noise0.4` (multiplicative ×U[0.6,
//!   1.4] scatter, widths widened to cover it), and `coarse+noise0.4+recal`
//!   (same source, plus the online per-route recalibrator shrinking or
//!   widening claimed widths from observed completions).
//! * **Ordering** — `sjf` (width-blind point baseline), `robust_sjf`
//!   (orders by `p50 + θ·width`, demoting wide-interval requests), and
//!   `feasible_set` under **quantized grouping** (`OrderingCfg::
//!   quantized()`), the index mode built for continuous noisy priors.
//! * **Rate** — 1× and 4× the regime base rate (requests scale with the
//!   rate, so both points cover the same model-time horizon).
//!
//! Besides the usual quality columns, the CSV carries the ordering-index
//! observability counters: entries examined per release (`select_work /
//! sends`), peak prior-group count, and scan-fallback selects — the
//! quantized index must keep groups bounded and fallbacks at zero even
//! under continuous priors, where exact-bit grouping degenerates.
//!
//! Note the recalibrator only moves *widths*, so under `sjf` and
//! `feasible_set` (which score p50/p90 alone) the `+recal` rows are
//! bit-identical to their no-recal siblings — the delta it buys is read
//! against `robust_sjf`, the one ordering that consumes the interval.
//!
//! Fanned out on [`ParallelSweep`], so `uncertainty.csv` is byte-identical
//! for any `--jobs` value (the CI determinism gate covers it via
//! `exp all`).

use anyhow::Result;

use crate::experiments::runner::{Congestion, Regime};
use crate::experiments::ExpOpts;
use crate::metrics::report::{fmt_pm, fmt_rate, TextTable};
use crate::metrics::{Aggregate, RunMetrics};
use crate::predictor::{InfoLevel, LadderSource, NoisySource, PriorSource};
use crate::provider::ProviderCfg;
use crate::scheduler::{OrderingCfg, OrderingKind, SchedulerCfg, StrategyKind};
use crate::sim::driver;
use crate::util::csvio::CsvTable;
use crate::util::rng::Rng;
use crate::util::stats::mean;
use crate::workload::{Mix, WorkloadSpec};

/// Multiplicative noise level for the noisy conditions (the paper's §4.10
/// mid band: estimates scatter ×U[0.6, 1.4] around the coarse rung).
const NOISE_L: f64 = 0.4;

/// Offered-rate multipliers on the regime's base rate.
const MULTS: [f64; 2] = [1.0, 4.0];

/// Prior-information condition: which source the scheduler sees and
/// whether the online recalibrator is closed over it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Condition {
    /// Exact token counts, width 0 — the information frontier.
    Oracle,
    /// The ladder's default semi-clairvoyant rung with its calibrated
    /// per-rung interval widths.
    Coarse,
    /// Coarse scattered by ×U[1−l, 1+l], widths widened to keep coverage.
    Noisy,
    /// [`Condition::Noisy`] plus the per-route online recalibrator.
    NoisyRecal,
}

impl Condition {
    const ALL: [Condition; 4] =
        [Condition::Oracle, Condition::Coarse, Condition::Noisy, Condition::NoisyRecal];

    fn name(self) -> &'static str {
        match self {
            Condition::Oracle => "oracle",
            Condition::Coarse => "coarse",
            Condition::Noisy => "coarse+noise0.4",
            Condition::NoisyRecal => "coarse+noise0.4+recal",
        }
    }

    fn info(self) -> InfoLevel {
        match self {
            Condition::Oracle => InfoLevel::Oracle,
            _ => InfoLevel::Coarse,
        }
    }

    fn noise(self) -> f64 {
        match self {
            Condition::Oracle | Condition::Coarse => 0.0,
            Condition::Noisy | Condition::NoisyRecal => NOISE_L,
        }
    }

    fn recal(self) -> bool {
        self == Condition::NoisyRecal
    }
}

/// The orderings under comparison: the width-blind point baseline, the
/// uncertainty-aware variant, and the indexed feasible-set rule.
const ORDERINGS: [OrderingKind; 3] =
    [OrderingKind::Sjf, OrderingKind::RobustSjf, OrderingKind::FeasibleSet];

/// One grid cell.
#[derive(Debug, Clone)]
struct UncertaintyCell {
    condition: Condition,
    ordering: OrderingKind,
    mult: f64,
}

/// Per-seed result: run metrics plus the ordering-index observability
/// counters (sends, entries examined, peak groups, scan fallbacks).
struct SeedOut {
    metrics: RunMetrics,
    depth_mean: f64,
    sends: u64,
    select_work: u64,
    group_count: u64,
    scan_fallbacks: u64,
}

/// The headline regime: balanced traffic in the paper's high-congestion
/// band — rate multipliers push it past the knee.
fn regime() -> Regime {
    Regime { mix: Mix::Balanced, congestion: Congestion::High }
}

fn run_cell_seed(cell: &UncertaintyCell, n_base: usize, seed: u64) -> SeedOut {
    let n = (n_base as f64 * cell.mult) as usize;
    let rate = regime().rate_rps() * cell.mult;
    let requests = WorkloadSpec::new(regime().mix, n, rate).generate(seed);
    // The established prior-stream convention (ladder bytes are identical
    // whether or not the noise wrapper is stacked on top).
    let root = Rng::new(seed ^ 0x5EED_50_u64);
    let ladder = LadderSource::new(cell.condition.info(), root.derive("priors"));
    let mut src: Box<dyn PriorSource> = if cell.condition.noise() > 0.0 {
        Box::new(NoisySource::new(ladder, cell.condition.noise(), root.derive("noise")))
    } else {
        Box::new(ladder)
    };
    let mut sched = SchedulerCfg::for_strategy(StrategyKind::AdaptiveDrr);
    sched.heavy_ordering = cell.ordering;
    if cell.ordering == OrderingKind::FeasibleSet {
        // The index mode built for this experiment's continuous priors;
        // winners are bit-identical to the exact path either way.
        sched.ordering = OrderingCfg::quantized();
    }
    sched.recalibrate = cell.condition.recal();
    let out = driver::run(&requests, src.as_mut(), sched, ProviderCfg::default(), seed);
    SeedOut {
        metrics: out.metrics,
        depth_mean: out.diagnostics.mean_queue_depth,
        sends: out.diagnostics.sends,
        select_work: out.diagnostics.ordering_select_work,
        group_count: out.diagnostics.ordering_group_count,
        scan_fallbacks: out.diagnostics.ordering_scan_fallbacks,
    }
}

/// The grid: condition × ordering × rate multiplier.
fn grid() -> Vec<UncertaintyCell> {
    let mut cells = Vec::new();
    for condition in Condition::ALL {
        for ordering in ORDERINGS {
            for mult in MULTS {
                cells.push(UncertaintyCell { condition, ordering, mult });
            }
        }
    }
    cells
}

pub fn run(opts: &ExpOpts) -> Result<()> {
    let cells = grid();
    let all: Vec<Vec<SeedOut>> = opts
        .sweep()
        .map_cells(cells.len(), opts.seeds, |c, s| run_cell_seed(&cells[c], opts.n_requests, s));

    let mut table = TextTable::new([
        "Condition",
        "Ordering",
        "Rate",
        "CR",
        "Global P95",
        "Goodput",
        "Work/rel",
        "Groups",
        "Fallbacks",
    ]);
    let mut csv = CsvTable::new([
        "condition",
        "ordering",
        "rate_mult",
        "rate_rps",
        "requests",
        "depth_mean",
        "cr_mean",
        "cr_std",
        "global_p95_mean",
        "global_p95_std",
        "goodput_mean",
        "goodput_std",
        "timeouts_mean",
        "work_per_release_mean",
        "ordering_group_count_mean",
        "ordering_scan_fallbacks_mean",
    ]);
    for (cell, runs) in cells.iter().zip(&all) {
        let metrics: Vec<RunMetrics> = runs.iter().map(|r| r.metrics.clone()).collect();
        let agg = Aggregate::new(&metrics);
        let cr = agg.mean_std(|m| m.completion_rate);
        let global = agg.mean_std(|m| m.global_p95_ms);
        let good = agg.mean_std(|m| m.goodput_rps);
        let timeouts = agg.mean_std(|m| m.n_timed_out as f64);
        let depth = mean(&runs.iter().map(|r| r.depth_mean).collect::<Vec<f64>>());
        let wpr = mean(
            &runs
                .iter()
                .map(|r| {
                    if r.sends > 0 {
                        r.select_work as f64 / r.sends as f64
                    } else {
                        0.0
                    }
                })
                .collect::<Vec<f64>>(),
        );
        let groups = mean(&runs.iter().map(|r| r.group_count as f64).collect::<Vec<f64>>());
        let fallbacks =
            mean(&runs.iter().map(|r| r.scan_fallbacks as f64).collect::<Vec<f64>>());
        let rate = regime().rate_rps() * cell.mult;
        let n = (opts.n_requests as f64 * cell.mult) as usize;
        table.row([
            cell.condition.name().to_string(),
            cell.ordering.name().to_string(),
            format!("{:.0}x", cell.mult),
            fmt_rate(cr),
            fmt_pm(global),
            format!("{:.1}±{:.1}", good.0, good.1),
            format!("{wpr:.1}"),
            format!("{groups:.0}"),
            format!("{fallbacks:.0}"),
        ]);
        csv.row([
            cell.condition.name().to_string(),
            cell.ordering.name().to_string(),
            format!("{:.0}", cell.mult),
            format!("{rate:.1}"),
            n.to_string(),
            format!("{depth:.2}"),
            format!("{:.4}", cr.0),
            format!("{:.4}", cr.1),
            format!("{:.1}", global.0),
            format!("{:.1}", global.1),
            format!("{:.3}", good.0),
            format!("{:.3}", good.1),
            format!("{:.1}", timeouts.0),
            format!("{wpr:.2}"),
            format!("{groups:.1}"),
            format!("{fallbacks:.1}"),
        ]);
    }
    println!("\nUncertainty — interval-prior condition × ordering (mean±std over seeds)");
    println!("{}", table.render());
    let path = format!("{}/uncertainty.csv", opts.out_dir);
    csv.write_file(&path)?;
    println!("wrote {path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_shape_is_stable() {
        let cells = grid();
        // 4 conditions × 3 orderings × 2 multipliers.
        assert_eq!(cells.len(), 24);
        assert!(cells.iter().all(|c| MULTS.contains(&c.mult)));
    }

    #[test]
    fn cell_runner_is_deterministic() {
        let cell = UncertaintyCell {
            condition: Condition::NoisyRecal,
            ordering: OrderingKind::RobustSjf,
            mult: 4.0,
        };
        let a = run_cell_seed(&cell, 30, 1);
        let b = run_cell_seed(&cell, 30, 1);
        assert_eq!(a.metrics.n_completed, b.metrics.n_completed);
        assert_eq!(a.depth_mean.to_bits(), b.depth_mean.to_bits());
        assert_eq!(a.select_work, b.select_work);
        assert_eq!(a.group_count, b.group_count);
    }

    #[test]
    fn quantized_index_keeps_groups_bounded_under_noise() {
        // Continuous noisy priors: exact-bit grouping would hold one group
        // per live entry; the quantized index must keep the peak bounded
        // and never fall back to a full scan.
        let cell = UncertaintyCell {
            condition: Condition::Noisy,
            ordering: OrderingKind::FeasibleSet,
            mult: 4.0,
        };
        let out = run_cell_seed(&cell, 60, 3);
        assert!(out.sends > 0, "releases happened");
        assert!(
            out.group_count < 200,
            "noisy priors must collapse into bounded bins, got {} groups",
            out.group_count
        );
    }

    #[test]
    fn recal_changes_nothing_for_width_blind_orderings() {
        // The recalibrator rescales interval *widths* only; sjf orders by
        // p50, so the +recal condition must be bit-identical to its
        // sibling — the delta is read against robust_sjf alone.
        let mk = |condition: Condition| UncertaintyCell {
            condition,
            ordering: OrderingKind::Sjf,
            mult: 1.0,
        };
        let a = run_cell_seed(&mk(Condition::Noisy), 40, 2);
        let b = run_cell_seed(&mk(Condition::NoisyRecal), 40, 2);
        assert_eq!(a.metrics.n_completed, b.metrics.n_completed);
        assert_eq!(a.depth_mean.to_bits(), b.depth_mean.to_bits());
        assert_eq!(a.select_work, b.select_work);
    }
}
