//! T5 — overload semantics and shedding-policy evidence (paper §4.7,
//! Table 5 + Figures 5 & 6, `overload_policy_comparison_summary.csv`):
//! Final (OLC) fixed, varying only `bucket_policy` under the two
//! high-congestion regimes; plus the Figure-5 aggregation of overload
//! actions over the main-benchmark Final (OLC) cells.

use anyhow::Result;

use crate::core::TokenBucket;
use crate::experiments::runner::{CellSpec, Congestion, Regime};
use crate::experiments::ExpOpts;
use crate::metrics::report::{fmt_pm, fmt_rate, TextTable};
use crate::metrics::Aggregate;
use crate::scheduler::overload::BucketPolicy;
use crate::scheduler::{SchedulerCfg, StrategyKind};
use crate::util::csvio::CsvTable;
use crate::workload::Mix;

/// Figure 5: overload action counts by bucket, summed over Final (OLC) runs
/// across all four regimes.
pub fn action_histogram(opts: &ExpOpts) -> ([u64; 5], [u64; 5]) {
    let specs: Vec<CellSpec> = Regime::GRID
        .iter()
        .map(|regime| {
            CellSpec::new(
                *regime,
                SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc),
                opts.n_requests,
            )
        })
        .collect();
    let mut defers = [0u64; 5];
    let mut rejects = [0u64; 5];
    for runs in opts.sweep().run_cells(&specs, opts.seeds) {
        for m in runs {
            for i in 0..5 {
                defers[i] += m.defers_by_bucket[i];
                rejects[i] += m.rejects_by_bucket[i];
            }
        }
    }
    (defers, rejects)
}

pub fn run(opts: &ExpOpts) -> Result<()> {
    // ---- Figure 5 ----
    let (defers, rejects) = action_histogram(opts);
    println!("\nFigure 5 — overload actions over Final (OLC) main-benchmark runs");
    let mut fig5 = TextTable::new(["Bucket", "Defers", "Rejects"]);
    let mut fig5_csv = CsvTable::new(["bucket", "defers", "rejects"]);
    let labels = ["short", "medium", "long", "xlong", "(unlabeled)"];
    for (i, label) in labels.iter().enumerate() {
        fig5.row([label.to_string(), defers[i].to_string(), rejects[i].to_string()]);
        fig5_csv.row([label.to_string(), defers[i].to_string(), rejects[i].to_string()]);
    }
    println!("{}", fig5.render());
    fig5_csv.write_file(&format!("{}/overload_actions_by_bucket.csv", opts.out_dir))?;
    assert_eq!(rejects[TokenBucket::Short.index()], 0, "shorts are never rejected");

    // ---- Table 5 / Figure 6 ----
    let regimes = [
        Regime { mix: Mix::Balanced, congestion: Congestion::High },
        Regime { mix: Mix::Heavy, congestion: Congestion::High },
    ];
    let mut table = TextTable::new([
        "Regime", "Policy", "Short P95", "Global P95", "CR", "Satisf.", "Goodput", "Rejects",
        "Defers",
    ]);
    let mut csv = CsvTable::new([
        "regime", "policy", "short_p95_mean", "short_p95_std", "global_p95_mean",
        "global_p95_std", "cr_mean", "cr_std", "satisfaction_mean", "satisfaction_std",
        "goodput_mean", "goodput_std", "rejects_mean", "rejects_std", "defers_mean", "defers_std",
    ]);
    let mut cells = Vec::new();
    for regime in regimes {
        for policy in BucketPolicy::ALL {
            cells.push((regime, policy));
        }
    }
    let specs: Vec<CellSpec> = cells
        .iter()
        .map(|(regime, policy)| {
            let mut sched = SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc);
            sched.overload.bucket_policy = *policy;
            CellSpec::new(*regime, sched, opts.n_requests)
        })
        .collect();
    let all_runs = opts.sweep().run_cells(&specs, opts.seeds);
    for ((regime, policy), runs) in cells.into_iter().zip(all_runs) {
        let agg = Aggregate::new(&runs);
        let short = agg.mean_std(|m| m.short_p95_ms);
        let global = agg.mean_std(|m| m.global_p95_ms);
        let cr = agg.mean_std(|m| m.completion_rate);
        let sat = agg.mean_std(|m| m.satisfaction);
        let good = agg.mean_std(|m| m.goodput_rps);
        let rej = agg.mean_std(|m| m.rejects_total as f64);
        let def = agg.mean_std(|m| m.defers_total as f64);
        table.row([
            regime.name(),
            policy.name().to_string(),
            fmt_pm(short),
            fmt_pm(global),
            fmt_rate(cr),
            fmt_rate(sat),
            format!("{:.1}±{:.1}", good.0, good.1),
            format!("{:.1}±{:.1}", rej.0, rej.1),
            format!("{:.1}±{:.1}", def.0, def.1),
        ]);
        csv.row([
            regime.name(),
            policy.name().to_string(),
            format!("{:.1}", short.0),
            format!("{:.1}", short.1),
            format!("{:.1}", global.0),
            format!("{:.1}", global.1),
            format!("{:.4}", cr.0),
            format!("{:.4}", cr.1),
            format!("{:.4}", sat.0),
            format!("{:.4}", sat.1),
            format!("{:.3}", good.0),
            format!("{:.3}", good.1),
            format!("{:.1}", rej.0),
            format!("{:.1}", rej.1),
            format!("{:.1}", def.0),
            format!("{:.1}", def.1),
        ]);
    }
    println!("\nTable 5 — overload bucket_policy comparison (Final OLC fixed)");
    println!("{}", table.render());
    let path = format!("{}/overload_policy_comparison_summary.csv", opts.out_dir);
    csv.write_file(&path)?;
    println!("wrote {path}");
    Ok(())
}
