//! Sharded-fleet experiment: shard count × heterogeneity × congestion ×
//! selection policy, on the full scheduler stack.
//!
//! The single-provider experiments hold the fleet fixed at one endpoint;
//! this grid asks the scale-out question instead: with the same total
//! capacity split across N black-box endpoints (optionally heterogeneous —
//! a ±skew speed spread), how much does the client-side shard-selection
//! policy matter, and what does sharding itself cost? The 1-shard cells
//! are the control group: they run the exact single-provider physics every
//! other table uses.
//!
//! Fanned out on [`ParallelSweep`], so the CSV is byte-identical for any
//! `--jobs` value (the CI determinism gate covers it).

use anyhow::Result;

use crate::experiments::runner::{Congestion, Regime};
use crate::experiments::ExpOpts;
use crate::metrics::report::{fmt_pm, fmt_rate, TextTable};
use crate::metrics::{Aggregate, RunMetrics};
use crate::predictor::{InfoLevel, LadderSource};
use crate::provider::pool::PoolCfg;
use crate::provider::ProviderCfg;
use crate::scheduler::{SchedulerCfg, ShardPolicy, StrategyKind};
use crate::sim::driver;
use crate::util::csvio::CsvTable;
use crate::util::rng::Rng;
use crate::util::stats::mean;
use crate::workload::{Mix, WorkloadSpec};

/// Speed skew for the heterogeneous cells: shard speeds spread ±50%.
const SKEW: f64 = 0.5;

/// One grid cell.
#[derive(Debug, Clone)]
struct ShardCell {
    congestion: Congestion,
    rate_rps: f64,
    shards: usize,
    skew: f64,
    policy: ShardPolicy,
}

impl ShardCell {
    fn pool(&self) -> PoolCfg {
        if self.skew > 0.0 {
            PoolCfg::heterogeneous(ProviderCfg::default(), self.shards, self.skew)
        } else {
            PoolCfg::split(ProviderCfg::default(), self.shards)
        }
    }

    fn hetero_name(&self) -> &'static str {
        if self.skew > 0.0 {
            "skewed"
        } else {
            "uniform"
        }
    }
}

/// Per-seed result: run metrics + fleet balance (max shard share over the
/// fair share; 1.0 = perfectly balanced).
fn run_cell_seed(cell: &ShardCell, n_requests: usize, seed: u64) -> (RunMetrics, f64) {
    let workload = WorkloadSpec::new(Mix::Balanced, n_requests, cell.rate_rps);
    let requests = workload.generate(seed);
    let root = Rng::new(seed ^ 0x5EED_50_u64);
    let mut src = LadderSource::new(InfoLevel::Coarse, root.derive("priors"));
    let mut sched = SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc);
    sched.shards.policy = cell.policy;
    let out = driver::run_pool(&requests, &mut src, sched, &cell.pool(), seed);
    let by_shard = &out.diagnostics.started_by_shard;
    let total: u64 = by_shard.iter().sum();
    let imbalance = if total == 0 {
        1.0
    } else {
        let fair = total as f64 / by_shard.len() as f64;
        by_shard.iter().copied().max().unwrap_or(0) as f64 / fair
    };
    (out.metrics, imbalance)
}

/// The grid: 1-shard control cells (policy-invariant by construction) plus
/// the full shards × heterogeneity × policy product.
fn grid() -> Vec<ShardCell> {
    let mut cells = Vec::new();
    for congestion in [Congestion::Medium, Congestion::High] {
        let rate_rps = Regime { mix: Mix::Balanced, congestion }.rate_rps();
        cells.push(ShardCell {
            congestion,
            rate_rps,
            shards: 1,
            skew: 0.0,
            policy: ShardPolicy::LeastInflight,
        });
        for shards in [2usize, 4] {
            for skew in [0.0, SKEW] {
                for policy in ShardPolicy::ALL {
                    cells.push(ShardCell { congestion, rate_rps, shards, skew, policy });
                }
            }
        }
    }
    cells
}

pub fn run(opts: &ExpOpts) -> Result<()> {
    let cells = grid();
    let all: Vec<Vec<(RunMetrics, f64)>> = opts
        .sweep()
        .map_cells(cells.len(), opts.seeds, |c, s| run_cell_seed(&cells[c], opts.n_requests, s));

    let mut table = TextTable::new([
        "Congestion",
        "Shards",
        "Fleet",
        "Policy",
        "Short P95",
        "Global P95",
        "CR",
        "Goodput",
        "Imbalance",
    ]);
    let mut csv = CsvTable::new([
        "congestion",
        "shards",
        "fleet",
        "policy",
        "short_p95_mean",
        "short_p95_std",
        "global_p95_mean",
        "global_p95_std",
        "cr_mean",
        "cr_std",
        "satisfaction_mean",
        "satisfaction_std",
        "goodput_mean",
        "goodput_std",
        "rejects_mean",
        "defers_mean",
        "imbalance_mean",
    ]);
    for (cell, runs) in cells.iter().zip(&all) {
        let metrics: Vec<RunMetrics> = runs.iter().map(|(m, _)| m.clone()).collect();
        let imbalances: Vec<f64> = runs.iter().map(|(_, b)| *b).collect();
        let agg = Aggregate::new(&metrics);
        let short = agg.mean_std(|m| m.short_p95_ms);
        let global = agg.mean_std(|m| m.global_p95_ms);
        let cr = agg.mean_std(|m| m.completion_rate);
        let sat = agg.mean_std(|m| m.satisfaction);
        let good = agg.mean_std(|m| m.goodput_rps);
        let rejects = agg.mean_std(|m| m.rejects_total as f64);
        let defers = agg.mean_std(|m| m.defers_total as f64);
        let imb = mean(&imbalances);
        table.row([
            cell.congestion.name().to_string(),
            cell.shards.to_string(),
            cell.hetero_name().to_string(),
            cell.policy.name().to_string(),
            fmt_pm(short),
            fmt_pm(global),
            fmt_rate(cr),
            format!("{:.1}±{:.1}", good.0, good.1),
            format!("{imb:.2}"),
        ]);
        csv.row([
            cell.congestion.name().to_string(),
            cell.shards.to_string(),
            cell.hetero_name().to_string(),
            cell.policy.name().to_string(),
            format!("{:.1}", short.0),
            format!("{:.1}", short.1),
            format!("{:.1}", global.0),
            format!("{:.1}", global.1),
            format!("{:.4}", cr.0),
            format!("{:.4}", cr.1),
            format!("{:.4}", sat.0),
            format!("{:.4}", sat.1),
            format!("{:.3}", good.0),
            format!("{:.3}", good.1),
            format!("{:.1}", rejects.0),
            format!("{:.1}", defers.0),
            format!("{imb:.3}"),
        ]);
    }
    println!("\nSharded fleet — shard count × heterogeneity × policy (mean±std over seeds)");
    println!("{}", table.render());
    let path = format!("{}/sharded_summary.csv", opts.out_dir);
    csv.write_file(&path)?;
    println!("wrote {path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_shape_is_stable() {
        let cells = grid();
        // Per congestion level: 1 control + 2 shard counts × 2 fleets × 3
        // policies = 13; two congestion levels.
        assert_eq!(cells.len(), 26);
        assert!(cells.iter().all(|c| c.shards == 1 || c.shards == 2 || c.shards == 4));
    }

    #[test]
    fn cell_runner_is_deterministic_and_balanced_sanely() {
        let cell = ShardCell {
            congestion: Congestion::Medium,
            rate_rps: 12.0,
            shards: 2,
            skew: SKEW,
            policy: ShardPolicy::Weighted,
        };
        let (a, imb_a) = run_cell_seed(&cell, 30, 1);
        let (b, imb_b) = run_cell_seed(&cell, 30, 1);
        assert_eq!(a.n_completed, b.n_completed);
        assert_eq!(imb_a.to_bits(), imb_b.to_bits());
        assert!(imb_a >= 1.0, "imbalance is max/fair-share, so ≥ 1: {imb_a}");
    }
}
