//! F7 — layerwise progression (paper §4.8, Figure 7): naive → quota-tiered
//! → adaptive DRR → Final (OLC) on the two high-congestion regimes, read as
//! moves on the same joint axes.

use anyhow::Result;

use crate::experiments::runner::{CellSpec, Congestion, Regime};
use crate::experiments::ExpOpts;
use crate::metrics::report::{fmt_pm, fmt_rate, TextTable};
use crate::metrics::Aggregate;
use crate::scheduler::{SchedulerCfg, StrategyKind};
use crate::util::csvio::CsvTable;
use crate::workload::Mix;

pub const PROGRESSION: [StrategyKind; 4] = [
    StrategyKind::DirectNaive,
    StrategyKind::QuotaTiered,
    StrategyKind::AdaptiveDrr,
    StrategyKind::FinalAdrrOlc,
];

pub fn run(opts: &ExpOpts) -> Result<()> {
    let regimes = [
        Regime { mix: Mix::Balanced, congestion: Congestion::High },
        Regime { mix: Mix::Heavy, congestion: Congestion::High },
    ];
    let mut table =
        TextTable::new(["Regime", "Layer stack", "Short P95", "Goodput", "CR", "Satisf."]);
    let mut csv = CsvTable::new([
        "regime", "strategy", "short_p95_mean", "short_p95_std", "goodput_mean", "goodput_std",
        "cr_mean", "cr_std", "satisfaction_mean", "satisfaction_std",
    ]);
    let mut cells = Vec::new();
    for regime in regimes {
        for strategy in PROGRESSION {
            cells.push((regime, strategy));
        }
    }
    let specs: Vec<CellSpec> = cells
        .iter()
        .map(|(regime, strategy)| {
            CellSpec::new(*regime, SchedulerCfg::for_strategy(*strategy), opts.n_requests)
        })
        .collect();
    let all_runs = opts.sweep().run_cells(&specs, opts.seeds);
    for ((regime, strategy), runs) in cells.into_iter().zip(all_runs) {
        let agg = Aggregate::new(&runs);
        let short = agg.mean_std(|m| m.short_p95_ms);
        let good = agg.mean_std(|m| m.goodput_rps);
        let cr = agg.mean_std(|m| m.completion_rate);
        let sat = agg.mean_std(|m| m.satisfaction);
        table.row([
            regime.name(),
            strategy.name().to_string(),
            fmt_pm(short),
            format!("{:.1}±{:.1}", good.0, good.1),
            fmt_rate(cr),
            fmt_rate(sat),
        ]);
        csv.row([
            regime.name(),
            strategy.name().to_string(),
            format!("{:.1}", short.0),
            format!("{:.1}", short.1),
            format!("{:.3}", good.0),
            format!("{:.3}", good.1),
            format!("{:.4}", cr.0),
            format!("{:.4}", cr.1),
            format!("{:.4}", sat.0),
            format!("{:.4}", sat.1),
        ]);
    }
    println!("\nFigure 7 — layerwise progression under high congestion");
    println!("{}", table.render());
    let path = format!("{}/layerwise_progression.csv", opts.out_dir);
    csv.write_file(&path)?;
    println!("wrote {path}");
    Ok(())
}
