//! Shared multi-seed cell runner: a *cell* is (workload regime × policy ×
//! information condition); every table aggregates cells over five seeds.
//! All policies within a seed see the **identical** request table (the
//! controlled-evaluation requirement).

use crate::core::SloPolicy;
use crate::metrics::RunMetrics;
use crate::predictor::{InfoLevel, LadderSource, NoisySource, PriorSource};
use crate::provider::ProviderCfg;
use crate::scheduler::SchedulerCfg;
use crate::sim::driver::{run, RunOutput};
use crate::util::pool;
use crate::util::rng::Rng;
use crate::workload::{Mix, WorkloadSpec};

/// Congestion level (paper §4.2). Offered arrival rates are expressed
/// relative to the mock's estimated capacity for the mix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Congestion {
    Medium,
    High,
}

impl Congestion {
    pub fn name(self) -> &'static str {
        match self {
            Congestion::Medium => "medium",
            Congestion::High => "high",
        }
    }
}

/// A workload regime: mix × congestion.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Regime {
    pub mix: Mix,
    pub congestion: Congestion,
}

impl Regime {
    /// The paper's four-regime grid (§4.2).
    pub const GRID: [Regime; 4] = [
        Regime { mix: Mix::Balanced, congestion: Congestion::Medium },
        Regime { mix: Mix::Balanced, congestion: Congestion::High },
        Regime { mix: Mix::Heavy, congestion: Congestion::Medium },
        Regime { mix: Mix::Heavy, congestion: Congestion::High },
    ];

    pub fn name(&self) -> String {
        format!("{}/{}", self.mix.name(), self.congestion.name())
    }

    /// Offered arrival rate (req/s). Chosen so medium ≈ 0.8× and high ≈
    /// 1.6–1.9× the default mock capacity for the mix (see
    /// `docs/EXPERIMENTS.md` §calibration); heavy mixes are already
    /// stressed at medium, matching
    /// the paper's heavy/medium failure band.
    pub fn rate_rps(&self) -> f64 {
        match (self.mix, self.congestion) {
            (Mix::Balanced | Mix::ShareGpt, Congestion::Medium) => 12.0,
            (Mix::Balanced | Mix::ShareGpt, Congestion::High) => 20.0,
            (Mix::Heavy | Mix::FairnessHeavy, Congestion::Medium) => 10.0,
            (Mix::Heavy | Mix::FairnessHeavy, Congestion::High) => 14.0,
        }
    }
}

/// Everything defining one cell.
#[derive(Debug, Clone)]
pub struct CellSpec {
    pub mix: Mix,
    pub rate_rps: f64,
    pub sched: SchedulerCfg,
    pub info: InfoLevel,
    /// Multiplicative prior noise L (§4.10); 0 = off.
    pub noise_l: f64,
    pub provider: ProviderCfg,
    pub n_requests: usize,
    pub slo: SloPolicy,
}

impl CellSpec {
    pub fn new(regime: Regime, sched: SchedulerCfg, n_requests: usize) -> CellSpec {
        CellSpec {
            mix: regime.mix,
            rate_rps: regime.rate_rps(),
            sched,
            info: InfoLevel::Coarse,
            noise_l: 0.0,
            provider: ProviderCfg::default(),
            n_requests,
            slo: SloPolicy::default(),
        }
    }

    pub fn with_info(mut self, info: InfoLevel) -> CellSpec {
        self.info = info;
        self
    }

    pub fn with_noise(mut self, l: f64) -> CellSpec {
        self.noise_l = l;
        self
    }
}

/// Run one seed of a cell.
pub fn run_seed(spec: &CellSpec, seed: u64) -> RunOutput {
    let mut workload = WorkloadSpec::new(spec.mix, spec.n_requests, spec.rate_rps);
    workload.slo = spec.slo.clone();
    let requests = workload.generate(seed);
    let root = Rng::new(seed ^ 0x5EED_50_u64);
    let ladder = LadderSource::new(spec.info, root.derive("priors"));
    let run_with = |src: &mut dyn PriorSource| {
        run(&requests, src, spec.sched.clone(), spec.provider.clone(), seed)
    };
    if spec.noise_l > 0.0 {
        let mut src = NoisySource::new(ladder, spec.noise_l, root.derive("noise"));
        run_with(&mut src)
    } else {
        let mut src = ladder;
        run_with(&mut src)
    }
}

/// Run all seeds of a cell serially; returns per-seed metrics. This is the
/// reference implementation the parallel sweep must match byte-for-byte.
pub fn run_cell(spec: &CellSpec, seeds: u64) -> Vec<RunMetrics> {
    (0..seeds).map(|s| run_seed(spec, s).metrics).collect()
}

/// Deterministic parallel sweep over `CellSpec × seed` jobs.
///
/// Fans the grid out across a scoped worker pool ([`pool::scoped_map`]) and
/// reassembles the results in submission order, so every table and CSV is
/// byte-identical to a serial [`run_cell`] loop. Each `(cell, seed)` job
/// regenerates its own request table from the seed and owns all of its
/// simulation state, which preserves the paired-comparison guarantee: the
/// per-seed request tables are identical across policies regardless of how
/// the workers interleave.
///
/// # Example
///
/// A two-cell sweep; the worker count never changes the numbers:
///
/// ```
/// use blackbox_sched::experiments::{run_cell, CellSpec, ParallelSweep, Regime};
/// use blackbox_sched::scheduler::{SchedulerCfg, StrategyKind};
///
/// let specs: Vec<CellSpec> = [StrategyKind::DirectNaive, StrategyKind::FinalAdrrOlc]
///     .into_iter()
///     .map(|s| CellSpec::new(Regime::GRID[0], SchedulerCfg::for_strategy(s), 20))
///     .collect();
/// let parallel = ParallelSweep::new(4).run_cells(&specs, 2);
/// let serial: Vec<_> = specs.iter().map(|s| run_cell(s, 2)).collect();
/// assert_eq!(parallel.len(), 2);
/// for (p, s) in parallel.iter().zip(&serial) {
///     for (a, b) in p.iter().zip(s) {
///         assert_eq!(a.n_completed, b.n_completed);
///         assert_eq!(a.global_p95_ms.to_bits(), b.global_p95_ms.to_bits());
///     }
/// }
/// ```
#[derive(Debug, Clone, Copy)]
pub struct ParallelSweep {
    jobs: usize,
}

impl ParallelSweep {
    /// `jobs == 0` uses all available cores.
    pub fn new(jobs: usize) -> ParallelSweep {
        ParallelSweep { jobs }
    }

    /// Configured worker count (0 = all cores).
    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Run `seeds` seeds of every cell; `out[i][s]` is cell `i`, seed `s` —
    /// exactly the shape a serial `specs.iter().map(run_cell)` produces.
    pub fn run_cells(&self, specs: &[CellSpec], seeds: u64) -> Vec<Vec<RunMetrics>> {
        self.map_cells(specs.len(), seeds, |cell, seed| run_seed(&specs[cell], seed).metrics)
    }

    /// Generalized fan-out: evaluate `f(cell_index, seed)` for every pair
    /// and regroup the results per cell in submission order. Experiments
    /// with custom per-seed runners (e.g. bursty arrivals) use this
    /// directly.
    pub fn map_cells<R, F>(&self, n_cells: usize, seeds: u64, f: F) -> Vec<Vec<R>>
    where
        R: Send,
        F: Fn(usize, u64) -> R + Sync,
    {
        let pairs: Vec<(usize, u64)> =
            (0..n_cells).flat_map(|c| (0..seeds).map(move |s| (c, s))).collect();
        let mut flat = pool::scoped_map(pairs, self.jobs, |(c, s)| f(c, s)).into_iter();
        (0..n_cells)
            .map(|_| (0..seeds).map(|_| flat.next().expect("one result per job")).collect())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::StrategyKind;

    #[test]
    fn regime_grid_names() {
        let names: Vec<String> = Regime::GRID.iter().map(Regime::name).collect();
        assert_eq!(names, vec!["balanced/medium", "balanced/high", "heavy/medium", "heavy/high"]);
    }

    #[test]
    fn high_rate_exceeds_medium() {
        for mix in [Mix::Balanced, Mix::Heavy] {
            let med = Regime { mix, congestion: Congestion::Medium }.rate_rps();
            let high = Regime { mix, congestion: Congestion::High }.rate_rps();
            assert!(high > med * 1.3);
        }
    }

    #[test]
    fn run_cell_gives_one_metrics_per_seed() {
        let spec = CellSpec::new(
            Regime::GRID[0],
            SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc),
            40,
        );
        let ms = run_cell(&spec, 3);
        assert_eq!(ms.len(), 3);
        for m in &ms {
            assert_eq!(m.n_offered, 40);
        }
    }

    fn metrics_bitwise_equal(a: &RunMetrics, b: &RunMetrics) {
        assert_eq!(a.n_offered, b.n_offered);
        assert_eq!(a.n_completed, b.n_completed);
        assert_eq!(a.n_rejected, b.n_rejected);
        assert_eq!(a.n_timed_out, b.n_timed_out);
        assert_eq!(a.defers_total, b.defers_total);
        assert_eq!(a.rejects_total, b.rejects_total);
        assert_eq!(a.feasibility_violations, b.feasibility_violations);
        // Bit-compare floats (NaN-safe): identical computations must land on
        // identical bits for CSVs to be byte-identical.
        for (x, y) in [
            (a.short_p95_ms, b.short_p95_ms),
            (a.global_p95_ms, b.global_p95_ms),
            (a.completion_rate, b.completion_rate),
            (a.satisfaction, b.satisfaction),
            (a.goodput_rps, b.goodput_rps),
            (a.makespan_ms, b.makespan_ms),
            (a.heavy_p90_ms, b.heavy_p90_ms),
        ] {
            assert_eq!(x.to_bits(), y.to_bits(), "float drift: {x} vs {y}");
        }
    }

    #[test]
    fn parallel_sweep_matches_serial_run_cell() {
        // 2 regimes × 2 policies × 3 seeds, at several worker counts.
        let mut specs = Vec::new();
        for regime in [Regime::GRID[0], Regime::GRID[3]] {
            for strategy in [StrategyKind::QuotaTiered, StrategyKind::FinalAdrrOlc] {
                specs.push(CellSpec::new(regime, SchedulerCfg::for_strategy(strategy), 30));
            }
        }
        let serial: Vec<Vec<RunMetrics>> = specs.iter().map(|s| run_cell(s, 3)).collect();
        for jobs in [1usize, 2, 4, 7] {
            let par = ParallelSweep::new(jobs).run_cells(&specs, 3);
            assert_eq!(par.len(), serial.len(), "jobs={jobs}");
            for (cell_par, cell_ser) in par.iter().zip(&serial) {
                assert_eq!(cell_par.len(), 3);
                for (a, b) in cell_par.iter().zip(cell_ser) {
                    metrics_bitwise_equal(a, b);
                }
            }
        }
    }

    #[test]
    fn map_cells_regroups_in_submission_order() {
        let sweep = ParallelSweep::new(4);
        let out = sweep.map_cells(3, 4, |cell, seed| (cell, seed));
        assert_eq!(out.len(), 3);
        for (c, row) in out.iter().enumerate() {
            let want: Vec<(usize, u64)> = (0..4u64).map(|s| (c, s)).collect();
            assert_eq!(row, &want);
        }
        // Degenerate shapes stay well-formed.
        assert_eq!(sweep.map_cells(0, 5, |c, s| (c, s)).len(), 0);
        let zero_seeds = sweep.map_cells(2, 0, |c, s| (c, s));
        assert_eq!(zero_seeds.len(), 2);
        assert!(zero_seeds.iter().all(|row| row.is_empty()));
    }

    #[test]
    fn same_seed_same_workload_across_strategies() {
        // Paired comparison guarantee: per-seed request tables are identical
        // regardless of the policy under test.
        let a = CellSpec::new(
            Regime::GRID[1],
            SchedulerCfg::for_strategy(StrategyKind::DirectNaive),
            30,
        );
        let b = CellSpec::new(
            Regime::GRID[1],
            SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc),
            30,
        );
        let wa = WorkloadSpec::new(a.mix, a.n_requests, a.rate_rps).generate(7);
        let wb = WorkloadSpec::new(b.mix, b.n_requests, b.rate_rps).generate(7);
        for (x, y) in wa.iter().zip(wb.iter()) {
            assert_eq!(x.true_output_tokens, y.true_output_tokens);
            assert_eq!(x.arrival_ms, y.arrival_ms);
        }
    }
}
