//! Shared multi-seed cell runner: a *cell* is (workload regime × policy ×
//! information condition); every table aggregates cells over five seeds.
//! All policies within a seed see the **identical** request table (the
//! controlled-evaluation requirement).

use crate::core::SloPolicy;
use crate::metrics::RunMetrics;
use crate::predictor::{InfoLevel, LadderSource, NoisySource, PriorSource};
use crate::provider::ProviderCfg;
use crate::scheduler::SchedulerCfg;
use crate::sim::driver::{run, RunOutput};
use crate::util::rng::Rng;
use crate::workload::{Mix, WorkloadSpec};

/// Congestion level (paper §4.2). Offered arrival rates are expressed
/// relative to the mock's estimated capacity for the mix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Congestion {
    Medium,
    High,
}

impl Congestion {
    pub fn name(self) -> &'static str {
        match self {
            Congestion::Medium => "medium",
            Congestion::High => "high",
        }
    }
}

/// A workload regime: mix × congestion.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Regime {
    pub mix: Mix,
    pub congestion: Congestion,
}

impl Regime {
    /// The paper's four-regime grid (§4.2).
    pub const GRID: [Regime; 4] = [
        Regime { mix: Mix::Balanced, congestion: Congestion::Medium },
        Regime { mix: Mix::Balanced, congestion: Congestion::High },
        Regime { mix: Mix::Heavy, congestion: Congestion::Medium },
        Regime { mix: Mix::Heavy, congestion: Congestion::High },
    ];

    pub fn name(&self) -> String {
        format!("{}/{}", self.mix.name(), self.congestion.name())
    }

    /// Offered arrival rate (req/s). Chosen so medium ≈ 0.8× and high ≈
    /// 1.6–1.9× the default mock capacity for the mix (see EXPERIMENTS.md
    /// §Calibration); heavy mixes are already stressed at medium, matching
    /// the paper's heavy/medium failure band.
    pub fn rate_rps(&self) -> f64 {
        match (self.mix, self.congestion) {
            (Mix::Balanced | Mix::ShareGpt, Congestion::Medium) => 12.0,
            (Mix::Balanced | Mix::ShareGpt, Congestion::High) => 20.0,
            (Mix::Heavy | Mix::FairnessHeavy, Congestion::Medium) => 10.0,
            (Mix::Heavy | Mix::FairnessHeavy, Congestion::High) => 14.0,
        }
    }
}

/// Everything defining one cell.
#[derive(Debug, Clone)]
pub struct CellSpec {
    pub mix: Mix,
    pub rate_rps: f64,
    pub sched: SchedulerCfg,
    pub info: InfoLevel,
    /// Multiplicative prior noise L (§4.10); 0 = off.
    pub noise_l: f64,
    pub provider: ProviderCfg,
    pub n_requests: usize,
    pub slo: SloPolicy,
}

impl CellSpec {
    pub fn new(regime: Regime, sched: SchedulerCfg, n_requests: usize) -> CellSpec {
        CellSpec {
            mix: regime.mix,
            rate_rps: regime.rate_rps(),
            sched,
            info: InfoLevel::Coarse,
            noise_l: 0.0,
            provider: ProviderCfg::default(),
            n_requests,
            slo: SloPolicy::default(),
        }
    }

    pub fn with_info(mut self, info: InfoLevel) -> CellSpec {
        self.info = info;
        self
    }

    pub fn with_noise(mut self, l: f64) -> CellSpec {
        self.noise_l = l;
        self
    }
}

/// Run one seed of a cell.
pub fn run_seed(spec: &CellSpec, seed: u64) -> RunOutput {
    let mut workload = WorkloadSpec::new(spec.mix, spec.n_requests, spec.rate_rps);
    workload.slo = spec.slo.clone();
    let requests = workload.generate(seed);
    let root = Rng::new(seed ^ 0x5EED_50_u64);
    let ladder = LadderSource::new(spec.info, root.derive("priors"));
    let run_with = |src: &mut dyn PriorSource| {
        run(&requests, src, spec.sched.clone(), spec.provider.clone(), seed)
    };
    if spec.noise_l > 0.0 {
        let mut src = NoisySource::new(ladder, spec.noise_l, root.derive("noise"));
        run_with(&mut src)
    } else {
        let mut src = ladder;
        run_with(&mut src)
    }
}

/// Run all seeds of a cell; returns per-seed metrics.
pub fn run_cell(spec: &CellSpec, seeds: u64) -> Vec<RunMetrics> {
    (0..seeds).map(|s| run_seed(spec, s).metrics).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::StrategyKind;

    #[test]
    fn regime_grid_names() {
        let names: Vec<String> = Regime::GRID.iter().map(Regime::name).collect();
        assert_eq!(names, vec!["balanced/medium", "balanced/high", "heavy/medium", "heavy/high"]);
    }

    #[test]
    fn high_rate_exceeds_medium() {
        for mix in [Mix::Balanced, Mix::Heavy] {
            let med = Regime { mix, congestion: Congestion::Medium }.rate_rps();
            let high = Regime { mix, congestion: Congestion::High }.rate_rps();
            assert!(high > med * 1.3);
        }
    }

    #[test]
    fn run_cell_gives_one_metrics_per_seed() {
        let spec = CellSpec::new(
            Regime::GRID[0],
            SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc),
            40,
        );
        let ms = run_cell(&spec, 3);
        assert_eq!(ms.len(), 3);
        for m in &ms {
            assert_eq!(m.n_offered, 40);
        }
    }

    #[test]
    fn same_seed_same_workload_across_strategies() {
        // Paired comparison guarantee: per-seed request tables are identical
        // regardless of the policy under test.
        let a = CellSpec::new(
            Regime::GRID[1],
            SchedulerCfg::for_strategy(StrategyKind::DirectNaive),
            30,
        );
        let b = CellSpec::new(
            Regime::GRID[1],
            SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc),
            30,
        );
        let wa = WorkloadSpec::new(a.mix, a.n_requests, a.rate_rps).generate(7);
        let wb = WorkloadSpec::new(b.mix, b.n_requests, b.rate_rps).generate(7);
        for (x, y) in wa.iter().zip(wb.iter()) {
            assert_eq!(x.true_output_tokens, y.true_output_tokens);
            assert_eq!(x.arrival_ms, y.arrival_ms);
        }
    }
}
