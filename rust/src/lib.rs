//! # blackbox-sched
//!
//! Reproduction of *"Scheduling the Unschedulable: Taming Black-Box LLM
//! Inference at Scale"* (CS.DC 2026): a client-side, semi-clairvoyant
//! scheduler for opaque LLM APIs, decomposed into allocation (adaptive DRR),
//! ordering (feasible-set scoring), and overload control (cost-ladder
//! shedding), plus the congestion-aware mock provider, workload generators,
//! experiment harness, and the PJRT-served output-length predictor
//! (JAX/Pallas, AOT-compiled — see `python/compile/`).
//!
//! Layering (see `docs/ARCHITECTURE.md`):
//! * L3 (this crate): coordination + simulation + experiments.
//! * L2/L1 (build-time Python): quantile-MLP predictor with Pallas kernels,
//!   lowered to `artifacts/*.hlo.txt`, executed via [`runtime`].

pub mod bench;
pub mod config;
pub mod core;
pub mod experiments;
pub mod metrics;
pub mod predictor;
pub mod provider;
pub mod runtime;
pub mod scheduler;
pub mod serve;
pub mod sim;
pub mod testing;
pub mod util;
pub mod workload;

pub use crate::core::{Class, Priors, Request, RequestStatus, TokenBucket};
pub use scheduler::{ClientScheduler, SchedulerCfg, StrategyKind};
