//! Joint metrics (paper §4.3): short/global tail percentiles over
//! completions, completion rate, deadline satisfaction, useful goodput,
//! makespan, and overload action counts — designed so tail improvements
//! cannot be read in isolation from completion and SLO satisfaction.
//!
//! Semantics:
//! * admitted        = offered − rejected (explicit shedding is excluded
//!                     from CR's denominator — the paper reports CR 1.00
//!                     alongside nonzero reject counts);
//! * completion rate = completed / admitted;
//! * satisfaction    = deadline-met / admitted;
//! * useful goodput  = deadline-met / makespan (completed AND SLO-met
//!                     requests per second);
//! * makespan        = last completion − first arrival.

pub mod report;

use crate::core::{Class, RequestStatus, TokenBucket};
use crate::util::stats::{mean_std, percentile_sorted};

/// Final per-request record produced by the driver.
#[derive(Debug, Clone)]
pub struct RequestOutcome {
    pub id: usize,
    pub bucket: TokenBucket,
    pub class: Class,
    pub arrival_ms: f64,
    pub deadline_ms: f64,
    pub status: RequestStatus,
    /// Client-perceived latency (completion − arrival), completed only.
    pub latency_ms: Option<f64>,
    pub defer_count: u32,
}

impl RequestOutcome {
    pub fn completed(&self) -> bool {
        self.status == RequestStatus::Completed
    }

    pub fn deadline_met(&self) -> bool {
        match (self.status, self.latency_ms) {
            (RequestStatus::Completed, Some(lat)) => self.arrival_ms + lat <= self.deadline_ms,
            _ => false,
        }
    }
}

/// Aggregated metrics for one run.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub n_offered: usize,
    pub n_completed: usize,
    pub n_rejected: usize,
    pub n_timed_out: usize,
    pub short_p95_ms: f64,
    pub short_p90_ms: f64,
    pub global_p95_ms: f64,
    pub global_std_ms: f64,
    /// Heavy-class (long+xlong) P90 — Table 4's "Long P90".
    pub heavy_p90_ms: f64,
    pub completion_rate: f64,
    pub satisfaction: f64,
    pub goodput_rps: f64,
    pub makespan_ms: f64,
    pub defers_total: u64,
    pub rejects_total: u64,
    pub defers_by_bucket: [u64; 5],
    pub rejects_by_bucket: [u64; 5],
    pub feasibility_violations: u64,
    pub completed_by_bucket: [usize; 4],
    pub offered_by_bucket: [usize; 4],
}

/// Compute run metrics from per-request outcomes + scheduler counters.
pub fn compute(
    outcomes: &[RequestOutcome],
    defers_by_bucket: [u64; 5],
    rejects_by_bucket: [u64; 5],
    feasibility_violations: u64,
) -> RunMetrics {
    let n_offered = outcomes.len();
    let n_completed = outcomes.iter().filter(|o| o.completed()).count();
    let n_rejected = outcomes.iter().filter(|o| o.status == RequestStatus::Rejected).count();
    let n_timed_out = outcomes.iter().filter(|o| o.status == RequestStatus::TimedOut).count();
    let n_admitted = n_offered.saturating_sub(n_rejected);
    let n_met = outcomes.iter().filter(|o| o.deadline_met()).count();

    let mut completed_lat: Vec<f64> =
        outcomes.iter().filter_map(|o| if o.completed() { o.latency_ms } else { None }).collect();
    let mut short_lat: Vec<f64> = outcomes
        .iter()
        .filter(|o| o.completed() && o.bucket == TokenBucket::Short)
        .filter_map(|o| o.latency_ms)
        .collect();
    let mut heavy_lat: Vec<f64> = outcomes
        .iter()
        .filter(|o| o.completed() && o.class == Class::Heavy)
        .filter_map(|o| o.latency_ms)
        .collect();
    // One sort per latency vector per run; every percentile below reads the
    // sorted slice directly instead of clone-and-selecting per call.
    // `percentile_sorted` itself yields NaN on empty input (all-rejected
    // runs produce empty latency vectors).
    completed_lat.sort_unstable_by(f64::total_cmp);
    short_lat.sort_unstable_by(f64::total_cmp);
    heavy_lat.sort_unstable_by(f64::total_cmp);
    let pct = percentile_sorted;

    let first_arrival =
        outcomes.iter().map(|o| o.arrival_ms).fold(f64::INFINITY, f64::min);
    let last_completion = outcomes
        .iter()
        .filter(|o| o.completed())
        .map(|o| o.arrival_ms + o.latency_ms.unwrap())
        .fold(0.0f64, f64::max);
    let makespan_ms = if n_completed > 0 { (last_completion - first_arrival).max(0.0) } else { 0.0 };

    let mut completed_by_bucket = [0usize; 4];
    let mut offered_by_bucket = [0usize; 4];
    for o in outcomes {
        offered_by_bucket[o.bucket.index()] += 1;
        if o.completed() {
            completed_by_bucket[o.bucket.index()] += 1;
        }
    }

    RunMetrics {
        n_offered,
        n_completed,
        n_rejected,
        n_timed_out,
        short_p95_ms: pct(&short_lat, 95.0),
        short_p90_ms: pct(&short_lat, 90.0),
        global_p95_ms: pct(&completed_lat, 95.0),
        global_std_ms: if completed_lat.is_empty() { f64::NAN } else { mean_std(&completed_lat).1 },
        heavy_p90_ms: pct(&heavy_lat, 90.0),
        completion_rate: if n_admitted > 0 { n_completed as f64 / n_admitted as f64 } else { 0.0 },
        satisfaction: if n_admitted > 0 { n_met as f64 / n_admitted as f64 } else { 0.0 },
        goodput_rps: if makespan_ms > 0.0 { n_met as f64 / (makespan_ms / 1000.0) } else { 0.0 },
        makespan_ms,
        defers_total: defers_by_bucket.iter().sum(),
        rejects_total: rejects_by_bucket.iter().sum(),
        defers_by_bucket,
        rejects_by_bucket,
        feasibility_violations,
        completed_by_bucket,
        offered_by_bucket,
    }
}

/// Cross-seed aggregate: mean ± std for each scalar field, via an accessor.
pub struct Aggregate<'a> {
    pub runs: &'a [RunMetrics],
}

impl<'a> Aggregate<'a> {
    pub fn new(runs: &'a [RunMetrics]) -> Self {
        Aggregate { runs }
    }

    pub fn mean_std(&self, f: impl Fn(&RunMetrics) -> f64) -> (f64, f64) {
        let xs: Vec<f64> = self.runs.iter().map(f).filter(|x| x.is_finite()).collect();
        mean_std(&xs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(
        id: usize,
        bucket: TokenBucket,
        arrival: f64,
        deadline_rel: f64,
        status: RequestStatus,
        latency: Option<f64>,
    ) -> RequestOutcome {
        RequestOutcome {
            id,
            bucket,
            class: bucket.class(),
            arrival_ms: arrival,
            deadline_ms: arrival + deadline_rel,
            status,
            latency_ms: latency,
            defer_count: 0,
        }
    }

    #[test]
    fn basic_counts_and_rates() {
        let outcomes = vec![
            outcome(0, TokenBucket::Short, 0.0, 1000.0, RequestStatus::Completed, Some(300.0)),
            outcome(1, TokenBucket::Short, 10.0, 1000.0, RequestStatus::Completed, Some(2000.0)), // late
            outcome(2, TokenBucket::XLong, 20.0, 5000.0, RequestStatus::Rejected, None),
            outcome(3, TokenBucket::Long, 30.0, 5000.0, RequestStatus::TimedOut, None),
        ];
        let m = compute(&outcomes, [0; 5], [0, 0, 0, 1, 0], 0);
        assert_eq!(m.n_offered, 4);
        assert_eq!(m.n_completed, 2);
        assert_eq!(m.n_rejected, 1);
        assert_eq!(m.n_timed_out, 1);
        // admitted = 3; CR = 2/3; satisfaction = 1/3 (one on-time).
        assert!((m.completion_rate - 2.0 / 3.0).abs() < 1e-9);
        assert!((m.satisfaction - 1.0 / 3.0).abs() < 1e-9);
        assert_eq!(m.rejects_total, 1);
        assert_eq!(m.offered_by_bucket, [2, 0, 1, 1]);
        assert_eq!(m.completed_by_bucket, [2, 0, 0, 0]);
    }

    #[test]
    fn goodput_counts_only_met_deadlines() {
        let outcomes = vec![
            outcome(0, TokenBucket::Short, 0.0, 1000.0, RequestStatus::Completed, Some(500.0)),
            outcome(1, TokenBucket::Short, 0.0, 1000.0, RequestStatus::Completed, Some(9_500.0)),
        ];
        let m = compute(&outcomes, [0; 5], [0; 5], 0);
        // makespan = 9_500 ms; 1 met → goodput ≈ 0.105 rps.
        assert!((m.makespan_ms - 9_500.0).abs() < 1e-9);
        assert!((m.goodput_rps - 1.0 / 9.5).abs() < 1e-6);
    }

    #[test]
    fn percentiles_split_by_bucket_and_class() {
        let mut outcomes = Vec::new();
        for i in 0..20 {
            outcomes.push(outcome(
                i,
                TokenBucket::Short,
                0.0,
                1e6,
                RequestStatus::Completed,
                Some(100.0 + i as f64),
            ));
        }
        for i in 0..3 {
            outcomes.push(outcome(
                100 + i,
                TokenBucket::XLong,
                0.0,
                1e6,
                RequestStatus::Completed,
                Some(50_000.0),
            ));
        }
        let m = compute(&outcomes, [0; 5], [0; 5], 0);
        assert!(m.short_p95_ms < 120.0);
        assert!(m.global_p95_ms > 1000.0, "xlong pulls the global tail");
        assert_eq!(m.heavy_p90_ms, 50_000.0);
        assert!(m.short_p90_ms <= m.short_p95_ms);
    }

    #[test]
    fn empty_run_is_nan_safe() {
        let m = compute(&[], [0; 5], [0; 5], 0);
        assert_eq!(m.n_offered, 0);
        assert!(m.short_p95_ms.is_nan());
        assert_eq!(m.completion_rate, 0.0);
        assert_eq!(m.goodput_rps, 0.0);
    }

    #[test]
    fn aggregate_mean_std() {
        let mut a = RunMetrics::default();
        a.goodput_rps = 2.0;
        let mut b = RunMetrics::default();
        b.goodput_rps = 4.0;
        let runs = vec![a, b];
        let agg = Aggregate::new(&runs);
        let (m, s) = agg.mean_std(|r| r.goodput_rps);
        assert_eq!(m, 3.0);
        assert_eq!(s, 1.0);
    }
}
