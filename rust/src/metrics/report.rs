//! Human-readable table rendering for experiment output (the paper-style
//! `mean ± std` rows printed by `bbsched exp ...`).

use crate::util::csvio::pm;

/// Fixed-width text table builder.
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    widths: Vec<usize>,
}

impl TextTable {
    pub fn new<S: Into<String>>(columns: impl IntoIterator<Item = S>) -> Self {
        let header: Vec<String> = columns.into_iter().map(Into::into).collect();
        let widths = header.iter().map(|h| h.len()).collect();
        TextTable { header, rows: Vec::new(), widths }
    }

    pub fn row<S: Into<String>>(&mut self, cells: impl IntoIterator<Item = S>) {
        let row: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(row.len(), self.header.len(), "row width mismatch");
        for (w, c) in self.widths.iter_mut().zip(row.iter()) {
            *w = (*w).max(c.len());
        }
        self.rows.push(row);
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize], out: &mut String| {
            for (i, (c, w)) in cells.iter().zip(widths).enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                out.push_str(&format!("{c:<w$}", w = *w));
            }
            out.push('\n');
        };
        line(&self.header, &self.widths, &mut out);
        let total: usize = self.widths.iter().sum::<usize>() + 2 * (self.widths.len() - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for r in &self.rows {
            line(r, &self.widths, &mut out);
        }
        out
    }
}

/// Format a (mean, std) pair like the paper's tables.
pub fn fmt_pm(pair: (f64, f64)) -> String {
    if pair.0.is_nan() {
        return "–".to_string();
    }
    pm(pair.0, pair.1)
}

/// Format a rate (CR / satisfaction) with 2 decimals, collapsing ±0.00.
pub fn fmt_rate(pair: (f64, f64)) -> String {
    if pair.0.is_nan() {
        return "–".to_string();
    }
    if pair.1 < 0.005 {
        format!("{:.2}", pair.0)
    } else {
        format!("{:.2}±{:.2}", pair.0, pair.1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = TextTable::new(["regime", "goodput"]);
        t.row(["balanced/high", "4.2±1.6"]);
        t.row(["heavy/med", "0.9"]);
        let s = t.render();
        assert!(s.contains("regime"));
        assert!(s.lines().count() == 4);
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[2].starts_with("balanced/high"));
    }

    #[test]
    fn rate_formatting() {
        assert_eq!(fmt_rate((1.0, 0.0)), "1.00");
        assert_eq!(fmt_rate((0.92, 0.04)), "0.92±0.04");
        assert_eq!(fmt_rate((f64::NAN, 0.0)), "–");
    }
}
