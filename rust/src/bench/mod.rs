//! Micro/end-to-end benchmark harness (the image vendors no criterion).
//!
//! `cargo bench` runs the `benches/*.rs` targets declared with
//! `harness = false`; each target builds a `Suite`, registers benchmarks,
//! and calls `run()`, which warms up, samples wall-clock batches, and prints
//! a criterion-style `name  time/iter  ±std  iters` table. End-to-end table
//! benches reuse the same harness with one iteration per seed.

pub mod perf;

use std::time::Instant;

/// Peak resident set size of this process in kB (`VmHWM` from
/// `/proc/self/status`) — the perf harness's memory proxy. Returns 0 on
/// platforms without procfs.
pub fn peak_rss_kb() -> u64 {
    if let Ok(s) = std::fs::read_to_string("/proc/self/status") {
        for line in s.lines() {
            if let Some(rest) = line.strip_prefix("VmHWM:") {
                return rest.split_whitespace().next().and_then(|v| v.parse().ok()).unwrap_or(0);
            }
        }
    }
    0
}

/// One measured benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub iters: u64,
}

impl BenchResult {
    pub fn human_time(&self) -> String {
        fmt_ns(self.mean_ns)
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Benchmark suite: register closures, run, print a table.
pub struct Suite {
    title: String,
    results: Vec<BenchResult>,
    /// Target wall time per benchmark (seconds).
    pub budget_s: f64,
    /// Minimum sample batches.
    pub min_batches: usize,
}

impl Suite {
    pub fn new(title: &str) -> Self {
        // Honour the --bench/--test harness args cargo passes; also allow
        // BENCH_BUDGET_S to trim CI time.
        let budget_s = std::env::var("BENCH_BUDGET_S")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(1.0);
        println!("\n== bench suite: {title} ==");
        Suite { title: title.to_string(), results: Vec::new(), budget_s, min_batches: 10 }
    }

    /// Measure `f`, which performs ONE logical iteration per call.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) {
        // Warmup + calibration: find iterations per batch so one batch ≈ 10ms.
        f();
        let t0 = Instant::now();
        f();
        let once_ns = t0.elapsed().as_nanos().max(1) as f64;
        let per_batch = ((10_000_000.0 / once_ns).ceil() as u64).clamp(1, 1_000_000);

        let mut batches: Vec<f64> = Vec::new();
        let deadline = Instant::now();
        while batches.len() < self.min_batches
            || (deadline.elapsed().as_secs_f64() < self.budget_s && batches.len() < 200)
        {
            let t = Instant::now();
            for _ in 0..per_batch {
                f();
            }
            batches.push(t.elapsed().as_nanos() as f64 / per_batch as f64);
        }
        let (mean, std) = crate::util::stats::mean_std(&batches);
        let r = BenchResult {
            name: name.to_string(),
            mean_ns: mean,
            std_ns: std,
            iters: per_batch * batches.len() as u64,
        };
        println!("{:<44} {:>12}  ±{:<10} {:>9} iters", r.name, fmt_ns(r.mean_ns), fmt_ns(r.std_ns), r.iters);
        self.results.push(r);
    }

    /// Measure a closure that runs a whole end-to-end experiment once;
    /// samples exactly `n` runs (used for table benches where one run is
    /// seconds of virtual time but only ms of wall time).
    pub fn bench_n(&mut self, name: &str, n: usize, mut f: impl FnMut()) {
        let mut samples: Vec<f64> = Vec::new();
        for _ in 0..n.max(1) {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        let (mean, std) = crate::util::stats::mean_std(&samples);
        let r = BenchResult { name: name.to_string(), mean_ns: mean, std_ns: std, iters: n as u64 };
        println!("{:<44} {:>12}  ±{:<10} {:>9} runs", r.name, fmt_ns(r.mean_ns), fmt_ns(r.std_ns), r.iters);
        self.results.push(r);
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Emit results as JSON next to bench output (for the perf log).
    pub fn finish(self) {
        use crate::util::jsonio::Json;
        let arr: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                Json::obj()
                    .set("name", r.name.as_str())
                    .set("mean_ns", r.mean_ns)
                    .set("std_ns", r.std_ns)
                    .set("iters", r.iters)
            })
            .collect();
        let out = Json::obj().set("suite", self.title.as_str()).set("results", Json::Arr(arr));
        let dir = "target/bench-results";
        let _ = std::fs::create_dir_all(dir);
        let path = format!("{dir}/{}.json", self.title.replace([' ', '/'], "_"));
        let _ = out.write_file(&path);
        println!("-- wrote {path}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_ns_ranges() {
        assert_eq!(fmt_ns(5.0), "5.0 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2_500_000.0), "2.50 ms");
        assert_eq!(fmt_ns(3_200_000_000.0), "3.200 s");
    }

    #[test]
    fn bench_measures_something() {
        std::env::set_var("BENCH_BUDGET_S", "0.05");
        let mut s = Suite::new("selftest");
        let mut acc = 0u64;
        s.bench("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert_eq!(s.results().len(), 1);
        assert!(s.results()[0].mean_ns > 0.0);
    }
}
