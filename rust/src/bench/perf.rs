//! `bbsched bench` — the standardized scale/perf harness behind BENCH.json.
//!
//! Runs the full DES driver over a large-scale workload (default 10k and
//! 100k requests) for **every** strategy, measuring wall time, engine
//! throughput (events/s), timer-cancellation effectiveness, and a peak-RSS
//! proxy, then writes the results as `BENCH.json` so the repo accumulates a
//! perf trajectory across PRs. A per-strategy scaling exponent
//! (`ln(t_hi/t_lo) / ln(n_hi/n_lo)`) makes O(n²) regressions visible at a
//! glance: healthy hot paths stay near 1.0.
//!
//! Wall-time numbers are informational; the per-strategy scaling exponent
//! is gateable — `--gate-exponent X` fails the run if any strategy scales
//! worse than `n^X` between the smallest and largest size (CI pins 1.3,
//! loose enough for timer noise, tight enough to catch a quadratic
//! regression). `--shards N` adds a second leg running every strategy
//! against an N-shard heterogeneous pool with weighted selection, so the
//! sharded dispatch path accumulates its own perf trajectory; `--tenants M`
//! adds a third leg splitting the same offered load across M independent
//! client schedulers on the shared fleet (`run_tenants`), so tenant
//! scaling is recorded — and gated — alongside.

//! `--depth` adds a deep-queue leg: the same model-time horizon offered at
//! 4× and 16× rate under `AdaptiveDrr` (no overload shedding), so
//! steady-state queue depth scales ~4× between the two points, and the
//! per-release ordering work (`Ordering::select_work`, a deterministic
//! count of entries examined + index migrations) is fit against that depth
//! ratio — `--depth-gate-exponent X` fails the run if any heavy-class
//! ordering's work still scales like depth^X or worse (the incremental
//! ordering indexes keep it near 0; the old full scans sat near 1).
//!
//! `--partitions N` adds a partition-scaling leg: one large multi-tenant
//! run (`--partition-requests`, ~1M events at the default) executed at
//! partition counts 1, 2, 4, … N through the partitioned event loop
//! (`sim::partition`), recording wall time, speedup over serial, and the
//! counted synchronization work (windows, barrier crossings, replayed
//! ops, routed deliveries). Every partitioned run is digest-checked
//! against the serial run — bit-identical outputs are a hard failure,
//! not a gate — and `--speedup-gate X` fails the bench when the
//! 4-partition run is not ≥X× faster than serial (CI pins 2.0).
//!
//! `--timers` adds a timer-churn leg: a schedule/cancel-heavy synthetic
//! workload (the driver's timeout/retry pattern, distilled) run directly
//! against the `EventQueue` at the smallest and largest `--sizes` points,
//! recording the queue's counted structural work per operation
//! (`EventQueue::work` — placements, cascade moves, clock jumps, due
//! transfers; deterministic, immune to runner noise). The timer wheel's
//! O(1)-amortized claim means the ratio stays flat as the queue count
//! grows; `--timer-gate-exponent X` fails the run when
//! `ln(wpo_hi/wpo_lo) / ln(n_hi/n_lo)` exceeds `X` (CI pins 0.35 — flat
//! enough to catch any polynomial per-op regression; the old binary heap's
//! log factor is below the gate's resolution at smoke sizes, which is why
//! the gate is on *counted* work where the wheel sits near 0 by design).

use std::time::Instant;

use anyhow::{bail, Result};

use crate::bench::peak_rss_kb;
use crate::metrics::report::TextTable;
use crate::predictor::{InfoLevel, LadderSource, NoisySource, PriorSource};
use crate::provider::pool::PoolCfg;
use crate::provider::ProviderCfg;
use crate::scheduler::{OrderingCfg, OrderingKind, SchedulerCfg, ShardPolicy, StrategyKind};
use crate::sim::driver::{self, RunDiagnostics, TenantSpec};
use crate::sim::BackendKind;
use crate::sim::EventQueue;
use crate::sim::TimerId;
use crate::util::jsonio::Json;
use crate::util::rng::Rng;
use crate::workload::{ArrivalSpec, Mix, WorkloadSpec};

/// Rate multipliers for the `--depth` deep-queue leg. The low point already
/// sits past the congestion knee; the high point is the 16×-rate regime.
/// Request counts scale with the rate so both points cover the same
/// model-time horizon.
const DEPTH_MULT_LO: f64 = 4.0;
const DEPTH_MULT_HI: f64 = 16.0;

/// Noise level for the depth leg's continuous-prior cases: enough
/// multiplicative scatter that every request's prior bits are distinct, so
/// exact-bit grouping degenerates to one group per entry (the regime
/// quantized grouping exists to fix).
const DEPTH_NOISE_L: f64 = 0.4;

/// The partition leg's fixed workload shape: the paper's headline regime
/// distilled — many tenants on a wide fleet under congestion. Jitter and
/// congestion slowdown are zeroed so the lookahead window is the full
/// `base_ms` (the widest-window, best-case-for-parallelism physics; the
/// equivalence tests cover jittered fleets bit-for-bit).
const PARTITION_TENANTS: usize = 8;
const PARTITION_SHARDS: usize = 16;
const PARTITION_BASE_MS: f64 = 40.0;
const PARTITION_PER_TOKEN_MS: f64 = 0.02;
const PARTITION_CONCURRENCY: usize = 1_280;
const PARTITION_RATE_RPS: f64 = 20_000.0;

/// Scale-bench configuration (CLI-settable via `bbsched bench`).
#[derive(Debug, Clone)]
pub struct ScaleBenchOpts {
    /// Request counts to run, ascending; the scaling exponent compares the
    /// first and last.
    pub sizes: Vec<usize>,
    /// Offered arrival rate (req/s). The default sits in the paper's
    /// "high congestion" band so queues carry realistic depth.
    pub rate_rps: f64,
    pub mix: Mix,
    /// Arrival process for the scale and tenant legs (`--arrivals`);
    /// defaults to Poisson, the pre-storms baseline. The depth and
    /// partition legs keep their fixed distilled regimes.
    pub arrivals: ArrivalSpec,
    pub seed: u64,
    /// Where to write BENCH.json.
    pub out_path: String,
    /// Fleet size for the multi-shard leg (1 = single-endpoint legs only).
    pub shards: usize,
    /// Tenant count for the multi-tenant leg (1 = no extra leg): the same
    /// offered load split across M independent schedulers on the fleet.
    pub tenants: usize,
    /// Fail if any (strategy, shards, tenants) scaling exponent exceeds this.
    pub gate_exponent: Option<f64>,
    /// Run the deep-queue leg: per-release cost vs steady-state queue depth
    /// across the 4×/16×-rate points, one run per heavy-class ordering.
    pub depth: bool,
    /// Fail if any ordering's per-release cost scales worse than
    /// depth^this between the depth leg's two points (needs `depth`).
    pub depth_gate_exponent: Option<f64>,
    /// Run the timer-churn leg: a schedule/cancel-heavy workload driven
    /// directly against the `EventQueue` at the smallest and largest sizes,
    /// recording counted structural work per operation.
    pub timers: bool,
    /// Fail if the queue's counted work per operation scales worse than
    /// n^this between the timer leg's two sizes (needs `timers`).
    pub timer_gate_exponent: Option<f64>,
    /// Max partition count for the partition-scaling leg (1 = no leg):
    /// one large multi-tenant run executed at counts 1, 2, 4, … this,
    /// digest-checked bit-identical across counts.
    pub partitions: usize,
    /// Request count for the partition leg's workload (~4 events each; the
    /// default is the million-event regime).
    pub partition_requests: usize,
    /// Fail if the 4-partition run is not ≥this× faster than serial
    /// (needs `partitions >= 4`).
    pub speedup_gate: Option<f64>,
}

impl Default for ScaleBenchOpts {
    fn default() -> Self {
        ScaleBenchOpts {
            sizes: vec![10_000, 100_000],
            rate_rps: 20.0,
            mix: Mix::Balanced,
            arrivals: ArrivalSpec::Poisson,
            seed: 0,
            out_path: "BENCH.json".to_string(),
            shards: 1,
            tenants: 1,
            gate_exponent: None,
            depth: false,
            depth_gate_exponent: None,
            timers: false,
            timer_gate_exponent: None,
            partitions: 1,
            partition_requests: 250_000,
            speedup_gate: None,
        }
    }
}

struct RunRecord {
    strategy: &'static str,
    shards: usize,
    tenants: usize,
    requests: usize,
    wall_ms: f64,
    events_processed: u64,
    events_skipped: u64,
    timers_canceled: u64,
    events_per_sec: f64,
    sends: u64,
    completed: usize,
    rejected: usize,
    timed_out: usize,
    /// Process-lifetime VmHWM after this run — monotone across records
    /// (earlier memory-heavy runs dominate later readings).
    peak_rss_kb: u64,
    /// VmHWM growth attributable to this run (reading after − before);
    /// 0 when the run stayed under the previous high-water mark.
    peak_rss_growth_kb: u64,
}

impl RunRecord {
    fn to_json(&self) -> Json {
        Json::obj()
            .set("strategy", self.strategy)
            .set("shards", self.shards)
            .set("tenants", self.tenants)
            .set("requests", self.requests)
            .set("wall_ms", self.wall_ms)
            .set("events_processed", self.events_processed)
            .set("events_skipped", self.events_skipped)
            .set("timers_canceled", self.timers_canceled)
            .set("events_per_sec", self.events_per_sec)
            .set("sends", self.sends)
            .set("completed", self.completed)
            .set("rejected", self.rejected)
            .set("timed_out", self.timed_out)
            .set("peak_rss_kb", self.peak_rss_kb)
            .set("peak_rss_growth_kb", self.peak_rss_growth_kb)
    }
}

/// Run the scale bench: every strategy × every size × every fleet leg, one
/// shared workload per size (the paired-comparison guarantee), BENCH.json
/// at the end.
pub fn run_scale_bench(opts: &ScaleBenchOpts) -> Result<()> {
    anyhow::ensure!(!opts.sizes.is_empty(), "bench needs at least one size");
    anyhow::ensure!(opts.shards >= 1, "bench needs at least one shard");
    anyhow::ensure!(opts.tenants >= 1, "bench needs at least one tenant");
    // An armed gate that never evaluates an exponent would pass silently;
    // make that misuse loud instead.
    anyhow::ensure!(
        opts.gate_exponent.is_none()
            || (opts.sizes.len() >= 2 && opts.sizes.first() != opts.sizes.last()),
        "--gate-exponent needs at least two distinct sizes to compute a scaling exponent"
    );
    anyhow::ensure!(
        opts.depth || opts.depth_gate_exponent.is_none(),
        "--depth-gate-exponent needs --depth (the deep-queue leg it gates)"
    );
    anyhow::ensure!(
        opts.timers || opts.timer_gate_exponent.is_none(),
        "--timer-gate-exponent needs --timers (the timer-churn leg it gates)"
    );
    anyhow::ensure!(
        opts.timer_gate_exponent.is_none()
            || (opts.sizes.len() >= 2 && opts.sizes.first() != opts.sizes.last()),
        "--timer-gate-exponent needs at least two distinct sizes to compute a scaling exponent"
    );
    anyhow::ensure!(opts.partitions >= 1, "bench needs at least one partition");
    anyhow::ensure!(
        opts.speedup_gate.is_none() || opts.partitions >= 4,
        "--speedup-gate needs --partitions >= 4 (it compares the 4-partition leg to serial)"
    );
    anyhow::ensure!(
        opts.partitions == 1 || opts.partition_requests > 0,
        "--partitions needs a positive --partition-requests workload"
    );
    let mut records: Vec<RunRecord> = Vec::new();
    // Legs as (shards, tenants): the classic single endpoint, plus (when
    // asked) an N-shard heterogeneous pool driven with weighted selection —
    // the sharded dispatch path under the same workloads — plus (when
    // asked) the same load split across M tenant schedulers on that fleet.
    let mut legs: Vec<(usize, usize)> = vec![(1, 1)];
    if opts.shards > 1 {
        legs.push((opts.shards, 1));
    }
    if opts.tenants > 1 {
        legs.push((opts.shards.max(1), opts.tenants));
    }
    // With the exponent gate armed, each leg runs three times and the
    // *minimum* wall time is recorded — the standard noise-robust wall
    // estimator, which matters on shared CI runners where smoke-size legs
    // finish in single-digit milliseconds. Runs are deterministic, so the
    // repeats differ only in scheduler interference.
    let repeats = if opts.gate_exponent.is_some() { 3 } else { 1 };

    for &n in &opts.sizes {
        println!(
            "== scale bench: {n} requests, {} req/s, mix {} ==",
            opts.rate_rps,
            opts.mix.name()
        );
        let requests = WorkloadSpec::new(opts.mix, n, opts.rate_rps)
            .with_arrivals(opts.arrivals)
            .generate(opts.seed);
        for &(n_shards, n_tenants) in &legs {
            let pool = if n_shards == 1 {
                PoolCfg::single(ProviderCfg::default())
            } else {
                PoolCfg::heterogeneous(ProviderCfg::default(), n_shards, 0.5)
            };
            for strategy in StrategyKind::ALL {
                let rss_before = peak_rss_kb();
                let mut wall_s = f64::INFINITY;
                let mut last_out: Option<(RunDiagnostics, usize, usize, usize)> = None;
                for _ in 0..repeats {
                    let make_sched = || {
                        let mut sched = SchedulerCfg::for_strategy(strategy);
                        if n_shards > 1 {
                            sched.shards.policy = ShardPolicy::Weighted;
                        }
                        sched
                    };
                    if n_tenants == 1 {
                        let mut src = LadderSource::new(
                            InfoLevel::Coarse,
                            Rng::new(opts.seed ^ 0x5EED_50_u64).derive("priors"),
                        );
                        let t0 = Instant::now();
                        let o =
                            driver::run_pool(&requests, &mut src, make_sched(), &pool, opts.seed);
                        wall_s = wall_s.min(t0.elapsed().as_secs_f64());
                        last_out = Some((
                            o.diagnostics,
                            o.metrics.n_completed,
                            o.metrics.n_rejected,
                            o.metrics.n_timed_out,
                        ));
                    } else {
                        // The tenant leg's wall time includes each tenant's
                        // O(n) workload/prior generation (run_tenants owns
                        // its streams); exponents compare within the leg,
                        // so the accounting is consistent. The split
                        // conserves the total: this leg offers exactly `n`.
                        let specs: Vec<TenantSpec> = driver::split_requests(n, n_tenants)
                            .into_iter()
                            .map(|per_n| TenantSpec {
                                workload: WorkloadSpec::new(
                                    opts.mix,
                                    per_n,
                                    opts.rate_rps / n_tenants as f64,
                                )
                                .with_arrivals(opts.arrivals),
                                sched: make_sched(),
                                info: InfoLevel::Coarse,
                                noise: 0.0,
                            })
                            .collect();
                        let t0 = Instant::now();
                        let o = driver::run_tenants(&specs, &pool, opts.seed);
                        wall_s = wall_s.min(t0.elapsed().as_secs_f64());
                        let mut completed = 0usize;
                        let mut rejected = 0usize;
                        let mut timed_out = 0usize;
                        for t in &o.tenants {
                            completed += t.metrics.n_completed;
                            rejected += t.metrics.n_rejected;
                            timed_out += t.metrics.n_timed_out;
                        }
                        last_out = Some((o.diagnostics, completed, rejected, timed_out));
                    }
                }
                let (d, completed, rejected, timed_out) = last_out.expect("repeats >= 1");
                let rss_after = peak_rss_kb();
                let offered = completed + rejected + timed_out;
                let cr = if offered > rejected {
                    completed as f64 / (offered - rejected) as f64
                } else {
                    0.0
                };
                let rec = RunRecord {
                    strategy: strategy.name(),
                    shards: n_shards,
                    tenants: n_tenants,
                    requests: n,
                    wall_ms: wall_s * 1e3,
                    events_processed: d.events_processed,
                    events_skipped: d.events_skipped,
                    timers_canceled: d.timers_canceled,
                    events_per_sec: if wall_s > 0.0 {
                        d.events_processed as f64 / wall_s
                    } else {
                        0.0
                    },
                    sends: d.sends,
                    completed,
                    rejected,
                    timed_out,
                    peak_rss_kb: rss_after,
                    peak_rss_growth_kb: rss_after.saturating_sub(rss_before),
                };
                println!(
                    "  {:<16} x{:<2}t{:<2} {:>9.1} ms  {:>10.0} ev/s  {:>8} events  {:>6} canceled  CR {:.3}",
                    rec.strategy,
                    rec.shards,
                    rec.tenants,
                    rec.wall_ms,
                    rec.events_per_sec,
                    rec.events_processed,
                    rec.timers_canceled,
                    cr,
                );
                records.push(rec);
            }
        }
    }

    // Scaling exponents: first vs last size per (strategy, fleet). Near
    // 1.0 means the hot path is linear in offered load; 2.0 would be the
    // old O(n²).
    let mut scaling: Vec<Json> = Vec::new();
    let mut violations: Vec<String> = Vec::new();
    if opts.sizes.len() >= 2 {
        let n_lo = opts.sizes[0];
        let n_hi = *opts.sizes.last().unwrap();
        println!("\n-- scaling {n_lo} → {n_hi} (exponent ≈ 1.0 is linear) --");
        let mut t = TextTable::new([
            "strategy",
            "shards",
            "tenants",
            "wall lo (ms)",
            "wall hi (ms)",
            "exponent",
        ]);
        for &(n_shards, n_tenants) in &legs {
            for strategy in StrategyKind::ALL {
                let find = |n: usize| {
                    records
                        .iter()
                        .find(|r| {
                            r.strategy == strategy.name()
                                && r.shards == n_shards
                                && r.tenants == n_tenants
                                && r.requests == n
                        })
                        .map(|r| r.wall_ms)
                };
                if let (Some(lo), Some(hi)) = (find(n_lo), find(n_hi)) {
                    let exponent = if lo > 0.0 && hi > 0.0 {
                        (hi / lo).ln() / (n_hi as f64 / n_lo as f64).ln()
                    } else {
                        f64::NAN
                    };
                    t.row([
                        strategy.name().to_string(),
                        n_shards.to_string(),
                        n_tenants.to_string(),
                        format!("{lo:.1}"),
                        format!("{hi:.1}"),
                        format!("{exponent:.2}"),
                    ]);
                    scaling.push(
                        Json::obj()
                            .set("strategy", strategy.name())
                            .set("shards", n_shards)
                            .set("tenants", n_tenants)
                            .set("n_lo", n_lo)
                            .set("n_hi", n_hi)
                            .set("wall_lo_ms", lo)
                            .set("wall_hi_ms", hi)
                            .set("exponent", exponent),
                    );
                    if let Some(max_e) = opts.gate_exponent {
                        if exponent.is_finite() && exponent > max_e {
                            violations.push(format!(
                                "{} x{n_shards}t{n_tenants}: exponent {exponent:.2} > {max_e}",
                                strategy.name()
                            ));
                        }
                    }
                }
            }
        }
        println!("{}", t.render());
    }

    // ---- deep-queue leg: per-release cost vs steady-state queue depth ----
    //
    // `AdaptiveDrr` (ordering exercised, no overload shedding) at 4× and
    // 16× the base rate over one model-time horizon: queue depth scales
    // with the rate. The gated cost is `ordering_select_work / sends` —
    // entries examined (plus index migrations) per release, a *counted*
    // quantity, so the exponent `ln(cost_hi/cost_lo)/ln(depth_hi/depth_lo)`
    // is deterministic and immune to runner noise. The reference scans sat
    // at ~1 (every release walked the live queue); the incremental indexes
    // keep it near 0. Wall time rides along informationally.
    let mut depth_runs: Vec<Json> = Vec::new();
    let mut depth_scaling: Vec<Json> = Vec::new();
    if opts.depth {
        let n_hi = *opts.sizes.last().unwrap();
        println!(
            "\n== depth leg: {DEPTH_MULT_LO}x / {DEPTH_MULT_HI}x rate, one horizon, \
             select work per release =="
        );
        struct DepthPoint {
            wall_ms: f64,
            sends: u64,
            select_work: u64,
            mean_depth: f64,
            peak_depth: usize,
            group_count: u64,
            scan_fallbacks: u64,
        }
        /// One depth-leg configuration: a heavy-class ordering, the prior
        /// noise level it runs under, and whether its exponent is gated.
        struct DepthCase {
            label: &'static str,
            ordering: OrderingKind,
            noise: f64,
            quantized: bool,
            gated: bool,
        }
        // Every ordering under the discrete Coarse ladder (the designed
        // regime for exact-bit grouping), then the FeasibleSet index under
        // *continuous* noisy priors twice: quantized grouping (gated — the
        // bins must keep per-release work sublinear in depth) and exact
        // grouping (ungated contrast: one group per distinct prior
        // degenerates to a scan, the regime quantization exists to fix).
        let mut cases: Vec<DepthCase> = OrderingKind::ALL
            .iter()
            .map(|&ordering| DepthCase {
                label: ordering.name(),
                ordering,
                noise: 0.0,
                quantized: false,
                gated: true,
            })
            .collect();
        cases.push(DepthCase {
            label: "feasible_set_noisy_quant",
            ordering: OrderingKind::FeasibleSet,
            noise: DEPTH_NOISE_L,
            quantized: true,
            gated: true,
        });
        cases.push(DepthCase {
            label: "feasible_set_noisy_exact",
            ordering: OrderingKind::FeasibleSet,
            noise: DEPTH_NOISE_L,
            quantized: false,
            gated: false,
        });
        let mut t = TextTable::new([
            "ordering",
            "depth lo",
            "depth hi",
            "work/release lo",
            "work/release hi",
            "exponent",
        ]);
        for case in &cases {
            let mut points: Vec<DepthPoint> = Vec::new();
            for mult in [DEPTH_MULT_LO, DEPTH_MULT_HI] {
                let n = ((n_hi as f64) * mult / DEPTH_MULT_HI).round() as usize;
                let rate = opts.rate_rps * mult;
                let requests = WorkloadSpec::new(opts.mix, n, rate).generate(opts.seed);
                let root = Rng::new(opts.seed ^ 0x5EED_50_u64);
                let ladder = LadderSource::new(InfoLevel::Coarse, root.derive("priors"));
                let mut src: Box<dyn PriorSource> = if case.noise > 0.0 {
                    Box::new(NoisySource::new(ladder, case.noise, root.derive("noise")))
                } else {
                    Box::new(ladder)
                };
                let mut sched = SchedulerCfg::for_strategy(StrategyKind::AdaptiveDrr);
                sched.heavy_ordering = case.ordering;
                if case.quantized {
                    sched.ordering = OrderingCfg::quantized();
                }
                let pool = PoolCfg::single(ProviderCfg::default());
                let t0 = Instant::now();
                let o = driver::run_pool(&requests, src.as_mut(), sched, &pool, opts.seed);
                let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
                let p = DepthPoint {
                    wall_ms,
                    sends: o.diagnostics.sends,
                    select_work: o.diagnostics.ordering_select_work,
                    mean_depth: o.diagnostics.mean_queue_depth,
                    peak_depth: o.diagnostics.peak_queue_depth,
                    group_count: o.diagnostics.ordering_group_count,
                    scan_fallbacks: o.diagnostics.ordering_scan_fallbacks,
                };
                let wpr = if p.sends > 0 { p.select_work as f64 / p.sends as f64 } else { 0.0 };
                depth_runs.push(
                    Json::obj()
                        .set("ordering", case.label)
                        .set("noise", case.noise)
                        .set("quantized", case.quantized)
                        .set("rate_mult", mult)
                        .set("rate_rps", rate)
                        .set("requests", n)
                        .set("wall_ms", p.wall_ms)
                        .set("sends", p.sends)
                        .set("select_work", p.select_work)
                        .set("work_per_release", wpr)
                        .set("mean_queue_depth", p.mean_depth)
                        .set("peak_queue_depth", p.peak_depth)
                        .set("ordering_group_count", p.group_count)
                        .set("ordering_scan_fallbacks", p.scan_fallbacks),
                );
                points.push(p);
            }
            let (lo, hi) = (&points[0], &points[1]);
            let wpr_lo = if lo.sends > 0 { lo.select_work as f64 / lo.sends as f64 } else { 0.0 };
            let wpr_hi = if hi.sends > 0 { hi.select_work as f64 / hi.sends as f64 } else { 0.0 };
            let depth_ratio = hi.mean_depth / lo.mean_depth;
            let exponent = if wpr_lo > 0.0 && wpr_hi > 0.0 && depth_ratio > 0.0 {
                (wpr_hi / wpr_lo).ln() / depth_ratio.ln()
            } else {
                f64::NAN
            };
            t.row([
                case.label.to_string(),
                format!("{:.1}", lo.mean_depth),
                format!("{:.1}", hi.mean_depth),
                format!("{wpr_lo:.2}"),
                format!("{wpr_hi:.2}"),
                format!("{exponent:.2}"),
            ]);
            depth_scaling.push(
                Json::obj()
                    .set("ordering", case.label)
                    .set("gated", case.gated)
                    .set("depth_lo", lo.mean_depth)
                    .set("depth_hi", hi.mean_depth)
                    .set("work_per_release_lo", wpr_lo)
                    .set("work_per_release_hi", wpr_hi)
                    .set("exponent", exponent),
            );
            if let Some(max_e) = opts.depth_gate_exponent {
                // Gate only when the two points actually built materially
                // different depths — otherwise the log-ratio fit is noise.
                // The noisy exact-grouping contrast is exempt: its scan
                // regression is the behavior being demonstrated.
                if case.gated && depth_ratio >= 2.0 && exponent.is_finite() && exponent > max_e {
                    violations.push(format!(
                        "depth {}: per-release work exponent {exponent:.2} > {max_e} \
                         (depth {:.0} -> {:.0})",
                        case.label,
                        lo.mean_depth,
                        hi.mean_depth,
                    ));
                }
            }
        }
        println!("{}", t.render());
    }

    // ---- timer-churn leg: event-queue work per op vs queue population ----
    //
    // The driver's timer pattern distilled (see `timer_churn_point`), run
    // directly against the `EventQueue` at the smallest and largest sizes.
    // The gated cost is `EventQueue::work / ops` — counted placements,
    // cascade moves, clock jumps, and due transfers per push/cancel/pop —
    // so the exponent is deterministic and immune to runner noise. The
    // wheel sits near 0 (O(1) amortized); a superlinear structure on the
    // event-queue hot path would push it up.
    let mut timer_runs: Vec<Json> = Vec::new();
    let mut timer_scaling: Vec<Json> = Vec::new();
    if opts.timers {
        let n_lo = opts.sizes[0];
        let n_hi = *opts.sizes.last().unwrap();
        println!("\n== timer leg: schedule/cancel churn at {n_lo} / {n_hi} requests ==");
        let churn_sizes: Vec<usize> = if n_lo == n_hi { vec![n_hi] } else { vec![n_lo, n_hi] };
        let mut t =
            TextTable::new(["requests", "work", "ops", "work/op", "wall (ms)", "backend"]);
        let mut points: Vec<(usize, TimerPoint)> = Vec::new();
        for &n in &churn_sizes {
            let p = timer_churn_point(n, opts.seed);
            let wpo = if p.ops > 0 { p.work as f64 / p.ops as f64 } else { 0.0 };
            t.row([
                n.to_string(),
                p.work.to_string(),
                p.ops.to_string(),
                format!("{wpo:.2}"),
                format!("{:.1}", p.wall_ms),
                p.backend.to_string(),
            ]);
            timer_runs.push(
                Json::obj()
                    .set("requests", n)
                    .set("wall_ms", p.wall_ms)
                    .set("work", p.work)
                    .set("ops", p.ops)
                    .set("work_per_op", wpo)
                    .set("events_processed", p.processed)
                    .set("events_skipped", p.skipped)
                    .set("backend", p.backend),
            );
            points.push((n, p));
        }
        println!("{}", t.render());
        if let [(lo_n, lo), (hi_n, hi)] = &points[..] {
            let wpo_lo = if lo.ops > 0 { lo.work as f64 / lo.ops as f64 } else { 0.0 };
            let wpo_hi = if hi.ops > 0 { hi.work as f64 / hi.ops as f64 } else { 0.0 };
            let exponent = if wpo_lo > 0.0 && wpo_hi > 0.0 {
                (wpo_hi / wpo_lo).ln() / (*hi_n as f64 / *lo_n as f64).ln()
            } else {
                f64::NAN
            };
            println!("timer work/op exponent {lo_n} -> {hi_n}: {exponent:.3}");
            timer_scaling.push(
                Json::obj()
                    .set("n_lo", *lo_n)
                    .set("n_hi", *hi_n)
                    .set("work_per_op_lo", wpo_lo)
                    .set("work_per_op_hi", wpo_hi)
                    .set("exponent", exponent)
                    .set("backend", lo.backend),
            );
            if let Some(max_e) = opts.timer_gate_exponent {
                if exponent.is_finite() && exponent > max_e {
                    violations.push(format!(
                        "timers: work/op exponent {exponent:.3} > {max_e} \
                         ({wpo_lo:.2} -> {wpo_hi:.2})"
                    ));
                }
            }
        }
    }

    // ---- partition leg: one big run across 1, 2, 4, … N event loops ----
    //
    // The same multi-tenant workload executed through the partitioned
    // executor at each count. Outputs must be bit-identical across counts
    // (digest-checked — a mismatch is a correctness bug, failed
    // immediately), so the only thing the sweep measures is wall time and
    // the counted synchronization overhead.
    let mut partition_runs: Vec<Json> = Vec::new();
    let mut partition_scaling: Vec<Json> = Vec::new();
    if opts.partitions > 1 {
        let n = opts.partition_requests;
        println!(
            "\n== partition leg: {n} requests, {PARTITION_TENANTS} tenants, \
             {PARTITION_SHARDS} shards, up to {} partitions ==",
            opts.partitions
        );
        let mut counts = vec![1usize];
        let mut c = 2usize;
        while c < opts.partitions {
            counts.push(c);
            c *= 2;
        }
        counts.push(opts.partitions);
        let shard = ProviderCfg {
            base_ms: PARTITION_BASE_MS,
            per_token_ms: PARTITION_PER_TOKEN_MS,
            max_concurrency: PARTITION_CONCURRENCY,
            jitter_sigma: 0.0,
            slowdown_gamma: 0.0,
            ..ProviderCfg::default()
        };
        let pool = PoolCfg::split(shard, PARTITION_SHARDS);
        let specs: Vec<TenantSpec> = driver::split_requests(n, PARTITION_TENANTS)
            .into_iter()
            .map(|per_n| TenantSpec {
                workload: WorkloadSpec::new(
                    opts.mix,
                    per_n,
                    PARTITION_RATE_RPS / PARTITION_TENANTS as f64,
                ),
                sched: SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc),
                info: InfoLevel::Coarse,
                noise: 0.0,
            })
            .collect();
        let repeats = if opts.speedup_gate.is_some() { 3 } else { 1 };
        let mut t = TextTable::new([
            "partitions",
            "wall (ms)",
            "speedup",
            "windows",
            "win/1k ev",
            "barriers",
            "ops replayed",
            "deliveries",
        ]);
        let mut serial_wall_ms: Option<f64> = None;
        let mut serial_digest: Option<u64> = None;
        let mut wall_by_count: Vec<(usize, f64)> = Vec::new();
        for &pcount in &counts {
            let mut wall_s = f64::INFINITY;
            let mut last: Option<driver::MultiRunOutput> = None;
            for _ in 0..repeats {
                let t0 = Instant::now();
                let o = driver::run_tenants_partitioned(&specs, &pool, opts.seed, pcount);
                wall_s = wall_s.min(t0.elapsed().as_secs_f64());
                last = Some(o);
            }
            let o = last.expect("repeats >= 1");
            let digest = digest_multi(&o);
            match serial_digest {
                None => serial_digest = Some(digest),
                Some(want) => {
                    if digest != want {
                        bail!(
                            "partition leg: {pcount}-partition output diverged from serial \
                             (digest {digest:#x} != {want:#x}) — the bit-compat contract is \
                             broken, see tests/partition_equivalence.rs"
                        );
                    }
                }
            }
            let wall_ms = wall_s * 1e3;
            let speedup = match serial_wall_ms {
                None => {
                    serial_wall_ms = Some(wall_ms);
                    1.0
                }
                Some(serial) => serial / wall_ms,
            };
            wall_by_count.push((pcount, wall_ms));
            let ps = &o.partition;
            let windows_per_1k = if o.diagnostics.events_processed > 0 {
                ps.windows as f64 * 1_000.0 / o.diagnostics.events_processed as f64
            } else {
                0.0
            };
            t.row([
                format!("{pcount} ({} ran)", ps.partitions),
                format!("{wall_ms:.1}"),
                format!("{speedup:.2}x"),
                ps.windows.to_string(),
                format!("{windows_per_1k:.2}"),
                ps.barrier_crossings.to_string(),
                ps.ops_routed.to_string(),
                ps.deliveries.to_string(),
            ]);
            partition_runs.push(
                Json::obj()
                    .set("partitions", pcount)
                    .set("partitions_effective", ps.partitions)
                    .set(
                        "serial_fallback",
                        ps.serial_fallback.map(|r| r.as_str()).unwrap_or("none"),
                    )
                    .set("requests", n)
                    .set("wall_ms", wall_ms)
                    .set("speedup", speedup)
                    .set("events_processed", o.diagnostics.events_processed)
                    .set(
                        "events_per_sec",
                        if wall_s > 0.0 {
                            o.diagnostics.events_processed as f64 / wall_s
                        } else {
                            0.0
                        },
                    )
                    .set("lookahead_ms", ps.lookahead_ms)
                    .set("windows", ps.windows)
                    .set("windows_per_1k_events", windows_per_1k)
                    .set("barrier_crossings", ps.barrier_crossings)
                    .set("ops_routed", ps.ops_routed)
                    .set("deliveries", ps.deliveries)
                    .set("boundary_deferrals", ps.boundary_deferrals),
            );
        }
        println!("{}", t.render());
        let serial = serial_wall_ms.expect("serial leg ran");
        for &(pcount, wall_ms) in wall_by_count.iter().skip(1) {
            partition_scaling.push(
                Json::obj()
                    .set("partitions", pcount)
                    .set("requests", n)
                    .set("serial_wall_ms", serial)
                    .set("wall_ms", wall_ms)
                    .set("speedup", serial / wall_ms),
            );
        }
        if let Some(min_speedup) = opts.speedup_gate {
            let p4 = wall_by_count.iter().find(|&&(pc, _)| pc == 4);
            match p4 {
                Some(&(_, wall_ms)) => {
                    let speedup = serial / wall_ms;
                    if speedup < min_speedup {
                        violations.push(format!(
                            "partitions: 4-partition speedup {speedup:.2}x < {min_speedup}x \
                             (serial {serial:.1} ms, partitioned {wall_ms:.1} ms)"
                        ));
                    }
                }
                None => violations.push(
                    "partitions: --speedup-gate armed but no 4-partition leg ran".to_string(),
                ),
            }
        }
    }

    let mut doc = Json::obj()
        .set("bench", "scale")
        .set("mix", opts.mix.name())
        .set("arrivals", opts.arrivals.name())
        .set("rate_rps", opts.rate_rps)
        .set("seed", opts.seed)
        .set("shards", opts.shards)
        .set("tenants", opts.tenants)
        .set("partitions", opts.partitions)
        .set("sizes", opts.sizes.clone())
        .set("runs", Json::Arr(records.iter().map(RunRecord::to_json).collect()))
        .set("scaling", Json::Arr(scaling));
    if opts.depth {
        doc = doc
            .set("depth_runs", Json::Arr(depth_runs))
            .set("depth_scaling", Json::Arr(depth_scaling));
    }
    if opts.timers {
        doc = doc
            .set("timer_runs", Json::Arr(timer_runs))
            .set("timer_scaling", Json::Arr(timer_scaling));
    }
    if opts.partitions > 1 {
        doc = doc
            .set("partition_runs", Json::Arr(partition_runs))
            .set("partition_scaling", Json::Arr(partition_scaling));
    }
    doc.write_file(&opts.out_path)?;
    println!("wrote {}", opts.out_path);
    if !violations.is_empty() {
        bail!("scaling gate failed: {}", violations.join("; "));
    }
    Ok(())
}

/// FNV-1a over u64 words — a stable digest for the partition leg's
/// bit-identity check (no dependency, no hashing of padding bytes).
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }
    fn put(&mut self, x: u64) {
        for b in x.to_le_bytes() {
            self.0 ^= u64::from(b);
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
}

/// Digest everything the run output that must be bit-identical across
/// partition counts: per-request outcomes (status, latency *bits*, defer
/// counts), per-tenant sends, and the full engine diagnostics including
/// the f64 depth integral.
fn digest_multi(o: &driver::MultiRunOutput) -> u64 {
    let mut h = Fnv::new();
    for t in &o.tenants {
        h.put(t.sends);
        h.put(t.metrics.n_completed as u64);
        h.put(t.metrics.n_rejected as u64);
        h.put(t.metrics.n_timed_out as u64);
        for oc in &t.outcomes {
            h.put(oc.id as u64);
            h.put(oc.status as u64);
            h.put(oc.latency_ms.map_or(u64::MAX, f64::to_bits));
            h.put(u64::from(oc.defer_count));
        }
    }
    let d = &o.diagnostics;
    h.put(d.events_processed);
    h.put(d.events_skipped);
    h.put(d.timers_canceled);
    h.put(d.sends);
    h.put(d.peak_provider_queue as u64);
    h.put(d.peak_inflight as u64);
    for &s in &d.started_by_shard {
        h.put(s);
    }
    h.put(d.mean_queue_depth.to_bits());
    h.put(d.peak_queue_depth as u64);
    h.put(d.ordering_select_work);
    h.put(d.ordering_group_count);
    h.put(d.ordering_scan_fallbacks);
    h.put(d.retries_scheduled);
    h.put(d.faulted_shard_ms.to_bits());
    h.0
}

/// One timer-churn measurement.
struct TimerPoint {
    /// Wall time for the point — informational, not gated.
    wall_ms: f64,
    /// `EventQueue::work` at the end: counted structural work.
    work: u64,
    /// Operations issued against the queue (pushes + cancels + pops).
    ops: u64,
    /// Live entries popped (`EventQueue::processed`).
    processed: u64,
    /// Dead (canceled) entries discarded (`EventQueue::skipped`).
    skipped: u64,
    /// Which backend served the run (`wheel` unless overridden by env).
    backend: &'static str,
}

/// One timer-churn point: `n` requests' worth of the driver's timer
/// pattern — an arrival event plus a cancelable timeout per request, most
/// timeouts canceled shortly after ("completions"), a quarter of those
/// re-armed as short retry timers, and the clock drained up to each
/// arrival — then a full drain. Work and op counts are deterministic for a
/// given `(n, seed)`; only `wall_ms` carries runner noise.
fn timer_churn_point(n: usize, seed: u64) -> TimerPoint {
    let mut q: EventQueue<usize> = EventQueue::new();
    let mut rng = Rng::new(seed).derive("timer_churn");
    let mut live: Vec<TimerId> = Vec::new();
    let mut ops: u64 = 0;
    let mut now = 0.0_f64;
    let t0 = Instant::now();
    for i in 0..n {
        now += rng.exp(0.02); // ~50 ms between arrivals
        q.push(now, i);
        live.push(q.push_cancelable(now + rng.range(5_000.0, 30_000.0), i));
        ops += 2;
        // Cancel a random live timeout (a "completion") and sometimes
        // re-arm a short retry timer — the schedule/cancel churn itself.
        if live.len() >= 8 {
            let id = live.swap_remove(rng.index(live.len()));
            q.cancel(id);
            ops += 1;
            if rng.index(4) == 0 {
                live.push(q.push_cancelable(now + rng.range(50.0, 1_000.0), i));
                ops += 1;
            }
        }
        while q.peek_time().is_some_and(|t| t <= now) {
            q.pop();
            ops += 1;
        }
    }
    while q.pop().is_some() {
        ops += 1;
    }
    TimerPoint {
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        work: q.work(),
        ops,
        processed: q.processed(),
        skipped: q.skipped(),
        backend: match q.backend() {
            BackendKind::Wheel => "wheel",
            BackendKind::Heap => "heap",
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_bench_runs_and_writes_json() {
        let out_path = std::env::temp_dir().join("bbsched_bench_test.json");
        let opts = ScaleBenchOpts {
            sizes: vec![40, 80],
            rate_rps: 12.0,
            out_path: out_path.to_string_lossy().into_owned(),
            ..ScaleBenchOpts::default()
        };
        run_scale_bench(&opts).expect("bench runs");
        let doc = Json::read_file(&opts.out_path).expect("BENCH.json parses");
        let runs = doc.get("runs").and_then(Json::as_arr).expect("runs array");
        assert_eq!(runs.len(), 2 * StrategyKind::ALL.len());
        let scaling = doc.get("scaling").and_then(Json::as_arr).expect("scaling array");
        assert_eq!(scaling.len(), StrategyKind::ALL.len());
        for r in runs {
            assert!(r.get("wall_ms").and_then(Json::as_f64).unwrap() >= 0.0);
            let n = r.get("requests").and_then(Json::as_usize).unwrap();
            let done = r.get("completed").and_then(Json::as_usize).unwrap()
                + r.get("rejected").and_then(Json::as_usize).unwrap()
                + r.get("timed_out").and_then(Json::as_usize).unwrap();
            assert_eq!(done, n, "conservation in bench records");
        }
        let _ = std::fs::remove_file(&opts.out_path);
    }

    #[test]
    fn multi_shard_leg_doubles_the_record_count() {
        let out_path = std::env::temp_dir().join("bbsched_bench_shard_test.json");
        let opts = ScaleBenchOpts {
            sizes: vec![40, 80],
            rate_rps: 12.0,
            shards: 2,
            gate_exponent: Some(50.0), // far above any real exponent
            out_path: out_path.to_string_lossy().into_owned(),
            ..ScaleBenchOpts::default()
        };
        run_scale_bench(&opts).expect("bench runs");
        let doc = Json::read_file(&opts.out_path).expect("BENCH.json parses");
        let runs = doc.get("runs").and_then(Json::as_arr).expect("runs array");
        assert_eq!(runs.len(), 2 * 2 * StrategyKind::ALL.len(), "sizes × fleets × strategies");
        let scaling = doc.get("scaling").and_then(Json::as_arr).expect("scaling array");
        assert_eq!(scaling.len(), 2 * StrategyKind::ALL.len(), "one exponent per fleet");
        for s in scaling {
            let n = s.get("shards").and_then(Json::as_usize).unwrap();
            assert!(n == 1 || n == 2);
        }
        let _ = std::fs::remove_file(&opts.out_path);
    }

    #[test]
    fn tenant_leg_adds_records_and_exponents() {
        let out_path = std::env::temp_dir().join("bbsched_bench_tenant_test.json");
        let opts = ScaleBenchOpts {
            sizes: vec![40, 80],
            rate_rps: 12.0,
            tenants: 2,
            gate_exponent: Some(50.0), // far above any real exponent
            out_path: out_path.to_string_lossy().into_owned(),
            ..ScaleBenchOpts::default()
        };
        run_scale_bench(&opts).expect("bench runs");
        let doc = Json::read_file(&opts.out_path).expect("BENCH.json parses");
        let runs = doc.get("runs").and_then(Json::as_arr).expect("runs array");
        assert_eq!(runs.len(), 2 * 2 * StrategyKind::ALL.len(), "sizes × legs × strategies");
        let tenant_runs: Vec<_> = runs
            .iter()
            .filter(|r| r.get("tenants").and_then(Json::as_usize) == Some(2))
            .collect();
        assert_eq!(tenant_runs.len(), 2 * StrategyKind::ALL.len());
        for r in &tenant_runs {
            let n = r.get("requests").and_then(Json::as_usize).unwrap();
            let done = r.get("completed").and_then(Json::as_usize).unwrap()
                + r.get("rejected").and_then(Json::as_usize).unwrap()
                + r.get("timed_out").and_then(Json::as_usize).unwrap();
            // split_requests conserves the fleet-wide total exactly.
            assert_eq!(done, n, "conservation across tenants");
        }
        let scaling = doc.get("scaling").and_then(Json::as_arr).expect("scaling array");
        assert_eq!(scaling.len(), 2 * StrategyKind::ALL.len(), "one exponent per leg");
        assert!(scaling
            .iter()
            .any(|s| s.get("tenants").and_then(Json::as_usize) == Some(2)));
        let _ = std::fs::remove_file(&opts.out_path);
    }

    #[test]
    fn depth_leg_records_runs_and_exponents() {
        let out_path = std::env::temp_dir().join("bbsched_bench_depth_test.json");
        let opts = ScaleBenchOpts {
            sizes: vec![40, 80],
            rate_rps: 12.0,
            depth: true,
            out_path: out_path.to_string_lossy().into_owned(),
            ..ScaleBenchOpts::default()
        };
        run_scale_bench(&opts).expect("bench runs");
        let doc = Json::read_file(&opts.out_path).expect("BENCH.json parses");
        let runs = doc.get("depth_runs").and_then(Json::as_arr).expect("depth_runs array");
        // Every ordering plus the two noisy-prior FeasibleSet cases, two
        // rate points each.
        let n_cases = OrderingKind::ALL.len() + 2;
        assert_eq!(runs.len(), 2 * n_cases, "two rate points per depth case");
        for r in runs {
            assert!(r.get("mean_queue_depth").and_then(Json::as_f64).unwrap() >= 0.0);
            assert!(r.get("sends").and_then(Json::as_u64).unwrap() > 0, "releases happened");
        }
        let noisy: Vec<_> = runs
            .iter()
            .filter(|r| r.get("noise").and_then(Json::as_f64) == Some(DEPTH_NOISE_L))
            .collect();
        assert_eq!(noisy.len(), 4, "quant + exact noisy cases, two points each");
        let scaling = doc.get("depth_scaling").and_then(Json::as_arr).expect("depth_scaling");
        assert_eq!(scaling.len(), n_cases, "one exponent per depth case");
        assert!(
            scaling.iter().any(|s| {
                s.get("ordering").and_then(Json::as_str) == Some("feasible_set_noisy_exact")
                    && s.get("gated").and_then(Json::as_bool) == Some(false)
            }),
            "the exact-grouping noisy contrast rides along ungated"
        );
        let _ = std::fs::remove_file(&opts.out_path);
    }

    #[test]
    fn depth_gate_requires_depth_leg() {
        let opts = ScaleBenchOpts {
            sizes: vec![40, 80],
            depth: false,
            depth_gate_exponent: Some(0.8),
            out_path: "/tmp/bbsched_bench_depth_gate.json".to_string(),
            ..ScaleBenchOpts::default()
        };
        let err = run_scale_bench(&opts).expect_err("gate without the leg it gates");
        assert!(err.to_string().contains("--depth"), "{err}");
    }

    #[test]
    fn impossible_depth_gate_fails_when_queues_deepen() {
        let out_path = std::env::temp_dir().join("bbsched_bench_depth_gate_fail.json");
        let opts = ScaleBenchOpts {
            sizes: vec![40, 160],
            rate_rps: 12.0,
            depth: true,
            // Any finite exponent exceeds this ceiling; the gate only arms
            // when the two points build materially different depths, which
            // a 4x rate gap at these rates does.
            depth_gate_exponent: Some(-100.0),
            out_path: out_path.to_string_lossy().into_owned(),
            ..ScaleBenchOpts::default()
        };
        assert!(run_scale_bench(&opts).is_err(), "depth gate must trip");
        let _ = std::fs::remove_file(&out_path.to_string_lossy().into_owned());
    }

    #[test]
    fn timer_leg_records_runs_and_exponent() {
        let out_path = std::env::temp_dir().join("bbsched_bench_timer_test.json");
        let opts = ScaleBenchOpts {
            sizes: vec![200, 1_000],
            rate_rps: 12.0,
            timers: true,
            timer_gate_exponent: Some(0.35), // the CI gate value must hold here too
            out_path: out_path.to_string_lossy().into_owned(),
            ..ScaleBenchOpts::default()
        };
        run_scale_bench(&opts).expect("bench runs under the armed timer gate");
        let doc = Json::read_file(&opts.out_path).expect("BENCH.json parses");
        let runs = doc.get("timer_runs").and_then(Json::as_arr).expect("timer_runs array");
        assert_eq!(runs.len(), 2, "one point per size");
        for r in runs {
            assert!(r.get("work").and_then(Json::as_u64).unwrap() > 0, "work counted");
            assert!(r.get("ops").and_then(Json::as_u64).unwrap() > 0, "ops counted");
            assert!(r.get("work_per_op").and_then(Json::as_f64).unwrap() > 0.0);
        }
        let scaling = doc.get("timer_scaling").and_then(Json::as_arr).expect("timer_scaling");
        assert_eq!(scaling.len(), 1, "one work/op exponent");
        let e = scaling[0].get("exponent").and_then(Json::as_f64).unwrap();
        assert!(e.is_finite(), "counted work yields a finite exponent, got {e}");
        let _ = std::fs::remove_file(&opts.out_path);
    }

    #[test]
    fn timer_gate_requires_timer_leg() {
        let opts = ScaleBenchOpts {
            sizes: vec![40, 80],
            timers: false,
            timer_gate_exponent: Some(0.35),
            out_path: "/tmp/bbsched_bench_timer_gate.json".to_string(),
            ..ScaleBenchOpts::default()
        };
        let err = run_scale_bench(&opts).expect_err("gate without the leg it gates");
        assert!(err.to_string().contains("--timers"), "{err}");
    }

    #[test]
    fn impossible_timer_gate_fails_on_churn() {
        let out_path = std::env::temp_dir().join("bbsched_bench_timer_gate_fail.json");
        let opts = ScaleBenchOpts {
            sizes: vec![200, 1_000],
            rate_rps: 12.0,
            timers: true,
            // Any finite exponent exceeds this ceiling, so the gate must
            // trip — this is the CI failure path for the timer leg.
            timer_gate_exponent: Some(f64::NEG_INFINITY),
            out_path: out_path.to_string_lossy().into_owned(),
            ..ScaleBenchOpts::default()
        };
        assert!(run_scale_bench(&opts).is_err(), "timer gate must trip");
        let _ = std::fs::remove_file(&out_path);
    }

    #[test]
    fn timer_churn_work_is_deterministic() {
        let a = timer_churn_point(500, 7);
        let b = timer_churn_point(500, 7);
        assert_eq!(a.work, b.work, "counted work must not carry runner noise");
        assert_eq!(a.ops, b.ops);
        assert_eq!(a.processed, b.processed);
        assert_eq!(a.skipped, b.skipped);
        assert!(a.skipped > 0, "churn actually cancels timers");
    }

    #[test]
    fn impossible_exponent_gate_fails_the_bench() {
        let out_path = std::env::temp_dir().join("bbsched_bench_gate_test.json");
        let opts = ScaleBenchOpts {
            sizes: vec![40, 160],
            rate_rps: 12.0,
            // Any finite exponent exceeds this ceiling, so the gate must
            // trip — this is the CI failure path.
            gate_exponent: Some(-100.0),
            out_path: out_path.to_string_lossy().into_owned(),
            ..ScaleBenchOpts::default()
        };
        assert!(run_scale_bench(&opts).is_err(), "gate must fail on exceeded exponent");
        let _ = std::fs::remove_file(&opts.out_path);
    }

    #[test]
    fn armed_gate_needs_two_distinct_sizes() {
        for sizes in [vec![100_000], vec![5_000, 5_000]] {
            let opts = ScaleBenchOpts {
                sizes,
                gate_exponent: Some(1.3),
                out_path: "/tmp/bbsched_bench_inert_gate.json".to_string(),
                ..ScaleBenchOpts::default()
            };
            let err = run_scale_bench(&opts).expect_err("gate with no evaluable exponent");
            assert!(err.to_string().contains("two distinct sizes"), "{err}");
        }
    }

    #[test]
    fn partition_leg_records_sweep_and_bitwise_identity() {
        let out_path = std::env::temp_dir().join("bbsched_bench_partition_test.json");
        let opts = ScaleBenchOpts {
            sizes: vec![40],
            rate_rps: 12.0,
            partitions: 4,
            partition_requests: 2_000,
            out_path: out_path.to_string_lossy().into_owned(),
            ..ScaleBenchOpts::default()
        };
        // The leg digest-checks every partitioned run against serial and
        // bails on divergence, so success here *is* the identity check.
        run_scale_bench(&opts).expect("bench runs with identical partitioned outputs");
        let doc = Json::read_file(&opts.out_path).expect("BENCH.json parses");
        let runs = doc.get("partition_runs").and_then(Json::as_arr).expect("partition_runs");
        assert_eq!(runs.len(), 3, "counts 1, 2, 4");
        for r in runs {
            assert!(r.get("wall_ms").and_then(Json::as_f64).unwrap() >= 0.0);
            assert!(r.get("lookahead_ms").and_then(Json::as_f64).unwrap() > 0.0);
            let req = r.get("partitions").and_then(Json::as_usize).unwrap();
            let ran = r.get("partitions_effective").and_then(Json::as_usize).unwrap();
            let fallback = r.get("serial_fallback").and_then(Json::as_str).unwrap();
            if req > 1 {
                assert_eq!(fallback, "none", "the parallel path must really run");
                assert_eq!(ran, req, "no fallback: the parallel path must really run");
                let windows = r.get("windows").and_then(Json::as_u64).unwrap();
                assert!(windows > 0);
                let per_1k = r.get("windows_per_1k_events").and_then(Json::as_f64).unwrap();
                assert!(per_1k > 0.0 && per_1k.is_finite(), "windows_per_1k_events {per_1k}");
                assert!(r.get("ops_routed").and_then(Json::as_u64).unwrap() > 0);
            } else {
                assert_eq!(fallback, "not_requested", "count 1 is serial by request");
                assert_eq!(ran, 1);
            }
        }
        let scaling =
            doc.get("partition_scaling").and_then(Json::as_arr).expect("partition_scaling");
        assert_eq!(scaling.len(), 2, "speedup entries for counts 2 and 4");
        for s in scaling {
            assert!(s.get("speedup").and_then(Json::as_f64).unwrap() > 0.0);
        }
        let _ = std::fs::remove_file(&opts.out_path);
    }

    #[test]
    fn speedup_gate_requires_partition_leg() {
        let opts = ScaleBenchOpts {
            sizes: vec![40, 80],
            partitions: 2, // < 4: the gate's comparison point never runs
            speedup_gate: Some(2.0),
            out_path: "/tmp/bbsched_bench_speedup_gate.json".to_string(),
            ..ScaleBenchOpts::default()
        };
        let err = run_scale_bench(&opts).expect_err("gate without its 4-partition leg");
        assert!(err.to_string().contains("--partitions"), "{err}");
    }

    #[test]
    fn impossible_speedup_gate_fails_the_bench() {
        let out_path = std::env::temp_dir().join("bbsched_bench_speedup_gate_fail.json");
        let opts = ScaleBenchOpts {
            sizes: vec![40],
            rate_rps: 12.0,
            partitions: 4,
            partition_requests: 2_000,
            // No real machine turns 4 partitions into a billion-fold
            // speedup; the gate must trip — the CI failure path.
            speedup_gate: Some(1e9),
            out_path: out_path.to_string_lossy().into_owned(),
            ..ScaleBenchOpts::default()
        };
        let err = run_scale_bench(&opts).expect_err("speedup gate must trip");
        assert!(err.to_string().contains("speedup"), "{err}");
        let _ = std::fs::remove_file(&out_path);
    }

    #[test]
    fn peak_rss_proxy_is_sane() {
        let kb = peak_rss_kb();
        // Either procfs is absent (0) or we report something plausible.
        assert!(kb == 0 || kb > 100, "peak_rss_kb = {kb}");
    }
}
