//! `bbsched bench` — the standardized scale/perf harness behind BENCH.json.
//!
//! Runs the full DES driver over a large-scale workload (default 10k and
//! 100k requests) for **every** strategy, measuring wall time, engine
//! throughput (events/s), timer-cancellation effectiveness, and a peak-RSS
//! proxy, then writes the results as `BENCH.json` so the repo accumulates a
//! perf trajectory across PRs. A per-strategy scaling exponent
//! (`ln(t_hi/t_lo) / ln(n_hi/n_lo)`) makes O(n²) regressions visible at a
//! glance: healthy hot paths stay near 1.0.
//!
//! Numbers are informational, not gating — CI runs `bbsched bench --smoke`
//! and fails only on panic, uploading BENCH.json as an artifact.

use std::time::Instant;

use anyhow::Result;

use crate::bench::peak_rss_kb;
use crate::metrics::report::TextTable;
use crate::predictor::{InfoLevel, LadderSource};
use crate::provider::ProviderCfg;
use crate::scheduler::{SchedulerCfg, StrategyKind};
use crate::sim::driver;
use crate::util::jsonio::Json;
use crate::util::rng::Rng;
use crate::workload::{Mix, WorkloadSpec};

/// Scale-bench configuration (CLI-settable via `bbsched bench`).
#[derive(Debug, Clone)]
pub struct ScaleBenchOpts {
    /// Request counts to run, ascending; the scaling exponent compares the
    /// first and last.
    pub sizes: Vec<usize>,
    /// Offered arrival rate (req/s). The default sits in the paper's
    /// "high congestion" band so queues carry realistic depth.
    pub rate_rps: f64,
    pub mix: Mix,
    pub seed: u64,
    /// Where to write BENCH.json.
    pub out_path: String,
}

impl Default for ScaleBenchOpts {
    fn default() -> Self {
        ScaleBenchOpts {
            sizes: vec![10_000, 100_000],
            rate_rps: 20.0,
            mix: Mix::Balanced,
            seed: 0,
            out_path: "BENCH.json".to_string(),
        }
    }
}

struct RunRecord {
    strategy: &'static str,
    requests: usize,
    wall_ms: f64,
    events_processed: u64,
    events_skipped: u64,
    timers_canceled: u64,
    events_per_sec: f64,
    sends: u64,
    completed: usize,
    rejected: usize,
    timed_out: usize,
    /// Process-lifetime VmHWM after this run — monotone across records
    /// (earlier memory-heavy runs dominate later readings).
    peak_rss_kb: u64,
    /// VmHWM growth attributable to this run (reading after − before);
    /// 0 when the run stayed under the previous high-water mark.
    peak_rss_growth_kb: u64,
}

impl RunRecord {
    fn to_json(&self) -> Json {
        Json::obj()
            .set("strategy", self.strategy)
            .set("requests", self.requests)
            .set("wall_ms", self.wall_ms)
            .set("events_processed", self.events_processed)
            .set("events_skipped", self.events_skipped)
            .set("timers_canceled", self.timers_canceled)
            .set("events_per_sec", self.events_per_sec)
            .set("sends", self.sends)
            .set("completed", self.completed)
            .set("rejected", self.rejected)
            .set("timed_out", self.timed_out)
            .set("peak_rss_kb", self.peak_rss_kb)
            .set("peak_rss_growth_kb", self.peak_rss_growth_kb)
    }
}

/// Run the scale bench: every strategy × every size, one shared workload
/// per size (the paired-comparison guarantee), BENCH.json at the end.
pub fn run_scale_bench(opts: &ScaleBenchOpts) -> Result<()> {
    anyhow::ensure!(!opts.sizes.is_empty(), "bench needs at least one size");
    let mut records: Vec<RunRecord> = Vec::new();

    for &n in &opts.sizes {
        println!(
            "== scale bench: {n} requests, {} req/s, mix {} ==",
            opts.rate_rps,
            opts.mix.name()
        );
        let requests = WorkloadSpec::new(opts.mix, n, opts.rate_rps).generate(opts.seed);
        for strategy in StrategyKind::ALL {
            let mut src = LadderSource::new(
                InfoLevel::Coarse,
                Rng::new(opts.seed ^ 0x5EED_50_u64).derive("priors"),
            );
            let rss_before = peak_rss_kb();
            let t0 = Instant::now();
            let out = driver::run(
                &requests,
                &mut src,
                SchedulerCfg::for_strategy(strategy),
                ProviderCfg::default(),
                opts.seed,
            );
            let wall_s = t0.elapsed().as_secs_f64();
            let rss_after = peak_rss_kb();
            let d = &out.diagnostics;
            let rec = RunRecord {
                strategy: strategy.name(),
                requests: n,
                wall_ms: wall_s * 1e3,
                events_processed: d.events_processed,
                events_skipped: d.events_skipped,
                timers_canceled: d.timers_canceled,
                events_per_sec: if wall_s > 0.0 { d.events_processed as f64 / wall_s } else { 0.0 },
                sends: d.sends,
                completed: out.metrics.n_completed,
                rejected: out.metrics.n_rejected,
                timed_out: out.metrics.n_timed_out,
                peak_rss_kb: rss_after,
                peak_rss_growth_kb: rss_after.saturating_sub(rss_before),
            };
            println!(
                "  {:<16} {:>9.1} ms  {:>10.0} ev/s  {:>8} events  {:>6} canceled  CR {:.3}",
                rec.strategy,
                rec.wall_ms,
                rec.events_per_sec,
                rec.events_processed,
                rec.timers_canceled,
                out.metrics.completion_rate,
            );
            records.push(rec);
        }
    }

    // Scaling exponents: first vs last size per strategy. Near 1.0 means
    // the hot path is linear in offered load; 2.0 would be the old O(n²).
    let mut scaling: Vec<Json> = Vec::new();
    if opts.sizes.len() >= 2 {
        let n_lo = opts.sizes[0];
        let n_hi = *opts.sizes.last().unwrap();
        println!("\n-- scaling {n_lo} → {n_hi} (exponent ≈ 1.0 is linear) --");
        let mut t = TextTable::new(["strategy", "wall lo (ms)", "wall hi (ms)", "exponent"]);
        for strategy in StrategyKind::ALL {
            let find = |n: usize| {
                records
                    .iter()
                    .find(|r| r.strategy == strategy.name() && r.requests == n)
                    .map(|r| r.wall_ms)
            };
            if let (Some(lo), Some(hi)) = (find(n_lo), find(n_hi)) {
                let exponent = if lo > 0.0 && hi > 0.0 {
                    (hi / lo).ln() / (n_hi as f64 / n_lo as f64).ln()
                } else {
                    f64::NAN
                };
                t.row([
                    strategy.name().to_string(),
                    format!("{lo:.1}"),
                    format!("{hi:.1}"),
                    format!("{exponent:.2}"),
                ]);
                scaling.push(
                    Json::obj()
                        .set("strategy", strategy.name())
                        .set("n_lo", n_lo)
                        .set("n_hi", n_hi)
                        .set("wall_lo_ms", lo)
                        .set("wall_hi_ms", hi)
                        .set("exponent", exponent),
                );
            }
        }
        println!("{}", t.render());
    }

    let doc = Json::obj()
        .set("bench", "scale")
        .set("mix", opts.mix.name())
        .set("rate_rps", opts.rate_rps)
        .set("seed", opts.seed)
        .set("sizes", opts.sizes.clone())
        .set("runs", Json::Arr(records.iter().map(RunRecord::to_json).collect()))
        .set("scaling", Json::Arr(scaling));
    doc.write_file(&opts.out_path)?;
    println!("wrote {}", opts.out_path);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_bench_runs_and_writes_json() {
        let out_path = std::env::temp_dir().join("bbsched_bench_test.json");
        let opts = ScaleBenchOpts {
            sizes: vec![40, 80],
            rate_rps: 12.0,
            out_path: out_path.to_string_lossy().into_owned(),
            ..ScaleBenchOpts::default()
        };
        run_scale_bench(&opts).expect("bench runs");
        let doc = Json::read_file(&opts.out_path).expect("BENCH.json parses");
        let runs = doc.get("runs").and_then(Json::as_arr).expect("runs array");
        assert_eq!(runs.len(), 2 * StrategyKind::ALL.len());
        let scaling = doc.get("scaling").and_then(Json::as_arr).expect("scaling array");
        assert_eq!(scaling.len(), StrategyKind::ALL.len());
        for r in runs {
            assert!(r.get("wall_ms").and_then(Json::as_f64).unwrap() >= 0.0);
            let n = r.get("requests").and_then(Json::as_usize).unwrap();
            let done = r.get("completed").and_then(Json::as_usize).unwrap()
                + r.get("rejected").and_then(Json::as_usize).unwrap()
                + r.get("timed_out").and_then(Json::as_usize).unwrap();
            assert_eq!(done, n, "conservation in bench records");
        }
        let _ = std::fs::remove_file(&opts.out_path);
    }

    #[test]
    fn peak_rss_proxy_is_sane() {
        let kb = peak_rss_kb();
        // Either procfs is absent (0) or we report something plausible.
        assert!(kb == 0 || kb > 100, "peak_rss_kb = {kb}");
    }
}
