//! Synthetic request sampler — the Rust twin of
//! `python/compile/datagen.py`'s generative model.
//!
//! The constants here MUST stay in lockstep with the Python side: the
//! predictor is trained on the Python sampler and served (via PJRT) against
//! requests from this one. `GEN_CONSTANTS` carries the canonical values and
//! `runtime::meta::check_constants` asserts them against
//! `artifacts/predictor_meta.json` at load time; the integration test
//! `tests/meta_consistency.rs` does the same in CI.

use crate::core::{Request, SloPolicy, Task, TokenBucket};
use crate::util::rng::Rng;

/// Workload mixes over (short, medium, long, xlong).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mix {
    /// Paper §4.2: 50/25/15/10.
    Balanced,
    /// Paper §4.2: 20/20/30/30.
    Heavy,
    /// Paper §4.1 ShareGPT-English split: 12/42/46/<1 (modeled as 1%).
    ShareGpt,
    /// Table 4's fairness workload: 70% long/xlong.
    FairnessHeavy,
}

impl Mix {
    pub fn weights(self) -> [f64; 4] {
        match self {
            Mix::Balanced => [0.50, 0.25, 0.15, 0.10],
            Mix::Heavy => [0.20, 0.20, 0.30, 0.30],
            Mix::ShareGpt => [0.12, 0.42, 0.45, 0.01],
            Mix::FairnessHeavy => [0.20, 0.10, 0.40, 0.30],
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Mix::Balanced => "balanced",
            Mix::Heavy => "heavy",
            Mix::ShareGpt => "sharegpt",
            Mix::FairnessHeavy => "fairness_heavy",
        }
    }

    pub fn parse(s: &str) -> Option<Mix> {
        match s {
            "balanced" => Some(Mix::Balanced),
            "heavy" => Some(Mix::Heavy),
            "sharegpt" => Some(Mix::ShareGpt),
            "fairness_heavy" => Some(Mix::FairnessHeavy),
            _ => None,
        }
    }

    /// Mean output tokens under this mix (for capacity estimates).
    pub fn mean_tokens(self) -> f64 {
        let w = self.weights();
        TokenBucket::ALL
            .iter()
            .zip(w.iter())
            .map(|(b, wi)| wi * b.geo_mid())
            .sum()
    }
}

/// Canonical generative-model constants (mirrors datagen.py; checked
/// against predictor_meta.json).
pub struct GenConstants {
    pub task_given_bucket: [[f64; 4]; 4],
    pub prompt_alpha: [f64; 4],
    pub prompt_beta: [f64; 4],
    pub prompt_sigma: f64,
    pub max_tokens_grid: [u32; 5],
}

pub const GEN_CONSTANTS: GenConstants = GenConstants {
    task_given_bucket: [
        [0.45, 0.05, 0.10, 0.40], // short
        [0.40, 0.20, 0.25, 0.15], // medium
        [0.25, 0.35, 0.30, 0.10], // long
        [0.10, 0.40, 0.45, 0.05], // xlong
    ],
    prompt_alpha: [2.2, 4.1, 1.8, 3.5],
    prompt_beta: [0.55, 0.35, 0.70, 0.30],
    prompt_sigma: 0.45,
    max_tokens_grid: [256, 512, 1024, 2048, 4096],
};

/// Stateful sampler bound to a mix + RNG stream.
pub struct SynthGen {
    mix: Mix,
    rng: Rng,
}

impl SynthGen {
    pub fn new(mix: Mix, rng: Rng) -> Self {
        SynthGen { mix, rng }
    }

    /// Sample one request arriving at `arrival_ms`.
    pub fn sample(&mut self, id: usize, arrival_ms: f64, slo: &SloPolicy) -> Request {
        let c = &GEN_CONSTANTS;
        let bucket_idx = self.rng.categorical(&self.mix.weights());
        let bucket = TokenBucket::ALL[bucket_idx];
        let (lo, hi) = bucket.bounds();
        let out_tok = self
            .rng
            .log_uniform(lo as f64, hi as f64)
            .round()
            .clamp(lo as f64, hi as f64) as u32;

        let task_idx = self.rng.categorical(&c.task_given_bucket[bucket_idx]);
        let task = Task::from_index(task_idx);

        let ln_prompt = c.prompt_alpha[task_idx]
            + c.prompt_beta[task_idx] * (out_tok as f64).ln()
            + self.rng.normal() * c.prompt_sigma;
        let prompt_tokens = ln_prompt.exp().round().clamp(4.0, 4096.0) as u32;

        let temperature = (self.rng.f64() * 20.0).round() / 20.0;
        let max_tokens = *c
            .max_tokens_grid
            .iter()
            .find(|g| **g >= hi)
            .unwrap_or(c.max_tokens_grid.last().unwrap());

        Request {
            id,
            arrival_ms,
            prompt_tokens,
            task,
            temperature,
            max_tokens,
            deadline_ms: arrival_ms + slo.deadline_for(bucket),
            timeout_ms: arrival_ms + slo.timeout_for(bucket),
            true_output_tokens: out_tok,
            true_bucket: bucket,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    fn sample_n(mix: Mix, n: usize, seed: u64) -> Vec<Request> {
        let mut g = SynthGen::new(mix, Rng::new(seed));
        let slo = SloPolicy::default();
        (0..n).map(|i| g.sample(i, i as f64, &slo)).collect()
    }

    #[test]
    fn tokens_within_bucket_bounds() {
        for r in sample_n(Mix::Balanced, 2000, 1) {
            let (lo, hi) = r.true_bucket.bounds();
            assert!(r.true_output_tokens >= lo && r.true_output_tokens <= hi);
        }
    }

    #[test]
    fn mix_proportions_converge() {
        for mix in [Mix::Balanced, Mix::Heavy, Mix::ShareGpt, Mix::FairnessHeavy] {
            let reqs = sample_n(mix, 40_000, 5);
            let mut counts = [0usize; 4];
            for r in &reqs {
                counts[r.true_bucket.index()] += 1;
            }
            for (i, w) in mix.weights().iter().enumerate() {
                let frac = counts[i] as f64 / reqs.len() as f64;
                assert!((frac - w).abs() < 0.015, "{mix:?} bucket {i}: {frac} vs {w}");
            }
        }
    }

    #[test]
    fn prompt_tokens_clamped_and_correlated() {
        let reqs = sample_n(Mix::Balanced, 20_000, 9);
        assert!(reqs.iter().all(|r| (4..=4096).contains(&r.prompt_tokens)));
        // log-log correlation between prompt and output should be clearly
        // positive — the predictor's signal.
        let xs: Vec<f64> = reqs.iter().map(|r| (r.prompt_tokens as f64).ln()).collect();
        let ys: Vec<f64> = reqs.iter().map(|r| (r.true_output_tokens as f64).ln()).collect();
        let n = xs.len() as f64;
        let mx = xs.iter().sum::<f64>() / n;
        let my = ys.iter().sum::<f64>() / n;
        let cov: f64 = xs.iter().zip(&ys).map(|(x, y)| (x - mx) * (y - my)).sum::<f64>() / n;
        let sx = (xs.iter().map(|x| (x - mx) * (x - mx)).sum::<f64>() / n).sqrt();
        let sy = (ys.iter().map(|y| (y - my) * (y - my)).sum::<f64>() / n).sqrt();
        let r = cov / (sx * sy);
        assert!(r > 0.3, "correlation too weak: {r}");
    }

    #[test]
    fn max_tokens_covers_bucket() {
        prop::forall(20, |g| {
            let seed = g.u64();
            for r in sample_n(Mix::Heavy, 200, seed) {
                let (_, hi) = r.true_bucket.bounds();
                assert!(r.max_tokens >= hi);
                assert!(GEN_CONSTANTS.max_tokens_grid.contains(&r.max_tokens));
            }
        });
    }

    #[test]
    fn temperature_grid() {
        for r in sample_n(Mix::Balanced, 500, 11) {
            let scaled = r.temperature * 20.0;
            assert!((scaled - scaled.round()).abs() < 1e-9);
            assert!((0.0..=1.0).contains(&r.temperature));
        }
    }

    #[test]
    fn mean_tokens_ordering() {
        assert!(Mix::Heavy.mean_tokens() > Mix::Balanced.mean_tokens());
        assert!(Mix::FairnessHeavy.mean_tokens() > Mix::Balanced.mean_tokens());
    }

    #[test]
    fn task_distribution_bucket_dependent() {
        let reqs = sample_n(Mix::Heavy, 40_000, 13);
        // xlong work should be dominated by code+summarize (0.85 weight).
        let xlong: Vec<&Request> =
            reqs.iter().filter(|r| r.true_bucket == TokenBucket::XLong).collect();
        let cs = xlong
            .iter()
            .filter(|r| matches!(r.task, Task::Code | Task::Summarize))
            .count() as f64
            / xlong.len() as f64;
        assert!(cs > 0.75, "code+summarize frac in xlong = {cs}");
    }
}
