//! Workload generation: synthetic request sampler (shared generative model
//! with the predictor's training data), arrival processes, the ShareGPT-
//! derived distribution, and trace record/replay.

pub mod arrivals;
pub mod synth;
pub mod trace;

pub use arrivals::ArrivalProcess;
pub use synth::{Mix, SynthGen, GEN_CONSTANTS};

use crate::core::{Request, SloPolicy};
use crate::util::rng::Rng;

/// Arrival-process shape for a workload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalKind {
    /// Memoryless arrivals at `rate_rps` (the paper's default).
    Poisson,
    /// Markov-modulated bursts: calm/burst phases alternate with the given
    /// mean phase length; `rate_rps` is reinterpreted as the calm rate and
    /// `burst_factor × rate_rps` as the burst rate (extension experiments).
    Bursty { burst_factor: f64, mean_phase_ms: f64 },
}

/// Everything needed to materialize one run's offered load.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub mix: Mix,
    /// Number of requests offered.
    pub n_requests: usize,
    /// Mean arrival rate, requests/second.
    pub rate_rps: f64,
    /// SLO policy assigning deadlines/timeouts by true bucket.
    pub slo: SloPolicy,
    /// Arrival-process shape.
    pub arrivals: ArrivalKind,
}

impl WorkloadSpec {
    pub fn new(mix: Mix, n_requests: usize, rate_rps: f64) -> Self {
        WorkloadSpec {
            mix,
            n_requests,
            rate_rps,
            slo: SloPolicy::default(),
            arrivals: ArrivalKind::Poisson,
        }
    }

    pub fn bursty(mut self, burst_factor: f64, mean_phase_ms: f64) -> Self {
        self.arrivals = ArrivalKind::Bursty { burst_factor, mean_phase_ms };
        self
    }

    /// Materialize the full request table for a seed. Deterministic:
    /// (spec, seed) → identical Vec<Request>.
    pub fn generate(&self, seed: u64) -> Vec<Request> {
        let root = Rng::new(seed);
        let mut arrivals = match self.arrivals {
            ArrivalKind::Poisson => ArrivalProcess::poisson(self.rate_rps, root.derive("arrivals")),
            ArrivalKind::Bursty { burst_factor, mean_phase_ms } => ArrivalProcess::bursty(
                self.rate_rps,
                self.rate_rps * burst_factor,
                mean_phase_ms,
                root.derive("arrivals"),
            ),
        };
        let mut synth = SynthGen::new(self.mix, root.derive("synth"));
        let mut out = Vec::with_capacity(self.n_requests);
        let mut now = 0.0;
        for id in 0..self.n_requests {
            now = arrivals.next_after(now);
            out.push(synth.sample(id, now, &self.slo));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::TokenBucket;

    #[test]
    fn generate_is_deterministic() {
        let spec = WorkloadSpec::new(Mix::Balanced, 50, 8.0);
        let a = spec.generate(42);
        let b = spec.generate(42);
        assert_eq!(a.len(), 50);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.arrival_ms, y.arrival_ms);
            assert_eq!(x.true_output_tokens, y.true_output_tokens);
            assert_eq!(x.prompt_tokens, y.prompt_tokens);
        }
    }

    #[test]
    fn different_seeds_differ() {
        let spec = WorkloadSpec::new(Mix::Balanced, 50, 8.0);
        let a = spec.generate(1);
        let b = spec.generate(2);
        assert!(a.iter().zip(b.iter()).any(|(x, y)| x.true_output_tokens != y.true_output_tokens));
    }

    #[test]
    fn arrivals_monotone_and_rate_sane() {
        let spec = WorkloadSpec::new(Mix::Heavy, 400, 10.0);
        let reqs = spec.generate(7);
        let mut prev = 0.0;
        for r in &reqs {
            assert!(r.arrival_ms >= prev);
            prev = r.arrival_ms;
        }
        // 400 arrivals at 10/s ≈ 40 s span (±30%).
        let span_s = reqs.last().unwrap().arrival_ms / 1000.0;
        assert!((28.0..55.0).contains(&span_s), "span={span_s}");
    }

    #[test]
    fn deadlines_match_bucket_slo() {
        let spec = WorkloadSpec::new(Mix::Balanced, 100, 8.0);
        let slo = SloPolicy::default();
        for r in spec.generate(3) {
            let rel = r.deadline_ms - r.arrival_ms;
            assert!((rel - slo.deadline_for(r.true_bucket)).abs() < 1e-9);
            assert!(r.timeout_ms > r.deadline_ms);
        }
    }

    #[test]
    fn ids_are_sequential() {
        let spec = WorkloadSpec::new(Mix::ShareGpt, 20, 5.0);
        for (i, r) in spec.generate(0).iter().enumerate() {
            assert_eq!(r.id, i);
        }
    }

    #[test]
    fn heavy_mix_is_heavier() {
        let bal = WorkloadSpec::new(Mix::Balanced, 2000, 8.0).generate(5);
        let heavy = WorkloadSpec::new(Mix::Heavy, 2000, 8.0).generate(5);
        let frac_heavy = |rs: &[Request]| {
            rs.iter().filter(|r| matches!(r.true_bucket, TokenBucket::Long | TokenBucket::XLong)).count()
                as f64
                / rs.len() as f64
        };
        assert!(frac_heavy(&heavy) > frac_heavy(&bal) + 0.2);
    }
}
