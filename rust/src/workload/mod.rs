//! Workload generation: synthetic request sampler (shared generative model
//! with the predictor's training data), arrival processes, the ShareGPT-
//! derived distribution, and trace record/replay.

pub mod arrivals;
pub mod synth;
pub mod trace;

pub use arrivals::ArrivalProcess;
pub use synth::{Mix, SynthGen, GEN_CONSTANTS};

use crate::core::{Request, SloPolicy};
use crate::util::rng::Rng;

/// Declarative arrival-process specification: one composable value naming
/// the process shape *and* its parameters, with stable [`name`]s for CLI
/// flags and CSV columns ([`parse`] accepts `name` or `name:p1:p2[:p3]`
/// to override the defaults).
///
/// The offered rate stays on [`WorkloadSpec::rate_rps`]; every variant is
/// parameterized relative to it, so swapping the arrival shape never
/// changes the long-run offered load (the controlled-evaluation
/// requirement across arrival scenarios).
///
/// [`name`]: ArrivalSpec::name
/// [`parse`]: ArrivalSpec::parse
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalSpec {
    /// Memoryless arrivals at `rate_rps` (the paper's default).
    Poisson,
    /// Fixed inter-arrival gap `1000/rate_rps` ms (calibration runs).
    Uniform,
    /// Markov-modulated bursts: calm/burst phases alternate with the given
    /// mean phase length; `rate_rps` is the calm rate and
    /// `burst_factor × rate_rps` the burst rate.
    Bursty {
        /// Burst-phase rate multiplier over the calm rate.
        burst_factor: f64,
        /// Mean calm/burst phase length (ms, exponential).
        mean_phase_ms: f64,
    },
    /// Diurnal tide: sinusoidal rate modulation around `rate_rps` with one
    /// full cycle per `period_ms` and modulation depth in `[0, 1)`.
    Diurnal {
        /// One full load cycle (ms).
        period_ms: f64,
        /// Modulation depth: instantaneous rate spans `rate·(1 ± depth)`.
        depth: f64,
    },
    /// Flash crowds on a deterministic timetable: every `every_ms` the
    /// rate spikes to `rate_rps × spike_factor` for `spike_ms`.
    FlashCrowd {
        /// Spike rate multiplier over the baseline.
        spike_factor: f64,
        /// Spike period (ms): one spike starts every `every_ms`.
        every_ms: f64,
        /// Spike duration (ms), at the start of each period.
        spike_ms: f64,
    },
    /// Session-affinity stream: `turns`-request sessions whose requests
    /// are separated by mean-`think_ms` think gaps (clustered multi-turn
    /// traffic — the shape that stresses `hash_affinity` pinning).
    Session {
        /// Requests per session.
        turns: u32,
        /// Mean think-time gap between a session's requests (ms).
        think_ms: f64,
    },
}

impl ArrivalSpec {
    /// Every arrival shape at its default parameters, in CLI listing order.
    pub const ALL: [ArrivalSpec; 6] = [
        ArrivalSpec::Poisson,
        ArrivalSpec::Uniform,
        ArrivalSpec::Bursty { burst_factor: 4.0, mean_phase_ms: 2_000.0 },
        ArrivalSpec::Diurnal { period_ms: 60_000.0, depth: 0.8 },
        ArrivalSpec::FlashCrowd { spike_factor: 8.0, every_ms: 30_000.0, spike_ms: 2_000.0 },
        ArrivalSpec::Session { turns: 4, think_ms: 800.0 },
    ];

    /// Stable CLI/CSV name.
    pub fn name(self) -> &'static str {
        match self {
            ArrivalSpec::Poisson => "poisson",
            ArrivalSpec::Uniform => "uniform",
            ArrivalSpec::Bursty { .. } => "bursty",
            ArrivalSpec::Diurnal { .. } => "diurnal",
            ArrivalSpec::FlashCrowd { .. } => "flash_crowd",
            ArrivalSpec::Session { .. } => "session",
        }
    }

    /// Parse a CLI spec: a bare name takes the [`ArrivalSpec::ALL`]
    /// defaults; `name:p1:p2[:p3]` overrides the variant's parameters in
    /// declaration order (`bursty:4:2000`, `diurnal:60000:0.8`,
    /// `flash_crowd:8:30000:2000`, `session:4:800`).
    pub fn parse(s: &str) -> Option<ArrivalSpec> {
        let mut parts = s.split(':');
        let name = parts.next()?;
        let params: Vec<&str> = parts.collect();
        let f = |i: usize| -> Option<f64> { params.get(i)?.parse::<f64>().ok() };
        match (name, params.len()) {
            ("poisson", 0) => Some(ArrivalSpec::Poisson),
            ("uniform", 0) => Some(ArrivalSpec::Uniform),
            ("bursty", 0) => Some(ArrivalSpec::ALL[2]),
            ("bursty", 2) => {
                Some(ArrivalSpec::Bursty { burst_factor: f(0)?, mean_phase_ms: f(1)? })
            }
            ("diurnal", 0) => Some(ArrivalSpec::ALL[3]),
            ("diurnal", 2) => Some(ArrivalSpec::Diurnal { period_ms: f(0)?, depth: f(1)? }),
            ("flash_crowd", 0) => Some(ArrivalSpec::ALL[4]),
            ("flash_crowd", 3) => Some(ArrivalSpec::FlashCrowd {
                spike_factor: f(0)?,
                every_ms: f(1)?,
                spike_ms: f(2)?,
            }),
            ("session", 0) => Some(ArrivalSpec::ALL[5]),
            ("session", 2) => {
                let turns = params[0].parse::<u32>().ok()?;
                Some(ArrivalSpec::Session { turns, think_ms: f(1)? })
            }
            _ => None,
        }
    }

    /// Instantiate the generator for this spec at the given offered rate.
    /// The constructor mapping is 1:1 with the old `ArrivalKind` match, so
    /// poisson/bursty specs consume the `"arrivals"` RNG stream exactly as
    /// before (the byte-identity contract for the shim constructors).
    pub fn process(self, rate_rps: f64, rng: Rng) -> ArrivalProcess {
        match self {
            ArrivalSpec::Poisson => ArrivalProcess::poisson(rate_rps, rng),
            ArrivalSpec::Uniform => ArrivalProcess::uniform(1000.0 / rate_rps, rng),
            ArrivalSpec::Bursty { burst_factor, mean_phase_ms } => {
                ArrivalProcess::bursty(rate_rps, rate_rps * burst_factor, mean_phase_ms, rng)
            }
            ArrivalSpec::Diurnal { period_ms, depth } => {
                ArrivalProcess::diurnal(rate_rps, period_ms, depth, rng)
            }
            ArrivalSpec::FlashCrowd { spike_factor, every_ms, spike_ms } => {
                ArrivalProcess::flash_crowd(rate_rps, spike_factor, every_ms, spike_ms, rng)
            }
            ArrivalSpec::Session { turns, think_ms } => {
                ArrivalProcess::session(rate_rps, turns, think_ms, rng)
            }
        }
    }
}

/// Everything needed to materialize one run's offered load.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub mix: Mix,
    /// Number of requests offered.
    pub n_requests: usize,
    /// Mean arrival rate, requests/second.
    pub rate_rps: f64,
    /// SLO policy assigning deadlines/timeouts by true bucket.
    pub slo: SloPolicy,
    /// Arrival-process shape (see [`ArrivalSpec`]).
    pub arrivals: ArrivalSpec,
}

impl WorkloadSpec {
    pub fn new(mix: Mix, n_requests: usize, rate_rps: f64) -> Self {
        WorkloadSpec {
            mix,
            n_requests,
            rate_rps,
            slo: SloPolicy::default(),
            arrivals: ArrivalSpec::Poisson,
        }
    }

    /// Thin shim over [`WorkloadSpec::with_arrivals`] kept for the historic
    /// builder call sites; produces byte-identical workloads to the
    /// equivalent `ArrivalSpec::Bursty` spec (tested in
    /// `tests/parallel_sweep.rs`).
    pub fn bursty(self, burst_factor: f64, mean_phase_ms: f64) -> Self {
        self.with_arrivals(ArrivalSpec::Bursty { burst_factor, mean_phase_ms })
    }

    /// Set the arrival-process shape (consuming builder).
    pub fn with_arrivals(mut self, arrivals: ArrivalSpec) -> Self {
        self.arrivals = arrivals;
        self
    }

    /// Materialize the full request table for a seed. Deterministic:
    /// (spec, seed) → identical Vec<Request>.
    pub fn generate(&self, seed: u64) -> Vec<Request> {
        let root = Rng::new(seed);
        let mut arrivals = self.arrivals.process(self.rate_rps, root.derive("arrivals"));
        let mut synth = SynthGen::new(self.mix, root.derive("synth"));
        let mut out = Vec::with_capacity(self.n_requests);
        let mut now = 0.0;
        for id in 0..self.n_requests {
            now = arrivals.next_after(now);
            out.push(synth.sample(id, now, &self.slo));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::TokenBucket;

    #[test]
    fn generate_is_deterministic() {
        let spec = WorkloadSpec::new(Mix::Balanced, 50, 8.0);
        let a = spec.generate(42);
        let b = spec.generate(42);
        assert_eq!(a.len(), 50);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.arrival_ms, y.arrival_ms);
            assert_eq!(x.true_output_tokens, y.true_output_tokens);
            assert_eq!(x.prompt_tokens, y.prompt_tokens);
        }
    }

    #[test]
    fn different_seeds_differ() {
        let spec = WorkloadSpec::new(Mix::Balanced, 50, 8.0);
        let a = spec.generate(1);
        let b = spec.generate(2);
        assert!(a.iter().zip(b.iter()).any(|(x, y)| x.true_output_tokens != y.true_output_tokens));
    }

    #[test]
    fn arrivals_monotone_and_rate_sane() {
        let spec = WorkloadSpec::new(Mix::Heavy, 400, 10.0);
        let reqs = spec.generate(7);
        let mut prev = 0.0;
        for r in &reqs {
            assert!(r.arrival_ms >= prev);
            prev = r.arrival_ms;
        }
        // 400 arrivals at 10/s ≈ 40 s span (±30%).
        let span_s = reqs.last().unwrap().arrival_ms / 1000.0;
        assert!((28.0..55.0).contains(&span_s), "span={span_s}");
    }

    #[test]
    fn deadlines_match_bucket_slo() {
        let spec = WorkloadSpec::new(Mix::Balanced, 100, 8.0);
        let slo = SloPolicy::default();
        for r in spec.generate(3) {
            let rel = r.deadline_ms - r.arrival_ms;
            assert!((rel - slo.deadline_for(r.true_bucket)).abs() < 1e-9);
            assert!(r.timeout_ms > r.deadline_ms);
        }
    }

    #[test]
    fn ids_are_sequential() {
        let spec = WorkloadSpec::new(Mix::ShareGpt, 20, 5.0);
        for (i, r) in spec.generate(0).iter().enumerate() {
            assert_eq!(r.id, i);
        }
    }

    #[test]
    fn heavy_mix_is_heavier() {
        let bal = WorkloadSpec::new(Mix::Balanced, 2000, 8.0).generate(5);
        let heavy = WorkloadSpec::new(Mix::Heavy, 2000, 8.0).generate(5);
        let frac_heavy = |rs: &[Request]| {
            rs.iter().filter(|r| matches!(r.true_bucket, TokenBucket::Long | TokenBucket::XLong)).count()
                as f64
                / rs.len() as f64
        };
        assert!(frac_heavy(&heavy) > frac_heavy(&bal) + 0.2);
    }

    #[test]
    fn arrival_spec_parse_roundtrip_and_params() {
        for spec in ArrivalSpec::ALL {
            assert_eq!(ArrivalSpec::parse(spec.name()), Some(spec), "{}", spec.name());
        }
        assert_eq!(
            ArrivalSpec::parse("bursty:6:500"),
            Some(ArrivalSpec::Bursty { burst_factor: 6.0, mean_phase_ms: 500.0 })
        );
        assert_eq!(
            ArrivalSpec::parse("diurnal:10000:0.5"),
            Some(ArrivalSpec::Diurnal { period_ms: 10_000.0, depth: 0.5 })
        );
        assert_eq!(
            ArrivalSpec::parse("flash_crowd:4:10000:1000"),
            Some(ArrivalSpec::FlashCrowd {
                spike_factor: 4.0,
                every_ms: 10_000.0,
                spike_ms: 1_000.0
            })
        );
        assert_eq!(
            ArrivalSpec::parse("session:8:200"),
            Some(ArrivalSpec::Session { turns: 8, think_ms: 200.0 })
        );
        for bad in ["", "vibes", "poisson:1", "bursty:4", "session:x:200"] {
            assert_eq!(ArrivalSpec::parse(bad), None, "{bad:?}");
        }
    }

    #[test]
    fn every_spec_generates_monotone_arrivals() {
        for spec in ArrivalSpec::ALL {
            let w = WorkloadSpec::new(Mix::Balanced, 200, 10.0).with_arrivals(spec);
            let reqs = w.generate(3);
            let mut prev = 0.0;
            for r in &reqs {
                assert!(r.arrival_ms > prev, "{}: non-monotone", spec.name());
                prev = r.arrival_ms;
            }
        }
    }

    #[test]
    fn bursty_shim_matches_spec_bitwise() {
        let shim = WorkloadSpec::new(Mix::Heavy, 120, 9.0).bursty(4.0, 1_500.0).generate(11);
        let spec = WorkloadSpec::new(Mix::Heavy, 120, 9.0)
            .with_arrivals(ArrivalSpec::Bursty { burst_factor: 4.0, mean_phase_ms: 1_500.0 })
            .generate(11);
        for (a, b) in shim.iter().zip(spec.iter()) {
            assert_eq!(a.arrival_ms.to_bits(), b.arrival_ms.to_bits());
            assert_eq!(a.true_output_tokens, b.true_output_tokens);
        }
    }
}
