//! Trace record/replay: JSON-lines serialization of request tables, so a
//! workload can be generated once, inspected, edited, and replayed across
//! policies (the paper's "controlled evaluation" requires every policy to
//! see the identical arrival sequence — replay guarantees it even across
//! binaries).

use crate::core::{Request, Task, TokenBucket};
use crate::util::jsonio::{Json, JsonError};

/// Serialize one request to a JSON object.
pub fn request_to_json(r: &Request) -> Json {
    Json::obj()
        .set("id", r.id)
        .set("arrival_ms", r.arrival_ms)
        .set("prompt_tokens", r.prompt_tokens as u64)
        .set("task", r.task.name())
        .set("temperature", r.temperature)
        .set("max_tokens", r.max_tokens as u64)
        .set("deadline_ms", r.deadline_ms)
        .set("timeout_ms", r.timeout_ms)
        .set("true_output_tokens", r.true_output_tokens as u64)
        .set("true_bucket", r.true_bucket.name())
}

/// Parse one request back.
pub fn request_from_json(j: &Json) -> Result<Request, JsonError> {
    let missing = |k: &str| JsonError::Missing(k.to_string());
    let task_name = j.req("task")?.as_str().ok_or_else(|| missing("task"))?;
    let task = Task::ALL
        .iter()
        .copied()
        .find(|t| t.name() == task_name)
        .ok_or_else(|| missing("task(valid)"))?;
    let bucket_name = j.req("true_bucket")?.as_str().ok_or_else(|| missing("true_bucket"))?;
    let bucket = TokenBucket::parse(bucket_name).ok_or_else(|| missing("true_bucket(valid)"))?;
    Ok(Request {
        id: j.req("id")?.as_usize().ok_or_else(|| missing("id"))?,
        arrival_ms: j.req("arrival_ms")?.as_f64().ok_or_else(|| missing("arrival_ms"))?,
        prompt_tokens: j.req("prompt_tokens")?.as_u64().ok_or_else(|| missing("prompt_tokens"))?
            as u32,
        task,
        temperature: j.req("temperature")?.as_f64().ok_or_else(|| missing("temperature"))?,
        max_tokens: j.req("max_tokens")?.as_u64().ok_or_else(|| missing("max_tokens"))? as u32,
        deadline_ms: j.req("deadline_ms")?.as_f64().ok_or_else(|| missing("deadline_ms"))?,
        timeout_ms: j.req("timeout_ms")?.as_f64().ok_or_else(|| missing("timeout_ms"))?,
        true_output_tokens: j
            .req("true_output_tokens")?
            .as_u64()
            .ok_or_else(|| missing("true_output_tokens"))? as u32,
        true_bucket: bucket,
    })
}

/// Write a trace as JSON lines.
pub fn save_trace(path: &str, requests: &[Request]) -> Result<(), JsonError> {
    let mut out = String::new();
    for r in requests {
        out.push_str(&request_to_json(r).to_string_compact());
        out.push('\n');
    }
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, out)?;
    Ok(())
}

/// Load a JSON-lines trace.
pub fn load_trace(path: &str) -> Result<Vec<Request>, JsonError> {
    let text = std::fs::read_to_string(path)?;
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        out.push(request_from_json(&Json::parse(line)?)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{Mix, WorkloadSpec};

    #[test]
    fn roundtrip_via_json() {
        let reqs = WorkloadSpec::new(Mix::Balanced, 30, 8.0).generate(3);
        for r in &reqs {
            let j = request_to_json(r);
            let back = request_from_json(&j).unwrap();
            assert_eq!(back.id, r.id);
            assert_eq!(back.true_output_tokens, r.true_output_tokens);
            assert_eq!(back.true_bucket, r.true_bucket);
            assert_eq!(back.task, r.task);
            assert!((back.arrival_ms - r.arrival_ms).abs() < 1e-9);
            assert!((back.deadline_ms - r.deadline_ms).abs() < 1e-9);
        }
    }

    #[test]
    fn roundtrip_via_file() {
        let reqs = WorkloadSpec::new(Mix::Heavy, 25, 10.0).generate(7);
        let path = std::env::temp_dir().join("bbsched_trace_test.jsonl");
        let path = path.to_str().unwrap();
        save_trace(path, &reqs).unwrap();
        let back = load_trace(path).unwrap();
        assert_eq!(back.len(), reqs.len());
        for (a, b) in reqs.iter().zip(back.iter()) {
            assert_eq!(a.true_output_tokens, b.true_output_tokens);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_malformed_lines() {
        let j = Json::parse(r#"{"id": 1}"#).unwrap();
        assert!(request_from_json(&j).is_err());
        let j = Json::parse(r#"{"id":1,"arrival_ms":0,"prompt_tokens":5,"task":"nope","temperature":0,"max_tokens":10,"deadline_ms":1,"timeout_ms":2,"true_output_tokens":3,"true_bucket":"short"}"#).unwrap();
        assert!(request_from_json(&j).is_err());
    }
}
