//! Arrival processes: Poisson (default), deterministic (calibration),
//! burst-modulated Poisson, and the storm-scenario generators (diurnal
//! tides with flash crowds, multi-turn session streams).

use crate::util::rng::Rng;

/// Generator of successive arrival instants.
pub struct ArrivalProcess {
    kind: Kind,
    rng: Rng,
}

enum Kind {
    /// Exponential inter-arrivals with the given rate (req/s).
    Poisson { rate_rps: f64 },
    /// Fixed inter-arrival gap (ms).
    Uniform { gap_ms: f64 },
    /// Markov-modulated Poisson: alternates calm/burst phases.
    Bursty {
        calm_rps: f64,
        burst_rps: f64,
        mean_phase_ms: f64,
        in_burst: bool,
        phase_ends_ms: f64,
    },
    /// Sinusoidal rate modulation around the mean (diurnal tide): the
    /// instantaneous rate is `mean·(1 + depth·sin(2π·t/period))`, sampled
    /// at each arrival instant (piecewise-homogeneous approximation).
    Diurnal { mean_rps: f64, period_ms: f64, depth: f64 },
    /// Deterministic flash-crowd schedule: every `every_ms` the rate spikes
    /// to `base·factor` for `spike_ms`, then returns to `base`. The spike
    /// timetable consumes no randomness, so fault/experiment alignment is
    /// exact across seeds.
    FlashCrowd { base_rps: f64, spike_factor: f64, every_ms: f64, spike_ms: f64 },
    /// Session-affinity stream: each session carries `turns` requests
    /// separated by exponential think-time gaps (mean `think_ms`); a new
    /// session opens an exponential `session_gap_ms` after the previous
    /// one ends — clustered arrivals modelling multi-turn chats.
    Session { session_gap_ms: f64, turns: u32, think_ms: f64, left_in_session: u32 },
}

impl ArrivalProcess {
    pub fn poisson(rate_rps: f64, rng: Rng) -> Self {
        assert!(rate_rps > 0.0);
        ArrivalProcess { kind: Kind::Poisson { rate_rps }, rng }
    }

    pub fn uniform(gap_ms: f64, rng: Rng) -> Self {
        assert!(gap_ms > 0.0);
        ArrivalProcess { kind: Kind::Uniform { gap_ms }, rng }
    }

    pub fn bursty(calm_rps: f64, burst_rps: f64, mean_phase_ms: f64, rng: Rng) -> Self {
        assert!(calm_rps > 0.0 && burst_rps > 0.0 && mean_phase_ms > 0.0);
        ArrivalProcess {
            kind: Kind::Bursty {
                calm_rps,
                burst_rps,
                mean_phase_ms,
                in_burst: false,
                phase_ends_ms: 0.0,
            },
            rng,
        }
    }

    /// Diurnal tide: mean rate `mean_rps`, one full cycle per `period_ms`,
    /// modulation depth in `[0, 1)` (depth 0 degenerates to Poisson).
    pub fn diurnal(mean_rps: f64, period_ms: f64, depth: f64, rng: Rng) -> Self {
        assert!(mean_rps > 0.0 && period_ms > 0.0);
        assert!((0.0..1.0).contains(&depth), "diurnal depth must be in [0,1)");
        ArrivalProcess { kind: Kind::Diurnal { mean_rps, period_ms, depth }, rng }
    }

    /// Flash crowds on a deterministic timetable: baseline `base_rps`,
    /// spiking to `base_rps·spike_factor` for `spike_ms` at the start of
    /// every `every_ms` interval.
    pub fn flash_crowd(
        base_rps: f64,
        spike_factor: f64,
        every_ms: f64,
        spike_ms: f64,
        rng: Rng,
    ) -> Self {
        assert!(base_rps > 0.0 && spike_factor > 0.0);
        assert!(every_ms > 0.0 && spike_ms > 0.0 && spike_ms <= every_ms);
        ArrivalProcess { kind: Kind::FlashCrowd { base_rps, spike_factor, every_ms, spike_ms }, rng }
    }

    /// Session stream targeting `rate_rps` requests/s overall: each session
    /// contributes `turns` requests separated by mean-`think_ms` think
    /// gaps; the inter-session gap absorbs the remaining cycle time
    /// (`turns/rate − (turns−1)·think`, floored at `think_ms` when the
    /// think time alone already exceeds the target rate).
    pub fn session(rate_rps: f64, turns: u32, think_ms: f64, rng: Rng) -> Self {
        assert!(rate_rps > 0.0 && turns >= 1 && think_ms > 0.0);
        let cycle_ms = turns as f64 * 1000.0 / rate_rps;
        let session_gap_ms = (cycle_ms - (turns - 1) as f64 * think_ms).max(think_ms);
        ArrivalProcess {
            kind: Kind::Session { session_gap_ms, turns, think_ms, left_in_session: 0 },
            rng,
        }
    }

    /// Next arrival instant strictly after `now` (ms).
    pub fn next_after(&mut self, now: f64) -> f64 {
        match &mut self.kind {
            Kind::Poisson { rate_rps } => now + self.rng.exp(*rate_rps / 1000.0),
            Kind::Uniform { gap_ms } => now + *gap_ms,
            Kind::Bursty { calm_rps, burst_rps, mean_phase_ms, in_burst, phase_ends_ms } => {
                if now >= *phase_ends_ms {
                    *in_burst = !*in_burst;
                    *phase_ends_ms = now + self.rng.exp(1.0 / *mean_phase_ms);
                }
                let rate = if *in_burst { *burst_rps } else { *calm_rps };
                now + self.rng.exp(rate / 1000.0)
            }
            Kind::Diurnal { mean_rps, period_ms, depth } => {
                let phase = 2.0 * std::f64::consts::PI * (now / *period_ms);
                let rate = *mean_rps * (1.0 + *depth * phase.sin());
                now + self.rng.exp(rate / 1000.0)
            }
            Kind::FlashCrowd { base_rps, spike_factor, every_ms, spike_ms } => {
                let in_spike = now.rem_euclid(*every_ms) < *spike_ms;
                let rate = if in_spike { *base_rps * *spike_factor } else { *base_rps };
                now + self.rng.exp(rate / 1000.0)
            }
            Kind::Session { session_gap_ms, turns, think_ms, left_in_session } => {
                if *left_in_session == 0 {
                    *left_in_session = *turns - 1;
                    now + self.rng.exp(1.0 / *session_gap_ms)
                } else {
                    *left_in_session -= 1;
                    now + self.rng.exp(1.0 / *think_ms)
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_matches() {
        let mut p = ArrivalProcess::poisson(10.0, Rng::new(1));
        let mut t = 0.0;
        let n = 20_000;
        for _ in 0..n {
            t = p.next_after(t);
        }
        let rate = n as f64 / (t / 1000.0);
        assert!((rate - 10.0).abs() < 0.3, "rate={rate}");
    }

    #[test]
    fn arrivals_strictly_increase() {
        let mut p = ArrivalProcess::poisson(100.0, Rng::new(2));
        let mut t = 0.0;
        for _ in 0..1000 {
            let nt = p.next_after(t);
            assert!(nt > t);
            t = nt;
        }
    }

    #[test]
    fn uniform_gap() {
        let mut p = ArrivalProcess::uniform(50.0, Rng::new(3));
        assert_eq!(p.next_after(0.0), 50.0);
        assert_eq!(p.next_after(50.0), 100.0);
    }

    #[test]
    fn bursty_has_higher_variance_than_poisson() {
        let gaps = |mut p: ArrivalProcess| {
            let mut t = 0.0;
            let mut gs = Vec::new();
            for _ in 0..20_000 {
                let nt = p.next_after(t);
                gs.push(nt - t);
                t = nt;
            }
            gs
        };
        let pg = gaps(ArrivalProcess::poisson(10.0, Rng::new(5)));
        let bg = gaps(ArrivalProcess::bursty(4.0, 40.0, 2_000.0, Rng::new(5)));
        let cv = |g: &[f64]| {
            let (m, s) = crate::util::stats::mean_std(g);
            s / m
        };
        assert!(cv(&bg) > cv(&pg) * 1.2, "burst cv={} poisson cv={}", cv(&bg), cv(&pg));
    }

    #[test]
    fn diurnal_modulates_rate_with_phase() {
        // Count arrivals landing in the rising half vs the falling half of
        // each cycle: with depth 0.9 the crest must see far more traffic.
        let mut p = ArrivalProcess::diurnal(10.0, 10_000.0, 0.9, Rng::new(7));
        let mut t = 0.0;
        let (mut crest, mut trough) = (0usize, 0usize);
        for _ in 0..40_000 {
            t = p.next_after(t);
            let phase = (t / 10_000.0).fract();
            if phase < 0.5 {
                crest += 1; // sin > 0 half-cycle
            } else {
                trough += 1;
            }
        }
        assert!(
            crest as f64 > trough as f64 * 2.0,
            "crest={crest} trough={trough}: diurnal tide must concentrate arrivals"
        );
    }

    #[test]
    fn flash_crowd_concentrates_arrivals_in_spikes() {
        // 8x spikes for 2s out of every 30s: the 1/15 spike share of the
        // timeline must carry several times its proportional share.
        let mut p = ArrivalProcess::flash_crowd(10.0, 8.0, 30_000.0, 2_000.0, Rng::new(9));
        let mut t = 0.0;
        let (mut inside, mut total) = (0usize, 0usize);
        for _ in 0..40_000 {
            t = p.next_after(t);
            total += 1;
            if t.rem_euclid(30_000.0) < 2_000.0 {
                inside += 1;
            }
        }
        let share = inside as f64 / total as f64;
        assert!(share > 0.25, "spike share={share}: flash crowds must dominate their windows");
    }

    #[test]
    fn session_stream_clusters_and_rate_is_sane() {
        // 8-turn sessions with 20 ms think time at 10 req/s: 7 of every 8
        // gaps are tight think gaps, the opener gap absorbs the slack, and
        // the long-run rate still lands near the target.
        let mut p = ArrivalProcess::session(10.0, 8, 20.0, Rng::new(11));
        let mut t = 0.0;
        let mut short_gaps = 0usize;
        let n = 40_000;
        for _ in 0..n {
            let nt = p.next_after(t);
            assert!(nt > t);
            if nt - t < 100.0 {
                short_gaps += 1;
            }
            t = nt;
        }
        // A plain Poisson process at 10 req/s puts only ~63% of gaps under
        // 100 ms; the session stream's think clustering pushes well past it.
        assert!(short_gaps as f64 > n as f64 * 0.8, "short_gaps={short_gaps}");
        let rate = n as f64 / (t / 1000.0);
        assert!((8.0..12.0).contains(&rate), "rate={rate}");
    }
}
