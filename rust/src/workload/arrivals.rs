//! Arrival processes: Poisson (default), deterministic (calibration), and
//! burst-modulated Poisson (extension experiments).

use crate::util::rng::Rng;

/// Generator of successive arrival instants.
pub struct ArrivalProcess {
    kind: Kind,
    rng: Rng,
}

enum Kind {
    /// Exponential inter-arrivals with the given rate (req/s).
    Poisson { rate_rps: f64 },
    /// Fixed inter-arrival gap (ms).
    Uniform { gap_ms: f64 },
    /// Markov-modulated Poisson: alternates calm/burst phases.
    Bursty {
        calm_rps: f64,
        burst_rps: f64,
        mean_phase_ms: f64,
        in_burst: bool,
        phase_ends_ms: f64,
    },
}

impl ArrivalProcess {
    pub fn poisson(rate_rps: f64, rng: Rng) -> Self {
        assert!(rate_rps > 0.0);
        ArrivalProcess { kind: Kind::Poisson { rate_rps }, rng }
    }

    pub fn uniform(gap_ms: f64, rng: Rng) -> Self {
        assert!(gap_ms > 0.0);
        ArrivalProcess { kind: Kind::Uniform { gap_ms }, rng }
    }

    pub fn bursty(calm_rps: f64, burst_rps: f64, mean_phase_ms: f64, rng: Rng) -> Self {
        assert!(calm_rps > 0.0 && burst_rps > 0.0 && mean_phase_ms > 0.0);
        ArrivalProcess {
            kind: Kind::Bursty {
                calm_rps,
                burst_rps,
                mean_phase_ms,
                in_burst: false,
                phase_ends_ms: 0.0,
            },
            rng,
        }
    }

    /// Next arrival instant strictly after `now` (ms).
    pub fn next_after(&mut self, now: f64) -> f64 {
        match &mut self.kind {
            Kind::Poisson { rate_rps } => now + self.rng.exp(*rate_rps / 1000.0),
            Kind::Uniform { gap_ms } => now + *gap_ms,
            Kind::Bursty { calm_rps, burst_rps, mean_phase_ms, in_burst, phase_ends_ms } => {
                if now >= *phase_ends_ms {
                    *in_burst = !*in_burst;
                    *phase_ends_ms = now + self.rng.exp(1.0 / *mean_phase_ms);
                }
                let rate = if *in_burst { *burst_rps } else { *calm_rps };
                now + self.rng.exp(rate / 1000.0)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_matches() {
        let mut p = ArrivalProcess::poisson(10.0, Rng::new(1));
        let mut t = 0.0;
        let n = 20_000;
        for _ in 0..n {
            t = p.next_after(t);
        }
        let rate = n as f64 / (t / 1000.0);
        assert!((rate - 10.0).abs() < 0.3, "rate={rate}");
    }

    #[test]
    fn arrivals_strictly_increase() {
        let mut p = ArrivalProcess::poisson(100.0, Rng::new(2));
        let mut t = 0.0;
        for _ in 0..1000 {
            let nt = p.next_after(t);
            assert!(nt > t);
            t = nt;
        }
    }

    #[test]
    fn uniform_gap() {
        let mut p = ArrivalProcess::uniform(50.0, Rng::new(3));
        assert_eq!(p.next_after(0.0), 50.0);
        assert_eq!(p.next_after(50.0), 100.0);
    }

    #[test]
    fn bursty_has_higher_variance_than_poisson() {
        let gaps = |mut p: ArrivalProcess| {
            let mut t = 0.0;
            let mut gs = Vec::new();
            for _ in 0..20_000 {
                let nt = p.next_after(t);
                gs.push(nt - t);
                t = nt;
            }
            gs
        };
        let pg = gaps(ArrivalProcess::poisson(10.0, Rng::new(5)));
        let bg = gaps(ArrivalProcess::bursty(4.0, 40.0, 2_000.0, Rng::new(5)));
        let cv = |g: &[f64]| {
            let (m, s) = crate::util::stats::mean_std(g);
            s / m
        };
        assert!(cv(&bg) > cv(&pg) * 1.2, "burst cv={} poisson cv={}", cv(&bg), cv(&pg));
    }
}
