//! Real-time serving driver: the identical scheduler policy code running
//! against wall-clock time with real threads and channels (the offline
//! image has no tokio; std threads + mpsc fill the role).
//!
//! Architecture:
//! * a **provider thread** owns the mock black-box fleet: it receives
//!   batched submissions over one channel — multiplexed from every tenant —
//!   enforces each shard's hidden concurrency limit + FIFO, and emits
//!   completions back to the *owning tenant's* channel at the right
//!   wall-clock instants;
//! * one **client thread per tenant** runs that tenant's scheduler loop:
//!   waits for the earliest of {next arrival, next retry, next timeout, a
//!   completion}, feeds the scheduler, and submits each tick's Send actions
//!   as one batch message. Tenant 0 runs on the caller thread, so the
//!   single-tenant demo is exactly the classic one.
//!
//! Model time is scaled by `scale` (wall ms per model ms) so demos finish
//! in seconds while preserving the physics ratios. If AOT artifacts are
//! present (single-tenant runs only), per-request priors come from the PJRT
//! predictor at admission time — the full L3→runtime→L1/L2 path on the live
//! request path.

use std::collections::{BinaryHeap, VecDeque};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::core::{Priors, ReqId, Request, RequestStatus};
use crate::metrics::{compute, RequestOutcome, RunMetrics};
use crate::predictor::{InfoLevel, LadderSource, PriorSource, Route};
use crate::provider::pool::PoolCfg;
use crate::provider::ProviderCfg;
use crate::runtime::{artifacts_available, NnPriorSource, Predictor};
use crate::scheduler::{
    Action, ClientScheduler, SchedulerCfg, ShardCfg, ShardPolicy, StrategyKind,
};
use crate::sim::driver::{split_requests, tenant_seed};
use crate::util::rng::Rng;
use crate::workload::{ArrivalSpec, Mix, WorkloadSpec};

/// One submission inside a batch message to the provider thread.
struct SubmitItem {
    tenant: usize,
    id: ReqId,
    output_tokens: f64,
    shard: usize,
}

/// Message into the provider thread.
enum ToProvider {
    /// One client tick's Send batch, in release order.
    Submit(Vec<SubmitItem>),
    Shutdown,
}

/// Pending completion in the provider thread's finish heap. Min-ordered by
/// `(at, tenant, id)`: the tiebreak mirrors the DES `EventQueue`'s
/// (time, seq) ordering, where setup seqs are tenant-major. Ordering on
/// `at` alone left simultaneous completions popping in unspecified order,
/// breaking run-to-run reproducibility of the wall-clock demo.
struct Finish {
    at: Instant,
    tenant: usize,
    id: ReqId,
    shard: usize,
}

impl PartialEq for Finish {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.tenant == other.tenant && self.id == other.id
    }
}
impl Eq for Finish {}
impl PartialOrd for Finish {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Finish {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reverse all keys for a min-heap on (at, tenant, id).
        let ord = other.at.cmp(&self.at).then_with(|| other.tenant.cmp(&self.tenant));
        ord.then_with(|| other.id.cmp(&self.id))
    }
}

/// One endpoint's wall-clock state: the DES mock's physics (hidden
/// concurrency gate, invisible FIFO, load-dependent service + jitter). The
/// fleet is shared by every tenant; the hidden queue remembers each
/// request's owner so its completion routes home.
struct ShardState {
    cfg: ProviderCfg,
    rng: Rng,
    running: usize,
    waiting: VecDeque<(usize, ReqId, f64)>,
}

/// Start `id` on shard `shard_ix`: sample service at the post-admission
/// running count and schedule the completion instant.
fn start_on(
    shard_ix: usize,
    shard: &mut ShardState,
    heap: &mut BinaryHeap<Finish>,
    tenant: usize,
    id: ReqId,
    tokens: f64,
    scale: f64,
) {
    shard.running += 1;
    let mean = shard.cfg.service_ms(tokens, shard.running);
    let ms = if shard.cfg.jitter_sigma > 0.0 {
        mean * shard.rng.lognormal(0.0, shard.cfg.jitter_sigma)
    } else {
        mean
    };
    let d = Duration::from_secs_f64(ms * scale / 1000.0);
    heap.push(Finish { at: Instant::now() + d, tenant, id, shard: shard_ix });
}

/// Provider thread: the sharded fleet on wall-clock time, multiplexing
/// submissions from every tenant. Completions are sent back to the owning
/// tenant's channel at their completion instants.
fn provider_thread(
    pool: PoolCfg,
    scale: f64,
    rx: mpsc::Receiver<ToProvider>,
    txs: Vec<mpsc::Sender<ReqId>>,
    seed: u64,
) {
    let base = Rng::new(seed).derive("provider");
    let n = pool.n_shards();
    let mut shards: Vec<ShardState> = pool
        .shards
        .iter()
        .enumerate()
        .map(|(i, cfg)| ShardState {
            cfg: cfg.clone(),
            rng: if n == 1 { base.clone() } else { base.derive(&format!("shard{i}")) },
            running: 0,
            waiting: VecDeque::new(),
        })
        .collect();
    let mut heap: BinaryHeap<Finish> = BinaryHeap::new();
    loop {
        // Drain due completions (instant ties pop in (tenant, id) order).
        let now = Instant::now();
        while heap.peek().map(|f| f.at <= now).unwrap_or(false) {
            let f = heap.pop().unwrap();
            let s = &mut shards[f.shard];
            s.running -= 1;
            let _ = txs[f.tenant].send(f.id);
            // Promote that shard's hidden queue.
            if let Some((tenant, id, tokens)) = s.waiting.pop_front() {
                start_on(f.shard, s, &mut heap, tenant, id, tokens, scale);
            }
        }
        // Wait for the next submission batch or the next finish.
        let timeout = heap
            .peek()
            .map(|f| f.at.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(ToProvider::Submit(batch)) => {
                for item in batch {
                    let s = &mut shards[item.shard];
                    if s.running < s.cfg.max_concurrency {
                        start_on(
                            item.shard,
                            s,
                            &mut heap,
                            item.tenant,
                            item.id,
                            item.output_tokens,
                            scale,
                        );
                    } else {
                        s.waiting.push_back((item.tenant, item.id, item.output_tokens));
                    }
                }
            }
            Ok(ToProvider::Shutdown) => break,
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
}

/// One tenant's client loop on wall-clock time: the scheduler tick cycle
/// against shared channels. Returns the tenant's metrics once every one of
/// its requests reaches a terminal state.
#[allow(clippy::too_many_arguments)]
fn client_loop(
    tenant: usize,
    label: &str,
    requests: &[Request],
    mut scheduler: ClientScheduler,
    mut priors_of: impl FnMut(&Request) -> (Priors, Route),
    scale: f64,
    epoch: Instant,
    to_provider: &mpsc::Sender<ToProvider>,
    completion_rx: &mpsc::Receiver<ReqId>,
) -> RunMetrics {
    let n_requests = requests.len();
    let to_model_ms = |i: Instant| i.duration_since(epoch).as_secs_f64() * 1000.0 / scale;
    let to_wall = |model_ms: f64| epoch + Duration::from_secs_f64(model_ms * scale / 1000.0);

    let mut status = vec![RequestStatus::Queued; n_requests];
    let mut latency: Vec<Option<f64>> = vec![None; n_requests];
    let mut defer_counts = vec![0u32; n_requests];
    // Pending client-side timers: (wall instant, kind, id).
    enum Timer {
        Arrival,
        Retry,
        Timeout,
    }
    let mut timers: Vec<(Instant, Timer, ReqId)> = Vec::new();
    for r in requests {
        timers.push((to_wall(r.arrival_ms), Timer::Arrival, r.id));
        timers.push((to_wall(r.timeout_ms), Timer::Timeout, r.id));
    }
    let mut arrived = 0usize;
    let mut done = 0usize;

    // Reusable action buffer: the scheduler appends, `apply` drains. Each
    // tick's Sends travel to the provider thread as ONE batch message in
    // release order — one channel send per tick instead of one per request.
    let mut actions: Vec<Action> = Vec::new();
    let apply = |actions: &[Action],
                 timers: &mut Vec<(Instant, Timer, ReqId)>,
                 status: &mut Vec<RequestStatus>,
                 defer_counts: &mut Vec<u32>| {
        let mut batch: Vec<SubmitItem> = Vec::new();
        for a in actions {
            match *a {
                Action::Send { id, shard } => {
                    status[id] = RequestStatus::InFlight;
                    batch.push(SubmitItem {
                        tenant,
                        id,
                        output_tokens: requests[id].true_output_tokens as f64,
                        shard,
                    });
                }
                Action::Retry { id, at_ms } => {
                    status[id] = RequestStatus::Deferred;
                    defer_counts[id] += 1;
                    timers.push((to_wall(at_ms), Timer::Retry, id));
                }
                Action::Reject { id } => {
                    status[id] = RequestStatus::Rejected;
                }
            }
        }
        if !batch.is_empty() {
            let _ = to_provider.send(ToProvider::Submit(batch));
        }
    };

    while done + timers.len() > 0 && !(timers.is_empty() && done >= arrived && arrived == n_requests)
    {
        // Find earliest timer.
        timers.sort_by_key(|(at, _, _)| *at);
        let next_at = timers.first().map(|(at, _, _)| *at);
        let timeout = next_at
            .map(|at| at.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(20));
        match completion_rx.recv_timeout(timeout) {
            Ok(id) => {
                let now_ms = to_model_ms(Instant::now());
                if status[id] == RequestStatus::InFlight {
                    status[id] = RequestStatus::Completed;
                    let lat = now_ms - requests[id].arrival_ms;
                    latency[id] = Some(lat);
                    done += 1;
                    let budget = requests[id].deadline_ms - requests[id].arrival_ms;
                    actions.clear();
                    scheduler.on_completion(id, lat, budget, now_ms, &mut actions);
                    apply(&actions, &mut timers, &mut status, &mut defer_counts);
                    let met = lat <= budget;
                    println!(
                        "{label}[{:>8.0}ms] done  #{id:<4} {}  latency {:>7.0}ms  {}",
                        now_ms,
                        requests[id].true_bucket.name(),
                        lat,
                        if met { "SLO ✓" } else { "SLO ✗" }
                    );
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                let now = Instant::now();
                // Fire every due timer.
                let mut i = 0;
                while i < timers.len() {
                    if timers[i].0 <= now {
                        let (_, kind, id) = timers.remove(i);
                        let now_ms = to_model_ms(Instant::now());
                        match kind {
                            Timer::Arrival => {
                                arrived += 1;
                                let (p, route) = priors_of(&requests[id]);
                                println!(
                                    "{label}[{:>8.0}ms] admit #{id:<4} {}  prior p50={:.0} p90={:.0}",
                                    now_ms,
                                    requests[id].true_bucket.name(),
                                    p.p50,
                                    p.p90
                                );
                                actions.clear();
                                scheduler.on_arrival(&requests[id], p, route, now_ms, &mut actions);
                                apply(&actions, &mut timers, &mut status, &mut defer_counts);
                            }
                            Timer::Retry => {
                                if status[id] == RequestStatus::Deferred {
                                    status[id] = RequestStatus::Queued;
                                    actions.clear();
                                    scheduler.on_retry_due(id, now_ms, &mut actions);
                                    apply(&actions, &mut timers, &mut status, &mut defer_counts);
                                }
                            }
                            Timer::Timeout => {
                                if matches!(
                                    status[id],
                                    RequestStatus::Queued
                                        | RequestStatus::Deferred
                                        | RequestStatus::InFlight
                                ) {
                                    actions.clear();
                                    scheduler.cancel(id, now_ms, &mut actions);
                                    status[id] = RequestStatus::TimedOut;
                                    println!("{label}[{:>8.0}ms] TIMEOUT #{id}", now_ms);
                                    apply(&actions, &mut timers, &mut status, &mut defer_counts);
                                }
                            }
                        }
                    } else {
                        i += 1;
                    }
                }
                // Count terminal rejects toward done.
                done = status
                    .iter()
                    .filter(|s| {
                        matches!(
                            s,
                            RequestStatus::Completed
                                | RequestStatus::Rejected
                                | RequestStatus::TimedOut
                        )
                    })
                    .count();
                if done == n_requests && timers.iter().all(|(_, k, _)| !matches!(k, Timer::Arrival))
                {
                    break;
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }

    let outcomes: Vec<RequestOutcome> = requests
        .iter()
        .map(|r| RequestOutcome {
            id: r.id,
            bucket: r.true_bucket,
            class: r.true_bucket.class(),
            arrival_ms: r.arrival_ms,
            deadline_ms: r.deadline_ms,
            status: status[r.id],
            latency_ms: latency[r.id],
            defer_count: defer_counts[r.id],
        })
        .collect();
    compute(
        &outcomes,
        scheduler.controller().defers_by_bucket,
        scheduler.controller().rejects_by_bucket,
        scheduler.feasibility_violations(),
    )
}

fn print_summary(prefix: &str, m: &RunMetrics) {
    println!(
        "{prefix}offered {}  completed {}  rejected {}  timed-out {}",
        m.n_offered, m.n_completed, m.n_rejected, m.n_timed_out
    );
    println!(
        "{prefix}completion {:.3}  satisfaction {:.3}  goodput {:.2} req/s  short P95 {:.0} ms  global P95 {:.0} ms",
        m.completion_rate, m.satisfaction, m.goodput_rps, m.short_p95_ms, m.global_p95_ms
    );
}

/// Run the real-time demo; prints live progress and a final metrics table.
///
/// `pool_cfg` shapes the provider fleet (one shard = the classic demo);
/// `shard_policy` is the client-side selection policy across it; `tenants`
/// is the number of independent client schedulers sharing the fleet. With
/// `tenants > 1` the offered load is split evenly (rate and request count),
/// each tenant runs its own scheduler thread on its own derived workload
/// stream, and the provider thread multiplexes all of their batches.
#[allow(clippy::too_many_arguments)]
pub fn serve_demo(
    strategy: StrategyKind,
    rate_rps: f64,
    n_requests: usize,
    scale: f64,
    artifacts_dir: &str,
    pool_cfg: PoolCfg,
    shard_policy: ShardPolicy,
    tenants: usize,
    arrivals: ArrivalSpec,
) -> Result<()> {
    anyhow::ensure!(tenants >= 1, "serve needs at least one tenant");
    let seed = 0u64;

    // Priors: PJRT predictor when the runtime is compiled in and artifacts
    // exist, analytic ladder otherwise (the default build ships a stub
    // runtime, so artifacts on disk must not turn into a hard failure).
    // Multi-tenant demos always use the analytic source: the predictor
    // handle is not shared across client threads.
    let mut nn_source: Option<NnPriorSource> = if tenants == 1
        && cfg!(feature = "pjrt")
        && !artifacts_dir.is_empty()
        && artifacts_available(artifacts_dir)
    {
        match Predictor::load(artifacts_dir) {
            Ok(p) => {
                println!("using PJRT predictor from {artifacts_dir}");
                Some(NnPriorSource::new(p))
            }
            Err(e) => {
                println!("PJRT predictor unavailable ({e}) — using analytic coarse priors");
                None
            }
        }
    } else {
        println!("PJRT disabled, artifacts missing, or multi-tenant — using analytic priors");
        None
    };

    let (to_provider, provider_rx) = mpsc::channel::<ToProvider>();
    let n_shards = pool_cfg.n_shards();
    println!(
        "provider fleet: {n_shards} shard(s), policy {}, {tenants} tenant(s)",
        shard_policy.name()
    );
    let mut completion_txs: Vec<mpsc::Sender<ReqId>> = Vec::with_capacity(tenants);
    let mut completion_rxs: Vec<mpsc::Receiver<ReqId>> = Vec::with_capacity(tenants);
    for _ in 0..tenants {
        let (tx, rx) = mpsc::channel::<ReqId>();
        completion_txs.push(tx);
        completion_rxs.push(rx);
    }
    let pcfg = pool_cfg.clone();
    let provider_handle = std::thread::spawn(move || {
        provider_thread(pcfg, scale, provider_rx, completion_txs, seed);
    });

    let shard_cfg = ShardCfg::new(
        n_shards,
        shard_policy,
        if n_shards == 1 { Vec::new() } else { pool_cfg.client_weights() },
    );
    // Total-conserving split: the fleet is offered exactly `n_requests`.
    let per_counts = split_requests(n_requests, tenants);
    let per_rate = rate_rps / tenants as f64;
    let epoch = Instant::now();

    // Tenants 1.. run on their own threads; tenant 0 runs on the caller
    // thread (so the single-tenant demo is exactly the classic one, and the
    // optional PJRT source never has to cross a thread boundary). Receivers
    // are handed out in tenant order, pairing with the provider's
    // `txs[tenant]` routing.
    let mut rx_iter = completion_rxs.into_iter();
    let rx0 = rx_iter.next().expect("tenant 0 receiver");
    let mut handles = Vec::new();
    for (t, rx) in rx_iter.enumerate().map(|(i, rx)| (i + 1, rx)) {
        let spec =
            WorkloadSpec::new(Mix::Balanced, per_counts[t], per_rate).with_arrivals(arrivals);
        let tseed = tenant_seed(seed, t);
        let mut cfg = SchedulerCfg::for_strategy(strategy);
        cfg.shards = shard_cfg.clone();
        let tx = to_provider.clone();
        handles.push(std::thread::spawn(move || {
            let requests = spec.generate(tseed);
            let scheduler = ClientScheduler::new(cfg);
            // Same prior-stream convention as the DES `run_tenants`, so a
            // wall-clock tenant and its simulated twin draw identical
            // priors for the same tseed. (Tenant 0 keeps the historic
            // serve stream below, preserving the classic 1-tenant demo.)
            let prior_rng = Rng::new(tseed ^ 0x5EED_50_u64).derive("priors");
            let mut src = LadderSource::new(InfoLevel::Coarse, prior_rng);
            let priors = |r: &Request| src.priors(r);
            let label = format!("t{t} ");
            client_loop(t, &label, &requests, scheduler, priors, scale, epoch, &tx, &rx)
        }));
    }

    let spec0 = WorkloadSpec::new(Mix::Balanced, per_counts[0], per_rate).with_arrivals(arrivals);
    let requests0 = spec0.generate(tenant_seed(seed, 0));
    let mut cfg0 = SchedulerCfg::for_strategy(strategy);
    cfg0.shards = shard_cfg.clone();
    let scheduler0 = ClientScheduler::new(cfg0);
    let mut analytic = LadderSource::new(InfoLevel::Coarse, Rng::new(seed).derive("priors"));
    let label0 = if tenants == 1 { String::new() } else { "t0 ".to_string() };
    let m0 = client_loop(
        0,
        &label0,
        &requests0,
        scheduler0,
        |r| match nn_source.as_mut() {
            Some(nn) => nn.priors(r),
            None => analytic.priors(r),
        },
        scale,
        epoch,
        &to_provider,
        &rx0,
    );

    let mut per_tenant: Vec<RunMetrics> = vec![m0];
    for h in handles {
        per_tenant.push(h.join().expect("tenant thread panicked"));
    }
    let _ = to_provider.send(ToProvider::Shutdown);
    let _ = provider_handle.join();

    println!("\n== serve summary ({}, {tenants} tenant(s)) ==", strategy.name());
    if tenants == 1 {
        print_summary("", &per_tenant[0]);
    } else {
        for (t, m) in per_tenant.iter().enumerate() {
            println!("-- tenant {t} --");
            print_summary("  ", m);
        }
        let offered: usize = per_tenant.iter().map(|m| m.n_offered).sum();
        let completed: usize = per_tenant.iter().map(|m| m.n_completed).sum();
        let goodput: f64 = per_tenant.iter().map(|m| m.goodput_rps).sum();
        let worst_sat = per_tenant.iter().map(|m| m.satisfaction).fold(f64::INFINITY, f64::min);
        println!("-- fleet --");
        println!(
            "  offered {offered}  completed {completed}  total goodput {goodput:.2} req/s  \
             worst-tenant satisfaction {worst_sat:.3}"
        );
    }
    if let Some(nn) = &nn_source {
        println!("PJRT predictor calls on the live path: {}", nn.calls());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finish_heap_breaks_instant_ties_by_tenant_then_req_id() {
        // Regression: ordering on `at` alone popped simultaneous
        // completions in unspecified (heap-internal) order.
        let t = Instant::now();
        let mut h: BinaryHeap<Finish> = BinaryHeap::new();
        h.push(Finish { at: t, tenant: 0, id: 7, shard: 0 });
        h.push(Finish { at: t, tenant: 0, id: 3, shard: 1 });
        h.push(Finish { at: t, tenant: 0, id: 5, shard: 0 });
        let order: Vec<ReqId> = std::iter::from_fn(|| h.pop().map(|f| f.id)).collect();
        assert_eq!(order, vec![3, 5, 7], "simultaneous completions pop in ReqId order");
        // Across tenants, tenant index breaks the tie first (mirroring the
        // DES's tenant-major seq assignment).
        let mut h: BinaryHeap<Finish> = BinaryHeap::new();
        h.push(Finish { at: t, tenant: 1, id: 1, shard: 0 });
        h.push(Finish { at: t, tenant: 0, id: 9, shard: 0 });
        let order: Vec<(usize, ReqId)> =
            std::iter::from_fn(|| h.pop().map(|f| (f.tenant, f.id))).collect();
        assert_eq!(order, vec![(0, 9), (1, 1)]);
    }

    #[test]
    fn finish_heap_orders_by_time_before_id() {
        let t = Instant::now();
        let mut h: BinaryHeap<Finish> = BinaryHeap::new();
        h.push(Finish { at: t + Duration::from_millis(5), tenant: 0, id: 1, shard: 0 });
        h.push(Finish { at: t, tenant: 1, id: 9, shard: 0 });
        assert_eq!(h.pop().unwrap().id, 9, "earlier instant wins regardless of id/tenant");
        assert_eq!(h.pop().unwrap().id, 1);
    }
}
