//! Real-time serving driver: the identical scheduler policy code running
//! against wall-clock time with real threads and channels (the offline
//! image has no tokio; std threads + mpsc fill the role).
//!
//! Architecture:
//! * a **provider thread** owns the mock black-box API: it receives
//!   submissions over a channel, enforces the hidden concurrency limit +
//!   FIFO, and emits completions back at the right wall-clock instants;
//! * the **client thread** (caller) runs the scheduler loop: waits for the
//!   earliest of {next arrival, next retry, next timeout, a completion},
//!   feeds the scheduler, and submits its Send actions.
//!
//! Model time is scaled by `scale` (wall ms per model ms) so demos finish
//! in seconds while preserving the physics ratios. If AOT artifacts are
//! present, per-request priors come from the PJRT predictor at admission
//! time — the full L3→runtime→L1/L2 path on the live request path.

use std::collections::BinaryHeap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::core::{ReqId, RequestStatus};
use crate::metrics::{compute, RequestOutcome};
use crate::predictor::{InfoLevel, LadderSource, PriorSource};
use crate::provider::ProviderCfg;
use crate::runtime::{artifacts_available, NnPriorSource, Predictor};
use crate::scheduler::{Action, ClientScheduler, SchedulerCfg, StrategyKind};
use crate::util::rng::Rng;
use crate::workload::{Mix, WorkloadSpec};

/// Message into the provider thread.
enum ToProvider {
    Submit { id: ReqId, output_tokens: f64 },
    Shutdown,
}

/// Provider thread: hidden concurrency + FIFO + load-dependent service, on
/// wall-clock time. Completions are sent as (id, completion_wall_instant).
fn provider_thread(
    cfg: ProviderCfg,
    scale: f64,
    rx: mpsc::Receiver<ToProvider>,
    tx: mpsc::Sender<ReqId>,
    seed: u64,
) {
    struct Finish {
        at: Instant,
        id: ReqId,
    }
    impl PartialEq for Finish {
        fn eq(&self, other: &Self) -> bool {
            self.at == other.at
        }
    }
    impl Eq for Finish {}
    impl PartialOrd for Finish {
        fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(other))
        }
    }
    impl Ord for Finish {
        fn cmp(&self, other: &Self) -> std::cmp::Ordering {
            other.at.cmp(&self.at) // min-heap
        }
    }

    let mut rng = Rng::new(seed).derive("provider");
    let mut running: BinaryHeap<Finish> = BinaryHeap::new();
    let mut waiting: std::collections::VecDeque<(ReqId, f64)> = Default::default();
    let service =
        |cfg: &ProviderCfg, rng: &mut Rng, tokens: f64, n_running: usize| -> Duration {
            let mean = cfg.service_ms(tokens, n_running);
            let ms = if cfg.jitter_sigma > 0.0 {
                mean * rng.lognormal(0.0, cfg.jitter_sigma)
            } else {
                mean
            };
            Duration::from_secs_f64(ms * scale / 1000.0)
        };
    loop {
        // Drain due completions.
        let now = Instant::now();
        while running.peek().map(|f| f.at <= now).unwrap_or(false) {
            let f = running.pop().unwrap();
            let _ = tx.send(f.id);
            // Promote hidden queue.
            if let Some((id, tokens)) = waiting.pop_front() {
                let n = running.len() + 1;
                let d = service(&cfg, &mut rng, tokens, n);
                running.push(Finish { at: Instant::now() + d, id });
            }
        }
        // Wait for the next submission or the next finish.
        let timeout = running
            .peek()
            .map(|f| f.at.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(ToProvider::Submit { id, output_tokens }) => {
                if running.len() < cfg.max_concurrency {
                    let n = running.len() + 1;
                    let d = service(&cfg, &mut rng, output_tokens, n);
                    running.push(Finish { at: Instant::now() + d, id });
                } else {
                    waiting.push_back((id, output_tokens));
                }
            }
            Ok(ToProvider::Shutdown) => break,
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
}

/// Run the real-time demo; prints live progress and a final metrics table.
pub fn serve_demo(
    strategy: StrategyKind,
    rate_rps: f64,
    n_requests: usize,
    scale: f64,
    artifacts_dir: &str,
) -> Result<()> {
    let seed = 0u64;
    let spec = WorkloadSpec::new(Mix::Balanced, n_requests, rate_rps);
    let requests = spec.generate(seed);

    // Priors: PJRT predictor when the runtime is compiled in and artifacts
    // exist, analytic ladder otherwise (the default build ships a stub
    // runtime, so artifacts on disk must not turn into a hard failure).
    let mut nn_source: Option<NnPriorSource> = if cfg!(feature = "pjrt")
        && !artifacts_dir.is_empty()
        && artifacts_available(artifacts_dir)
    {
        println!("using PJRT predictor from {artifacts_dir}");
        Some(NnPriorSource::new(Predictor::load(artifacts_dir)?))
    } else {
        println!("artifacts not found or PJRT disabled — using analytic coarse priors");
        None
    };
    let mut analytic = LadderSource::new(InfoLevel::Coarse, Rng::new(seed).derive("priors"));

    let (to_provider, provider_rx) = mpsc::channel::<ToProvider>();
    let (completion_tx, completion_rx) = mpsc::channel::<ReqId>();
    let provider_cfg = ProviderCfg::default();
    let pcfg = provider_cfg.clone();
    let handle =
        std::thread::spawn(move || provider_thread(pcfg, scale, provider_rx, completion_tx, seed));

    let mut scheduler = ClientScheduler::new(SchedulerCfg::for_strategy(strategy));
    let epoch = Instant::now();
    let to_model_ms = |i: Instant| i.duration_since(epoch).as_secs_f64() * 1000.0 / scale;
    let to_wall = |model_ms: f64| epoch + Duration::from_secs_f64(model_ms * scale / 1000.0);

    let mut status = vec![RequestStatus::Queued; n_requests];
    let mut latency: Vec<Option<f64>> = vec![None; n_requests];
    let mut defer_counts = vec![0u32; n_requests];
    // Pending client-side timers: (wall instant, kind, id).
    enum Timer {
        Arrival,
        Retry,
        Timeout,
    }
    let mut timers: Vec<(Instant, Timer, ReqId)> = Vec::new();
    for r in &requests {
        timers.push((to_wall(r.arrival_ms), Timer::Arrival, r.id));
        timers.push((to_wall(r.timeout_ms), Timer::Timeout, r.id));
    }
    let mut arrived = 0usize;
    let mut done = 0usize;

    // Reusable action buffer: the scheduler appends, `apply` drains.
    let mut actions: Vec<Action> = Vec::new();
    let apply = |actions: &[Action],
                     timers: &mut Vec<(Instant, Timer, ReqId)>,
                     status: &mut Vec<RequestStatus>,
                     defer_counts: &mut Vec<u32>| {
        for a in actions {
            match *a {
                Action::Send { id } => {
                    status[id] = RequestStatus::InFlight;
                    let _ = to_provider.send(ToProvider::Submit {
                        id,
                        output_tokens: requests[id].true_output_tokens as f64,
                    });
                }
                Action::Retry { id, at_ms } => {
                    status[id] = RequestStatus::Deferred;
                    defer_counts[id] += 1;
                    timers.push((to_wall(at_ms), Timer::Retry, id));
                }
                Action::Reject { id } => {
                    status[id] = RequestStatus::Rejected;
                }
            }
        }
    };

    while done + timers.len() > 0 && !(timers.is_empty() && done >= arrived && arrived == n_requests)
    {
        // Find earliest timer.
        timers.sort_by_key(|(at, _, _)| *at);
        let next_at = timers.first().map(|(at, _, _)| *at);
        let timeout = next_at
            .map(|at| at.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(20));
        match completion_rx.recv_timeout(timeout) {
            Ok(id) => {
                let now_ms = to_model_ms(Instant::now());
                if status[id] == RequestStatus::InFlight {
                    status[id] = RequestStatus::Completed;
                    let lat = now_ms - requests[id].arrival_ms;
                    latency[id] = Some(lat);
                    done += 1;
                    let budget = requests[id].deadline_ms - requests[id].arrival_ms;
                    actions.clear();
                    scheduler.on_completion(id, lat, budget, now_ms, &mut actions);
                    apply(&actions, &mut timers, &mut status, &mut defer_counts);
                    let met = lat <= budget;
                    println!(
                        "[{:>8.0}ms] done  #{id:<4} {}  latency {:>7.0}ms  {}",
                        now_ms,
                        requests[id].true_bucket.name(),
                        lat,
                        if met { "SLO ✓" } else { "SLO ✗" }
                    );
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                let now = Instant::now();
                // Fire every due timer.
                let mut i = 0;
                while i < timers.len() {
                    if timers[i].0 <= now {
                        let (_, kind, id) = timers.remove(i);
                        let now_ms = to_model_ms(Instant::now());
                        match kind {
                            Timer::Arrival => {
                                arrived += 1;
                                let (p, route) = match nn_source.as_mut() {
                                    Some(nn) => nn.priors(&requests[id]),
                                    None => analytic.priors(&requests[id]),
                                };
                                println!(
                                    "[{:>8.0}ms] admit #{id:<4} {}  prior p50={:.0} p90={:.0}",
                                    now_ms,
                                    requests[id].true_bucket.name(),
                                    p.p50,
                                    p.p90
                                );
                                actions.clear();
                                scheduler.on_arrival(&requests[id], p, route, now_ms, &mut actions);
                                apply(&actions, &mut timers, &mut status, &mut defer_counts);
                            }
                            Timer::Retry => {
                                if status[id] == RequestStatus::Deferred {
                                    status[id] = RequestStatus::Queued;
                                    actions.clear();
                                    scheduler.on_retry_due(id, now_ms, &mut actions);
                                    apply(&actions, &mut timers, &mut status, &mut defer_counts);
                                }
                            }
                            Timer::Timeout => {
                                if matches!(
                                    status[id],
                                    RequestStatus::Queued
                                        | RequestStatus::Deferred
                                        | RequestStatus::InFlight
                                ) {
                                    actions.clear();
                                    scheduler.cancel(id, now_ms, &mut actions);
                                    status[id] = RequestStatus::TimedOut;
                                    println!("[{:>8.0}ms] TIMEOUT #{id}", now_ms);
                                    apply(&actions, &mut timers, &mut status, &mut defer_counts);
                                }
                            }
                        }
                    } else {
                        i += 1;
                    }
                }
                // Count terminal rejects toward done.
                done = status
                    .iter()
                    .filter(|s| {
                        matches!(
                            s,
                            RequestStatus::Completed
                                | RequestStatus::Rejected
                                | RequestStatus::TimedOut
                        )
                    })
                    .count();
                if done == n_requests && timers.iter().all(|(_, k, _)| !matches!(k, Timer::Arrival))
                {
                    break;
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
    let _ = to_provider.send(ToProvider::Shutdown);
    let _ = handle.join();

    let outcomes: Vec<RequestOutcome> = requests
        .iter()
        .map(|r| RequestOutcome {
            id: r.id,
            bucket: r.true_bucket,
            class: r.true_bucket.class(),
            arrival_ms: r.arrival_ms,
            deadline_ms: r.deadline_ms,
            status: status[r.id],
            latency_ms: latency[r.id],
            defer_count: defer_counts[r.id],
        })
        .collect();
    let m = compute(
        &outcomes,
        scheduler.controller().defers_by_bucket,
        scheduler.controller().rejects_by_bucket,
        scheduler.feasibility_violations(),
    );
    println!("\n== serve summary ({}) ==", strategy.name());
    println!("offered {}  completed {}  rejected {}  timed-out {}", m.n_offered, m.n_completed, m.n_rejected, m.n_timed_out);
    println!(
        "completion {:.3}  satisfaction {:.3}  goodput {:.2} req/s  short P95 {:.0} ms  global P95 {:.0} ms",
        m.completion_rate, m.satisfaction, m.goodput_rps, m.short_p95_ms, m.global_p95_ms
    );
    if let Some(nn) = &nn_source {
        println!("PJRT predictor calls on the live path: {}", nn.calls());
    }
    Ok(())
}
