//! Config system: every knob of the scheduler, provider, workload, and
//! SLO policy is settable from a JSON file, so deployments and experiment
//! variants are data, not code. `bbsched run --config cfg.json` and the
//! library's `RunConfig::from_file` both land here.
//!
//! The file is a JSON object with (all-optional) sections; anything omitted
//! keeps the built-in default. See `example_config()` for the full schema.

use anyhow::{bail, Context, Result};

use crate::core::SloPolicy;
use crate::provider::ProviderCfg;
use crate::scheduler::overload::BucketPolicy;
use crate::scheduler::{OrderingKind, SchedulerCfg, StrategyKind};
use crate::util::jsonio::Json;
use crate::workload::{ArrivalSpec, Mix, WorkloadSpec};

/// Fully-resolved configuration for one run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub workload: WorkloadSpec,
    pub scheduler: SchedulerCfg,
    pub provider: ProviderCfg,
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            workload: WorkloadSpec::new(Mix::Balanced, 200, 12.0),
            scheduler: SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc),
            provider: ProviderCfg::default(),
            seed: 0,
        }
    }
}

impl RunConfig {
    pub fn from_file(path: &str) -> Result<RunConfig> {
        let j = Json::read_file(path).with_context(|| format!("reading config {path}"))?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        cfg.seed = j.f64_or("seed", cfg.seed as f64) as u64;

        if let Some(w) = j.get("workload") {
            let mix_name = w.str_or("mix", cfg.workload.mix.name());
            let mix = Mix::parse(mix_name)
                .with_context(|| format!("unknown workload.mix {mix_name:?}"))?;
            let mut spec = WorkloadSpec::new(
                mix,
                w.f64_or("n_requests", cfg.workload.n_requests as f64) as usize,
                w.f64_or("rate_rps", cfg.workload.rate_rps),
            );
            if let Some(name) = w.get("arrivals").and_then(Json::as_str) {
                spec.arrivals = ArrivalSpec::parse(name)
                    .with_context(|| format!("unknown workload.arrivals {name:?}"))?;
            }
            if let Some(slo) = w.get("slo") {
                let mut policy = SloPolicy::default();
                if let Some(d) = slo.get("deadline_ms") {
                    let v = d.f64_array().context("slo.deadline_ms")?;
                    if v.len() != 4 {
                        bail!("slo.deadline_ms needs 4 entries (short..xlong)");
                    }
                    policy.deadline_ms = [v[0], v[1], v[2], v[3]];
                }
                policy.timeout_factor = slo.f64_or("timeout_factor", policy.timeout_factor);
                spec.slo = policy;
            }
            cfg.workload = spec;
        }

        if let Some(s) = j.get("scheduler") {
            let name = s.str_or("strategy", cfg.scheduler.strategy.name());
            let strategy = StrategyKind::parse(name)
                .with_context(|| format!("unknown scheduler.strategy {name:?}"))?;
            let mut sched = SchedulerCfg::for_strategy(strategy);
            sched.max_inflight = s.f64_or("max_inflight", sched.max_inflight as f64) as usize;
            sched.interactive_bypass =
                s.f64_or("interactive_bypass", sched.interactive_bypass as f64) as usize;
            sched.quota_interactive =
                s.f64_or("quota_interactive", sched.quota_interactive as f64) as usize;
            sched.quota_heavy = s.f64_or("quota_heavy", sched.quota_heavy as f64) as usize;
            if let Some(name) = s.get("heavy_ordering").and_then(Json::as_str) {
                sched.heavy_ordering = OrderingKind::parse(name)
                    .with_context(|| format!("unknown heavy_ordering {name:?}"))?;
            }
            if let Some(d) = s.get("drr") {
                sched.drr.quantum_tokens = d.f64_or("quantum_tokens", sched.drr.quantum_tokens);
                sched.drr.w_interactive = d.f64_or("w_interactive", sched.drr.w_interactive);
                sched.drr.w_heavy = d.f64_or("w_heavy", sched.drr.w_heavy);
                sched.drr.adaptive_gain = d.f64_or("adaptive_gain", sched.drr.adaptive_gain);
            }
            if let Some(o) = s.get("ordering") {
                sched.ordering.w_wait = o.f64_or("w_wait", sched.ordering.w_wait);
                sched.ordering.w_size = o.f64_or("w_size", sched.ordering.w_size);
                sched.ordering.w_urgency = o.f64_or("w_urgency", sched.ordering.w_urgency);
                sched.ordering.ref_tokens = o.f64_or("ref_tokens", sched.ordering.ref_tokens);
                sched.ordering.est_base_ms = o.f64_or("est_base_ms", sched.ordering.est_base_ms);
                sched.ordering.est_per_token_ms =
                    o.f64_or("est_per_token_ms", sched.ordering.est_per_token_ms);
                sched.ordering.est_slack_factor =
                    o.f64_or("est_slack_factor", sched.ordering.est_slack_factor);
            }
            if let Some(o) = s.get("overload") {
                sched.overload.enabled = o.get("enabled").and_then(Json::as_bool).unwrap_or(sched.overload.enabled);
                sched.overload.t_defer = o.f64_or("t_defer", sched.overload.t_defer);
                sched.overload.t_reject_xlong =
                    o.f64_or("t_reject_xlong", sched.overload.t_reject_xlong);
                sched.overload.t_reject_long =
                    o.f64_or("t_reject_long", sched.overload.t_reject_long);
                sched.overload.w_load = o.f64_or("w_load", sched.overload.w_load);
                sched.overload.w_queue = o.f64_or("w_queue", sched.overload.w_queue);
                sched.overload.w_tail = o.f64_or("w_tail", sched.overload.w_tail);
                sched.overload.defer_base_ms = o.f64_or("defer_base_ms", sched.overload.defer_base_ms);
                sched.overload.defer_cap_ms = o.f64_or("defer_cap_ms", sched.overload.defer_cap_ms);
                sched.overload.queue_budget_tokens =
                    o.f64_or("queue_budget_tokens", sched.overload.queue_budget_tokens);
                if let Some(name) = o.get("bucket_policy").and_then(Json::as_str) {
                    sched.overload.bucket_policy = BucketPolicy::parse(name)
                        .with_context(|| format!("unknown bucket_policy {name:?}"))?;
                }
            }
            cfg.scheduler = sched;
        }

        if let Some(p) = j.get("provider") {
            cfg.provider.base_ms = p.f64_or("base_ms", cfg.provider.base_ms);
            cfg.provider.per_token_ms = p.f64_or("per_token_ms", cfg.provider.per_token_ms);
            cfg.provider.max_concurrency =
                p.f64_or("max_concurrency", cfg.provider.max_concurrency as f64) as usize;
            cfg.provider.slowdown_gamma = p.f64_or("slowdown_gamma", cfg.provider.slowdown_gamma);
            cfg.provider.slowdown_exp = p.f64_or("slowdown_exp", cfg.provider.slowdown_exp);
            cfg.provider.slowdown_ref = p.f64_or("slowdown_ref", cfg.provider.slowdown_ref);
            cfg.provider.jitter_sigma = p.f64_or("jitter_sigma", cfg.provider.jitter_sigma);
        }
        Ok(cfg)
    }
}

/// A complete example config (also used by tests; `bbsched run
/// --dump-config` prints it).
pub fn example_config() -> Json {
    Json::obj()
        .set("seed", 0u64)
        .set(
            "workload",
            Json::obj()
                .set("mix", "heavy")
                .set("n_requests", 200usize)
                .set("rate_rps", 14.0)
                .set("arrivals", "bursty:4:2000")
                .set(
                    "slo",
                    Json::obj()
                        .set("deadline_ms", vec![2500.0, 8000.0, 20000.0, 40000.0])
                        .set("timeout_factor", 1.2),
                ),
        )
        .set(
            "scheduler",
            Json::obj()
                .set("strategy", "final_adrr_olc")
                .set("max_inflight", 8usize)
                .set("interactive_bypass", 4usize)
                .set("heavy_ordering", "feasible_set")
                .set(
                    "drr",
                    Json::obj()
                        .set("quantum_tokens", 400.0)
                        .set("w_interactive", 2.0)
                        .set("w_heavy", 1.0)
                        .set("adaptive_gain", 1.5),
                )
                .set(
                    "overload",
                    Json::obj()
                        .set("enabled", true)
                        .set("t_defer", 0.45)
                        .set("t_reject_xlong", 0.65)
                        .set("t_reject_long", 0.80)
                        .set("bucket_policy", "cost_ladder"),
                ),
        )
        .set(
            "provider",
            Json::obj()
                .set("base_ms", 150.0)
                .set("per_token_ms", 0.9)
                .set("slowdown_gamma", 0.8)
                .set("slowdown_exp", 1.5)
                .set("slowdown_ref", 8.0)
                .set("jitter_sigma", 0.06),
        )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_config_is_defaults() {
        let cfg = RunConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(cfg.scheduler.strategy, StrategyKind::FinalAdrrOlc);
        assert_eq!(cfg.workload.n_requests, 200);
        assert_eq!(cfg.seed, 0);
    }

    #[test]
    fn example_config_roundtrips() {
        let j = example_config();
        let cfg = RunConfig::from_json(&j).unwrap();
        assert_eq!(cfg.workload.mix, Mix::Heavy);
        assert_eq!(cfg.workload.rate_rps, 14.0);
        assert_eq!(
            cfg.workload.arrivals,
            ArrivalSpec::Bursty { burst_factor: 4.0, mean_phase_ms: 2000.0 }
        );
        assert_eq!(cfg.scheduler.overload.bucket_policy, BucketPolicy::CostLadder);
        assert_eq!(cfg.provider.slowdown_ref, 8.0);
        // Text round-trip too.
        let cfg2 = RunConfig::from_json(&Json::parse(&j.to_string_pretty()).unwrap()).unwrap();
        assert_eq!(cfg2.scheduler.max_inflight, cfg.scheduler.max_inflight);
    }

    #[test]
    fn partial_overrides_keep_defaults() {
        let j = Json::parse(
            r#"{"scheduler": {"strategy": "quota_tiered", "quota_heavy": 3},
                "provider": {"base_ms": 500}}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json(&j).unwrap();
        assert_eq!(cfg.scheduler.strategy, StrategyKind::QuotaTiered);
        assert_eq!(cfg.scheduler.quota_heavy, 3);
        assert_eq!(cfg.scheduler.quota_interactive, 4, "default kept");
        assert_eq!(cfg.provider.base_ms, 500.0);
        assert_eq!(cfg.provider.per_token_ms, 0.9, "default kept");
    }

    #[test]
    fn rejects_unknown_enums() {
        for bad in [
            r#"{"scheduler": {"strategy": "wizardry"}}"#,
            r#"{"workload": {"mix": "nope"}}"#,
            r#"{"scheduler": {"overload": {"bucket_policy": "chaos"}}}"#,
            r#"{"scheduler": {"heavy_ordering": "vibes"}}"#,
            r#"{"workload": {"arrivals": "chaos"}}"#,
        ] {
            assert!(RunConfig::from_json(&Json::parse(bad).unwrap()).is_err(), "{bad}");
        }
    }

    #[test]
    fn slo_deadline_validation() {
        let bad = r#"{"workload": {"slo": {"deadline_ms": [1, 2, 3]}}}"#;
        assert!(RunConfig::from_json(&Json::parse(bad).unwrap()).is_err());
        let good = r#"{"workload": {"slo": {"deadline_ms": [1000, 2000, 3000, 4000], "timeout_factor": 2.0}}}"#;
        let cfg = RunConfig::from_json(&Json::parse(good).unwrap()).unwrap();
        assert_eq!(cfg.workload.slo.deadline_ms[3], 4000.0);
        assert_eq!(cfg.workload.slo.timeout_factor, 2.0);
    }

    #[test]
    fn config_drives_a_run() {
        use crate::predictor::{InfoLevel, LadderSource};
        use crate::sim::driver;
        use crate::util::rng::Rng;
        let cfg = RunConfig::from_json(&example_config()).unwrap();
        let requests = cfg.workload.generate(cfg.seed);
        let mut src = LadderSource::new(InfoLevel::Coarse, Rng::new(cfg.seed).derive("p"));
        let out = driver::run(&requests, &mut src, cfg.scheduler, cfg.provider, cfg.seed);
        assert_eq!(out.metrics.n_offered, 200);
    }
}
