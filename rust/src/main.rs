//! `bbsched` — leader binary: experiments, single runs, traces, predictor
//! smoke tests, and the real-time serve demo.
//!
//! Usage:
//!   bbsched exp <name|all> [--seeds N] [--requests N] [--jobs N] [--partitions N] [--out DIR]
//!   bbsched run [--strategy S] [--mix M] [--rate R] [--seed N] ...
//!   bbsched bench [--sizes N,N] [--shards N] [--tenants M] [--depth] [--timers] [--partitions N] [--out BENCH.json] [--smoke]
//!   bbsched trace gen|show [--out PATH] ...
//!   bbsched predict [--artifacts DIR] [--n N]        (PJRT smoke + goldens)
//!   bbsched serve [--rate R] [--requests N] [--scale S] [--tenants M] (real-time demo)

use anyhow::{bail, Context, Result};

use blackbox_sched::bench::perf::{run_scale_bench, ScaleBenchOpts};
use blackbox_sched::experiments::{self, ExpOpts};
use blackbox_sched::metrics::report::TextTable;
use blackbox_sched::predictor::features::batch_features;
use blackbox_sched::predictor::{InfoLevel, LadderSource};
use blackbox_sched::provider::pool::PoolCfg;
use blackbox_sched::provider::ProviderCfg;
use blackbox_sched::runtime;
use blackbox_sched::scheduler::{SchedulerCfg, ShardPolicy, StrategyKind};
use blackbox_sched::sim::driver;
use blackbox_sched::util::cli::Cmd;
use blackbox_sched::util::rng::Rng;
use blackbox_sched::workload::{trace, ArrivalSpec, Mix, WorkloadSpec};

/// Parse an `--arrivals` CLI value (`poisson`, `bursty:4:2000`, …) with a
/// helpful error listing the accepted forms.
fn parse_arrivals(s: &str) -> Result<ArrivalSpec> {
    ArrivalSpec::parse(s).with_context(|| {
        format!(
            "bad arrivals {s:?}; accepted: poisson, uniform, bursty[:FACTOR:PHASE_MS], \
             diurnal[:PERIOD_MS:DEPTH], flash_crowd[:FACTOR:EVERY_MS:SPIKE_MS], \
             session[:TURNS:THINK_MS]"
        )
    })
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(sub) = args.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &args[1..];
    match sub.as_str() {
        "exp" => cmd_exp(rest),
        "run" => cmd_run(rest),
        "bench" => cmd_bench(rest),
        "trace" => cmd_trace(rest),
        "predict" => cmd_predict(rest),
        "serve" => cmd_serve(rest),
        "--help" | "-h" | "help" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand {other:?} (try --help)"),
    }
}

fn print_usage() {
    println!(
        "bbsched — client-side black-box LLM scheduler (paper reproduction)\n\
         \n\
         subcommands:\n\
         \x20 exp <name|all>   regenerate paper tables/figures ({})\n\
         \x20 run              one simulated run, printed summary\n\
         \x20 bench            scale/perf harness (all strategies) → BENCH.json\n\
         \x20 trace gen|show   generate / inspect workload traces\n\
         \x20 predict          PJRT predictor smoke test vs golden vectors\n\
         \x20 serve            real-time serving demo (wall-clock)\n",
        experiments::ALL_EXPERIMENTS.join(", ")
    );
}

fn cmd_exp(args: &[String]) -> Result<()> {
    let cmd = Cmd::new("exp", "regenerate paper tables/figures")
        .opt("seeds", "5", "seeds per cell")
        .opt("requests", "200", "offered requests per run")
        .opt("jobs", "0", "sweep worker threads (0 = all cores; output is identical for any value)")
        .opt(
            "partitions",
            "",
            "event-loop partitions per multi-tenant run (sets BBSCHED_PARTITIONS: 1 = serial, \
             0 = all cores; output is identical for any value)",
        )
        .opt("out", "paper_results/tables", "CSV output dir")
        .flag("verbose", "per-seed detail")
        .positionals();
    let a = cmd.parse(args)?;
    if a.help {
        print!("{}", cmd.help_text());
        return Ok(());
    }
    // The partition count travels by env (like BBSCHED_EVENT_QUEUE) so
    // every run_tenants call site inherits it without threading a
    // parameter through the experiment drivers.
    if !a.str("partitions").is_empty() {
        let p = a.usize("partitions")?;
        std::env::set_var(blackbox_sched::sim::partition::PARTITIONS_ENV, p.to_string());
    }
    let name = a.positionals.first().map(String::as_str).unwrap_or("all");
    let opts = ExpOpts {
        seeds: a.u64("seeds")?,
        n_requests: a.usize("requests")?,
        out_dir: a.str("out").to_string(),
        jobs: a.usize("jobs")?,
        verbose: a.flag("verbose"),
    };
    experiments::run_experiment(name, &opts)
}

fn cmd_run(args: &[String]) -> Result<()> {
    let cmd = Cmd::new("run", "one simulated run")
        .opt(
            "strategy",
            "final_adrr_olc",
            "direct_naive|quota_tiered|adaptive_drr|final_adrr_olc|fair_queuing|short_priority|plain_drr",
        )
        .opt("mix", "balanced", "balanced|heavy|sharegpt|fairness_heavy")
        .opt("rate", "10.0", "arrival rate (req/s)")
        .opt("requests", "120", "offered requests")
        .opt(
            "arrivals",
            "poisson",
            "arrival process: poisson|uniform|bursty[:F:PHASE]|diurnal[:PERIOD:DEPTH]|\
             flash_crowd[:F:EVERY:DUR]|session[:TURNS:THINK]",
        )
        .opt("seed", "0", "random seed")
        .opt("info", "coarse", "no_info|class_only|coarse|oracle")
        .opt("noise", "0.0", "multiplicative prior noise L")
        .opt("config", "", "JSON config file (overrides strategy/mix/rate/requests)")
        .flag("dump-config", "print the full example config schema and exit");
    let a = cmd.parse(args)?;
    if a.help {
        print!("{}", cmd.help_text());
        return Ok(());
    }
    if a.flag("dump-config") {
        println!("{}", blackbox_sched::config::example_config().to_string_pretty());
        return Ok(());
    }
    let info = InfoLevel::parse(a.str("info"))
        .with_context(|| format!("bad info level {:?}", a.str("info")))?;
    let (spec, sched_cfg, provider_cfg, seed, strategy, mix) = if !a.str("config").is_empty() {
        let cfg = blackbox_sched::config::RunConfig::from_file(a.str("config"))?;
        let strategy = cfg.scheduler.strategy;
        let mix = cfg.workload.mix;
        (cfg.workload, cfg.scheduler, cfg.provider, cfg.seed, strategy, mix)
    } else {
        let strategy = StrategyKind::parse(a.str("strategy"))
            .with_context(|| format!("bad strategy {:?}", a.str("strategy")))?;
        let mix =
            Mix::parse(a.str("mix")).with_context(|| format!("bad mix {:?}", a.str("mix")))?;
        (
            WorkloadSpec::new(mix, a.usize("requests")?, a.f64("rate")?)
                .with_arrivals(parse_arrivals(a.str("arrivals"))?),
            SchedulerCfg::for_strategy(strategy),
            ProviderCfg::default(),
            a.u64("seed")?,
            strategy,
            mix,
        )
    };
    let spec_rate = spec.rate_rps;
    let requests = spec.generate(seed);
    let root = Rng::new(seed ^ 0x5EED_50_u64);
    let noise = a.f64("noise")?;
    let base = LadderSource::new(info, root.derive("priors"));
    let output = if noise > 0.0 {
        let mut src =
            blackbox_sched::predictor::NoisySource::new(base, noise, root.derive("noise"));
        driver::run(&requests, &mut src, sched_cfg, provider_cfg, seed)
    } else {
        let mut src = base;
        driver::run(&requests, &mut src, sched_cfg, provider_cfg, seed)
    };
    let m = &output.metrics;
    println!(
        "strategy={} mix={} rate={} seed={seed} info={}",
        strategy.name(),
        mix.name(),
        spec_rate,
        info.name()
    );
    let mut t = TextTable::new(["metric", "value"]);
    t.row(["offered", &m.n_offered.to_string()]);
    t.row(["completed", &m.n_completed.to_string()]);
    t.row(["rejected", &m.n_rejected.to_string()]);
    t.row(["timed out", &m.n_timed_out.to_string()]);
    t.row(["completion rate", &format!("{:.3}", m.completion_rate)]);
    t.row(["satisfaction", &format!("{:.3}", m.satisfaction)]);
    t.row(["useful goodput (rps)", &format!("{:.2}", m.goodput_rps)]);
    t.row(["short P95 (ms)", &format!("{:.1}", m.short_p95_ms)]);
    t.row(["global P95 (ms)", &format!("{:.1}", m.global_p95_ms)]);
    t.row(["makespan (ms)", &format!("{:.0}", m.makespan_ms)]);
    t.row(["defers", &m.defers_total.to_string()]);
    t.row(["rejects", &m.rejects_total.to_string()]);
    t.row(["feasibility violations", &m.feasibility_violations.to_string()]);
    t.row(["peak provider hidden queue", &output.diagnostics.peak_provider_queue.to_string()]);
    println!("{}", t.render());
    Ok(())
}

fn cmd_bench(args: &[String]) -> Result<()> {
    let cmd = Cmd::new("bench", "scale/perf harness: every strategy at large request counts")
        .opt("sizes", "", "comma-separated request counts per run (default 10000,100000)")
        .opt("rate", "20.0", "arrival rate (req/s)")
        .opt("mix", "balanced", "balanced|heavy|sharegpt|fairness_heavy")
        .opt("arrivals", "poisson", "arrival process for the scale/tenant legs (see `run --help`)")
        .opt("seed", "0", "random seed (one shared workload per size)")
        .opt("out", "BENCH.json", "output JSON path")
        .opt("shards", "1", "add a multi-shard leg with this fleet size (1 = single endpoint)")
        .opt("tenants", "1", "add a multi-tenant leg splitting load across M schedulers")
        .opt("gate-exponent", "0", "fail if any scaling exponent exceeds this (0 = off)")
        .opt(
            "depth-gate-exponent",
            "0",
            "fail if a depth-leg per-release cost exponent exceeds this (0 = off; needs --depth)",
        )
        .opt(
            "timer-gate-exponent",
            "0",
            "fail if the timer-leg work/op exponent exceeds this (0 = off; needs --timers)",
        )
        .opt(
            "partitions",
            "1",
            "add a partition-scaling leg sweeping the event loop at 1,2,4..N partitions \
             (outputs digest-checked identical to serial)",
        )
        .opt(
            "partition-requests",
            "250000",
            "request count for the partition leg's workload (~1M events at the default)",
        )
        .opt(
            "speedup-gate",
            "0",
            "fail if the 4-partition run is not >= this x faster than serial \
             (0 = off; needs --partitions >= 4)",
        )
        .flag("depth", "add the deep-queue leg: per-release cost vs queue depth at 4x/16x rate")
        .flag("timers", "add the timer-churn leg: event-queue work/op at the two size points")
        .flag("smoke", "CI smoke sizes (1000,5000)");
    let a = cmd.parse(args)?;
    if a.help {
        print!("{}", cmd.help_text());
        return Ok(());
    }
    // An empty --sizes means "not given" (the declared default), so an
    // explicit --sizes — even one spelling out the default — always either
    // takes effect or conflicts loudly with --smoke.
    let sizes: Vec<usize> = if a.flag("smoke") {
        if !a.str("sizes").is_empty() {
            bail!("--smoke picks its own sizes (1000,5000); pass either --smoke or --sizes");
        }
        vec![1_000, 5_000]
    } else if a.str("sizes").is_empty() {
        vec![10_000, 100_000]
    } else {
        let mut sizes = Vec::new();
        for s in a.list("sizes") {
            sizes.push(s.parse::<usize>().ok().with_context(|| format!("bad size {s:?}"))?);
        }
        sizes
    };
    let gate = a.f64("gate-exponent")?;
    let depth_gate = a.f64("depth-gate-exponent")?;
    let timer_gate = a.f64("timer-gate-exponent")?;
    let speedup_gate = a.f64("speedup-gate")?;
    let opts = ScaleBenchOpts {
        sizes,
        rate_rps: a.f64("rate")?,
        mix: Mix::parse(a.str("mix")).with_context(|| format!("bad mix {:?}", a.str("mix")))?,
        arrivals: parse_arrivals(a.str("arrivals"))?,
        seed: a.u64("seed")?,
        out_path: a.str("out").to_string(),
        shards: a.usize("shards")?,
        tenants: a.usize("tenants")?,
        gate_exponent: if gate > 0.0 { Some(gate) } else { None },
        depth: a.flag("depth"),
        depth_gate_exponent: if depth_gate > 0.0 { Some(depth_gate) } else { None },
        timers: a.flag("timers"),
        timer_gate_exponent: if timer_gate > 0.0 { Some(timer_gate) } else { None },
        partitions: a.usize("partitions")?,
        partition_requests: a.usize("partition-requests")?,
        speedup_gate: if speedup_gate > 0.0 { Some(speedup_gate) } else { None },
    };
    run_scale_bench(&opts)
}

fn cmd_trace(args: &[String]) -> Result<()> {
    let cmd = Cmd::new("trace", "generate or inspect workload traces")
        .opt("mix", "balanced", "workload mix")
        .opt("rate", "10.0", "arrival rate (req/s)")
        .opt("requests", "120", "request count")
        .opt("seed", "0", "seed")
        .opt("out", "/tmp/bbsched_trace.jsonl", "trace path")
        .positionals();
    let a = cmd.parse(args)?;
    if a.help {
        print!("{}", cmd.help_text());
        return Ok(());
    }
    match a.positionals.first().map(String::as_str) {
        Some("gen") => {
            let mix = Mix::parse(a.str("mix")).context("bad mix")?;
            let spec = WorkloadSpec::new(mix, a.usize("requests")?, a.f64("rate")?);
            let reqs = spec.generate(a.u64("seed")?);
            trace::save_trace(a.str("out"), &reqs)?;
            println!("wrote {} requests to {}", reqs.len(), a.str("out"));
            Ok(())
        }
        Some("show") => {
            let reqs = trace::load_trace(a.str("out"))?;
            let mut counts = [0usize; 4];
            for r in &reqs {
                counts[r.true_bucket.index()] += 1;
            }
            println!("{} requests; bucket mix short/medium/long/xlong = {counts:?}", reqs.len());
            for r in reqs.iter().take(5) {
                println!(
                    "  id={} t={:.0}ms prompt={} task={} out={} bucket={}",
                    r.id,
                    r.arrival_ms,
                    r.prompt_tokens,
                    r.task.name(),
                    r.true_output_tokens,
                    r.true_bucket.name()
                );
            }
            Ok(())
        }
        _ => bail!("trace needs 'gen' or 'show'"),
    }
}

fn cmd_predict(args: &[String]) -> Result<()> {
    let cmd = Cmd::new("predict", "PJRT predictor smoke test")
        .opt("artifacts", &runtime::default_artifacts_dir(), "artifacts dir")
        .opt("n", "8", "golden rows to check");
    let a = cmd.parse(args)?;
    if a.help {
        print!("{}", cmd.help_text());
        return Ok(());
    }
    let dir = a.str("artifacts");
    let predictor = runtime::Predictor::load(dir)?;
    println!(
        "loaded predictor: d_in={} batches={:?} (train p90 coverage {:.3})",
        predictor.meta.d_in, predictor.meta.batch_sizes, predictor.meta.training_coverage_p90
    );
    let g = &predictor.meta.golden;
    let n = a.usize("n")?.min(g.features.len());
    let feats: Vec<f32> = g.features[..n].iter().flatten().copied().collect();
    let priors = predictor.predict(&feats, n)?;
    let mut t =
        TextTable::new(["true tokens", "p50 (rust)", "p50 (python)", "p90 (rust)", "p90 (python)"]);
    let mut max_rel = 0.0f64;
    for i in 0..n {
        let rel = ((priors[i].p50 - g.expected_p50[i]) / g.expected_p50[i])
            .abs()
            .max(((priors[i].p90 - g.expected_p90[i]) / g.expected_p90[i]).abs());
        max_rel = max_rel.max(rel);
        t.row([
            format!("{:.0}", g.true_tokens[i]),
            format!("{:.1}", priors[i].p50),
            format!("{:.1}", g.expected_p50[i]),
            format!("{:.1}", priors[i].p90),
            format!("{:.1}", g.expected_p90[i]),
        ]);
    }
    println!("{}", t.render());
    println!("max relative error vs python reference: {max_rel:.2e}");
    if max_rel > 1e-3 {
        bail!("golden mismatch: PJRT output diverges from the python reference");
    }
    println!("predict OK");

    // Throughput spot check with the batched path.
    let spec = WorkloadSpec::new(Mix::Balanced, 512, 100.0);
    let reqs = spec.generate(1);
    let refs: Vec<&blackbox_sched::Request> = reqs.iter().collect();
    let feats = batch_features(&refs[..512], 512);
    let t0 = std::time::Instant::now();
    let _ = predictor.predict(&feats, 512)?;
    let dt = t0.elapsed().as_secs_f64();
    println!("batched predict: 512 rows in {:.1} ms ({:.0} rows/s)", dt * 1e3, 512.0 / dt);
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let cmd = Cmd::new("serve", "real-time serving demo")
        .opt("rate", "20.0", "arrival rate (req/s, model time)")
        .opt("requests", "60", "request count")
        .opt("scale", "0.05", "wall-clock ms per model ms (0.05 = 20× faster)")
        .opt("strategy", "final_adrr_olc", "strategy")
        .opt("shards", "1", "provider fleet size (N>1 = heterogeneous N-shard pool)")
        .opt("shard-policy", "least_inflight", "least_inflight|weighted|hash_affinity")
        .opt("tenants", "1", "independent client schedulers sharing the fleet (load split evenly)")
        .opt("arrivals", "poisson", "arrival process (see `run --help`)")
        .opt("artifacts", &runtime::default_artifacts_dir(), "artifacts dir ('' = analytic priors)");
    let a = cmd.parse(args)?;
    if a.help {
        print!("{}", cmd.help_text());
        return Ok(());
    }
    let strategy = StrategyKind::parse(a.str("strategy")).context("bad strategy")?;
    let shards = a.usize("shards")?;
    let policy = ShardPolicy::parse(a.str("shard-policy"))
        .with_context(|| format!("bad shard policy {:?}", a.str("shard-policy")))?;
    let tenants = a.usize("tenants")?;
    let pool = if shards <= 1 {
        PoolCfg::single(ProviderCfg::default())
    } else {
        PoolCfg::heterogeneous(ProviderCfg::default(), shards, 0.5)
    };
    blackbox_sched::serve::serve_demo(
        strategy,
        a.f64("rate")?,
        a.usize("requests")?,
        a.f64("scale")?,
        a.str("artifacts"),
        pool,
        policy,
        tenants,
        parse_arrivals(a.str("arrivals"))?,
    )
}
