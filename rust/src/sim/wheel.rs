//! Hierarchical timer wheel — the default [`EventQueue`](super::EventQueue)
//! backend.
//!
//! Six levels of 64 slots each, tick-quantized at [`TICK_MS`]: an entry at
//! tick `T` lives at the level of the highest base-64 digit in which `T`
//! differs from the current tick `cur`, in the slot named by that digit.
//! Scheduling and canceling are O(1); advancing the clock jumps straight to
//! the next occupied slot (per-level occupancy bitmaps + `trailing_zeros`),
//! cascading higher-level slots down as their digits resolve. Entries more
//! than `2^36` ticks out (~2 model-years) park in a time-ordered overflow
//! heap and enter the wheel as the clock approaches.
//!
//! # Exact heap equivalence
//!
//! The wheel must be pop-for-pop identical to the retained `BinaryHeap`
//! reference (`(time, seq)` min-order) — the determinism contract every
//! experiment table rests on. The invariant that guarantees it: the `due`
//! heap holds exactly the entries with `tick ≤ cur`, while wheel slots and
//! the overflow heap hold only entries with `tick > cur`, and `cur` only
//! advances while `due` is empty. Any due entry's time is therefore
//! `< (cur+1)·TICK_MS ≤` any non-due entry's time, so the head of `due` —
//! a true `(time, seq)` min-heap — is always the global minimum, for *any*
//! interleaving of pushes and pops. Same-tick entries never lose their
//! exact sub-tick times; they are compared by `(time, seq)` inside `due`
//! exactly as the reference heap compares them.
//!
//! Structural work (placements, cascade moves, clock jumps, due transfers)
//! is counted in [`TimerWheel::work`]; the `bbsched bench` timer-churn leg
//! gates the count's growth per operation, so the O(1)-amortized claim is
//! enforced rather than asserted.

use std::collections::BinaryHeap;

use super::Entry;

/// Simulated milliseconds per wheel tick. 1 ms resolves every same-tick
/// ordering through the `due` heap's exact `(time, seq)` comparison while
/// keeping the six-level wheel horizon at ~2 model-years; the DES clock is
/// in ms, so one tick is the natural quantum.
pub(super) const TICK_MS: f64 = 1.0;
/// log2 of the slots per level.
const LEVEL_BITS: u32 = 6;
/// Slots per wheel level.
const SLOTS: usize = 1 << LEVEL_BITS;
/// Wheel levels. Together they address `2^(LEVEL_BITS · LEVELS)` ticks.
const LEVELS: usize = 6;
/// Ticks addressable in-wheel; entries further out park in overflow.
const HORIZON_BITS: u32 = LEVEL_BITS * LEVELS as u32;

/// Quantize an event time to its wheel tick (saturating at 0 and u64::MAX).
fn tick_of(t: f64) -> u64 {
    (t.max(0.0) / TICK_MS) as u64
}

/// The wheel proper. Generic over the payload exactly like the facade; the
/// facade owns sequence numbers, timer generations, and all counters except
/// the structural-work count.
pub(super) struct TimerWheel<E> {
    /// Entries with `tick ≤ cur`: a `(time, seq)` min-heap whose head is the
    /// queue's global minimum (see the module docs for the proof sketch).
    due: BinaryHeap<Entry<E>>,
    /// `slots[level * SLOTS + idx]` — unsorted; a slot is only ever emptied
    /// whole (level 0: all same tick → `due`; higher: cascade down).
    slots: Vec<Vec<Entry<E>>>,
    /// Per-level occupancy bitmap (bit `idx` set ⇔ slot non-empty).
    occupied: [u64; LEVELS],
    /// Entries beyond the wheel horizon, time-ordered; drained into the
    /// wheel as `cur` advances toward them.
    overflow: BinaryHeap<Entry<E>>,
    /// Current tick. Advances only while `due` is empty.
    cur: u64,
    /// Entries currently in wheel slots (excludes `due` and `overflow`).
    in_slots: usize,
    /// Cascade scratch buffer, kept to retain its allocation.
    scratch: Vec<Entry<E>>,
    /// Counted structural work: placements, cascade moves, clock jumps,
    /// due transfers, pops. Deterministic — the timer-churn gate's metric.
    work: u64,
}

impl<E> TimerWheel<E> {
    pub(super) fn new() -> Self {
        TimerWheel {
            due: BinaryHeap::new(),
            slots: (0..LEVELS * SLOTS).map(|_| Vec::new()).collect(),
            occupied: [0; LEVELS],
            overflow: BinaryHeap::new(),
            cur: 0,
            in_slots: 0,
            scratch: Vec::new(),
            work: 0,
        }
    }

    pub(super) fn len(&self) -> usize {
        self.due.len() + self.in_slots + self.overflow.len()
    }

    pub(super) fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub(super) fn work(&self) -> u64 {
        self.work
    }

    /// Schedule an entry. O(1): one bitmap bit, one Vec push.
    pub(super) fn push(&mut self, e: Entry<E>) {
        self.work += 1;
        let tick = tick_of(e.time);
        if tick <= self.cur {
            self.due.push(e);
        } else if (tick ^ self.cur) >> HORIZON_BITS != 0 {
            self.overflow.push(e);
        } else {
            self.place(tick, e);
        }
    }

    /// Pop the `(time, seq)`-minimum entry, live or dead — liveness (timer
    /// generations) is the facade's concern.
    pub(super) fn pop(&mut self) -> Option<Entry<E>> {
        self.ensure_due();
        let e = self.due.pop();
        if e.is_some() {
            self.work += 1;
        }
        e
    }

    /// Peek the `(time, seq)`-minimum entry without removing it.
    pub(super) fn peek(&mut self) -> Option<&Entry<E>> {
        self.ensure_due();
        self.due.peek()
    }

    /// File an entry with `tick > cur` into its wheel slot: the level of
    /// the highest base-64 digit differing from `cur`, at that digit.
    fn place(&mut self, tick: u64, e: Entry<E>) {
        debug_assert!(tick > self.cur && (tick ^ self.cur) >> HORIZON_BITS == 0);
        let diff = tick ^ self.cur;
        let level = ((63 - diff.leading_zeros()) / LEVEL_BITS) as usize;
        let idx = ((tick >> (LEVEL_BITS * level as u32)) & (SLOTS as u64 - 1)) as usize;
        // Invariant: `tick > cur` with all higher digits equal ⇒ this digit
        // exceeds cur's, so occupied bits always sit above the clock digit.
        debug_assert!(idx as u64 > (self.cur >> (LEVEL_BITS * level as u32)) & (SLOTS as u64 - 1));
        self.slots[level * SLOTS + idx].push(e);
        self.occupied[level] |= 1u64 << idx;
        self.in_slots += 1;
    }

    /// Establish "`due` is non-empty or the wheel is empty": drain overflow
    /// entries the clock has reached, then repeatedly jump `cur` to the
    /// earliest occupied slot, moving level-0 slots to `due` and cascading
    /// higher slots down. Terminates: every iteration either returns, moves
    /// an entry strictly closer to `due`, or advances `cur`.
    fn ensure_due(&mut self) {
        loop {
            // Overflow first, every iteration: clock jumps below may have
            // brought parked entries into range (or past). The overflow
            // heap is time-ordered, so a prefix drain is complete.
            while let Some(head) = self.overflow.peek() {
                let tick = tick_of(head.time);
                if tick > self.cur && (tick ^ self.cur) >> HORIZON_BITS != 0 {
                    break;
                }
                let e = self.overflow.pop().expect("peeked entry");
                self.work += 1;
                if tick <= self.cur {
                    self.due.push(e);
                } else {
                    self.place(tick, e);
                }
            }
            if !self.due.is_empty() {
                return;
            }
            // Bottom-up scan: the first occupied slot (lowest level, lowest
            // index) is the globally earliest — after the drain above, all
            // remaining overflow entries sort after every wheel entry, and
            // the place() invariant keeps each level's bits above the clock
            // digit, so lower levels always hold nearer ticks.
            let mut advanced = false;
            for level in 0..LEVELS {
                if self.occupied[level] == 0 {
                    continue;
                }
                let shift = LEVEL_BITS * level as u32;
                let idx = self.occupied[level].trailing_zeros() as u64;
                debug_assert!(idx > (self.cur >> shift) & (SLOTS as u64 - 1));
                // Jump the clock to the slot's base tick: digits above this
                // level unchanged, this digit = idx, lower digits zeroed
                // (lower levels are empty — we scanned them first).
                self.cur = (self.cur & !((1u64 << (shift + LEVEL_BITS)) - 1)) | (idx << shift);
                self.occupied[level] &= !(1u64 << idx);
                self.work += 1;
                // Take the slot whole, swapping in the retained scratch
                // allocation so cascade capacity circulates instead of
                // being freed and regrown.
                let si = level * SLOTS + idx as usize;
                let mut batch = std::mem::take(&mut self.scratch);
                std::mem::swap(&mut batch, &mut self.slots[si]);
                self.in_slots -= batch.len();
                for e in batch.drain(..) {
                    self.work += 1;
                    let tick = tick_of(e.time);
                    if tick <= self.cur {
                        // Level 0: every entry shares the slot's tick, which
                        // is now `cur`. Higher levels: the slot-base entry.
                        self.due.push(e);
                    } else {
                        // Cascade: this digit now matches `cur`, so the
                        // entry re-files at a strictly lower level.
                        self.place(tick, e);
                    }
                }
                self.scratch = batch;
                advanced = true;
                break;
            }
            if !advanced {
                // Wheel empty. Jump to the overflow head (strictly ahead of
                // `cur` or the drain would have taken it) and let the next
                // iteration's drain admit it — or report empty.
                match self.overflow.peek() {
                    Some(head) => {
                        self.cur = tick_of(head.time);
                        self.work += 1;
                    }
                    None => return,
                }
            }
        }
    }
}
