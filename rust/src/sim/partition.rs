//! Partitioned event loop: one run across all cores, bit-identical to
//! serial.
//!
//! The serial DES (`driver::run_core`) pops a single global `(time, seq)`
//! heap. This module carves a multi-tenant run into **partitions** —
//! contiguous tenant groups with their contiguous tenant-major request-id
//! ranges — and runs one event loop per partition on its own thread, under
//! **conservative time-window synchronization** (the classic
//! Chandy–Misra–Bryant lookahead discipline):
//!
//! * **Partition map.** Tenants are carved into `P` balanced contiguous
//!   groups ([`crate::scheduler::shard::carve`]). Because request ids are
//!   assigned tenant-major at setup, each partition owns a contiguous id
//!   range, and the global per-request arrays split into disjoint `&mut`
//!   windows — no locks on the hot path. All scheduler state is
//!   tenant-local (selector EWMAs included), so it partitions cleanly.
//!
//! * **Lookahead.** The only cross-partition coupling is the shared
//!   provider pool, and the pool cannot *reorder* the past: a submission
//!   at time `t` finishes no earlier than `t + L`, where `L` is the
//!   minimum service-time floor over shards ([`lookahead_floor_ms`]).
//!   Within a window `[W, W + L)` every partition can therefore advance
//!   independently: no provider completion generated inside the window
//!   can land inside it.
//!
//! * **Mailbox protocol.** Partition workers never touch the pool.
//!   Each tick records its shard ops (submit / finish) with their
//!   timestamps into a per-partition mailbox. At the window barrier the
//!   coordinator k-way-merges all mailboxes by `(time, partition)` and
//!   **replays** them against the one shared pool — the exact op sequence
//!   the serial loop would have applied, so shard RNG draws, hidden-queue
//!   FIFO order, and `started_by_shard` are bitwise identical by
//!   construction. Resulting completions are routed back to the owning
//!   partition's mailbox and drained into its local heap at the next
//!   window start.
//!
//! * **Why `(time, partition)` merge order preserves the serial `(time,
//!   seq)` tie-break.** Setup events (arrivals, timeouts) get seqs
//!   tenant-major, i.e. partition-major — equal-time setup ties resolve
//!   by partition index in both modes. Dynamically pushed events carry
//!   continuous-valued times (RNG-jittered arrivals, service times,
//!   backoffs), so exact f64 collisions between causally unrelated events
//!   of *different* partitions have measure zero; only such a collision
//!   (or an equal-time inversion between a local push and a routed
//!   completion) could diverge from serial, and the release-mode property
//!   test (`tests/partition_equivalence.rs`) pins the contract across
//!   strategies × fleets × tenant mixes × seeds.
//!
//! Diagnostics merge deterministically: counters sum, peaks max, and the
//! time-weighted queue-depth integral re-runs the serial fold op-for-op
//! over the merged `(time, depth)` sample stream (`driver::DepthFold`), so
//! `RunDiagnostics` is identical regardless of partition count.
//!
//! Degenerate configurations fall back to the serial reference loop:
//! an effective partition count below 2, or a fleet with no positive
//! service-time floor (`base_ms == 0` — zero lookahead would deadlock the
//! window protocol). [`PartitionStats::serial_fallback`] records the
//! latter, so callers can tell "asked serial" from "couldn't partition".

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

use crate::core::{Priors, ReqId, Request, RequestStatus};
use crate::predictor::Route;
use crate::provider::pool::{PoolCfg, ProviderPool};
use crate::provider::Started;
use crate::scheduler::shard::carve;
use crate::scheduler::{Action, ClientScheduler};
use crate::sim::driver::{self, process_tick, CoreRun, DepthFold, Ev, LoopState, ShardFabric};
use crate::sim::{EventQueue, TimerId};
use crate::util::pool::{default_jobs, scoped_workers, SpinBarrier};

/// Environment variable selecting the default partition count for
/// multi-tenant runs (mirrors `BBSCHED_EVENT_QUEUE`): unset or
/// unparsable means `1` (serial); `0` means one partition per core.
pub const PARTITIONS_ENV: &str = "BBSCHED_PARTITIONS";

/// Partition count from [`PARTITIONS_ENV`]; `1` (serial) when unset or
/// invalid, `0` passes through as "one partition per core".
pub fn default_partitions() -> usize {
    match std::env::var(PARTITIONS_ENV) {
        Ok(v) => v.trim().parse::<usize>().unwrap_or(1),
        Err(_) => 1,
    }
}

/// `normal()` draws from a 53-bit uniform, so Box–Muller yields
/// `|z| <= sqrt(2 * 53 * ln 2) ≈ 8.5717`; any bound above that makes the
/// lognormal floor conservative.
const Z_BOUND: f64 = 8.58;

/// Floors below this are useless as lookahead (each window would advance
/// virtual time by less than a nanosecond) — treat them as zero.
const MIN_LOOKAHEAD_MS: f64 = 1e-9;

/// The conservative lookahead: a lower bound on every service time any
/// shard can ever sample, or `None` if the fleet admits (near-)zero
/// service times.
///
/// Service is `(base_ms + per_token_ms * tokens) * slowdown(n) *
/// lognormal(0, σ)` with `tokens >= 0`, `slowdown >= 1` (for `γ >= 0`),
/// and the lognormal factor bounded below by `exp(-σ * Z_BOUND)` because
/// the RNG's Box–Muller normal draws from a 53-bit uniform and is
/// therefore bounded (`|z| <= 8.5717 < Z_BOUND = 8.58`). The floor is
/// the minimum over shards of `base_ms * exp(-σ * Z_BOUND)`, shaved by one
/// part in 10⁹ when `σ > 0` to absorb the floating-point rounding of the
/// jitter product; for `σ == 0` the floor is exactly `base_ms` (and the
/// window-boundary guarantee follows from monotonicity of f64 rounding).
///
/// A fault plan participates through its speeds: brownouts with `factor <=
/// 1` and blackouts (`speed 0`) only ever *extend* service, so the floor
/// survives them unchanged. A plan with any speed-up window (`factor > 1`)
/// could finish work earlier than the clean physics allow, invalidating
/// the bound — such fleets return `None` and take the flagged serial
/// fallback (`PartitionStats::serial_fallback`).
pub fn lookahead_floor_ms(cfg: &PoolCfg) -> Option<f64> {
    if !cfg.faults.extension_only() {
        return None;
    }
    let mut floor = f64::INFINITY;
    for shard in &cfg.shards {
        let valid = shard.base_ms > 0.0
            && shard.per_token_ms >= 0.0
            && shard.jitter_sigma >= 0.0
            && shard.slowdown_gamma >= 0.0;
        if !valid {
            return None; // NaNs fail the comparisons too
        }
        let mut f = shard.base_ms;
        if shard.jitter_sigma > 0.0 {
            f *= (-shard.jitter_sigma * Z_BOUND).exp();
            f *= 1.0 - 1e-9;
        }
        floor = floor.min(f);
    }
    if floor.is_finite() && floor > MIN_LOOKAHEAD_MS {
        Some(floor)
    } else {
        None
    }
}

/// What the partition executor actually did for one run — recorded on
/// [`driver::MultiRunOutput`] so callers and benches can verify the
/// parallel path (not the serial fallback) ran, and how much
/// synchronization it cost.
#[derive(Debug, Clone, Default)]
pub struct PartitionStats {
    /// Event loops that actually ran (1 = the serial reference loop).
    pub partitions: usize,
    /// Lookahead windows executed.
    pub windows: u64,
    /// Barrier waits performed by the coordinator (two per window, plus
    /// the initial collection and the final release).
    pub barrier_crossings: u64,
    /// Shard ops (submit/finish) replayed by the coordinator.
    pub ops_routed: u64,
    /// Provider completions routed back to partition mailboxes.
    pub deliveries: u64,
    /// Times a partition stopped at an event *exactly* on its window
    /// boundary (processed next window — the lookahead bound is strict).
    pub boundary_deferrals: u64,
    /// `true` when >= 2 partitions were requested but the fleet has no
    /// positive service-time floor, forcing the serial loop.
    pub serial_fallback: bool,
    /// The conservative window length used (0 when no floor exists).
    pub lookahead_ms: f64,
}

/// A partition worker's provider seam: record stamped shard ops for the
/// coordinator's replay instead of touching the pool, and buffer the
/// per-tick depth samples for the merged diagnostics fold.
struct PartitionFabric {
    ops: Vec<StampedOp>,
    samples: Vec<(f64, usize)>,
}

/// One shard op with the virtual time it happened at. In-stream order is
/// the within-partition order; the coordinator merges streams by
/// `(time, partition)`.
#[derive(Debug, Clone, Copy)]
struct StampedOp {
    time: f64,
    op: ShardOp,
}

#[derive(Debug, Clone, Copy)]
enum ShardOp {
    /// A `Send` action released `id` to `shard` (serial: `submit`).
    Submit { id: ReqId, tokens: f64, shard: usize },
    /// A `ProviderDone` popped for `id` (serial: `on_finish`).
    Finish { id: ReqId },
}

impl ShardFabric for PartitionFabric {
    fn send(&mut self, id: ReqId, tokens: f64, shard: usize, now: f64, _q: &mut EventQueue<Ev>) {
        self.ops.push(StampedOp { time: now, op: ShardOp::Submit { id, tokens, shard } });
    }
    fn flush(&mut self, _now: f64, _q: &mut EventQueue<Ev>) {
        // Replay applies ops one by one in stream order — the serial
        // fabric's batch boundaries carry no information (submit_batch is
        // per-item submit in order).
    }
    fn finish(&mut self, id: ReqId, now: f64, _q: &mut EventQueue<Ev>) {
        self.ops.push(StampedOp { time: now, op: ShardOp::Finish { id } });
    }
    fn end_tick(&mut self, now: f64, depth: usize) {
        self.samples.push((now, depth));
    }
}

/// One partition's mailbox. Workers publish `ops`/`samples`/`peek` at the
/// end of each window; the coordinator consumes them, then fills
/// `deliveries` (completions owned by this partition, in replay order)
/// for the worker to drain at the next window start.
#[derive(Default)]
struct Slot {
    ops: Vec<StampedOp>,
    samples: Vec<(f64, usize)>,
    deliveries: Vec<(f64, ReqId)>,
    peek: Option<f64>,
}

/// The per-partition `&mut` windows into the run's global arrays, claimed
/// once by the owning worker.
struct PartMut<'a> {
    schedulers: &'a mut [ClientScheduler],
    status: &'a mut [RequestStatus],
    latency: &'a mut [Option<f64>],
    defer_counts: &'a mut [u32],
    sends_by_tenant: &'a mut [u64],
}

/// Scalars each worker accumulates privately and returns at join.
struct WorkerOut {
    sends: u64,
    peak_inflight: usize,
    timers_canceled: u64,
    retries_scheduled: u64,
    processed: u64,
    skipped: u64,
    boundary_deferrals: u64,
}

/// What the coordinator thread accumulates across windows.
struct CoordOut {
    fold: DepthFold,
    windows: u64,
    barrier_crossings: u64,
    ops_routed: u64,
    deliveries: u64,
    panic: Option<Box<dyn std::any::Any + Send>>,
}

/// Poison-tolerant lock: a worker panic is surfaced through the abort
/// protocol (and re-raised at join), not by poisoning every mailbox.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Split `s` into consecutive `&mut` chunks matching `bounds` (contiguous
/// `(lo, hi)` half-open ranges covering the slice).
fn split_chunks<'a, T>(mut s: &'a mut [T], bounds: &[(usize, usize)]) -> Vec<&'a mut [T]> {
    let mut out = Vec::with_capacity(bounds.len());
    let mut consumed = 0usize;
    for &(lo, hi) in bounds {
        debug_assert_eq!(lo, consumed, "bounds must be contiguous");
        let (head, rest) = s.split_at_mut(hi - lo);
        out.push(head);
        s = rest;
        consumed = hi;
    }
    debug_assert!(s.is_empty(), "bounds must cover the slice");
    out
}

/// Route a replayed completion to the partition owning its request id.
fn route(
    guards: &mut [MutexGuard<'_, Slot>],
    req_parts: &[(usize, usize)],
    started: Started,
    window_end: f64,
    deliveries: &mut u64,
) {
    // Empty partitions share `lo` with their successor; the last range
    // with `lo <= id` is the nonempty one containing `id`.
    let pi = req_parts.partition_point(|&(lo, _)| lo <= started.id) - 1;
    debug_assert!(
        started.id >= req_parts[pi].0 && started.id < req_parts[pi].1,
        "routed {} outside partition {pi} range {:?}",
        started.id,
        req_parts[pi],
    );
    // The conservative-lookahead invariant: nothing submitted or promoted
    // inside a window can finish inside it.
    debug_assert!(
        started.finish_ms >= window_end,
        "completion {} at {} lands before window end {window_end}",
        started.id,
        started.finish_ms,
    );
    guards[pi].deliveries.push((started.finish_ms, started.id));
    *deliveries += 1;
}

/// Run the DES across `partitions` event loops (see the module docs), or
/// fall back to the serial [`driver::run_core`] when the effective count
/// is < 2 or the fleet has no lookahead. Returns the same [`CoreRun`] the
/// serial loop would — bit-identical — plus what the executor did.
#[allow(clippy::too_many_arguments)] // the run's full working set, threaded explicitly
pub(crate) fn run_core_partitioned(
    requests: &[Request],
    priors: &[(Priors, Route)],
    owner: &[u32],
    tenant_ranges: &[(usize, usize)],
    schedulers: &mut [ClientScheduler],
    provider: &mut ProviderPool,
    pool_cfg: &PoolCfg,
    partitions: usize,
) -> (CoreRun, PartitionStats) {
    let n_tenants = schedulers.len();
    let requested = if partitions == 0 { default_jobs() } else { partitions };
    let p = requested.min(n_tenants);
    let floor = lookahead_floor_ms(pool_cfg);
    if p < 2 || floor.is_none() {
        let core = driver::run_core(requests, priors, owner, schedulers, provider);
        let stats = PartitionStats {
            partitions: 1,
            serial_fallback: p >= 2 && floor.is_none(),
            lookahead_ms: floor.unwrap_or(0.0),
            ..PartitionStats::default()
        };
        return (core, stats);
    }
    run_partitioned(
        requests,
        priors,
        owner,
        tenant_ranges,
        schedulers,
        provider,
        p,
        floor.expect("checked above"),
    )
}

#[allow(clippy::too_many_arguments)]
fn run_partitioned(
    requests: &[Request],
    priors: &[(Priors, Route)],
    owner: &[u32],
    tenant_ranges: &[(usize, usize)],
    schedulers: &mut [ClientScheduler],
    provider: &mut ProviderPool,
    p: usize,
    lookahead_ms: f64,
) -> (CoreRun, PartitionStats) {
    let n = requests.len();
    let n_tenants = schedulers.len();
    debug_assert!(p >= 2 && p <= n_tenants);

    // The partition map: balanced contiguous tenant groups; request-id
    // ranges follow because ids are tenant-major.
    let tenant_parts = carve(n_tenants, p);
    let req_parts: Vec<(usize, usize)> = tenant_parts
        .iter()
        .map(|&(tlo, thi)| (tenant_ranges[tlo].0, tenant_ranges[thi - 1].1))
        .collect();
    debug_assert_eq!(req_parts.last().map(|r| r.1), Some(n));

    let mut status = vec![RequestStatus::Queued; n];
    let mut latency: Vec<Option<f64>> = vec![None; n];
    let mut defer_counts = vec![0u32; n];
    let mut sends_by_tenant = vec![0u64; n_tenants];

    let slots: Vec<Mutex<Slot>> = (0..p).map(|_| Mutex::new(Slot::default())).collect();
    // Coordinator → workers: the next window start (f64 bits) and the two
    // stop signals. `abort` is set by a panicking worker *before* its
    // barrier arrival so siblings are released instead of deadlocking.
    let w_bits = AtomicU64::new(0);
    let done = AtomicBool::new(false);
    let abort = AtomicBool::new(false);
    // Two barriers per window: `release` starts a round (workers may read
    // `w_bits`/`done` after it), `collect` ends it (the coordinator may
    // read mailboxes after it).
    let release = SpinBarrier::new(p + 1);
    let collect = SpinBarrier::new(p + 1);

    let (worker_outs, coord) = {
        let parts: Vec<Mutex<Option<PartMut<'_>>>> = {
            let sched_chunks = split_chunks(&mut *schedulers, &tenant_parts);
            let status_chunks = split_chunks(&mut status[..], &req_parts);
            let latency_chunks = split_chunks(&mut latency[..], &req_parts);
            let defer_chunks = split_chunks(&mut defer_counts[..], &req_parts);
            let sbt_chunks = split_chunks(&mut sends_by_tenant[..], &tenant_parts);
            sched_chunks
                .into_iter()
                .zip(status_chunks)
                .zip(latency_chunks)
                .zip(defer_chunks)
                .zip(sbt_chunks)
                .map(|((((sch, st), lat), def), sbt)| {
                    Mutex::new(Some(PartMut {
                        schedulers: sch,
                        status: st,
                        latency: lat,
                        defer_counts: def,
                        sends_by_tenant: sbt,
                    }))
                })
                .collect()
        };

        let worker = |i: usize| -> WorkerOut {
            let pm = lock(&parts[i]).take().expect("partition state claimed exactly once");
            let (req_lo, req_hi) = req_parts[i];
            let pn = req_hi - req_lo;
            // Local queue setup in the serial push order for this id
            // range: within-partition (time, seq) ties resolve exactly as
            // the global loop's tenant-major setup does.
            let mut q: EventQueue<Ev> = EventQueue::with_capacity(pn * 4);
            let mut timeout_timer: Vec<Option<TimerId>> = Vec::with_capacity(pn);
            for r in &requests[req_lo..req_hi] {
                q.push(r.arrival_ms, Ev::Arrival(r.id));
                timeout_timer.push(Some(q.push_cancelable(r.timeout_ms, Ev::Timeout(r.id))));
            }
            let mut retry_timer: Vec<Option<TimerId>> = vec![None; pn];
            let mut retry_attempts = vec![0u32; pn];
            let mut actions: Vec<Action> = Vec::new();
            let mut fabric = PartitionFabric { ops: Vec::new(), samples: Vec::new() };
            let schedulers = pm.schedulers;
            let mut st = LoopState {
                base: req_lo,
                tenant_base: tenant_parts[i].0,
                status: pm.status,
                latency: pm.latency,
                defer_counts: pm.defer_counts,
                timeout_timer: &mut timeout_timer,
                retry_timer: &mut retry_timer,
                retry_attempts: &mut retry_attempts,
                sends_by_tenant: pm.sends_by_tenant,
                sends: 0,
                peak_inflight: 0,
                timers_canceled: 0,
                retries_scheduled: 0,
            };
            let mut boundary_deferrals = 0u64;
            let mut pending_panic: Option<Box<dyn std::any::Any + Send>> = None;
            lock(&slots[i]).peek = q.peek_time();
            collect.wait();
            loop {
                release.wait();
                if done.load(Ordering::Acquire) {
                    break;
                }
                let w = f64::from_bits(w_bits.load(Ordering::Acquire));
                let end = w + lookahead_ms;
                let round = catch_unwind(AssertUnwindSafe(|| {
                    // Mailbox drain: completions the coordinator routed
                    // here, pushed in replay order (the serial push order).
                    {
                        let mut slot = lock(&slots[i]);
                        for &(finish_ms, id) in slot.deliveries.iter() {
                            debug_assert!(
                                finish_ms >= w,
                                "delivery for {id} at {finish_ms} precedes window start {w}"
                            );
                            q.push(finish_ms, Ev::ProviderDone(id));
                        }
                        slot.deliveries.clear();
                    }
                    // Advance strictly below `end`: the lookahead bound
                    // covers times < end only, so a boundary-exact event
                    // belongs to the next window.
                    loop {
                        match q.peek_time() {
                            Some(t) if t < end => {}
                            Some(t) => {
                                if t == end {
                                    boundary_deferrals += 1;
                                }
                                break;
                            }
                            None => break,
                        }
                        let (now, ev) = q.pop().expect("peeked event pops");
                        debug_assert!(
                            now >= w && now < end,
                            "event at {now} outside window [{w}, {end})"
                        );
                        process_tick(
                            now,
                            ev,
                            requests,
                            priors,
                            owner,
                            schedulers,
                            &mut st,
                            &mut q,
                            &mut actions,
                            &mut fabric,
                        );
                    }
                    // Publish the round: swap keeps both buffers' capacity
                    // ping-ponging instead of reallocating every window.
                    let mut slot = lock(&slots[i]);
                    std::mem::swap(&mut slot.ops, &mut fabric.ops);
                    std::mem::swap(&mut slot.samples, &mut fabric.samples);
                    slot.peek = q.peek_time();
                }));
                if let Err(payload) = round {
                    pending_panic = Some(payload);
                    abort.store(true, Ordering::Release);
                }
                collect.wait();
            }
            if let Some(payload) = pending_panic {
                resume_unwind(payload);
            }
            WorkerOut {
                sends: st.sends,
                peak_inflight: st.peak_inflight,
                timers_canceled: st.timers_canceled,
                retries_scheduled: st.retries_scheduled,
                processed: q.processed(),
                skipped: q.skipped(),
                boundary_deferrals,
            }
        };

        let coordinator = || -> CoordOut {
            let mut out = CoordOut {
                fold: DepthFold::new(),
                windows: 0,
                barrier_crossings: 0,
                ops_routed: 0,
                deliveries: 0,
                panic: None,
            };
            // Per-partition latest depth: the global depth after any
            // sample is the integer sum of each partition's latest local
            // depth — exactly the serial fold's observations.
            let mut cur_depth = vec![0usize; p];
            let mut depth_total = 0usize;
            collect.wait();
            out.barrier_crossings += 1;
            loop {
                // Next window start: the earliest pending event anywhere —
                // local heap heads and undrained deliveries (a drained-out
                // partition may still owe a routed completion).
                let mut w = f64::INFINITY;
                for slot in &slots {
                    let slot = lock(slot);
                    if let Some(t) = slot.peek {
                        w = w.min(t);
                    }
                    for &(finish_ms, _) in slot.deliveries.iter() {
                        w = w.min(finish_ms);
                    }
                }
                if w == f64::INFINITY {
                    done.store(true, Ordering::Release);
                    release.wait();
                    out.barrier_crossings += 1;
                    break;
                }
                w_bits.store(w.to_bits(), Ordering::Release);
                release.wait();
                collect.wait();
                out.barrier_crossings += 2;
                out.windows += 1;
                if abort.load(Ordering::Acquire) {
                    // A worker panicked this round: release everyone into
                    // the done-branch and let join re-raise its payload.
                    done.store(true, Ordering::Release);
                    release.wait();
                    out.barrier_crossings += 1;
                    break;
                }
                let window_end = w + lookahead_ms;
                let merged = catch_unwind(AssertUnwindSafe(|| {
                    let mut guards: Vec<MutexGuard<'_, Slot>> =
                        slots.iter().map(|s| lock(s)).collect();
                    // Replay shard ops in merged (time, partition) order —
                    // the serial loop's op order (see module docs).
                    let mut cursors = vec![0usize; p];
                    loop {
                        let mut best: Option<(f64, usize)> = None;
                        for (pi, g) in guards.iter().enumerate() {
                            if let Some(op) = g.ops.get(cursors[pi]) {
                                let better = match best {
                                    None => true,
                                    Some((bt, _)) => op.time < bt,
                                };
                                if better {
                                    best = Some((op.time, pi));
                                }
                            }
                        }
                        let Some((_, pi)) = best else { break };
                        let op = guards[pi].ops[cursors[pi]];
                        cursors[pi] += 1;
                        out.ops_routed += 1;
                        match op.op {
                            ShardOp::Submit { id, tokens, shard } => {
                                if let Some(s) = provider.submit(id, tokens, shard, op.time) {
                                    route(
                                        &mut guards,
                                        &req_parts,
                                        s,
                                        window_end,
                                        &mut out.deliveries,
                                    );
                                }
                            }
                            ShardOp::Finish { id } => {
                                for s in provider.on_finish(id, op.time) {
                                    route(
                                        &mut guards,
                                        &req_parts,
                                        s,
                                        window_end,
                                        &mut out.deliveries,
                                    );
                                }
                            }
                        }
                    }
                    // Fold depth samples in the same merged order, keeping
                    // the integer global depth exact.
                    let mut cursors = vec![0usize; p];
                    loop {
                        let mut best: Option<(f64, usize)> = None;
                        for (pi, g) in guards.iter().enumerate() {
                            if let Some(&(t, _)) = g.samples.get(cursors[pi]) {
                                let better = match best {
                                    None => true,
                                    Some((bt, _)) => t < bt,
                                };
                                if better {
                                    best = Some((t, pi));
                                }
                            }
                        }
                        let Some((_, pi)) = best else { break };
                        let (t, d) = guards[pi].samples[cursors[pi]];
                        cursors[pi] += 1;
                        depth_total = depth_total - cur_depth[pi] + d;
                        cur_depth[pi] = d;
                        out.fold.observe(t, depth_total);
                    }
                    for g in guards.iter_mut() {
                        g.ops.clear();
                        g.samples.clear();
                    }
                }));
                if let Err(payload) = merged {
                    out.panic = Some(payload);
                    done.store(true, Ordering::Release);
                    release.wait();
                    out.barrier_crossings += 1;
                    break;
                }
            }
            out
        };

        scoped_workers(p, worker, coordinator)
    };

    if let Some(payload) = coord.panic {
        resume_unwind(payload);
    }

    let (mean_queue_depth, peak_queue_depth) = coord.fold.finish();
    let core = CoreRun {
        status,
        latency,
        defer_counts,
        sends: worker_outs.iter().map(|w| w.sends).sum(),
        sends_by_tenant,
        peak_inflight: worker_outs.iter().map(|w| w.peak_inflight).max().unwrap_or(0),
        timers_canceled: worker_outs.iter().map(|w| w.timers_canceled).sum(),
        events_processed: worker_outs.iter().map(|w| w.processed).sum(),
        events_skipped: worker_outs.iter().map(|w| w.skipped).sum(),
        mean_queue_depth,
        peak_queue_depth,
        ordering_select_work: schedulers.iter().map(|s| s.ordering_work()).sum(),
        ordering_group_count: schedulers.iter().map(|s| s.ordering_group_count()).sum(),
        ordering_scan_fallbacks: schedulers.iter().map(|s| s.ordering_scan_fallbacks()).sum(),
        retries_scheduled: worker_outs.iter().map(|w| w.retries_scheduled).sum(),
    };
    let stats = PartitionStats {
        partitions: p,
        windows: coord.windows,
        barrier_crossings: coord.barrier_crossings,
        ops_routed: coord.ops_routed,
        deliveries: coord.deliveries,
        boundary_deferrals: worker_outs.iter().map(|w| w.boundary_deferrals).sum(),
        serial_fallback: false,
        lookahead_ms,
    };
    (core, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::provider::fault::FaultPlan;
    use crate::provider::ProviderCfg;

    fn cfg(base_ms: f64, jitter_sigma: f64) -> ProviderCfg {
        ProviderCfg { base_ms, jitter_sigma, ..ProviderCfg::default() }
    }

    #[test]
    fn floor_is_exact_base_without_jitter() {
        let pool = PoolCfg::single(cfg(40.0, 0.0));
        assert_eq!(lookahead_floor_ms(&pool), Some(40.0));
    }

    #[test]
    fn floor_takes_min_across_shards_and_discounts_jitter() {
        let pool = PoolCfg {
            shards: vec![cfg(100.0, 0.0), cfg(80.0, 0.1)],
            faults: FaultPlan::default(),
        };
        let f = lookahead_floor_ms(&pool).unwrap();
        let expected = 80.0 * (-0.1f64 * Z_BOUND).exp() * (1.0 - 1e-9);
        assert_eq!(f.to_bits(), expected.to_bits());
        assert!(f < 80.0 && f > 0.0);
    }

    #[test]
    fn floor_rejects_speedup_fault_plans() {
        // A brownout factor above 1.0 means a shard can run *faster* than its
        // nominal service model inside the window, so the lookahead floor is
        // unsound and the partitioned loop must fall back to serial.
        let speedup = FaultPlan::default().brownout(0, 0.0, 1_000.0, 2.0).unwrap();
        let pool = PoolCfg::single(cfg(40.0, 0.0)).with_faults(speedup);
        assert_eq!(lookahead_floor_ms(&pool), None);

        // Extension-only plans (blackouts and slow-down brownouts) only ever
        // push finishes later, so the fault-free floor stays valid.
        let ext = FaultPlan::default()
            .blackout(0, 0.0, 500.0)
            .unwrap()
            .brownout(0, 1_000.0, 2_000.0, 0.5)
            .unwrap();
        let pool = PoolCfg::single(cfg(40.0, 0.0)).with_faults(ext);
        assert_eq!(lookahead_floor_ms(&pool), Some(40.0));
    }

    #[test]
    fn floor_rejects_degenerate_fleets() {
        assert_eq!(lookahead_floor_ms(&PoolCfg::single(cfg(0.0, 0.0))), None);
        assert_eq!(lookahead_floor_ms(&PoolCfg::single(cfg(-1.0, 0.0))), None);
        assert_eq!(lookahead_floor_ms(&PoolCfg::single(cfg(f64::NAN, 0.0))), None);
        assert_eq!(lookahead_floor_ms(&PoolCfg::single(cfg(40.0, f64::NAN))), None);
        let mut neg_token = cfg(40.0, 0.0);
        neg_token.per_token_ms = -0.5;
        assert_eq!(lookahead_floor_ms(&PoolCfg::single(neg_token)), None);
        let mut neg_gamma = cfg(40.0, 0.0);
        neg_gamma.slowdown_gamma = -0.1;
        assert_eq!(lookahead_floor_ms(&PoolCfg::single(neg_gamma)), None);
        // A huge sigma drives the floor below the useful threshold.
        assert_eq!(lookahead_floor_ms(&PoolCfg::single(cfg(1e-3, 3.0))), None);
    }

    #[test]
    fn floor_bound_really_holds_for_sampled_services() {
        // Empirical guard for the Z_BOUND analysis: no sampled service
        // time may undercut the floor.
        use crate::util::rng::Rng;
        let shard = cfg(50.0, 0.25);
        let pool = PoolCfg::single(shard);
        let floor = lookahead_floor_ms(&pool).unwrap();
        let mut rng = Rng::new(0xF1005);
        for _ in 0..200_000 {
            let s = 50.0 * rng.lognormal(0.0, 0.25);
            assert!(s >= floor, "sampled service {s} under floor {floor}");
        }
    }

    #[test]
    fn default_partitions_parses_env_conventions() {
        // Can't mutate the env safely in parallel tests; exercise the
        // parse path the function uses.
        assert_eq!("4".trim().parse::<usize>().unwrap_or(1), 4);
        assert_eq!("".trim().parse::<usize>().unwrap_or(1), 1);
        assert_eq!("nope".trim().parse::<usize>().unwrap_or(1), 1);
        assert_eq!(" 0 ".trim().parse::<usize>().unwrap_or(1), 0);
    }

    #[test]
    fn split_chunks_covers_and_isolates() {
        let mut v: Vec<u32> = (0..10).collect();
        let bounds = [(0usize, 3usize), (3, 3), (3, 10)];
        let chunks = split_chunks(&mut v[..], &bounds);
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0], &[0, 1, 2]);
        assert!(chunks[1].is_empty());
        assert_eq!(chunks[2].len(), 7);
    }

    #[test]
    fn routing_picks_the_owning_partition_with_empty_ranges() {
        // partition_point convention: empty ranges share `lo` with their
        // successor and must never win.
        let req_parts = [(0usize, 4usize), (4, 4), (4, 9)];
        for (id, want) in [(0usize, 0usize), (3, 0), (4, 2), (8, 2)] {
            let pi = req_parts.partition_point(|&(lo, _)| lo <= id) - 1;
            assert_eq!(pi, want, "id {id}");
        }
    }
}
