//! Partitioned event loop: one run across all cores, bit-identical to
//! serial.
//!
//! The serial DES (`driver::run_core`) pops a single global `(time, seq)`
//! heap. This module carves a multi-tenant run into **partitions** —
//! contiguous tenant groups with their contiguous tenant-major request-id
//! ranges — and runs one event loop per partition on its own thread, under
//! **conservative time-window synchronization** (the classic
//! Chandy–Misra–Bryant lookahead discipline):
//!
//! * **Partition map.** Multi-tenant runs carve tenants into `P` balanced
//!   contiguous groups ([`crate::scheduler::shard::carve`]). Because
//!   request ids are assigned tenant-major at setup, each partition owns a
//!   contiguous id range, and the global per-request arrays split into
//!   disjoint `&mut` windows — no locks on the hot path. All scheduler
//!   state is tenant-local (selector EWMAs included), so it partitions
//!   cleanly. Single-tenant runs carve **contiguous request-id ranges**
//!   instead when the configured stack is request-local
//!   ([`crate::scheduler::SchedulerCfg::request_local`]): each worker runs
//!   a fresh scheduler clone whose per-request decisions are independent
//!   of every other request, so the carve changes nothing observable.
//!   Stateful single-tenant stacks take the flagged serial fallback
//!   ([`FallbackReason::StatefulCarve`]).
//!
//! * **Lookahead.** The only cross-partition coupling is the shared
//!   provider pool, and the pool cannot *reorder* the past: a submission
//!   at time `t` finishes no earlier than `t + L`, where `L` is the
//!   minimum service-time floor over shards ([`lookahead_floor_ms`]).
//!   Within a window `[W, W + L)` every partition can therefore advance
//!   independently: no provider completion generated inside the window
//!   can land inside it.
//!
//! * **Dynamic window bound.** The static floor is the worst case *ever*;
//!   the coordinator knows the current pool state at every window start
//!   and negotiates a per-window bound from it ([`WindowBound::Dynamic`],
//!   the default). For each shard it takes the earliest instant any *new*
//!   (not-yet-committed) completion could be created — the window start
//!   `W` if the shard has free slots, else its earliest committed
//!   in-flight finish `E_s` (slots free only at committed finish times,
//!   and a saturated shard's hidden-queue promotions start exactly there)
//!   — and pushes that shard's own floor through the fault plan's
//!   `adjusted_finish` walk. The window end is the minimum over shards,
//!   which always dominates `W + L` and, under saturation, long services,
//!   or an extension-only brownout, is *much* larger: calm stretches
//!   advance in a handful of windows instead of thousands of floor-sized
//!   ones. Safety: in-flight finishes are already committed `f64` event
//!   times, so the bound never admits an uncommitted start (the full
//!   argument lives in `docs/ARCHITECTURE.md`).
//!
//! * **Mailbox protocol.** Partition workers never touch the pool.
//!   Each tick records its shard ops (submit / finish) with their
//!   timestamps into a per-partition mailbox. At the window barrier the
//!   coordinator k-way-merges all mailboxes by `(time, partition)` and
//!   **replays** them against the one shared pool — the exact op sequence
//!   the serial loop would have applied, so shard RNG draws, hidden-queue
//!   FIFO order, and `started_by_shard` are bitwise identical by
//!   construction. Resulting completions are routed back to the owning
//!   partition's mailbox and drained into its local heap at the next
//!   window start.
//!
//! * **Why `(time, partition)` merge order preserves the serial `(time,
//!   seq)` tie-break.** Setup events (arrivals, timeouts) get seqs
//!   tenant-major, i.e. partition-major — equal-time setup ties resolve
//!   by partition index in both modes. Dynamically pushed events carry
//!   continuous-valued times (RNG-jittered arrivals, service times,
//!   backoffs), so exact f64 collisions between causally unrelated events
//!   of *different* partitions have measure zero; only such a collision
//!   (or an equal-time inversion between a local push and a routed
//!   completion) could diverge from serial, and the release-mode property
//!   test (`tests/partition_equivalence.rs`) pins the contract across
//!   strategies × fleets × tenant mixes × seeds.
//!
//! Diagnostics merge deterministically: counters sum, peaks max, and the
//! time-weighted queue-depth integral re-runs the serial fold op-for-op
//! over the merged `(time, depth)` sample stream (`driver::DepthFold`), so
//! `RunDiagnostics` is identical regardless of partition count.
//!
//! Degenerate configurations fall back to the serial reference loop, and
//! [`PartitionStats::serial_fallback`] records *why* as a
//! [`FallbackReason`]: serial was asked for (`NotRequested`), the fault
//! plan contains a speed-up window (`SpeedupFault`), the fleet has no
//! positive service-time floor (`NoFloor` — zero lookahead would deadlock
//! the window protocol), or a single-tenant run's scheduler state cannot
//! be carved by request range (`StatefulCarve`). A silently-serialized
//! "parallel" run is therefore diagnosable instead of just slow.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

use crate::core::{Priors, ReqId, Request, RequestStatus};
use crate::predictor::Route;
use crate::provider::fault::FaultPlan;
use crate::provider::pool::{PoolCfg, ProviderPool};
use crate::provider::{ProviderCfg, Started};
use crate::scheduler::shard::carve;
use crate::scheduler::{Action, ClientScheduler, SchedulerCfg};
use crate::sim::driver::{self, process_tick, CoreRun, DepthFold, Ev, LoopState, ShardFabric};
use crate::sim::{EventQueue, TimerId};
use crate::util::pool::{default_jobs, scoped_workers, SpinBarrier};

/// Environment variable selecting the default partition count for
/// multi-tenant runs (mirrors `BBSCHED_EVENT_QUEUE`): unset or
/// unparsable means `1` (serial); `0` means one partition per core.
pub const PARTITIONS_ENV: &str = "BBSCHED_PARTITIONS";

/// Partition count from [`PARTITIONS_ENV`]; `1` (serial) when unset or
/// invalid, `0` passes through as "one partition per core".
pub fn default_partitions() -> usize {
    match std::env::var(PARTITIONS_ENV) {
        Ok(v) => v.trim().parse::<usize>().unwrap_or(1),
        Err(_) => 1,
    }
}

/// `normal()` draws from a 53-bit uniform, so Box–Muller yields
/// `|z| <= sqrt(2 * 53 * ln 2) ≈ 8.5717`; any bound above that makes the
/// lognormal floor conservative.
const Z_BOUND: f64 = 8.58;

/// Floors below this are useless as lookahead (each window would advance
/// virtual time by less than a nanosecond) — treat them as zero.
const MIN_LOOKAHEAD_MS: f64 = 1e-9;

/// The conservative lookahead: a lower bound on every service time any
/// shard can ever sample, or `None` if the fleet admits (near-)zero
/// service times.
///
/// Service is `(base_ms + per_token_ms * tokens) * slowdown(n) *
/// lognormal(0, σ)` with `tokens >= 0`, `slowdown >= 1` (for `γ >= 0`),
/// and the lognormal factor bounded below by `exp(-σ * Z_BOUND)` because
/// the RNG's Box–Muller normal draws from a 53-bit uniform and is
/// therefore bounded (`|z| <= 8.5717 < Z_BOUND = 8.58`). The floor is
/// the minimum over shards of `base_ms * exp(-σ * Z_BOUND)`, shaved by one
/// part in 10⁹ when `σ > 0` to absorb the floating-point rounding of the
/// jitter product; for `σ == 0` the floor is exactly `base_ms` (and the
/// window-boundary guarantee follows from monotonicity of f64 rounding).
///
/// A fault plan participates through its speeds: brownouts with `factor <=
/// 1` and blackouts (`speed 0`) only ever *extend* service, so the floor
/// survives them unchanged. A plan with any speed-up window (`factor > 1`)
/// could finish work earlier than the clean physics allow, invalidating
/// the bound — such fleets return `None` and take the flagged serial
/// fallback (`PartitionStats::serial_fallback`).
pub fn lookahead_floor_ms(cfg: &PoolCfg) -> Option<f64> {
    if !cfg.faults.extension_only() {
        return None;
    }
    let mut floor = f64::INFINITY;
    for shard in &cfg.shards {
        floor = floor.min(shard_floor_ms(shard)?);
    }
    if floor.is_finite() {
        Some(floor)
    } else {
        None
    }
}

/// One shard's service-time floor: a lower bound on any service time that
/// shard can sample (same analysis as [`lookahead_floor_ms`], which is the
/// fleet minimum of these), or `None` for degenerate physics. Fault
/// windows do not enter here — the dynamic bound pushes this floor through
/// [`FaultPlan::adjusted_finish`] per shard instead.
fn shard_floor_ms(shard: &ProviderCfg) -> Option<f64> {
    let valid = shard.base_ms > 0.0
        && shard.per_token_ms >= 0.0
        && shard.jitter_sigma >= 0.0
        && shard.slowdown_gamma >= 0.0;
    if !valid {
        return None; // NaNs fail the comparisons too
    }
    let mut f = shard.base_ms;
    if shard.jitter_sigma > 0.0 {
        f *= (-shard.jitter_sigma * Z_BOUND).exp();
        f *= 1.0 - 1e-9;
    }
    if f.is_finite() && f > MIN_LOOKAHEAD_MS {
        Some(f)
    } else {
        None
    }
}

/// Why the partition executor ran the serial reference loop instead of the
/// parallel path. Recorded in [`PartitionStats::serial_fallback`]
/// (`None` = the parallel path ran) and surfaced in BENCH.json, so a
/// silently-serialized run is diagnosable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FallbackReason {
    /// Fewer than 2 effective partitions were requested (or the run is too
    /// small to split) — serial is what was asked for.
    NotRequested,
    /// The fault plan has a speed-up window (`factor > 1`), which can
    /// finish work below every service-time floor; no conservative bound
    /// exists.
    SpeedupFault,
    /// The fleet admits (near-)zero service times (`base_ms == 0`, NaN
    /// physics, …): no positive lookahead floor, and a zero-length window
    /// would deadlock the protocol.
    NoFloor,
    /// A single-tenant run whose scheduler stack keeps cross-request state
    /// (see [`SchedulerCfg::request_local`]) — carving its requests would
    /// change scheduling decisions, so bit-identity forces serial.
    StatefulCarve,
}

impl FallbackReason {
    /// Stable lowercase token for BENCH.json and log lines.
    pub fn as_str(self) -> &'static str {
        match self {
            FallbackReason::NotRequested => "not_requested",
            FallbackReason::SpeedupFault => "speedup_fault",
            FallbackReason::NoFloor => "no_floor",
            FallbackReason::StatefulCarve => "stateful_carve",
        }
    }
}

/// How the coordinator bounds each window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WindowBound {
    /// Negotiate each window's end from the current pool state: per shard,
    /// the earliest instant a new completion could be committed (window
    /// start with free slots, earliest committed in-flight finish when
    /// saturated), plus that shard's own floor, pushed through the fault
    /// plan. Always at least as wide as [`WindowBound::StaticFloor`]; the
    /// default everywhere.
    Dynamic,
    /// Every window is exactly the static fleet floor
    /// ([`lookahead_floor_ms`]) long — the original conservative baseline,
    /// kept as the reference for window-count comparisons
    /// (`tests/partition_equivalence.rs` asserts Dynamic strictly wins in
    /// the regimes that matter).
    StaticFloor,
}

/// What the partition executor actually did for one run — recorded on
/// [`driver::MultiRunOutput`] so callers and benches can verify the
/// parallel path (not the serial fallback) ran, and how much
/// synchronization it cost.
#[derive(Debug, Clone, Default)]
pub struct PartitionStats {
    /// Event loops that actually ran (1 = the serial reference loop).
    pub partitions: usize,
    /// Lookahead windows executed.
    pub windows: u64,
    /// Barrier waits performed by the coordinator (two per window, plus
    /// the initial collection and the final release).
    pub barrier_crossings: u64,
    /// Shard ops (submit/finish) replayed by the coordinator.
    pub ops_routed: u64,
    /// Provider completions routed back to partition mailboxes.
    pub deliveries: u64,
    /// Times a partition stopped at an event *exactly* on its window
    /// boundary (processed next window — the lookahead bound is strict).
    pub boundary_deferrals: u64,
    /// Why the serial reference loop ran instead of the parallel path
    /// (`None` = the parallel path ran).
    pub serial_fallback: Option<FallbackReason>,
    /// The static fleet floor (ms) — every dynamic window is at least this
    /// long (0 when no floor exists).
    pub lookahead_ms: f64,
}

/// A partition worker's provider seam: record stamped shard ops for the
/// coordinator's replay instead of touching the pool, and buffer the
/// per-tick samples for the merged diagnostics folds.
struct PartitionFabric {
    ops: Vec<StampedOp>,
    samples: Vec<TickSample>,
}

/// One end-of-tick observation a partition publishes for the coordinator's
/// merged diagnostics folds: the loop's queue depth (the serial
/// `DepthFold` stream) plus, for the single-tenant request-range carve,
/// the local in-flight count and whether this tick sent — the serial
/// `peak_inflight` is re-derived exactly from the merged stream (see the
/// coordinator).
#[derive(Debug, Clone, Copy)]
struct TickSample {
    time: f64,
    depth: usize,
    inflight: usize,
    sent: bool,
}

/// One shard op with the virtual time it happened at. In-stream order is
/// the within-partition order; the coordinator merges streams by
/// `(time, partition)`.
#[derive(Debug, Clone, Copy)]
struct StampedOp {
    time: f64,
    op: ShardOp,
}

#[derive(Debug, Clone, Copy)]
enum ShardOp {
    /// A `Send` action released `id` to `shard` (serial: `submit`).
    Submit { id: ReqId, tokens: f64, shard: usize },
    /// A `ProviderDone` popped for `id` (serial: `on_finish`).
    Finish { id: ReqId },
}

impl ShardFabric for PartitionFabric {
    fn send(&mut self, id: ReqId, tokens: f64, shard: usize, now: f64, _q: &mut EventQueue<Ev>) {
        self.ops.push(StampedOp { time: now, op: ShardOp::Submit { id, tokens, shard } });
    }
    fn flush(&mut self, _now: f64, _q: &mut EventQueue<Ev>) {
        // Replay applies ops one by one in stream order — the serial
        // fabric's batch boundaries carry no information (submit_batch is
        // per-item submit in order).
    }
    fn finish(&mut self, id: ReqId, now: f64, _q: &mut EventQueue<Ev>) {
        self.ops.push(StampedOp { time: now, op: ShardOp::Finish { id } });
    }
    fn end_tick(&mut self, now: f64, depth: usize, inflight: usize, sent: bool) {
        self.samples.push(TickSample { time: now, depth, inflight, sent });
    }
}

/// One partition's mailbox. Workers publish `ops`/`samples`/`peek` at the
/// end of each window; the coordinator consumes them, then fills
/// `deliveries` (completions owned by this partition, in replay order)
/// for the worker to drain at the next window start.
#[derive(Default)]
struct Slot {
    ops: Vec<StampedOp>,
    samples: Vec<TickSample>,
    deliveries: Vec<(f64, ReqId)>,
    peek: Option<f64>,
}

/// The per-partition `&mut` windows into the run's global arrays, claimed
/// once by the owning worker. In the single-tenant request-range carve the
/// scheduler windows are `None`: each worker runs its own fresh scheduler
/// clone (the stack is request-local, so a clone decides identically) and
/// its sends are summed back into the global array at join.
struct PartMut<'a> {
    schedulers: Option<&'a mut [ClientScheduler]>,
    status: &'a mut [RequestStatus],
    latency: &'a mut [Option<f64>],
    defer_counts: &'a mut [u32],
    sends_by_tenant: Option<&'a mut [u64]>,
}

/// Scalars each worker accumulates privately and returns at join.
struct WorkerOut {
    sends: u64,
    peak_inflight: usize,
    timers_canceled: u64,
    retries_scheduled: u64,
    processed: u64,
    skipped: u64,
    boundary_deferrals: u64,
}

/// What the coordinator thread accumulates across windows.
struct CoordOut {
    fold: DepthFold,
    windows: u64,
    barrier_crossings: u64,
    ops_routed: u64,
    deliveries: u64,
    /// Serial-exact `peak_inflight` for the single-tenant request-range
    /// carve, folded from the merged sample stream (unused otherwise).
    peak_inflight: usize,
    panic: Option<Box<dyn std::any::Any + Send>>,
}

/// Poison-tolerant lock: a worker panic is surfaced through the abort
/// protocol (and re-raised at join), not by poisoning every mailbox.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Split `s` into consecutive `&mut` chunks matching `bounds` (contiguous
/// `(lo, hi)` half-open ranges covering the slice).
fn split_chunks<'a, T>(mut s: &'a mut [T], bounds: &[(usize, usize)]) -> Vec<&'a mut [T]> {
    let mut out = Vec::with_capacity(bounds.len());
    let mut consumed = 0usize;
    for &(lo, hi) in bounds {
        debug_assert_eq!(lo, consumed, "bounds must be contiguous");
        let (head, rest) = s.split_at_mut(hi - lo);
        out.push(head);
        s = rest;
        consumed = hi;
    }
    debug_assert!(s.is_empty(), "bounds must cover the slice");
    out
}

/// Route a replayed completion to the partition owning its request id.
fn route(
    guards: &mut [MutexGuard<'_, Slot>],
    req_parts: &[(usize, usize)],
    started: Started,
    window_end: f64,
    deliveries: &mut u64,
) {
    // Empty partitions share `lo` with their successor; the last range
    // with `lo <= id` is the nonempty one containing `id`.
    let pi = req_parts.partition_point(|&(lo, _)| lo <= started.id) - 1;
    debug_assert!(
        started.id >= req_parts[pi].0 && started.id < req_parts[pi].1,
        "routed {} outside partition {pi} range {:?}",
        started.id,
        req_parts[pi],
    );
    // The conservative-lookahead invariant: nothing submitted or promoted
    // inside a window can finish inside it.
    debug_assert!(
        started.finish_ms >= window_end,
        "completion {} at {} lands before window end {window_end}",
        started.id,
        started.finish_ms,
    );
    guards[pi].deliveries.push((started.finish_ms, started.id));
    *deliveries += 1;
}

/// Run the DES across `partitions` event loops (see the module docs), or
/// fall back to the serial [`driver::run_core`] when partitioning was not
/// requested or is impossible ([`PartitionStats::serial_fallback`] says
/// which). Returns the same [`CoreRun`] the serial loop would —
/// bit-identical — plus what the executor did.
#[allow(clippy::too_many_arguments)] // the run's full working set, threaded explicitly
pub(crate) fn run_core_partitioned(
    requests: &[Request],
    priors: &[(Priors, Route)],
    owner: &[u32],
    tenant_ranges: &[(usize, usize)],
    schedulers: &mut [ClientScheduler],
    provider: &mut ProviderPool,
    pool_cfg: &PoolCfg,
    partitions: usize,
    bound: WindowBound,
) -> (CoreRun, PartitionStats) {
    let n_tenants = schedulers.len();
    let requested = if partitions == 0 { default_jobs() } else { partitions };
    // Multi-tenant runs never split below one tenant per partition; a
    // single-tenant run carves contiguous request-id ranges instead,
    // provided its scheduler stack is request-local.
    let split_single = n_tenants == 1 && requested >= 2;
    let p = if split_single { requested.min(requests.len()) } else { requested.min(n_tenants) };
    let floor = lookahead_floor_ms(pool_cfg);
    // Checked most-specific first, so `SpeedupFault` is distinguishable
    // from a genuinely floorless fleet (`lookahead_floor_ms` conflates the
    // two in its return value).
    let fallback = if p < 2 {
        Some(FallbackReason::NotRequested)
    } else if !pool_cfg.faults.extension_only() {
        Some(FallbackReason::SpeedupFault)
    } else if floor.is_none() {
        Some(FallbackReason::NoFloor)
    } else if split_single && !schedulers[0].cfg().request_local() {
        Some(FallbackReason::StatefulCarve)
    } else {
        None
    };
    if let Some(reason) = fallback {
        let core = driver::run_core(requests, priors, owner, schedulers, provider);
        let stats = PartitionStats {
            partitions: 1,
            serial_fallback: Some(reason),
            lookahead_ms: floor.unwrap_or(0.0),
            ..PartitionStats::default()
        };
        return (core, stats);
    }
    run_partitioned(
        requests,
        priors,
        owner,
        tenant_ranges,
        schedulers,
        provider,
        pool_cfg,
        p,
        floor.expect("checked above"),
        bound,
        split_single,
    )
}

#[allow(clippy::too_many_arguments)]
fn run_partitioned(
    requests: &[Request],
    priors: &[(Priors, Route)],
    owner: &[u32],
    tenant_ranges: &[(usize, usize)],
    schedulers: &mut [ClientScheduler],
    provider: &mut ProviderPool,
    pool_cfg: &PoolCfg,
    p: usize,
    lookahead_ms: f64,
    bound: WindowBound,
    split_single: bool,
) -> (CoreRun, PartitionStats) {
    let n = requests.len();
    let n_tenants = schedulers.len();
    debug_assert!(p >= 2 && (split_single || p <= n_tenants));

    // The partition map: balanced contiguous tenant groups (request-id
    // ranges follow because ids are tenant-major), or — for the
    // single-tenant request-local carve — balanced contiguous request-id
    // ranges directly.
    let tenant_parts = if split_single { Vec::new() } else { carve(n_tenants, p) };
    let req_parts: Vec<(usize, usize)> = if split_single {
        carve(n, p)
    } else {
        tenant_parts
            .iter()
            .map(|&(tlo, thi)| (tenant_ranges[tlo].0, tenant_ranges[thi - 1].1))
            .collect()
    };
    debug_assert_eq!(req_parts.last().map(|r| r.1), Some(n));

    // Shard-level inputs to the dynamic window bound. Every shard has a
    // floor here (the fleet floor exists) and the fault plan is
    // extension-only (both checked by the caller).
    let shard_floors: Vec<f64> = pool_cfg
        .shards
        .iter()
        .map(|s| shard_floor_ms(s).expect("caller checked the fleet floor"))
        .collect();
    let faults: &FaultPlan = &pool_cfg.faults;
    let fault_touched: Vec<bool> = (0..pool_cfg.shards.len()).map(|s| faults.touches(s)).collect();
    if bound == WindowBound::Dynamic {
        // The pool keeps a per-shard multiset of committed in-flight finish
        // times for `shard_earliest_pending_finish`; enabled only for the
        // duration of this run.
        provider.set_finish_tracking(true);
    }
    // The config each split worker builds its private scheduler clone from
    // (request-local: a clone decides identically, see `run_core_partitioned`).
    let split_cfg: Option<SchedulerCfg> =
        if split_single { Some(schedulers[0].cfg().clone()) } else { None };

    let mut status = vec![RequestStatus::Queued; n];
    let mut latency: Vec<Option<f64>> = vec![None; n];
    let mut defer_counts = vec![0u32; n];
    let mut sends_by_tenant = vec![0u64; n_tenants];

    let slots: Vec<Mutex<Slot>> = (0..p).map(|_| Mutex::new(Slot::default())).collect();
    // Coordinator → workers: the next window's start and end (f64 bits;
    // the end is negotiated per window, see the coordinator) and the two
    // stop signals. `abort` is set by a panicking worker *before* its
    // barrier arrival so siblings are released instead of deadlocking.
    let w_bits = AtomicU64::new(0);
    let end_bits = AtomicU64::new(0);
    let done = AtomicBool::new(false);
    let abort = AtomicBool::new(false);
    // Two barriers per window: `release` starts a round (workers may read
    // `w_bits`/`done` after it), `collect` ends it (the coordinator may
    // read mailboxes after it).
    let release = SpinBarrier::new(p + 1);
    let collect = SpinBarrier::new(p + 1);

    let (worker_outs, coord) = {
        let parts: Vec<Mutex<Option<PartMut<'_>>>> = {
            let status_chunks = split_chunks(&mut status[..], &req_parts);
            let latency_chunks = split_chunks(&mut latency[..], &req_parts);
            let defer_chunks = split_chunks(&mut defer_counts[..], &req_parts);
            if split_single {
                // Request-range carve: the one real scheduler and the
                // global send counter stay untouched — each worker runs a
                // private clone and its sends are summed back at join.
                status_chunks
                    .into_iter()
                    .zip(latency_chunks)
                    .zip(defer_chunks)
                    .map(|((st, lat), def)| {
                        Mutex::new(Some(PartMut {
                            schedulers: None,
                            status: st,
                            latency: lat,
                            defer_counts: def,
                            sends_by_tenant: None,
                        }))
                    })
                    .collect()
            } else {
                let sched_chunks = split_chunks(&mut *schedulers, &tenant_parts);
                let sbt_chunks = split_chunks(&mut sends_by_tenant[..], &tenant_parts);
                sched_chunks
                    .into_iter()
                    .zip(status_chunks)
                    .zip(latency_chunks)
                    .zip(defer_chunks)
                    .zip(sbt_chunks)
                    .map(|((((sch, st), lat), def), sbt)| {
                        Mutex::new(Some(PartMut {
                            schedulers: Some(sch),
                            status: st,
                            latency: lat,
                            defer_counts: def,
                            sends_by_tenant: Some(sbt),
                        }))
                    })
                    .collect()
            }
        };

        let worker = |i: usize| -> WorkerOut {
            let pm = lock(&parts[i]).take().expect("partition state claimed exactly once");
            let (req_lo, req_hi) = req_parts[i];
            let pn = req_hi - req_lo;
            // Local queue setup in the serial push order for this id
            // range: within-partition (time, seq) ties resolve exactly as
            // the global loop's tenant-major setup does.
            let mut q: EventQueue<Ev> = EventQueue::with_capacity(pn * 4);
            let mut timeout_timer: Vec<Option<TimerId>> = Vec::with_capacity(pn);
            for r in &requests[req_lo..req_hi] {
                q.push(r.arrival_ms, Ev::Arrival(r.id));
                timeout_timer.push(Some(q.push_cancelable(r.timeout_ms, Ev::Timeout(r.id))));
            }
            let mut retry_timer: Vec<Option<TimerId>> = vec![None; pn];
            let mut retry_attempts = vec![0u32; pn];
            let mut actions: Vec<Action> = Vec::new();
            let mut fabric = PartitionFabric { ops: Vec::new(), samples: Vec::new() };
            // Split mode: a fresh request-local scheduler clone and a
            // private send counter, folded back into the globals at join.
            let mut own_sched: Vec<ClientScheduler> = Vec::new();
            let mut own_sbt: Vec<u64> = Vec::new();
            let (schedulers, sends_by_tenant): (&mut [ClientScheduler], &mut [u64]) =
                match (pm.schedulers, pm.sends_by_tenant) {
                    (Some(sch), Some(sbt)) => (sch, sbt),
                    _ => {
                        let cfg = split_cfg.clone().expect("split mode carries a config");
                        own_sched.push(ClientScheduler::new(cfg));
                        own_sbt.push(0);
                        (&mut own_sched[..], &mut own_sbt[..])
                    }
                };
            let mut st = LoopState {
                base: req_lo,
                tenant_base: if split_single { 0 } else { tenant_parts[i].0 },
                status: pm.status,
                latency: pm.latency,
                defer_counts: pm.defer_counts,
                timeout_timer: &mut timeout_timer,
                retry_timer: &mut retry_timer,
                retry_attempts: &mut retry_attempts,
                sends_by_tenant,
                sends: 0,
                peak_inflight: 0,
                timers_canceled: 0,
                retries_scheduled: 0,
            };
            let mut boundary_deferrals = 0u64;
            let mut pending_panic: Option<Box<dyn std::any::Any + Send>> = None;
            lock(&slots[i]).peek = q.peek_time();
            collect.wait();
            loop {
                release.wait();
                if done.load(Ordering::Acquire) {
                    break;
                }
                let w = f64::from_bits(w_bits.load(Ordering::Acquire));
                // The negotiated bound for this window (>= w + the static
                // fleet floor; see the coordinator).
                let end = f64::from_bits(end_bits.load(Ordering::Acquire));
                let round = catch_unwind(AssertUnwindSafe(|| {
                    // Mailbox drain: completions the coordinator routed
                    // here, pushed in replay order (the serial push order).
                    {
                        let mut slot = lock(&slots[i]);
                        for &(finish_ms, id) in slot.deliveries.iter() {
                            debug_assert!(
                                finish_ms >= w,
                                "delivery for {id} at {finish_ms} precedes window start {w}"
                            );
                            q.push(finish_ms, Ev::ProviderDone(id));
                        }
                        slot.deliveries.clear();
                    }
                    // Advance strictly below `end`: the lookahead bound
                    // covers times < end only, so a boundary-exact event
                    // belongs to the next window.
                    loop {
                        match q.peek_time() {
                            Some(t) if t < end => {}
                            Some(t) => {
                                if t == end {
                                    boundary_deferrals += 1;
                                }
                                break;
                            }
                            None => break,
                        }
                        let (now, ev) = q.pop().expect("peeked event pops");
                        debug_assert!(
                            now >= w && now < end,
                            "event at {now} outside window [{w}, {end})"
                        );
                        process_tick(
                            now,
                            ev,
                            requests,
                            priors,
                            owner,
                            schedulers,
                            &mut st,
                            &mut q,
                            &mut actions,
                            &mut fabric,
                        );
                    }
                    // Publish the round: swap keeps both buffers' capacity
                    // ping-ponging instead of reallocating every window.
                    let mut slot = lock(&slots[i]);
                    std::mem::swap(&mut slot.ops, &mut fabric.ops);
                    std::mem::swap(&mut slot.samples, &mut fabric.samples);
                    slot.peek = q.peek_time();
                }));
                if let Err(payload) = round {
                    pending_panic = Some(payload);
                    abort.store(true, Ordering::Release);
                }
                collect.wait();
            }
            if let Some(payload) = pending_panic {
                resume_unwind(payload);
            }
            WorkerOut {
                sends: st.sends,
                peak_inflight: st.peak_inflight,
                timers_canceled: st.timers_canceled,
                retries_scheduled: st.retries_scheduled,
                processed: q.processed(),
                skipped: q.skipped(),
                boundary_deferrals,
            }
        };

        let coordinator = || -> CoordOut {
            let mut out = CoordOut {
                fold: DepthFold::new(),
                windows: 0,
                barrier_crossings: 0,
                ops_routed: 0,
                deliveries: 0,
                peak_inflight: 0,
                panic: None,
            };
            // Per-partition latest depth: the global depth after any
            // sample is the integer sum of each partition's latest local
            // depth — exactly the serial fold's observations. The same
            // construction re-derives the serial in-flight peak for the
            // single-tenant carve (in-flight only changes at a partition's
            // own events, so the sum at any merged sample is the one
            // serial scheduler's count at that instant).
            let mut cur_depth = vec![0usize; p];
            let mut depth_total = 0usize;
            let mut cur_inflight = vec![0usize; p];
            let mut inflight_total = 0usize;
            collect.wait();
            out.barrier_crossings += 1;
            loop {
                // Next window start: the earliest pending event anywhere —
                // local heap heads and undrained deliveries (a drained-out
                // partition may still owe a routed completion).
                let mut w = f64::INFINITY;
                for slot in &slots {
                    let slot = lock(slot);
                    if let Some(t) = slot.peek {
                        w = w.min(t);
                    }
                    for &(finish_ms, _) in slot.deliveries.iter() {
                        w = w.min(finish_ms);
                    }
                }
                if w == f64::INFINITY {
                    done.store(true, Ordering::Release);
                    release.wait();
                    out.barrier_crossings += 1;
                    break;
                }
                // Negotiate this window's end before releasing the round.
                // Static: always the fleet floor. Dynamic: per shard, the
                // earliest instant a *new* completion could be committed —
                // `w` while slots are free, else the shard's earliest
                // committed in-flight finish (slots free only at committed
                // finish times, and replay order guarantees any submission
                // a freed slot admits carries a timestamp at or after that
                // finish) — plus the shard's own service floor, pushed
                // through the fault plan's walk (`adjusted_finish` is
                // monotone in start and service, so the walked floor lower-
                // bounds every walked real service). The min over shards
                // therefore still bounds every completion committable this
                // window, and it dominates `w + lookahead_ms` because each
                // start >= w and each shard floor >= the fleet floor.
                let window_end = match bound {
                    WindowBound::StaticFloor => w + lookahead_ms,
                    WindowBound::Dynamic => {
                        let mut end = f64::INFINITY;
                        for (s, &floor_s) in shard_floors.iter().enumerate() {
                            let start = if provider.shard_free_slots(s) > 0 {
                                w
                            } else if let Some(e) = provider.shard_earliest_pending_finish(s) {
                                e
                            } else {
                                // Saturated with nothing in flight: a
                                // zero-capacity shard that can never
                                // commit anything — it bounds nothing.
                                continue;
                            };
                            let b = if fault_touched[s] {
                                faults.adjusted_finish(s, start, floor_s)
                            } else {
                                start + floor_s
                            };
                            end = end.min(b);
                        }
                        end
                    }
                };
                w_bits.store(w.to_bits(), Ordering::Release);
                end_bits.store(window_end.to_bits(), Ordering::Release);
                release.wait();
                collect.wait();
                out.barrier_crossings += 2;
                out.windows += 1;
                if abort.load(Ordering::Acquire) {
                    // A worker panicked this round: release everyone into
                    // the done-branch and let join re-raise its payload.
                    done.store(true, Ordering::Release);
                    release.wait();
                    out.barrier_crossings += 1;
                    break;
                }
                let merged = catch_unwind(AssertUnwindSafe(|| {
                    let mut guards: Vec<MutexGuard<'_, Slot>> =
                        slots.iter().map(|s| lock(s)).collect();
                    // Replay shard ops in merged (time, partition) order —
                    // the serial loop's op order (see module docs).
                    let mut cursors = vec![0usize; p];
                    loop {
                        let mut best: Option<(f64, usize)> = None;
                        for (pi, g) in guards.iter().enumerate() {
                            if let Some(op) = g.ops.get(cursors[pi]) {
                                let better = match best {
                                    None => true,
                                    Some((bt, _)) => op.time < bt,
                                };
                                if better {
                                    best = Some((op.time, pi));
                                }
                            }
                        }
                        let Some((_, pi)) = best else { break };
                        let op = guards[pi].ops[cursors[pi]];
                        cursors[pi] += 1;
                        out.ops_routed += 1;
                        match op.op {
                            ShardOp::Submit { id, tokens, shard } => {
                                if let Some(s) = provider.submit(id, tokens, shard, op.time) {
                                    route(
                                        &mut guards,
                                        &req_parts,
                                        s,
                                        window_end,
                                        &mut out.deliveries,
                                    );
                                }
                            }
                            ShardOp::Finish { id } => {
                                for s in provider.on_finish(id, op.time) {
                                    route(
                                        &mut guards,
                                        &req_parts,
                                        s,
                                        window_end,
                                        &mut out.deliveries,
                                    );
                                }
                            }
                        }
                    }
                    // Fold tick samples in the same merged order, keeping
                    // the integer global depth (and, for the single-tenant
                    // carve, the global in-flight count) exact.
                    let mut cursors = vec![0usize; p];
                    loop {
                        let mut best: Option<(f64, usize)> = None;
                        for (pi, g) in guards.iter().enumerate() {
                            if let Some(sm) = g.samples.get(cursors[pi]) {
                                let better = match best {
                                    None => true,
                                    Some((bt, _)) => sm.time < bt,
                                };
                                if better {
                                    best = Some((sm.time, pi));
                                }
                            }
                        }
                        let Some((_, pi)) = best else { break };
                        let sm = guards[pi].samples[cursors[pi]];
                        cursors[pi] += 1;
                        depth_total = depth_total - cur_depth[pi] + sm.depth;
                        cur_depth[pi] = sm.depth;
                        out.fold.observe(sm.time, depth_total);
                        if split_single {
                            inflight_total = inflight_total - cur_inflight[pi] + sm.inflight;
                            cur_inflight[pi] = sm.inflight;
                            // The serial loop takes its peak in the Send
                            // arm; a naive send tick's end-of-tick count
                            // equals that mid-tick count (one send per
                            // tick, nothing after it changes in-flight).
                            if sm.sent {
                                out.peak_inflight = out.peak_inflight.max(inflight_total);
                            }
                        }
                    }
                    for g in guards.iter_mut() {
                        g.ops.clear();
                        g.samples.clear();
                    }
                }));
                if let Err(payload) = merged {
                    out.panic = Some(payload);
                    done.store(true, Ordering::Release);
                    release.wait();
                    out.barrier_crossings += 1;
                    break;
                }
            }
            out
        };

        scoped_workers(p, worker, coordinator)
    };

    if bound == WindowBound::Dynamic {
        provider.set_finish_tracking(false);
    }

    if let Some(payload) = coord.panic {
        resume_unwind(payload);
    }

    if split_single {
        // The workers ran private scheduler clones; fold their private
        // send counters back into the one tenant's global slot.
        sends_by_tenant[0] = worker_outs.iter().map(|w| w.sends).sum();
    }

    let (mean_queue_depth, peak_queue_depth) = coord.fold.finish();
    let core = CoreRun {
        status,
        latency,
        defer_counts,
        sends: worker_outs.iter().map(|w| w.sends).sum(),
        sends_by_tenant,
        // Split mode folds the serial-exact peak from the merged sample
        // stream (per-worker peaks see only their own range's in-flight).
        peak_inflight: if split_single {
            coord.peak_inflight
        } else {
            worker_outs.iter().map(|w| w.peak_inflight).max().unwrap_or(0)
        },
        timers_canceled: worker_outs.iter().map(|w| w.timers_canceled).sum(),
        events_processed: worker_outs.iter().map(|w| w.processed).sum(),
        events_skipped: worker_outs.iter().map(|w| w.skipped).sum(),
        mean_queue_depth,
        peak_queue_depth,
        // Split mode reads the untouched originals: a request-local stack
        // structurally never increments these, matching serial's zeros.
        ordering_select_work: schedulers.iter().map(|s| s.ordering_work()).sum(),
        ordering_group_count: schedulers.iter().map(|s| s.ordering_group_count()).sum(),
        ordering_scan_fallbacks: schedulers.iter().map(|s| s.ordering_scan_fallbacks()).sum(),
        retries_scheduled: worker_outs.iter().map(|w| w.retries_scheduled).sum(),
    };
    let stats = PartitionStats {
        partitions: p,
        windows: coord.windows,
        barrier_crossings: coord.barrier_crossings,
        ops_routed: coord.ops_routed,
        deliveries: coord.deliveries,
        boundary_deferrals: worker_outs.iter().map(|w| w.boundary_deferrals).sum(),
        serial_fallback: None,
        lookahead_ms,
    };
    (core, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::provider::fault::FaultPlan;
    use crate::provider::ProviderCfg;

    fn cfg(base_ms: f64, jitter_sigma: f64) -> ProviderCfg {
        ProviderCfg { base_ms, jitter_sigma, ..ProviderCfg::default() }
    }

    #[test]
    fn floor_is_exact_base_without_jitter() {
        let pool = PoolCfg::single(cfg(40.0, 0.0));
        assert_eq!(lookahead_floor_ms(&pool), Some(40.0));
    }

    #[test]
    fn floor_takes_min_across_shards_and_discounts_jitter() {
        let pool = PoolCfg {
            shards: vec![cfg(100.0, 0.0), cfg(80.0, 0.1)],
            faults: FaultPlan::default(),
        };
        let f = lookahead_floor_ms(&pool).unwrap();
        let expected = 80.0 * (-0.1f64 * Z_BOUND).exp() * (1.0 - 1e-9);
        assert_eq!(f.to_bits(), expected.to_bits());
        assert!(f < 80.0 && f > 0.0);
    }

    #[test]
    fn floor_rejects_speedup_fault_plans() {
        // A brownout factor above 1.0 means a shard can run *faster* than its
        // nominal service model inside the window, so the lookahead floor is
        // unsound and the partitioned loop must fall back to serial.
        let speedup = FaultPlan::default().brownout(0, 0.0, 1_000.0, 2.0).unwrap();
        let pool = PoolCfg::single(cfg(40.0, 0.0)).with_faults(speedup);
        assert_eq!(lookahead_floor_ms(&pool), None);

        // Extension-only plans (blackouts and slow-down brownouts) only ever
        // push finishes later, so the fault-free floor stays valid.
        let ext = FaultPlan::default()
            .blackout(0, 0.0, 500.0)
            .unwrap()
            .brownout(0, 1_000.0, 2_000.0, 0.5)
            .unwrap();
        let pool = PoolCfg::single(cfg(40.0, 0.0)).with_faults(ext);
        assert_eq!(lookahead_floor_ms(&pool), Some(40.0));
    }

    #[test]
    fn floor_rejects_degenerate_fleets() {
        assert_eq!(lookahead_floor_ms(&PoolCfg::single(cfg(0.0, 0.0))), None);
        assert_eq!(lookahead_floor_ms(&PoolCfg::single(cfg(-1.0, 0.0))), None);
        assert_eq!(lookahead_floor_ms(&PoolCfg::single(cfg(f64::NAN, 0.0))), None);
        assert_eq!(lookahead_floor_ms(&PoolCfg::single(cfg(40.0, f64::NAN))), None);
        let mut neg_token = cfg(40.0, 0.0);
        neg_token.per_token_ms = -0.5;
        assert_eq!(lookahead_floor_ms(&PoolCfg::single(neg_token)), None);
        let mut neg_gamma = cfg(40.0, 0.0);
        neg_gamma.slowdown_gamma = -0.1;
        assert_eq!(lookahead_floor_ms(&PoolCfg::single(neg_gamma)), None);
        // A huge sigma drives the floor below the useful threshold.
        assert_eq!(lookahead_floor_ms(&PoolCfg::single(cfg(1e-3, 3.0))), None);
    }

    #[test]
    fn floor_bound_really_holds_for_sampled_services() {
        // Empirical guard for the Z_BOUND analysis: no sampled service
        // time may undercut the floor.
        use crate::util::rng::Rng;
        let shard = cfg(50.0, 0.25);
        let pool = PoolCfg::single(shard);
        let floor = lookahead_floor_ms(&pool).unwrap();
        let mut rng = Rng::new(0xF1005);
        for _ in 0..200_000 {
            let s = 50.0 * rng.lognormal(0.0, 0.25);
            assert!(s >= floor, "sampled service {s} under floor {floor}");
        }
    }

    #[test]
    fn default_partitions_parses_env_conventions() {
        // Can't mutate the env safely in parallel tests; exercise the
        // parse path the function uses.
        assert_eq!("4".trim().parse::<usize>().unwrap_or(1), 4);
        assert_eq!("".trim().parse::<usize>().unwrap_or(1), 1);
        assert_eq!("nope".trim().parse::<usize>().unwrap_or(1), 1);
        assert_eq!(" 0 ".trim().parse::<usize>().unwrap_or(1), 0);
    }

    #[test]
    fn split_chunks_covers_and_isolates() {
        let mut v: Vec<u32> = (0..10).collect();
        let bounds = [(0usize, 3usize), (3, 3), (3, 10)];
        let chunks = split_chunks(&mut v[..], &bounds);
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0], &[0, 1, 2]);
        assert!(chunks[1].is_empty());
        assert_eq!(chunks[2].len(), 7);
    }

    #[test]
    fn routing_picks_the_owning_partition_with_empty_ranges() {
        // partition_point convention: empty ranges share `lo` with their
        // successor and must never win.
        let req_parts = [(0usize, 4usize), (4, 4), (4, 9)];
        for (id, want) in [(0usize, 0usize), (3, 0), (4, 2), (8, 2)] {
            let pi = req_parts.partition_point(|&(lo, _)| lo <= id) - 1;
            assert_eq!(pi, want, "id {id}");
        }
    }
}
