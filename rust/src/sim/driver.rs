//! Virtual-time run driver: wires workload → scheduler → mock provider on
//! the discrete-event engine and produces per-request outcomes.
//!
//! The driver is the only component that sees both sides of the black-box
//! boundary: it hands the scheduler nothing but arrival/completion events
//! and hands the provider nothing but submissions. All experiment tables
//! are produced by running this driver across seeds/policies/regimes.
//!
//! Two entry points share one event loop (`run_core`):
//! * [`run_pool`] — one client scheduler against a (possibly sharded)
//!   provider pool: every classic experiment;
//! * [`run_tenants`] — M independent client schedulers, each with its own
//!   `SchedulerCfg`, workload stream, and shard selector, sharing one
//!   [`ProviderPool`]. Tenant ticks interleave deterministically: events
//!   order by `(time, seq)` with seqs assigned tenant-major at setup, so
//!   simultaneous cross-tenant events resolve by tenant index, then
//!   arrival order. Tenant 0 consumes the base RNG streams verbatim, so a
//!   1-tenant run is **byte-identical** to [`run_pool`] (property-tested
//!   in `tests/tenant_equivalence.rs`); tenants ≥ 1 derive independent
//!   streams, so adding a tenant never perturbs existing ones' workloads.
//!
//! Hot-path notes: one `Action` buffer is reused for the entire run (the
//! scheduler appends, the driver drains), and every `Timeout`/`Retry`
//! event is a cancelable timer — when a request reaches a terminal state
//! its pending timers are canceled in O(1), so at scale the event heap
//! carries no dead entry per completed request and `events_processed`
//! counts only real work.

use crate::core::{Priors, ReqId, Request, RequestStatus};
use crate::metrics::{compute, RequestOutcome, RunMetrics};
use crate::predictor::{InfoLevel, LadderSource, NoisySource, PriorSource, Route};
use crate::provider::pool::{PoolCfg, ProviderPool};
use crate::provider::{ProviderCfg, Started};
use crate::scheduler::{Action, ClientScheduler, SchedulerCfg};
use crate::sim::{EventQueue, TimerId};
use crate::util::rng::Rng;
use crate::workload::WorkloadSpec;

/// DES event payloads.
#[derive(Debug, Clone, Copy)]
pub(crate) enum Ev {
    /// A request enters its owner's scheduler.
    Arrival(ReqId),
    /// The provider finished a submission (or promoted hidden-queue work).
    ProviderDone(ReqId),
    /// A deferred request's backoff expired.
    Retry(ReqId),
    /// A request's hard timeout fired.
    Timeout(ReqId),
}

impl Ev {
    /// The request this event belongs to (every event has exactly one).
    pub(crate) fn req(self) -> ReqId {
        match self {
            Ev::Arrival(id) | Ev::ProviderDone(id) | Ev::Retry(id) | Ev::Timeout(id) => id,
        }
    }
}

/// Extra run diagnostics beyond `RunMetrics`.
#[derive(Debug, Clone, Default)]
pub struct RunDiagnostics {
    /// Live events handled (canceled timers excluded).
    pub events_processed: u64,
    /// Canceled timer entries discarded at the heap head without handling.
    pub events_skipped: u64,
    /// Timers canceled because their request reached a terminal state.
    pub timers_canceled: u64,
    /// Completed submissions to the provider (fleet-wide).
    pub sends: u64,
    /// Peak hidden provider-side queue depth (total across shards).
    pub peak_provider_queue: usize,
    /// Largest per-client in-flight count observed.
    pub peak_inflight: usize,
    /// Requests started per provider shard (`vec![n_started]` for the
    /// classic single-endpoint runs) — the fleet balance signal.
    pub started_by_shard: Vec<u64>,
    /// Time-weighted mean of the schedulers' total queued depth (deferred
    /// requests excluded — this is the population the ordering layer
    /// selects over), taken across the event-time span of the run. The
    /// steady-state depth signal the `scale` experiment and the bench
    /// `--depth` leg report.
    pub mean_queue_depth: f64,
    /// Largest total scheduler queue depth observed after any event.
    pub peak_queue_depth: usize,
    /// Cumulative ordering-index work across all schedulers: entries
    /// examined + migrations processed by `Ordering::select`. Deterministic
    /// (counted, not timed) — the numerator of the bench `--depth` leg's
    /// per-release cost.
    pub ordering_select_work: u64,
    /// Peak distinct ordering index groups across all schedulers. Under
    /// quantized prior grouping this counts occupied prior bins — the
    /// quantity that bounds per-release scan cost under continuous priors.
    pub ordering_group_count: u64,
    /// Releases where an ordering index degenerated to a full scan of the
    /// selected side (every live entry examined), summed over schedulers.
    pub ordering_scan_fallbacks: u64,
    /// Client retry re-entries scheduled (timed-out or rejected requests
    /// that re-arrived under a [`crate::scheduler::RetryCfg`] budget).
    /// Zero whenever retries are disabled — the bit-compat default.
    pub retries_scheduled: u64,
    /// Total service-time extension (ms) the provider fault plan added
    /// across all submissions: Σ (adjusted finish − clean finish). Zero for
    /// an empty [`crate::provider::fault::FaultPlan`].
    pub faulted_shard_ms: f64,
}

/// Outcome bundle of one simulated run.
pub struct RunOutput {
    /// Aggregate metrics (the CSV row).
    pub metrics: RunMetrics,
    /// Per-request terminal states and latencies.
    pub outcomes: Vec<RequestOutcome>,
    /// Engine-level diagnostics beyond the metrics.
    pub diagnostics: RunDiagnostics,
    /// Partitioned-execution accounting (window/barrier/mailbox counters).
    /// `partitions == 1` for serial runs; never affects `diagnostics` or
    /// `outcomes` — the single-tenant carve is bit-identical to serial.
    pub partition: crate::sim::partition::PartitionStats,
}

/// Simulate one run to completion against a single provider endpoint.
///
/// Runs on a degenerate 1-shard [`ProviderPool`], which is bit-identical to
/// the bare `MockProvider` path this driver used before sharding (same RNG
/// stream, same event order) — every pre-pool experiment CSV is preserved.
pub fn run(
    requests: &[Request],
    prior_source: &mut dyn PriorSource,
    sched_cfg: SchedulerCfg,
    provider_cfg: ProviderCfg,
    seed: u64,
) -> RunOutput {
    run_pool(requests, prior_source, sched_cfg, &PoolCfg::single(provider_cfg), seed)
}

/// Submit every batched Send in action order and schedule the completions.
///
/// Called at Send-run boundaries (and at end of tick) so that event-queue
/// push order — and therefore heap tie-breaking — is exactly what
/// per-action submission produced: a `ProviderDone` scheduled by Send #k is
/// pushed before any event a later action pushes.
fn flush_sends(
    provider: &mut ProviderPool,
    batch: &mut Vec<(ReqId, f64, usize)>,
    started: &mut Vec<Started>,
    q: &mut EventQueue<Ev>,
    now: f64,
) {
    if batch.is_empty() {
        return;
    }
    started.clear();
    provider.submit_batch(batch, now, started);
    for s in started.iter() {
        q.push(s.finish_ms, Ev::ProviderDone(s.id));
    }
    batch.clear();
}

/// Mutable event-loop results shared by the single- and multi-tenant entry
/// points (and assembled by the partition executor from its per-partition
/// loops). Indexed by global request id.
pub(crate) struct CoreRun {
    pub(crate) status: Vec<RequestStatus>,
    pub(crate) latency: Vec<Option<f64>>,
    pub(crate) defer_counts: Vec<u32>,
    pub(crate) sends: u64,
    pub(crate) sends_by_tenant: Vec<u64>,
    pub(crate) peak_inflight: usize,
    pub(crate) timers_canceled: u64,
    pub(crate) events_processed: u64,
    pub(crate) events_skipped: u64,
    pub(crate) mean_queue_depth: f64,
    pub(crate) peak_queue_depth: usize,
    pub(crate) ordering_select_work: u64,
    pub(crate) ordering_group_count: u64,
    pub(crate) ordering_scan_fallbacks: u64,
    pub(crate) retries_scheduled: u64,
}

/// Time-weighted queue-depth integrator, shared verbatim by the serial loop
/// and the partitioned coordinator so `mean_queue_depth` is bit-identical
/// regardless of partition count: both modes perform the exact same
/// sequence of f64 operations over the same (time, depth) observations.
pub(crate) struct DepthFold {
    span_start: Option<f64>,
    last_now: f64,
    last_depth: usize,
    area: f64,
    peak: usize,
}

impl DepthFold {
    pub(crate) fn new() -> DepthFold {
        DepthFold { span_start: None, last_now: 0.0, last_depth: 0, area: 0.0, peak: 0 }
    }

    /// Record the total scheduler queue depth after an event at `now`. The
    /// depth after each event holds until the next event pops, so
    /// ∫depth·dt accumulates one rectangle per event.
    pub(crate) fn observe(&mut self, now: f64, depth: usize) {
        if self.span_start.is_none() {
            self.span_start = Some(now);
        } else {
            self.area += self.last_depth as f64 * (now - self.last_now);
        }
        self.last_now = now;
        self.last_depth = depth;
        self.peak = self.peak.max(depth);
    }

    /// `(mean, peak)` depth over the observed event-time span.
    pub(crate) fn finish(&self) -> (f64, usize) {
        let span = self.last_now - self.span_start.unwrap_or(0.0);
        let mean = if span > 0.0 { self.area / span } else { 0.0 };
        (mean, self.peak)
    }
}

/// The event loop's provider-facing seam. The serial loop talks to the
/// shared [`ProviderPool`] directly ([`SerialFabric`]); a partition worker
/// records stamped shard ops into its mailbox instead
/// (`sim::partition::PartitionFabric`) for the coordinator to replay in
/// merged stamp order between windows. [`process_tick`] is generic over
/// this trait, so both modes run the *same* tick body — the partitioned
/// bit-compat contract is structural, not re-implemented.
pub(crate) trait ShardFabric {
    /// A `Send` action released `id` to `shard`.
    fn send(&mut self, id: ReqId, tokens: f64, shard: usize, now: f64, q: &mut EventQueue<Ev>);
    /// A contiguous run of Sends ended (the next action pushes an event, or
    /// the tick is over): dispatch the batch.
    fn flush(&mut self, now: f64, q: &mut EventQueue<Ev>);
    /// A `ProviderDone` popped: retire the submission, promote hidden work.
    fn finish(&mut self, id: ReqId, now: f64, q: &mut EventQueue<Ev>);
    /// The tick is fully applied; `depth` is this loop's scheduler queue
    /// depth after it, `inflight` the tick-owning tenant's in-flight count,
    /// and `sent` whether the tick released at least one Send. The serial
    /// fabric folds only `depth`; the partition fabric buffers all three so
    /// the coordinator can re-derive serial-exact diagnostics from the
    /// merged sample stream.
    fn end_tick(&mut self, now: f64, depth: usize, inflight: usize, sent: bool);
}

/// Direct pool access plus the inline depth fold: the serial reference
/// fabric.
pub(crate) struct SerialFabric<'p> {
    provider: &'p mut ProviderPool,
    batch: Vec<(ReqId, f64, usize)>,
    started: Vec<Started>,
    pub(crate) fold: DepthFold,
}

impl<'p> SerialFabric<'p> {
    pub(crate) fn new(provider: &'p mut ProviderPool) -> SerialFabric<'p> {
        SerialFabric { provider, batch: Vec::new(), started: Vec::new(), fold: DepthFold::new() }
    }
}

impl ShardFabric for SerialFabric<'_> {
    fn send(&mut self, id: ReqId, tokens: f64, shard: usize, _now: f64, _q: &mut EventQueue<Ev>) {
        self.batch.push((id, tokens, shard));
    }
    fn flush(&mut self, now: f64, q: &mut EventQueue<Ev>) {
        flush_sends(self.provider, &mut self.batch, &mut self.started, q, now);
    }
    fn finish(&mut self, id: ReqId, now: f64, q: &mut EventQueue<Ev>) {
        // Promote hidden-queue work first (provider-internal). The promoted
        // requests may belong to any tenant — their completions are routed
        // by ownership when they pop.
        for started in self.provider.on_finish(id, now) {
            q.push(started.finish_ms, Ev::ProviderDone(started.id));
        }
    }
    fn end_tick(&mut self, now: f64, depth: usize, _inflight: usize, _sent: bool) {
        self.fold.observe(now, depth);
    }
}

/// One event loop's mutable request-state window. The serial loop owns the
/// whole run (`base == 0`, full-length slices); a partition worker owns the
/// contiguous tenant-major slice carved for it, with `base`/`tenant_base`
/// translating global request and tenant ids to slice indices.
pub(crate) struct LoopState<'a> {
    /// Global id of the first request in these slices.
    pub(crate) base: usize,
    /// Tenant index of the first scheduler in the loop's scheduler slice.
    pub(crate) tenant_base: usize,
    pub(crate) status: &'a mut [RequestStatus],
    pub(crate) latency: &'a mut [Option<f64>],
    pub(crate) defer_counts: &'a mut [u32],
    pub(crate) timeout_timer: &'a mut [Option<TimerId>],
    pub(crate) retry_timer: &'a mut [Option<TimerId>],
    /// Client retry attempts consumed per request (0 until the first
    /// timeout/reject re-entry is scheduled).
    pub(crate) retry_attempts: &'a mut [u32],
    pub(crate) sends_by_tenant: &'a mut [u64],
    pub(crate) sends: u64,
    pub(crate) peak_inflight: usize,
    pub(crate) timers_canceled: u64,
    pub(crate) retries_scheduled: u64,
}

impl LoopState<'_> {
    /// Schedule a client retry re-entry for a terminally failed request, if
    /// the owning tenant's [`crate::scheduler::RetryCfg`] still has budget.
    /// The re-entry is a plain future `Ev::Arrival` — tenant-local, so the
    /// partitioned loop handles it exactly like a first arrival — and the
    /// attempt counter is charged here, at scheduling time, so a storm of
    /// failures terminates once `max_attempts` re-entries have been spent.
    fn maybe_schedule_client_retry(
        &mut self,
        id: ReqId,
        retry: &crate::scheduler::RetryCfg,
        now: f64,
        q: &mut EventQueue<Ev>,
    ) {
        let li = id - self.base;
        if self.retry_attempts[li] >= retry.max_attempts {
            return;
        }
        let delay = retry.backoff_ms(self.retry_attempts[li]);
        self.retry_attempts[li] += 1;
        self.retries_scheduled += 1;
        q.push(now + delay, Ev::Arrival(id));
    }
}

/// Apply one popped event — the scheduler callback plus the resulting
/// actions — against the loop's state window. This is the *entire*
/// per-event body of the DES: the serial loop and every partition worker
/// call it with their own fabric, so there is exactly one copy of the
/// scheduling semantics.
#[allow(clippy::too_many_arguments)] // the loop's full working set, threaded explicitly
pub(crate) fn process_tick<F: ShardFabric>(
    now: f64,
    ev: Ev,
    requests: &[Request],
    priors: &[(Priors, Route)],
    owner: &[u32],
    schedulers: &mut [ClientScheduler],
    st: &mut LoopState<'_>,
    q: &mut EventQueue<Ev>,
    actions: &mut Vec<Action>,
    fabric: &mut F,
) {
    actions.clear();
    // Every event belongs to exactly one tenant; all actions this tick
    // come from that tenant's scheduler.
    let tenant = owner[ev.req()] as usize - st.tenant_base;
    let scheduler = &mut schedulers[tenant];
    match ev {
        Ev::Arrival(id) => {
            let (p, route) = priors[id];
            let li = id - st.base;
            if matches!(st.status[li], RequestStatus::TimedOut | RequestStatus::Rejected) {
                // Client retry re-entry: the request failed terminally and
                // its owner scheduled a backed-off resubmission. The client
                // re-submits with a fresh SLO clock (deadline/timeout shift
                // to re-entry time), reusing the stored prior — retries
                // consume no new RNG, so they stay bit-identical across
                // partition counts. Completion latency is still measured
                // from the *original* arrival (the Ev::ProviderDone arm),
                // so retried completions pay their full end-to-end delay.
                st.status[li] = RequestStatus::Queued;
                let r = &requests[id];
                let timeout_budget = r.timeout_ms - r.arrival_ms;
                st.timeout_timer[li] =
                    Some(q.push_cancelable(now + timeout_budget, Ev::Timeout(id)));
                let mut rr = r.clone();
                rr.arrival_ms = now;
                rr.deadline_ms = now + (r.deadline_ms - r.arrival_ms);
                rr.timeout_ms = now + timeout_budget;
                scheduler.on_arrival(&rr, p, route, now, actions);
            } else {
                scheduler.on_arrival(&requests[id], p, route, now, actions);
            }
        }
        Ev::ProviderDone(id) => {
            fabric.finish(id, now, q);
            let li = id - st.base;
            if st.status[li] == RequestStatus::InFlight {
                st.status[li] = RequestStatus::Completed;
                let lat = now - requests[id].arrival_ms;
                st.latency[li] = Some(lat);
                if let Some(t) = st.timeout_timer[li].take() {
                    if q.cancel(t) {
                        st.timers_canceled += 1;
                    }
                }
                let budget = requests[id].deadline_ms - requests[id].arrival_ms;
                scheduler.on_completion(id, lat, budget, now, actions);
                // Interval recalibration learns only from *observed*
                // completions — this arm. Abandoned/timed-out requests are
                // censored and never reach the update path. The claimed
                // (source-emitted, pre-recalibration) priors are the
                // reference the realized length is scored against.
                let (claimed, route) = priors[id];
                scheduler.observe_completion(
                    claimed,
                    &route,
                    requests[id].true_output_tokens as f64,
                );
            }
            // TimedOut → client already abandoned; completion is unobserved.
        }
        Ev::Retry(id) => {
            let li = id - st.base;
            st.retry_timer[li] = None;
            if st.status[li] == RequestStatus::Deferred {
                st.status[li] = RequestStatus::Queued;
                scheduler.on_retry_due(id, now, actions);
            }
        }
        Ev::Timeout(id) => {
            // The timer fired; its slot is already retired by the queue.
            let li = id - st.base;
            st.timeout_timer[li] = None;
            if matches!(
                st.status[li],
                RequestStatus::Queued | RequestStatus::Deferred | RequestStatus::InFlight
            ) {
                scheduler.cancel(id, now, actions);
                st.status[li] = RequestStatus::TimedOut;
                if let Some(t) = st.retry_timer[li].take() {
                    if q.cancel(t) {
                        st.timers_canceled += 1;
                    }
                }
                let retry = &scheduler.cfg().retry;
                st.maybe_schedule_client_retry(id, retry, now, q);
            }
        }
    }
    // Apply scheduler actions; sending can cascade (a Send fills a slot;
    // the provider may queue it internally). Contiguous Sends are
    // dispatched as one batch; the batch flushes before any action that
    // pushes an event, preserving per-action event order exactly.
    let mut sent = false;
    for a in actions.iter() {
        match *a {
            Action::Send { id, shard } => {
                let li = id - st.base;
                debug_assert_eq!(st.status[li], RequestStatus::Queued, "send of non-queued {id}");
                st.status[li] = RequestStatus::InFlight;
                st.sends += 1;
                st.sends_by_tenant[tenant] += 1;
                st.peak_inflight = st.peak_inflight.max(schedulers[tenant].state().inflight());
                sent = true;
                fabric.send(id, requests[id].true_output_tokens as f64, shard, now, q);
            }
            Action::Retry { id, at_ms } => {
                fabric.flush(now, q);
                let li = id - st.base;
                st.status[li] = RequestStatus::Deferred;
                st.defer_counts[li] += 1;
                st.retry_timer[li] = Some(q.push_cancelable(at_ms, Ev::Retry(id)));
            }
            Action::Reject { id } => {
                let li = id - st.base;
                st.status[li] = RequestStatus::Rejected;
                if let Some(t) = st.timeout_timer[li].take() {
                    if q.cancel(t) {
                        st.timers_canceled += 1;
                    }
                }
                // Rejected work may also re-enter under the client retry
                // budget — overload sheds it now, the client comes back
                // after backoff. Budget exhaustion leaves the terminal
                // Rejected state to stand (counted in `RunDiagnostics`).
                let retry = &schedulers[tenant].cfg().retry;
                st.maybe_schedule_client_retry(id, retry, now, q);
            }
        }
    }
    fabric.flush(now, q);
    let depth = schedulers.iter().map(|s| s.queued()).sum();
    fabric.end_tick(now, depth, schedulers[tenant].state().inflight(), sent);
}

/// The shared DES loop: pop events, feed the owning tenant's scheduler,
/// apply its actions against the one shared provider pool.
///
/// `owner[id]` names the tenant (scheduler index) each request belongs to;
/// the single-tenant entry point passes all-zeros, so this is *literally*
/// the same code path for both — the 1-tenant bit-compat contract is
/// structural, not re-implemented. The partitioned executor
/// (`sim::partition`) runs the same [`process_tick`] body per partition and
/// must stay bit-identical to this loop; `--partitions 1` runs come here.
pub(crate) fn run_core(
    requests: &[Request],
    priors: &[(Priors, Route)],
    owner: &[u32],
    schedulers: &mut [ClientScheduler],
    provider: &mut ProviderPool,
) -> CoreRun {
    let n = requests.len();
    let mut status = vec![RequestStatus::Queued; n];
    let mut latency: Vec<Option<f64>> = vec![None; n];
    let mut defer_counts = vec![0u32; n];
    let mut sends_by_tenant = vec![0u64; schedulers.len()];

    // Setup pushes are tenant-major (requests are concatenated per tenant),
    // so heap ties — (time, seq) — resolve by (tenant, arrival order).
    let mut q: EventQueue<Ev> = EventQueue::with_capacity(n * 4);
    let mut timeout_timer: Vec<Option<TimerId>> = Vec::with_capacity(n);
    for r in requests {
        q.push(r.arrival_ms, Ev::Arrival(r.id));
        timeout_timer.push(Some(q.push_cancelable(r.timeout_ms, Ev::Timeout(r.id))));
    }
    let mut retry_timer: Vec<Option<TimerId>> = vec![None; n];
    let mut retry_attempts = vec![0u32; n];

    // One action buffer for the whole run: the scheduler appends, the
    // apply loop drains, and `clear` keeps the capacity. The serial fabric
    // batches Sends to the pool (one `submit_batch` per contiguous run of
    // Sends), reusing its two buffers for the whole run.
    let mut actions: Vec<Action> = Vec::new();
    let mut fabric = SerialFabric::new(provider);
    let mut st = LoopState {
        base: 0,
        tenant_base: 0,
        status: &mut status,
        latency: &mut latency,
        defer_counts: &mut defer_counts,
        timeout_timer: &mut timeout_timer,
        retry_timer: &mut retry_timer,
        retry_attempts: &mut retry_attempts,
        sends_by_tenant: &mut sends_by_tenant,
        sends: 0,
        peak_inflight: 0,
        timers_canceled: 0,
        retries_scheduled: 0,
    };

    while let Some((now, ev)) = q.pop() {
        process_tick(
            now,
            ev,
            requests,
            priors,
            owner,
            schedulers,
            &mut st,
            &mut q,
            &mut actions,
            &mut fabric,
        );
    }

    let (sends, peak_inflight, timers_canceled) = (st.sends, st.peak_inflight, st.timers_canceled);
    let retries_scheduled = st.retries_scheduled;
    let (mean_queue_depth, peak_queue_depth) = fabric.fold.finish();
    let ordering_select_work = schedulers.iter().map(|s| s.ordering_work()).sum();
    let ordering_group_count = schedulers.iter().map(|s| s.ordering_group_count()).sum();
    let ordering_scan_fallbacks = schedulers.iter().map(|s| s.ordering_scan_fallbacks()).sum();

    CoreRun {
        status,
        latency,
        defer_counts,
        sends,
        sends_by_tenant,
        peak_inflight,
        timers_canceled,
        events_processed: q.processed(),
        events_skipped: q.skipped(),
        mean_queue_depth,
        peak_queue_depth,
        ordering_select_work,
        ordering_group_count,
        ordering_scan_fallbacks,
        retries_scheduled,
    }
}

/// Build per-request outcome records for a (slice of a) request table.
/// Request ids are global indices into the core arrays, so tenant slices
/// work unchanged.
fn build_outcomes(requests: &[Request], core: &CoreRun) -> Vec<RequestOutcome> {
    requests
        .iter()
        .map(|r| RequestOutcome {
            id: r.id,
            bucket: r.true_bucket,
            class: r.true_bucket.class(),
            arrival_ms: r.arrival_ms,
            deadline_ms: r.deadline_ms,
            status: core.status[r.id],
            latency_ms: core.latency[r.id],
            defer_count: core.defer_counts[r.id],
        })
        .collect()
}

/// Reconcile a scheduler's fleet view with the pool actually running: shard
/// count and (when not explicitly set) advertised weights come from
/// `pool_cfg`; the selection policy stays the client's choice.
fn reconcile_shards(sched_cfg: &mut SchedulerCfg, pool_cfg: &PoolCfg) {
    sched_cfg.shards.n = pool_cfg.n_shards();
    if sched_cfg.shards.weights.len() != pool_cfg.n_shards() {
        sched_cfg.shards.weights =
            if pool_cfg.n_shards() == 1 { Vec::new() } else { pool_cfg.client_weights() };
    }
}

/// Simulate one run to completion against a sharded provider pool.
///
/// `prior_source` is consulted once per request, in arrival order, before
/// the run starts — priors are a pure function of the request, so
/// precomputing preserves semantics while letting the PJRT-backed source
/// batch its kernel invocations.
pub fn run_pool(
    requests: &[Request],
    prior_source: &mut dyn PriorSource,
    sched_cfg: SchedulerCfg,
    pool_cfg: &PoolCfg,
    seed: u64,
) -> RunOutput {
    run_pool_partitioned(
        requests,
        prior_source,
        sched_cfg,
        pool_cfg,
        seed,
        crate::sim::partition::default_partitions(),
    )
}

/// [`run_pool`] with an explicit partition count for the event loop.
///
/// Single-tenant runs partition by carving **contiguous request-id
/// ranges** across workers — available exactly when the scheduler stack is
/// request-local ([`SchedulerCfg::request_local`]); stateful stacks take
/// the flagged serial fallback
/// (`FallbackReason::StatefulCarve`). Outputs are bit-identical to the
/// serial loop either way; `RunOutput::partition` records what actually
/// ran. `partitions == 0` means one partition per core.
pub fn run_pool_partitioned(
    requests: &[Request],
    prior_source: &mut dyn PriorSource,
    mut sched_cfg: SchedulerCfg,
    pool_cfg: &PoolCfg,
    seed: u64,
    partitions: usize,
) -> RunOutput {
    reconcile_shards(&mut sched_cfg, pool_cfg);
    let mut schedulers = vec![ClientScheduler::new(sched_cfg)];
    let mut provider = ProviderPool::new(pool_cfg, Rng::new(seed).derive("provider"));
    let priors: Vec<(Priors, Route)> = requests.iter().map(|r| prior_source.priors(r)).collect();
    let owner = vec![0u32; requests.len()];
    let ranges = [(0usize, requests.len())];

    let (core, partition) = crate::sim::partition::run_core_partitioned(
        requests,
        &priors,
        &owner,
        &ranges,
        &mut schedulers,
        &mut provider,
        pool_cfg,
        partitions,
        crate::sim::partition::WindowBound::Dynamic,
    );

    let outcomes = build_outcomes(requests, &core);
    let scheduler = &schedulers[0];
    let metrics = compute(
        &outcomes,
        scheduler.controller().defers_by_bucket,
        scheduler.controller().rejects_by_bucket,
        scheduler.feasibility_violations(),
    );
    RunOutput {
        metrics,
        outcomes,
        diagnostics: RunDiagnostics {
            events_processed: core.events_processed,
            events_skipped: core.events_skipped,
            timers_canceled: core.timers_canceled,
            sends: core.sends,
            peak_provider_queue: provider.peak_hidden_queue(),
            peak_inflight: core.peak_inflight,
            started_by_shard: provider.started_by_shard(),
            mean_queue_depth: core.mean_queue_depth,
            peak_queue_depth: core.peak_queue_depth,
            ordering_select_work: core.ordering_select_work,
            ordering_group_count: core.ordering_group_count,
            ordering_scan_fallbacks: core.ordering_scan_fallbacks,
            retries_scheduled: core.retries_scheduled,
            faulted_shard_ms: provider.faulted_shard_ms(),
        },
        partition,
    }
}

/// One tenant of a multi-tenant run: its own workload stream, scheduler
/// configuration (including the shard-selection policy), and information
/// condition. The driver derives the tenant's RNG streams and builds its
/// analytic prior source internally.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    /// The tenant's own arrival stream.
    pub workload: WorkloadSpec,
    /// The tenant's scheduler stack (including shard policy).
    pub sched: SchedulerCfg,
    /// Information condition for the tenant's prior source.
    pub info: InfoLevel,
    /// Multiplicative prior-noise level L (§4.10) wrapped around the
    /// ladder source; `0.0` leaves the ladder unwrapped — bit-identical to
    /// every pre-noise tenant run.
    pub noise: f64,
}

/// One tenant's slice of a multi-tenant run.
pub struct TenantOutput {
    /// The tenant's own aggregate metrics.
    pub metrics: RunMetrics,
    /// Outcome ids are *global* (offset by the preceding tenants' counts).
    pub outcomes: Vec<RequestOutcome>,
    /// Submissions this tenant completed.
    pub sends: u64,
}

/// Outcome bundle of one multi-tenant run.
pub struct MultiRunOutput {
    /// Per-tenant slices, in spec order.
    pub tenants: Vec<TenantOutput>,
    /// Engine-level diagnostics for the whole run. `peak_inflight` is the
    /// max over tenants of a tenant's own in-flight count (each client
    /// paces only itself); `sends`/`started_by_shard` are fleet-wide.
    /// Identical regardless of partition count — the partitioned
    /// executor's merge contract (`tests/partition_equivalence.rs`).
    pub diagnostics: RunDiagnostics,
    /// Partitioned-execution accounting (window/barrier/mailbox counters).
    /// `partitions == 1` for serial runs; never affects `diagnostics`.
    pub partition: crate::sim::partition::PartitionStats,
}

/// Workload/prior seed for tenant `t` of a run. Tenant 0 uses the run seed
/// verbatim — the bit-compat contract: a 1-tenant [`run_tenants`] consumes
/// exactly the RNG streams [`run_pool`] consumes. Later tenants derive
/// independent streams, so adding a tenant never perturbs existing ones.
pub fn tenant_seed(seed: u64, t: usize) -> u64 {
    if t == 0 {
        seed
    } else {
        Rng::new(seed).derive(&format!("tenant{t}")).next_u64()
    }
}

/// Split `total` offered requests across `tenants` with the fleet-wide
/// total conserved exactly: the first `total % tenants` tenants carry one
/// extra request. (A plain `total / tenants` silently drops the remainder —
/// and a `.max(1)` rounds *up* when `tenants > total` — so recorded request
/// counts would misstate the actual offered load.) Shared by the bench
/// tenant leg, the `tenants` experiment, and the serve demo so all three
/// mean the same thing by "the same total load split across M tenants".
pub fn split_requests(total: usize, tenants: usize) -> Vec<usize> {
    assert!(tenants >= 1, "need at least one tenant");
    let base = total / tenants;
    let rem = total % tenants;
    (0..tenants).map(|t| base + usize::from(t < rem)).collect()
}

/// Simulate M independent client schedulers sharing one provider pool.
///
/// Each tenant generates its own request table on its own derived stream
/// (ids are remapped into one global space, tenant-major), consults its own
/// analytic prior source in arrival order, and runs its own scheduler; the
/// pool — and therefore all cross-tenant interference — is shared. The
/// provider stream is the same `derive("provider")` stream `run_pool`
/// uses, so the fleet physics are identical across tenant counts.
///
/// # Example
///
/// Two tenants with different strategies contending on a 2-shard fleet:
///
/// ```
/// use blackbox_sched::predictor::InfoLevel;
/// use blackbox_sched::provider::pool::PoolCfg;
/// use blackbox_sched::provider::ProviderCfg;
/// use blackbox_sched::scheduler::{SchedulerCfg, StrategyKind};
/// use blackbox_sched::sim::driver::{run_tenants, TenantSpec};
/// use blackbox_sched::workload::{Mix, WorkloadSpec};
///
/// let spec = |strategy| TenantSpec {
///     workload: WorkloadSpec::new(Mix::Balanced, 30, 6.0),
///     sched: SchedulerCfg::for_strategy(strategy),
///     info: InfoLevel::Coarse,
///     noise: 0.0,
/// };
/// let pool = PoolCfg::split(ProviderCfg::default(), 2);
/// let out = run_tenants(
///     &[spec(StrategyKind::FinalAdrrOlc), spec(StrategyKind::DirectNaive)],
///     &pool,
///     7,
/// );
/// assert_eq!(out.tenants.len(), 2);
/// let offered: usize = out.tenants.iter().map(|t| t.metrics.n_offered).sum();
/// assert_eq!(offered, 60, "every tenant's workload is offered");
/// assert_eq!(out.diagnostics.started_by_shard.len(), 2);
/// ```
pub fn run_tenants(tenants: &[TenantSpec], pool_cfg: &PoolCfg, seed: u64) -> MultiRunOutput {
    run_tenants_partitioned(tenants, pool_cfg, seed, crate::sim::partition::default_partitions())
}

/// [`run_tenants`] with an explicit partition count for the event loop.
///
/// `partitions == 1` is the serial reference loop (exactly [`run_tenants`]
/// with the default environment); `partitions >= 2` carves the tenants into
/// that many contiguous groups and runs one event loop per group in
/// parallel under conservative time-window synchronization — see
/// [`crate::sim::partition`] for the protocol and the bit-compat contract
/// (outputs are bit-identical to serial). `partitions == 0` means one
/// partition per core. The effective count is capped by the tenant count
/// (except single-tenant request-local runs, which carve request-id
/// ranges), and impossible configurations fall back to serial —
/// `MultiRunOutput::partition` records what ran and why
/// (`FallbackReason`).
pub fn run_tenants_partitioned(
    tenants: &[TenantSpec],
    pool_cfg: &PoolCfg,
    seed: u64,
    partitions: usize,
) -> MultiRunOutput {
    run_tenants_partitioned_with_bound(
        tenants,
        pool_cfg,
        seed,
        partitions,
        crate::sim::partition::WindowBound::Dynamic,
    )
}

/// [`run_tenants_partitioned`] with an explicit window-bound policy.
///
/// `WindowBound::Dynamic` (what every other entry point uses) negotiates
/// each window's end from the live pool state; `WindowBound::StaticFloor`
/// is the original fixed-floor baseline, kept so tests can assert the
/// dynamic bound executes strictly fewer windows on the same workload
/// while both stay bit-identical to serial.
pub fn run_tenants_partitioned_with_bound(
    tenants: &[TenantSpec],
    pool_cfg: &PoolCfg,
    seed: u64,
    partitions: usize,
    bound: crate::sim::partition::WindowBound,
) -> MultiRunOutput {
    assert!(!tenants.is_empty(), "need at least one tenant");
    let mut all_requests: Vec<Request> = Vec::new();
    let mut priors: Vec<(Priors, Route)> = Vec::new();
    let mut owner: Vec<u32> = Vec::new();
    let mut ranges: Vec<(usize, usize)> = Vec::new();
    let mut schedulers: Vec<ClientScheduler> = Vec::new();
    for (t, spec) in tenants.iter().enumerate() {
        let tseed = tenant_seed(seed, t);
        let offset = all_requests.len();
        let mut reqs = spec.workload.generate(tseed);
        // Same prior-stream conventions every experiment runner uses, on
        // the tenant's own seed: the ladder on `derive("priors")`, the
        // optional noise wrapper on `derive("noise")`. A noise level of 0
        // leaves the ladder unwrapped, so the RNG streams consumed — and
        // therefore every downstream byte — match the pre-noise driver.
        let root = Rng::new(tseed ^ 0x5EED_50_u64);
        let ladder = LadderSource::new(spec.info, root.derive("priors"));
        let mut src: Box<dyn PriorSource> = if spec.noise > 0.0 {
            Box::new(NoisySource::new(ladder, spec.noise, root.derive("noise")))
        } else {
            Box::new(ladder)
        };
        for r in reqs.iter_mut() {
            r.id += offset;
        }
        for r in &reqs {
            priors.push(src.priors(r));
            owner.push(t as u32);
        }
        ranges.push((offset, offset + reqs.len()));
        all_requests.extend(reqs);
        let mut cfg = spec.sched.clone();
        reconcile_shards(&mut cfg, pool_cfg);
        schedulers.push(ClientScheduler::new(cfg));
    }
    let mut provider = ProviderPool::new(pool_cfg, Rng::new(seed).derive("provider"));

    let (core, partition) = crate::sim::partition::run_core_partitioned(
        &all_requests,
        &priors,
        &owner,
        &ranges,
        &mut schedulers,
        &mut provider,
        pool_cfg,
        partitions,
        bound,
    );

    let tenants_out: Vec<TenantOutput> = ranges
        .iter()
        .zip(schedulers.iter())
        .enumerate()
        .map(|(t, (&(lo, hi), sched))| {
            let outcomes = build_outcomes(&all_requests[lo..hi], &core);
            let metrics = compute(
                &outcomes,
                sched.controller().defers_by_bucket,
                sched.controller().rejects_by_bucket,
                sched.feasibility_violations(),
            );
            TenantOutput { metrics, outcomes, sends: core.sends_by_tenant[t] }
        })
        .collect();
    MultiRunOutput {
        tenants: tenants_out,
        diagnostics: RunDiagnostics {
            events_processed: core.events_processed,
            events_skipped: core.events_skipped,
            timers_canceled: core.timers_canceled,
            sends: core.sends,
            peak_provider_queue: provider.peak_hidden_queue(),
            peak_inflight: core.peak_inflight,
            started_by_shard: provider.started_by_shard(),
            mean_queue_depth: core.mean_queue_depth,
            peak_queue_depth: core.peak_queue_depth,
            ordering_select_work: core.ordering_select_work,
            ordering_group_count: core.ordering_group_count,
            ordering_scan_fallbacks: core.ordering_scan_fallbacks,
            retries_scheduled: core.retries_scheduled,
            faulted_shard_ms: provider.faulted_shard_ms(),
        },
        partition,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::RequestStatus;
    use crate::predictor::{InfoLevel, LadderSource};
    use crate::scheduler::{OrderingKind, ShardPolicy, StrategyKind};
    use crate::workload::{Mix, WorkloadSpec};

    fn run_strategy(strategy: StrategyKind, mix: Mix, rate: f64, seed: u64) -> RunOutput {
        let spec = WorkloadSpec::new(mix, 80, rate);
        let requests = spec.generate(seed);
        let mut src = LadderSource::new(InfoLevel::Coarse, Rng::new(seed).derive("priors"));
        run(
            &requests,
            &mut src,
            SchedulerCfg::for_strategy(strategy),
            ProviderCfg::default(),
            seed,
        )
    }

    #[test]
    fn all_requests_reach_terminal_state() {
        for strategy in [
            StrategyKind::DirectNaive,
            StrategyKind::QuotaTiered,
            StrategyKind::AdaptiveDrr,
            StrategyKind::FinalAdrrOlc,
            StrategyKind::FairQueuing,
            StrategyKind::ShortPriority,
        ] {
            let out = run_strategy(strategy, Mix::Balanced, 6.0, 1);
            for o in &out.outcomes {
                assert!(
                    matches!(
                        o.status,
                        RequestStatus::Completed | RequestStatus::Rejected | RequestStatus::TimedOut
                    ),
                    "{strategy:?}: request {} stuck in {:?}",
                    o.id,
                    o.status
                );
            }
            assert_eq!(out.metrics.n_offered, 80, "{strategy:?}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_strategy(StrategyKind::FinalAdrrOlc, Mix::Heavy, 8.0, 3);
        let b = run_strategy(StrategyKind::FinalAdrrOlc, Mix::Heavy, 8.0, 3);
        assert_eq!(a.metrics.n_completed, b.metrics.n_completed);
        assert_eq!(a.metrics.rejects_total, b.metrics.rejects_total);
        assert!((a.metrics.global_p95_ms - b.metrics.global_p95_ms).abs() < 1e-12);
        for (x, y) in a.outcomes.iter().zip(b.outcomes.iter()) {
            assert_eq!(x.status, y.status);
            assert_eq!(x.latency_ms, y.latency_ms);
        }
        assert_eq!(a.diagnostics.events_processed, b.diagnostics.events_processed);
        assert_eq!(a.diagnostics.timers_canceled, b.diagnostics.timers_canceled);
    }

    #[test]
    fn low_load_completes_everything() {
        let out = run_strategy(StrategyKind::FinalAdrrOlc, Mix::Balanced, 1.0, 5);
        assert_eq!(out.metrics.completion_rate, 1.0);
        assert_eq!(out.metrics.n_rejected, 0);
        assert!(out.metrics.satisfaction > 0.95);
    }

    #[test]
    fn completed_requests_cancel_their_timeout_timers() {
        let out = run_strategy(StrategyKind::FinalAdrrOlc, Mix::Balanced, 1.0, 5);
        // Low load: everything completes, so every timeout timer must have
        // been canceled and none of them processed as an event.
        assert_eq!(out.metrics.n_completed, 80);
        assert_eq!(out.diagnostics.timers_canceled, 80);
        // The canceled timers surface at the heap head eventually and are
        // discarded there, not handled.
        assert_eq!(out.diagnostics.events_skipped, 80);
    }

    #[test]
    fn queue_depth_diagnostics_are_sane() {
        let shaped = run_strategy(StrategyKind::AdaptiveDrr, Mix::Heavy, 12.0, 3);
        assert!(shaped.diagnostics.peak_queue_depth > 0, "stressed run must queue");
        assert!(shaped.diagnostics.mean_queue_depth > 0.0);
        assert!(
            shaped.diagnostics.mean_queue_depth <= shaped.diagnostics.peak_queue_depth as f64,
            "mean {} vs peak {}",
            shaped.diagnostics.mean_queue_depth,
            shaped.diagnostics.peak_queue_depth
        );
        // Naive dispatch never queues client-side.
        let naive = run_strategy(StrategyKind::DirectNaive, Mix::Heavy, 12.0, 3);
        assert_eq!(naive.diagnostics.peak_queue_depth, 0);
        assert_eq!(naive.diagnostics.mean_queue_depth, 0.0);
    }

    #[test]
    fn naive_floods_provider() {
        let naive = run_strategy(StrategyKind::DirectNaive, Mix::Heavy, 10.0, 7);
        let shaped = run_strategy(StrategyKind::FinalAdrrOlc, Mix::Heavy, 10.0, 7);
        // Naive pushes far more concurrent work into the provider (paying
        // the slowdown curve); shaped policies pace near their budget.
        assert!(
            naive.diagnostics.peak_inflight > 2 * shaped.diagnostics.peak_inflight,
            "naive={} shaped={}",
            naive.diagnostics.peak_inflight,
            shaped.diagnostics.peak_inflight
        );
    }

    #[test]
    fn shaping_protects_short_tail_under_stress() {
        let naive = run_strategy(StrategyKind::DirectNaive, Mix::Balanced, 10.0, 11);
        let shaped = run_strategy(StrategyKind::FinalAdrrOlc, Mix::Balanced, 10.0, 11);
        assert!(
            shaped.metrics.short_p95_ms < naive.metrics.short_p95_ms,
            "shaped={} naive={}",
            shaped.metrics.short_p95_ms,
            naive.metrics.short_p95_ms
        );
    }

    #[test]
    fn rejects_only_from_final_stack() {
        let adrr = run_strategy(StrategyKind::AdaptiveDrr, Mix::Heavy, 10.0, 13);
        assert_eq!(adrr.metrics.rejects_total, 0, "no OLC → no rejects");
        assert_eq!(adrr.metrics.defers_total, 0);
    }

    fn run_sharded(policy: ShardPolicy, n_shards: usize, skew: f64, seed: u64) -> RunOutput {
        let spec = WorkloadSpec::new(Mix::Balanced, 80, 12.0);
        let requests = spec.generate(seed);
        let mut src = LadderSource::new(InfoLevel::Coarse, Rng::new(seed).derive("priors"));
        let mut cfg = SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc);
        cfg.shards.policy = policy;
        let pool = PoolCfg::heterogeneous(ProviderCfg::default(), n_shards, skew);
        run_pool(&requests, &mut src, cfg, &pool, seed)
    }

    #[test]
    fn sharded_runs_terminate_and_are_deterministic() {
        for policy in ShardPolicy::ALL {
            let a = run_sharded(policy, 4, 0.4, 2);
            let b = run_sharded(policy, 4, 0.4, 2);
            for (x, y) in a.outcomes.iter().zip(b.outcomes.iter()) {
                assert_eq!(x.status, y.status, "{policy:?}");
                assert_eq!(x.latency_ms, y.latency_ms, "{policy:?}");
            }
            assert_eq!(a.metrics.n_offered, 80, "{policy:?}");
            for o in &a.outcomes {
                assert!(
                    matches!(
                        o.status,
                        RequestStatus::Completed | RequestStatus::Rejected | RequestStatus::TimedOut
                    ),
                    "{policy:?}: request {} stuck in {:?}",
                    o.id,
                    o.status
                );
            }
            // Every submitted request eventually starts (hidden queues
            // drain through promotions), and every shard sees traffic
            // under load-aware policies.
            let by_shard = &a.diagnostics.started_by_shard;
            assert_eq!(by_shard.len(), 4, "{policy:?}");
            assert_eq!(by_shard.iter().sum::<u64>(), a.diagnostics.sends, "{policy:?}");
            if policy != ShardPolicy::HashAffinity {
                assert!(by_shard.iter().all(|&c| c > 0), "{policy:?}: starved shard {by_shard:?}");
            }
        }
    }

    #[test]
    fn one_shard_pool_matches_bare_run_exactly() {
        // `run` is the 1-shard pool path; an explicitly-built single-shard
        // PoolCfg through `run_pool` must be indistinguishable from it,
        // whatever the configured policy (the selector fast-path).
        let spec = WorkloadSpec::new(Mix::Heavy, 60, 10.0);
        let requests = spec.generate(4);
        let mk_src = || LadderSource::new(InfoLevel::Coarse, Rng::new(4).derive("priors"));
        let base = run(
            &requests,
            &mut mk_src(),
            SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc),
            ProviderCfg::default(),
            4,
        );
        for policy in ShardPolicy::ALL {
            let mut cfg = SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc);
            cfg.shards.policy = policy;
            let pool = PoolCfg::single(ProviderCfg::default());
            let pooled = run_pool(&requests, &mut mk_src(), cfg, &pool, 4);
            assert_eq!(base.metrics.n_completed, pooled.metrics.n_completed);
            assert_eq!(base.diagnostics.events_processed, pooled.diagnostics.events_processed);
            for (x, y) in base.outcomes.iter().zip(pooled.outcomes.iter()) {
                assert_eq!(x.status, y.status);
                assert_eq!(
                    x.latency_ms.map(f64::to_bits),
                    y.latency_ms.map(f64::to_bits),
                    "latency bits must match"
                );
            }
        }
    }

    #[test]
    fn recalibration_on_point_priors_is_bit_exact_with_off() {
        // The "disabled == static source" contract at driver level: oracle
        // priors have width 0, so the recalibrator's multiplier scales a
        // zero interval and never moves a key — even under the
        // width-consuming robust_sjf ordering. Turning it on must be
        // invisible bit-for-bit, which is what lets `recalibrate` default
        // off without forking any existing CSV.
        let spec = WorkloadSpec::new(Mix::Heavy, 60, 10.0);
        let requests = spec.generate(8);
        let mk_src = || LadderSource::new(InfoLevel::Oracle, Rng::new(8).derive("priors"));
        let mut on = SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc);
        on.heavy_ordering = OrderingKind::RobustSjf;
        on.recalibrate = true;
        let mut off = on.clone();
        off.recalibrate = false;
        let a = run(&requests, &mut mk_src(), on, ProviderCfg::default(), 8);
        let b = run(&requests, &mut mk_src(), off, ProviderCfg::default(), 8);
        assert_eq!(a.metrics.n_completed, b.metrics.n_completed);
        assert_eq!(a.metrics.rejects_total, b.metrics.rejects_total);
        assert_eq!(a.metrics.global_p95_ms.to_bits(), b.metrics.global_p95_ms.to_bits());
        assert_eq!(a.diagnostics.events_processed, b.diagnostics.events_processed);
        assert_eq!(a.diagnostics.ordering_select_work, b.diagnostics.ordering_select_work);
        for (x, y) in a.outcomes.iter().zip(b.outcomes.iter()) {
            assert_eq!(x.status, y.status);
            assert_eq!(x.latency_ms.map(f64::to_bits), y.latency_ms.map(f64::to_bits));
        }
    }

    #[test]
    fn recalibration_under_interval_priors_is_deterministic() {
        // With coarse (nonzero-width) priors and robust_sjf consuming the
        // widths, the recalibrator's feedback loop runs through completions
        // inside the event loop. Two identical runs must stay bitwise
        // equal: the multiplier state is a pure function of the event
        // sequence, never of wall clock or iteration order.
        let spec = WorkloadSpec::new(Mix::Heavy, 60, 10.0);
        let requests = spec.generate(9);
        let mk_src = || LadderSource::new(InfoLevel::Coarse, Rng::new(9).derive("priors"));
        let mut cfg = SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc);
        cfg.heavy_ordering = OrderingKind::RobustSjf;
        cfg.recalibrate = true;
        let a = run(&requests, &mut mk_src(), cfg.clone(), ProviderCfg::default(), 9);
        let b = run(&requests, &mut mk_src(), cfg, ProviderCfg::default(), 9);
        assert_eq!(a.metrics.n_completed, b.metrics.n_completed);
        assert_eq!(a.diagnostics.events_processed, b.diagnostics.events_processed);
        for (x, y) in a.outcomes.iter().zip(b.outcomes.iter()) {
            assert_eq!(x.status, y.status);
            assert_eq!(x.latency_ms.map(f64::to_bits), y.latency_ms.map(f64::to_bits));
        }
    }

    fn tenant_spec(mix: Mix, n: usize, rate: f64, strategy: StrategyKind) -> TenantSpec {
        TenantSpec {
            workload: WorkloadSpec::new(mix, n, rate),
            sched: SchedulerCfg::for_strategy(strategy),
            info: InfoLevel::Coarse,
            noise: 0.0,
        }
    }

    #[test]
    fn one_tenant_run_matches_run_pool_bitwise() {
        // The structural contract: a 1-tenant run consumes the base RNG
        // streams verbatim and shares run_pool's event loop, so outputs are
        // byte-identical (the full sweep lives in tests/tenant_equivalence).
        let seed = 6u64;
        let spec = WorkloadSpec::new(Mix::Balanced, 60, 12.0);
        let requests = spec.generate(seed);
        let mut src =
            LadderSource::new(InfoLevel::Coarse, Rng::new(seed ^ 0x5EED_50_u64).derive("priors"));
        let pool = PoolCfg::split(ProviderCfg::default(), 2);
        let base = run_pool(
            &requests,
            &mut src,
            SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc),
            &pool,
            seed,
        );
        let multi = run_tenants(
            &[tenant_spec(Mix::Balanced, 60, 12.0, StrategyKind::FinalAdrrOlc)],
            &pool,
            seed,
        );
        assert_eq!(multi.tenants.len(), 1);
        let t0 = &multi.tenants[0];
        assert_eq!(t0.metrics.n_completed, base.metrics.n_completed);
        assert_eq!(t0.metrics.rejects_total, base.metrics.rejects_total);
        assert_eq!(t0.metrics.global_p95_ms.to_bits(), base.metrics.global_p95_ms.to_bits());
        for (x, y) in t0.outcomes.iter().zip(base.outcomes.iter()) {
            assert_eq!(x.status, y.status);
            assert_eq!(x.latency_ms.map(f64::to_bits), y.latency_ms.map(f64::to_bits));
        }
        assert_eq!(multi.diagnostics.events_processed, base.diagnostics.events_processed);
        assert_eq!(multi.diagnostics.sends, base.diagnostics.sends);
        assert_eq!(t0.sends, base.diagnostics.sends);
    }

    #[test]
    fn multi_tenant_run_is_deterministic_and_conserving() {
        let specs = vec![
            tenant_spec(Mix::Balanced, 40, 8.0, StrategyKind::FinalAdrrOlc),
            tenant_spec(Mix::Heavy, 30, 5.0, StrategyKind::QuotaTiered),
            tenant_spec(Mix::Balanced, 20, 4.0, StrategyKind::DirectNaive),
        ];
        let pool = PoolCfg::split(ProviderCfg::default(), 4);
        let a = run_tenants(&specs, &pool, 3);
        let b = run_tenants(&specs, &pool, 3);
        assert_eq!(a.tenants.len(), 3);
        let mut gid = 0usize;
        for (ta, tb) in a.tenants.iter().zip(b.tenants.iter()) {
            assert_eq!(ta.metrics.n_completed, tb.metrics.n_completed);
            for (x, y) in ta.outcomes.iter().zip(tb.outcomes.iter()) {
                assert_eq!(x.status, y.status);
                assert_eq!(x.latency_ms.map(f64::to_bits), y.latency_ms.map(f64::to_bits));
                assert_eq!(x.id, gid, "outcome ids are global and contiguous");
                gid += 1;
                assert!(
                    matches!(
                        x.status,
                        RequestStatus::Completed | RequestStatus::Rejected | RequestStatus::TimedOut
                    ),
                    "request {} stuck in {:?}",
                    x.id,
                    x.status
                );
            }
        }
        assert_eq!(a.tenants.iter().map(|t| t.metrics.n_offered).sum::<usize>(), 90);
        assert_eq!(a.tenants.iter().map(|t| t.sends).sum::<u64>(), a.diagnostics.sends);
        assert_eq!(
            a.diagnostics.started_by_shard.iter().sum::<u64>(),
            a.diagnostics.sends,
            "every send eventually starts on some shard"
        );
    }

    #[test]
    fn split_requests_conserves_totals() {
        for (total, tenants) in [(40, 2), (41, 2), (10, 16), (0, 3), (7, 7), (100, 8)] {
            let counts = split_requests(total, tenants);
            assert_eq!(counts.len(), tenants);
            assert_eq!(counts.iter().sum::<usize>(), total, "{total}/{tenants}");
            // Max spread of 1: "even" means even.
            let (min, max) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
            assert!(max - min <= 1, "{total}/{tenants}: {counts:?}");
        }
    }

    #[test]
    fn tenant_streams_are_independent() {
        // Adding a tenant must not perturb tenant 0's workload: its request
        // table is a pure function of the run seed.
        let w1 = tenant_seed(9, 1);
        let w2 = tenant_seed(9, 2);
        assert_eq!(tenant_seed(9, 0), 9, "tenant 0 is the base stream");
        assert_ne!(w1, w2);
        assert_ne!(w1, 9);
        let spec = WorkloadSpec::new(Mix::Balanced, 20, 6.0);
        let a = spec.generate(tenant_seed(9, 1));
        let b = spec.generate(tenant_seed(9, 2));
        assert!(a.iter().zip(b.iter()).any(|(x, y)| x.true_output_tokens != y.true_output_tokens));
    }

    #[test]
    fn retry_and_fault_counters_are_zero_on_clean_runs() {
        // Retries default off and the pool has no fault plan: both new
        // diagnostics must be exactly zero (the bit-compat baseline every
        // pre-storms CSV rides on).
        let out = run_strategy(StrategyKind::FinalAdrrOlc, Mix::Heavy, 10.0, 7);
        assert_eq!(out.diagnostics.retries_scheduled, 0);
        assert_eq!(out.diagnostics.faulted_shard_ms, 0.0);
    }

    fn blackout_run(failover: bool, max_attempts: u32, seed: u64) -> RunOutput {
        use crate::provider::fault::FaultPlan;
        use crate::scheduler::RetryCfg;
        // Load chosen so the surviving shard alone absorbs everything
        // within the SLO timeouts; the blackout outlives every timeout
        // budget, so work stranded on shard 0 is guaranteed to time out.
        let spec = WorkloadSpec::new(Mix::Balanced, 40, 1.5);
        let requests = spec.generate(seed);
        let mut src = LadderSource::new(InfoLevel::Coarse, Rng::new(seed).derive("priors"));
        let mut cfg = SchedulerCfg::for_strategy(StrategyKind::AdaptiveDrr);
        cfg.shards.policy = ShardPolicy::LeastInflight;
        cfg.shards.failover = failover;
        cfg.retry = RetryCfg::new(max_attempts, 250.0, 2_000.0);
        let pool = PoolCfg::split(ProviderCfg::default(), 2)
            .with_faults(FaultPlan::default().blackout(0, 0.0, 600_000.0).unwrap());
        run_pool(&requests, &mut src, cfg, &pool, seed)
    }

    #[test]
    fn blackout_failover_with_retries_completes_what_the_ablation_loses() {
        // The storms acceptance scenario. Full stack: the first casualties
        // saturate shard 0's censored tail, previews re-route to the
        // surviving shard, and the casualties' own retries come back on it
        // — every surviving-shard-feasible request completes. Ablation
        // (failover off): abandoned attempts leave the dead shard looking
        // idle, least-inflight keeps resubmitting into it, and budgets
        // exhaust into terminal timeouts.
        let full = blackout_run(true, 6, 21);
        let ablated = blackout_run(false, 6, 21);
        assert_eq!(
            full.metrics.n_completed, full.metrics.n_offered,
            "full stack must complete everything the surviving shard can serve"
        );
        assert!(full.diagnostics.retries_scheduled > 0, "casualties must have retried");
        assert!(full.diagnostics.faulted_shard_ms > 0.0);
        assert!(
            ablated.metrics.n_completed < full.metrics.n_completed,
            "ablation {} vs full {}",
            ablated.metrics.n_completed,
            full.metrics.n_completed
        );
    }

    #[test]
    fn retry_storms_terminate_within_budget() {
        // Exhausted budgets must surface as terminal states, never as live
        // events: the run drains with every request settled and the retry
        // count bounded by n_requests × max_attempts, and the whole storm
        // is deterministic.
        let a = blackout_run(false, 3, 5);
        let b = blackout_run(false, 3, 5);
        for o in &a.outcomes {
            assert!(
                matches!(
                    o.status,
                    RequestStatus::Completed | RequestStatus::Rejected | RequestStatus::TimedOut
                ),
                "request {} stuck in {:?}",
                o.id,
                o.status
            );
        }
        assert!(a.diagnostics.retries_scheduled > 0);
        assert!(a.diagnostics.retries_scheduled <= 40 * 3);
        assert_eq!(a.diagnostics.retries_scheduled, b.diagnostics.retries_scheduled);
        assert_eq!(a.diagnostics.events_processed, b.diagnostics.events_processed);
        for (x, y) in a.outcomes.iter().zip(b.outcomes.iter()) {
            assert_eq!(x.status, y.status);
            assert_eq!(x.latency_ms.map(f64::to_bits), y.latency_ms.map(f64::to_bits));
        }
    }

    #[test]
    fn shorts_never_rejected_by_final() {
        for seed in 0..5 {
            let out = run_strategy(StrategyKind::FinalAdrrOlc, Mix::Heavy, 12.0, seed);
            assert_eq!(out.metrics.rejects_by_bucket[0], 0, "seed {seed}");
            for o in &out.outcomes {
                if o.bucket == crate::core::TokenBucket::Short {
                    assert_ne!(o.status, RequestStatus::Rejected);
                }
            }
        }
    }
}
