//! Virtual-time run driver: wires workload → scheduler → mock provider on
//! the discrete-event engine and produces per-request outcomes.
//!
//! The driver is the only component that sees both sides of the black-box
//! boundary: it hands the scheduler nothing but arrival/completion events
//! and hands the provider nothing but submissions. All experiment tables
//! are produced by running this driver across seeds/policies/regimes.
//!
//! Hot-path notes: one `Action` buffer is reused for the entire run (the
//! scheduler appends, the driver drains), and every `Timeout`/`Retry`
//! event is a cancelable timer — when a request reaches a terminal state
//! its pending timers are canceled in O(1), so at scale the event heap
//! carries no dead entry per completed request and `events_processed`
//! counts only real work.

use crate::core::{ReqId, Request, RequestStatus};
use crate::metrics::{compute, RequestOutcome, RunMetrics};
use crate::predictor::PriorSource;
use crate::provider::pool::{PoolCfg, ProviderPool};
use crate::provider::{ProviderCfg, Started};
use crate::scheduler::{Action, ClientScheduler, SchedulerCfg};
use crate::sim::{EventQueue, TimerId};
use crate::util::rng::Rng;

/// DES event payloads.
#[derive(Debug, Clone, Copy)]
enum Ev {
    Arrival(ReqId),
    ProviderDone(ReqId),
    Retry(ReqId),
    Timeout(ReqId),
}

/// Extra run diagnostics beyond `RunMetrics`.
#[derive(Debug, Clone, Default)]
pub struct RunDiagnostics {
    /// Live events handled (canceled timers excluded).
    pub events_processed: u64,
    /// Canceled timer entries discarded at the heap head without handling.
    pub events_skipped: u64,
    /// Timers canceled because their request reached a terminal state.
    pub timers_canceled: u64,
    pub sends: u64,
    pub peak_provider_queue: usize,
    pub peak_inflight: usize,
    /// Requests started per provider shard (`vec![n_started]` for the
    /// classic single-endpoint runs) — the fleet balance signal.
    pub started_by_shard: Vec<u64>,
}

/// Outcome bundle of one simulated run.
pub struct RunOutput {
    pub metrics: RunMetrics,
    pub outcomes: Vec<RequestOutcome>,
    pub diagnostics: RunDiagnostics,
}

/// Simulate one run to completion against a single provider endpoint.
///
/// Runs on a degenerate 1-shard [`ProviderPool`], which is bit-identical to
/// the bare `MockProvider` path this driver used before sharding (same RNG
/// stream, same event order) — every pre-pool experiment CSV is preserved.
pub fn run(
    requests: &[Request],
    prior_source: &mut dyn PriorSource,
    sched_cfg: SchedulerCfg,
    provider_cfg: ProviderCfg,
    seed: u64,
) -> RunOutput {
    run_pool(requests, prior_source, sched_cfg, &PoolCfg::single(provider_cfg), seed)
}

/// Submit every batched Send in action order and schedule the completions.
///
/// Called at Send-run boundaries (and at end of tick) so that event-queue
/// push order — and therefore heap tie-breaking — is exactly what
/// per-action submission produced: a `ProviderDone` scheduled by Send #k is
/// pushed before any event a later action pushes.
fn flush_sends(
    provider: &mut ProviderPool,
    batch: &mut Vec<(ReqId, f64, usize)>,
    started: &mut Vec<Started>,
    q: &mut EventQueue<Ev>,
    now: f64,
) {
    if batch.is_empty() {
        return;
    }
    started.clear();
    provider.submit_batch(batch, now, started);
    for s in started.iter() {
        q.push(s.finish_ms, Ev::ProviderDone(s.id));
    }
    batch.clear();
}

/// Simulate one run to completion against a sharded provider pool.
///
/// `prior_source` is consulted once per request, in arrival order, before
/// the run starts — priors are a pure function of the request, so
/// precomputing preserves semantics while letting the PJRT-backed source
/// batch its kernel invocations.
///
/// The scheduler's fleet view is reconciled with the pool actually running:
/// shard count and (when not explicitly set) advertised weights come from
/// `pool_cfg`; the selection policy stays the client's choice.
pub fn run_pool(
    requests: &[Request],
    prior_source: &mut dyn PriorSource,
    mut sched_cfg: SchedulerCfg,
    pool_cfg: &PoolCfg,
    seed: u64,
) -> RunOutput {
    sched_cfg.shards.n = pool_cfg.n_shards();
    if sched_cfg.shards.weights.len() != pool_cfg.n_shards() {
        sched_cfg.shards.weights =
            if pool_cfg.n_shards() == 1 { Vec::new() } else { pool_cfg.client_weights() };
    }
    let mut scheduler = ClientScheduler::new(sched_cfg);
    let mut provider = ProviderPool::new(pool_cfg, Rng::new(seed).derive("provider"));

    let n = requests.len();
    let priors: Vec<_> = requests.iter().map(|r| prior_source.priors(r)).collect();

    let mut status = vec![RequestStatus::Queued; n];
    let mut latency: Vec<Option<f64>> = vec![None; n];
    let mut defer_counts = vec![0u32; n];
    let mut sends = 0u64;
    let mut peak_inflight = 0usize;
    let mut timers_canceled = 0u64;

    let mut q: EventQueue<Ev> = EventQueue::with_capacity(n * 4);
    let mut timeout_timer: Vec<Option<TimerId>> = Vec::with_capacity(n);
    for r in requests {
        q.push(r.arrival_ms, Ev::Arrival(r.id));
        timeout_timer.push(Some(q.push_cancelable(r.timeout_ms, Ev::Timeout(r.id))));
    }
    let mut retry_timer: Vec<Option<TimerId>> = vec![None; n];

    // One action buffer for the whole run: the scheduler appends, the
    // apply loop below drains, and `clear` keeps the capacity. Sends are
    // dispatched to the pool in batches (one `submit_batch` per contiguous
    // run of Sends), reusing the same two buffers for the whole run.
    let mut actions: Vec<Action> = Vec::new();
    let mut send_batch: Vec<(ReqId, f64, usize)> = Vec::new();
    let mut started_buf: Vec<Started> = Vec::new();

    while let Some((now, ev)) = q.pop() {
        actions.clear();
        match ev {
            Ev::Arrival(id) => {
                let (p, route) = priors[id];
                scheduler.on_arrival(&requests[id], p, route, now, &mut actions);
            }
            Ev::ProviderDone(id) => {
                // Promote hidden-queue work first (provider-internal).
                for started in provider.on_finish(id, now) {
                    q.push(started.finish_ms, Ev::ProviderDone(started.id));
                }
                if status[id] == RequestStatus::InFlight {
                    status[id] = RequestStatus::Completed;
                    let lat = now - requests[id].arrival_ms;
                    latency[id] = Some(lat);
                    if let Some(t) = timeout_timer[id].take() {
                        if q.cancel(t) {
                            timers_canceled += 1;
                        }
                    }
                    let budget = requests[id].deadline_ms - requests[id].arrival_ms;
                    scheduler.on_completion(id, lat, budget, now, &mut actions);
                }
                // TimedOut → client already abandoned; completion is unobserved.
            }
            Ev::Retry(id) => {
                retry_timer[id] = None;
                if status[id] == RequestStatus::Deferred {
                    status[id] = RequestStatus::Queued;
                    scheduler.on_retry_due(id, now, &mut actions);
                }
            }
            Ev::Timeout(id) => {
                // The timer fired; its slot is already retired by the queue.
                timeout_timer[id] = None;
                if matches!(status[id], RequestStatus::Queued | RequestStatus::Deferred | RequestStatus::InFlight)
                {
                    scheduler.cancel(id, now, &mut actions);
                    status[id] = RequestStatus::TimedOut;
                    if let Some(t) = retry_timer[id].take() {
                        if q.cancel(t) {
                            timers_canceled += 1;
                        }
                    }
                }
            }
        }
        // Apply scheduler actions; sending can cascade (a Send fills a slot;
        // the provider may queue it internally). Contiguous Sends are
        // dispatched as one batch; the batch flushes before any action that
        // pushes an event, preserving per-action event order exactly.
        for a in &actions {
            match *a {
                Action::Send { id, shard } => {
                    debug_assert_eq!(status[id], RequestStatus::Queued, "send of non-queued {id}");
                    status[id] = RequestStatus::InFlight;
                    sends += 1;
                    peak_inflight = peak_inflight.max(scheduler.state().inflight());
                    send_batch.push((id, requests[id].true_output_tokens as f64, shard));
                }
                Action::Retry { id, at_ms } => {
                    flush_sends(&mut provider, &mut send_batch, &mut started_buf, &mut q, now);
                    status[id] = RequestStatus::Deferred;
                    defer_counts[id] += 1;
                    retry_timer[id] = Some(q.push_cancelable(at_ms, Ev::Retry(id)));
                }
                Action::Reject { id } => {
                    status[id] = RequestStatus::Rejected;
                    if let Some(t) = timeout_timer[id].take() {
                        if q.cancel(t) {
                            timers_canceled += 1;
                        }
                    }
                }
            }
        }
        flush_sends(&mut provider, &mut send_batch, &mut started_buf, &mut q, now);
    }

    let outcomes: Vec<RequestOutcome> = requests
        .iter()
        .map(|r| RequestOutcome {
            id: r.id,
            bucket: r.true_bucket,
            class: r.true_bucket.class(),
            arrival_ms: r.arrival_ms,
            deadline_ms: r.deadline_ms,
            status: status[r.id],
            latency_ms: latency[r.id],
            defer_count: defer_counts[r.id],
        })
        .collect();

    let metrics = compute(
        &outcomes,
        scheduler.controller().defers_by_bucket,
        scheduler.controller().rejects_by_bucket,
        scheduler.feasibility_violations(),
    );
    RunOutput {
        metrics,
        outcomes,
        diagnostics: RunDiagnostics {
            events_processed: q.processed(),
            events_skipped: q.skipped(),
            timers_canceled,
            sends,
            peak_provider_queue: provider.peak_hidden_queue(),
            peak_inflight,
            started_by_shard: provider.started_by_shard(),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::RequestStatus;
    use crate::predictor::{InfoLevel, LadderSource};
    use crate::scheduler::{ShardPolicy, StrategyKind};
    use crate::workload::{Mix, WorkloadSpec};

    fn run_strategy(strategy: StrategyKind, mix: Mix, rate: f64, seed: u64) -> RunOutput {
        let spec = WorkloadSpec::new(mix, 80, rate);
        let requests = spec.generate(seed);
        let mut src = LadderSource::new(InfoLevel::Coarse, Rng::new(seed).derive("priors"));
        run(
            &requests,
            &mut src,
            SchedulerCfg::for_strategy(strategy),
            ProviderCfg::default(),
            seed,
        )
    }

    #[test]
    fn all_requests_reach_terminal_state() {
        for strategy in [
            StrategyKind::DirectNaive,
            StrategyKind::QuotaTiered,
            StrategyKind::AdaptiveDrr,
            StrategyKind::FinalAdrrOlc,
            StrategyKind::FairQueuing,
            StrategyKind::ShortPriority,
        ] {
            let out = run_strategy(strategy, Mix::Balanced, 6.0, 1);
            for o in &out.outcomes {
                assert!(
                    matches!(
                        o.status,
                        RequestStatus::Completed | RequestStatus::Rejected | RequestStatus::TimedOut
                    ),
                    "{strategy:?}: request {} stuck in {:?}",
                    o.id,
                    o.status
                );
            }
            assert_eq!(out.metrics.n_offered, 80, "{strategy:?}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_strategy(StrategyKind::FinalAdrrOlc, Mix::Heavy, 8.0, 3);
        let b = run_strategy(StrategyKind::FinalAdrrOlc, Mix::Heavy, 8.0, 3);
        assert_eq!(a.metrics.n_completed, b.metrics.n_completed);
        assert_eq!(a.metrics.rejects_total, b.metrics.rejects_total);
        assert!((a.metrics.global_p95_ms - b.metrics.global_p95_ms).abs() < 1e-12);
        for (x, y) in a.outcomes.iter().zip(b.outcomes.iter()) {
            assert_eq!(x.status, y.status);
            assert_eq!(x.latency_ms, y.latency_ms);
        }
        assert_eq!(a.diagnostics.events_processed, b.diagnostics.events_processed);
        assert_eq!(a.diagnostics.timers_canceled, b.diagnostics.timers_canceled);
    }

    #[test]
    fn low_load_completes_everything() {
        let out = run_strategy(StrategyKind::FinalAdrrOlc, Mix::Balanced, 1.0, 5);
        assert_eq!(out.metrics.completion_rate, 1.0);
        assert_eq!(out.metrics.n_rejected, 0);
        assert!(out.metrics.satisfaction > 0.95);
    }

    #[test]
    fn completed_requests_cancel_their_timeout_timers() {
        let out = run_strategy(StrategyKind::FinalAdrrOlc, Mix::Balanced, 1.0, 5);
        // Low load: everything completes, so every timeout timer must have
        // been canceled and none of them processed as an event.
        assert_eq!(out.metrics.n_completed, 80);
        assert_eq!(out.diagnostics.timers_canceled, 80);
        // The canceled timers surface at the heap head eventually and are
        // discarded there, not handled.
        assert_eq!(out.diagnostics.events_skipped, 80);
    }

    #[test]
    fn naive_floods_provider() {
        let naive = run_strategy(StrategyKind::DirectNaive, Mix::Heavy, 10.0, 7);
        let shaped = run_strategy(StrategyKind::FinalAdrrOlc, Mix::Heavy, 10.0, 7);
        // Naive pushes far more concurrent work into the provider (paying
        // the slowdown curve); shaped policies pace near their budget.
        assert!(
            naive.diagnostics.peak_inflight > 2 * shaped.diagnostics.peak_inflight,
            "naive={} shaped={}",
            naive.diagnostics.peak_inflight,
            shaped.diagnostics.peak_inflight
        );
    }

    #[test]
    fn shaping_protects_short_tail_under_stress() {
        let naive = run_strategy(StrategyKind::DirectNaive, Mix::Balanced, 10.0, 11);
        let shaped = run_strategy(StrategyKind::FinalAdrrOlc, Mix::Balanced, 10.0, 11);
        assert!(
            shaped.metrics.short_p95_ms < naive.metrics.short_p95_ms,
            "shaped={} naive={}",
            shaped.metrics.short_p95_ms,
            naive.metrics.short_p95_ms
        );
    }

    #[test]
    fn rejects_only_from_final_stack() {
        let adrr = run_strategy(StrategyKind::AdaptiveDrr, Mix::Heavy, 10.0, 13);
        assert_eq!(adrr.metrics.rejects_total, 0, "no OLC → no rejects");
        assert_eq!(adrr.metrics.defers_total, 0);
    }

    fn run_sharded(policy: ShardPolicy, n_shards: usize, skew: f64, seed: u64) -> RunOutput {
        let spec = WorkloadSpec::new(Mix::Balanced, 80, 12.0);
        let requests = spec.generate(seed);
        let mut src = LadderSource::new(InfoLevel::Coarse, Rng::new(seed).derive("priors"));
        let mut cfg = SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc);
        cfg.shards.policy = policy;
        let pool = PoolCfg::heterogeneous(ProviderCfg::default(), n_shards, skew);
        run_pool(&requests, &mut src, cfg, &pool, seed)
    }

    #[test]
    fn sharded_runs_terminate_and_are_deterministic() {
        for policy in ShardPolicy::ALL {
            let a = run_sharded(policy, 4, 0.4, 2);
            let b = run_sharded(policy, 4, 0.4, 2);
            for (x, y) in a.outcomes.iter().zip(b.outcomes.iter()) {
                assert_eq!(x.status, y.status, "{policy:?}");
                assert_eq!(x.latency_ms, y.latency_ms, "{policy:?}");
            }
            assert_eq!(a.metrics.n_offered, 80, "{policy:?}");
            for o in &a.outcomes {
                assert!(
                    matches!(
                        o.status,
                        RequestStatus::Completed | RequestStatus::Rejected | RequestStatus::TimedOut
                    ),
                    "{policy:?}: request {} stuck in {:?}",
                    o.id,
                    o.status
                );
            }
            // Every submitted request eventually starts (hidden queues
            // drain through promotions), and every shard sees traffic
            // under load-aware policies.
            let by_shard = &a.diagnostics.started_by_shard;
            assert_eq!(by_shard.len(), 4, "{policy:?}");
            assert_eq!(by_shard.iter().sum::<u64>(), a.diagnostics.sends, "{policy:?}");
            if policy != ShardPolicy::HashAffinity {
                assert!(by_shard.iter().all(|&c| c > 0), "{policy:?}: starved shard {by_shard:?}");
            }
        }
    }

    #[test]
    fn one_shard_pool_matches_bare_run_exactly() {
        // `run` is the 1-shard pool path; an explicitly-built single-shard
        // PoolCfg through `run_pool` must be indistinguishable from it,
        // whatever the configured policy (the selector fast-path).
        let spec = WorkloadSpec::new(Mix::Heavy, 60, 10.0);
        let requests = spec.generate(4);
        let mk_src = || LadderSource::new(InfoLevel::Coarse, Rng::new(4).derive("priors"));
        let base = run(
            &requests,
            &mut mk_src(),
            SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc),
            ProviderCfg::default(),
            4,
        );
        for policy in ShardPolicy::ALL {
            let mut cfg = SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc);
            cfg.shards.policy = policy;
            let pool = PoolCfg::single(ProviderCfg::default());
            let pooled = run_pool(&requests, &mut mk_src(), cfg, &pool, 4);
            assert_eq!(base.metrics.n_completed, pooled.metrics.n_completed);
            assert_eq!(base.diagnostics.events_processed, pooled.diagnostics.events_processed);
            for (x, y) in base.outcomes.iter().zip(pooled.outcomes.iter()) {
                assert_eq!(x.status, y.status);
                assert_eq!(
                    x.latency_ms.map(f64::to_bits),
                    y.latency_ms.map(f64::to_bits),
                    "latency bits must match"
                );
            }
        }
    }

    #[test]
    fn shorts_never_rejected_by_final() {
        for seed in 0..5 {
            let out = run_strategy(StrategyKind::FinalAdrrOlc, Mix::Heavy, 12.0, seed);
            assert_eq!(out.metrics.rejects_by_bucket[0], 0, "seed {seed}");
            for o in &out.outcomes {
                if o.bucket == crate::core::TokenBucket::Short {
                    assert_ne!(o.status, RequestStatus::Rejected);
                }
            }
        }
    }
}
