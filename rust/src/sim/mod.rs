//! Discrete-event simulation engine.
//!
//! A binary-heap event queue keyed by (time, sequence) — the sequence number
//! makes tie-breaking deterministic, which the five-seed reproducibility of
//! every paper table depends on. The engine is generic over the event
//! payload; the experiment driver (`experiments::driver`) owns the handler
//! loop.
//!
//! Entries come in two flavors: plain events ([`EventQueue::push`]) and
//! cancelable timers ([`EventQueue::push_cancelable`]), which return a
//! generation-stamped [`TimerId`]. Canceling is O(1) lazy deletion: the
//! slot's generation is bumped and the stale heap entry is discarded when
//! it surfaces at the head, without ever invoking the handler or counting
//! toward [`EventQueue::processed`]. At million-request scale this keeps
//! the heap from carrying one dead `Timeout` entry per completed request.

pub mod driver;

use std::cmp::Ordering;
use std::collections::BinaryHeap;

const NIL: u32 = u32::MAX;

/// Handle to a cancelable heap entry. Generation-stamped: once the entry
/// fires or is canceled, the slot's generation advances and this id becomes
/// inert (a late [`EventQueue::cancel`] returns `false` instead of
/// corrupting a reused slot).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TimerId {
    slot: u32,
    gen: u32,
}

/// Heap entry: min-ordered by (time, seq).
struct Entry<E> {
    time: f64,
    seq: u64,
    payload: E,
    /// `Some` for cancelable timers; checked against the slot generation
    /// table at pop time (lazy deletion).
    timer: Option<TimerId>,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap; NaN times are a programmer error.
        other
            .time
            .partial_cmp(&self.time)
            .expect("NaN event time")
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic future-event list.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    next_seq: u64,
    popped: u64,
    skipped: u64,
    /// Current generation per timer slot; an entry is live iff its stamped
    /// generation matches.
    gens: Vec<u32>,
    /// Retired timer slots available for reuse.
    free: Vec<u32>,
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            next_seq: 0,
            popped: 0,
            skipped: 0,
            gens: Vec::new(),
            free: Vec::new(),
        }
    }

    pub fn with_capacity(cap: usize) -> Self {
        EventQueue { heap: BinaryHeap::with_capacity(cap), ..EventQueue::new() }
    }

    /// Schedule `payload` at absolute time `t` (ms).
    pub fn push(&mut self, t: f64, payload: E) {
        debug_assert!(t.is_finite(), "non-finite event time {t}");
        self.heap.push(Entry { time: t, seq: self.next_seq, payload, timer: None });
        self.next_seq += 1;
    }

    /// Schedule a cancelable event at absolute time `t`; the returned
    /// [`TimerId`] cancels it in O(1) via [`EventQueue::cancel`].
    pub fn push_cancelable(&mut self, t: f64, payload: E) -> TimerId {
        debug_assert!(t.is_finite(), "non-finite event time {t}");
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                assert!(self.gens.len() < NIL as usize, "timer slot space exhausted");
                self.gens.push(0);
                (self.gens.len() - 1) as u32
            }
        };
        let id = TimerId { slot, gen: self.gens[slot as usize] };
        self.heap.push(Entry { time: t, seq: self.next_seq, payload, timer: Some(id) });
        self.next_seq += 1;
        id
    }

    /// Cancel a pending cancelable event. Returns `true` if it was still
    /// pending (it will now be silently discarded when it reaches the heap
    /// head); `false` if it already fired or was already canceled.
    pub fn cancel(&mut self, id: TimerId) -> bool {
        let g = &mut self.gens[id.slot as usize];
        if *g == id.gen {
            *g = g.wrapping_add(1);
            self.free.push(id.slot);
            true
        } else {
            false
        }
    }

    fn entry_live(gens: &[u32], e: &Entry<E>) -> bool {
        match e.timer {
            None => true,
            Some(t) => gens[t.slot as usize] == t.gen,
        }
    }

    /// Discard canceled entries sitting at the heap head.
    fn drop_dead_head(&mut self) {
        while let Some(e) = self.heap.peek() {
            if Self::entry_live(&self.gens, e) {
                break;
            }
            self.heap.pop();
            self.skipped += 1;
        }
    }

    /// Pop the earliest live event: `(time, payload)`. Canceled timers are
    /// skipped without counting toward [`EventQueue::processed`].
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.drop_dead_head();
        self.heap.pop().map(|e| {
            if let Some(t) = e.timer {
                // The timer fired: retire the slot so its id is inert and
                // the slot can be reused by a future push_cancelable.
                self.gens[t.slot as usize] = self.gens[t.slot as usize].wrapping_add(1);
                self.free.push(t.slot);
            }
            self.popped += 1;
            (e.time, e.payload)
        })
    }

    /// Earliest live scheduled time without popping.
    pub fn peek_time(&mut self) -> Option<f64> {
        self.drop_dead_head();
        self.heap.peek().map(|e| e.time)
    }

    /// Entries currently in the heap, including canceled timers that have
    /// not yet surfaced at the head.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Total live events processed so far (engine throughput metric).
    pub fn processed(&self) -> u64 {
        self.popped
    }

    /// Canceled entries discarded at the head without being processed.
    pub fn skipped(&self) -> u64 {
        self.skipped
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(5.0, "c");
        q.push(1.0, "a");
        q.push(3.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push(2.0, 1);
        q.push(2.0, 2);
        q.push(2.0, 3);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn peek_and_len() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
        q.push(7.5, ());
        q.push(2.5, ());
        assert_eq!(q.peek_time(), Some(2.5));
        assert_eq!(q.len(), 2);
        q.pop();
        assert_eq!(q.peek_time(), Some(7.5));
        assert_eq!(q.processed(), 1);
    }

    #[test]
    fn canceled_timers_are_skipped_silently() {
        let mut q = EventQueue::new();
        q.push(1.0, "a");
        let t = q.push_cancelable(2.0, "dead");
        q.push(3.0, "b");
        assert!(q.cancel(t));
        assert!(!q.cancel(t), "double cancel is a no-op");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec!["a", "b"]);
        assert_eq!(q.processed(), 2, "canceled entry must not count as processed");
        assert_eq!(q.skipped(), 1);
    }

    #[test]
    fn uncanceled_timer_fires_and_id_goes_inert() {
        let mut q = EventQueue::new();
        let t = q.push_cancelable(1.0, 42);
        assert_eq!(q.pop(), Some((1.0, 42)));
        assert!(!q.cancel(t), "cancel after fire must be a no-op");
    }

    #[test]
    fn timer_slots_are_reused_with_fresh_generations() {
        let mut q = EventQueue::new();
        let t1 = q.push_cancelable(1.0, "x");
        assert!(q.cancel(t1));
        // The freed slot is reused; the stale id must not cancel the new entry.
        let t2 = q.push_cancelable(2.0, "y");
        assert!(!q.cancel(t1));
        assert_eq!(q.pop(), Some((2.0, "y")));
        assert!(!q.cancel(t2));
        assert_eq!(q.skipped(), 1);
    }

    #[test]
    fn peek_time_skips_canceled_head() {
        let mut q = EventQueue::new();
        let t = q.push_cancelable(1.0, ());
        q.push(5.0, ());
        q.cancel(t);
        assert_eq!(q.peek_time(), Some(5.0));
        assert_eq!(q.pop(), Some((5.0, ())));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn interleaved_push_pop_is_globally_monotone() {
        use crate::testing::prop;
        // The DES contract: handlers only ever schedule at or after the
        // current simulated time, so under any interleaving of pushes and
        // pops the popped timestamps must be globally nondecreasing.
        prop::forall(50, |g| {
            let mut q = EventQueue::new();
            let mut now = 0.0_f64;
            let n = g.usize_in(1, 100);
            for _ in 0..n {
                for _ in 0..g.usize_in(1, 4) {
                    q.push(now + g.f64_in(0.0, 1000.0), ());
                }
                if g.bool() {
                    if let Some((t, _)) = q.pop() {
                        assert!(t >= now, "clock went backwards: popped {t} after {now}");
                        now = t;
                    }
                }
            }
            // Drain: still monotone from the last observed time.
            while let Some((t, _)) = q.pop() {
                assert!(t >= now, "drain went backwards: popped {t} after {now}");
                now = t;
            }
        });
    }

    #[test]
    fn prop_cancelation_never_reorders_live_events() {
        use crate::testing::prop;
        // Interleave plain events and cancelable timers, cancel a random
        // subset, and check the surviving pop sequence equals the sorted
        // (time, seq) order of live entries.
        prop::forall(50, |g| {
            let mut q = EventQueue::new();
            let mut live: Vec<(u64, usize)> = Vec::new(); // (time in µs, tag)
            let mut timers = Vec::new();
            let n = g.usize_in(1, 60);
            for tag in 0..n {
                let t_us = g.usize_in(0, 1_000_000) as u64;
                let t = t_us as f64 / 1000.0;
                if g.bool() {
                    timers.push((q.push_cancelable(t, tag), t_us, tag));
                } else {
                    q.push(t, tag);
                    live.push((t_us, tag));
                }
            }
            for (id, t_us, tag) in timers {
                if g.bool() {
                    assert!(q.cancel(id));
                } else {
                    live.push((t_us, tag));
                }
            }
            // Expected order: by time, ties by insertion (tag) order.
            live.sort_by_key(|&(t, tag)| (t, tag));
            let got: Vec<usize> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
            let want: Vec<usize> = live.iter().map(|&(_, tag)| tag).collect();
            assert_eq!(got, want);
        });
    }
}
