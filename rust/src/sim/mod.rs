//! Discrete-event simulation engine.
//!
//! A binary-heap event queue keyed by (time, sequence) — the sequence number
//! makes tie-breaking deterministic, which the five-seed reproducibility of
//! every paper table depends on. The engine is generic over the event
//! payload; the experiment driver (`experiments::driver`) owns the handler
//! loop.

pub mod driver;

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Heap entry: min-ordered by (time, seq).
struct Entry<E> {
    time: f64,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap; NaN times are a programmer error.
        other
            .time
            .partial_cmp(&self.time)
            .expect("NaN event time")
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic future-event list.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    next_seq: u64,
    popped: u64,
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), next_seq: 0, popped: 0 }
    }

    pub fn with_capacity(cap: usize) -> Self {
        EventQueue { heap: BinaryHeap::with_capacity(cap), next_seq: 0, popped: 0 }
    }

    /// Schedule `payload` at absolute time `t` (ms).
    pub fn push(&mut self, t: f64, payload: E) {
        debug_assert!(t.is_finite(), "non-finite event time {t}");
        self.heap.push(Entry { time: t, seq: self.next_seq, payload });
        self.next_seq += 1;
    }

    /// Pop the earliest event: `(time, payload)`.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|e| {
            self.popped += 1;
            (e.time, e.payload)
        })
    }

    /// Earliest scheduled time without popping.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Total events processed so far (engine throughput metric).
    pub fn processed(&self) -> u64 {
        self.popped
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(5.0, "c");
        q.push(1.0, "a");
        q.push(3.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push(2.0, 1);
        q.push(2.0, 2);
        q.push(2.0, 3);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn peek_and_len() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
        q.push(7.5, ());
        q.push(2.5, ());
        assert_eq!(q.peek_time(), Some(2.5));
        assert_eq!(q.len(), 2);
        q.pop();
        assert_eq!(q.peek_time(), Some(7.5));
        assert_eq!(q.processed(), 1);
    }

    #[test]
    fn interleaved_push_pop_stays_ordered() {
        use crate::testing::prop;
        prop::forall(50, |g| {
            let mut q = EventQueue::new();
            let mut last = f64::NEG_INFINITY;
            let n = g.usize_in(1, 100);
            for _ in 0..n {
                for _ in 0..g.usize_in(1, 4) {
                    q.push(g.f64_in(0.0, 1000.0), ());
                }
                if g.bool() {
                    if let Some((t, _)) = q.pop() {
                        // Popped times must be >= any previously popped time
                        // only when no earlier pushes happen later — instead
                        // assert heap property directly: pop ≤ new peek.
                        if let Some(nt) = q.peek_time() {
                            assert!(t <= nt);
                        }
                        let _ = last; // silence unused in release
                        last = t;
                    }
                }
            }
            // Drain: fully sorted.
            let mut prev = f64::NEG_INFINITY;
            while let Some((t, _)) = q.pop() {
                assert!(t >= prev);
                prev = t;
            }
        });
    }
}
