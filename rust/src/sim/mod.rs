//! Discrete-event simulation engine.
//!
//! The future-event list is keyed by (time, sequence) — the sequence number
//! makes tie-breaking deterministic, which the five-seed reproducibility of
//! every paper table depends on. The engine is generic over the event
//! payload; the simulation driver ([`driver`]) owns the handler loop.
//!
//! Two interchangeable backends sit behind the [`EventQueue`] API:
//!
//! * **Hierarchical timer wheel** ([`wheel`], the default): tick-quantized
//!   levels with O(1) schedule/cancel and cascading overflow — the timer
//!   churn of millions of timeout/retry timers costs constant work per
//!   operation instead of the heap's O(log n). Pop order is *exactly* the
//!   reference heap's `(time, seq)` order (see `wheel` for the invariant).
//! * **Binary heap** (the retained reference): the original
//!   `BinaryHeap<(time, seq)>` implementation, selected with
//!   `BBSCHED_EVENT_QUEUE=heap` or [`EventQueue::with_backend`]. Debug
//!   builds of the wheel cross-check every pop against a shadow copy of
//!   this heap (the same discipline as the ordering indexes'
//!   `reference_select`), and `tests/event_queue_wheel.rs` property-tests
//!   the equivalence in release mode.
//!
//! Entries come in two flavors: plain events ([`EventQueue::push`]) and
//! cancelable timers ([`EventQueue::push_cancelable`]), which return a
//! generation-stamped [`TimerId`]. Canceling is O(1) lazy deletion: the
//! slot's generation is bumped and the stale entry is discarded when it
//! surfaces at the head, without ever invoking the handler or counting
//! toward [`EventQueue::processed`]. At million-request scale this keeps
//! the queue from carrying one dead `Timeout` entry per completed request.
#![warn(missing_docs)]

pub mod driver;
pub mod partition;
mod wheel;

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use wheel::TimerWheel;

const NIL: u32 = u32::MAX;

/// Handle to a cancelable queue entry. Generation-stamped: once the entry
/// fires or is canceled, the slot's generation advances and this id becomes
/// inert (a late [`EventQueue::cancel`] returns `false` instead of
/// corrupting a reused slot).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TimerId {
    slot: u32,
    gen: u32,
}

/// Queue entry: min-ordered by (time, seq).
struct Entry<E> {
    time: f64,
    seq: u64,
    payload: E,
    /// `Some` for cancelable timers; checked against the slot generation
    /// table at pop time (lazy deletion).
    timer: Option<TimerId>,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap; NaN times are a programmer error.
        other
            .time
            .partial_cmp(&self.time)
            .expect("NaN event time")
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Which future-event-list implementation an [`EventQueue`] runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Hierarchical timer wheel (the default): O(1) schedule/cancel.
    Wheel,
    /// The retained `BinaryHeap` reference implementation.
    Heap,
}

enum Backend<E> {
    Wheel(TimerWheel<E>),
    Heap(BinaryHeap<Entry<E>>),
}

/// The process-wide default backend: the wheel, unless the reference mode
/// flag `BBSCHED_EVENT_QUEUE=heap` is set in the environment.
fn default_backend() -> BackendKind {
    match std::env::var("BBSCHED_EVENT_QUEUE") {
        Ok(v) if v == "heap" => BackendKind::Heap,
        _ => BackendKind::Wheel,
    }
}

/// Deterministic future-event list.
///
/// # Examples
///
/// Cancelable timers — the driver's timeout pattern: schedule a hard
/// timeout per request, kill it in O(1) when the request completes first.
///
/// ```
/// use blackbox_sched::sim::EventQueue;
///
/// let mut q = EventQueue::new();
/// q.push(3.0, "completion");
/// let timeout = q.push_cancelable(30_000.0, "timeout");
/// assert_eq!(q.peek_time(), Some(3.0));
///
/// // The completion arrives first: cancel the now-moot timeout timer.
/// assert!(q.cancel(timeout));
/// assert!(!q.cancel(timeout), "second cancel is a no-op");
///
/// assert_eq!(q.pop(), Some((3.0, "completion")));
/// assert_eq!(q.pop(), None, "the canceled timer never fires");
/// assert_eq!(q.processed(), 1);
/// assert_eq!(q.skipped(), 1, "the dead timer was discarded, not processed");
/// ```
pub struct EventQueue<E> {
    backend: Backend<E>,
    next_seq: u64,
    popped: u64,
    skipped: u64,
    /// Facade-level operation count (pushes + pops + skips): the heap
    /// backend's "structural work" stand-in, so [`EventQueue::work`] is
    /// meaningful on either backend.
    ops: u64,
    /// Current generation per timer slot; an entry is live iff its stamped
    /// generation matches.
    gens: Vec<u32>,
    /// Retired timer slots available for reuse.
    free: Vec<u32>,
    /// Pop-for-pop cross-check against the reference heap (wheel backend,
    /// debug builds only) — mirrors the PR 5 `reference_select` pattern.
    #[cfg(debug_assertions)]
    mirror: Option<BinaryHeap<Entry<()>>>,
}

impl<E> EventQueue<E> {
    /// An empty queue on the process default backend (the wheel, unless
    /// `BBSCHED_EVENT_QUEUE=heap` selects the reference heap).
    pub fn new() -> Self {
        Self::with_backend(default_backend())
    }

    /// An empty queue on an explicitly chosen backend — the reference heap
    /// for cross-checking, or the wheel regardless of the environment.
    pub fn with_backend(kind: BackendKind) -> Self {
        let backend = match kind {
            BackendKind::Wheel => Backend::Wheel(TimerWheel::new()),
            BackendKind::Heap => Backend::Heap(BinaryHeap::new()),
        };
        EventQueue {
            #[cfg(debug_assertions)]
            mirror: match &backend {
                Backend::Wheel(_) => Some(BinaryHeap::new()),
                Backend::Heap(_) => None,
            },
            backend,
            next_seq: 0,
            popped: 0,
            skipped: 0,
            ops: 0,
            gens: Vec::new(),
            free: Vec::new(),
        }
    }

    /// An empty queue sized for `cap` entries. The heap backend reserves
    /// eagerly; the wheel's slot vectors grow organically (its entries are
    /// spread across 384 slots, so one up-front reservation has no home).
    pub fn with_capacity(cap: usize) -> Self {
        let mut q = Self::new();
        if let Backend::Heap(h) = &mut q.backend {
            h.reserve(cap);
        }
        q
    }

    /// Which backend this queue runs on.
    pub fn backend(&self) -> BackendKind {
        match self.backend {
            Backend::Wheel(_) => BackendKind::Wheel,
            Backend::Heap(_) => BackendKind::Heap,
        }
    }

    /// Schedule `payload` at absolute time `t` (ms).
    pub fn push(&mut self, t: f64, payload: E) {
        debug_assert!(t.is_finite(), "non-finite event time {t}");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.ops += 1;
        #[cfg(debug_assertions)]
        if let Some(m) = &mut self.mirror {
            m.push(Entry { time: t, seq, payload: (), timer: None });
        }
        let e = Entry { time: t, seq, payload, timer: None };
        match &mut self.backend {
            Backend::Wheel(w) => w.push(e),
            Backend::Heap(h) => h.push(e),
        }
    }

    /// Schedule a cancelable event at absolute time `t`; the returned
    /// [`TimerId`] cancels it in O(1) via [`EventQueue::cancel`].
    pub fn push_cancelable(&mut self, t: f64, payload: E) -> TimerId {
        debug_assert!(t.is_finite(), "non-finite event time {t}");
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                assert!(self.gens.len() < NIL as usize, "timer slot space exhausted");
                self.gens.push(0);
                (self.gens.len() - 1) as u32
            }
        };
        let id = TimerId { slot, gen: self.gens[slot as usize] };
        let seq = self.next_seq;
        self.next_seq += 1;
        self.ops += 1;
        #[cfg(debug_assertions)]
        if let Some(m) = &mut self.mirror {
            m.push(Entry { time: t, seq, payload: (), timer: Some(id) });
        }
        let e = Entry { time: t, seq, payload, timer: Some(id) };
        match &mut self.backend {
            Backend::Wheel(w) => w.push(e),
            Backend::Heap(h) => h.push(e),
        }
        id
    }

    /// Cancel a pending cancelable event. Returns `true` if it was still
    /// pending (it will now be silently discarded when it reaches the queue
    /// head); `false` if it already fired or was already canceled.
    pub fn cancel(&mut self, id: TimerId) -> bool {
        let g = &mut self.gens[id.slot as usize];
        if *g == id.gen {
            *g = g.wrapping_add(1);
            self.free.push(id.slot);
            true
        } else {
            false
        }
    }

    fn entry_live<P>(gens: &[u32], e: &Entry<P>) -> bool {
        match e.timer {
            None => true,
            Some(t) => gens[t.slot as usize] == t.gen,
        }
    }

    /// Discard canceled entries sitting at the queue head.
    fn drop_dead_head(&mut self) {
        loop {
            let live = match &mut self.backend {
                Backend::Wheel(w) => match w.peek() {
                    None => return,
                    Some(e) => Self::entry_live(&self.gens, e),
                },
                Backend::Heap(h) => match h.peek() {
                    None => return,
                    Some(e) => Self::entry_live(&self.gens, e),
                },
            };
            if live {
                return;
            }
            match &mut self.backend {
                Backend::Wheel(w) => w.pop(),
                Backend::Heap(h) => h.pop(),
            };
            self.skipped += 1;
            self.ops += 1;
        }
    }

    /// Pop the earliest live event: `(time, payload)`. Canceled timers are
    /// skipped without counting toward [`EventQueue::processed`].
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.drop_dead_head();
        let e = match &mut self.backend {
            Backend::Wheel(w) => w.pop(),
            Backend::Heap(h) => h.pop(),
        }?;
        #[cfg(debug_assertions)]
        if let Some(m) = &mut self.mirror {
            // Pop-for-pop cross-check: the reference heap must surface the
            // same live (time, seq). Dead mirror entries are skipped
            // against the same generation table — before the fired timer's
            // slot is retired below.
            loop {
                let me = m.pop().expect("reference heap exhausted before the wheel");
                if Self::entry_live(&self.gens, &me) {
                    assert!(
                        me.time.to_bits() == e.time.to_bits() && me.seq == e.seq,
                        "wheel diverged from the reference heap: wheel popped (t={}, seq={}), \
                         reference (t={}, seq={})",
                        e.time,
                        e.seq,
                        me.time,
                        me.seq
                    );
                    break;
                }
            }
        }
        if let Some(t) = e.timer {
            // The timer fired: retire the slot so its id is inert and
            // the slot can be reused by a future push_cancelable.
            self.gens[t.slot as usize] = self.gens[t.slot as usize].wrapping_add(1);
            self.free.push(t.slot);
        }
        self.popped += 1;
        self.ops += 1;
        Some((e.time, e.payload))
    }

    /// Earliest live scheduled time without popping.
    pub fn peek_time(&mut self) -> Option<f64> {
        self.drop_dead_head();
        match &mut self.backend {
            Backend::Wheel(w) => w.peek().map(|e| e.time),
            Backend::Heap(h) => h.peek().map(|e| e.time),
        }
    }

    /// Entries currently in the queue, including canceled timers that have
    /// not yet surfaced at the head.
    pub fn len(&self) -> usize {
        match &self.backend {
            Backend::Wheel(w) => w.len(),
            Backend::Heap(h) => h.len(),
        }
    }

    /// True when no entries (live or dead) remain.
    pub fn is_empty(&self) -> bool {
        match &self.backend {
            Backend::Wheel(w) => w.is_empty(),
            Backend::Heap(h) => h.is_empty(),
        }
    }

    /// Total live events processed so far (engine throughput metric).
    pub fn processed(&self) -> u64 {
        self.popped
    }

    /// Canceled entries discarded at the head without being processed.
    pub fn skipped(&self) -> u64 {
        self.skipped
    }

    /// Counted structural work: on the wheel, placements + cascade moves +
    /// clock jumps + due transfers + pops (the `bbsched bench` timer-churn
    /// leg gates this per operation — O(1) amortized means the ratio stays
    /// flat as the queue grows); on the reference heap, the plain operation
    /// count, so the ratio is 1 by construction.
    pub fn work(&self) -> u64 {
        match &self.backend {
            Backend::Wheel(w) => w.work(),
            Backend::Heap(_) => self.ops,
        }
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(5.0, "c");
        q.push(1.0, "a");
        q.push(3.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push(2.0, 1);
        q.push(2.0, 2);
        q.push(2.0, 3);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn peek_and_len() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
        q.push(7.5, ());
        q.push(2.5, ());
        assert_eq!(q.peek_time(), Some(2.5));
        assert_eq!(q.len(), 2);
        q.pop();
        assert_eq!(q.peek_time(), Some(7.5));
        assert_eq!(q.processed(), 1);
    }

    #[test]
    fn canceled_timers_are_skipped_silently() {
        let mut q = EventQueue::new();
        q.push(1.0, "a");
        let t = q.push_cancelable(2.0, "dead");
        q.push(3.0, "b");
        assert!(q.cancel(t));
        assert!(!q.cancel(t), "double cancel is a no-op");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec!["a", "b"]);
        assert_eq!(q.processed(), 2, "canceled entry must not count as processed");
        assert_eq!(q.skipped(), 1);
    }

    #[test]
    fn uncanceled_timer_fires_and_id_goes_inert() {
        let mut q = EventQueue::new();
        let t = q.push_cancelable(1.0, 42);
        assert_eq!(q.pop(), Some((1.0, 42)));
        assert!(!q.cancel(t), "cancel after fire must be a no-op");
    }

    #[test]
    fn timer_slots_are_reused_with_fresh_generations() {
        let mut q = EventQueue::new();
        let t1 = q.push_cancelable(1.0, "x");
        assert!(q.cancel(t1));
        // The freed slot is reused; the stale id must not cancel the new entry.
        let t2 = q.push_cancelable(2.0, "y");
        assert!(!q.cancel(t1));
        assert_eq!(q.pop(), Some((2.0, "y")));
        assert!(!q.cancel(t2));
        assert_eq!(q.skipped(), 1);
    }

    #[test]
    fn peek_time_skips_canceled_head() {
        let mut q = EventQueue::new();
        let t = q.push_cancelable(1.0, ());
        q.push(5.0, ());
        q.cancel(t);
        assert_eq!(q.peek_time(), Some(5.0));
        assert_eq!(q.pop(), Some((5.0, ())));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn same_tick_events_pop_by_exact_time_then_seq() {
        // Sub-tick times inside one wheel tick, pushed out of order, plus
        // exact ties: the (time, seq) contract must survive quantization.
        let mut q = EventQueue::new();
        q.push(4.9, "late");
        q.push(4.1, "early");
        q.push(4.5, "mid-a");
        q.push(4.5, "mid-b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec!["early", "mid-a", "mid-b", "late"]);
    }

    #[test]
    fn fifo_holds_across_the_tick_boundary() {
        // Straddling a tick edge: 4.999... sorts before 5.0 even though
        // they land one tick apart, and events pushed after a pop at the
        // current tick still interleave by exact time.
        let mut q = EventQueue::new();
        q.push(5.0, "b");
        q.push(4.999, "a");
        q.push(5.001, "c");
        assert_eq!(q.pop(), Some((4.999, "a")));
        q.push(5.0005, "b2"); // same tick as the current head, later time
        assert_eq!(q.pop(), Some((5.0, "b")));
        assert_eq!(q.pop(), Some((5.0005, "b2")));
        assert_eq!(q.pop(), Some((5.001, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn cascade_preserves_order_and_cancelation() {
        // 65 and 70 share a level-1 slot from tick 0; popping 65 forces the
        // cascade that re-files 70 at level 0. Canceling it *after* the
        // cascade exercises lazy deletion on a cascaded entry.
        let mut q = EventQueue::new();
        let t = q.push_cancelable(70.0, "timer");
        q.push(65.0, "a");
        q.push(68.0, "b");
        assert_eq!(q.pop(), Some((65.0, "a")));
        assert!(q.cancel(t), "cancelable after cascading down a level");
        assert_eq!(q.pop(), Some((68.0, "b")));
        assert_eq!(q.pop(), None, "canceled cascaded timer never fires");
        assert_eq!(q.skipped(), 1);
    }

    #[test]
    fn far_future_events_overflow_and_return() {
        // Beyond the 2^36-tick wheel horizon: entries park in overflow and
        // re-enter as the clock jumps; order and cancelation still hold.
        let far = 80_000_000_000.0; // ~2.5 model-years in ms
        let mut q = EventQueue::new();
        q.push(far + 7.0, "far-b");
        q.push(5.0, "near");
        let t = q.push_cancelable(far + 3.0, "far-dead");
        q.push(far + 1.0, "far-a");
        assert_eq!(q.pop(), Some((5.0, "near")));
        assert!(q.cancel(t));
        assert_eq!(q.pop(), Some((far + 1.0, "far-a")));
        assert_eq!(q.pop(), Some((far + 7.0, "far-b")));
        assert_eq!(q.pop(), None);
        assert_eq!(q.skipped(), 1);
    }

    #[test]
    fn explicit_backends_agree_on_a_fixed_script() {
        let mut wheel = EventQueue::with_backend(BackendKind::Wheel);
        let mut heap = EventQueue::with_backend(BackendKind::Heap);
        assert_eq!(wheel.backend(), BackendKind::Wheel);
        assert_eq!(heap.backend(), BackendKind::Heap);
        for q in [&mut wheel, &mut heap] {
            q.push(10.0, 0);
            let a = q.push_cancelable(4.0, 1);
            q.push_cancelable(6.5, 2);
            q.push(6.5, 3);
            assert!(q.cancel(a));
        }
        loop {
            let (w, h) = (wheel.pop(), heap.pop());
            assert_eq!(w, h);
            if w.is_none() {
                break;
            }
        }
        assert_eq!(wheel.processed(), heap.processed());
        assert_eq!(wheel.skipped(), heap.skipped());
    }

    #[test]
    fn work_counter_is_positive_and_deterministic() {
        let run = || {
            let mut q = EventQueue::with_backend(BackendKind::Wheel);
            for i in 0..200u64 {
                let t = q.push_cancelable((i * 7 % 311) as f64, i);
                if i % 3 == 0 {
                    q.cancel(t);
                }
            }
            while q.pop().is_some() {}
            q.work()
        };
        let w = run();
        assert!(w > 0, "wheel work must be counted");
        assert_eq!(w, run(), "counted work is deterministic");
    }

    #[test]
    fn interleaved_push_pop_is_globally_monotone() {
        use crate::testing::prop;
        // The DES contract: handlers only ever schedule at or after the
        // current simulated time, so under any interleaving of pushes and
        // pops the popped timestamps must be globally nondecreasing.
        prop::forall(50, |g| {
            let mut q = EventQueue::new();
            let mut now = 0.0_f64;
            let n = g.usize_in(1, 100);
            for _ in 0..n {
                for _ in 0..g.usize_in(1, 4) {
                    q.push(now + g.f64_in(0.0, 1000.0), ());
                }
                if g.bool() {
                    if let Some((t, _)) = q.pop() {
                        assert!(t >= now, "clock went backwards: popped {t} after {now}");
                        now = t;
                    }
                }
            }
            // Drain: still monotone from the last observed time.
            while let Some((t, _)) = q.pop() {
                assert!(t >= now, "drain went backwards: popped {t} after {now}");
                now = t;
            }
        });
    }

    #[test]
    fn prop_cancelation_never_reorders_live_events() {
        use crate::testing::prop;
        // Interleave plain events and cancelable timers, cancel a random
        // subset, and check the surviving pop sequence equals the sorted
        // (time, seq) order of live entries.
        prop::forall(50, |g| {
            let mut q = EventQueue::new();
            let mut live: Vec<(u64, usize)> = Vec::new(); // (time in µs, tag)
            let mut timers = Vec::new();
            let n = g.usize_in(1, 60);
            for tag in 0..n {
                let t_us = g.usize_in(0, 1_000_000) as u64;
                let t = t_us as f64 / 1000.0;
                if g.bool() {
                    timers.push((q.push_cancelable(t, tag), t_us, tag));
                } else {
                    q.push(t, tag);
                    live.push((t_us, tag));
                }
            }
            for (id, t_us, tag) in timers {
                if g.bool() {
                    assert!(q.cancel(id));
                } else {
                    live.push((t_us, tag));
                }
            }
            // Expected order: by time, ties by insertion (tag) order.
            live.sort_by_key(|&(t, tag)| (t, tag));
            let got: Vec<usize> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
            let want: Vec<usize> = live.iter().map(|&(_, tag)| tag).collect();
            assert_eq!(got, want);
        });
    }
}
