//! Sharded provider pool: N independent [`MockProvider`] endpoints behind
//! one submit/finish surface.
//!
//! Real deployments schedule across multiple black-box endpoints with
//! heterogeneous capacity (several API keys, regional deployments, mixed
//! hardware tiers). Each shard keeps the single-provider physics — hidden
//! FIFO, load-dependent slowdown, its own jitter stream — and the pool adds
//! only *routing*: a submission names a shard, and a finish is routed back
//! to the shard that served it. Which shard a request should go to is a
//! **client-side** decision (see `scheduler::shard`); the pool itself never
//! second-guesses the routing, exactly like a real endpoint never steals
//! traffic addressed to a different one.
//!
//! Bit-compat contract: a 1-shard pool is **byte-identical** to a bare
//! [`MockProvider`] — same RNG stream, same state transitions, same
//! `Started` events — so every pre-pool experiment CSV stays valid. This is
//! property-tested in `tests/pool_equivalence.rs`.

use std::collections::{BTreeMap, HashMap};

use crate::core::ReqId;
use crate::provider::fault::FaultPlan;
use crate::provider::{MockProvider, ProviderCfg, Started};
use crate::util::rng::Rng;
use crate::workload::Mix;

/// Pool shape: one `ProviderCfg` per shard, plus an optional deterministic
/// fault schedule. Policy lives client-side (`scheduler::shard::ShardCfg`)
/// — the pool is pure provider physics.
#[derive(Debug, Clone)]
pub struct PoolCfg {
    /// One physics config per endpoint.
    pub shards: Vec<ProviderCfg>,
    /// Scheduled brownouts/blackouts (empty = bit-identical to a fault-free
    /// pool; see [`FaultPlan`]).
    pub faults: FaultPlan,
}

impl PoolCfg {
    /// The degenerate pool every pre-pool experiment runs on.
    pub fn single(cfg: ProviderCfg) -> PoolCfg {
        PoolCfg { shards: vec![cfg], faults: FaultPlan::default() }
    }

    /// `n` identical shards, each carrying `1/n` of the base capacity
    /// (`max_concurrency` and `slowdown_ref` split), so total fleet
    /// capacity stays comparable across shard counts.
    pub fn split(cfg: ProviderCfg, n: usize) -> PoolCfg {
        assert!(n >= 1, "pool needs at least one shard");
        let per = ProviderCfg {
            max_concurrency: (cfg.max_concurrency / n).max(1),
            slowdown_ref: (cfg.slowdown_ref / n as f64).max(1.0),
            ..cfg
        };
        PoolCfg { shards: vec![per; n], faults: FaultPlan::default() }
    }

    /// Like [`PoolCfg::split`], but shard `i`'s service speed is scaled by
    /// a linear spread of ±`skew` around 1 (shard 0 fastest): the
    /// heterogeneous-fleet regime where weighted selection matters.
    pub fn heterogeneous(cfg: ProviderCfg, n: usize, skew: f64) -> PoolCfg {
        assert!((0.0..1.0).contains(&skew), "skew must be in [0,1)");
        let mut pool = PoolCfg::split(cfg, n);
        if n > 1 {
            for (i, shard) in pool.shards.iter_mut().enumerate() {
                let t = i as f64 / (n - 1) as f64; // 0..=1 across shards
                let factor = 1.0 + skew * (2.0 * t - 1.0); // 1-skew ..= 1+skew
                shard.base_ms *= factor;
                shard.per_token_ms *= factor;
            }
        }
        pool
    }

    /// Attach a fault schedule (consuming builder). The plan's shard
    /// indices are checked against the pool size when the pool is built.
    pub fn with_faults(mut self, faults: FaultPlan) -> PoolCfg {
        self.faults = faults;
        self
    }

    /// Number of shards in the pool.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Advertised relative capacity per shard, for the client's weighted
    /// selection policy. A real operator knows this about its own
    /// provisioned endpoints (tier, region, rate limit) even though the
    /// per-request physics stay opaque. Evaluated at the balanced mix's
    /// mean token count; for shards built by [`PoolCfg::heterogeneous`]
    /// (speed factor scales `base_ms` and `per_token_ms` together) the
    /// weight *ratios* are independent of that reference anyway.
    pub fn client_weights(&self) -> Vec<f64> {
        let ref_tokens = Mix::Balanced.mean_tokens();
        self.shards.iter().map(|c| c.capacity_rps(ref_tokens)).collect()
    }
}

/// One outstanding submission of a request: which shard is serving it and,
/// once it has actually started, the exact finish time its `ProviderDone`
/// event carries (`None` while it waits in the shard's hidden queue).
///
/// A request normally has one slot, but client *retries* legitimately
/// resubmit an id whose abandoned first attempt is still stalled inside a
/// blacked-out shard — the provider, like a real endpoint, keeps serving a
/// connection the client walked away from. Finishes disambiguate by exact
/// finish-time bits: the popped event time is the same `f64` the pool
/// handed out at start, so the match is exact, not a tolerance.
#[derive(Debug, Clone, Copy)]
struct Slot {
    shard: u32,
    finish_bits: Option<u64>,
}

/// N mock endpoints behind one routing surface. All state here is invisible
/// to the scheduler; the driver only ever crosses the boundary with
/// `(id, shard)` on submit and `(id, completion time)` on finish.
pub struct ProviderPool {
    shards: Vec<MockProvider>,
    /// id → outstanding submissions (running or hidden-queued), in
    /// submission order. Unused for 1-shard pools (shard physics are
    /// count-based, so duplicate ids need no routing there).
    assigned: HashMap<ReqId, Vec<Slot>>,
    /// Total hidden-queue depth across shards, tracked incrementally.
    waiting_total: usize,
    peak_waiting_total: usize,
    /// Scheduled brownouts/blackouts applied to start events.
    faults: FaultPlan,
    /// Per-shard "has any fault window" flags: untouched shards skip the
    /// adjustment walk entirely, so their starts stay bit-identical to a
    /// fault-free pool.
    fault_touched: Vec<bool>,
    /// Net service-time extension injected by faults (ms, lifetime sum).
    faulted_ms: f64,
    /// Per-shard multiset of committed in-flight finish times (post-fault
    /// bits → count), maintained only when `track_pending` is on. Keys are
    /// non-negative `f64` bits, so `BTreeMap` order *is* numeric order and
    /// the smallest key is the shard's earliest pending finish. A count is
    /// needed because distinct requests can legitimately collide on the
    /// exact same finish bits (identical token counts, σ = 0).
    pending: Vec<BTreeMap<u64, u32>>,
    /// Off by default so the serial hot path pays nothing; the partitioned
    /// coordinator switches it on before the run starts.
    track_pending: bool,
}

impl ProviderPool {
    /// `rng` is the base provider stream (`Rng::new(seed).derive("provider")`).
    /// A 1-shard pool consumes it verbatim — the bit-compat contract with
    /// the bare `MockProvider`; multi-shard pools derive one independent
    /// stream per shard.
    pub fn new(cfg: &PoolCfg, rng: Rng) -> ProviderPool {
        assert!(!cfg.shards.is_empty(), "pool needs at least one shard");
        let shards: Vec<MockProvider> = if cfg.shards.len() == 1 {
            vec![MockProvider::new(cfg.shards[0].clone(), rng)]
        } else {
            cfg.shards
                .iter()
                .enumerate()
                .map(|(i, c)| MockProvider::new(c.clone(), rng.derive(&format!("shard{i}"))))
                .collect()
        };
        if let Some(max) = cfg.faults.max_shard() {
            assert!(
                max < shards.len(),
                "fault plan names shard {max} but the pool has {} shards",
                shards.len()
            );
        }
        let fault_touched = (0..shards.len()).map(|i| cfg.faults.touches(i)).collect();
        let n = shards.len();
        ProviderPool {
            shards,
            assigned: HashMap::new(),
            waiting_total: 0,
            peak_waiting_total: 0,
            faults: cfg.faults.clone(),
            fault_touched,
            faulted_ms: 0.0,
            pending: vec![BTreeMap::new(); n],
            track_pending: false,
        }
    }

    /// Enable committed-finish tracking for the dynamic partition window
    /// bound ([`ProviderPool::earliest_pending_finish`]). Must be called on
    /// an idle pool: entries are recorded at start time, so anything already
    /// running would be invisible to the bound and could make it unsafe.
    pub fn set_finish_tracking(&mut self, on: bool) {
        if on {
            assert!(
                self.total_running() == 0 && self.waiting_total == 0,
                "finish tracking must be enabled before any work is submitted"
            );
        }
        self.track_pending = on;
        if !on {
            for m in &mut self.pending {
                m.clear();
            }
        }
    }

    /// Record a committed (post-fault) finish time for `shard`.
    fn pending_insert(&mut self, shard: usize, finish_ms: f64) {
        *self.pending[shard].entry(finish_ms.to_bits()).or_insert(0) += 1;
    }

    /// Retire one committed finish on `shard`. The event loop finishes at
    /// the exact `f64` the pool handed out, so the bits match; callers that
    /// finish at synthetic times (tests driving the pool by hand) fall back
    /// to retiring the earliest entry, which keeps the multiset conservative.
    fn pending_remove(&mut self, shard: usize, now: f64) {
        let m = &mut self.pending[shard];
        let key = if m.contains_key(&now.to_bits()) {
            now.to_bits()
        } else if let Some((&k, _)) = m.iter().next() {
            k
        } else {
            return;
        };
        let c = m.get_mut(&key).expect("key just observed");
        *c -= 1;
        if *c == 0 {
            m.remove(&key);
        }
    }

    /// Apply the fault schedule to a start event on `shard`: re-derive the
    /// nominal service from the sampled finish and walk the shard's fault
    /// windows. Shards without windows return the event untouched (no
    /// float ops — the empty-plan/untouched-shard bit-compat contract).
    fn apply_faults(&mut self, shard: usize, now: f64, s: Started) -> Started {
        if !self.fault_touched[shard] {
            return s;
        }
        let adjusted = self.faults.adjusted_finish(shard, now, s.finish_ms - now);
        self.faulted_ms += adjusted - s.finish_ms;
        Started { id: s.id, finish_ms: adjusted }
    }

    /// Number of endpoints behind the pool.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Per-shard introspection (tests/experiments only).
    pub fn shard(&self, i: usize) -> &MockProvider {
        &self.shards[i]
    }

    /// Submit `id` to `shard`. Routing is the client's choice; a full shard
    /// queues the request in *that shard's* hidden FIFO even if another
    /// shard has free slots — the cost of imperfect client-side information.
    pub fn submit(
        &mut self,
        id: ReqId,
        output_tokens: f64,
        shard: usize,
        now: f64,
    ) -> Option<Started> {
        let started = self.shards[shard].submit(id, output_tokens, now);
        if started.is_none() {
            self.waiting_total += 1;
            self.peak_waiting_total = self.peak_waiting_total.max(self.waiting_total);
        }
        let started = started.map(|s| self.apply_faults(shard, now, s));
        if self.track_pending {
            if let Some(s) = started {
                self.pending_insert(shard, s.finish_ms);
            }
        }
        if self.shards.len() > 1 {
            self.assigned.entry(id).or_default().push(Slot {
                shard: shard as u32,
                finish_bits: started.map(|s| s.finish_ms.to_bits()),
            });
        }
        started
    }

    /// Batched dispatch: submit every `(id, tokens, shard)` in order,
    /// appending the immediately-started ones to `out`. State transitions
    /// are identical to the equivalent sequence of [`ProviderPool::submit`]
    /// calls — batching is a call-count optimization, not a semantic change.
    pub fn submit_batch(
        &mut self,
        batch: &[(ReqId, f64, usize)],
        now: f64,
        out: &mut Vec<Started>,
    ) {
        for &(id, tokens, shard) in batch {
            if let Some(s) = self.submit(id, tokens, shard, now) {
                out.push(s);
            }
        }
    }

    /// Request `id` finished: route the finish to its shard and promote that
    /// shard's queued work. Panics on an unknown id — a spurious finish is
    /// the same hard invariant violation as `MockProvider::on_finish` with
    /// nothing running.
    ///
    /// With client retries a request can have several outstanding
    /// submissions; the finish retires the slot whose recorded finish time
    /// matches `now` bit-for-bit (each `ProviderDone` event carries the
    /// exact `f64` the pool handed out when the work started). When no slot
    /// matches — callers outside the event loop may finish at synthetic
    /// times — the first *started* slot is retired, which is the unique
    /// outstanding submission in every pre-retry usage.
    pub fn on_finish(&mut self, id: ReqId, now: f64) -> Vec<Started> {
        let shard = if self.shards.len() == 1 {
            0
        } else {
            let slots =
                self.assigned.get_mut(&id).expect("finish for a request the pool never started");
            let bits = now.to_bits();
            let idx = slots
                .iter()
                .position(|s| s.finish_bits == Some(bits))
                .or_else(|| slots.iter().position(|s| s.finish_bits.is_some()))
                .expect("finish for a request the pool never started");
            let shard = slots.remove(idx).shard as usize;
            if slots.is_empty() {
                self.assigned.remove(&id);
            }
            shard
        };
        let started = self.shards[shard].on_finish(now);
        self.waiting_total -= started.len();
        let out: Vec<Started> = if self.fault_touched[shard] {
            started.into_iter().map(|s| self.apply_faults(shard, now, s)).collect()
        } else {
            started
        };
        if self.track_pending {
            self.pending_remove(shard, now);
            for s in &out {
                self.pending_insert(shard, s.finish_ms);
            }
        }
        // Hidden-queued slots learn their finish time at promotion; fill in
        // FIFO order (first unstarted slot of that id on this shard).
        if self.shards.len() > 1 {
            for s in &out {
                if let Some(slots) = self.assigned.get_mut(&s.id) {
                    if let Some(slot) = slots
                        .iter_mut()
                        .find(|sl| sl.shard as usize == shard && sl.finish_bits.is_none())
                    {
                        slot.finish_bits = Some(s.finish_ms.to_bits());
                    }
                }
            }
        }
        out
    }

    // ---- aggregate introspection (tests/experiments) ----

    /// Requests currently generating, summed across shards.
    pub fn total_running(&self) -> usize {
        self.shards.iter().map(MockProvider::running).sum()
    }

    /// Hidden-queue depth summed across shards.
    pub fn hidden_queue_len(&self) -> usize {
        self.waiting_total
    }

    /// Peak total hidden-queue depth. For a 1-shard pool this equals the
    /// bare provider's peak (same update points), preserving diagnostics
    /// byte-compat.
    pub fn peak_hidden_queue(&self) -> usize {
        if self.shards.len() == 1 {
            self.shards[0].peak_hidden_queue()
        } else {
            self.peak_waiting_total
        }
    }

    /// Lifetime started count summed across shards.
    pub fn total_started(&self) -> u64 {
        self.shards.iter().map(MockProvider::total_started).sum()
    }

    /// Requests started per shard — the balance signal the sharded
    /// experiment reports.
    pub fn started_by_shard(&self) -> Vec<u64> {
        self.shards.iter().map(MockProvider::total_started).collect()
    }

    /// Net service-time extension injected by the fault plan (ms, lifetime
    /// sum across shards; exactly 0.0 for an empty plan). Surfaces in
    /// `RunDiagnostics::faulted_shard_ms`.
    pub fn faulted_shard_ms(&self) -> f64 {
        self.faulted_ms
    }

    /// Earliest committed in-flight finish across the whole pool, or `None`
    /// when nothing is running. Finish times here are *post-fault*: they are
    /// the exact `ProviderDone` event times already handed out, so a
    /// partition window bounded by them never admits an uncommitted start.
    /// Requires [`ProviderPool::set_finish_tracking`]; panics otherwise, so
    /// a misconfigured coordinator fails loudly instead of computing an
    /// unsafe bound from an empty multiset.
    pub fn earliest_pending_finish(&self) -> Option<f64> {
        assert!(self.track_pending, "earliest_pending_finish needs finish tracking enabled");
        self.pending
            .iter()
            .filter_map(|m| m.keys().next().copied())
            .min()
            .map(f64::from_bits)
    }

    /// Earliest committed in-flight finish on one shard (see
    /// [`ProviderPool::earliest_pending_finish`]).
    pub fn shard_earliest_pending_finish(&self, shard: usize) -> Option<f64> {
        assert!(self.track_pending, "earliest_pending_finish needs finish tracking enabled");
        self.pending[shard].keys().next().copied().map(f64::from_bits)
    }

    /// Free generation slots on `shard` right now. A shard with free slots
    /// can start *new* work at any submission instant, so the dynamic
    /// window bound must fall back to the static floor from the window
    /// start for it; a saturated shard cannot start anything before its
    /// earliest committed finish.
    pub fn shard_free_slots(&self, shard: usize) -> usize {
        let s = &self.shards[shard];
        s.cfg().max_concurrency.saturating_sub(s.running())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(cap: usize) -> ProviderCfg {
        ProviderCfg {
            base_ms: 100.0,
            per_token_ms: 1.0,
            max_concurrency: cap,
            slowdown_gamma: 1.0,
            slowdown_exp: 1.0,
            slowdown_ref: 3.0,
            jitter_sigma: 0.0,
        }
    }

    #[test]
    fn split_divides_capacity() {
        let pool = PoolCfg::split(ProviderCfg::default(), 4);
        assert_eq!(pool.n_shards(), 4);
        for s in &pool.shards {
            assert_eq!(s.max_concurrency, 16);
            assert!((s.slowdown_ref - 2.0).abs() < 1e-12);
        }
    }

    #[test]
    fn heterogeneous_spread_is_symmetric() {
        let pool = PoolCfg::heterogeneous(ProviderCfg::default(), 3, 0.4);
        let base = ProviderCfg::default().per_token_ms;
        let per: Vec<f64> = pool.shards.iter().map(|s| s.per_token_ms).collect();
        assert!((per[0] - base * 0.6).abs() < 1e-12);
        assert!((per[1] - base).abs() < 1e-12);
        assert!((per[2] - base * 1.4).abs() < 1e-12);
        // Faster shards advertise larger weights.
        let w = pool.client_weights();
        assert!(w[0] > w[1] && w[1] > w[2], "weights {w:?}");
    }

    #[test]
    fn routing_is_respected_even_when_unbalanced() {
        // Everything addressed to shard 0: shard 1 stays idle and shard 0
        // queues — the pool must not steal traffic across shards.
        let pool_cfg = PoolCfg { shards: vec![cfg(1), cfg(1)], faults: FaultPlan::default() };
        let mut pool = ProviderPool::new(&pool_cfg, Rng::new(7));
        assert!(pool.submit(0, 10.0, 0, 0.0).is_some());
        assert!(pool.submit(1, 10.0, 0, 0.0).is_none());
        assert!(pool.submit(2, 10.0, 0, 0.0).is_none());
        assert_eq!(pool.shard(0).hidden_queue_len(), 2);
        assert_eq!(pool.shard(1).running(), 0);
        assert_eq!(pool.hidden_queue_len(), 2);
        assert_eq!(pool.peak_hidden_queue(), 2);
        // Finishing on shard 0 promotes shard 0's queue, FIFO.
        let started = pool.on_finish(0, 50.0);
        assert_eq!(started.len(), 1);
        assert_eq!(started[0].id, 1);
        assert_eq!(pool.hidden_queue_len(), 1);
    }

    #[test]
    fn finishes_route_back_to_the_serving_shard() {
        let pool_cfg = PoolCfg { shards: vec![cfg(2), cfg(2)], faults: FaultPlan::default() };
        let mut pool = ProviderPool::new(&pool_cfg, Rng::new(9));
        pool.submit(10, 10.0, 0, 0.0);
        pool.submit(11, 10.0, 1, 0.0);
        pool.submit(12, 10.0, 1, 0.0);
        pool.submit(13, 10.0, 1, 0.0); // queues on shard 1
        assert_eq!(pool.shard(1).hidden_queue_len(), 1);
        // Finishing the shard-0 request must not promote shard 1's queue.
        assert!(pool.on_finish(10, 5.0).is_empty());
        assert_eq!(pool.shard(1).hidden_queue_len(), 1);
        // Finishing a shard-1 request promotes id 13 on shard 1.
        let started = pool.on_finish(11, 6.0);
        assert_eq!(started.len(), 1);
        assert_eq!(started[0].id, 13);
        assert_eq!(pool.total_running(), 2);
        assert_eq!(pool.started_by_shard(), vec![1, 3]);
    }

    #[test]
    #[should_panic(expected = "never started")]
    fn unknown_finish_panics() {
        let pool_cfg = PoolCfg { shards: vec![cfg(2), cfg(2)], faults: FaultPlan::default() };
        let mut pool = ProviderPool::new(&pool_cfg, Rng::new(1));
        pool.on_finish(99, 1.0);
    }

    #[test]
    fn multi_shard_streams_are_independent_and_deterministic() {
        let jcfg = ProviderCfg { jitter_sigma: 0.1, ..ProviderCfg::default() };
        let pool_cfg = PoolCfg { shards: vec![jcfg.clone(), jcfg], faults: FaultPlan::default() };
        let mut a = ProviderPool::new(&pool_cfg, Rng::new(3));
        let mut b = ProviderPool::new(&pool_cfg, Rng::new(3));
        let mut finishes = Vec::new();
        for i in 0..8 {
            let sa = a.submit(i, 400.0, i % 2, 0.0);
            let sb = b.submit(i, 400.0, i % 2, 0.0);
            assert_eq!(sa, sb, "same seed, same pool, same events");
            finishes.push(sa.unwrap().finish_ms);
        }
        // Shards draw from distinct streams: the first request on shard 0
        // and the first on shard 1 see the same mean service (running=1 on
        // each) but different jitter draws.
        assert_ne!(finishes[0].to_bits(), finishes[1].to_bits());
    }

    #[test]
    fn blackout_extends_only_the_faulted_shard() {
        let faults = FaultPlan::default().blackout(0, 0.0, 1_000.0).unwrap();
        let pool_cfg = PoolCfg { shards: vec![cfg(2), cfg(2)], faults };
        let clean_cfg = PoolCfg { shards: vec![cfg(2), cfg(2)], faults: FaultPlan::default() };
        let mut pool = ProviderPool::new(&pool_cfg, Rng::new(4));
        let mut clean = ProviderPool::new(&clean_cfg, Rng::new(4));
        // Shard 0 is blacked out: the whole service waits for t=1000.
        let f0 = pool.submit(0, 100.0, 0, 0.0).unwrap();
        let c0 = clean.submit(0, 100.0, 0, 0.0).unwrap();
        assert_eq!(f0.finish_ms, 1_000.0 + c0.finish_ms);
        assert_eq!(pool.faulted_shard_ms(), 1_000.0);
        // Shard 1 has no windows: bit-identical to the clean pool.
        let f1 = pool.submit(1, 100.0, 1, 0.0).unwrap();
        let c1 = clean.submit(1, 100.0, 1, 0.0).unwrap();
        assert_eq!(f1.finish_ms.to_bits(), c1.finish_ms.to_bits());
        assert_eq!(pool.faulted_shard_ms(), 1_000.0);
    }

    #[test]
    fn faults_apply_to_hidden_queue_promotions_too() {
        let faults = FaultPlan::default().brownout(0, 0.0, 1_000_000.0, 0.5).unwrap();
        let pool_cfg = PoolCfg { shards: vec![cfg(1), cfg(1)], faults };
        let mut pool = ProviderPool::new(&pool_cfg, Rng::new(4));
        let first = pool.submit(0, 100.0, 0, 0.0).unwrap();
        // Half-speed brownout doubles the 200 ms nominal service.
        assert_eq!(first.finish_ms, 400.0);
        assert!(pool.submit(1, 100.0, 0, 0.0).is_none()); // hidden queue
        let promoted = pool.on_finish(0, first.finish_ms);
        assert_eq!(promoted.len(), 1);
        // Promotion starts at t=400 inside the same brownout: again 2×.
        assert_eq!(promoted[0].finish_ms, 400.0 + 2.0 * 200.0);
    }

    #[test]
    fn client_retry_resubmits_same_id_while_first_attempt_is_stalled() {
        // A timed-out request's abandoned submission keeps stalling inside a
        // blacked-out shard while the client's retry resubmits the same id
        // to a live shard. Finishes must retire the right slot (matched by
        // exact finish-time bits), in either completion order.
        let faults = FaultPlan::default().blackout(0, 0.0, 10_000.0).unwrap();
        let pool_cfg = PoolCfg { shards: vec![cfg(2), cfg(2)], faults };
        let mut pool = ProviderPool::new(&pool_cfg, Rng::new(4));
        let stale = pool.submit(7, 100.0, 0, 0.0).unwrap(); // stalls past t=10s
        assert!(stale.finish_ms >= 10_000.0);
        let fresh = pool.submit(7, 100.0, 1, 500.0).unwrap(); // retry, live shard
        assert!(fresh.finish_ms < stale.finish_ms);
        // The fresh attempt finishes first and retires the shard-1 slot...
        assert!(pool.on_finish(7, fresh.finish_ms).is_empty());
        assert_eq!(pool.shard(1).running(), 0);
        assert_eq!(pool.shard(0).running(), 1);
        // ...and the stalled attempt drains at blackout end from shard 0.
        assert!(pool.on_finish(7, stale.finish_ms).is_empty());
        assert_eq!(pool.shard(0).running(), 0);
    }

    #[test]
    #[should_panic(expected = "fault plan names shard")]
    fn fault_plan_shard_out_of_range_panics_at_pool_build() {
        let faults = FaultPlan::default().blackout(5, 0.0, 10.0).unwrap();
        let pool_cfg = PoolCfg { shards: vec![cfg(1), cfg(1)], faults };
        ProviderPool::new(&pool_cfg, Rng::new(1));
    }

    #[test]
    fn pending_finish_tracking_follows_starts_and_promotions() {
        let pool_cfg = PoolCfg { shards: vec![cfg(1), cfg(2)], faults: FaultPlan::default() };
        let mut pool = ProviderPool::new(&pool_cfg, Rng::new(11));
        pool.set_finish_tracking(true);
        assert_eq!(pool.earliest_pending_finish(), None);
        let a = pool.submit(0, 50.0, 0, 0.0).unwrap();
        let b = pool.submit(1, 200.0, 1, 0.0).unwrap();
        assert!(pool.submit(2, 50.0, 0, 0.0).is_none()); // hidden-queued: not pending
        let earliest = a.finish_ms.min(b.finish_ms);
        assert_eq!(pool.earliest_pending_finish().unwrap().to_bits(), earliest.to_bits());
        assert_eq!(
            pool.shard_earliest_pending_finish(0).unwrap().to_bits(),
            a.finish_ms.to_bits()
        );
        assert_eq!(pool.shard_free_slots(0), 0);
        assert_eq!(pool.shard_free_slots(1), 1);
        // Finishing id 0 retires its entry and records the promotion of id 2.
        let promoted = pool.on_finish(0, a.finish_ms);
        assert_eq!(promoted.len(), 1);
        assert_eq!(
            pool.shard_earliest_pending_finish(0).unwrap().to_bits(),
            promoted[0].finish_ms.to_bits()
        );
        pool.on_finish(1, b.finish_ms);
        pool.on_finish(2, promoted[0].finish_ms);
        assert_eq!(pool.earliest_pending_finish(), None);
        assert_eq!(pool.shard_free_slots(0), 1);
    }

    #[test]
    fn pending_finish_entries_are_post_fault_times() {
        // The tracked entry must be the *adjusted* finish the event loop
        // will pop, not the nominal sample — otherwise the dynamic bound
        // would run ahead of a blacked-out shard's real completions.
        let faults = FaultPlan::default().blackout(0, 0.0, 1_000.0).unwrap();
        let pool_cfg = PoolCfg { shards: vec![cfg(2), cfg(2)], faults };
        let mut pool = ProviderPool::new(&pool_cfg, Rng::new(4));
        pool.set_finish_tracking(true);
        let s = pool.submit(0, 100.0, 0, 0.0).unwrap();
        assert!(s.finish_ms >= 1_000.0);
        assert_eq!(pool.earliest_pending_finish().unwrap().to_bits(), s.finish_ms.to_bits());
    }

    #[test]
    fn pending_finish_counts_exact_bit_collisions() {
        // σ = 0 and identical token counts: two requests share the same
        // finish bits. The multiset must survive retiring one of them.
        let nojit = ProviderCfg { slowdown_gamma: 0.0, ..cfg(2) };
        let pool_cfg = PoolCfg { shards: vec![nojit], faults: FaultPlan::default() };
        let mut pool = ProviderPool::new(&pool_cfg, Rng::new(5));
        pool.set_finish_tracking(true);
        let a = pool.submit(0, 100.0, 0, 0.0).unwrap();
        let b = pool.submit(1, 100.0, 0, 0.0).unwrap();
        assert_eq!(a.finish_ms.to_bits(), b.finish_ms.to_bits());
        pool.on_finish(0, a.finish_ms);
        assert_eq!(pool.earliest_pending_finish().unwrap().to_bits(), b.finish_ms.to_bits());
        pool.on_finish(1, b.finish_ms);
        assert_eq!(pool.earliest_pending_finish(), None);
    }

    #[test]
    #[should_panic(expected = "before any work is submitted")]
    fn finish_tracking_cannot_be_enabled_mid_run() {
        let pool_cfg = PoolCfg { shards: vec![cfg(2)], faults: FaultPlan::default() };
        let mut pool = ProviderPool::new(&pool_cfg, Rng::new(6));
        pool.submit(0, 100.0, 0, 0.0);
        pool.set_finish_tracking(true);
    }
}
