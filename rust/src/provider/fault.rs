//! Deterministic provider fault plans: scheduled per-shard brownouts and
//! blackouts injected mid-run.
//!
//! A [`FaultPlan`] is a typed, validated schedule attached to
//! [`PoolCfg`](crate::provider::pool::PoolCfg). Each window names one shard
//! and a half-open interval `[t0, t1)` during which that shard's effective
//! processing speed changes: a **brownout** runs at `factor`× speed
//! (capacity × factor), a **blackout** at speed 0 (in-flight work stalls
//! until the window closes — long enough stalls blow client timeouts and
//! surface as abandons, which is exactly the live failover test the
//! censored-tail EWMA needs).
//!
//! The plan is *pure schedule*: it consumes no randomness and is evaluated
//! with the same f64 walk wherever the pool runs, so fault-afflicted runs
//! stay byte-identical across `--jobs` and `--partitions`. Windows with
//! speed ≤ 1 can only *extend* service, which keeps the partition lookahead
//! floor valid — and because [`FaultPlan::adjusted_finish`] is monotone in
//! both its start and service arguments, the partitioned loop's dynamic
//! window bound can push each shard's floor *through* the fault walk, so an
//! extension-only brownout or blackout now widens the window across the
//! stalled span instead of merely permitting the static floor. A speed-up
//! brownout (`factor > 1`) can shorten service below the floor, so
//! [`FaultPlan::extension_only`] lets the partitioner fall back to the
//! flagged serial loop in that case (see `sim::partition`).

use anyhow::{bail, Result};

/// What happens to a shard inside a fault window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Capacity scaled by `factor`: service proceeds at `factor`× speed.
    Brownout {
        /// Relative processing speed in the window (`0 < factor`, finite;
        /// `factor < 1` degrades, `factor > 1` models burst capacity and
        /// forces the partitioner's serial fallback).
        factor: f64,
    },
    /// Full stall: no service progress until the window closes.
    Blackout,
}

impl FaultKind {
    /// Effective processing speed inside the window (1.0 = nominal).
    pub fn speed(self) -> f64 {
        match self {
            FaultKind::Brownout { factor } => factor,
            FaultKind::Blackout => 0.0,
        }
    }
}

/// One scheduled fault: `shard` runs at `kind.speed()` over `[t0_ms, t1_ms)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultWindow {
    /// Pool shard index the fault applies to.
    pub shard: usize,
    /// Window start (absolute sim ms, inclusive).
    pub t0_ms: f64,
    /// Window end (absolute sim ms, exclusive).
    pub t1_ms: f64,
    /// Brownout factor or blackout.
    pub kind: FaultKind,
}

/// A validated schedule of per-shard fault windows. Construct by chaining
/// the builder methods off [`FaultPlan::default`]:
///
/// ```
/// use blackbox_sched::provider::fault::FaultPlan;
/// # fn main() -> anyhow::Result<()> {
/// let plan = FaultPlan::default()
///     .brownout(0, 5_000.0, 10_000.0, 0.25)?
///     .blackout(1, 8_000.0, 20_000.0)?;
/// assert_eq!(plan.windows().len(), 2);
/// # Ok(())
/// # }
/// ```
///
/// Overlapping windows on the *same* shard, inverted intervals, and
/// non-positive/non-finite parameters are construction-time `anyhow`
/// errors, never panics. The empty plan is the universal default and is
/// bit-identical to a fault-free pool (property-tested next to
/// `tests/pool_equivalence.rs`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Windows kept sorted by `(shard, t0_ms)` — the insertion invariant
    /// [`FaultPlan::adjusted_finish`] relies on.
    windows: Vec<FaultWindow>,
}

impl FaultPlan {
    /// Add a brownout: `shard` runs at `factor`× speed over `[t0, t1)`.
    pub fn brownout(self, shard: usize, t0_ms: f64, t1_ms: f64, factor: f64) -> Result<Self> {
        if !(factor > 0.0 && factor.is_finite()) {
            bail!("brownout factor must be positive and finite, got {factor}");
        }
        self.push(FaultWindow { shard, t0_ms, t1_ms, kind: FaultKind::Brownout { factor } })
    }

    /// Add a blackout: `shard` makes no progress over `[t0, t1)`.
    pub fn blackout(self, shard: usize, t0_ms: f64, t1_ms: f64) -> Result<Self> {
        self.push(FaultWindow { shard, t0_ms, t1_ms, kind: FaultKind::Blackout })
    }

    fn push(mut self, w: FaultWindow) -> Result<Self> {
        if !(w.t0_ms.is_finite() && w.t1_ms.is_finite()) {
            bail!("fault window bounds must be finite, got [{}, {})", w.t0_ms, w.t1_ms);
        }
        if w.t0_ms < 0.0 || w.t0_ms >= w.t1_ms {
            bail!("fault window must satisfy 0 <= t0 < t1, got [{}, {})", w.t0_ms, w.t1_ms);
        }
        for e in self.windows.iter().filter(|e| e.shard == w.shard) {
            if w.t0_ms < e.t1_ms && e.t0_ms < w.t1_ms {
                bail!(
                    "fault windows overlap on shard {}: [{}, {}) vs [{}, {})",
                    w.shard,
                    e.t0_ms,
                    e.t1_ms,
                    w.t0_ms,
                    w.t1_ms
                );
            }
        }
        let at = self
            .windows
            .partition_point(|e| (e.shard, e.t0_ms) < (w.shard, w.t0_ms));
        self.windows.insert(at, w);
        Ok(self)
    }

    /// No faults scheduled (the default, bit-identical-to-today plan).
    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }

    /// All scheduled windows, sorted by `(shard, t0_ms)`.
    pub fn windows(&self) -> &[FaultWindow] {
        &self.windows
    }

    /// Whether any window touches `shard` — pools skip the adjustment walk
    /// (and thus any float rounding) entirely for untouched shards.
    pub fn touches(&self, shard: usize) -> bool {
        self.windows.iter().any(|w| w.shard == shard)
    }

    /// Largest shard index named by any window (`None` when empty); pools
    /// check it against their shard count at construction.
    pub fn max_shard(&self) -> Option<usize> {
        self.windows.iter().map(|w| w.shard).max()
    }

    /// True when every window runs at speed ≤ 1, i.e. faults can only
    /// *extend* service. This is the condition under which the partition
    /// lookahead floor (a service-time lower bound) remains valid; a
    /// speed-up brownout breaks it and must force the serial fallback.
    pub fn extension_only(&self) -> bool {
        self.windows.iter().all(|w| w.kind.speed() <= 1.0)
    }

    /// Completion time for work starting on `shard` at `start_ms` with
    /// nominal (fault-free) service `service_ms`: walk the shard's windows
    /// in time order, crediting full-speed progress between windows and
    /// `speed`× progress inside them, until the nominal work is done.
    ///
    /// Pure f64 arithmetic, no randomness; with speed ≤ 1 everywhere the
    /// result is ≥ `start_ms + service_ms` minus nothing — extension-only.
    pub fn adjusted_finish(&self, shard: usize, start_ms: f64, service_ms: f64) -> f64 {
        let mut t = start_ms;
        let mut remaining = service_ms;
        for w in self.windows.iter().filter(|w| w.shard == shard) {
            if w.t1_ms <= t {
                continue; // window fully in the past
            }
            // Full-speed stretch from t to the window start.
            let gap = (w.t0_ms - t).max(0.0);
            if remaining <= gap {
                return t + remaining;
            }
            remaining -= gap;
            t = t.max(w.t0_ms);
            // Degraded stretch inside the window.
            let speed = w.kind.speed();
            let capacity = (w.t1_ms - t) * speed;
            if speed > 0.0 && remaining <= capacity {
                return t + remaining / speed;
            }
            remaining -= capacity;
            t = w.t1_ms;
        }
        t + remaining
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_validates_windows() {
        assert!(FaultPlan::default().brownout(0, 0.0, 10.0, 0.5).is_ok());
        assert!(FaultPlan::default().brownout(0, 5.0, 5.0, 0.5).is_err(), "empty interval");
        assert!(FaultPlan::default().brownout(0, 10.0, 5.0, 0.5).is_err(), "inverted");
        assert!(FaultPlan::default().brownout(0, -1.0, 5.0, 0.5).is_err(), "negative t0");
        assert!(FaultPlan::default().brownout(0, 0.0, f64::NAN, 0.5).is_err(), "nan bound");
        assert!(FaultPlan::default().brownout(0, 0.0, 10.0, 0.0).is_err(), "zero factor");
        assert!(FaultPlan::default().brownout(0, 0.0, 10.0, -0.5).is_err(), "negative factor");
        assert!(FaultPlan::default().brownout(0, 0.0, 10.0, f64::INFINITY).is_err());
        assert!(FaultPlan::default().blackout(1, 100.0, 200.0).is_ok());
    }

    #[test]
    fn same_shard_overlap_is_an_error_cross_shard_is_not() {
        let p = FaultPlan::default().blackout(0, 0.0, 100.0).unwrap();
        assert!(p.clone().blackout(0, 50.0, 150.0).is_err(), "same-shard overlap");
        assert!(p.clone().blackout(0, 100.0, 150.0).is_ok(), "touching is fine (half-open)");
        assert!(p.blackout(1, 50.0, 150.0).is_ok(), "different shard may overlap in time");
    }

    #[test]
    fn windows_sort_by_shard_then_time() {
        let p = FaultPlan::default()
            .blackout(1, 0.0, 10.0)
            .unwrap()
            .brownout(0, 50.0, 60.0, 0.5)
            .unwrap()
            .brownout(0, 5.0, 15.0, 0.5)
            .unwrap();
        let order: Vec<(usize, f64)> = p.windows().iter().map(|w| (w.shard, w.t0_ms)).collect();
        assert_eq!(order, vec![(0, 5.0), (0, 50.0), (1, 0.0)]);
    }

    #[test]
    fn blackout_stalls_work_until_the_window_closes() {
        let p = FaultPlan::default().blackout(0, 100.0, 500.0).unwrap();
        // Starts before, would nominally finish inside: stalls to 500 then
        // spends the leftover 50 ms.
        assert_eq!(p.adjusted_finish(0, 0.0, 150.0), 550.0);
        // Starts inside the blackout: all work waits for the window.
        assert_eq!(p.adjusted_finish(0, 200.0, 80.0), 580.0);
        // Finishes before the window opens: untouched.
        assert_eq!(p.adjusted_finish(0, 0.0, 100.0), 100.0);
        // Other shards untouched.
        assert_eq!(p.adjusted_finish(1, 0.0, 150.0), 150.0);
    }

    #[test]
    fn brownout_stretches_in_window_service_by_the_factor() {
        let p = FaultPlan::default().brownout(0, 100.0, 1_000.0, 0.5).unwrap();
        // 50 ms at full speed, then 100 ms of work at half speed = 200 ms.
        assert_eq!(p.adjusted_finish(0, 50.0, 150.0), 350.0);
        // A speed-up brownout shortens service (and must flag serial fallback).
        let fast = FaultPlan::default().brownout(0, 0.0, 1_000.0, 2.0).unwrap();
        assert_eq!(fast.adjusted_finish(0, 0.0, 100.0), 50.0);
        assert!(!fast.extension_only());
        assert!(p.extension_only());
    }

    #[test]
    fn work_spans_multiple_windows() {
        let p = FaultPlan::default()
            .blackout(0, 10.0, 20.0)
            .unwrap()
            .brownout(0, 30.0, 40.0, 0.5)
            .unwrap();
        // 10 full + stall + 10 full + 10@half=5 + finish after 40:
        // work done by t=40 is 25; remaining 15 at full speed → 55.
        assert_eq!(p.adjusted_finish(0, 0.0, 40.0), 55.0);
    }

    #[test]
    fn adjusted_finish_is_monotone_in_start_and_service() {
        // The dynamic partition bound evaluates `adjusted_finish(s, start,
        // floor)` at a start no later than any real in-window start, with a
        // service no larger than any real sampled service, and relies on the
        // result lower-bounding every real adjusted finish. That is exactly
        // monotonicity in both arguments, checked here over a dense grid
        // spanning gaps, a brownout, and a blackout (including boundaries).
        let p = FaultPlan::default()
            .brownout(0, 10.0, 30.0, 0.25)
            .unwrap()
            .blackout(0, 50.0, 90.0)
            .unwrap();
        let grid: Vec<f64> = (0..=240).map(|i| i as f64 * 0.5).collect();
        for win in grid.windows(2) {
            let (a, b) = (win[0], win[1]);
            for &svc in &[0.0, 1.0, 7.5, 25.0, 60.0, 200.0] {
                // Later start never finishes earlier (same service)...
                assert!(
                    p.adjusted_finish(0, a, svc) <= p.adjusted_finish(0, b, svc),
                    "start monotonicity at start {a}->{b}, svc {svc}"
                );
                // ...and more service never finishes earlier (same start).
                assert!(
                    p.adjusted_finish(0, a, svc) <= p.adjusted_finish(0, a, svc + 0.5),
                    "service monotonicity at start {a}, svc {svc}"
                );
            }
        }
    }

    #[test]
    fn introspection_accessors() {
        let p = FaultPlan::default().blackout(2, 0.0, 10.0).unwrap();
        assert!(!p.is_empty());
        assert!(p.touches(2) && !p.touches(0));
        assert_eq!(p.max_shard(), Some(2));
        assert!(FaultPlan::default().is_empty());
        assert_eq!(FaultPlan::default().max_shard(), None);
        assert!(FaultPlan::default().extension_only());
    }
}
