//! Latency calibration harness (paper §4.1, Table 3 +
//! `latency_calibration.csv`).
//!
//! The paper measured 18 single requests against a production API
//! (Volcengine Doubao) under low load and fit `latency = a + b·tokens`,
//! reporting R² = 0.97. We cannot reach a production API from this image, so
//! the harness measures **our own mock provider** in paper-scale mode
//! (a = 3294, b = 18.7, log-normal jitter) the same way — one request at a
//! time, three buckets — and fits the same model. The point of the
//! experiment (generation time scales linearly with output length, which
//! the mock must preserve) transfers: the harness would produce the paper's
//! table verbatim if pointed at the real API.

use crate::core::TokenBucket;
use crate::provider::{MockProvider, ProviderCfg};
use crate::util::rng::Rng;
use crate::util::stats::{linear_fit, mean_std};

/// One measured sample.
#[derive(Debug, Clone)]
pub struct CalibrationSample {
    /// Bucket the probe was drawn from.
    pub bucket: TokenBucket,
    /// Sampled output length of the probe.
    pub output_tokens: f64,
    /// Measured end-to-end latency.
    pub latency_ms: f64,
}

/// Per-bucket summary row (Table 3 layout).
#[derive(Debug, Clone)]
pub struct BucketRow {
    /// The bucket summarized.
    pub bucket: TokenBucket,
    /// Probes in this bucket.
    pub count: usize,
    /// Mean sampled output tokens.
    pub mean_tokens: f64,
    /// Std dev of sampled output tokens.
    pub std_tokens: f64,
    /// Mean measured latency.
    pub mean_latency_ms: f64,
    /// Std dev of measured latency.
    pub std_latency_ms: f64,
}

/// Full calibration result.
#[derive(Debug, Clone)]
pub struct CalibrationResult {
    /// Every probe, in measurement order.
    pub samples: Vec<CalibrationSample>,
    /// Per-bucket summaries (Table 3 layout).
    pub rows: Vec<BucketRow>,
    /// Fit `latency_ms = intercept + slope · output_tokens`.
    pub intercept: f64,
    /// Per-token slope of the fit (ms/token).
    pub slope: f64,
    /// Coefficient of determination of the fit.
    pub r2: f64,
}

/// Token-count design matching the paper: 3 medium, 5 long, 10 xlong
/// (18 requests spanning three buckets).
pub fn paper_design(rng: &mut Rng) -> Vec<(TokenBucket, f64)> {
    let mut plan = Vec::new();
    // Means/σ chosen to mirror the paper's bucket stats (155±35, 670±259,
    // 2839±907) — sampled log-normally around the same centers.
    for _ in 0..3 {
        plan.push((TokenBucket::Medium, (155.0 * rng.lognormal(0.0, 0.22)).clamp(65.0, 256.0)));
    }
    for _ in 0..5 {
        plan.push((TokenBucket::Long, (670.0 * rng.lognormal(0.0, 0.35)).clamp(257.0, 1024.0)));
    }
    for _ in 0..10 {
        plan.push((TokenBucket::XLong, (2839.0 * rng.lognormal(0.0, 0.30)).clamp(1025.0, 4096.0)));
    }
    plan
}

/// Run the calibration: sequential single requests (no concurrency ⇒ no
/// slowdown term), fit the linear model.
pub fn run_calibration(cfg: ProviderCfg, seed: u64) -> CalibrationResult {
    let mut rng = Rng::new(seed).derive("calibration");
    let mut provider = MockProvider::new(cfg, rng.derive("provider"));
    let plan = paper_design(&mut rng);

    let mut samples = Vec::new();
    let mut now = 0.0;
    for (i, (bucket, tokens)) in plan.iter().enumerate() {
        let started = provider
            .submit(i, *tokens, now)
            .expect("calibration is sequential; slot must be free");
        let latency = started.finish_ms - now;
        provider.on_finish(started.finish_ms);
        now = started.finish_ms + 100.0; // think time between probes
        samples.push(CalibrationSample { bucket: *bucket, output_tokens: *tokens, latency_ms: latency });
    }

    let rows = summarize(&samples);
    let xs: Vec<f64> = samples.iter().map(|s| s.output_tokens).collect();
    let ys: Vec<f64> = samples.iter().map(|s| s.latency_ms).collect();
    let (intercept, slope, r2) = linear_fit(&xs, &ys);
    CalibrationResult { samples, rows, intercept, slope, r2 }
}

fn summarize(samples: &[CalibrationSample]) -> Vec<BucketRow> {
    let mut rows = Vec::new();
    for bucket in [TokenBucket::Medium, TokenBucket::Long, TokenBucket::XLong] {
        let sel: Vec<&CalibrationSample> =
            samples.iter().filter(|s| s.bucket == bucket).collect();
        if sel.is_empty() {
            continue;
        }
        let toks: Vec<f64> = sel.iter().map(|s| s.output_tokens).collect();
        let lats: Vec<f64> = sel.iter().map(|s| s.latency_ms).collect();
        let (mt, st) = mean_std(&toks);
        let (ml, sl) = mean_std(&lats);
        rows.push(BucketRow {
            bucket,
            count: sel.len(),
            mean_tokens: mt,
            std_tokens: st,
            mean_latency_ms: ml,
            std_latency_ms: sl,
        });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_design_is_18_requests() {
        let mut rng = Rng::new(0);
        let plan = paper_design(&mut rng);
        assert_eq!(plan.len(), 18);
        assert_eq!(plan.iter().filter(|(b, _)| *b == TokenBucket::Medium).count(), 3);
        assert_eq!(plan.iter().filter(|(b, _)| *b == TokenBucket::Long).count(), 5);
        assert_eq!(plan.iter().filter(|(b, _)| *b == TokenBucket::XLong).count(), 10);
    }

    #[test]
    fn fit_recovers_linear_model() {
        let res = run_calibration(ProviderCfg::paper_scale(), 42);
        // True model: 3294 + 18.7·tok with 12% log-normal jitter.
        assert!(res.r2 > 0.90, "r2={}", res.r2);
        assert!((res.slope - 18.7).abs() < 4.0, "slope={}", res.slope);
        assert!(res.intercept.abs() < 9000.0, "intercept={}", res.intercept);
        assert_eq!(res.rows.len(), 3);
        assert_eq!(res.samples.len(), 18);
    }

    #[test]
    fn zero_jitter_fit_is_exact() {
        let cfg = ProviderCfg { jitter_sigma: 0.0, ..ProviderCfg::paper_scale() };
        let res = run_calibration(cfg, 7);
        assert!((res.r2 - 1.0).abs() < 1e-9);
        assert!((res.slope - 18.7).abs() < 1e-6);
        assert!((res.intercept - 3294.0).abs() < 1e-3);
    }

    #[test]
    fn bucket_means_ordered() {
        let res = run_calibration(ProviderCfg::paper_scale(), 3);
        assert!(res.rows[0].mean_latency_ms < res.rows[1].mean_latency_ms);
        assert!(res.rows[1].mean_latency_ms < res.rows[2].mean_latency_ms);
    }
}
