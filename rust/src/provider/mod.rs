//! Congestion-aware mock black-box LLM provider (paper §4.1).
//!
//! The mock is an *abstraction* preserving the causal chain the paper needs:
//! arrival shaping → offered load → load-dependent slowdown → completions.
//! Its qualitative physics: bigger jobs cost more (linear in output tokens,
//! validated by the calibration harness with R² ≈ 1), overload hurts
//! everyone (multiplicative slowdown in concurrent load), and arrivals
//! beyond the concurrency limit queue FIFO *inside* the provider — the
//! hidden head-of-line effect that naive client dispatch suffers.
//!
//! Nothing in this module is visible to the scheduler except completion
//! timing: the black-box boundary is enforced by the driver only ever
//! handing the client `(request id, completion time)`.

#![warn(missing_docs)]

pub mod calibration;
pub mod fault;
pub mod pool;

use std::collections::VecDeque;

use crate::core::ReqId;
use crate::util::rng::Rng;

/// Provider physics parameters.
///
/// The mock has **no hard admission gate** at typical loads: the paper's
/// abstraction is "per-request delay grows with concurrent load", so the
/// congestion cost of over-submitting is a *slowdown everyone pays*, not a
/// clean queue. `max_concurrency` is a distant hard ceiling (a real vendor
/// eventually queues or 429s); the operative knob is `slowdown_ref` — the
/// concurrency at which service stretches by `1 + slowdown_gamma`.
#[derive(Debug, Clone)]
pub struct ProviderCfg {
    /// Fixed per-request overhead (network + prefill), ms.
    pub base_ms: f64,
    /// Linear generation cost per output token, ms.
    pub per_token_ms: f64,
    /// Hard concurrency ceiling; beyond this, requests queue FIFO unseen.
    pub max_concurrency: usize,
    /// Congestion slowdown amplitude: service × (1 + γ·((n−1)/ref)^p).
    pub slowdown_gamma: f64,
    /// Congestion curve exponent p.
    pub slowdown_exp: f64,
    /// Reference concurrency for the slowdown curve.
    pub slowdown_ref: f64,
    /// Log-normal service jitter sigma (0 = deterministic).
    pub jitter_sigma: f64,
}

impl Default for ProviderCfg {
    fn default() -> Self {
        // Defaults put the joint metrics in the paper's bands (short P95
        // ≈ 320 ms under structured policies); see `docs/EXPERIMENTS.md`
        // §calibration for the harness that checks them.
        ProviderCfg {
            base_ms: 150.0,
            per_token_ms: 0.9,
            max_concurrency: 64,
            slowdown_gamma: 0.8,
            slowdown_exp: 1.5,
            slowdown_ref: 8.0,
            jitter_sigma: 0.06,
        }
    }
}

impl ProviderCfg {
    /// Paper-scale calibration constants (Volcengine Doubao fit:
    /// 3294 + 18.7·tokens). Used by the Table-3 calibration experiment.
    pub fn paper_scale() -> Self {
        ProviderCfg {
            base_ms: 3294.0,
            per_token_ms: 18.7,
            max_concurrency: 64,
            slowdown_gamma: 0.0,
            slowdown_exp: 1.0,
            slowdown_ref: 8.0,
            jitter_sigma: 0.12,
        }
    }

    /// Mean service time for a token count at a given running count.
    pub fn service_ms(&self, output_tokens: f64, running: usize) -> f64 {
        (self.base_ms + self.per_token_ms * output_tokens) * self.slowdown(running)
    }

    /// [`ProviderCfg::service_ms`] at a fractional concurrency level (the
    /// slowdown curve is continuous; capacity math evaluates it at
    /// `slowdown_ref`, which need not be an integer).
    pub fn service_ms_at(&self, output_tokens: f64, n: f64) -> f64 {
        (self.base_ms + self.per_token_ms * output_tokens) * self.slowdown_at(n)
    }

    /// Multiplicative slowdown when `running` requests (including the new
    /// one) occupy the engine. Uncapped: flooding the provider stretches
    /// everyone's generation time.
    pub fn slowdown(&self, running: usize) -> f64 {
        self.slowdown_at(running as f64)
    }

    /// Slowdown at a fractional concurrency level. All capacity math is
    /// computed on f64 throughout: truncating `slowdown_ref` to an integer
    /// would silently evaluate the curve at the wrong concurrency for
    /// non-integer refs (e.g. 8.5).
    pub fn slowdown_at(&self, n: f64) -> f64 {
        if n <= 1.0 {
            return 1.0;
        }
        let frac = (n - 1.0) / self.slowdown_ref.max(1.0);
        1.0 + self.slowdown_gamma * frac.powf(self.slowdown_exp)
    }

    /// Rough capacity estimate (req/s) for a mean token count at the
    /// reference concurrency — used to express offered load as a ratio.
    pub fn capacity_rps(&self, mean_tokens: f64) -> f64 {
        let n = self.slowdown_ref.max(1.0);
        let mean_service_s = self.service_ms_at(mean_tokens, n) / 1000.0;
        n / mean_service_s
    }
}

/// Event emitted by the provider toward the DES: request `id` will complete
/// at absolute time `finish_ms`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Started {
    /// The request that just began generating.
    pub id: ReqId,
    /// Absolute completion time the DES should schedule.
    pub finish_ms: f64,
}

/// The mock provider. All state here is invisible to the scheduler.
pub struct MockProvider {
    cfg: ProviderCfg,
    rng: Rng,
    /// Requests currently generating.
    running: usize,
    /// Hidden FIFO of (req, tokens) waiting for a slot.
    waiting: VecDeque<(ReqId, f64)>,
    // ---- introspection for tests/experiments (not exposed to the client) ----
    peak_running: usize,
    peak_waiting: usize,
    total_started: u64,
}

impl MockProvider {
    /// An idle provider with `cfg` physics and its own service-jitter RNG.
    pub fn new(cfg: ProviderCfg, rng: Rng) -> Self {
        MockProvider {
            cfg,
            rng,
            running: 0,
            waiting: VecDeque::new(),
            peak_running: 0,
            peak_waiting: 0,
            total_started: 0,
        }
    }

    /// The physics parameters this provider runs with.
    pub fn cfg(&self) -> &ProviderCfg {
        &self.cfg
    }

    fn sample_service(&mut self, tokens: f64) -> f64 {
        let mean = self.cfg.service_ms(tokens, self.running);
        if self.cfg.jitter_sigma > 0.0 {
            // Log-normal with median = mean service (mu = ln mean).
            mean * self.rng.lognormal(0.0, self.cfg.jitter_sigma)
        } else {
            mean
        }
    }

    fn start(&mut self, id: ReqId, tokens: f64, now: f64) -> Started {
        self.running += 1;
        self.peak_running = self.peak_running.max(self.running);
        self.total_started += 1;
        let service = self.sample_service(tokens);
        Started { id, finish_ms: now + service }
    }

    /// Client submits a request. Returns `Some(Started)` if a slot was free,
    /// else the request queues invisibly and `None` is returned.
    pub fn submit(&mut self, id: ReqId, output_tokens: f64, now: f64) -> Option<Started> {
        if self.running < self.cfg.max_concurrency {
            Some(self.start(id, output_tokens, now))
        } else {
            self.waiting.push_back((id, output_tokens));
            self.peak_waiting = self.peak_waiting.max(self.waiting.len());
            None
        }
    }

    /// A running request finished; promote queued work. Returns newly
    /// started requests (the DES schedules their completions).
    ///
    /// A finish with nothing running is a **hard invariant violation** in
    /// every build profile: a `debug_assert!` here once let release builds
    /// wrap `running` to `usize::MAX`, silently disabling the concurrency
    /// gate forever.
    pub fn on_finish(&mut self, now: f64) -> Vec<Started> {
        assert!(self.running > 0, "provider finish with nothing running");
        self.running -= 1;
        let mut started = Vec::new();
        while self.running < self.cfg.max_concurrency {
            match self.waiting.pop_front() {
                Some((id, tokens)) => started.push(self.start(id, tokens, now)),
                None => break,
            }
        }
        started
    }

    // ---- test/experiment introspection ----

    /// Requests currently generating.
    pub fn running(&self) -> usize {
        self.running
    }

    /// Requests queued invisibly behind the concurrency gate.
    pub fn hidden_queue_len(&self) -> usize {
        self.waiting.len()
    }

    /// Highest concurrent running count observed.
    pub fn peak_running(&self) -> usize {
        self.peak_running
    }

    /// Longest hidden queue observed.
    pub fn peak_hidden_queue(&self) -> usize {
        self.peak_waiting
    }

    /// Requests that have started generating (lifetime total).
    pub fn total_started(&self) -> u64 {
        self.total_started
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn provider(cap: usize) -> MockProvider {
        let cfg = ProviderCfg {
            base_ms: 100.0,
            per_token_ms: 1.0,
            max_concurrency: cap,
            slowdown_gamma: 1.0,
            slowdown_exp: 1.0,
            slowdown_ref: 3.0,
            jitter_sigma: 0.0,
        };
        MockProvider::new(cfg, Rng::new(1))
    }

    #[test]
    fn linear_cost_no_load() {
        let mut p = provider(4);
        let s = p.submit(0, 100.0, 0.0).unwrap();
        assert!((s.finish_ms - 200.0).abs() < 1e-9); // 100 + 1.0*100, no slowdown
    }

    #[test]
    fn slowdown_grows_with_load_uncapped() {
        let cfg = ProviderCfg::default();
        let s1 = cfg.slowdown(1);
        let s2 = cfg.slowdown(2);
        let s8 = cfg.slowdown(8);
        let s40 = cfg.slowdown(40);
        assert_eq!(s1, 1.0);
        assert!(s2 > s1 && s8 > s2 && s40 > s8);
        // At ref+1 running, the slowdown equals 1 + gamma by construction —
        // including for non-integer refs, which the old `as usize`
        // truncation evaluated at the wrong concurrency.
        for r in [8.0, 8.5, 3.25] {
            let c = ProviderCfg { slowdown_ref: r, ..ProviderCfg::default() };
            let at_ref = c.slowdown_at(c.slowdown_ref + 1.0);
            assert!((at_ref - (1.0 + c.slowdown_gamma)).abs() < 1e-9, "ref={r}");
        }
        // Flooding is punished superlinearly (the naive pathology).
        assert!(s40 > 5.0, "s40={s40}");
    }

    #[test]
    fn capacity_rps_respects_fractional_ref() {
        // Capacity at ref 8.5 must lie strictly between the integer
        // neighbours' capacities evaluated on the continuous curve; the old
        // truncating implementation pinned it to the ref-8 service time.
        let mk = |r: f64| ProviderCfg { slowdown_ref: r, ..ProviderCfg::default() };
        let c8 = mk(8.0).capacity_rps(352.0);
        let c85 = mk(8.5).capacity_rps(352.0);
        let c9 = mk(9.0).capacity_rps(352.0);
        assert!(c8 < c85 && c85 < c9, "c8={c8} c85={c85} c9={c9}");
        // And the exact value matches the f64 formula end-to-end.
        let cfg = mk(8.5);
        let want = 8.5 / (cfg.service_ms_at(352.0, 8.5) / 1000.0);
        assert_eq!(c85.to_bits(), want.to_bits());
    }

    #[test]
    #[should_panic(expected = "finish with nothing running")]
    fn spurious_finish_is_a_hard_panic_in_every_profile() {
        // Regression: this was a debug_assert!, so release builds wrapped
        // `running` to usize::MAX and disabled the concurrency gate forever.
        // `assert!` fires in release too; this test guards the invariant in
        // whichever profile the suite runs under.
        let mut p = provider(2);
        p.on_finish(1.0);
    }

    #[test]
    fn spurious_finish_cannot_disable_the_gate() {
        // The release-profile failure mode: running wraps to usize::MAX and
        // every later submit bypasses the FIFO. With the hard invariant the
        // wrap is unreachable; catch_unwind keeps the suite profile-agnostic.
        let result = std::panic::catch_unwind(|| {
            let mut p = provider(1);
            p.on_finish(0.0);
            p
        });
        assert!(result.is_err(), "spurious finish must not return a provider with running=MAX");
    }

    #[test]
    fn queues_beyond_capacity_fifo() {
        let mut p = provider(2);
        assert!(p.submit(0, 10.0, 0.0).is_some());
        assert!(p.submit(1, 10.0, 0.0).is_some());
        assert!(p.submit(2, 10.0, 0.0).is_none());
        assert!(p.submit(3, 10.0, 0.0).is_none());
        assert_eq!(p.hidden_queue_len(), 2);
        assert_eq!(p.running(), 2);
        let started = p.on_finish(50.0);
        assert_eq!(started.len(), 1);
        assert_eq!(started[0].id, 2, "FIFO order");
        assert_eq!(p.hidden_queue_len(), 1);
    }

    #[test]
    fn second_request_sees_slowdown() {
        let mut p = provider(4);
        let a = p.submit(0, 100.0, 0.0).unwrap();
        let b = p.submit(1, 100.0, 0.0).unwrap();
        // running=2, ref=3: slowdown = 1 + 1.0·(1/3) = 1.333…
        assert!((a.finish_ms - 200.0).abs() < 1e-9);
        assert!((b.finish_ms - 200.0 * (1.0 + 1.0 / 3.0)).abs() < 1e-6);
    }

    #[test]
    fn jitter_is_seed_deterministic() {
        let cfg = ProviderCfg { jitter_sigma: 0.1, ..ProviderCfg::default() };
        let mut p1 = MockProvider::new(cfg.clone(), Rng::new(9));
        let mut p2 = MockProvider::new(cfg, Rng::new(9));
        for i in 0..10 {
            let a = p1.submit(i, 500.0, 0.0);
            let b = p2.submit(i, 500.0, 0.0);
            assert_eq!(a, b);
            if p1.running() == p1.cfg.max_concurrency {
                p1.on_finish(1.0);
                p2.on_finish(1.0);
            }
        }
    }

    #[test]
    fn peak_tracking() {
        let mut p = provider(1);
        p.submit(0, 10.0, 0.0);
        p.submit(1, 10.0, 0.0);
        p.submit(2, 10.0, 0.0);
        assert_eq!(p.peak_running(), 1);
        assert_eq!(p.peak_hidden_queue(), 2);
        assert_eq!(p.total_started(), 1);
    }

    #[test]
    fn capacity_estimate_sane() {
        let cfg = ProviderCfg::default();
        let cap = cfg.capacity_rps(352.0);
        assert!(cap > 1.0 && cap < 50.0, "capacity={cap}");
    }

    #[test]
    fn drain_all_queued() {
        use crate::testing::prop;
        prop::forall(30, |g| {
            let capn = g.usize_in(1, 6);
            let mut p = provider(capn);
            let n = g.usize_in(1, 40);
            let mut completed = 0usize;
            let mut inflight: Vec<ReqId> = Vec::new();
            for i in 0..n {
                if p.submit(i, g.f64_in(10.0, 3000.0), 0.0).is_some() {
                    inflight.push(i);
                }
            }
            // Finish everything: each on_finish may start more.
            let mut pending = inflight.len();
            while pending > 0 {
                completed += 1;
                pending -= 1;
                pending += p.on_finish(completed as f64).len();
            }
            assert_eq!(completed, n, "all requests eventually run");
            assert_eq!(p.hidden_queue_len(), 0);
            assert_eq!(p.running(), 0);
        });
    }
}
