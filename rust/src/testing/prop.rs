//! Seeded property-testing harness (proptest-lite).
//!
//! `forall` runs a property over N generated cases from a deterministic
//! seed sequence; on failure it retries with progressively "smaller"
//! generator budgets (shrink-lite) and reports the smallest failing seed so
//! the case can be replayed exactly:
//!
//! ```ignore
//! prop::forall(200, |g| {
//!     let xs = g.vec(0..50, |g| g.f64_in(0.0, 1e6));
//!     let p = percentile(&xs, g.f64_in(0.0, 100.0));
//!     ...assert!(...);
//! });
//! ```

use crate::util::rng::Rng;

/// Generator handle passed to properties. Wraps an RNG plus a size budget
/// that shrinks on failure reruns.
pub struct Gen {
    rng: Rng,
    /// Size multiplier in (0, 1]; shrink reruns reduce it.
    pub size: f64,
    pub case_seed: u64,
}

impl Gen {
    fn new(seed: u64, size: f64) -> Self {
        Gen { rng: Rng::new(seed), size, case_seed: seed }
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range(lo, hi)
    }

    /// Integer in [lo, hi), range scaled down by the shrink budget.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        let span = ((hi - lo) as f64 * self.size).max(1.0) as usize;
        lo + self.rng.index(span)
    }

    /// Vec with length in `len_range`, elements from `f` (length shrinks).
    pub fn vec<T>(&mut self, len_range: std::ops::Range<usize>, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = if len_range.is_empty() {
            len_range.start
        } else {
            self.usize_in(len_range.start, len_range.end)
        };
        (0..n).map(|_| f(self)).collect()
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.index(xs.len())]
    }
}

/// Run `prop` over `cases` generated inputs. Panics (test failure) with the
/// failing seed on the smallest reproduction found.
pub fn forall(cases: usize, mut prop: impl FnMut(&mut Gen)) {
    forall_seeded(0xC0FFEE, cases, &mut prop);
}

/// `forall` with an explicit base seed (replay a reported failure with
/// `replay(seed, size, prop)`).
pub fn forall_seeded(base_seed: u64, cases: usize, prop: &mut impl FnMut(&mut Gen)) {
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let failed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen::new(seed, 1.0);
            prop(&mut g);
        }))
        .is_err();
        if failed {
            // Shrink-lite: rerun with smaller size budgets, report smallest failure.
            let mut smallest: f64 = 1.0;
            for size in [0.5, 0.25, 0.1, 0.05] {
                let fails = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let mut g = Gen::new(seed, size);
                    prop(&mut g);
                }))
                .is_err();
                if fails {
                    smallest = size;
                }
            }
            // Reproduce at the smallest failing size so the panic message of
            // the property itself surfaces in the test output.
            eprintln!(
                "property failed: case={case} seed={seed:#x} smallest_size={smallest} \
                 (replay with prop::replay({seed:#x}, {smallest}, ..))"
            );
            let mut g = Gen::new(seed, smallest);
            prop(&mut g); // panics again, with context printed above
            unreachable!("property passed on replay — flaky (non-deterministic) property");
        }
    }
}

/// Replay one failing case.
pub fn replay(seed: u64, size: f64, mut prop: impl FnMut(&mut Gen)) {
    let mut g = Gen::new(seed, size);
    prop(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        forall(50, |g| {
            let x = g.f64_in(0.0, 1.0);
            assert!((0.0..1.0).contains(&x));
            n += 1;
        });
        assert!(n >= 50);
    }

    #[test]
    #[should_panic]
    fn failing_property_panics() {
        forall(50, |g| {
            let v = g.vec(0..20, |g| g.f64_in(0.0, 10.0));
            assert!(v.len() < 5, "vector too long");
        });
    }

    #[test]
    fn deterministic_cases() {
        let mut first: Vec<u64> = Vec::new();
        forall(10, |g| first.push(g.u64()));
        let mut second: Vec<u64> = Vec::new();
        forall(10, |g| second.push(g.u64()));
        assert_eq!(first, second);
    }

    #[test]
    fn usize_in_respects_bounds() {
        forall(100, |g| {
            let x = g.usize_in(3, 10);
            assert!((3..10).contains(&x));
        });
    }
}
