//! Test-support substrates (the image vendors no proptest/quickcheck).

pub mod prop;
