//! Clock abstraction: the scheduler and provider are written against
//! `Clock` so the identical policy code runs under the discrete-event
//! simulator (virtual ms, experiments) and under wall-clock time (the
//! `serve` real-time driver). Times are f64 milliseconds.

use std::cell::Cell;
use std::rc::Rc;
use std::time::Instant;

/// A source of "now" in milliseconds.
pub trait Clock {
    fn now_ms(&self) -> f64;
}

/// Virtual clock for the DES: shared cell advanced by the engine.
#[derive(Debug, Clone)]
pub struct SimClock {
    now: Rc<Cell<f64>>,
}

impl SimClock {
    pub fn new() -> Self {
        SimClock { now: Rc::new(Cell::new(0.0)) }
    }

    /// Advance to an absolute time. The engine enforces monotonicity; a
    /// backwards set is a bug.
    pub fn advance_to(&self, t: f64) {
        debug_assert!(t >= self.now.get(), "clock moved backwards: {} -> {t}", self.now.get());
        self.now.set(t);
    }
}

impl Default for SimClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for SimClock {
    fn now_ms(&self) -> f64 {
        self.now.get()
    }
}

/// Wall-clock time since construction, optionally scaled (e.g. 0.1 =
/// 10× faster than real time for demos).
#[derive(Debug, Clone)]
pub struct RealClock {
    epoch: Instant,
    scale: f64,
}

impl RealClock {
    pub fn new() -> Self {
        RealClock { epoch: Instant::now(), scale: 1.0 }
    }

    /// `scale` > 1 stretches virtual ms per wall ms (slower); < 1 compresses.
    pub fn scaled(scale: f64) -> Self {
        assert!(scale > 0.0);
        RealClock { epoch: Instant::now(), scale }
    }

    /// Convert a duration in model-ms to wall-clock ms.
    pub fn to_wall_ms(&self, model_ms: f64) -> f64 {
        model_ms * self.scale
    }
}

impl Default for RealClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for RealClock {
    fn now_ms(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64() * 1000.0 / self.scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_clock_advances() {
        let c = SimClock::new();
        assert_eq!(c.now_ms(), 0.0);
        c.advance_to(10.5);
        assert_eq!(c.now_ms(), 10.5);
        let c2 = c.clone();
        c2.advance_to(20.0);
        assert_eq!(c.now_ms(), 20.0, "clones share the cell");
    }

    #[test]
    #[should_panic(expected = "clock moved backwards")]
    #[cfg(debug_assertions)]
    fn sim_clock_rejects_backwards() {
        let c = SimClock::new();
        c.advance_to(5.0);
        c.advance_to(1.0);
    }

    #[test]
    fn real_clock_progresses() {
        let c = RealClock::new();
        let a = c.now_ms();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(c.now_ms() > a);
    }

    #[test]
    fn real_clock_scaling() {
        let c = RealClock::scaled(0.5);
        assert_eq!(c.to_wall_ms(100.0), 50.0);
    }
}
