//! Request model: what the client knows (prompt features, priors, SLOs),
//! what only the provider knows (true output tokens), and lifecycle state.

/// Request identifier — index into the run's request table.
pub type ReqId = usize;

/// Output-token buckets, paper §4.1/§4.2. Bounds are inclusive and mirror
/// `python/compile/datagen.py::BUCKETS` (asserted against
/// `predictor_meta.json` at runtime-load).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum TokenBucket {
    Short,
    Medium,
    Long,
    XLong,
}

impl TokenBucket {
    pub const ALL: [TokenBucket; 4] =
        [TokenBucket::Short, TokenBucket::Medium, TokenBucket::Long, TokenBucket::XLong];

    /// Inclusive token bounds.
    pub fn bounds(self) -> (u32, u32) {
        match self {
            TokenBucket::Short => (8, 64),
            TokenBucket::Medium => (65, 256),
            TokenBucket::Long => (257, 1024),
            TokenBucket::XLong => (1025, 4096),
        }
    }

    /// Classify a realized/predicted token count.
    pub fn from_tokens(tokens: f64) -> TokenBucket {
        if tokens <= 64.0 {
            TokenBucket::Short
        } else if tokens <= 256.0 {
            TokenBucket::Medium
        } else if tokens <= 1024.0 {
            TokenBucket::Long
        } else {
            TokenBucket::XLong
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TokenBucket::Short => "short",
            TokenBucket::Medium => "medium",
            TokenBucket::Long => "long",
            TokenBucket::XLong => "xlong",
        }
    }

    pub fn parse(s: &str) -> Option<TokenBucket> {
        match s {
            "short" => Some(TokenBucket::Short),
            "medium" => Some(TokenBucket::Medium),
            "long" => Some(TokenBucket::Long),
            "xlong" => Some(TokenBucket::XLong),
            _ => None,
        }
    }

    pub fn index(self) -> usize {
        match self {
            TokenBucket::Short => 0,
            TokenBucket::Medium => 1,
            TokenBucket::Long => 2,
            TokenBucket::XLong => 3,
        }
    }

    /// Geometric midpoint of the bucket — the "class-only" neutral estimate
    /// when per-request magnitude is unavailable within a known bucket.
    pub fn geo_mid(self) -> f64 {
        let (lo, hi) = self.bounds();
        ((lo as f64).ln() * 0.5 + (hi as f64).ln() * 0.5).exp()
    }

    /// The scheduler's two routing lanes (paper §3.1: "short versus
    /// heavy"). Shorts ride the protected interactive lane; everything
    /// else goes through the heavy lane, whose intra-class ordering
    /// (feasible-set) favors older/smaller jobs — which is how mediums get
    /// ahead of xlongs *within* the lane.
    pub fn class(self) -> Class {
        match self {
            TokenBucket::Short => Class::Interactive,
            _ => Class::Heavy,
        }
    }
}

/// Allocation-layer routing class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Class {
    Interactive,
    Heavy,
}

impl Class {
    pub const ALL: [Class; 2] = [Class::Interactive, Class::Heavy];

    pub fn name(self) -> &'static str {
        match self {
            Class::Interactive => "interactive",
            Class::Heavy => "heavy",
        }
    }

    pub fn index(self) -> usize {
        match self {
            Class::Interactive => 0,
            Class::Heavy => 1,
        }
    }
}

/// Task types from the shared generative model (feature one-hot lanes 2–5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    Chat,
    Summarize,
    Code,
    Extract,
}

impl Task {
    pub const ALL: [Task; 4] = [Task::Chat, Task::Summarize, Task::Code, Task::Extract];

    pub fn index(self) -> usize {
        match self {
            Task::Chat => 0,
            Task::Summarize => 1,
            Task::Code => 2,
            Task::Extract => 3,
        }
    }

    pub fn from_index(i: usize) -> Task {
        Task::ALL[i]
    }

    pub fn name(self) -> &'static str {
        match self {
            Task::Chat => "chat",
            Task::Summarize => "summarize",
            Task::Code => "code",
            Task::Extract => "extract",
        }
    }
}

/// Policy-facing output-length prior (the semi-clairvoyant signal),
/// extended to an *interval* prior: the point quantiles plus a calibrated
/// prediction width (± tokens at one sigma) that uncertainty-aware
/// orderings may hedge on.
/// Invariant: `p90 >= p50 > 0` and `width >= 0` — enforced by the
/// constructors and by the quantile-head kernel's gap parameterization.
/// Point priors carry `width == 0.0`, so every policy that ignores width
/// (and every pre-interval table) is bit-identical to the point world.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Priors {
    /// Median output-token estimate.
    pub p50: f64,
    /// 90th-percentile output-token estimate.
    pub p90: f64,
    /// Calibrated one-sigma prediction half-width in tokens; `0.0` means
    /// the source claims a point estimate (oracle, or a pre-interval
    /// source that never set it).
    pub width: f64,
}

impl Priors {
    /// Point prior: quantiles only, `width = 0.0`.
    pub fn new(p50: f64, p90: f64) -> Priors {
        let p50 = p50.max(1.0);
        Priors { p50, p90: p90.max(p50), width: 0.0 }
    }

    /// Interval prior: quantiles plus a calibrated prediction half-width.
    pub fn with_width(p50: f64, p90: f64, width: f64) -> Priors {
        let mut p = Priors::new(p50, p90);
        p.width = width.max(0.0);
        p
    }

    /// The bucket this prior routes to (used by tiered overload + routing
    /// in the coarse/oracle ladder conditions).
    pub fn bucket(&self) -> TokenBucket {
        TokenBucket::from_tokens(self.p50)
    }

    /// Scale both quantiles — and the width, which is in the same token
    /// units (predictor-noise sweep §4.10).
    pub fn scaled(&self, factor: f64) -> Priors {
        Priors::with_width(self.p50 * factor, self.p90 * factor, self.width * factor)
    }

    /// Width-demoted cost: `p50 + theta·width`. Robust-SJF's sort key —
    /// a wide interval inflates the effective size estimate, so uncertain
    /// requests yield to confidently-small ones.
    pub fn robust_cost(&self, theta: f64) -> f64 {
        self.p50 + theta * self.width
    }
}

/// Request lifecycle as seen by the client.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestStatus {
    /// Waiting in a client-side queue.
    Queued,
    /// Deferred by overload control; retry scheduled.
    Deferred,
    /// Submitted to the provider, awaiting completion.
    InFlight,
    /// Finished; latency recorded.
    Completed,
    /// Explicitly shed by overload control.
    Rejected,
    /// Gave up (client-side timeout) — implicit failure.
    TimedOut,
}

/// One request. Fields above the line are client-observable at submission
/// time; `true_output_tokens` is the provider-side ground truth that only
/// the mock physics (and the oracle ladder condition) may read.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: ReqId,
    pub arrival_ms: f64,
    pub prompt_tokens: u32,
    pub task: Task,
    pub temperature: f64,
    pub max_tokens: u32,
    /// Deadline for SLO satisfaction, absolute ms.
    pub deadline_ms: f64,
    /// Hard client-side give-up time, absolute ms.
    pub timeout_ms: f64,
    // ---- hidden ground truth (mock provider + oracle only) ----
    pub true_output_tokens: u32,
    pub true_bucket: TokenBucket,
}

impl Request {
    /// Deadline slack remaining at `now` (negative = already late).
    pub fn slack(&self, now: f64) -> f64 {
        self.deadline_ms - now
    }

    pub fn wait(&self, now: f64) -> f64 {
        (now - self.arrival_ms).max(0.0)
    }
}

/// Per-bucket SLO policy: relative deadline and hard timeout from arrival.
#[derive(Debug, Clone)]
pub struct SloPolicy {
    /// Relative deadlines per bucket index (ms from arrival).
    pub deadline_ms: [f64; 4],
    /// Hard timeout as a multiple of the deadline.
    pub timeout_factor: f64,
}

impl Default for SloPolicy {
    fn default() -> Self {
        // Interactive work gets tight deadlines; heavy work generous ones.
        // Chosen so the paper's joint-metric bands are reachable (see
        // `docs/EXPERIMENTS.md` §calibration).
        SloPolicy { deadline_ms: [2_500.0, 8_000.0, 20_000.0, 40_000.0], timeout_factor: 1.2 }
    }
}

impl SloPolicy {
    pub fn deadline_for(&self, bucket: TokenBucket) -> f64 {
        self.deadline_ms[bucket.index()]
    }

    pub fn timeout_for(&self, bucket: TokenBucket) -> f64 {
        self.deadline_for(bucket) * self.timeout_factor
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_from_tokens_edges() {
        assert_eq!(TokenBucket::from_tokens(1.0), TokenBucket::Short);
        assert_eq!(TokenBucket::from_tokens(64.0), TokenBucket::Short);
        assert_eq!(TokenBucket::from_tokens(65.0), TokenBucket::Medium);
        assert_eq!(TokenBucket::from_tokens(256.0), TokenBucket::Medium);
        assert_eq!(TokenBucket::from_tokens(257.0), TokenBucket::Long);
        assert_eq!(TokenBucket::from_tokens(1024.0), TokenBucket::Long);
        assert_eq!(TokenBucket::from_tokens(1025.0), TokenBucket::XLong);
        assert_eq!(TokenBucket::from_tokens(99999.0), TokenBucket::XLong);
    }

    #[test]
    fn bucket_name_roundtrip() {
        for b in TokenBucket::ALL {
            assert_eq!(TokenBucket::parse(b.name()), Some(b));
        }
        assert_eq!(TokenBucket::parse("huge"), None);
    }

    #[test]
    fn class_routing() {
        assert_eq!(TokenBucket::Short.class(), Class::Interactive);
        assert_eq!(TokenBucket::Medium.class(), Class::Heavy);
        assert_eq!(TokenBucket::Long.class(), Class::Heavy);
        assert_eq!(TokenBucket::XLong.class(), Class::Heavy);
    }

    #[test]
    fn geo_mid_inside_bounds() {
        for b in TokenBucket::ALL {
            let (lo, hi) = b.bounds();
            let mid = b.geo_mid();
            assert!(mid > lo as f64 && mid < hi as f64, "{b:?} mid={mid}");
        }
    }

    #[test]
    fn priors_enforce_monotonicity() {
        let p = Priors::new(100.0, 50.0);
        assert_eq!(p.p90, p.p50);
        let p = Priors::new(-5.0, -10.0);
        assert!(p.p50 >= 1.0 && p.p90 >= p.p50);
        let p = Priors::new(10.0, 20.0).scaled(3.0);
        assert_eq!(p.p50, 30.0);
        assert_eq!(p.p90, 60.0);
        assert_eq!(p.width, 0.0, "point priors stay point under scaling");
    }

    #[test]
    fn interval_priors_width() {
        let p = Priors::with_width(100.0, 200.0, 40.0);
        assert_eq!(p.width, 40.0);
        assert_eq!(p.robust_cost(0.0), 100.0);
        assert_eq!(p.robust_cost(1.0), 140.0);
        let s = p.scaled(2.0);
        assert_eq!((s.p50, s.p90, s.width), (200.0, 400.0, 80.0));
        // Width can never go negative.
        assert_eq!(Priors::with_width(10.0, 20.0, -5.0).width, 0.0);
        // Point constructor always yields width 0 (the bit-compat anchor).
        assert_eq!(Priors::new(10.0, 20.0).width, 0.0);
    }

    #[test]
    fn slo_policy_ordering() {
        let slo = SloPolicy::default();
        assert!(slo.deadline_for(TokenBucket::Short) < slo.deadline_for(TokenBucket::Medium));
        assert!(slo.deadline_for(TokenBucket::Long) < slo.deadline_for(TokenBucket::XLong));
        assert!(slo.timeout_for(TokenBucket::Short) > slo.deadline_for(TokenBucket::Short));
    }

    #[test]
    fn request_slack_and_wait() {
        let req = Request {
            id: 0,
            arrival_ms: 100.0,
            prompt_tokens: 50,
            task: Task::Chat,
            temperature: 0.5,
            max_tokens: 256,
            deadline_ms: 2_600.0,
            timeout_ms: 5_100.0,
            true_output_tokens: 40,
            true_bucket: TokenBucket::Short,
        };
        assert_eq!(req.wait(150.0), 50.0);
        assert_eq!(req.slack(600.0), 2_000.0);
        assert_eq!(req.wait(50.0), 0.0);
    }
}
