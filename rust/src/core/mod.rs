//! Core domain types shared by every layer: requests, token buckets,
//! priors, SLOs, and the clock abstraction.

pub mod clock;
pub mod request;

pub use clock::{Clock, RealClock, SimClock};
pub use request::{Class, Priors, ReqId, Request, RequestStatus, SloPolicy, Task, TokenBucket};
