//! `cargo bench --bench table1_ladder` — regenerates Table 1 / Figure 2 (information ladder)
//! end-to-end and reports the wall-clock cost of the experiment.

use blackbox_sched::bench::Suite;
use blackbox_sched::experiments::{self, ExpOpts};

fn main() {
    let mut suite = Suite::new("table1_ladder");
    let opts = ExpOpts {
        seeds: std::env::var("BENCH_SEEDS").ok().and_then(|s| s.parse().ok()).unwrap_or(5),
        out_dir: "target/bench-results/tables".to_string(),
        ..ExpOpts::default()
    };
    suite.bench_n("table1_ladder (full experiment)", 3, || {
        experiments::run_experiment("ladder", &opts).expect("experiment failed");
    });
    suite.finish();
}
