//! `cargo bench --bench ablation` — regenerates the design-choice ablation
//! table end-to-end (ordering / DRR weights / bypass).

use blackbox_sched::bench::Suite;
use blackbox_sched::experiments::{self, ExpOpts};

fn main() {
    let mut suite = Suite::new("ablation");
    let opts = ExpOpts {
        seeds: std::env::var("BENCH_SEEDS").ok().and_then(|s| s.parse().ok()).unwrap_or(5),
        out_dir: "target/bench-results/tables".to_string(),
        ..ExpOpts::default()
    };
    suite.bench_n("ablation (full experiment)", 3, || {
        experiments::run_experiment("ablation", &opts).expect("experiment failed");
    });
    suite.finish();
}
