//! `cargo bench --bench table2_main` — regenerates Table 2 / Figures 3-4 (main policy comparison)
//! end-to-end and reports the wall-clock cost of the experiment.

use blackbox_sched::bench::Suite;
use blackbox_sched::experiments::{self, ExpOpts};

fn main() {
    let mut suite = Suite::new("table2_main");
    let opts = ExpOpts {
        seeds: std::env::var("BENCH_SEEDS").ok().and_then(|s| s.parse().ok()).unwrap_or(5),
        out_dir: "target/bench-results/tables".to_string(),
        ..ExpOpts::default()
    };
    suite.bench_n("table2_main (full experiment)", 3, || {
        experiments::run_experiment("main", &opts).expect("experiment failed");
    });
    suite.finish();
}
