//! `cargo bench --bench hot_paths` — micro-benchmarks of every component on
//! the request path, plus the PJRT predictor when artifacts are present.
//! These are the hot-path numbers behind the `bbsched bench` scaling
//! gates (see `rust/README.md` §Benchmarking).

use blackbox_sched::bench::Suite;
use blackbox_sched::core::{Class, Priors, TokenBucket};
use blackbox_sched::predictor::features::batch_features;
use blackbox_sched::predictor::{InfoLevel, LadderSource, PriorSource, Route};
use blackbox_sched::provider::pool::{PoolCfg, ProviderPool};
use blackbox_sched::provider::{MockProvider, ProviderCfg};
use blackbox_sched::runtime::{artifacts_available, default_artifacts_dir, Predictor};
use blackbox_sched::scheduler::ordering::{FeasibleSet, Ordering, OrderingCfg};
use blackbox_sched::scheduler::queues::{ClassQueues, SchedRequest};
use blackbox_sched::scheduler::{Action, ClientScheduler, SchedulerCfg, StrategyKind};
use blackbox_sched::sim::driver;
use blackbox_sched::sim::EventQueue;
use blackbox_sched::util::rng::Rng;
use blackbox_sched::util::stats::{percentile, percentile_sorted};
use blackbox_sched::workload::{Mix, WorkloadSpec};

fn heavy_sreq(id: usize, arrival: f64, p50: f64) -> SchedRequest {
    SchedRequest {
        id,
        arrival_ms: arrival,
        deadline_ms: arrival + 40_000.0,
        priors: Priors::new(p50, p50 * 1.5),
        route: Route::from_bucket(TokenBucket::Long),
        defer_attempts: 0,
    }
}

fn main() {
    let mut suite = Suite::new("hot_paths");

    // ---- RNG ----
    let mut rng = Rng::new(1);
    suite.bench("rng: next_u64", || {
        std::hint::black_box(rng.next_u64());
    });
    let mut rng2 = Rng::new(2);
    suite.bench("rng: lognormal", || {
        std::hint::black_box(rng2.lognormal(0.0, 0.25));
    });

    // ---- DES event queue ----
    suite.bench("event queue: push+pop (1k queue)", || {
        // steady-state: queue pre-filled once per batch amortized by closure state
        static mut Q: Option<EventQueue<u32>> = None;
        #[allow(static_mut_refs)]
        let q = unsafe {
            if Q.is_none() {
                let mut q = EventQueue::new();
                for i in 0..1000 {
                    q.push(i as f64, i);
                }
                Q = Some(q);
            }
            Q.as_mut().unwrap()
        };
        let (t, v) = q.pop().unwrap();
        q.push(t + 1000.0, v);
    });
    let mut qc: EventQueue<u32> = EventQueue::new();
    for i in 0..1000 {
        qc.push_cancelable(i as f64, i);
    }
    let mut cancel_t = 1000.0;
    suite.bench("event queue: cancelable churn (1k queue)", || {
        // push a timer, cancel it, recycle one live entry — the lazy
        // deletion path plus slot reuse.
        let id = qc.push_cancelable(cancel_t, 0);
        cancel_t += 1.0;
        qc.cancel(id);
        let (t, v) = qc.pop().unwrap();
        qc.push_cancelable(t + 1000.0, v);
    });

    // ---- slab-indexed scheduler queues ----
    let mut cq = ClassQueues::new();
    for id in 0..10_000 {
        cq.push(heavy_sreq(id, id as f64, 300.0));
    }
    let mut next_id = 10_000usize;
    let mut oldest = 0usize;
    suite.bench("queues: push + remove_id (10k deep)", || {
        cq.push(heavy_sreq(next_id, next_id as f64, 300.0));
        next_id += 1;
        std::hint::black_box(cq.remove_id(oldest));
        oldest += 1;
    });
    // Ordering selection: the incremental index vs the retained reference
    // scan, same 1k-deep queue. Pushes drive the lifecycle hooks exactly as
    // the scheduler's slab mutations do.
    let mut fq = ClassQueues::new();
    let mut fsel = FeasibleSet::new(OrderingCfg::default());
    for id in 0..1_000 {
        let r = heavy_sreq(id, id as f64, 100.0 + (id % 29) as f64 * 100.0);
        fsel.on_push(&r, id as f64);
        fq.push(r);
    }
    let mut sel_now = 1_000.0;
    suite.bench("ordering: feasible-set select (1k deep, indexed)", || {
        sel_now += 1.0;
        std::hint::black_box(fsel.select(fq.view(Class::Heavy), sel_now));
    });
    let mut ref_now = 1_000.0;
    suite.bench("ordering: feasible-set reference scan (1k deep)", || {
        ref_now += 1.0;
        std::hint::black_box(fsel.reference_select(fq.view(Class::Heavy), ref_now));
    });

    // ---- provider ----
    let mut provider = MockProvider::new(ProviderCfg::default(), Rng::new(3));
    let mut i = 0usize;
    suite.bench("provider: submit+finish", || {
        if let Some(_s) = provider.submit(i, 500.0, i as f64) {
            provider.on_finish(i as f64 + 1.0);
        }
        i += 1;
    });

    // ---- provider pool (sharded dispatch path) ----
    let mut pool = ProviderPool::new(&PoolCfg::split(ProviderCfg::default(), 4), Rng::new(3));
    let mut pi = 0usize;
    let mut batch: Vec<(usize, f64, usize)> = Vec::new();
    let mut started = Vec::new();
    suite.bench("pool: 8-submit batch + finishes (4 shards)", || {
        batch.clear();
        for k in 0..8usize {
            batch.push((pi + k, 500.0, k % 4));
        }
        started.clear();
        pool.submit_batch(&batch, pi as f64, &mut started);
        for s in &started {
            std::hint::black_box(s.finish_ms);
        }
        for k in 0..8usize {
            pool.on_finish(pi + k, pi as f64 + 1.0);
        }
        pi += 8;
    });

    // ---- dynamic window bound: earliest committed finish over the pool ----
    // The partitioned executor's coordinator asks this once per shard per
    // window; fill a 16-shard fleet to capacity (64 running per shard) so
    // the query walks deep pending sets.
    let mut epool = ProviderPool::new(&PoolCfg::split(ProviderCfg::default(), 16), Rng::new(7));
    epool.set_finish_tracking(true);
    let mut ebatch: Vec<(usize, f64, usize)> = Vec::new();
    let mut estarted = Vec::new();
    for b in 0..128usize {
        ebatch.clear();
        for k in 0..8usize {
            let id = b * 8 + k;
            ebatch.push((id, 400.0 + 40.0 * k as f64, id % 16));
        }
        estarted.clear();
        epool.submit_batch(&ebatch, b as f64, &mut estarted);
    }
    suite.bench("pool: earliest_pending_finish (16 shards, 1k in flight)", || {
        std::hint::black_box(epool.earliest_pending_finish());
    });
    suite.bench("pool: shard_earliest_pending_finish (64 in flight)", || {
        std::hint::black_box(epool.shard_earliest_pending_finish(3));
    });

    // ---- prior sources ----
    let reqs = WorkloadSpec::new(Mix::Balanced, 4096, 50.0).generate(5);
    let mut src = LadderSource::new(InfoLevel::Coarse, Rng::new(9));
    let mut k = 0usize;
    suite.bench("priors: coarse ladder per-request", || {
        std::hint::black_box(src.priors(&reqs[k % reqs.len()]));
        k += 1;
    });

    // ---- scheduler decision path ----
    let mut j = 0usize;
    let mut sched = ClientScheduler::new(SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc));
    let mut ladder = LadderSource::new(InfoLevel::Coarse, Rng::new(11));
    let mut actions: Vec<Action> = Vec::new();
    let mut drain: Vec<Action> = Vec::new();
    suite.bench("scheduler: arrival→actions (Final OLC)", || {
        let r = &reqs[j % reqs.len()];
        let (p, route) = ladder.priors(r);
        actions.clear();
        sched.on_arrival(r, p, route, j as f64, &mut actions);
        // Drain sends so in-flight doesn't saturate: fake completions.
        for a in &actions {
            if let Action::Send { id, .. } = *a {
                drain.clear();
                sched.on_completion(id, 200.0, 2500.0, j as f64 + 1.0, &mut drain);
            }
        }
        j += 1;
    });

    // ---- end-to-end DES run ----
    let requests = WorkloadSpec::new(Mix::Heavy, 200, 14.0).generate(1);
    suite.bench_n("end-to-end: 200-request heavy/high run", 20, || {
        let mut src = LadderSource::new(InfoLevel::Coarse, Rng::new(1).derive("priors"));
        let out = driver::run(
            &requests,
            &mut src,
            SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc),
            ProviderCfg::default(),
            1,
        );
        std::hint::black_box(out.metrics.goodput_rps);
    });

    // ---- metrics ----
    let lat: Vec<f64> = (0..10_000).map(|i| (i as f64 * 37.7) % 5000.0).collect();
    suite.bench("metrics: p95 over 10k samples", || {
        std::hint::black_box(percentile(&lat, 95.0));
    });
    let mut sorted = lat.clone();
    sorted.sort_unstable_by(f64::total_cmp);
    suite.bench("metrics: p95 presorted (one-sort path)", || {
        std::hint::black_box(percentile_sorted(&sorted, 95.0));
    });

    // ---- PJRT predictor (feature- and artifact-gated) ----
    let dir = default_artifacts_dir();
    if cfg!(feature = "pjrt") && artifacts_available(&dir) {
        let predictor = Predictor::load(&dir).expect("artifacts present but unloadable");
        let refs: Vec<&blackbox_sched::Request> = reqs.iter().take(512).collect();
        let feats512 = batch_features(&refs, 512);
        suite.bench_n("pjrt: predict batch=512", 50, || {
            let out = predictor.predict(&feats512, 512).unwrap();
            std::hint::black_box(out[0].p50);
        });
        let feats1 = batch_features(&refs[..1], 1);
        suite.bench_n("pjrt: predict batch=1 (padded 128)", 200, || {
            let out = predictor.predict(&feats1, 1).unwrap();
            std::hint::black_box(out[0].p50);
        });
    } else {
        println!("(skipping PJRT benches: build with --features pjrt and run `make artifacts`)");
    }

    suite.finish();
}
