//! `cargo bench --bench hot_paths` — micro-benchmarks of every component on
//! the request path, plus the PJRT predictor when artifacts are present.
//! These are the numbers tracked in EXPERIMENTS.md §Perf.

use blackbox_sched::bench::Suite;
use blackbox_sched::core::{Class, Priors};
use blackbox_sched::predictor::features::batch_features;
use blackbox_sched::predictor::{InfoLevel, LadderSource, PriorSource};
use blackbox_sched::provider::{MockProvider, ProviderCfg};
use blackbox_sched::runtime::{artifacts_available, default_artifacts_dir, Predictor};
use blackbox_sched::scheduler::{Action, ClientScheduler, SchedulerCfg, StrategyKind};
use blackbox_sched::sim::driver;
use blackbox_sched::sim::EventQueue;
use blackbox_sched::util::rng::Rng;
use blackbox_sched::util::stats::percentile;
use blackbox_sched::workload::{Mix, WorkloadSpec};

fn main() {
    let mut suite = Suite::new("hot_paths");

    // ---- RNG ----
    let mut rng = Rng::new(1);
    suite.bench("rng: next_u64", || {
        std::hint::black_box(rng.next_u64());
    });
    let mut rng2 = Rng::new(2);
    suite.bench("rng: lognormal", || {
        std::hint::black_box(rng2.lognormal(0.0, 0.25));
    });

    // ---- DES event queue ----
    suite.bench("event queue: push+pop (1k queue)", || {
        // steady-state: queue pre-filled once per batch amortized by closure state
        static mut Q: Option<EventQueue<u32>> = None;
        #[allow(static_mut_refs)]
        let q = unsafe {
            if Q.is_none() {
                let mut q = EventQueue::new();
                for i in 0..1000 {
                    q.push(i as f64, i);
                }
                Q = Some(q);
            }
            Q.as_mut().unwrap()
        };
        let (t, v) = q.pop().unwrap();
        q.push(t + 1000.0, v);
    });

    // ---- provider ----
    let mut provider = MockProvider::new(ProviderCfg::default(), Rng::new(3));
    let mut i = 0usize;
    suite.bench("provider: submit+finish", || {
        if let Some(_s) = provider.submit(i, 500.0, i as f64) {
            provider.on_finish(i as f64 + 1.0);
        }
        i += 1;
    });

    // ---- prior sources ----
    let reqs = WorkloadSpec::new(Mix::Balanced, 4096, 50.0).generate(5);
    let mut src = LadderSource::new(InfoLevel::Coarse, Rng::new(9));
    let mut k = 0usize;
    suite.bench("priors: coarse ladder per-request", || {
        std::hint::black_box(src.priors(&reqs[k % reqs.len()]));
        k += 1;
    });

    // ---- scheduler decision path ----
    let mut j = 0usize;
    let mut sched = ClientScheduler::new(SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc));
    let mut ladder = LadderSource::new(InfoLevel::Coarse, Rng::new(11));
    suite.bench("scheduler: arrival→actions (Final OLC)", || {
        let r = &reqs[j % reqs.len()];
        let (p, route) = ladder.priors(r);
        let actions = sched.on_arrival(r, p, route, j as f64);
        // Drain sends so in-flight doesn't saturate: fake completions.
        for a in actions {
            if let Action::Send { id } = a {
                sched.on_completion(id, 200.0, 2500.0, j as f64 + 1.0);
            }
        }
        j += 1;
    });

    // ---- end-to-end DES run ----
    let requests = WorkloadSpec::new(Mix::Heavy, 200, 14.0).generate(1);
    suite.bench_n("end-to-end: 200-request heavy/high run", 20, || {
        let mut src = LadderSource::new(InfoLevel::Coarse, Rng::new(1).derive("priors"));
        let out = driver::run(
            &requests,
            &mut src,
            SchedulerCfg::for_strategy(StrategyKind::FinalAdrrOlc),
            ProviderCfg::default(),
            1,
        );
        std::hint::black_box(out.metrics.goodput_rps);
    });

    // ---- metrics ----
    let mut lat: Vec<f64> = (0..10_000).map(|i| (i as f64 * 37.7) % 5000.0).collect();
    suite.bench("metrics: p95 over 10k samples", || {
        std::hint::black_box(percentile(&lat, 95.0));
    });
    lat.truncate(10_000);

    // ---- PJRT predictor (feature- and artifact-gated) ----
    let dir = default_artifacts_dir();
    if cfg!(feature = "pjrt") && artifacts_available(&dir) {
        let predictor = Predictor::load(&dir).expect("artifacts present but unloadable");
        let refs: Vec<&blackbox_sched::Request> = reqs.iter().take(512).collect();
        let feats512 = batch_features(&refs, 512);
        suite.bench_n("pjrt: predict batch=512", 50, || {
            let out = predictor.predict(&feats512, 512).unwrap();
            std::hint::black_box(out[0].p50);
        });
        let feats1 = batch_features(&refs[..1], 1);
        suite.bench_n("pjrt: predict batch=1 (padded 128)", 200, || {
            let out = predictor.predict(&feats1, 1).unwrap();
            std::hint::black_box(out[0].p50);
        });
    } else {
        println!("(skipping PJRT benches: build with --features pjrt and run `make artifacts`)");
    }

    let _ = Class::Interactive; // keep import for doc symmetry
    let _ = Priors::new(1.0, 2.0);
    suite.finish();
}
